"""Correctness of the §Perf optimization paths against their baselines:
every optimized implementation must reproduce the baseline numerics (exact
paths) or be a documented approximation with finite gradients."""
import os
import subprocess
import sys
import textwrap
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.base import LMConfig


def test_grouped_moe_matches_global_when_dropless():
    from repro.models import transformer as T
    base = LMConfig(name="t", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                    d_ff=16, vocab=50, moe=True, n_routed=8, n_shared=1, top_k=2,
                    first_dense_layers=0, capacity_factor=8.0, dtype="float32",
                    router_aux_coef=0.0)  # aux estimator differs per group
    params, _ = T.init(jax.random.key(0), base)
    toks = jax.random.randint(jax.random.key(1), (4, 8), 0, 50)
    batch = {"tokens": toks, "labels": toks}
    l0, _ = T.loss_fn(params, base, batch)
    l1, _ = T.loss_fn(params, dataclasses.replace(base, moe_groups=4), batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


def test_lm_fused_ce_matches_standard():
    from repro.models import transformer as T
    cfg = get_arch("qwen2-1.5b").smoke()
    params, _ = T.init(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    l0, _ = T.loss_fn(params, cfg, batch)
    l1, _ = T.loss_fn(params, dataclasses.replace(cfg, fused_ce=32), batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5, atol=1e-5)
    g = jax.grad(lambda p: T.loss_fn(
        p, dataclasses.replace(cfg, fused_ce=32), batch)[0])(params)
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(g))


def test_bert4rec_fused_ce_exact_and_sampled_trains():
    from repro.models import bert4rec
    from repro.data import MaskedSequenceStream
    from repro.train import TrainConfig, build_train_step, init_state
    from repro.optim.adamw import AdamWConfig
    cfg = get_arch("bert4rec").smoke()
    p, _ = bert4rec.init(jax.random.key(0), cfg)
    b = MaskedSequenceStream(cfg.n_items, 4, cfg.seq_len, seed=0)(0)
    l0, _ = bert4rec.loss_fn(p, cfg, b)
    l1, _ = bert4rec.loss_fn(p, dataclasses.replace(cfg, fused_ce=128), b)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
    # sampled softmax: approximation, must train
    scfg = dataclasses.replace(cfg, n_negatives=128)
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3))
    state, _ = init_state(jax.random.key(0), scfg, tc)
    step = jax.jit(build_train_step(scfg, tc))
    stream = MaskedSequenceStream(scfg.n_items, 8, scfg.seq_len, seed=0)
    losses = []
    for i in range(4):
        state, metrics = step(state, stream(i))
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses)


def test_blockwise_attention_matches_ref():
    from repro.kernels import ref
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 4, 300, 32)) * 0.4, jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 2, 300, 32)) * 0.4, jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 2, 300, 48)) * 0.4, jnp.float32)
    got = ref.attention_blockwise(q, k, v, causal=True, block_k=128)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_distributed_pna_matches_single_device():
    """shard_map message passing over the edge partition == gnn.apply.
    Runs in a subprocess with 4 forced host devices."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax, jax.numpy as jnp
        from repro.graph import generators as gen
        from repro.configs import get_arch
        from repro.models import gnn, gnn_distributed as gd
        g = gen.erdos_renyi_graph(80, 5.0, seed=2, n_labels=4)
        cfg = get_arch("pna").smoke()
        mesh = jax.make_mesh((4,), ("data",))
        params, _ = gnn.init(jax.random.key(0), cfg, 8, 4)
        batch, feats, part = gd.partitioned_batch_from_graph(g, 8, 4, 4, seed=0)
        loss_fn = gd.build_distributed_pna_loss(cfg, mesh, ("data",), part.n_local)
        ld, _ = jax.jit(loss_fn)(params, batch)
        nl = part.n_local
        ids = np.arange(g.n)
        full = {"x": jnp.asarray(feats), "src": jnp.asarray(g.src),
                "dst": jnp.asarray(g.dst), "labels": jnp.asarray(g.labels % 4),
                "train_mask": jnp.asarray(np.asarray(batch["train_mask"])[ids//nl, ids%nl]),
                "log_deg_avg": float(batch["log_deg_avg"])}
        ls, _ = gnn.loss_fn(params, cfg, full)
        assert abs(float(ld) - float(ls)) < 1e-4, (float(ld), float(ls))
        print("PARITY_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "PARITY_OK" in out.stdout, out.stdout + out.stderr


try:  # optional dev dependency: the property test degrades to a skip
    from hypothesis import given, settings, strategies as st, HealthCheck
except ImportError:
    given = None


def _nlcc_edge_prune_fast_path_exact(seed, n_labels, cyc_len):
    """Beyond-paper claim: CC + forward-backward frontier edge pruning yields
    the exact solution subgraph for unique-label cycle templates WITHOUT the
    complete-walk TDS. Property-tested against the brute-force oracle."""
    from repro.graph import generators as gen
    from repro.core.template import Template
    from repro.core.pipeline import prune
    from repro.core.oracle import solution_subgraph_oracle
    import numpy as np

    g = gen.erdos_renyi_graph(90, 5.0, seed=seed, n_labels=n_labels)
    labels = list(range(cyc_len)) if cyc_len <= n_labels else list(range(n_labels)) + list(range(cyc_len - n_labels))
    if len(set(labels)) < cyc_len:
        labels = list(range(cyc_len))  # unique labels (may exceed graph's set)
    edges = [(i, (i + 1) % cyc_len) for i in range(cyc_len)]
    tmpl = Template(labels, edges)
    res = prune(g, tmpl, nlcc_edge_prune=True)
    assert res.stats.get("tds_skipped_via_frontier_edge_prune") is True
    vm, em, om, _ = solution_subgraph_oracle(g, tmpl)
    order = np.lexsort((g.src, g.dst))
    assert np.array_equal(res.vertex_mask, vm)
    assert np.array_equal(res.edge_mask, em[order])
    assert np.array_equal(res.omega, om)


if given is not None:
    @settings(max_examples=12, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(seed=st.integers(0, 10_000), n_labels=st.integers(3, 6),
           cyc_len=st.integers(3, 6))
    def test_nlcc_edge_prune_fast_path_exact(seed, n_labels, cyc_len):
        _nlcc_edge_prune_fast_path_exact(seed, n_labels, cyc_len)
else:
    def test_nlcc_edge_prune_fast_path_exact():
        pytest.importorskip("hypothesis")


def test_nlcc_edge_prune_cactus_exact():
    """The fast path also holds for cacti (edge-monocyclic, unique labels)."""
    from repro.graph import generators as gen
    from repro.core.template import Template
    from repro.core.pipeline import prune
    from repro.core.oracle import solution_subgraph_oracle
    import numpy as np

    # two triangles joined by a path + a pendant: a classic cactus
    tmpl = Template(
        [0, 1, 2, 3, 4, 5, 6],
        [(0, 1), (1, 2), (2, 0), (2, 3), (3, 4), (4, 5), (5, 3), (4, 6)])
    for seed in (0, 3, 7):
        g = gen.erdos_renyi_graph(140, 6.5, seed=seed, n_labels=7)
        res = prune(g, tmpl, nlcc_edge_prune=True)
        vm, em, om, _ = solution_subgraph_oracle(g, tmpl)
        order = np.lexsort((g.src, g.dst))
        assert np.array_equal(res.vertex_mask, vm)
        assert np.array_equal(res.edge_mask, em[order])


def test_hlo_cost_counts_loop_trips():
    from repro.launch.hlo_cost import analyze

    def scanned(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, None, length=8)[0]

    c = jax.jit(scanned).lower(
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile()
    r = analyze(c.as_text())
    want = 8 * 2 * 256 ** 3
    assert 0.95 * want < r["flops_per_device"] < 1.1 * want


def test_hlo_cost_charges_gather_slices():
    from repro.launch.hlo_cost import analyze

    def emb(t, ids):
        return jnp.take(t, ids, axis=0).sum()

    c = jax.jit(emb).lower(
        jax.ShapeDtypeStruct((100000, 128), jnp.float32),
        jax.ShapeDtypeStruct((64,), jnp.int32)).compile()
    r = analyze(c.as_text())
    assert r["bytes_per_device"] < 1e6  # slices, not the 51MB table

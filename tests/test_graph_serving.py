"""Graph-query serving engine (serve/graph_query.py): admission, bucketed
batching under max-wait/max-batch, deadlines, streamed emission.

Driven with a fake clock throughout — batching and deadline decisions are
asserted exactly, never timed.
"""
import numpy as np
import pytest

from repro.graph import rmat_graph
from repro.core import Template, prune, count_matches
from repro.core.batch import STATUS_OK, STATUS_DEADLINE_MISSED
from repro.serve import (GraphQueryEngine, example_workload,
                         MODE_PRUNE, MODE_COUNT, MODE_STREAM)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _graph():
    return rmat_graph(8, edge_factor=6, seed=3)


def _engine(g=None, **kw):
    clock = FakeClock()
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_wait_s", 1.0)
    return GraphQueryEngine(g if g is not None else _graph(),
                            clock=clock, **kw), clock


def test_batcher_waits_then_launches_on_max_wait():
    eng, clock = _engine()
    t = Template([4, 3, 3], [(0, 1), (1, 2), (2, 0)])
    eng.submit(t)
    assert eng.pump() == []  # not full, not overdue -> keeps waiting
    assert eng.n_pending == 1
    clock.t = 1.5  # oldest query is now past max_wait_s
    out = eng.pump()
    assert len(out) == 1 and out[0].status == STATUS_OK
    assert out[0].batch_size == 1
    assert eng.n_pending == 0


def test_batcher_launches_full_batch_immediately():
    eng, _ = _engine(max_batch=2)
    t = Template([4, 3, 3], [(0, 1), (1, 2), (2, 0)])
    eng.submit(t)
    eng.submit(t)
    out = eng.pump()  # full batch -> no waiting
    assert len(out) == 2
    assert {r.batch_size for r in out} == {2}
    assert eng.stats["n_batches"] == 1


def test_batcher_groups_by_shape_bucket():
    """Different-bucket templates never share a batch; same-bucket ones do."""
    eng, clock = _engine(max_batch=8)
    small = Template([5, 4], [(0, 1)])                      # bucket 2
    big = Template([5, 4, 3, 2], [(0, 1), (1, 2), (2, 3)])  # bucket 4
    ids = [eng.submit(x) for x in (big, small, big, small)]
    clock.t = 2.0
    out = eng.pump()
    assert len(out) == 4
    by_id = {r.query_id: r for r in out}
    assert by_id[ids[0]].batch_id == by_id[ids[2]].batch_id
    assert by_id[ids[1]].batch_id == by_id[ids[3]].batch_id
    assert by_id[ids[0]].batch_id != by_id[ids[1]].batch_id
    assert eng.stats["n_batches"] == 2


def test_queued_deadline_cancellation_skips_execution():
    """A query whose deadline passes while queued is emitted deadline_missed
    without device time; batchmates run normally."""
    eng, clock = _engine()
    t = Template([4, 3, 3], [(0, 1), (1, 2), (2, 0)])
    qid_dead = eng.submit(t, timeout_s=0.5)
    qid_live = eng.submit(t)
    clock.t = 2.0
    out = eng.pump()
    by_id = {r.query_id: r for r in out}
    assert by_id[qid_dead].status == STATUS_DEADLINE_MISSED
    assert by_id[qid_dead].batch_id is None  # cancelled in queue, not run
    assert by_id[qid_live].status == STATUS_OK
    assert eng.stats["n_deadline_missed"] == 1


def test_count_mode_matches_standalone_prune():
    g = _graph()
    eng, clock = _engine(g)
    t = Template([5, 4, 3, 2], [(0, 1), (1, 2), (2, 3)])
    qid = eng.submit(t, mode=MODE_COUNT)
    clock.t = 2.0
    (r,) = eng.pump()
    seq = prune(g, t)
    want = int(count_matches(seq.dg, seq.state, t).n_embeddings)
    assert r.n_embeddings == want
    np.testing.assert_array_equal(
        np.asarray(eng.result(qid).result.state.omega),
        np.asarray(seq.state.omega))


def test_stream_emission():
    """MODE_STREAM queries emit embedding blocks identical to the standalone
    enumeration of the sequentially pruned subgraph."""
    g = _graph()
    eng, clock = _engine(g)
    t = Template([5, 4, 3, 2], [(0, 1), (1, 2), (2, 3)])
    qid = eng.submit(t, mode=MODE_STREAM)
    clock.t = 2.0
    eng.pump()
    rows = [b for b in eng.stream(qid, chunk=64)]
    got = (np.concatenate(rows) if rows
           else np.empty((0, t.n0), np.int32))
    from repro.core import enumerate_matches
    seq = prune(g, t)
    want = enumerate_matches(seq.dg, seq.state, t).embeddings
    got = got[np.lexsort(got.T[::-1])]
    want = want[np.lexsort(want.T[::-1])]
    np.testing.assert_array_equal(got, want)


def test_stream_of_deadline_missed_query_is_empty():
    eng, clock = _engine()
    t = Template([4, 3, 3], [(0, 1), (1, 2), (2, 0)])
    qid = eng.submit(t, mode=MODE_STREAM, timeout_s=0.1)
    clock.t = 5.0
    eng.pump()
    assert list(eng.stream(qid)) == []


def test_drain_32_query_workload_zero_dropped():
    """Acceptance: a 32-query mixed-template workload drains completely —
    every submitted query gets a result and none is dropped (the only
    non-ok status possible is an explicit deadline miss; here none)."""
    g = _graph()
    eng, clock = _engine(g, max_batch=8)
    templates = example_workload(32, seed=1,
                                 labels_max=int(g.labels.max()))
    ids = [eng.submit(t, mode=MODE_PRUNE) for t in templates]
    results = eng.drain()
    assert len(results) == 32
    assert eng.n_pending == 0
    assert {r.query_id for r in results} == set(ids)
    assert all(r.status == STATUS_OK for r in results)
    assert eng.stats["n_completed"] == 32
    assert eng.stats["n_deadline_missed"] == 0
    # batches actually formed (not 32 singleton launches)
    assert eng.stats["n_batches"] <= 8
    assert max(b["B"] for b in eng.stats["batches"]) == 8


def test_policy_cache_routing_at_startup(tmp_path):
    """A tuned dispatch-policy cache passed at engine startup drives batched
    route resolution (b<B>-prefixed bucket keys)."""
    from repro.kernels import registry

    g = _graph()
    pol = registry.DispatchPolicy()
    bucket = registry.batch_bucket(
        2, registry.shard_bucket(1, g.n, 1024))
    import jax
    pol.set_route("prune.nlcc", jax.default_backend(), bucket,
                  registry.ROUTE_UNPACKED)
    path = tmp_path / "policy.json"
    pol.save(path)
    eng, clock = _engine(g, policy=str(path), max_batch=2, wave=1024)
    assert eng.stats.get("policy_active")
    t = Template([4, 3, 3], [(0, 1), (1, 2), (2, 0)])
    eng.submit(t)
    eng.submit(t)
    out = eng.pump()
    assert all(r.status == STATUS_OK for r in out)
    lane = eng.result(out[0].query_id).result
    assert lane.stats["dispatch_routes"]["prune.nlcc"] == "unpacked"

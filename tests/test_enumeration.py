"""Match-enumeration engine correctness (core/join.py + core/enumerate.py).

Covers: host-vs-device join route parity on the local backend, the counting
fast path (symmetry-broken in-flight: canonical count x |Aut| equals the
brute-force embedding count), the streaming emitter, the chunk-1 streaming
fallback on overflow, automorphism-group caching, and dispatch-policy
routing of ``enumerate.join``.
"""
import numpy as np
import jax
import pytest

from repro.graph import generators as gen
from repro.core import Template, prune, enumerate_matches, count_matches, stream_matches
from repro.core.oracle import enumerate_matches_bruteforce
from repro.core import template as template_mod
from repro.kernels import registry


def _er(seed=1, n=150, deg=6.0, n_labels=3):
    return gen.erdos_renyi_graph(n, deg, seed=seed, n_labels=n_labels)


TEMPLATES = [
    # acyclic, repeated labels (PC + TDS walk, no revisits)
    ("path-repeat", Template([0, 1, 2, 1], [(0, 1), (1, 2), (2, 3)])),
    # cyclic walk with a revisit step closing the cycle
    ("triangle", Template([0, 1, 2], [(0, 1), (1, 2), (2, 0)])),
    # same-label triangle: |Aut| = 6, all three symmetry restrictions fire
    ("triangle-sym", Template([1, 1, 1], [(0, 1), (1, 2), (2, 0)])),
    # two triangles sharing a vertex: revisit-heavy edge-cover walk
    ("bowtie", Template([0, 1, 1, 2, 2],
                        [(0, 1), (1, 2), (2, 0), (0, 3), (3, 4), (4, 0)])),
]


@pytest.mark.parametrize("case", TEMPLATES, ids=lambda c: c[0])
def test_host_device_route_parity(case):
    """The device-resident join (local context, identity exchange) is
    bit-identical to the host numpy join: embeddings, counts, vertex sets."""
    _, tmpl = case
    g = _er()
    res = prune(g, tmpl)
    host = enumerate_matches(res.dg, res.state, tmpl, route="host")
    dev = enumerate_matches(res.dg, res.state, tmpl, route="device")
    assert dev.route == "device" and host.route == "host"
    np.testing.assert_array_equal(host.embeddings, dev.embeddings)
    assert host.n_embeddings == dev.n_embeddings
    assert host.n_distinct_vertex_sets == dev.n_distinct_vertex_sets
    oracle = enumerate_matches_bruteforce(g, tmpl)
    assert host.n_embeddings == len(oracle)


@pytest.mark.parametrize("route", ["host", "device"])
@pytest.mark.parametrize("case", TEMPLATES, ids=lambda c: c[0])
def test_count_mode_matches_oracle(case, route):
    """The counting-only fast path: symmetry restrictions enforced in-flight,
    canonical count x |Aut| == the brute-force embedding count — no post-hoc
    dedup anywhere."""
    _, tmpl = case
    g = _er(seed=2)
    res = prune(g, tmpl)
    oracle = enumerate_matches_bruteforce(g, tmpl)
    c = count_matches(res.dg, res.state, tmpl, route=route)
    assert c.mode == "count"
    assert c.embeddings.shape == (0, tmpl.n0)  # rows never materialized
    assert c.n_distinct_vertex_sets == -1
    assert c.n_embeddings == len(oracle)
    assert c.n_canonical * c.automorphisms == len(oracle)
    assert c.automorphisms == tmpl.automorphism_count()


def test_symmetry_broken_counts_randomized():
    """Oracle cross-check over random graphs and symmetric templates:
    restricted counts x |Aut| equal brute-force counts on both routes."""
    tmpls = [
        Template([1, 1, 1], [(0, 1), (1, 2), (2, 0)]),  # Aut 6
        Template([0, 1, 0, 1], [(0, 1), (1, 2), (2, 3), (3, 0)]),  # Aut 4
        Template([0, 0], [(0, 1)]),  # Aut 2
    ]
    for seed in range(3):
        g = _er(seed=seed + 10, n=80, deg=4.0, n_labels=2)
        for tmpl in tmpls:
            res = prune(g, tmpl)
            oracle = len(enumerate_matches_bruteforce(g, tmpl))
            for route in ("host", "device"):
                c = count_matches(res.dg, res.state, tmpl, route=route)
                assert c.n_canonical * c.automorphisms == oracle, (
                    seed, tmpl.labels.tolist(), route)
                assert c.n_embeddings == oracle


def test_symmetry_broken_materialize_is_canonical():
    """materialize + symmetry_break yields exactly the canonical
    representatives: one embedding per automorphism class, each the
    restriction-minimal member."""
    g = _er(seed=3, n_labels=2)
    tmpl = Template([1, 1, 1], [(0, 1), (1, 2), (2, 0)])
    res = prune(g, tmpl)
    full = enumerate_matches(res.dg, res.state, tmpl)
    canon = enumerate_matches(res.dg, res.state, tmpl, symmetry_break=True)
    assert canon.n_canonical * canon.automorphisms == full.n_embeddings
    assert canon.n_embeddings == full.n_embeddings
    # every canonical row satisfies the restrictions (here: strictly sorted)
    emb = canon.embeddings
    assert np.all(emb[:, 0] < emb[:, 1]) and np.all(emb[:, 1] < emb[:, 2])
    # and each is a member of the full embedding set
    full_set = {tuple(r) for r in full.embeddings}
    assert all(tuple(r) in full_set for r in emb)


@pytest.mark.parametrize("route", ["host", "device"])
def test_stream_matches_equals_materialize(route):
    g = _er(seed=4)
    tmpl = Template([0, 1, 2, 1], [(0, 1), (1, 2), (2, 3)])
    res = prune(g, tmpl)
    full = enumerate_matches(res.dg, res.state, tmpl)
    blocks = list(stream_matches(res.dg, res.state, tmpl, max_rows=40,
                                 route=route))
    assert all(b.shape[1] == tmpl.n0 for b in blocks)
    cat = (np.unique(np.concatenate(blocks, axis=0), axis=0)
           if blocks else np.zeros((0, tmpl.n0), np.int32))
    np.testing.assert_array_equal(cat, full.embeddings)
    # the budget bounds block sizes (single-row fan-out is the only excess)
    assert sum(b.shape[0] for b in blocks) == full.n_embeddings


@pytest.mark.parametrize("route", ["host", "device"])
@pytest.mark.parametrize("mode", ["materialize", "count"])
def test_chunk1_overflow_falls_back_to_streaming(route, mode):
    """A max_rows so tight that even a single source overflows must no longer
    raise: the enumeration finishes through the bounded-memory streaming
    emitter and still matches the oracle."""
    g = _er(seed=5)
    tmpl = Template([0, 1, 2, 1], [(0, 1), (1, 2), (2, 3)])
    res = prune(g, tmpl)
    oracle = enumerate_matches_bruteforce(g, tmpl)
    stats = {}
    enum = enumerate_matches(res.dg, res.state, tmpl, max_rows=3, chunk=8,
                             route=route, mode=mode, stats=stats)
    assert stats.get("enum_stream_fallbacks", 0) > 0
    assert enum.n_embeddings == len(oracle)


def test_empty_result_both_modes_and_routes():
    g = gen.star_graph(10, center_label=0, leaf_label=1)
    tmpl = Template([0, 1, 1], [(0, 1), (1, 2), (0, 2)])  # triangle, absent
    res = prune(g, tmpl)
    for route in ("host", "device"):
        for mode in ("materialize", "count"):
            enum = enumerate_matches(res.dg, res.state, tmpl, route=route,
                                     mode=mode)
            assert enum.n_embeddings == 0


def test_automorphism_group_cached_on_template(monkeypatch):
    """The group is computed once and cached on the Template — repeated
    enumeration calls (including the empty-result path) never re-search."""
    calls = {"n": 0}
    real = template_mod._automorphism_search

    def counting(t):
        calls["n"] += 1
        return real(t)

    monkeypatch.setattr(template_mod, "_automorphism_search", counting)
    tmpl = Template([1, 1, 1], [(0, 1), (1, 2), (2, 0)])
    g = gen.star_graph(6, center_label=0, leaf_label=0)  # no triangle: empty
    res = prune(g, tmpl)
    for _ in range(3):
        enum = enumerate_matches(res.dg, res.state, tmpl)
        assert enum.n_embeddings == 0
        count_matches(res.dg, res.state, tmpl)
    assert calls["n"] == 1
    assert tmpl.automorphisms() is tmpl.automorphisms()


def test_enumerate_join_route_honors_policy():
    """A tuned ``enumerate.join`` decision routes the local join; the route
    taken is recorded in stats."""
    g = _er(seed=6)
    tmpl = Template([0, 1, 2], [(0, 1), (1, 2), (2, 0)])
    res = prune(g, tmpl)
    pol = registry.DispatchPolicy()
    pol.set_route("enumerate.join", jax.default_backend(),
                  ("local", "count"), registry.ROUTE_DEVICE)
    registry.set_policy(pol)
    try:
        stats = {}
        c = count_matches(res.dg, res.state, tmpl, stats=stats)
    finally:
        registry.set_policy(None)
    assert c.route == registry.ROUTE_DEVICE
    assert stats["enumerate_route"] == registry.ROUTE_DEVICE
    # untuned default stays on the host join
    stats = {}
    c2 = count_matches(res.dg, res.state, tmpl, stats=stats)
    assert c2.route == registry.ROUTE_HOST
    assert c2.n_embeddings == c.n_embeddings


def test_sharded_route_rejects_host():
    g = gen.rmat_graph(7, edge_factor=4, seed=1)
    tmpl = Template([3, 4, 5, 3], [(0, 1), (1, 2), (2, 3)])
    res = prune(g, tmpl, partition=2, guarantee_precision=False)
    with pytest.raises(ValueError, match="device-resident"):
        enumerate_matches(res, route="host")

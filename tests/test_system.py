"""End-to-end behaviour tests for the paper's system.

Covers the Fig. 2 pathological structures (the paper's own justification for
each constraint class), needle-in-a-haystack planted patterns, match
enumeration counts vs the brute-force oracle, and the analytic scenario APIs
(categories (a)-(e) of §1).
"""
import numpy as np
import pytest

from repro.graph.structs import Graph
from repro.graph import generators as gen
from repro.core.template import Template, generate_constraints
from repro.core.pipeline import prune
from repro.core.enumerate import enumerate_matches
from repro.core.oracle import enumerate_matches_bruteforce, solution_subgraph_oracle


def test_fig2a_unrolled_cycle_defeats_lcc_but_not_cc():
    """Fig 2(a): a 3-cycle template; a 9-cycle background with repeating labels
    survives LCC (every vertex sees both neighbor labels) but must be fully
    eliminated by cycle checking."""
    tmpl = Template([0, 1, 2], [(0, 1), (1, 2), (2, 0)])
    g = gen.cycle_graph(9, [0, 1, 2] * 3)
    res = prune(g, tmpl)
    assert res.counts() == {"V*": 0, "E*": 0}


def test_fig2c_torus_defeats_cc_but_not_tds():
    """Fig 2(c) flavor: structures that satisfy all single-cycle constraints
    but contain no clique match require TDS."""
    # two 4-cliques sharing a triangle -- the paper's template (c)
    tmpl = Template(
        [0, 0, 0, 0, 0],
        [(0, 1), (0, 2), (1, 2), (1, 3), (2, 3), (2, 4), (1, 4), (3, 4)],
    )
    g = gen.torus_graph(4, 3, np.zeros(12, dtype=np.int32))
    res = prune(g, tmpl)
    oracle_v, _, _, matches = solution_subgraph_oracle(g, tmpl)
    assert not matches  # torus has no 4-clique
    assert res.counts()["V*"] == 0


def test_planted_needle_in_haystack():
    """Plant 3 copies of a labeled diamond in an R-MAT background; the pruned
    graph must contain exactly the planted matches (plus any natural ones ==
    oracle agreement)."""
    pattern = Graph.from_undirected_pairs(
        4, [(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)], [7, 8, 9, 8]
    )
    bg = gen.rmat_graph(8, edge_factor=4, seed=3, labeler="random", n_labels=6)
    g = gen.planted_pattern_graph(bg, pattern, n_copies=3, seed=5)
    tmpl = Template([7, 8, 9, 8], [(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)])
    res = prune(g, tmpl)
    vm, em, omega_o, matches = solution_subgraph_oracle(g, tmpl)
    assert len(matches) >= 3 * 2  # 3 copies x |Aut| (q1<->q3 swap)
    assert np.array_equal(res.vertex_mask, vm)
    order = np.lexsort((g.src, g.dst))
    assert np.array_equal(res.edge_mask, em[order])


def test_enumeration_count_matches_oracle():
    g = gen.erdos_renyi_graph(150, 6.0, seed=1, n_labels=3)
    tmpl = Template([0, 1, 2, 1], [(0, 1), (1, 2), (2, 3)])
    res = prune(g, tmpl)
    enum = enumerate_matches(res.dg, res.state, tmpl)
    oracle = enumerate_matches_bruteforce(g, tmpl)
    assert enum.n_embeddings == len(oracle)


def test_category_a_existence_and_d_counting():
    """Categories (a) yes/no and (d) counting from §1 fall out of the pipeline."""
    g = gen.cycle_graph(6, [0, 1, 0, 1, 0, 1])
    tmpl = Template([0, 1], [(0, 1)])
    res = prune(g, tmpl)
    assert res.counts()["V*"] == 6  # exists
    enum = enumerate_matches(res.dg, res.state, tmpl)
    assert enum.n_embeddings == 6  # one orientation per edge (q0 -> label 0)


def test_omega_annotation_is_exact_superset_free():
    """The per-vertex match lists (omega) returned after a precision-guaranteed
    run contain exactly the (v, q) pairs realized by some match (paper: 'for
    each vertex in the pruned graph, a list of its possible matches')."""
    g = gen.erdos_renyi_graph(120, 5.0, seed=2, n_labels=3)
    tmpl = Template([0, 1, 2], [(0, 1), (1, 2), (2, 0)])
    res = prune(g, tmpl)
    _, _, omega_o, _ = solution_subgraph_oracle(g, tmpl)
    assert np.array_equal(res.omega, omega_o)


def test_no_match_fully_prunes():
    g = gen.star_graph(10, center_label=0, leaf_label=1)
    tmpl = Template([0, 1, 1], [(0, 1), (1, 2), (0, 2)])  # triangle, absent
    res = prune(g, tmpl)
    assert res.counts() == {"V*": 0, "E*": 0}


def test_single_vertex_template():
    g = gen.star_graph(4, center_label=3, leaf_label=1)
    res = prune(g, Template([1], []))
    assert res.counts()["V*"] == 4


def test_phase_snapshots_defer_host_syncs(monkeypatch):
    """Phase snapshots accumulate device-side: without collect_stats, the
    per-phase counts never call the blocking PruneState.counts() mid-run —
    they materialize once at the end — and the numbers still match the eager
    (collect_stats=True) path exactly."""
    from repro.core.state import PruneState

    g = gen.erdos_renyi_graph(120, 5.0, seed=2, n_labels=3)
    tmpl = Template([0, 1, 2], [(0, 1), (1, 2), (2, 0)])

    calls = {"counts": 0}
    real_counts = PruneState.counts

    def counting_counts(self):
        calls["counts"] += 1
        return real_counts(self)

    monkeypatch.setattr(PruneState, "counts", counting_counts)
    lazy = prune(g, tmpl)
    assert calls["counts"] == 0  # no blocking count reads on the hot path
    eager = prune(g, tmpl, collect_stats=True)
    assert calls["counts"] > 0  # eager snapshots preserved under collect_stats
    assert [
        (p.phase, p.active_vertices, p.active_edges, p.omega_bits)
        for p in lazy.phases
    ] == [
        (p.phase, p.active_vertices, p.active_edges, p.omega_bits)
        for p in eager.phases
    ]


def test_prune_result_masks_are_cached():
    """vertex_mask / edge_mask / omega materialize device arrays once and are
    cached — benchmarks and enumeration hit edge_mask repeatedly."""
    g = gen.erdos_renyi_graph(120, 5.0, seed=2, n_labels=3)
    tmpl = Template([0, 1, 2], [(0, 1), (1, 2), (2, 0)])
    res = prune(g, tmpl)
    assert res.omega is res.omega
    assert res.vertex_mask is res.vertex_mask
    assert res.edge_mask is res.edge_mask


def test_enumeration_chunk_recovers_after_overflow(monkeypatch):
    """A TdsOverflow must shrink only the overflowing wave: subsequent source
    chunks grow back toward the configured chunk instead of staying tiny for
    the rest of the enumeration."""
    from repro.core import enumerate as enum_mod
    from repro.core.tds import TdsOverflow

    g = gen.erdos_renyi_graph(150, 6.0, seed=1, n_labels=3)
    tmpl = Template([0, 1, 2, 1], [(0, 1), (1, 2), (2, 3)])
    res = prune(g, tmpl)

    sizes = []
    real_tds_walk = enum_mod.tds_walk
    state = {"overflowed": False}

    def flaky_tds_walk(sub, walk, ids, **kw):
        sizes.append(len(ids))
        if not state["overflowed"] and len(ids) >= 16:
            state["overflowed"] = True  # one dense region overflows once
            raise TdsOverflow("simulated")
        return real_tds_walk(sub, walk, ids, **kw)

    monkeypatch.setattr(enum_mod, "tds_walk", flaky_tds_walk)
    enum = enumerate_matches(res.dg, res.state, tmpl, chunk=16)
    oracle = enumerate_matches_bruteforce(g, tmpl)
    assert enum.n_embeddings == len(oracle)  # recovery never loses matches
    ok_sizes = sizes[1:]  # sizes after the simulated overflow
    assert ok_sizes[0] == 4  # quartered for the overflowing wave
    assert max(ok_sizes) == 16  # ...but later waves grow back to `chunk`

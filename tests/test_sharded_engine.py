"""Sharded execution-backend correctness (core/engine.py).

The full prune() pipeline on 1/2/4/8 shards must equal the single-device
engine BIT-FOR-BIT — omega, the edge mask, and the phase count trajectory —
across the three template classes (cyclic, acyclic/path, TDS-bearing) and all
three sharded NLCC wave routes (fused / packed / unpacked).

The sim backend (vmap, axis-name collectives) runs in-process on one device.
The spmd backend (shard_map + all_to_all) runs in-process when this process
sees >= 8 devices (CI's multi-device job forces
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) and via a subprocess
fallback in the plain tier-1 run.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import pytest

from repro.graph import rmat_graph, partition_graph
from repro.core import Template, prune
from repro.kernels import registry


# --------------------------------------------------------------- templates
def _graph():
    return rmat_graph(9, edge_factor=6, seed=5)


def _cases():
    """(name, template, prune kwargs) — one per template class of the
    acceptance criteria. Labels chosen so every case keeps a nontrivial G*."""
    return [
        # CC constraints only (monocycle, unique labels, no complete TDS)
        ("cyclic", Template([8, 7, 7], [(0, 1), (1, 2), (2, 0)]),
         dict(guarantee_precision=False)),
        # acyclic, repeated labels >= 3 hops apart -> PC + union-of-paths TDS
        ("path", Template([3, 4, 5, 3], [(0, 1), (1, 2), (2, 3)]),
         dict(guarantee_precision=False)),
        # complete-walk TDS annotation (Def. 1 zero-false-positive pipeline)
        ("tds", Template([4, 3, 5, 3], [(0, 1), (1, 2), (2, 3)]),
         dict(guarantee_precision=True)),
    ]


def _assert_bit_identical(base, sharded, tag):
    np.testing.assert_array_equal(base.omega, sharded.omega, err_msg=tag)
    np.testing.assert_array_equal(base.edge_mask, sharded.edge_mask, err_msg=tag)
    np.testing.assert_array_equal(base.vertex_mask, sharded.vertex_mask, err_msg=tag)
    # same pruning trajectory, not just the same endpoint
    base_traj = [(p.phase, p.active_vertices, p.active_edges, p.omega_bits)
                 for p in base.phases]
    sh_traj = [(p.phase, p.active_vertices, p.active_edges, p.omega_bits)
               for p in sharded.phases]
    assert base_traj == sh_traj, tag


# ----------------------------------------------------------- sim backend
@pytest.mark.parametrize("P", [1, 2, 4, 8])
@pytest.mark.parametrize("case", _cases(), ids=lambda c: c[0])
def test_sim_prune_parity(P, case):
    name, tmpl, kw = case
    g = _graph()
    base = prune(g, tmpl, **kw)
    assert base.counts()["V*"] > 0  # nontrivial
    sharded = prune(g, tmpl, partition=P, **kw)
    assert sharded.stats["backend"] == "sim"
    assert sharded.stats["sharded"]["P"] == P
    if name == "tds":
        # this template generates ONLY the complete-TDS constraint: no wave
        # ever runs, and the reported route must say so
        assert sharded.stats["dispatch_routes"]["prune.nlcc"] == "none"
    _assert_bit_identical(base, sharded, f"sim P={P} {name}")


@pytest.mark.parametrize("route", [
    registry.ROUTE_FUSED, registry.ROUTE_PACKED, registry.ROUTE_UNPACKED])
def test_sim_wave_routes_parity_and_reporting(route):
    """All three sharded NLCC wave routes produce identical prunes, report the
    route actually taken, and count their waves under the right stat key."""
    g = _graph()
    tmpl = Template([8, 7, 7], [(0, 1), (1, 2), (2, 0)])
    base = prune(g, tmpl)
    pol = registry.DispatchPolicy()
    pol.set_route("prune.nlcc", jax.default_backend(),
                  registry.shard_bucket(4, partition_graph(g, 4).n_local, 1024),
                  route)
    registry.set_policy(pol)
    try:
        sharded = prune(g, tmpl, partition=4)
    finally:
        registry.set_policy(None)
    assert sharded.stats["dispatch_routes"]["prune.nlcc"] == route
    stat_key = {
        registry.ROUTE_FUSED: "nlcc_fused_waves",
        registry.ROUTE_PACKED: "nlcc_packed_waves",
        registry.ROUTE_UNPACKED: "nlcc_plane_waves",
    }[route]
    waves = sum(p.extra.get(stat_key, 0) for p in sharded.phases)
    others = sum(p.extra.get(k, 0) for p in sharded.phases
                 for k in ("nlcc_fused_waves", "nlcc_packed_waves",
                           "nlcc_plane_waves") if k != stat_key)
    assert waves > 0 and others == 0
    _assert_bit_identical(base, sharded, f"route={route}")


def test_sim_multiplicity_counts_path():
    """Same-label multiplicity templates exercise the counts side of the
    sharded LCC receive aggregation."""
    g = rmat_graph(8, edge_factor=10, seed=6)
    lbl = int(np.bincount(g.labels).argmax())
    tmpl = Template([lbl, lbl, lbl], [(0, 1), (0, 2)])
    base = prune(g, tmpl, guarantee_precision=False)
    assert base.counts()["V*"] > 0
    sharded = prune(g, tmpl, partition=4, guarantee_precision=False)
    _assert_bit_identical(base, sharded, "multiplicity")


def test_sim_wave_chunking_and_small_waves():
    """wave= smaller than the source count forces multiple waves per walk;
    survivors still accumulate identically on device."""
    g = _graph()
    tmpl = Template([8, 7, 7], [(0, 1), (1, 2), (2, 0)])
    base = prune(g, tmpl, wave=32, guarantee_precision=False)
    sharded = prune(g, tmpl, partition=4, wave=32, guarantee_precision=False)
    _assert_bit_identical(base, sharded, "wave=32")
    waves = sum(p.extra.get("nlcc_waves", 0) for p in sharded.phases)
    consts = sum(p.extra.get("nlcc_constraints", 0) for p in sharded.phases)
    syncs = sum(p.extra.get("nlcc_host_syncs", 0) for p in sharded.phases)
    assert consts > 0 and waves > consts
    # the sharded executor's host-sync contract: one per constraint
    assert syncs == consts


def test_sharded_fused_gate_composes_with_shard_local_shapes(monkeypatch):
    """A tuned `fused` choice whose shard-local resident state exceeds the
    bitset_wave budget falls back to the packed per-hop route."""
    from repro.core import engine
    from repro.kernels import ops as kops

    g = _graph()
    tmpl = Template([8, 7, 7], [(0, 1), (1, 2), (2, 0)])
    pol = registry.DispatchPolicy()
    pol.set_route("prune.nlcc", jax.default_backend(), registry.BUCKET_ANY,
                  registry.ROUTE_FUSED)
    registry.set_policy(pol)
    monkeypatch.setattr(kops, "BITSET_WAVE_VMEM_BUDGET", 1)
    try:
        assert not engine.sharded_fused_eligible(64, 4, 8, 1024, 3)
        sharded = prune(g, tmpl, partition=4)
    finally:
        registry.set_policy(None)
    assert sharded.stats["dispatch_routes"]["prune.nlcc"] == registry.ROUTE_PACKED
    base = prune(g, tmpl)
    _assert_bit_identical(base, sharded, "gated fallback")


def test_shard_bucket_keys():
    b = registry.shard_bucket(4, 500, 1024)
    assert b == ("p4", 512, 1024)
    assert registry.bucket_key(b) == "p4x512x1024"
    # distinct decompositions of the same global graph never share decisions
    assert registry.shard_bucket(8, 500, 1024) != b


def test_sharded_rejects_local_only_knobs():
    g = _graph()
    tmpl = Template([8, 7, 7], [(0, 1), (1, 2), (2, 0)])
    with pytest.raises(ValueError, match="local backend"):
        prune(g, tmpl, partition=2, force_pallas=True)
    with pytest.raises(ValueError, match="local-backend-only"):
        prune(g, tmpl, partition=2, edge_elimination=False)


def test_sim_edge_prune_parity_and_change_flag():
    """nlcc_edge_prune composes with the sharded backends through the bridge,
    and an edge-ONLY elimination (omega unchanged) still triggers the
    post-constraint LCC re-run — the change flag watches edge_active too.

    Construction: two disjoint labeled 4-cycles plus a label-compatible chord
    between them. Every vertex keeps its candidacy (it sits on its own
    cycle), but the chord lies on no completing 4-cycle, so the frontier
    edge-prune pass eliminates it while omega is untouched."""
    from repro.graph.structs import Graph

    pairs = [(0, 1), (1, 2), (2, 3), (3, 0),
             (4, 5), (5, 6), (6, 7), (7, 4),
             (0, 5)]  # the chord: label-compatible, on no injective 4-cycle
    g = Graph.from_undirected_pairs(8, pairs, [0, 1, 0, 1, 0, 1, 0, 1])
    tmpl = Template([0, 1, 0, 1], [(0, 1), (1, 2), (2, 3), (3, 0)])
    base = prune(g, tmpl, nlcc_edge_prune=True, guarantee_precision=True)
    # the chord's arcs die (here via the complete-TDS exact edge set) while
    # every vertex keeps its candidacy — an edge-only elimination
    assert base.counts() == {"V*": 8, "E*": 16}
    sharded = prune(g, tmpl, partition=2, nlcc_edge_prune=True,
                    guarantee_precision=True)
    _assert_bit_identical(base, sharded, "edge_prune")


def test_sharded_change_flag_sees_edge_only_elimination(monkeypatch):
    """Regression: the sharded nlcc() change flag must watch edge_active, not
    just omega — an edge-prune-bridge elimination that leaves omega untouched
    still has to trigger the post-constraint LCC re-run."""
    import jax.numpy as jnp
    from repro.core import engine
    from repro.core import nlcc as nlcc_mod
    from repro.core.state import PruneState
    from repro.core.template import generate_constraints

    g = _graph()
    tmpl = Template([8, 7, 7], [(0, 1), (1, 2), (2, 0)])
    # empty candidacy: no wave ever runs, so the ONLY state difference the
    # constraint can produce is the bridge's edge elimination
    empty = PruneState(
        omega=jnp.zeros((g.n, tmpl.n0), bool),
        edge_active=jnp.ones((g.m,), bool))

    def edge_only_prune(dg, state, c, template, wave, stats):
        ea = np.asarray(state.edge_active).copy()
        ea[np.flatnonzero(ea)[0]] = False
        return PruneState(omega=state.omega, edge_active=jnp.asarray(ea))

    monkeypatch.setattr(nlcc_mod, "_edge_prune_pass", edge_only_prune)
    backend = engine.make_backend(g, tmpl, partition=2, nlcc_edge_prune=True)
    backend.init(empty)
    c = [c for c in generate_constraints(tmpl, guarantee_precision=False)
         if c.kind == "cycle"][0]
    changed = backend.nlcc(c, {})
    after = backend.gather_state()
    assert not np.asarray(after.omega).any()  # omega untouched (still empty)
    assert int(np.asarray(after.edge_active).sum()) == g.m - 1
    assert bool(changed)  # edge-only change MUST re-trigger LCC


def test_sharded_initial_state_roundtrip():
    """initial_state= scatters onto the shards and gathers back losslessly —
    resuming an interrupted prune works across backends."""
    g = _graph()
    tmpl = Template([4, 3, 5, 3], [(0, 1), (1, 2), (2, 3)])
    base = prune(g, tmpl, guarantee_precision=False)
    resumed = prune(g, tmpl, partition=4, guarantee_precision=False,
                    initial_state=base.state)
    np.testing.assert_array_equal(base.omega, resumed.omega)
    np.testing.assert_array_equal(
        np.asarray(base.state.edge_active), np.asarray(resumed.state.edge_active))


# ----------------------------------------------------- sharded enumeration
def _enumerate_no_gather(result, **kw):
    """enumerate_matches on a sharded result, asserting the join never
    host-compacts the reduced subgraph (the PR's no-gather contract)."""
    from repro.core import enumerate as enum_mod
    from repro.core import tds as tds_mod

    calls = {"n": 0}
    real = tds_mod.compact_active

    def guard(*a, **k):
        calls["n"] += 1
        return real(*a, **k)

    tds_mod.compact_active = guard
    enum_mod.compact_active = guard
    try:
        out = enum_mod.enumerate_matches(result, **kw)
    finally:
        tds_mod.compact_active = real
        enum_mod.compact_active = real
    assert calls["n"] == 0, "sharded enumeration gathered the reduced subgraph"
    return out


_BASE_ENUM_CACHE = {}


def _base_enum(case):
    """Local-engine baseline (prune + host-route enumeration), computed once
    per template case — every shard count compares against the same bits."""
    from repro.core import enumerate_matches

    name, tmpl, kw = case
    if name not in _BASE_ENUM_CACHE:
        base = prune(_graph(), tmpl, **kw)
        _BASE_ENUM_CACHE[name] = enumerate_matches(base)
    return _BASE_ENUM_CACHE[name]


@pytest.mark.parametrize("P", [1, 2, 4, 8])
@pytest.mark.parametrize("case", _cases(), ids=lambda c: c[0])
def test_sim_enumeration_parity(P, case):
    """Sharded enumeration (the device-resident join over the sim backend's
    shard arrays) is bit-identical to the local host join — embeddings,
    counts, distinct vertex sets — and never gathers the reduced subgraph."""
    name, tmpl, kw = case
    g = _graph()
    be = _base_enum(case)
    sharded = prune(g, tmpl, partition=P, **kw)
    se = _enumerate_no_gather(sharded)
    assert se.route == "device"
    np.testing.assert_array_equal(be.embeddings, se.embeddings,
                                  err_msg=f"{name} P={P}")
    assert be.n_embeddings == se.n_embeddings
    assert be.n_distinct_vertex_sets == se.n_distinct_vertex_sets
    if name == "cyclic":
        assert se.n_embeddings > 0  # nontrivial parity

    # counting fast path: same totals, symmetry-broken in-flight
    sc = _enumerate_no_gather(sharded, mode="count")
    assert sc.n_embeddings == be.n_embeddings
    assert sc.n_canonical * sc.automorphisms == be.n_embeddings


def test_sim_enumeration_symmetry_counts_vs_oracle():
    """Symmetry-broken sharded counts x |Aut| equal the brute-force embedding
    count (|Aut| = 6 here: same-label triangle)."""
    from repro.core import enumerate_matches
    from repro.core.oracle import enumerate_matches_bruteforce

    g = _graph()
    tmpl = Template([5, 5, 5], [(0, 1), (1, 2), (2, 0)])
    oracle = len(enumerate_matches_bruteforce(g, tmpl))
    assert oracle > 0
    sharded = prune(g, tmpl, partition=4)
    sc = _enumerate_no_gather(sharded, mode="count")
    assert sc.automorphisms == 6
    assert sc.n_canonical * 6 == oracle
    assert sc.n_embeddings == oracle


def test_sim_enumeration_streaming_parity():
    """stream_matches over a sharded result: device-resident blocks under a
    row budget concatenate to the local materialized embeddings."""
    from repro.core import enumerate_matches, stream_matches

    g = _graph()
    tmpl = Template([3, 4, 5, 3], [(0, 1), (1, 2), (2, 3)])
    base = prune(g, tmpl, guarantee_precision=False)
    be = enumerate_matches(base)
    sharded = prune(g, tmpl, partition=2, guarantee_precision=False)
    blocks = list(stream_matches(sharded, max_rows=64))
    cat = (np.unique(np.concatenate(blocks, axis=0), axis=0)
           if blocks else np.zeros((0, tmpl.n0), np.int32))
    np.testing.assert_array_equal(be.embeddings, cat)


# ------------------------------------------------- distributed-rows join
def _hub_graph():
    """A skew construction: four hub vertices at ids 0..3 (one shard's block
    at every P in 1/2/4/8) adjacent to every leaf. Any walk through the hub
    label funnels >80% of the expandable rows onto the hubs' owner shard."""
    from repro.graph.structs import Graph

    n, hubs = 64, 4
    pairs = [(h, v) for h in range(hubs) for v in range(hubs, n)]
    labels = [1] * hubs + [0] * (n - hubs)
    return Graph.from_undirected_pairs(n, pairs, labels)


def test_rowsharded_vs_replicated_flavor_parity():
    """The two sharded row placements are bit-identical to each other and to
    the local host join — embeddings, counts x |Aut| — and report their
    engine flavor under the public 'device' route."""
    from repro.core import enumerate_matches

    case = _cases()[0]
    g = _graph()
    be = _base_enum(case)
    sharded = prune(g, case[1], partition=4, **case[2])
    outs = {}
    for flavor in (registry.ROUTE_ROWSHARDED, registry.ROUTE_REPLICATED):
        stats = {}
        se = enumerate_matches(sharded, route=flavor, stats=stats)
        assert se.route == "device"
        assert stats["enumerate_route"] == "device"
        assert stats["enumerate_join_engine"] == flavor
        np.testing.assert_array_equal(be.embeddings, se.embeddings,
                                      err_msg=flavor)
        sc = enumerate_matches(sharded, route=flavor, mode="count")
        assert sc.n_embeddings == be.n_embeddings, flavor
        outs[flavor] = se
    assert outs[registry.ROUTE_ROWSHARDED].n_embeddings > 0


def test_rowsharded_flavor_policy_and_rejections():
    """The dispatch policy's ("sharded", mode) bucket picks the row
    placement (default rowsharded); flavors are meaningless on the local
    backend and route='host' stays rejected on sharded results."""
    from repro.core import enumerate_matches

    g = _graph()
    tmpl = Template([8, 7, 7], [(0, 1), (1, 2), (2, 0)])
    sharded = prune(g, tmpl, partition=2, guarantee_precision=False)
    stats = {}
    enumerate_matches(sharded, mode="count", stats=stats)
    assert stats["enumerate_join_engine"] == registry.ROUTE_ROWSHARDED

    pol = registry.DispatchPolicy()
    pol.set_route("enumerate.join", jax.default_backend(),
                  ("sharded", "count"), registry.ROUTE_REPLICATED)
    registry.set_policy(pol)
    try:
        stats = {}
        se = enumerate_matches(sharded, mode="count", stats=stats)
    finally:
        registry.set_policy(None)
    assert se.route == "device"
    assert stats["enumerate_join_engine"] == registry.ROUTE_REPLICATED

    local = prune(g, tmpl, guarantee_precision=False)
    with pytest.raises(ValueError, match="row placement"):
        enumerate_matches(local, route=registry.ROUTE_ROWSHARDED)
    with pytest.raises(ValueError, match="device-resident"):
        enumerate_matches(sharded, route="host")


@pytest.mark.parametrize("P", [1, 2, 4, 8])
def test_rowsharded_skewed_ownership_pads_not_drops(P):
    """Power-law frontier: one shard owns every hub, hence >80% of the
    expandable rows. The exchange buckets must PAD, never drop — occupancy
    bounded by the bucket cap — and the result stays bit-identical to the
    local host join."""
    from repro.core import enumerate_matches

    g = _hub_graph()
    tmpl = Template([0, 1, 0], [(0, 1), (1, 2)])
    base = prune(g, tmpl, guarantee_precision=False)
    be = enumerate_matches(base, route="host")
    assert be.n_embeddings > 0
    sharded = prune(g, tmpl, partition=P, guarantee_precision=False)
    stats = {}
    se = _enumerate_no_gather(sharded, route=registry.ROUTE_ROWSHARDED,
                              stats=stats)
    np.testing.assert_array_equal(be.embeddings, se.embeddings,
                                  err_msg=f"skew P={P}")
    assert stats["rowshard_owner_frac_max"] >= 0.8
    # pad-not-drop: every (sender, owner) bucket fits under the pow2 cap
    assert stats["rowshard_bucket_occupancy_max"] <= stats["rowshard_bucket_cap"]
    sc = enumerate_matches(sharded, route=registry.ROUTE_ROWSHARDED,
                           mode="count")
    assert sc.n_embeddings == be.n_embeddings


def test_rowsharded_memory_scales_inverse_P():
    """The tentpole's point: on a balanced frontier the per-shard resident
    row table shrinks with P — peak shard rows at P=8 is a fraction of the
    P=1 (== replicated) table, while totals stay bit-equal."""
    from repro.core import enumerate_matches
    from repro.graph.generators import erdos_renyi_graph

    g = erdos_renyi_graph(256, 6.0, seed=3, n_labels=2)
    tmpl = Template([0, 1, 0], [(0, 1), (1, 2)])
    peaks = {}
    counts = {}
    for P in (1, 8):
        sharded = prune(g, tmpl, partition=P, guarantee_precision=False)
        stats = {}
        sc = enumerate_matches(sharded, mode="count",
                               route=registry.ROUTE_ROWSHARDED, stats=stats)
        counts[P] = sc.n_embeddings
        peaks[P] = stats["rowshard_peak_shard_rows"]
        # every shard's resident block is bounded by pow2(peak shard rows),
        # never the global row count
        assert (stats["rowshard_resident_rows_max"]
                < 2 * max(stats["rowshard_peak_shard_rows"], 1) + 1)
    assert counts[1] == counts[8] and counts[1] > 0
    # at least a 2x reduction (ideally ~8x; pow2 padding + imbalance slop)
    assert peaks[8] * 2 <= peaks[1]


def test_join_plan_and_row_plan_cached_on_partition():
    """Satellite regression: `join_plan()` / `join_plan_dev()` / `row_plan()`
    build once per partition — repeated enumerations reuse the same plan and
    the same device buffers instead of re-staging the CSR."""
    from repro.core import enumerate_matches
    from repro.graph import partition as part_mod

    g = _graph()
    tmpl = Template([8, 7, 7], [(0, 1), (1, 2), (2, 0)])
    part = partition_graph(g, 4)
    calls = {"n": 0}
    real = part_mod.build_join_plan

    def counting(p):
        calls["n"] += 1
        return real(p)

    part_mod.build_join_plan = counting
    try:
        sharded = prune(g, tmpl, partition=part, guarantee_precision=False)
        enumerate_matches(sharded, mode="count")
        enumerate_matches(sharded, mode="count")
    finally:
        part_mod.build_join_plan = real
    assert calls["n"] <= 1
    assert part.join_plan() is part.join_plan()
    assert part.join_plan_dev() is part.join_plan_dev()
    assert part.row_plan() is part.row_plan()
    assert part.row_plan().deg.dtype == np.int64


def test_rowsharded_int32_capacity_guard():
    """Mirrors PR 4's slot-map guard: a per-shard expansion capacity that
    would overflow int32 slot ids raises a diagnostic NotImplementedError
    instead of silently wrapping."""
    import dataclasses as _dc
    from repro.core import enumerate as enum_mod
    from repro.core import join as join_mod

    with pytest.raises(NotImplementedError, match="int32"):
        join_mod._guard_int32(2 ** 31, "unit slots")
    join_mod._guard_int32(2 ** 31 - 1, "unit slots")  # boundary: fine

    g = _hub_graph()
    tmpl = Template([0, 1, 0], [(0, 1), (1, 2)])
    sharded = prune(g, tmpl, partition=2, guarantee_precision=False)
    eng = enum_mod._make_engine(
        registry.ROUTE_ROWSHARDED, "sharded", sharded.dg, sharded.state,
        tmpl, enum_mod.template_walk(tmpl), 2 ** 40, False,
        sharded.backend, None)
    # a private copy of the row plan: the partition's cached plan must not
    # see the poisoned degree table
    eng.rp = _dc.replace(
        eng.rp, deg=np.full_like(eng.rp.deg, np.int64(2) ** 27))
    rows = eng.seed(eng.sources()[:64])
    with pytest.raises(NotImplementedError, match="int32"):
        eng.step(rows, 1)


# ---------------------------------------------------------- spmd backend
_needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="spmd in-process tests need 8 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


@_needs_devices
@pytest.mark.parametrize("case", _cases(), ids=lambda c: c[0])
def test_spmd_prune_parity_8_devices(case):
    from repro.launch.mesh import make_shard_mesh

    name, tmpl, kw = case
    g = _graph()
    base = prune(g, tmpl, **kw)
    mesh = make_shard_mesh(8)
    sharded = prune(g, tmpl, mesh=mesh, **kw)
    assert sharded.stats["backend"] == "spmd"
    _assert_bit_identical(base, sharded, f"spmd {name}")


@_needs_devices
def test_spmd_enumeration_parity_8_devices():
    """The device-resident enumeration join on a real shard_map mesh: no
    gather, bit-identical embeddings and symmetry-broken counts."""
    from repro.core import enumerate_matches
    from repro.launch.mesh import make_shard_mesh

    g = _graph()
    tmpl = Template([8, 7, 7], [(0, 1), (1, 2), (2, 0)])
    base = prune(g, tmpl, guarantee_precision=False)
    be = enumerate_matches(base)
    sharded = prune(g, tmpl, mesh=make_shard_mesh(8),
                    guarantee_precision=False)
    assert sharded.stats["backend"] == "spmd"
    se = _enumerate_no_gather(sharded)
    np.testing.assert_array_equal(be.embeddings, se.embeddings)
    sc = _enumerate_no_gather(sharded, mode="count")
    assert sc.n_embeddings == be.n_embeddings


@_needs_devices
def test_spmd_rowsharded_skew_8_devices():
    """The skewed-ownership case on a real shard_map mesh: exchange buckets
    pad-not-drop and the distributed-rows join stays bit-identical."""
    from repro.core import enumerate_matches
    from repro.launch.mesh import make_shard_mesh

    g = _hub_graph()
    tmpl = Template([0, 1, 0], [(0, 1), (1, 2)])
    base = prune(g, tmpl, guarantee_precision=False)
    be = enumerate_matches(base, route="host")
    sharded = prune(g, tmpl, mesh=make_shard_mesh(8),
                    guarantee_precision=False)
    assert sharded.stats["backend"] == "spmd"
    stats = {}
    se = enumerate_matches(sharded, route=registry.ROUTE_ROWSHARDED,
                           stats=stats)
    np.testing.assert_array_equal(be.embeddings, se.embeddings)
    assert stats["rowshard_owner_frac_max"] >= 0.8
    assert stats["rowshard_bucket_occupancy_max"] <= stats["rowshard_bucket_cap"]
    sp = enumerate_matches(sharded, route=registry.ROUTE_REPLICATED,
                           mode="count")
    assert sp.n_embeddings == be.n_embeddings


@_needs_devices
def test_spmd_partition_coarser_than_mesh_rejected():
    from repro.launch.mesh import make_shard_mesh

    g = _graph()
    tmpl = Template([8, 7, 7], [(0, 1), (1, 2), (2, 0)])
    with pytest.raises(ValueError, match="shards"):
        prune(g, tmpl, mesh=make_shard_mesh(8), partition=partition_graph(g, 4))


SPMD_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.graph import rmat_graph
    from repro.core import Template, prune, enumerate_matches
    from repro.launch.mesh import make_shard_mesh

    g = rmat_graph(9, edge_factor=6, seed=5)
    mesh = make_shard_mesh(8)
    for name, tmpl, kw in [
        ("cyclic", Template([8, 7, 7], [(0, 1), (1, 2), (2, 0)]),
         dict(guarantee_precision=False)),
        ("tds", Template([4, 3, 5, 3], [(0, 1), (1, 2), (2, 3)]),
         dict(guarantee_precision=True)),
    ]:
        base = prune(g, tmpl, **kw)
        sh = prune(g, tmpl, mesh=mesh, **kw)
        assert np.array_equal(base.omega, sh.omega), name
        assert np.array_equal(base.edge_mask, sh.edge_mask), name
        assert sh.stats["backend"] == "spmd", sh.stats
        be = enumerate_matches(base)
        se = enumerate_matches(sh)  # device-resident join on the mesh
        assert se.route == "device", se.route
        assert np.array_equal(be.embeddings, se.embeddings), name
        st = {}
        sc = enumerate_matches(sh, mode="count", stats=st)
        assert sc.n_embeddings == be.n_embeddings, name
        # distributed rows are the default flavor; replicated stays bit-equal
        assert st["enumerate_join_engine"] == "rowsharded", st
        if sc.n_embeddings:
            assert st["rowshard_bucket_occupancy_max"] <= st["rowshard_bucket_cap"]
        sp = enumerate_matches(sh, mode="count", route="replicated")
        assert sp.n_embeddings == be.n_embeddings, name
    print("SPMD_PRUNE_OK")
    """
)


def test_spmd_prune_subprocess_8_devices():
    """The tier-1 guarantee that the real shard_map path works even when this
    process only sees one device."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    r = subprocess.run(
        [sys.executable, "-c", SPMD_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SPMD_PRUNE_OK" in r.stdout

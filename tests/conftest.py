"""Shared test helpers. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; only launch/dryrun.py forces 512 host devices (in a subprocess).
"""
import numpy as np
import pytest

from repro.graph.structs import Graph
from repro.core.template import Template


def sample_template_from(g: Graph, size: int, seed: int, extra_edge_p: float = 0.5) -> Template:
    """Random connected subgraph of g as a template — guarantees >= 1 match."""
    r = np.random.default_rng(seed)
    offsets, neighbors = g.csr()
    deg = offsets[1:] - offsets[:-1]
    nz = np.flatnonzero(deg > 0)
    if nz.size == 0:
        raise ValueError("graph has no edges")
    start = int(r.choice(nz))
    verts = [start]
    edges = set()
    for _ in range(size * 4):
        if len(verts) >= size:
            break
        u = int(r.choice(verts))
        nb = neighbors[offsets[u]:offsets[u + 1]]
        if nb.size == 0:
            continue
        v = int(r.choice(nb))
        if v not in verts:
            verts.append(v)
        edges.add((min(u, v), max(u, v)))
    vid = {v: i for i, v in enumerate(verts)}
    es = [(vid[a], vid[b]) for a, b in edges if a in vid and b in vid]
    keyset = set(zip(g.src.tolist(), g.dst.tolist()))
    for a in verts:
        for b in verts:
            if a < b and (a, b) in keyset and r.random() < extra_edge_p:
                es.append((vid[a], vid[b]))
    es = list({tuple(sorted(e)) for e in es})
    return Template([int(g.labels[v]) for v in verts], es)


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _isolated_dispatch_policy(tmp_path, monkeypatch):
    """Keep tier-1 hermetic w.r.t. any tuned dispatch-policy cache in the
    workspace: every test sees an empty per-test cache path and starts from
    the untuned eligibility fallback (tests install policies explicitly)."""
    from repro.kernels import registry

    monkeypatch.setenv(
        "REPRO_DISPATCH_POLICY", str(tmp_path / "dispatch_policy.json"))
    registry.clear_policy()
    yield
    registry.clear_policy()

"""Fault-tolerant elastic execution (core/resilience.py + the re-enterable
pipeline driver + checkpoint torn-write hardening).

The acceptance bar: a shard lost at ANY phase boundary (and mid-wave) on
1/2/4/8 sim shards — and on a real 8-device spmd mesh — recovers onto the
same or a SMALLER shard count and lands bit-identical to the fault-free run
(omega, endpoint-consistent edge mask, and the committed phase trajectory).
Monotone phases make phase boundaries exact consistency points; these tests
pin that argument end to end.
"""
import glob
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import pytest

from repro.graph import rmat_graph
from repro.core import Template, prune, enumerate_matches
from repro.core import resilience as res
from repro.core import loadbalance as lb
from repro.checkpoint import ckpt
from repro.kernels import registry


def _graph():
    return rmat_graph(9, edge_factor=6, seed=5)


def _template():
    # acyclic, repeated labels -> PC + union-of-paths TDS: K=2 constraints,
    # i.e. phases 0 (LCC), 1 (NLCC-path + LCC re-run), 2 (TDS)
    return Template([3, 4, 5, 3], [(0, 1), (1, 2), (2, 3)])


KW = dict(guarantee_precision=False)


@pytest.fixture(scope="module")
def base():
    return prune(_graph(), _template(), **KW)


def _traj(result):
    return [(p.phase, p.active_vertices, p.active_edges, p.omega_bits)
            for p in result.phases]


def _assert_bit_identical(a, b, tag):
    np.testing.assert_array_equal(a.omega, b.omega, err_msg=tag)
    np.testing.assert_array_equal(a.edge_mask, b.edge_mask, err_msg=tag)
    np.testing.assert_array_equal(a.vertex_mask, b.vertex_mask, err_msg=tag)
    assert _traj(a) == _traj(b), tag


# ------------------------------------------------------------ fault injector
def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        res.FaultSpec(kind="meteor_strike")
    with pytest.raises(ValueError, match="ladder rung"):
        res.FaultSpec(kind=res.FAULT_SHARD_LOSS, cleared_by="nope")


def test_injector_is_deterministic():
    def drive(inj):
        seen = []
        for phase in range(3):
            inj.begin_phase(phase)
            for site in ("lcc", "nlcc", "wave", "tds"):
                try:
                    inj.event(site, wave=0 if site == "wave" else None)
                except res.InjectedFault as e:
                    seen.append((phase, site, e.kind))
        return seen

    plan = [res.FaultSpec(kind=res.FAULT_SHARD_LOSS, phase=1, site="nlcc"),
            res.FaultSpec(kind=res.FAULT_COLLECTIVE_TIMEOUT, phase=2,
                          site="wave", wave=0, times=2)]
    runs = [drive(res.FaultInjector(plan)) for _ in range(2)]
    assert runs[0] == runs[1]
    assert (1, "nlcc", "shard_loss") in runs[0]


def test_injector_after_and_times():
    inj = res.FaultInjector([res.FaultSpec(
        kind=res.FAULT_TRANSIENT_KERNEL, site="lcc", after=1, times=1)])
    inj.begin_phase(0)
    inj.event("lcc")  # skipped (after=1)
    with pytest.raises(res.TransientKernelFailure):
        inj.event("lcc")
    inj.event("lcc")  # exhausted (times=1)
    assert [f["site"] for f in inj.fired] == ["lcc"]


def test_injector_random_plan_is_seed_deterministic():
    a = res.FaultInjector.random(7, n_phases=3, n_faults=4,
                                 kinds=res.FAULT_KINDS)
    b = res.FaultInjector.random(7, n_phases=3, n_faults=4,
                                 kinds=res.FAULT_KINDS)
    assert [x.spec for x in a.armed] == [x.spec for x in b.armed]
    c = res.FaultInjector.random(8, n_phases=3, n_faults=4,
                                 kinds=res.FAULT_KINDS)
    assert [x.spec for x in a.armed] != [x.spec for x in c.armed]


def test_instrument_prims_traces_and_injects():
    from repro.core.engine import axis_prims

    prims = axis_prims("shards")
    inj = res.FaultInjector([res.FaultSpec(
        kind=res.FAULT_COLLECTIVE_TIMEOUT, site="prim:psum")])
    wrapped = res.instrument_prims(prims, inj)
    assert type(wrapped) is type(prims)
    inj.begin_phase(0)
    with pytest.raises(res.CollectiveTimeout):
        wrapped.psum(np.ones(3))
    assert inj.prim_trace["psum"] == 1


def test_registry_dispatch_hook_seam():
    feats = np.zeros((8, 4, 8), np.float32)
    mask = np.zeros((8, 4), bool)
    calls = []
    with registry.dispatch_hook(lambda name, mode: calls.append((name, mode))):
        registry.dispatch("segment_agg", feats, mask)
    assert calls and calls[0][0] == "segment_agg"
    # a raising hook propagates (the fault seam) and uninstalls cleanly
    inj = res.FaultInjector([res.FaultSpec(
        kind=res.FAULT_TRANSIENT_KERNEL, site="dispatch",
        kernel="segment_agg")])
    inj.begin_phase(0)
    with registry.dispatch_hook(inj.on_dispatch):
        with pytest.raises(res.TransientKernelFailure):
            registry.dispatch("segment_agg", feats, mask)
    assert registry.get_dispatch_hook() is None


def test_registry_mode_override():
    feats = np.zeros((8, 4, 8), np.float32)
    mask = np.zeros((8, 4), bool)
    with registry.mode_override(registry.MODE_REF):
        assert (registry.resolve_mode("segment_agg", feats, mask)
                == registry.MODE_REF)
    with pytest.raises(ValueError):
        with registry.mode_override("warp-drive"):
            pass


# ------------------------------------------------- checkpoint torn-write
def _tree():
    return {"omega": np.arange(12, dtype=np.int32).reshape(3, 4),
            "edge_active": np.ones(5, bool)}


def test_restore_skips_truncated_checkpoint(tmp_path):
    d = str(tmp_path)
    ckpt.save_checkpoint(d, 1, _tree())
    ckpt.save_checkpoint(d, 2, {k: v * 0 for k, v in _tree().items()})
    # tear the newest checkpoint's array payload mid-file
    [arrays] = glob.glob(os.path.join(d, "step_000000000002", "*.npz"))
    blob = open(arrays, "rb").read()
    with open(arrays, "wb") as f:
        f.write(blob[:len(blob) // 2])
    assert ckpt.latest_step(d) == 2
    assert not ckpt.checkpoint_valid(os.path.join(d, "step_000000000002"))
    with pytest.warns(RuntimeWarning, match="corrupt/partial checkpoint"):
        assert ckpt.latest_valid_step(d) == 1
    with pytest.warns(RuntimeWarning):
        tree, meta = ckpt.restore_checkpoint(d, _tree())
    assert meta["step"] == 1
    np.testing.assert_array_equal(tree["omega"], _tree()["omega"])


def test_restore_skips_corrupt_manifest(tmp_path):
    d = str(tmp_path)
    ckpt.save_checkpoint(d, 3, _tree())
    ckpt.save_checkpoint(d, 4, _tree())
    with open(os.path.join(d, "step_000000000004", "manifest.json"), "w") as f:
        f.write("{ torn")
    with pytest.warns(RuntimeWarning, match="corrupt/partial"):
        assert ckpt.latest_valid_step(d) == 3
    # an explicitly requested corrupt step still raises loudly
    with pytest.raises(Exception):
        ckpt.restore_checkpoint(d, _tree(), step=4)


def test_restore_no_valid_checkpoints(tmp_path):
    d = str(tmp_path)
    ckpt.save_checkpoint(d, 1, _tree())
    with open(os.path.join(d, "step_000000000001", "manifest.json"), "w") as f:
        f.write("!")
    with pytest.warns(RuntimeWarning):
        with pytest.raises(FileNotFoundError, match="no valid checkpoints"):
            ckpt.restore_checkpoint(d, _tree())


# ------------------------------------------- phase-boundary checkpointing
def test_phase_checkpoints_written_and_harmless(tmp_path, base):
    cfg = res.ResilienceConfig(checkpoint_dir=str(tmp_path))
    out = prune(_graph(), _template(), partition=4, resilience=cfg, **KW)
    rs = out.stats["resilience"]
    n_phases = out.stats["n_constraints"] + 1
    assert rs["checkpoints"] == n_phases
    assert len(rs["checkpoint_seconds"]) == n_phases
    assert rs["restarts"] == [] and rs["rebalances"] == []
    _assert_bit_identical(base, out, "checkpointing-only run")
    # the newest checkpoint holds the final original-coordinate state
    tree, meta = ckpt.restore_checkpoint(
        str(tmp_path), {"omega": np.zeros(base.omega.shape, bool),
                        "edge_active": np.zeros(base.edge_mask.shape, bool)})
    assert meta["phase"] == n_phases - 1
    np.testing.assert_array_equal(np.asarray(tree["omega"]), base.omega)


def test_checkpoint_cadence_and_restore_truncation(tmp_path, base):
    # checkpoint_every=2 -> snapshots only at phases 0 and 2; a fault at
    # phase 2 restores phase 0 and replays 1..2 (committed trajectory must
    # not duplicate the replayed phases)
    inj = res.FaultInjector([res.FaultSpec(kind=res.FAULT_SHARD_LOSS, phase=2)])
    cfg = res.ResilienceConfig(checkpoint_dir=str(tmp_path),
                               checkpoint_every=2, injector=inj)
    out = prune(_graph(), _template(), partition=4, resilience=cfg, **KW)
    rs = out.stats["resilience"]
    assert [r["restored_phase"] for r in rs["restarts"]] == [0]
    _assert_bit_identical(base, out, "cadence-2 recovery")


# --------------------------------------------------- recovery-parity sweep
@pytest.mark.parametrize("P", [1, 2, 4, 8])
@pytest.mark.parametrize("phase", [0, 1, 2])
def test_shard_loss_recovery_parity(tmp_path, base, P, phase):
    """Shard loss at every phase boundary on 1/2/4/8 sim shards: restore the
    last checkpoint (possibly none -> from-scratch) and land bit-identical."""
    inj = res.FaultInjector([res.FaultSpec(kind=res.FAULT_SHARD_LOSS,
                                           phase=phase)])
    cfg = res.ResilienceConfig(checkpoint_dir=str(tmp_path), injector=inj)
    out = prune(_graph(), _template(), partition=P, resilience=cfg, **KW)
    rs = out.stats["resilience"]
    assert len(rs["restarts"]) == 1
    assert rs["restarts"][0]["restored_phase"] == phase - 1
    assert rs["recovery_seconds"] > 0
    _assert_bit_identical(base, out, f"P={P} phase={phase}")


def test_recovery_onto_fewer_shards_and_enumeration(tmp_path, base):
    """P=4 -> restart_P=2 restore: bit-parity, and enumeration still works
    (the result drops its backend and takes the host route)."""
    inj = res.FaultInjector([res.FaultSpec(kind=res.FAULT_SHARD_LOSS, phase=1)])
    cfg = res.ResilienceConfig(checkpoint_dir=str(tmp_path), injector=inj,
                               elastic=res.ElasticConfig(restart_P=2))
    out = prune(_graph(), _template(), partition=4, resilience=cfg, **KW)
    r = out.stats["resilience"]["restarts"][0]
    assert (r["from_P"], r["to_P"]) == (4, 2)
    assert out.backend is None  # compacted coordinates: host-route enumeration
    _assert_bit_identical(base, out, "elastic 4->2")
    be = enumerate_matches(base)
    oe = enumerate_matches(out)
    np.testing.assert_array_equal(be.embeddings, oe.embeddings)


def test_local_backend_recovery(tmp_path, base):
    """The driver recovers the LOCAL backend too (plain restart, original
    graph, restored original-coordinate state)."""
    inj = res.FaultInjector([res.FaultSpec(kind=res.FAULT_SHARD_LOSS, phase=2)])
    cfg = res.ResilienceConfig(checkpoint_dir=str(tmp_path), injector=inj)
    out = prune(_graph(), _template(), resilience=cfg, **KW)
    assert len(out.stats["resilience"]["restarts"]) == 1
    _assert_bit_identical(base, out, "local recovery")


def test_mid_wave_fault_recovery(tmp_path, base):
    """A fault INSIDE a constraint (2nd NLCC wave batch) rolls back to the
    previous phase boundary — partial wave progress must not leak."""
    inj = res.FaultInjector([res.FaultSpec(kind=res.FAULT_SHARD_LOSS,
                                           phase=1, site="wave", wave=1)])
    cfg = res.ResilienceConfig(checkpoint_dir=str(tmp_path), injector=inj)
    # wave=4 forces multiple batches per constraint at this graph size
    out = prune(_graph(), _template(), partition=4, wave=4,
                resilience=cfg, **KW)
    assert inj.fired and inj.fired[0]["site"] == "wave"
    assert inj.fired[0]["wave"] == 1
    assert len(out.stats["resilience"]["restarts"]) == 1
    _assert_bit_identical(base, out, "mid-wave recovery")


def test_seeded_random_fault_plan_recovers(tmp_path, base):
    out = prune(_graph(), _template(), partition=4,
                resilience=res.ResilienceConfig(
                    checkpoint_dir=str(tmp_path),
                    injector=res.FaultInjector.random(3, n_phases=3)),
                **KW)
    _assert_bit_identical(base, out, "random plan")


# ------------------------------------------------------- degradation ladder
def test_transient_collective_retries_in_place(base):
    inj = res.FaultInjector([res.FaultSpec(
        kind=res.FAULT_COLLECTIVE_TIMEOUT, phase=1, cleared_by="retry")])
    out = prune(_graph(), _template(), partition=4,
                resilience=res.ResilienceConfig(injector=inj), **KW)
    rs = out.stats["resilience"]
    assert rs["restarts"] == []  # absorbed by the ladder, no checkpoint needed
    assert [r for r, _ in rs["ladder"]] == ["retry"]
    _assert_bit_identical(base, out, "retry in place")


def test_kernel_fault_escalates_to_ref_rung(base):
    # times=0 (every match) + cleared_by="ref": retries keep failing until
    # the ladder forces reference kernels via registry.mode_override
    inj = res.FaultInjector([res.FaultSpec(
        kind=res.FAULT_TRANSIENT_KERNEL, phase=1, cleared_by="ref", times=0)])
    out = prune(_graph(), _template(), partition=4,
                resilience=res.ResilienceConfig(injector=inj), **KW)
    rungs = [r for r, _ in out.stats["resilience"]["ladder"]]
    assert rungs == ["retry", "retry", "ref"]
    _assert_bit_identical(base, out, "ref rung")


def test_resource_exhaustion_backs_off_chunk(base):
    inj = res.FaultInjector([res.FaultSpec(
        kind=res.FAULT_RESOURCE_EXHAUSTED, phase=2, site="tds",
        cleared_by="chunk")])
    out = prune(_graph(), _template(), partition=4, tds_chunk=4096,
                resilience=res.ResilienceConfig(injector=inj), **KW)
    rs = out.stats["resilience"]
    assert [r for r, _ in rs["ladder"]] == ["chunk"]
    assert out.backend.tds_chunk == 4096 // 4  # RetryPolicy.chunk_backoff_factor
    _assert_bit_identical(base, out, "chunk back-off")


def test_unrecoverable_without_checkpoint_dir():
    inj = res.FaultInjector([res.FaultSpec(kind=res.FAULT_SHARD_LOSS, phase=1)])
    with pytest.raises(res.ResilienceExhausted, match="no checkpoint_dir"):
        prune(_graph(), _template(), partition=4,
              resilience=res.ResilienceConfig(injector=inj), **KW)


def test_restart_budget_exhausts(tmp_path):
    # a PERSISTENT fault (times=0): every restart re-fires it until the
    # restart budget runs out
    inj = res.FaultInjector([res.FaultSpec(kind=res.FAULT_SHARD_LOSS,
                                           phase=1, times=0)])
    cfg = res.ResilienceConfig(checkpoint_dir=str(tmp_path), injector=inj,
                               max_restarts=2)
    with pytest.raises(res.ResilienceExhausted, match="restart budget"):
        prune(_graph(), _template(), partition=4, resilience=cfg, **KW)
    assert len(inj.fired) == 3  # initial attempt + 2 restarted attempts


# ------------------------------------------------- imbalance + elastic unit
def test_device_shard_counts_match_host_oracle(base):
    out = prune(_graph(), _template(), partition=4, **KW)
    counts = np.asarray(out.backend.shard_counts_dev())
    host = lb.imbalance_stats(_graph(), out.state, 4, out.dg)
    np.testing.assert_array_equal(counts[:, 0], host.vertices_per_shard)
    np.testing.assert_array_equal(counts[:, 1], host.edges_per_shard)
    dev_stats = lb.imbalance_stats_from_counts(counts[:, 0], counts[:, 1])
    assert dev_stats.max_over_mean_edges == host.max_over_mean_edges
    assert dev_stats.shards_holding_half == host.shards_holding_half


def test_imbalance_triggered_rebalance(base):
    # trigger ~1.0 trips at the first boundary: compact-and-reshuffle onto
    # P=2 with NO fault, still bit-identical
    cfg = res.ResilienceConfig(elastic=res.ElasticConfig(
        imbalance_trigger=1.0, rebalance_P=2))
    out = prune(_graph(), _template(), partition=4, resilience=cfg, **KW)
    rb = out.stats["resilience"]["rebalances"]
    assert rb and rb[0]["from_P"] == 4 and rb[0]["to_P"] == 2
    assert rb[0]["max_over_mean_before"] > 1.0
    assert out.backend is None
    _assert_bit_identical(base, out, "triggered rebalance")


def test_elastic_handoff_remap_roundtrip(base):
    g = _graph()
    state = base.state
    out = lb.elastic_handoff(g, base.dg, state, 2, seed=11)
    assert out is not None
    sub, part, state_new, remap = out
    assert part.P == 2 and sub.n == int(base.vertex_mask.sum())
    back = lb.remap_state_to_original(state_new, remap, base.template.n0)
    # roundtrip = the endpoint-consistent restriction of the original state
    vact = base.vertex_mask
    np.testing.assert_array_equal(back.omega, base.omega * vact[:, None])
    np.testing.assert_array_equal(back.edge_active, base.edge_mask)


def test_elastic_handoff_degenerate_returns_none():
    g = _graph()
    n0 = 4
    empty = lb.elastic_handoff(
        g, prune(g, _template(), **KW).dg,
        type(prune(g, _template(), **KW).state)(
            omega=np.zeros((g.n, n0), bool),
            edge_active=np.zeros(g.m, bool)),
        2)
    assert empty is None


# ----------------------------------------------------------- spmd backend
_needs_devices = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="spmd in-process tests need 8 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


@_needs_devices
def test_spmd_shard_loss_restarts_onto_smaller_mesh(tmp_path, base):
    from repro.launch.mesh import make_shard_mesh

    inj = res.FaultInjector([res.FaultSpec(kind=res.FAULT_SHARD_LOSS, phase=1)])
    cfg = res.ResilienceConfig(checkpoint_dir=str(tmp_path), injector=inj,
                               elastic=res.ElasticConfig(restart_P=4))
    out = prune(_graph(), _template(), mesh=make_shard_mesh(8),
                resilience=cfg, **KW)
    assert out.stats["backend"] == "spmd"
    r = out.stats["resilience"]["restarts"][0]
    assert (r["from_P"], r["to_P"]) == (8, 4)
    _assert_bit_identical(base, out, "spmd 8->4")


SPMD_RESILIENCE_SCRIPT = textwrap.dedent(
    """
    import os, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    from repro.graph import rmat_graph
    from repro.core import Template, prune
    from repro.core import resilience as res
    from repro.launch.mesh import make_shard_mesh

    g = rmat_graph(9, edge_factor=6, seed=5)
    tmpl = Template([3, 4, 5, 3], [(0, 1), (1, 2), (2, 3)])
    base = prune(g, tmpl, guarantee_precision=False)
    with tempfile.TemporaryDirectory() as d:
        inj = res.FaultInjector([res.FaultSpec(kind=res.FAULT_SHARD_LOSS,
                                               phase=1)])
        cfg = res.ResilienceConfig(checkpoint_dir=d, injector=inj,
                                   elastic=res.ElasticConfig(restart_P=4))
        out = prune(g, tmpl, mesh=make_shard_mesh(8), resilience=cfg,
                    guarantee_precision=False)
        assert out.stats["backend"] == "spmd"
        r = out.stats["resilience"]["restarts"][0]
        assert (r["from_P"], r["to_P"]) == (8, 4), r
        assert np.array_equal(base.omega, out.omega)
        assert np.array_equal(base.edge_mask, out.edge_mask)
    print("SPMD_RESILIENCE_OK")
    """
)


def test_spmd_resilience_subprocess():
    if len(jax.devices()) >= 8:
        pytest.skip("covered in-process by the 8-device test")
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SPMD_RESILIENCE_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert out.returncode == 0, out.stdout + out.stderr
    assert "SPMD_RESILIENCE_OK" in out.stdout

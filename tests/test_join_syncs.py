"""Host-sync accounting of the distributed-rows join (core/join.py).

The folded handshake pins the contract: RowShardedJoin performs exactly ONE
host readback per step() call — the [P, 2, P] count+capacity matrix — with
no separate frontier-column readback before an expansion. The
``rowshard_host_syncs`` stats counter is incremented at that single readback
site, so counter == number of step() calls is the pin.
"""
import numpy as np
import pytest

from repro.graph import rmat_graph
from repro.core import Template, prune, enumerate_matches
from repro.core import join as join_mod
from repro.core.enumerate import template_walk


def _engine(P=2, seed=5):
    g = rmat_graph(9, edge_factor=6, seed=seed)
    tmpl = Template([8, 7, 7], [(0, 1), (1, 2), (2, 0)])
    res = prune(g, tmpl, partition=P, guarantee_precision=False)
    walk = template_walk(tmpl)
    stats = {}
    eng = join_mod.RowShardedJoin(res.backend.join_context(), tmpl, walk,
                                  max_rows=2_000_000, stats=stats)
    return eng, stats


@pytest.mark.parametrize("P", [1, 2, 4])
def test_one_host_sync_per_step(P):
    eng, stats = _engine(P=P)
    sources = eng.sources()
    assert sources.size > 0
    rows = eng.seed(sources)
    n_calls = 0
    for r in range(1, len(eng.steps) + 1):
        if eng.nrows(rows) == 0:
            break
        rows = eng.step(rows, r)
        n_calls += 1
    assert n_calls == len(eng.steps)  # the cyclic walk survives every step
    assert stats.get("rowshard_host_syncs", 0) == n_calls
    assert eng.nrows(rows) > 0


def test_expand_and_revisit_both_single_sync():
    """The walk above ends in a revisit (cycle closure), so both step kinds
    are exercised; assert the per-kind accounting explicitly."""
    eng, stats = _engine(P=2)
    kinds = [s.kind for s in eng.steps]
    assert "expand" in kinds and "revisit" in kinds
    rows = eng.seed(eng.sources())
    for r in range(1, len(eng.steps) + 1):
        before = stats.get("rowshard_host_syncs", 0)
        rows = eng.step(rows, r)
        assert stats["rowshard_host_syncs"] == before + 1, (
            f"step {r} ({kinds[r - 1]}) performed more than one handshake")


def test_capacity_folds_through_exchange():
    """A routed block carries the NEXT step's expansion capacity from the
    same handshake that sized it — equal to the host recomputation from the
    static degree table."""
    eng, _ = _engine(P=2)
    rows = eng.seed(eng.sources())
    for r in range(1, len(eng.steps)):
        rows = eng.step(rows, r)
        nxt = eng.steps[r]
        if nxt.kind != "expand":
            continue
        host = eng._gather(rows)
        fcol = host[:, nxt.c_prev]
        want = np.bincount(fcol // eng.n_local,
                           weights=eng.rp.deg[fcol].astype(np.float64),
                           minlength=eng.P).astype(np.int64)
        np.testing.assert_array_equal(np.asarray(rows.cap), want)


def test_enumeration_reports_sync_counter():
    g = rmat_graph(9, edge_factor=6, seed=5)
    tmpl = Template([8, 7, 7], [(0, 1), (1, 2), (2, 0)])
    res = prune(g, tmpl, partition=2, guarantee_precision=False)
    stats = {}
    enumerate_matches(res, route="rowsharded", mode="count", stats=stats)
    assert stats.get("rowshard_host_syncs", 0) > 0

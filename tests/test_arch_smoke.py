"""Per-architecture smoke tests (deliverable (f)): every assigned arch
instantiates a REDUCED same-family config and runs one forward/train step on
CPU, asserting output shapes and no NaNs. The FULL configs are exercised only
via the dry-run."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_arch
from repro.configs.base import LMConfig, GNNConfig, RecsysConfig
from repro.train import TrainConfig, build_train_step, init_state
from repro.optim.adamw import AdamWConfig
from repro.data import SyntheticTokenStream, MaskedSequenceStream, full_graph_batch
from repro.graph import generators as gen

LM_ARCHS = [a for a in ARCH_IDS if isinstance(get_arch(a).CONFIG, LMConfig)]
GNN_ARCHS = [a for a in ARCH_IDS if isinstance(get_arch(a).CONFIG, GNNConfig)]
REC_ARCHS = [a for a in ARCH_IDS if isinstance(get_arch(a).CONFIG, RecsysConfig)]


def _finite(tree):
    return all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_forward_and_train(arch):
    from repro.models import transformer
    cfg = get_arch(arch).smoke()
    params, specs = transformer.init(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    logits, aux = transformer.forward(params, cfg, toks)
    assert logits.shape == (2, 16, cfg.vocab)
    assert _finite(logits)
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3))
    state, _ = init_state(jax.random.key(0), cfg, tc)
    step = jax.jit(build_train_step(cfg, tc))
    batch = SyntheticTokenStream(cfg.vocab, 4, 16, seed=0)(0)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode_matches_forward(arch):
    """Teacher-forced decode must reproduce the training forward logits —
    validates the KV cache (incl. MLA latent cache and windowed ring)."""
    from repro.models import transformer
    cfg = get_arch(arch).smoke()
    params, _ = transformer.init(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 12), 0, cfg.vocab)
    full_logits, _ = transformer.forward(params, cfg, toks)
    cache = transformer.init_cache(cfg, 2, 32)
    outs = []
    for t in range(12):
        lg, cache = transformer.decode_step(params, cfg, toks[:, t], cache)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    win = cfg.window
    for t in range(12):
        if win is not None and t + 1 > win:
            continue  # windowed: positions beyond the window legitimately differ
        np.testing.assert_allclose(
            np.asarray(dec[:, t]), np.asarray(full_logits[:, t]),
            rtol=2e-2, atol=2e-2,
        )


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke_train(arch):
    from repro.models import gnn
    cfg = get_arch(arch).smoke()
    g = gen.erdos_renyi_graph(120, 5.0, seed=1, n_labels=4)
    batch = full_graph_batch(g, d_feat=8, n_classes=4, seed=0)
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-2, weight_decay=0.0))
    state, _ = init_state(jax.random.key(0), cfg, tc, d_in=8, n_classes=4)
    step = jax.jit(build_train_step(cfg, tc))
    losses = []
    for i in range(5):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]  # learns the (random but fixed) labels


@pytest.mark.parametrize("arch", REC_ARCHS)
def test_recsys_smoke_train_and_serve(arch):
    from repro.models import bert4rec
    cfg = get_arch(arch).smoke()
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3))
    state, _ = init_state(jax.random.key(0), cfg, tc)
    step = jax.jit(build_train_step(cfg, tc))
    stream = MaskedSequenceStream(cfg.n_items, 8, cfg.seq_len, seed=0)
    state, metrics = step(state, stream(0))
    assert np.isfinite(float(metrics["loss"]))
    scores = bert4rec.serve_scores(state["params"], cfg, stream(1)["items"][:2])
    assert scores.shape == (2, cfg.n_items + 2)
    assert _finite(scores)
    r = bert4rec.retrieval_scores(
        state["params"], cfg, stream(1)["items"][:1],
        jnp.arange(1, 51, dtype=jnp.int32))
    assert r.shape == (1, 50) and _finite(r)


def test_moe_dispatch_conservation():
    """Every kept token-slot lands in exactly one expert slot; gates
    renormalized; capacity respected."""
    from repro.models.transformer import moe_dispatch
    cfg = get_arch("deepseek-v2-lite-16b").smoke()
    x = jax.random.normal(jax.random.key(0), (64, cfg.d_model))
    router = jax.random.normal(jax.random.key(1), (cfg.d_model, cfg.n_routed))
    slot, token_of, keep, gate, aux, capacity = moe_dispatch(x, router, cfg)
    assert slot.shape == (64 * cfg.top_k,)
    s = np.asarray(slot)[np.asarray(keep)]
    assert len(np.unique(s)) == len(s), "slot collision"
    g = np.asarray(gate).reshape(64, cfg.top_k) if False else None
    per_token = np.zeros(64)
    np.add.at(per_token, np.asarray(token_of), np.asarray(gate))
    np.testing.assert_allclose(per_token, 1.0, rtol=1e-4)


def test_all_archs_have_full_configs_and_shapes():
    for arch in ARCH_IDS:
        mod = get_arch(arch)
        assert mod.CONFIG.name == arch or mod.CONFIG.name.startswith(arch.split("-")[0])
        assert len(mod.SHAPES) == 4, f"{arch}: every arch has 4 shape cells"
        smoke = mod.smoke()
        assert type(smoke) is type(mod.CONFIG)

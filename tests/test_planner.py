"""Plan-level query optimizer (core/planner.py): plan enumeration, the
calibrated cost model, and — the load-bearing contract — BIT-IDENTITY of
planned execution against the heuristic order.

Soundness recap (full argument in core/planner.py): every phase is reductive
and monotone, and the final complete edge-cover TDS walk maps ANY sound
superset to the exact match set, with the trailing conditional-LCC fixpoint
making the edge mask a pure function of the final omega. Therefore any plan
that keeps the complete TDS phase last produces a PruneResult bit-identical
to the heuristic order — which these tests pin across backends and plans.

Also here: checkpoint phase identity (satellite). Checkpoints key phases by
constraint signature + engine + direction, not positional index; resuming
under a different plan must refuse cleanly with PlanMismatch.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (Template, prune, count_matches, PlanMismatch,
                        plan_query, heuristic_plan, resolve_query_plan,
                        record_plan, constraint_signature, template_signature,
                        plan_bucket)
from repro.core import planner
from repro.core import nlcc as nlcc_mod
from repro.core import resilience as res
from repro.core.template import generate_constraints
from repro.graph import generators as gen
from repro.graph import collect_graph_stats
from repro.graph.structs import Graph, DeviceGraph
from repro.kernels import registry


# ------------------------------------------------------------- fixtures
def _graph():
    """R-MAT background with 3 planted labeled squares: non-trivial pruning
    with a known non-empty match set."""
    pattern = Graph.from_undirected_pairs(
        4, [(0, 1), (1, 2), (2, 3), (3, 0)], [2, 3, 4, 3])
    bg = gen.rmat_graph(8, edge_factor=4, seed=3, labeler="random",
                        n_labels=6)
    return gen.planted_pattern_graph(bg, pattern, n_copies=3, seed=5)


def _template():
    return Template([2, 3, 4, 3], [(0, 1), (1, 2), (2, 3), (3, 0)])


def _multi_constraint_template():
    """Square + chord + tail: generates several cycle/path constraints plus
    the complete TDS — a real reordering space."""
    return Template([2, 3, 4, 3, 5],
                    [(0, 1), (1, 2), (2, 3), (3, 0), (0, 2), (2, 4)])


def _constraints(g, t, **kw):
    return generate_constraints(t, label_freq=g.label_frequency(), **kw)


def _assert_bit_identical(a, b, what):
    np.testing.assert_array_equal(
        np.asarray(a.state.omega), np.asarray(b.state.omega),
        err_msg=f"{what}: omega differs")
    np.testing.assert_array_equal(
        np.asarray(a.state.edge_active), np.asarray(b.state.edge_active),
        err_msg=f"{what}: edge mask differs")
    ca = count_matches(a.dg, a.state, a.template)
    cb = count_matches(b.dg, b.state, b.template)
    assert ca.n_embeddings == cb.n_embeddings, f"{what}: match counts"


# ------------------------------------------------------------- signatures
def test_constraint_and_template_signatures():
    g, t = _graph(), _template()
    cs = _constraints(g, t)
    sigs = [constraint_signature(c) for c in cs]
    assert len(set(sigs)) == len(sigs)  # distinct phases -> distinct keys
    for c, s in zip(cs, sigs):
        assert s.startswith(f"{c.kind}:")
        assert s.endswith(":complete") == c.complete
    tsig = template_signature(t)
    assert tsig == template_signature(
        Template(t.labels, sorted(t.edge_set)[::-1]))
    assert tsig != template_signature(_multi_constraint_template())


def test_plan_bucket_is_template_x_graph_stats():
    g, t = _graph(), _template()
    st = collect_graph_stats(g)
    tsig, sbucket = plan_bucket(t, st)
    assert tsig == template_signature(t)
    assert sbucket == st.bucket()


# ------------------------------------------------------------- graph stats
def test_graph_stats_device_path_matches_host_path():
    g = _graph()
    host = collect_graph_stats(g)
    dev = collect_graph_stats(DeviceGraph.from_host(g),
                              n_labels=len(g.label_frequency()))
    assert host.n == dev.n and host.m == dev.m
    np.testing.assert_array_equal(host.label_hist, dev.label_hist)
    np.testing.assert_array_equal(host.degree_hist, dev.degree_hist)
    assert host.bucket() == dev.bucket()


def test_graph_stats_device_path_requires_n_labels():
    dg = DeviceGraph.from_host(_graph())
    with pytest.raises(ValueError, match="n_labels"):
        collect_graph_stats(dg)


# ------------------------------------------------------------- expand_walks
def test_expand_walks_directions_partition_the_default():
    g, t = _graph(), _multi_constraint_template()
    for c in _constraints(g, t):
        default = nlcc_mod.expand_walks(c, "default")
        assert nlcc_mod.expand_walks(c) == default
        for d in ("fwd", "rev", "head"):
            sub = nlcc_mod.expand_walks(c, d)
            assert sub, f"{d} produced no walks"
            for w in sub:
                # a variant walk is either one of the default walks or (for
                # the cycle "rev" orientation flip) the element-wise reversal
                # of one — the same closed cycle in an undirected graph
                assert w in default or tuple(reversed(w)) in default, (
                    f"direction {d} walk {w} unrelated to the default set — "
                    "direction variants must weaken, never change, the phase")


def test_expand_walks_cycle_rotations():
    c = [c for c in _constraints(_graph(), _template()) if c.is_cyclic][0]
    base = c.walk[:-1]
    assert len(nlcc_mod.expand_walks(c, "default")) == len(base)
    assert len(nlcc_mod.expand_walks(c, "head")) == 1
    rev = nlcc_mod.expand_walks(c, "rev")[0]
    assert rev[0] == rev[-1]  # still closed


# ------------------------------------------------------------- plan shape
def test_heuristic_plan_mirrors_generate_constraints_order():
    g, t = _graph(), _multi_constraint_template()
    cs = _constraints(g, t)
    hp = heuristic_plan(cs)
    assert hp.source == "heuristic"
    assert [p.constraint for p in hp.phases] == list(cs)
    assert all(p.is_default() for p in hp.phases)


def test_reorder_is_sound_requires_complete_tds_last():
    g = _graph()
    cs = _constraints(g, _multi_constraint_template())
    assert planner.reorder_is_sound(cs)
    no_precision = _constraints(g, _multi_constraint_template(),
                                guarantee_precision=False)
    if no_precision and not no_precision[-1].complete:
        assert not planner.reorder_is_sound(no_precision)
    assert not planner.reorder_is_sound([])


def test_plan_query_covers_exactly_the_constraints():
    g, t = _graph(), _multi_constraint_template()
    st = collect_graph_stats(g)
    qp = plan_query(t, st, backend="cpu")
    cs = _constraints(g, t)
    assert sorted(qp.signatures()) == sorted(
        constraint_signature(c) for c in cs)
    # the complete TDS phase is pinned last — the soundness gate
    assert qp.phases[-1].constraint.complete
    assert qp.phases[-1].engine == planner.ENGINE_TDS
    assert qp.predicted_s > 0
    assert qp.per_phase_s is not None and len(qp.per_phase_s) == len(qp.phases)


def test_plan_query_without_complete_tds_stays_heuristic():
    """Reordering is gated on the complete edge-cover TDS phase being
    present and last; without it (guarantee_precision=False on a cyclic
    template) the planner must return the heuristic order untouched."""
    g, t = _graph(), _template()
    st = collect_graph_stats(g)
    cs = _constraints(g, t, guarantee_precision=False)
    if any(c.complete for c in cs):
        pytest.skip("template generates a complete phase even without "
                    "guarantee_precision")
    qp = plan_query(t, st, backend="cpu", guarantee_precision=False,
                    label_freq=g.label_frequency(), constraints=cs)
    assert qp.is_heuristic()
    assert [p.constraint for p in qp.phases] == list(cs)


def test_phase_identity_includes_engine_and_direction():
    g = _graph()
    cs = _constraints(g, _template())
    hp = heuristic_plan(cs)
    p = hp.phases[0]
    alt = planner.PlanPhase(p.constraint, p.engine, "head")
    assert p.signature == alt.signature
    assert p.identity != alt.identity


# ------------------------------------------------------------- cost model
def test_static_dispatch_seconds_positive_and_cached():
    a = planner.static_dispatch_seconds("cpu", 1024, 2048)
    b = planner.static_dispatch_seconds("cpu", 1024, 2048)
    assert a > 0 and a == b


def test_cost_model_orders_by_walk_volume():
    """More walks on the same frontier must never be predicted cheaper."""
    g, t = _graph(), _template()
    st = collect_graph_stats(g)
    cs = _constraints(g, t)
    model = planner._CostModel(t, st, backend="cpu", wave=1024)
    cyc = [c for c in cs if c.is_cyclic][0]
    full = model.phase_seconds(
        planner.PlanPhase(cyc, planner.ENGINE_NLCC, "default"), 1.0)
    head = model.phase_seconds(
        planner.PlanPhase(cyc, planner.ENGINE_NLCC, "head"), 1.0)
    assert full >= head > 0


def test_enumerate_orders_includes_heuristic_and_caps():
    g, t = _graph(), _multi_constraint_template()
    st = collect_graph_stats(g)
    cs = _constraints(g, t)
    model = planner._CostModel(t, st, backend="cpu", wave=1024)
    prefix = [c for c in cs if not c.complete]  # caller pins complete last
    orders = planner.enumerate_orders(model, prefix)
    assert orders
    assert all(sorted(constraint_signature(c) for c in o)
               == sorted(constraint_signature(c) for c in prefix)
               for o in orders)  # permutations only — nothing dropped
    assert any(list(o) == list(prefix) for o in orders)  # heuristic included
    assert len(orders) <= 720  # MAX_ENUM_CLASSES! ceiling


# --------------------------------------------------- bit-identity pins
# The acceptance contract: planned and heuristic orders produce bit-identical
# PruneResults on every backend. local = single device; sim P in {1,4} =
# vmap-simulated shards; spmd = shard_map on a real mesh (skipped when the
# process has fewer devices than shards).
def _backends():
    out = [("local", dict()), ("sim-P1", dict(partition=1)),
           ("sim-P4", dict(partition=4))]
    return out


@pytest.mark.parametrize("name,kw", _backends(), ids=lambda v: v[0]
                         if isinstance(v, str) else "")
def test_planned_vs_heuristic_bit_identical(name, kw):
    g, t = _graph(), _template()
    st = collect_graph_stats(g)
    qp = plan_query(t, st, backend="cpu")
    base = prune(g, t, **kw)
    planned = prune(g, t, plan=qp, **kw)
    assert base.stats["plan"]["source"] == "heuristic"
    assert planned.stats["plan"]["source"] in ("planner", "heuristic")
    _assert_bit_identical(base, planned, f"{name} planned-vs-heuristic")


@pytest.mark.parametrize("P", [1, 4])
def test_planned_vs_heuristic_bit_identical_spmd(P):
    if len(jax.devices()) < P:
        pytest.skip(f"spmd P={P} needs {P} devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    from repro.launch.mesh import make_shard_mesh

    g, t = _graph(), _template()
    st = collect_graph_stats(g)
    qp = plan_query(t, st, backend="cpu")
    mesh = make_shard_mesh(P)
    base = prune(g, t, mesh=mesh)
    planned = prune(g, t, plan=qp, mesh=mesh)
    assert base.stats["backend"] == "spmd"
    _assert_bit_identical(base, planned, f"spmd P={P} planned-vs-heuristic")


def test_every_enumerable_plan_is_bit_identical():
    """Stronger than the argmin pin: EVERY order/variant the planner may
    emit lands on the same bits — permuted phases, direction subsets, and
    the complete TDS pinned last."""
    g, t = _graph(), _multi_constraint_template()
    cs = _constraints(g, t)
    assert planner.reorder_is_sound(cs)
    base = prune(g, t)
    head, last = list(cs[:-1]), cs[-1]
    variants = [
        list(cs),                         # heuristic order
        head[::-1] + [last],              # reversed prefix
    ]
    for order in variants:
        for direction in ("default", "head", "fwd"):
            phases = [planner.PlanPhase(
                c, planner.default_engine(c),
                direction if not c.complete else "default")
                for c in order]
            qp = planner.QueryPlan(phases=phases, source="planner")
            out = prune(g, t, plan=qp)
            _assert_bit_identical(
                base, out, f"order={[c.kind for c in order]} dir={direction}")


def test_plan_stats_report_predicted_vs_actual():
    g, t = _graph(), _template()
    st = collect_graph_stats(g)
    qp = plan_query(t, st, backend="cpu")
    out = prune(g, t, plan=qp)
    rep = out.stats["plan"]
    assert rep["source"] == qp.source
    assert len(rep["phases"]) == len(qp.phases)
    for ph, p in zip(rep["phases"], qp.phases):
        assert ph["sig"] == p.signature
        assert ph["engine"] == p.engine and ph["direction"] == p.direction
        assert ph["actual_s"] is not None and ph["actual_s"] >= 0
        if qp.source == "planner":
            assert ph["predicted_s"] is not None and ph["predicted_s"] > 0


def test_mismatched_plan_is_rejected():
    g, t = _graph(), _template()
    st = collect_graph_stats(g)
    other = plan_query(_multi_constraint_template(), st, backend="cpu")
    with pytest.raises(ValueError, match="does not match"):
        prune(g, t, plan=other)


# --------------------------------------------------- policy-cache resolve
def test_record_and_resolve_roundtrip():
    g, t = _graph(), _template()
    st = collect_graph_stats(g)
    cs = _constraints(g, t)
    pol = registry.DispatchPolicy()
    qp = plan_query(t, st, backend="cpu", policy=pol)
    record_plan(pol, t, st, qp, backend="cpu")
    registry.set_policy(pol)
    got = resolve_query_plan(t, cs, st, backend="cpu")
    assert got is not None
    assert got.source == "policy"
    assert got.identities() == qp.identities()
    # a different stats bucket misses (exact-key lookup, no wildcard)
    bigger = gen.rmat_graph(10, edge_factor=8, seed=1, labeler="random",
                            n_labels=6)
    st2 = collect_graph_stats(bigger)
    assert st2.bucket() != st.bucket()
    assert resolve_query_plan(t, cs, st2, backend="cpu") is None


def test_tuned_policy_drives_prune_and_stays_bit_identical():
    g, t = _graph(), _template()
    st = collect_graph_stats(g)
    base = prune(g, t)  # untuned run under the autouse empty policy
    pol = registry.DispatchPolicy()
    qp = plan_query(t, st, backend="cpu", policy=pol)
    record_plan(pol, t, st, qp, backend="cpu")
    registry.set_policy(pol)
    tuned = prune(g, t)
    assert tuned.stats["plan"]["source"] == "policy"
    _assert_bit_identical(base, tuned, "policy-cache-driven prune")


# --------------------------------------------------- checkpoint identity
def test_checkpoint_resume_under_different_order_refuses(tmp_path):
    g, t = _graph(), _multi_constraint_template()
    cs = _constraints(g, t)
    cfg = res.ResilienceConfig(checkpoint_dir=str(tmp_path))
    prune(g, t, resilience=cfg)
    # same constraints, different order — plan identity differs
    alt = planner.QueryPlan(
        phases=[planner.PlanPhase(c, planner.default_engine(c))
                for c in (list(cs[:-1])[::-1] + [cs[-1]])],
        source="planner")
    inj = res.FaultInjector(
        [res.FaultSpec(kind=res.FAULT_SHARD_LOSS, phase=1)])
    cfg2 = res.ResilienceConfig(checkpoint_dir=str(tmp_path), injector=inj)
    with pytest.raises(PlanMismatch, match="written under plan"):
        prune(g, t, resilience=cfg2, plan=alt)


def test_checkpoint_resume_under_different_direction_refuses(tmp_path):
    """Identity is signature + engine + direction: the same constraint order
    executed with a weaker direction commits different state."""
    g, t = _graph(), _template()
    cs = _constraints(g, t)
    cfg = res.ResilienceConfig(checkpoint_dir=str(tmp_path))
    prune(g, t, resilience=cfg)
    hp = heuristic_plan(cs)
    alt = planner.QueryPlan(
        phases=[planner.PlanPhase(
            p.constraint, p.engine,
            "head" if p.engine == planner.ENGINE_NLCC else p.direction)
            for p in hp.phases],
        source="planner")
    inj = res.FaultInjector(
        [res.FaultSpec(kind=res.FAULT_SHARD_LOSS, phase=1)])
    cfg2 = res.ResilienceConfig(checkpoint_dir=str(tmp_path), injector=inj)
    with pytest.raises(PlanMismatch):
        prune(g, t, resilience=cfg2, plan=alt)


def test_checkpoint_resume_under_same_plan_recovers_bit_identical(tmp_path):
    g, t = _graph(), _template()
    cfg = res.ResilienceConfig(checkpoint_dir=str(tmp_path))
    base = prune(g, t, resilience=cfg)
    inj = res.FaultInjector(
        [res.FaultSpec(kind=res.FAULT_SHARD_LOSS, phase=1)])
    cfg2 = res.ResilienceConfig(checkpoint_dir=str(tmp_path), injector=inj)
    out = prune(g, t, resilience=cfg2)
    assert [r["restored_phase"]
            for r in out.stats["resilience"]["restarts"]]
    _assert_bit_identical(base, out, "same-plan checkpoint resume")


def test_legacy_checkpoint_without_plan_fields_resumes(tmp_path):
    """Checkpoints written before plan identity existed (no phase_sig /
    plan_sigs in meta) fall back to the positional rule instead of
    refusing."""
    from repro.checkpoint import ckpt

    g, t = _graph(), _template()
    cfg = res.ResilienceConfig(checkpoint_dir=str(tmp_path))
    base = prune(g, t, resilience=cfg)
    # rewrite the newest checkpoint's meta with the plan fields stripped
    like = {"omega": np.zeros(base.omega.shape, bool),
            "edge_active": np.zeros(base.edge_mask.shape, bool)}
    tree, meta = ckpt.restore_checkpoint(str(tmp_path), like)
    legacy = {k: v for k, v in meta.items()
              if k not in ("phase_sig", "plan_sigs")}
    ckpt.save_checkpoint(str(tmp_path), int(meta["phase"]) + 1,
                         tree, extra_meta=dict(legacy, phase=int(
                             meta["phase"])), keep=1)
    inj = res.FaultInjector(
        [res.FaultSpec(kind=res.FAULT_SHARD_LOSS, phase=1)])
    cfg2 = res.ResilienceConfig(checkpoint_dir=str(tmp_path), injector=inj)
    out = prune(g, t, resilience=cfg2)
    _assert_bit_identical(base, out, "legacy checkpoint resume")

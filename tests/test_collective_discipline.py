"""The engine-prims seam is the ONLY collective boundary (acceptance
criterion of the distributed-rows refactor).

Every cross-shard movement in the core pipeline — candidacy exchange,
reductions, the keyed row exchange, the overlapped convergence check — must
go through the `Prims` layer in ``core/engine.py``. No other core module may
call a raw ``jax.lax`` collective: that is what keeps the local / sim / spmd
backends bit-interchangeable and the 1/2/4/8-shard parity suites meaningful.
"""
import pathlib
import re

import pytest

CORE = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro" / "core"

# collective primitives that move data across the shard axis
_COLLECTIVE = re.compile(
    r"\bjax\.lax\.(all_to_all|psum|psum_scatter|all_gather|ppermute|"
    r"pshuffle|axis_index|pmean|pmax|pmin)\b")


def _strip_comments(text: str) -> str:
    return "\n".join(line.split("#", 1)[0] for line in text.splitlines())


def test_no_raw_collectives_outside_engine_prims():
    offenders = {}
    for path in sorted(CORE.glob("*.py")):
        if path.name == "engine.py":  # the prims seam itself
            continue
        hits = _COLLECTIVE.findall(_strip_comments(path.read_text()))
        if hits:
            offenders[path.name] = sorted(set(hits))
    assert not offenders, (
        f"raw jax.lax collectives outside the core/engine.py prims seam: "
        f"{offenders} — route them through Prims instead")


def test_engine_prims_expose_the_full_seam():
    """The Prims tuple carries every collective the refactor added — the
    keyed row exchange and the overlap combinator — on all three backends."""
    from repro.core import engine

    for prims in (engine.local_prims(), ):
        for field in ("exchange", "all_reduce_or", "psum", "axis_index",
                      "exchange_rows", "overlap"):
            assert callable(getattr(prims, field)), field


def test_collective_pattern_matches_known_spellings():
    """Guard the guard: engine.py itself must still match the regex, so a
    rename of the collective spellings can't silently blind this test."""
    text = _strip_comments((CORE / "engine.py").read_text())
    assert _COLLECTIVE.search(text), (
        "core/engine.py no longer matches the collective regex; update "
        "test_collective_discipline.py to track the new spellings")


if __name__ == "__main__":
    pytest.main([__file__, "-v"])

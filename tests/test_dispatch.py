"""Compat/registry dispatch contract (CPU-runnable):

  - routing: force_pallas off-TPU -> interpret mode, ineligible shapes -> ref,
    plain CPU calls -> ref, for all four registered kernels,
  - parity: the interpret-mode Pallas path and the reference oracle agree
    (allclose / exact) through the SAME public ops wrapper,
  - trap-to-ref: a Pallas entrypoint that dies with an API-drift error falls
    back to the oracle unless force_pallas pins the kernel path,
  - compat shims: make_mesh accepts axis-type names on this JAX, shard_map
    resolves, packed NLCC frontier equals the boolean-plane wave.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.graph.blocked import build_blocked_structure
from repro.graph.structs import DeviceGraph
from repro.graph import generators as gen
from repro.kernels import compat, ops, ref, registry


def _graph_args(scale=6, w=2, bn=64):
    g = gen.rmat_graph(scale, edge_factor=4, seed=scale)
    dg = DeviceGraph.from_host(g)
    rng = np.random.default_rng(scale)
    vals = jnp.asarray(rng.integers(0, 2**32, size=(g.n, w), dtype=np.uint32))
    active = jnp.asarray(rng.random(dg.m) < 0.7)
    bs = build_blocked_structure(np.asarray(dg.src), np.asarray(dg.dst), g.n, bn=bn)
    return (vals, dg.src, dg.dst, g.n, active, bs)


def _attn_args(s=256, d=128):
    rng = np.random.default_rng(s)
    q = jnp.asarray(rng.standard_normal((1, 2, s, d)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 2, s, d)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 2, s, d)) * 0.3, jnp.float32)
    return (q, k, v)


def _seg_args(nt=8, dd=5, f=128):
    rng = np.random.default_rng(nt + f)
    feats = jnp.asarray(rng.standard_normal((nt, dd, f)), jnp.float32)
    mask = jnp.asarray(rng.random((nt, dd)) < 0.8)
    return (feats, mask)


def _bag_args(v=200, d=128, b=4, l=3):
    rng = np.random.default_rng(v)
    table = jnp.asarray(rng.standard_normal((v, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, v, size=(b, l)), jnp.int32)
    weights = jnp.asarray((rng.random((b, l)) < 0.9), jnp.float32)
    return (table, ids, weights)


def test_all_five_kernels_registered():
    assert registry.names() == (
        "bitset_spmm", "bitset_wave", "embedding_bag", "flash_attention",
        "segment_agg",
    )


def _wave_args(scale=6, w=2, bn=64, hops=3):
    vals, src, dst, n, active, bs = _graph_args(scale=scale, w=w, bn=bn)
    rng = np.random.default_rng(scale + hops)
    cand = jnp.asarray(
        np.where(rng.random((hops, n)) < 0.8, np.uint32(0xFFFFFFFF), np.uint32(0))
    )
    return (vals, src, dst, n, active, cand, bs)


# --------------------------------------------------------------- routing
CASES = [
    ("bitset_spmm", _graph_args(), {}),
    ("bitset_wave", _wave_args(), {}),
    ("segment_agg", _seg_args(), {}),
    ("flash_attention", _attn_args(), {"causal": True, "window": None,
                                       "block_q": 128, "block_k": 128}),
    ("embedding_bag", _bag_args(), {"mode": "sum"}),
]


@pytest.mark.parametrize("name,args,kw", CASES, ids=[c[0] for c in CASES])
def test_force_pallas_routes_to_interpret_off_tpu(name, args, kw):
    assert registry.resolve_mode(
        name, *args, force_pallas=True, backend="cpu", **kw
    ) == registry.MODE_INTERPRET


@pytest.mark.parametrize("name,args,kw", CASES, ids=[c[0] for c in CASES])
def test_cpu_without_force_routes_to_ref(name, args, kw):
    assert registry.resolve_mode(
        name, *args, backend="cpu", **kw
    ) == registry.MODE_REF


@pytest.mark.parametrize("name,args,kw", CASES, ids=[c[0] for c in CASES])
def test_tpu_backend_routes_to_compiled_pallas(name, args, kw):
    assert registry.resolve_mode(
        name, *args, backend="tpu", **kw
    ) == registry.MODE_PALLAS


INELIGIBLE = [
    # no blocked structure -> the kernel's grid cannot be built
    ("bitset_spmm", _graph_args()[:5] + (None,), {}),
    # fused wave without a blocked structure -> scan-based oracle
    ("bitset_wave", _wave_args()[:6] + (None,), {}),
    # NT % tile_n != 0
    ("segment_agg", _seg_args(nt=6), {}),
    # S not divisible by the kv block
    ("flash_attention", _attn_args(s=300), {"causal": True, "window": None,
                                            "block_q": 128, "block_k": 128}),
    # d_qk != d_v (MLA regime) — kernel assumes same dims
    ("flash_attention",
     (_attn_args()[0], _attn_args()[1], _attn_args()[2][..., :64]),
     {"causal": True, "window": None, "block_q": 128, "block_k": 128}),
]


@pytest.mark.parametrize("name,args,kw", INELIGIBLE,
                         ids=["no-blocked", "wave-no-blocked",
                              "tile-misaligned", "seq-misaligned",
                              "dqk-ne-dv"])
def test_ineligible_shapes_route_to_ref_even_forced(name, args, kw):
    assert registry.resolve_mode(
        name, *args, force_pallas=True, backend="cpu", **kw
    ) == registry.MODE_REF
    assert registry.resolve_mode(
        name, *args, backend="tpu", **kw
    ) == registry.MODE_REF


# ---------------------------------------------------------------- parity
def test_bitset_wave_parity_through_wrapper():
    vals, src, dst, n, active, cand, bs = _wave_args()
    got = ops.bitset_wave(vals, src, dst, n, active, cand,
                          blocked=bs, force_pallas=True)
    want = ops.bitset_wave(vals, src, dst, n, active, cand, blocked=None)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bitset_wave_equals_hop_by_hop_spmm():
    # the fused L-hop wave must equal L single-hop bitset_spmm aggregations
    # with the per-hop candidacy mask applied in between
    vals, src, dst, n, active, cand, bs = _wave_args(hops=4)
    got = ops.bitset_wave(vals, src, dst, n, active, cand,
                          blocked=bs, force_pallas=True)
    step = vals
    for r in range(cand.shape[0]):
        agg = ops.bitset_or_aggregate(step, src, dst, n, active, blocked=None)
        step = agg & cand[r][:, None]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(step))


def test_bitset_spmm_parity_through_wrapper():
    vals, src, dst, n, active, bs = _graph_args()
    got = ops.bitset_or_aggregate(vals, src, dst, n, active,
                                  blocked=bs, force_pallas=True)
    want = ops.bitset_or_aggregate(vals, src, dst, n, active, blocked=None)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_segment_agg_parity_through_wrapper():
    feats, mask = _seg_args()
    deg = jnp.sum(mask, axis=1).astype(jnp.float32)
    got = ops.neighborhood_agg(feats, mask, deg, force_pallas=True)
    want = ops.neighborhood_agg(feats, mask, deg, force_pallas=False)
    for key in ("sum", "mean", "min", "max", "std"):
        np.testing.assert_allclose(np.asarray(got[key]), np.asarray(want[key]),
                                   rtol=2e-5, atol=2e-5)


def test_attention_parity_through_wrapper():
    q, k, v = _attn_args()
    got = ops.attention(q, k, v, causal=True, force_pallas=True)
    want = ops.attention(q, k, v, causal=True, force_pallas=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_embedding_bag_parity_through_wrapper():
    table, ids, weights = _bag_args()
    got = ops.embedding_bag(table, ids, weights, mode="mean", force_pallas=True)
    want = ops.embedding_bag(table, ids, weights, mode="mean")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------- trap-to-ref
def test_trap_to_ref_falls_back_unless_forced():
    calls = {"pallas": 0, "ref": 0}

    def broken_pallas(x, *, interpret):
        calls["pallas"] += 1
        raise AttributeError("module has no attribute (simulated API drift)")

    def oracle(x):
        calls["ref"] += 1
        return x + 1

    registry.register("_test_broken", pallas=broken_pallas, ref=oracle)
    try:
        with pytest.warns(RuntimeWarning, match="falling back"):
            out = registry.dispatch("_test_broken", jnp.asarray(1),
                                    backend="tpu")
        assert int(out) == 2 and calls == {"pallas": 1, "ref": 1}
        with pytest.raises(AttributeError):
            registry.dispatch("_test_broken", jnp.asarray(1),
                              force_pallas=True, backend="cpu")
    finally:
        registry._REGISTRY.pop("_test_broken", None)


def test_unknown_kernel_name_is_a_clear_error():
    with pytest.raises(KeyError, match="no kernel"):
        registry.dispatch("nope", 1)


# ---------------------------------------------------------------- compat
def test_make_mesh_accepts_axis_type_names():
    n = len(jax.devices())
    mesh = compat.make_mesh((n,), ("data",), axis_types=("auto",))
    assert mesh.axis_names == ("data",)
    assert mesh.devices.size == n


def test_shard_map_resolves_on_this_jax():
    mesh = compat.make_mesh((1,), ("x",))
    from jax.sharding import PartitionSpec as P

    f = compat.shard_map(lambda a: a * 2, mesh=mesh,
                         in_specs=(P(),), out_specs=P(), check_vma=False)
    np.testing.assert_array_equal(
        np.asarray(f(jnp.arange(4))), np.arange(4) * 2)


def test_tpu_compiler_params_resolves_dimension_semantics():
    params = compat.tpu_compiler_params(dimension_semantics=("arbitrary",))
    assert params is not None
    assert tuple(params.dimension_semantics) == ("arbitrary",)


# ----------------------------------------- packed NLCC frontier integration
def test_packed_walk_constraint_matches_boolean_plane():
    from repro.core import Template, init_state
    from repro.core.nlcc import (
        check_walk_constraint, check_walk_constraint_packed,
    )
    from repro.core.state import PruneState

    g = gen.erdos_renyi_graph(120, 5.0, seed=9, n_labels=3)
    dg = DeviceGraph.from_host(g)
    tmpl = Template([0, 1, 2], [(0, 1), (1, 2), (2, 0)])
    st = init_state(dg, tmpl)
    bs = build_blocked_structure(np.asarray(dg.src), np.asarray(dg.dst),
                                 g.n, bn=64)
    walk = (0, 1, 2, 0)
    cand = jnp.stack([st.omega[:, q] for q in walk], axis=0)
    sources = np.flatnonzero(np.asarray(st.omega[:, 0]))[:32]
    ids = np.full(32, -1, np.int64)
    ids[: sources.size] = sources
    ids = jnp.asarray(ids, jnp.int32)

    want, _ = check_walk_constraint(dg, st, cand, True, ids)
    got = check_walk_constraint_packed(dg, st, cand, True, ids, bs)
    got_forced = check_walk_constraint_packed(
        dg, st, cand, True, ids, bs, force_pallas=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got_forced), np.asarray(want))


def test_prune_with_blocked_structure_matches_default():
    from repro.core import Template, prune

    g = gen.erdos_renyi_graph(100, 5.0, seed=3, n_labels=3)
    tmpl = Template([0, 1, 2], [(0, 1), (1, 2), (2, 0)])
    dg = DeviceGraph.from_host(g)
    bs = build_blocked_structure(np.asarray(dg.src), np.asarray(dg.dst),
                                 g.n, bn=64)
    base = prune(g, tmpl)
    packed = prune(g, tmpl, blocked=bs)
    np.testing.assert_array_equal(base.omega, packed.omega)
    np.testing.assert_array_equal(base.vertex_mask, packed.vertex_mask)
    np.testing.assert_array_equal(base.edge_mask, packed.edge_mask)


# --------------------------------------------- fused NLCC wave engine
def _nlcc_setup(n=120, seed=9, bn=64):
    from repro.core import Template, init_state

    g = gen.erdos_renyi_graph(n, 5.0, seed=seed, n_labels=3)
    dg = DeviceGraph.from_host(g)
    tmpl = Template([0, 1, 2], [(0, 1), (1, 2), (2, 0)])
    st = init_state(dg, tmpl)
    bs = build_blocked_structure(np.asarray(dg.src), np.asarray(dg.dst),
                                 g.n, bn=bn)
    return g, dg, tmpl, st, bs


def _wave_ids(st, q0, wave, limit=None):
    sources = np.flatnonzero(np.asarray(st.omega[:, q0]))[: limit or wave]
    ids = np.full(wave, -1, np.int64)
    ids[: sources.size] = sources
    return jnp.asarray(ids, jnp.int32)


@pytest.mark.parametrize("walk,is_cyclic", [
    ((0, 1, 2, 0), True),   # cyclic: token must return to its source
    ((0, 1, 2), False),     # path: the paper's ack at a different vertex
], ids=["cyclic", "path"])
@pytest.mark.parametrize("wave,limit", [
    (32, None),   # word-aligned, fully populated
    (64, 10),     # padded wave: sources < wave
], ids=["aligned", "padded"])
def test_fused_wave_matches_boolean_plane(walk, is_cyclic, wave, limit):
    from repro.core.nlcc import (
        check_walk_constraint, check_walk_constraint_fused,
    )

    g, dg, tmpl, st, bs = _nlcc_setup()
    cand = jnp.stack([st.omega[:, q] for q in walk], axis=0)
    ids = _wave_ids(st, walk[0], wave, limit)

    want, _ = check_walk_constraint(dg, st, cand, is_cyclic, ids)
    got = check_walk_constraint_fused(dg, st, cand, is_cyclic, ids, bs)
    got_forced = check_walk_constraint_fused(
        dg, st, cand, is_cyclic, ids, bs, force_pallas=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    np.testing.assert_array_equal(np.asarray(got_forced), np.asarray(want))


def test_fused_wave_empty_frontier_and_all_pruned_sources():
    from repro.core.nlcc import check_walk_constraint_fused

    g, dg, tmpl, st, bs = _nlcc_setup()
    walk = (0, 1, 2, 0)
    cand = jnp.stack([st.omega[:, q] for q in walk], axis=0)
    # empty frontier: every wave slot is padding
    empty = jnp.full((32,), -1, jnp.int32)
    for force in (False, True):
        out = check_walk_constraint_fused(
            dg, st, cand, True, empty, bs, force_pallas=force)
        assert not np.asarray(out).any()
    # all-pruned sources: head candidacy fully eliminated kills every token
    ids = _wave_ids(st, walk[0], 32)
    dead = cand.at[0].set(jnp.zeros_like(cand[0]))
    for force in (False, True):
        out = check_walk_constraint_fused(
            dg, st, dead, True, ids, bs, force_pallas=force)
        assert not np.asarray(out).any()


def test_fused_route_gates_fall_back_to_unpacked():
    from repro.core.nlcc import nlcc_resolved_route, NLCC_ROUTE

    g, dg, tmpl, st, bs = _nlcc_setup()
    pol = registry.DispatchPolicy()
    pol.set_route(NLCC_ROUTE, "cpu", registry.BUCKET_ANY, registry.ROUTE_FUSED)
    registry.set_policy(pol)
    try:
        assert nlcc_resolved_route(st, 32, bs) == registry.ROUTE_FUSED
        # capability gates beat the tuned fused choice
        assert nlcc_resolved_route(st, 32, None) == registry.ROUTE_UNPACKED
        assert nlcc_resolved_route(st, 33, bs) == registry.ROUTE_UNPACKED
        assert nlcc_resolved_route(
            st, 32, bs, count_messages=True) == registry.ROUTE_UNPACKED
        # force_pallas still pins the per-hop packed parity path
        assert nlcc_resolved_route(
            st, 32, bs, force_pallas=True) == registry.ROUTE_PACKED
    finally:
        registry.set_policy(None)


def test_prune_fused_route_matches_default_and_reports_waves():
    from repro.core import Template, prune
    from repro.core.nlcc import NLCC_ROUTE

    g, dg, tmpl, st, bs = _nlcc_setup(seed=3, n=100)
    registry.set_policy(None)
    base = prune(g, tmpl, blocked=bs)
    pol = registry.DispatchPolicy()
    pol.set_route(NLCC_ROUTE, "cpu", registry.BUCKET_ANY, registry.ROUTE_FUSED)
    registry.set_policy(pol)
    try:
        fused = prune(g, tmpl, blocked=bs)
    finally:
        registry.set_policy(None)
    assert fused.stats["dispatch_routes"][NLCC_ROUTE] == registry.ROUTE_FUSED
    fused_waves = sum(p.extra.get("nlcc_fused_waves", 0) for p in fused.phases)
    other_waves = sum(p.extra.get("nlcc_packed_waves", 0)
                      + p.extra.get("nlcc_plane_waves", 0)
                      for p in fused.phases)
    assert fused_waves > 0 and other_waves == 0
    np.testing.assert_array_equal(base.omega, fused.omega)
    np.testing.assert_array_equal(base.edge_mask, fused.edge_mask)


def test_wave_executor_syncs_host_at_most_twice_per_constraint():
    """The acceptance contract: survivors accumulate on device — host syncs
    per CC/PC constraint stay bounded (head-candidacy read + optional message
    readback) no matter how many waves the constraint takes."""
    from repro.core import Template, prune

    g, dg, tmpl, st, bs = _nlcc_setup()
    # wave=32 forces many waves per constraint (~40 sources per label)
    res = prune(g, tmpl, wave=32, blocked=bs)
    stats_sum = {}
    for p in res.phases:
        for k, v in p.extra.items():
            stats_sum[k] = stats_sum.get(k, 0) + v
    n_constraints = stats_sum.get("nlcc_constraints", 0)
    n_waves = stats_sum.get("nlcc_waves", 0)
    assert n_constraints > 0 and n_waves > n_constraints
    assert stats_sum["nlcc_host_syncs"] <= 2 * n_constraints

    # the instrumented path may add exactly one message readback
    res2 = prune(g, tmpl, wave=32, collect_stats=True)
    stats_sum2 = {}
    for p in res2.phases:
        for k, v in p.extra.items():
            stats_sum2[k] = stats_sum2.get(k, 0) + v
    assert stats_sum2["nlcc_host_syncs"] <= 2 * stats_sum2["nlcc_constraints"]


def test_fused_route_packs_once_per_wave(monkeypatch):
    """Pack/unpack must happen once per wave on the fused route — not once
    per hop (the per-hop oracle round-trip the fused engine eliminates)."""
    from repro.core import state as state_mod
    from repro.core.nlcc import check_walk_constraint_fused

    g, dg, tmpl, st, bs = _nlcc_setup()
    walk = (0, 1, 2, 0)  # 3 hops
    cand = jnp.stack([st.omega[:, q] for q in walk], axis=0)
    ids = _wave_ids(st, 0, 32)

    calls = {"pack": 0, "unpack": 0}
    real_pack, real_unpack = state_mod.pack_bits, state_mod.unpack_bits

    def counting_pack(x):
        calls["pack"] += 1
        return real_pack(x)

    def counting_unpack(x, n0):
        calls["unpack"] += 1
        return real_unpack(x, n0)

    monkeypatch.setattr(state_mod, "pack_bits", counting_pack)
    monkeypatch.setattr(state_mod, "unpack_bits", counting_unpack)
    for force in (False, True):  # scan-based oracle AND interpret-mode kernel
        calls["pack"] = calls["unpack"] = 0
        check_walk_constraint_fused(
            dg, st, cand, True, ids, bs, force_pallas=force)
        assert calls == {"pack": 1, "unpack": 1}

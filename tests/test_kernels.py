"""Per-kernel correctness: interpret-mode Pallas vs pure-jnp oracle,
swept over shapes and dtypes (deliverable (c))."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.graph.blocked import build_blocked_structure, masks_from_active, pad_values
from repro.graph.structs import Graph, DeviceGraph
from repro.graph import generators as gen
from repro.kernels import ref
from repro.kernels import ops
from repro.kernels.bitset_spmm import bitset_spmm
from repro.kernels.segment_agg import segment_agg
from repro.kernels.flash_attention import flash_attention
from repro.kernels.embedding_bag import embedding_bag


# ------------------------------------------------------------- bitset_spmm
@pytest.mark.parametrize("scale,w,bn", [(6, 1, 64), (7, 2, 128), (8, 4, 64), (6, 8, 32)])
def test_bitset_spmm_matches_ref(scale, w, bn):
    g = gen.rmat_graph(scale, edge_factor=4, seed=scale + w)
    dg = DeviceGraph.from_host(g)
    rng = np.random.default_rng(scale * 10 + w)
    vals = jnp.asarray(rng.integers(0, 2**32, size=(g.n, w), dtype=np.uint32))
    active = jnp.asarray(rng.random(dg.m) < 0.7)

    want = ref.bitset_spmm_ref(vals, dg.src, dg.dst, g.n, active)

    bs = build_blocked_structure(np.asarray(dg.src), np.asarray(dg.dst), g.n, bn=bn)
    got = ops.bitset_or_aggregate(
        vals, dg.src, dg.dst, g.n, active, blocked=bs, force_pallas=True
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bitset_spmm_all_edges_inactive():
    g = gen.erdos_renyi_graph(100, 4.0, seed=0)
    dg = DeviceGraph.from_host(g)
    vals = jnp.ones((g.n, 1), jnp.uint32)
    bs = build_blocked_structure(np.asarray(dg.src), np.asarray(dg.dst), g.n, bn=32)
    got = ops.bitset_or_aggregate(
        vals, dg.src, dg.dst, g.n, jnp.zeros(dg.m, bool), blocked=bs, force_pallas=True
    )
    assert int(np.asarray(got).sum()) == 0


# ------------------------------------------------------------- bitset_wave
@pytest.mark.parametrize("scale,w,bn,hops", [
    (6, 1, 64, 1),    # single hop degenerates to bitset_spmm + mask
    (7, 2, 128, 3),
    (8, 4, 64, 5),
    (6, 8, 32, 2),
])
def test_bitset_wave_matches_ref(scale, w, bn, hops):
    g = gen.rmat_graph(scale, edge_factor=4, seed=scale + w)
    dg = DeviceGraph.from_host(g)
    rng = np.random.default_rng(scale * 10 + w + hops)
    vals = jnp.asarray(rng.integers(0, 2**32, size=(g.n, w), dtype=np.uint32))
    active = jnp.asarray(rng.random(dg.m) < 0.7)
    cand = jnp.asarray(np.where(
        rng.random((hops, g.n)) < 0.8, np.uint32(0xFFFFFFFF), np.uint32(0)))

    want = ref.bitset_wave_ref(vals, dg.src, dg.dst, g.n, active, cand)
    bs = build_blocked_structure(np.asarray(dg.src), np.asarray(dg.dst), g.n, bn=bn)
    got = ops.bitset_wave(
        vals, dg.src, dg.dst, g.n, active, cand, blocked=bs, force_pallas=True
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bitset_wave_ref_equals_iterated_spmm_ref():
    # the scan-based packed-word oracle against L iterations of the
    # single-hop oracle with the candidacy mask applied between hops
    g = gen.erdos_renyi_graph(200, 5.0, seed=11)
    dg = DeviceGraph.from_host(g)
    rng = np.random.default_rng(11)
    vals = jnp.asarray(rng.integers(0, 2**32, size=(g.n, 2), dtype=np.uint32))
    active = jnp.asarray(rng.random(dg.m) < 0.6)
    cand = jnp.asarray(np.where(
        rng.random((4, g.n)) < 0.75, np.uint32(0xFFFFFFFF), np.uint32(0)))
    got = ref.bitset_wave_ref(vals, dg.src, dg.dst, g.n, active, cand)
    step = vals
    for r in range(cand.shape[0]):
        step = ref.bitset_spmm_ref(step, dg.src, dg.dst, g.n, active) & cand[r][:, None]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(step))


def test_bitset_wave_all_edges_inactive():
    g = gen.erdos_renyi_graph(100, 4.0, seed=0)
    dg = DeviceGraph.from_host(g)
    vals = jnp.ones((g.n, 1), jnp.uint32)
    cand = jnp.full((2, g.n), 0xFFFFFFFF, jnp.uint32)
    bs = build_blocked_structure(np.asarray(dg.src), np.asarray(dg.dst), g.n, bn=32)
    for force in (False, True):
        got = ops.bitset_wave(
            vals, dg.src, dg.dst, g.n, jnp.zeros(dg.m, bool), cand,
            blocked=bs, force_pallas=force)
        assert int(np.asarray(got).sum()) == 0


def test_bitset_wave_zero_hops_is_identity():
    vals = jnp.asarray(
        np.random.default_rng(0).integers(0, 2**32, size=(16, 2), dtype=np.uint32))
    src = jnp.zeros((0,), jnp.int32)
    dst = jnp.zeros((0,), jnp.int32)
    cand = jnp.zeros((0, 16), jnp.uint32)
    out = ops.bitset_wave(vals, src, dst, 16, jnp.zeros((0,), bool), cand)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(vals))


def test_bitset_wave_vmem_budget_gates_eligibility():
    from repro.kernels.ops import _wave_eligible, BITSET_WAVE_VMEM_BUDGET

    g = gen.erdos_renyi_graph(256, 3.0, seed=1)
    dg = DeviceGraph.from_host(g)
    bs = build_blocked_structure(np.asarray(dg.src), np.asarray(dg.dst), g.n, bn=64)
    small = jnp.ones((g.n, 2), jnp.uint32)
    cand = jnp.ones((2, g.n), jnp.uint32)
    assert _wave_eligible(small, dg.src, dg.dst, g.n, None, cand, bs)
    # a frontier too wide to keep resident in VMEM must route to the oracle
    huge_w = BITSET_WAVE_VMEM_BUDGET // (3 * bs.n_pad * 4) + 1
    huge = jnp.ones((g.n, huge_w), jnp.uint32)
    assert not _wave_eligible(huge, dg.src, dg.dst, g.n, None, cand, bs)
    assert not _wave_eligible(small, dg.src, dg.dst, g.n, None, cand, None)


def test_blocked_masks_roundtrip():
    """Every (src,dst) arc must land on exactly its bit."""
    g = gen.erdos_renyi_graph(300, 5.0, seed=3)
    dg = DeviceGraph.from_host(g)
    bs = build_blocked_structure(np.asarray(dg.src), np.asarray(dg.dst), g.n, bn=64)
    masks = np.asarray(masks_from_active(bs, jnp.ones(dg.m, bool)))
    src, dst = np.asarray(dg.src), np.asarray(dg.dst)
    total_bits = sum(bin(int(x)).count("1") for x in masks.reshape(-1))
    assert total_bits == dg.m
    for e in np.random.default_rng(0).integers(0, dg.m, 20):
        b = bs.edge_block[e]
        r, c = dst[e] % bs.bn, src[e] % bs.bn
        assert (masks[b, r, c // 32] >> (c % 32)) & 1 == 1


# ------------------------------------------------------------- segment_agg
@pytest.mark.parametrize("nt,d,f,dtype", [
    (16, 10, 128, jnp.float32),
    (8, 25, 256, jnp.float32),
    (32, 4, 128, jnp.bfloat16),
])
def test_segment_agg_matches_ref(nt, d, f, dtype):
    rng = np.random.default_rng(nt + d)
    feats = jnp.asarray(rng.standard_normal((nt, d, f)), dtype)
    mask = jnp.asarray(rng.random((nt, d)) < 0.8)
    got = segment_agg(feats, mask, interpret=True)
    want = ref.segment_agg_ref(feats, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2)


def test_neighborhood_agg_stats():
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.standard_normal((8, 6, 128)), jnp.float32)
    mask = jnp.ones((8, 6), bool).at[0, 3:].set(False).at[1].set(False)
    deg = jnp.sum(mask, axis=1).astype(jnp.float32)
    out = ops.neighborhood_agg(feats, mask, deg, force_pallas=True)
    x0 = np.asarray(feats)[0, :3]
    np.testing.assert_allclose(np.asarray(out["mean"][0]), x0.mean(0), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out["std"][0]), x0.std(0), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out["max"][1]), 0.0)  # empty segment


# --------------------------------------------------------- flash_attention
@pytest.mark.parametrize("b,hq,hkv,s,d,causal,window", [
    (1, 4, 4, 256, 128, True, None),    # MHA causal
    (2, 8, 2, 256, 128, True, None),    # GQA
    (1, 4, 1, 384, 128, False, None),   # MQA bidirectional
    (1, 2, 2, 512, 128, True, 128),     # sliding window (StarCoder2 regime)
    (1, 2, 2, 256, 256, True, None),    # wide head dim
    (3, 6, 3, 128, 128, True, 64),      # GQA + window, odd batch
])
def test_flash_attention_matches_ref(b, hq, hkv, s, d, causal, window):
    rng = np.random.default_rng(hq * s)
    q = jnp.asarray(rng.standard_normal((b, hq, s, d)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)) * 0.3, jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((1, 2, 256, 128)) * 0.3, jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 2, 256, 128)) * 0.3, jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 2, 256, 128)) * 0.3, jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=5e-2, atol=5e-2
    )


# ----------------------------------------------------------- embedding_bag
@pytest.mark.parametrize("v,d,b,l,mode", [
    (1000, 128, 8, 4, "sum"),
    (5000, 256, 16, 10, "mean"),
    (128, 128, 4, 1, "sum"),
    (2048, 512, 2, 32, "mean"),   # long bags, wide rows
])
def test_embedding_bag_matches_ref(v, d, b, l, mode):
    rng = np.random.default_rng(v + b)
    table = jnp.asarray(rng.standard_normal((v, d)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, v, size=(b, l)), jnp.int32)
    weights = jnp.asarray((rng.random((b, l)) < 0.9), jnp.float32)  # some padding
    got = embedding_bag(table, ids, weights, mode=mode, interpret=True)
    want = ref.embedding_bag_ref(table, ids, weights, mode=mode)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_lcc_fixpoint_packed_engine_parity():
    """The engine's packed-word LCC (bitset_spmm kernel path) must reach the
    same fixpoint as the boolean-plane reference iteration."""
    from repro.core.state import init_state
    from repro.core.template import Template
    from repro.core.lcc import TemplateDev, lcc_iteration, lcc_iteration_packed

    g = gen.rmat_graph(8, edge_factor=6, seed=4, labeler="random", n_labels=4)
    dg = DeviceGraph.from_host(g)
    tmpl = Template([0, 1, 2, 3], [(0, 1), (1, 2), (2, 3), (3, 0)])
    tdev = TemplateDev(tmpl)
    bs = build_blocked_structure(np.asarray(dg.src), np.asarray(dg.dst), g.n, bn=64)

    st_ref = st_pk = init_state(dg, tmpl)
    for _ in range(20):
        st_ref, ch_ref = lcc_iteration(dg, tdev, st_ref)
        st_pk, ch_pk = lcc_iteration_packed(dg, tdev, st_pk, bs, force_pallas=True)
        np.testing.assert_array_equal(np.asarray(st_ref.omega), np.asarray(st_pk.omega))
        np.testing.assert_array_equal(
            np.asarray(st_ref.edge_active), np.asarray(st_pk.edge_active))
        if not bool(ch_ref):
            break
    assert not bool(ch_ref) and not bool(ch_pk)


def test_lcc_sweep_via_bitset_kernel_equals_segment_path():
    """The engine's LCC OR-aggregation through the kernel path must equal the
    boolean-plane segment path used by lcc.py."""
    from repro.core.state import pack_bits, unpack_bits, init_state
    from repro.core.template import Template
    from repro.graph import segment_ops

    g = gen.erdos_renyi_graph(200, 6.0, seed=5, n_labels=3)
    dg = DeviceGraph.from_host(g)
    tmpl = Template([0, 1, 2], [(0, 1), (1, 2), (2, 0)])
    st = init_state(dg, tmpl)
    packed = pack_bits(st.omega)
    bs = build_blocked_structure(np.asarray(dg.src), np.asarray(dg.dst), g.n, bn=64)
    got = ops.bitset_or_aggregate(
        packed, dg.src, dg.dst, g.n, st.edge_active, blocked=bs, force_pallas=True
    )
    msgs = jnp.take(st.omega, dg.src, axis=0) & st.edge_active[:, None]
    want = segment_ops.segment_or_bool(msgs, dg.dst, g.n)
    np.testing.assert_array_equal(
        np.asarray(unpack_bits(got, tmpl.n0)), np.asarray(want)
    )

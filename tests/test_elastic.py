"""Elastic scaling: checkpoints are global arrays + manifest, so a run can
resume on a DIFFERENT device count / mesh shape (the paper's LB-16 / LB-1
smaller-deployment scenario, applied to the training substrate). Verified in
subprocesses with different forced host-device counts."""
import os
import subprocess
import sys
import tempfile
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, cwd=REPO)
    assert out.returncode == 0, out.stdout + out.stderr
    return out.stdout


def test_checkpoint_restores_onto_different_mesh():
    with tempfile.TemporaryDirectory() as ckpt_dir:
        save_code = textwrap.dedent(f"""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.configs import get_arch
            from repro.train import TrainConfig, build_train_step, init_state
            from repro.optim.adamw import AdamWConfig
            from repro.data import SyntheticTokenStream
            from repro.checkpoint import ckpt
            from repro.launch.abstract import shardings_for
            from repro.sharding import active_mesh

            mesh = jax.make_mesh((4, 2), ("data", "model"))
            cfg = get_arch("qwen2-1.5b").smoke()
            tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3))
            with active_mesh(mesh):
                state, specs = init_state(jax.random.key(0), cfg, tc)
                sh = shardings_for(jax.eval_shape(lambda: state), specs, mesh)
                state = jax.device_put(state, sh)
                # out_shardings pins the output state onto the same
                # NamedShardings as the input; leaving it unspecified lets
                # GSPMD drift the state sharding between iterations, which
                # the declared in_shardings then rejects.
                step = jax.jit(build_train_step(cfg, tc),
                               in_shardings=(sh, None), out_shardings=(sh, None))
                stream = SyntheticTokenStream(cfg.vocab, 8, 32, seed=0)
                for i in range(3):
                    state, metrics = step(state, stream(i))
            ckpt.save_checkpoint({ckpt_dir!r}, 3, state)
            print("SAVED loss", float(metrics["loss"]))
        """)
        out1 = _run(save_code)
        assert "SAVED" in out1

        # restore on 3 devices with a different mesh, keep training
        restore_code = textwrap.dedent(f"""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=3"
            import jax, jax.numpy as jnp
            from repro.configs import get_arch
            from repro.train import TrainConfig, build_train_step, init_state
            from repro.optim.adamw import AdamWConfig
            from repro.data import SyntheticTokenStream
            from repro.checkpoint import ckpt
            from repro.launch.abstract import shardings_for
            from repro.sharding import active_mesh

            mesh = jax.make_mesh((3, 1), ("data", "model"))
            cfg = get_arch("qwen2-1.5b").smoke()
            tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3))
            with active_mesh(mesh):
                like, specs = init_state(jax.random.key(0), cfg, tc)
                sh = shardings_for(jax.eval_shape(lambda: like), specs, mesh)
                state, meta = ckpt.restore_checkpoint({ckpt_dir!r}, like, shardings=sh)
                assert int(meta["step"]) == 3
                assert int(state["step"]) == 3
                step = jax.jit(build_train_step(cfg, tc))
                stream = SyntheticTokenStream(cfg.vocab, 8, 32, seed=0)
                state, metrics = step(state, stream(3))
            import math
            assert math.isfinite(float(metrics["loss"]))
            print("RESUMED on 3 devices, loss", float(metrics["loss"]))
        """)
        out2 = _run(restore_code)
        assert "RESUMED on 3 devices" in out2

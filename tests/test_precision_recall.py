"""The paper's central claim (contributions (ii)+(iii)): the pruned solution
subgraph G* equals the union of all exact matches — 100% precision, 100%
recall, for arbitrary templates — and the collected omega equals the exact
per-vertex match lists. Verified against a brute-force enumeration oracle on
random graphs, plus the pathological structures of Fig. 2 that defeat pure
local checking.
"""
import numpy as np
import pytest

try:  # optional dev dependency: property tests degrade to skips without it
    from hypothesis import given, settings, strategies as st, HealthCheck
except ImportError:
    given = None

from repro.graph import erdos_renyi_graph, rmat_graph, cycle_graph, torus_graph
from repro.graph.structs import Graph
from repro.core import (
    Template, prune, enumerate_matches, solution_subgraph_oracle,
)
from conftest import sample_template_from


def _assert_exact(g, tmpl):
    res = prune(g, tmpl)
    vm_o, em_o, omega_o, matches = solution_subgraph_oracle(g, tmpl)
    order = np.lexsort((g.src, g.dst))
    assert np.array_equal(res.vertex_mask, vm_o), "vertex set differs from oracle"
    assert np.array_equal(res.edge_mask, em_o[order]), "edge set differs from oracle"
    assert np.array_equal(res.omega, omega_o), "omega differs from oracle"
    er = enumerate_matches(res.dg, res.state, tmpl)
    assert er.n_embeddings == len(matches)
    return res, matches


# ---------------------------------------------------------- Fig. 2 pathologies
def test_fig2a_unrolled_cycle_rejected():
    """3-cycle template; 3k-cycles with repeating labels survive LCC but must
    be eliminated by cycle checking."""
    tmpl = Template([0, 1, 2], [(0, 1), (1, 2), (2, 0)])
    bg = cycle_graph(6, [0, 1, 2, 0, 1, 2])
    res, matches = _assert_exact(bg, tmpl)
    assert res.counts()["V*"] == 0 and len(matches) == 0


def test_fig2b_path_constraint_needed():
    """Template with repeated labels where point-to-point local checks pass but
    no global assignment exists."""
    tmpl = Template([5, 1, 2, 5], [(0, 1), (1, 2), (2, 3)])
    # background: a path 5-1-2-? where the far endpoint label 5 is missing
    bg = Graph.from_undirected_pairs(
        5, [(0, 1), (1, 2), (2, 3), (3, 4)], [5, 1, 2, 1, 5]
    )
    _assert_exact(bg, tmpl)


def test_fig2c_torus_survives_cycle_checks_but_tds_rejects():
    """Doubly-periodic torus meets all cycle constraints of a 4-cycle-rich
    template but contains no 4-clique-overlap structure."""
    tmpl = Template(
        [0, 1, 2, 3], [(0, 1), (1, 2), (2, 0), (1, 3), (3, 2)]
    )  # two triangles sharing edge (1,2)
    bg = torus_graph(4, 3, np.tile([0, 1, 2, 3], 3))
    _assert_exact(bg, tmpl)


def test_triangle_exact_on_planted():
    tmpl = Template([0, 1, 2], [(0, 1), (1, 2), (2, 0)])
    g = Graph.from_undirected_pairs(
        6, [(0, 1), (1, 2), (2, 0), (2, 3), (3, 4), (4, 5), (5, 3)],
        [0, 1, 2, 0, 1, 2],
    )
    res, matches = _assert_exact(g, tmpl)
    assert len(matches) > 0


# ------------------------------------------------------------- property tests
if given is not None:
    @settings(max_examples=15, deadline=None, suppress_health_check=list(HealthCheck))
    @given(
        seed=st.integers(0, 10_000),
        n=st.integers(20, 70),
        avg_deg=st.floats(2.0, 5.0),
        n_labels=st.integers(2, 5),
        size=st.integers(3, 6),
    )
    def test_property_exactness_erdos_renyi(seed, n, avg_deg, n_labels, size):
        g = erdos_renyi_graph(n=n, avg_degree=avg_deg, seed=seed, n_labels=n_labels)
        if g.m == 0:
            return
        try:
            tmpl = sample_template_from(g, size, seed + 1)
        except ValueError:
            return
        if tmpl.n0 < 2 or tmpl.m0 < 1:
            return
        _assert_exact(g, tmpl)

    @settings(max_examples=6, deadline=None, suppress_health_check=list(HealthCheck))
    @given(seed=st.integers(0, 1000), size=st.integers(3, 5))
    def test_property_exactness_rmat(seed, size):
        g = rmat_graph(8, edge_factor=4, seed=seed)
        try:
            tmpl = sample_template_from(g, size, seed + 7)
        except ValueError:
            return
        if tmpl.n0 < 2 or tmpl.m0 < 1:
            return
        _assert_exact(g, tmpl)
else:
    def test_property_exactness_erdos_renyi():
        pytest.importorskip("hypothesis")

    def test_property_exactness_rmat():
        pytest.importorskip("hypothesis")


def test_recall_never_violated_heuristic_mode():
    """Even without the complete-TDS guarantee, recall must be 100%:
    heuristic pruning may keep false positives but never drops a match."""
    for seed in range(5):
        g = erdos_renyi_graph(40, 4.0, seed=seed, n_labels=3)
        if g.m == 0:
            continue
        try:
            tmpl = sample_template_from(g, 4, seed + 3)
        except ValueError:
            continue
        if tmpl.m0 < 1:
            continue
        res = prune(g, tmpl, guarantee_precision=False)
        vm_o, _, omega_o, _ = solution_subgraph_oracle(g, tmpl)
        assert np.all(res.omega[omega_o]), "heuristic mode dropped a true match"


def test_networkx_cross_check():
    """Independent oracle: networkx VF2 subgraph monomorphism count."""
    import networkx as nx
    from networkx.algorithms import isomorphism as iso

    g = erdos_renyi_graph(30, 4.0, seed=11, n_labels=2)
    tmpl = sample_template_from(g, 4, 13)
    if tmpl.m0 < 2:
        tmpl = Template([0, 1, 0], [(0, 1), (1, 2)])
    res = prune(g, tmpl)
    er = enumerate_matches(res.dg, res.state, tmpl)

    G = nx.Graph()
    G.add_nodes_from((i, {"l": int(g.labels[i])}) for i in range(g.n))
    G.add_edges_from(zip(g.src.tolist(), g.dst.tolist()))
    T = nx.Graph()
    T.add_nodes_from((i, {"l": int(tmpl.labels[i])}) for i in range(tmpl.n0))
    T.add_edges_from(tmpl.edge_set)
    gm = iso.GraphMatcher(G, T, node_match=lambda a, b: a["l"] == b["l"])
    nx_count = sum(1 for _ in gm.subgraph_monomorphisms_iter())
    assert er.n_embeddings == nx_count

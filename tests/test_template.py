"""Constraint generation (paper Table 2) unit tests."""
import numpy as np
import pytest

from repro.core.template import Template, generate_constraints


def test_triangle_gets_cycle_constraint():
    t = Template([0, 1, 2], [(0, 1), (1, 2), (2, 0)])
    cs = generate_constraints(t)
    # CC for the cycle + complete-walk TDS (exact edge set for cyclic templates)
    assert len(cs) == 2
    assert cs[0].kind == "cycle" and cs[0].is_cyclic and cs[0].length == 3
    assert cs[1].kind == "tds" and cs[1].complete
    # without the precision guarantee, CC alone (paper's Fig 2a claim)
    cs2 = generate_constraints(t, guarantee_precision=False)
    assert len(cs2) == 1 and cs2[0].kind == "cycle"


def test_acyclic_unique_labels_no_constraints():
    t = Template([0, 1, 2, 3], [(0, 1), (1, 2), (1, 3)])
    assert generate_constraints(t) == []


def test_path_constraint_same_label_three_hops():
    # labels a-b-c-a : same label pair at distance 3 -> PC + complete TDS
    t = Template([5, 1, 2, 5], [(0, 1), (1, 2), (2, 3)])
    cs = generate_constraints(t)
    kinds = [c.kind for c in cs]
    assert "path" in kinds
    pc = next(c for c in cs if c.kind == "path")
    assert not pc.is_cyclic and pc.length == 3
    assert any(c.kind == "tds" and c.complete for c in cs)


def test_same_label_two_hops_no_path_constraint():
    t = Template([5, 1, 5], [(0, 1), (1, 2)])
    cs = generate_constraints(t)
    assert all(c.kind != "path" for c in cs)  # LCC multiplicity handles distance 2


def test_cactus_classification():
    tri_plus_tail = Template([0, 1, 2, 3], [(0, 1), (1, 2), (2, 0), (2, 3)])
    assert tri_plus_tail.is_edge_monocyclic()
    # two triangles sharing an edge (non-edge-monocyclic; Fig 2c flavor)
    t = Template([0, 1, 2, 3], [(0, 1), (1, 2), (2, 0), (1, 3), (3, 2)])
    assert not t.is_edge_monocyclic()
    cs = generate_constraints(t)
    assert any(c.kind == "tds" for c in cs)


def test_complete_walk_covers_all_edges():
    t = Template([0, 0, 1, 1, 2], [(0, 1), (1, 2), (2, 3), (3, 4), (4, 0), (0, 2)])
    cs = generate_constraints(t)
    complete = [c for c in cs if c.complete]
    assert complete
    assert complete[0].edges() == set(t.edge_set)
    # consecutive walk entries are template edges
    for a, b in zip(complete[0].walk[:-1], complete[0].walk[1:]):
        assert t.has_edge(a, b)


def test_constraint_ordering():
    t = Template([0, 0, 1, 1, 2], [(0, 1), (1, 2), (2, 3), (3, 4), (4, 0), (0, 2)])
    cs = generate_constraints(t)
    kinds = [c.kind for c in cs]
    # all cycles/paths strictly before any tds
    if "tds" in kinds:
        first_tds = kinds.index("tds")
        assert all(k != "tds" for k in kinds[:first_tds])
        assert all(k == "tds" for k in kinds[first_tds:])


def test_constraint_cost_estimates():
    """Tripoul'18 primitives: cost grows with label frequency and walk
    length; selectivity grows as interior labels get rarer."""
    from repro.core.template import (
        estimate_walk_cost, estimate_constraint_selectivity, NonLocalConstraint,
    )
    t = Template([0, 1, 2, 0], [(0, 1), (1, 2), (2, 3), (3, 0)])
    freq = np.array([1000.0, 10.0, 10.0])
    c_cycle = NonLocalConstraint("cycle", (0, 1, 2, 3, 0))
    freq_rare = np.array([1000.0, 1.0, 1.0])
    cost_freq = estimate_walk_cost(t, c_cycle, freq)
    cost_rare = estimate_walk_cost(t, c_cycle, freq_rare)
    assert cost_freq > cost_rare  # frequent interior labels cost more
    sel_freq = estimate_constraint_selectivity(t, c_cycle, freq)
    sel_rare = estimate_constraint_selectivity(t, c_cycle, freq_rare)
    assert sel_rare >= sel_freq   # rare labels eliminate more sources
    # ordering: same-length constraints sorted cheapest-first
    # two triangles sharing vertex 0: one through frequent labels, one rare
    t2 = Template([0, 1, 2, 3, 4],
                  [(0, 1), (1, 2), (2, 0), (0, 3), (3, 4), (4, 0)])
    freq2 = np.array([100.0, 1000.0, 1000.0, 2.0, 2.0])
    cs = generate_constraints(t2, label_freq=freq2, guarantee_precision=False)
    cycles = [c for c in cs if c.kind == "cycle"]
    assert len(cycles) == 2
    from repro.core.template import estimate_walk_cost as ec
    costs = [ec(t2, c, freq2) for c in cycles]
    assert costs == sorted(costs), "cheaper cycle constraint must come first"


def test_multiplicity_requirements():
    t = Template([0, 1, 1, 1], [(0, 1), (0, 2), (0, 3)])
    req = t.multiplicity_requirements()
    assert req[0] == {1: 3}


def test_template_validation():
    with pytest.raises(ValueError):
        Template([0, 1], [(0, 0)])  # self edge
    with pytest.raises(ValueError):
        Template([0, 1, 2], [(0, 1)])  # disconnected
    with pytest.raises(ValueError):
        Template(list(range(65)), [(i, i + 1) for i in range(64)])  # too large


def test_edge_deletion_variants_connected():
    t = Template([0, 1, 2], [(0, 1), (1, 2), (2, 0)])
    vs = t.edge_deletion_variants(1)
    assert len(vs) == 3
    for v in vs:
        assert v.m0 == 2


# -------------------------------------------------- symmetry (automorphisms)
def test_automorphism_group_known_templates():
    """The orbit-refined backtracking search equals the brute-force
    self-enumeration on templates with known groups."""
    from repro.core.enumerate import count_automorphisms
    from repro.core.oracle import enumerate_matches_bruteforce

    cases = [
        (Template([0, 0, 0], [(0, 1), (1, 2), (2, 0)]), 6),
        (Template([3, 4, 3, 4], [(0, 1), (1, 2), (2, 3), (3, 0)]), 4),
        (Template([6, 7, 8, 7], [(0, 1), (1, 2), (2, 3), (3, 0)]), 2),
        (Template([3, 4, 5, 3], [(0, 1), (1, 2), (2, 3)]), 1),
        (Template([0, 0, 0, 0],
                  [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]), 24),
    ]
    for tmpl, expect in cases:
        assert tmpl.automorphism_count() == expect
        assert count_automorphisms(tmpl) == expect
        # matches the old brute-force definition: self-monomorphism count
        assert len(enumerate_matches_bruteforce(tmpl.to_graph(), tmpl)) == expect
        # every member really is a label-preserving automorphism
        A = tmpl.adjacency_matrix()
        for g in tmpl.automorphisms():
            assert sorted(g) == list(range(tmpl.n0))
            assert all(tmpl.labels[g[q]] == tmpl.labels[q]
                       for q in range(tmpl.n0))
            assert all(A[g[a], g[b]] for a, b in tmpl.edge_set)


def test_symmetry_restrictions_orbit_chain():
    """Restriction generation follows the orbit/stabilizer chain: the product
    of orbit sizes along the chain equals |Aut|, and the restrictions select
    exactly one representative per automorphism class of any embedding."""
    import itertools

    for tmpl in [
        Template([0, 0, 0], [(0, 1), (1, 2), (2, 0)]),
        Template([3, 4, 3, 4], [(0, 1), (1, 2), (2, 3), (3, 0)]),
        Template([0, 0, 0, 0],
                 [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]),
    ]:
        restr = tmpl.symmetry_restrictions()
        auts = tmpl.automorphisms()
        # apply the group to an arbitrary injective assignment: exactly one
        # image satisfies every restriction
        phi = list(range(10, 10 + tmpl.n0))
        ok = 0
        for g in auts:
            img = [phi[g[q]] for q in range(tmpl.n0)]
            if all(img[a] < img[b] for a, b in restr):
                ok += 1
        assert ok == 1, (tmpl.labels.tolist(), restr)


def test_symmetry_restrictions_asymmetric_template_empty():
    tmpl = Template([3, 4, 5, 3], [(0, 1), (1, 2), (2, 3)])
    assert tmpl.symmetry_restrictions() == ()
    assert tmpl.automorphism_count() == 1

"""Distributed engine correctness: the shard_map math (vmap-simulated — the
collective is a transpose) must equal the single-device engine bit-for-bit for
every shard count, and the real shard_map path must run on a multi-device
(subprocess-forced) host platform.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.graph import rmat_graph, partition_graph
from repro.graph.structs import DeviceGraph
from repro.core import Template, init_state
from repro.core.lcc import TemplateDev, lcc_fixpoint
from repro.core.distributed import (
    make_vmap_engine, init_distributed_state, TemplateMasks,
)
from repro.core.state import unpack_bits


def _find_triangle_labels(g):
    off, nbr = g.csr()
    for u in range(g.n):
        nu = set(nbr[off[u]:off[u + 1]].tolist())
        for v in nbr[off[u]:off[u + 1]]:
            for w in nbr[off[v]:off[v + 1]]:
                if w != u and int(w) in nu:
                    return [int(g.labels[x]) for x in (u, int(v), int(w))]
    return None


@pytest.mark.parametrize("P", [2, 4, 8])
def test_vmap_engine_matches_single_device(P):
    g = rmat_graph(9, edge_factor=6, seed=5)
    labels = _find_triangle_labels(g)
    assert labels is not None
    tmpl = Template(labels=labels, edges=[(0, 1), (1, 2), (2, 0)])
    dg = DeviceGraph.from_host(g)
    tdev = TemplateDev(tmpl)
    st = lcc_fixpoint(dg, tdev, init_state(dg, tmpl))

    part = partition_graph(g, P)
    eng = make_vmap_engine(part, TemplateMasks(tdev))
    om0, ea0 = init_distributed_state(part, tmpl)
    om, ea, it = eng(om0, ea0)
    bits = np.asarray(unpack_bits(om[:, :-1], tmpl.n0))
    omega_dist = np.zeros((g.n, tmpl.n0), bool)
    ids = np.arange(g.n)
    omega_dist[ids] = bits[ids // part.n_local, ids % part.n_local]
    assert np.array_equal(omega_dist, np.asarray(st.omega))
    assert int(np.asarray(ea).sum()) == int(np.asarray(st.edge_active).sum())
    assert int(np.asarray(st.omega).sum()) > 0  # nontrivial


def test_multiplicity_template_distributed():
    g = rmat_graph(8, edge_factor=6, seed=2)
    # star template with repeated-label leaves exercises the counts path
    lbl = int(np.bincount(g.labels).argmax())
    tmpl = Template([lbl, lbl, lbl], [(0, 1), (0, 2)])
    dg = DeviceGraph.from_host(g)
    tdev = TemplateDev(tmpl)
    assert tdev.needs_counts
    st = lcc_fixpoint(dg, tdev, init_state(dg, tmpl))
    part = partition_graph(g, 4)
    eng = make_vmap_engine(part, TemplateMasks(tdev))
    om0, ea0 = init_distributed_state(part, tmpl)
    om, ea, _ = eng(om0, ea0)
    bits = np.asarray(unpack_bits(om[:, :-1], tmpl.n0))
    ids = np.arange(g.n)
    omega_dist = bits[ids // part.n_local, ids % part.n_local]
    assert np.array_equal(omega_dist, np.asarray(st.omega))


SHARD_MAP_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from repro.graph import rmat_graph, partition_graph
    from repro.graph.structs import DeviceGraph
    from repro.core import Template, init_state
    from repro.core.lcc import TemplateDev, lcc_fixpoint
    from repro.core.distributed import (
        make_shard_map_engine, init_distributed_state, TemplateMasks,
    )
    from repro.core.state import unpack_bits
    from repro.kernels.compat import make_mesh

    g = rmat_graph(9, edge_factor=6, seed=5)
    tmpl = Template([8, 7, 7], [(0, 1), (1, 2), (2, 0)])
    dg = DeviceGraph.from_host(g)
    tdev = TemplateDev(tmpl)
    st = lcc_fixpoint(dg, tdev, init_state(dg, tmpl))

    # axis types resolved by the compat shim: "auto" maps onto the mesh
    # axis-type enum where it exists, and is dropped on JAX lines (0.4.x)
    # that predate typed mesh axes.
    mesh = make_mesh((8,), ("shards",), axis_types=("auto",))
    part = partition_graph(g, 8)
    eng = make_shard_map_engine(mesh, ("shards",), part.device_arrays(),
                                TemplateMasks(tdev))
    om0, ea0 = init_distributed_state(part, tmpl)
    om, ea, it = eng(om0, ea0, part.device_arrays())
    bits = np.asarray(unpack_bits(om[:, :-1], tmpl.n0))
    ids = np.arange(g.n)
    omega_dist = bits[ids // part.n_local, ids % part.n_local]
    assert np.array_equal(omega_dist, np.asarray(st.omega)), "omega mismatch"
    assert int(np.asarray(ea).sum()) == int(np.asarray(st.edge_active).sum())
    print("SHARD_MAP_OK", int(it))
    """
)


def test_shard_map_engine_on_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    r = subprocess.run(
        [sys.executable, "-c", SHARD_MAP_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "SHARD_MAP_OK" in r.stdout

"""Template-batched execution (core/batch.py): bit-parity, stragglers,
deadlines.

The contract under test: stacking B same-bucket templates along the lane
axis and running them through shared dispatches produces, for every lane,
final omega / edge masks / match counts BIT-IDENTICAL to running that
template alone through `prune` on the same backend — including lanes that
converge in one wave round while a batchmate needs several (masked, not
exited), and lanes cancelled by a deadline (masked, not a batch abort).
"""
import numpy as np
import pytest

from repro.graph import rmat_graph
from repro.core import (Template, prune, prune_batch, count_matches,
                        BatchedPruneResult)
from repro.core.batch import STATUS_DEADLINE_MISSED, STATUS_OK


def _graph():
    return rmat_graph(8, edge_factor=6, seed=3)


# same pow2 shape bucket (n0 in {3, 4} -> 4); mixed cyclic / path / counted
def _variants():
    return [
        Template([5, 4, 4, 3], [(0, 1), (1, 2), (2, 3), (3, 0)]),  # square
        Template([5, 4, 3, 2], [(0, 1), (1, 2), (2, 3)]),          # path
        Template([4, 3, 3], [(0, 1), (1, 2), (2, 0)]),             # triangle
        Template([6, 5, 4, 3], [(0, 1), (1, 2), (2, 3), (3, 0)]),
        Template([3, 2, 2, 2], [(0, 1), (1, 2), (2, 3)]),
        Template([5, 5, 4], [(0, 1), (1, 2), (2, 0)]),    # repeated label
        Template([4, 4, 3, 3], [(0, 1), (1, 2), (2, 3), (3, 0)]),
        Template([6, 4, 2], [(0, 1), (1, 2), (2, 0)]),
    ]


def _assert_lane_parity(bres, templates, g, *, partition=None, **kw):
    assert isinstance(bres, BatchedPruneResult)
    assert bres.n_lanes == len(templates)
    for i, t in enumerate(templates):
        seq = prune(g, t, partition=partition, **kw)
        bl = bres.results[i]
        np.testing.assert_array_equal(
            np.asarray(bl.state.omega), np.asarray(seq.state.omega),
            err_msg=f"lane {i}: omega differs from sequential prune")
        np.testing.assert_array_equal(
            np.asarray(bl.state.edge_active),
            np.asarray(seq.state.edge_active),
            err_msg=f"lane {i}: edge mask differs from sequential prune")
        cb = count_matches(bl.dg, bl.state, t)
        cs = count_matches(seq.dg, seq.state, t)
        assert cb.n_embeddings == cs.n_embeddings, f"lane {i}: match counts"


@pytest.mark.parametrize("B", [1, 2, 8])
def test_batched_parity_local(B):
    """Batched B queries == B sequential prunes, bit for bit (P=1 — the
    batched analogue of the local backend)."""
    g = _graph()
    templates = _variants()[:B]
    bres = prune_batch(g, templates)
    _assert_lane_parity(bres, templates, g, partition=None)
    assert bres.stats["batched"]["B"] == B
    assert bres.stats["batched"]["bucket"].startswith(
        f"b{1 << (B - 1).bit_length() if B > 1 else 1}x")


def test_batched_parity_sharded():
    """Same contract composed with the shard axis (sim P=4)."""
    g = _graph()
    templates = [_variants()[0], _variants()[1], _variants()[2]]
    bres = prune_batch(g, templates, partition=4)
    _assert_lane_parity(bres, templates, g, partition=4)
    assert bres.stats["batched"]["P"] == 4


def test_straggler_masking():
    """One lane's wave sources run dry in round 1 while a batchmate needs
    several rounds: the exhausted lane rides pad (-1) waves — pinned by the
    lockstep-padded counter — and parity still holds for both."""
    g = rmat_graph(9, edge_factor=8, seed=5)
    fast = Template([8, 3, 8], [(0, 1), (1, 2), (2, 0)])  # 1-vertex head
    slow = Template([6, 5, 6], [(0, 1), (1, 2), (2, 0)])  # wide head
    templates = [fast, slow]
    bres = prune_batch(g, templates, wave=32, guarantee_precision=False)
    assert bres.stats.get("nlcc_lockstep_padded", 0) > 0, (
        "expected at least one job to exhaust early and ride pad waves")
    _assert_lane_parity(bres, templates, g, partition=None,
                        wave=32, guarantee_precision=False)


def test_deadline_cancellation_masks_lane():
    """A deadline-missed lane is zeroed at a phase boundary and masked for
    the rest of the batch; surviving lanes stay bit-identical."""
    g = _graph()
    templates = _variants()[:3]
    bres = prune_batch(g, templates,
                       deadlines=[None, 50.0, None],
                       clock=lambda: 100.0)
    assert bres.status == [STATUS_OK, STATUS_DEADLINE_MISSED, STATUS_OK]
    dead = bres.results[1]
    assert not np.asarray(dead.state.omega).any()
    assert not np.asarray(dead.state.edge_active).any()
    assert dead.stats["lane_status"] == STATUS_DEADLINE_MISSED
    assert bres.stats["deadline_cancelled"] == 1
    for i in (0, 2):
        seq = prune(g, templates[i])
        np.testing.assert_array_equal(
            np.asarray(bres.results[i].state.omega),
            np.asarray(seq.state.omega))
        np.testing.assert_array_equal(
            np.asarray(bres.results[i].state.edge_active),
            np.asarray(seq.state.edge_active))


def test_deadline_midrun_cancellation():
    """A deadline crossed mid-run cancels at the NEXT phase boundary (ticking
    clock), never aborting the batch."""
    g = _graph()
    templates = _variants()[:2]
    tick = {"t": 0.0}

    def clock():
        tick["t"] += 1.0
        return tick["t"]

    bres = prune_batch(g, templates, deadlines=[1.5, None], clock=clock)
    assert bres.status[0] == STATUS_DEADLINE_MISSED
    assert bres.status[1] == STATUS_OK
    assert not np.asarray(bres.results[0].state.omega).any()
    seq = prune(g, templates[1])
    np.testing.assert_array_equal(
        np.asarray(bres.results[1].state.omega), np.asarray(seq.state.omega))


def test_mixed_bucket_batch_rejected():
    g = _graph()
    t_small = Template([5, 4], [(0, 1)])                      # bucket 2
    t_big = Template([5, 4, 3, 2], [(0, 1), (1, 2), (2, 3)])  # bucket 4
    with pytest.raises(ValueError, match="bucket"):
        prune_batch(g, [t_small, t_big])


def test_batched_route_resolution_uses_batch_bucket():
    """The batched executor resolves prune.nlcc under a b<B>-prefixed bucket
    so batched routes tune separately from single-query ones."""
    g = _graph()
    templates = _variants()[:2]
    bres = prune_batch(g, templates)
    bucket = bres.stats["batched"]["bucket"]
    assert bucket.startswith("b2x")
    assert bres.stats["dispatch_routes"]["prune.nlcc"] != "none"


# --------------------------------------------- shared candidacy planes
def test_shared_candidacy_plane_prefix_parity():
    """Lane init builds ONE candidacy plane per DISTINCT label and assembles
    every lane's omega columns from those shared planes — with heavy label
    overlap across the batch the plane count collapses well below the column
    count, and the assembled init must stay bit-identical to the per-lane
    construction (pinned through full-prune lane parity)."""
    g = _graph()
    # 4 lanes x 4 columns = 16 columns over only 4 distinct labels
    templates = [
        Template([5, 4, 4, 3], [(0, 1), (1, 2), (2, 3), (3, 0)]),
        Template([4, 5, 3, 4], [(0, 1), (1, 2), (2, 3), (3, 0)]),
        Template([3, 4, 5, 2], [(0, 1), (1, 2), (2, 3)]),
        Template([2, 3, 4, 5], [(0, 1), (1, 2), (2, 3)]),
    ]
    bres = prune_batch(g, templates)
    planes = bres.stats["shared_candidacy_planes"]
    assert planes["distinct"] == 4
    assert planes["lane_columns"] == 16
    _assert_lane_parity(bres, templates, g)


def test_shared_candidacy_planes_sharded():
    g = _graph()
    templates = _variants()[:4]
    bres = prune_batch(g, templates, partition=4)
    planes = bres.stats["shared_candidacy_planes"]
    assert planes["distinct"] <= planes["lane_columns"]
    _assert_lane_parity(bres, templates, g, partition=4)

"""Dispatch-policy contract (CPU-runnable):

  - cache round-trip: persist -> reload -> identical table and identical
    `resolve_mode` decisions, including the lazy load from the persisted
    cache path,
  - untuned fallback: with no policy, `resolve_mode` behaves exactly like
    the pre-policy registry (eligibility -> backend -> force_pallas),
  - policy safety: ineligible shapes stay "ref", a tuned mode the backend
    cannot run is ignored, force_pallas bypasses the policy,
  - routing: packed/unpacked `prune` routing follows an injected policy and
    yields identical pruning results either way,
  - tune(): measures every runnable candidate, picks the argmin, persists,
  - roll-up: BENCH_pipeline.json schema is stable (validate_rollup).
"""
import json

import numpy as np
import pytest
import jax.numpy as jnp

from benchmarks import common as bench_common
from repro.core import Template, prune
from repro.core.lcc import LCC_ROUTE
from repro.core.nlcc import NLCC_ROUTE
from repro.graph import generators as gen
from repro.graph.blocked import build_blocked_structure
from repro.graph.structs import DeviceGraph
from repro.kernels import registry


def _graph_args(scale=6, w=2, bn=64):
    g = gen.rmat_graph(scale, edge_factor=4, seed=scale)
    dg = DeviceGraph.from_host(g)
    r = np.random.default_rng(scale)
    vals = jnp.asarray(r.integers(0, 2**32, size=(g.n, w), dtype=np.uint32))
    active = jnp.asarray(r.random(dg.m) < 0.7)
    bs = build_blocked_structure(np.asarray(dg.src), np.asarray(dg.dst), g.n, bn=bn)
    return (vals, dg.src, dg.dst, g.n, active, bs)


def _bitset_bucket(args):
    return registry.get("bitset_spmm").bucket(*args)


def _prune_setup():
    g = gen.erdos_renyi_graph(100, 5.0, seed=3, n_labels=3)
    tmpl = Template([0, 1, 2], [(0, 1), (1, 2), (2, 0)])
    dg = DeviceGraph.from_host(g)
    bs = build_blocked_structure(np.asarray(dg.src), np.asarray(dg.dst),
                                 g.n, bn=64)
    return g, tmpl, bs


# ------------------------------------------------------------- round-trip
def test_policy_cache_roundtrip(tmp_path):
    args = _graph_args()
    bucket = _bitset_bucket(args)
    pol = registry.DispatchPolicy()
    pol.set_mode("bitset_spmm", "cpu", bucket, registry.MODE_INTERPRET,
                 {"interpret": 0.001, "ref": 0.002})
    pol.set_route(LCC_ROUTE, "cpu", registry.BUCKET_ANY,
                  registry.ROUTE_UNPACKED, {"packed": 0.2, "unpacked": 0.1})
    path = pol.save(str(tmp_path / "pol.json"))

    reloaded = registry.DispatchPolicy.load(path)
    assert reloaded.to_json() == pol.to_json()

    registry.set_policy(reloaded)
    assert registry.resolve_mode(
        "bitset_spmm", *args, backend="cpu") == registry.MODE_INTERPRET
    assert registry.resolve_route(
        LCC_ROUTE, (1, 2), default=registry.ROUTE_PACKED,
        backend="cpu") == registry.ROUTE_UNPACKED


def test_resolve_mode_lazily_loads_persisted_cache(tmp_path, monkeypatch):
    """The acceptance contract: a persisted cache at policy_path() is honored
    without any explicit set_policy call."""
    args = _graph_args()
    path = str(tmp_path / "cache.json")
    pol = registry.DispatchPolicy()
    pol.set_mode("bitset_spmm", "cpu", _bitset_bucket(args),
                 registry.MODE_INTERPRET)
    pol.save(path)

    monkeypatch.setenv("REPRO_DISPATCH_POLICY", path)
    registry.clear_policy()
    assert registry.resolve_mode(
        "bitset_spmm", *args, backend="cpu") == registry.MODE_INTERPRET
    # ...and the same call with no cache file falls back to "ref"
    monkeypatch.setenv("REPRO_DISPATCH_POLICY", str(tmp_path / "absent.json"))
    registry.clear_policy()
    assert registry.resolve_mode(
        "bitset_spmm", *args, backend="cpu") == registry.MODE_REF


def test_unreadable_cache_warns_and_falls_back(tmp_path, monkeypatch):
    path = tmp_path / "broken.json"
    path.write_text('{"schema_version": 999}')
    monkeypatch.setenv("REPRO_DISPATCH_POLICY", str(path))
    registry.clear_policy()
    args = _graph_args()
    with pytest.warns(RuntimeWarning, match="unreadable dispatch policy"):
        mode = registry.resolve_mode("bitset_spmm", *args, backend="cpu")
    assert mode == registry.MODE_REF


def test_unopenable_cache_path_warns_and_falls_back(tmp_path, monkeypatch):
    # exists() is True but open() raises OSError (here: a directory; in the
    # field: a root-owned cache in CI) — dispatch must warn and run untuned
    monkeypatch.setenv("REPRO_DISPATCH_POLICY", str(tmp_path))
    registry.clear_policy()
    args = _graph_args()
    with pytest.warns(RuntimeWarning, match="unreadable dispatch policy"):
        mode = registry.resolve_mode("bitset_spmm", *args, backend="cpu")
    assert mode == registry.MODE_REF


def test_unknown_route_value_falls_back_to_default_everywhere():
    # a hand-edited cache with a typo'd route value must not split LCC and
    # NLCC onto different interpretations — both fall back to their defaults
    g, tmpl, bs = _prune_setup()
    pol = registry.DispatchPolicy()
    pol.set_route(LCC_ROUTE, "cpu", registry.BUCKET_ANY, "Packed-Typo")
    pol.set_route(NLCC_ROUTE, "cpu", registry.BUCKET_ANY, "Packed-Typo")
    registry.set_policy(pol)
    res = prune(g, tmpl, blocked=bs)
    assert res.stats["dispatch_routes"] == {
        LCC_ROUTE: registry.ROUTE_PACKED,      # untuned default with blocked
        NLCC_ROUTE: registry.ROUTE_UNPACKED,   # untuned default off-TPU
    }


# -------------------------------------------------------- untuned fallback
def test_untuned_fallback_matches_legacy_registry_behavior():
    args = _graph_args()
    assert registry.get_policy() is None  # conftest isolates the cache path
    assert registry.resolve_mode(
        "bitset_spmm", *args, backend="cpu") == registry.MODE_REF
    assert registry.resolve_mode(
        "bitset_spmm", *args, backend="cpu",
        force_pallas=True) == registry.MODE_INTERPRET
    assert registry.resolve_mode(
        "bitset_spmm", *args, backend="tpu") == registry.MODE_PALLAS
    ineligible = args[:5] + (None,)
    assert registry.resolve_mode(
        "bitset_spmm", *ineligible, backend="tpu") == registry.MODE_REF
    assert registry.resolve_route(
        LCC_ROUTE, (4, 4), default=registry.ROUTE_PACKED) == registry.ROUTE_PACKED


def test_policy_never_overrides_eligibility():
    args = _graph_args()
    pol = registry.DispatchPolicy()
    pol.set_mode("bitset_spmm", "cpu", registry.BUCKET_ANY,
                 registry.MODE_INTERPRET)
    registry.set_policy(pol)
    ineligible = args[:5] + (None,)  # no blocked structure
    assert registry.resolve_mode(
        "bitset_spmm", *ineligible, backend="cpu") == registry.MODE_REF


def test_unrunnable_tuned_mode_falls_back():
    # a policy tuned on TPU says "pallas"; on CPU that cannot execute, so the
    # untuned fallback ("ref") wins rather than a guaranteed kernel failure
    args = _graph_args()
    pol = registry.DispatchPolicy()
    pol.set_mode("bitset_spmm", "cpu", registry.BUCKET_ANY, registry.MODE_PALLAS)
    registry.set_policy(pol)
    assert registry.resolve_mode(
        "bitset_spmm", *args, backend="cpu") == registry.MODE_REF


def test_force_pallas_bypasses_policy():
    args = _graph_args()
    pol = registry.DispatchPolicy()
    pol.set_mode("bitset_spmm", "cpu", registry.BUCKET_ANY, registry.MODE_REF)
    registry.set_policy(pol)
    assert registry.resolve_mode(
        "bitset_spmm", *args, backend="cpu",
        force_pallas=True) == registry.MODE_INTERPRET


def test_wildcard_bucket_matches_every_shape():
    pol = registry.DispatchPolicy()
    pol.set_mode("bitset_spmm", "cpu", registry.BUCKET_ANY,
                 registry.MODE_INTERPRET)
    registry.set_policy(pol)
    for scale in (5, 6, 7):
        args = _graph_args(scale=scale)
        assert registry.resolve_mode(
            "bitset_spmm", *args, backend="cpu") == registry.MODE_INTERPRET
    # exact bucket beats the wildcard
    args = _graph_args()
    pol.set_mode("bitset_spmm", "cpu", _bitset_bucket(args), registry.MODE_REF)
    assert registry.resolve_mode(
        "bitset_spmm", *args, backend="cpu") == registry.MODE_REF


# ------------------------------------------------------------ prune routing
def test_prune_lcc_routing_follows_injected_policy():
    g, tmpl, bs = _prune_setup()

    registry.set_policy(None)
    base = prune(g, tmpl, blocked=bs)
    # untuned default: blocked was passed, so LCC routes packed
    assert base.stats["dispatch_routes"][LCC_ROUTE] == registry.ROUTE_PACKED
    assert base.stats.get("lcc_packed_calls", 0) > 0

    pol = registry.DispatchPolicy()
    pol.set_route(LCC_ROUTE, "cpu", registry.BUCKET_ANY,
                  registry.ROUTE_UNPACKED)
    registry.set_policy(pol)
    routed = prune(g, tmpl, blocked=bs)
    assert routed.stats["dispatch_routes"][LCC_ROUTE] == registry.ROUTE_UNPACKED
    assert "lcc_packed_calls" not in routed.stats
    assert routed.stats.get("lcc_routed_unpacked", 0) > 0

    # routing is a performance choice, never a semantic one
    np.testing.assert_array_equal(base.omega, routed.omega)
    np.testing.assert_array_equal(base.edge_mask, routed.edge_mask)


def test_prune_nlcc_routing_follows_injected_policy():
    g, tmpl, bs = _prune_setup()

    registry.set_policy(None)
    base = prune(g, tmpl, blocked=bs)
    # untuned default off-TPU: boolean-plane waves
    assert base.stats["dispatch_routes"][NLCC_ROUTE] == registry.ROUTE_UNPACKED

    pol = registry.DispatchPolicy()
    pol.set_route(NLCC_ROUTE, "cpu", registry.BUCKET_ANY,
                  registry.ROUTE_PACKED)
    registry.set_policy(pol)
    routed = prune(g, tmpl, blocked=bs)
    assert routed.stats["dispatch_routes"][NLCC_ROUTE] == registry.ROUTE_PACKED
    packed_waves = sum(
        p.extra.get("nlcc_packed_waves", 0) for p in routed.phases)
    plane_waves = sum(
        p.extra.get("nlcc_plane_waves", 0) for p in routed.phases)
    assert packed_waves > 0 and plane_waves == 0

    np.testing.assert_array_equal(base.omega, routed.omega)
    np.testing.assert_array_equal(base.edge_mask, routed.edge_mask)


def test_dispatch_routes_report_the_route_actually_taken():
    # capability gates (collect_stats forces message counting / per-iteration
    # python loops) beat a packed-routed policy, and the stats must say so
    g, tmpl, bs = _prune_setup()
    pol = registry.DispatchPolicy()
    pol.set_route(LCC_ROUTE, "cpu", registry.BUCKET_ANY, registry.ROUTE_PACKED)
    pol.set_route(NLCC_ROUTE, "cpu", registry.BUCKET_ANY, registry.ROUTE_PACKED)
    registry.set_policy(pol)

    gated = prune(g, tmpl, blocked=bs, collect_stats=True)
    assert gated.stats["dispatch_routes"] == {
        LCC_ROUTE: registry.ROUTE_UNPACKED,
        NLCC_ROUTE: registry.ROUTE_UNPACKED,
    }
    assert "lcc_packed_calls" not in gated.stats
    assert not any(p.extra.get("nlcc_packed_waves") for p in gated.phases)

    ungated = prune(g, tmpl, blocked=bs)
    assert ungated.stats["dispatch_routes"] == {
        LCC_ROUTE: registry.ROUTE_PACKED,
        NLCC_ROUTE: registry.ROUTE_PACKED,
    }
    assert ungated.stats.get("lcc_packed_calls", 0) > 0

    # the Fig-6a ablation path never runs the packed sweep
    ablated = prune(g, tmpl, blocked=bs, edge_elimination=False)
    assert ablated.stats["dispatch_routes"][LCC_ROUTE] == registry.ROUTE_UNPACKED
    assert "lcc_packed_calls" not in ablated.stats


# ------------------------------------------------------------------- tune
def test_tune_measures_candidates_and_persists(tmp_path):
    args = _graph_args()
    path = str(tmp_path / "tuned.json")
    calls = {"a": 0, "b": 0}

    def cand_a():
        calls["a"] += 1
        return jnp.zeros(4)

    def cand_b():
        calls["b"] += 1
        return jnp.zeros(4)

    pol = registry.tune(
        cases=[("bitset_spmm", args, {})],
        routes=[("test.route", registry.BUCKET_ANY,
                 {"a": cand_a, "b": cand_b})],
        repeat=2, path=path,
    )
    bucket = _bitset_bucket(args)
    entry = pol.modes[f"bitset_spmm|cpu|{registry._bucket_key(bucket)}"]
    # on CPU both interpret and ref are runnable candidates; compiled pallas
    # is not (TPU only)
    assert set(entry.measured_s) == {registry.MODE_INTERPRET, registry.MODE_REF}
    assert entry.choice == min(entry.measured_s, key=entry.measured_s.get)

    rentry = pol.routes[f"test.route|cpu|{registry.BUCKET_ANY}"]
    assert rentry.choice == min(rentry.measured_s, key=rentry.measured_s.get)
    assert calls["a"] >= 3 and calls["b"] >= 3  # warmup + repeats

    # persisted and installed as the active policy
    assert registry.get_policy() is pol
    assert registry.DispatchPolicy.load(path).to_json() == pol.to_json()


# ------------------------------------------- batched buckets / cache compat
def test_batch_bucket_rendering_and_separate_tuning():
    base = registry.shard_bucket(4, 512, 1024)
    b8 = registry.batch_bucket(8, base)
    assert registry.bucket_key(b8) == "b8xp4x512x1024"
    assert registry.bucket_key(registry.batch_bucket(6, (2048, 1024))) == (
        "b8x2048x1024")  # batch size pow2-rounds like any other dim
    # a batched decision never shadows (or is shadowed by) the unbatched one
    pol = registry.DispatchPolicy()
    pol.set_route(NLCC_ROUTE, "cpu", base, registry.ROUTE_UNPACKED)
    pol.set_route(NLCC_ROUTE, "cpu", b8, registry.ROUTE_FUSED)
    assert pol.route_for(NLCC_ROUTE, "cpu", base) == registry.ROUTE_UNPACKED
    assert pol.route_for(NLCC_ROUTE, "cpu", b8) == registry.ROUTE_FUSED
    # B=8 with no batched entry falls to the wildcard, NOT the unbatched key
    pol2 = registry.DispatchPolicy()
    pol2.set_route(NLCC_ROUTE, "cpu", base, registry.ROUTE_UNPACKED)
    pol2.set_route(NLCC_ROUTE, "cpu", registry.BUCKET_ANY,
                   registry.ROUTE_PACKED)
    assert pol2.route_for(NLCC_ROUTE, "cpu", b8) == registry.ROUTE_PACKED


def test_b1_lookup_resolves_pre_batching_cache_entries():
    """Forward-compat: a cache tuned before the batch axis existed has no
    ``b<B>`` keys; batch-size-1 lookups must resolve its unbatched entries
    (exact bucket, then wildcard) — an old cache keeps working untouched."""
    base = registry.shard_bucket(4, 512, 1024)
    pol = registry.DispatchPolicy()
    pol.set_route(NLCC_ROUTE, "cpu", base, registry.ROUTE_FUSED,
                  {"fused": 0.01})
    b1 = registry.batch_bucket(1, base)
    assert registry.bucket_key(b1) == "b1xp4x512x1024"
    assert pol.route_for(NLCC_ROUTE, "cpu", b1) == registry.ROUTE_FUSED
    entry = pol.route_entry_for(NLCC_ROUTE, "cpu", b1)
    assert entry is not None and entry.measured_s == {"fused": 0.01}
    # an explicit b1 entry (a re-tune on the batched path) wins over compat
    pol.set_route(NLCC_ROUTE, "cpu", b1, registry.ROUTE_PACKED)
    assert pol.route_for(NLCC_ROUTE, "cpu", b1) == registry.ROUTE_PACKED
    # b1 over the wildcard bucket reaches the plain wildcard entry
    pol2 = registry.DispatchPolicy()
    pol2.set_mode("bitset_spmm", "cpu", registry.BUCKET_ANY,
                  registry.MODE_INTERPRET)
    assert pol2.mode_for(
        "bitset_spmm", "cpu",
        registry.batch_bucket(1, registry.BUCKET_ANY)) == (
            registry.MODE_INTERPRET)


def test_tune_extends_existing_cache_instead_of_invalidating(tmp_path):
    """registry.tune() must not throw away decisions it didn't re-measure:
    with no explicit policy it loads the cache at the target path and
    extends it — the pre-existing (e.g. hand-tuned or unbatched) entries
    survive the re-tune byte-for-byte."""
    path = str(tmp_path / "tuned.json")
    old = registry.DispatchPolicy()
    old.set_route(LCC_ROUTE, "cpu", (2048, 32768), registry.ROUTE_PACKED,
                  {"packed": 0.05, "unpacked": 0.07})
    old.set_mode("bitset_spmm", "cpu", registry.BUCKET_ANY,
                 registry.MODE_INTERPRET, {"interpret": 0.001})
    old.save(path)

    pol = registry.tune(
        routes=[("test.batched", registry.batch_bucket(8, (2048, 1024)),
                 {"a": lambda: None, "b": lambda: None})],
        repeat=1, path=path,
    )
    key = f"{LCC_ROUTE}|cpu|2048x32768"
    assert pol.routes[key].choice == registry.ROUTE_PACKED
    assert pol.routes[key].measured_s == {"packed": 0.05, "unpacked": 0.07}
    assert pol.modes["bitset_spmm|cpu|*"].choice == registry.MODE_INTERPRET
    assert "test.batched|cpu|b8x2048x1024" in pol.routes
    # and the merged table is what got persisted
    reloaded = registry.DispatchPolicy.load(path)
    assert reloaded.to_json() == pol.to_json()


def test_tune_replaces_unreadable_cache(tmp_path):
    path = tmp_path / "stale.json"
    path.write_text('{"schema_version": 999}')
    pol = registry.tune(
        routes=[("test.route", registry.BUCKET_ANY, {"a": lambda: None})],
        repeat=1, path=str(path),
    )
    assert list(pol.routes) == [f"test.route|cpu|{registry.BUCKET_ANY}"]
    registry.DispatchPolicy.load(str(path))  # rewritten, readable again


# ----------------------------------------------------------------- roll-up
def _minimal_rollup_suites():
    return {"dispatch_policy": {"seconds": 1.5, "ok": True,
                                "description": "autotune"}}


def test_rollup_schema_roundtrip(tmp_path):
    pol = registry.DispatchPolicy()
    pol.set_route(LCC_ROUTE, "cpu", registry.BUCKET_ANY, registry.ROUTE_PACKED,
                  {"packed": 0.1, "unpacked": 0.2})
    registry.set_policy(pol)
    path = bench_common.write_rollup(
        _minimal_rollup_suites(), "small",
        graph={"n": 2048, "m": 25316},
        phases=[{"phase": "LCC", "seconds": 0.5}],
        sharded_prune={"P": 4, "backend": "sim", "seconds": 7.4,
                       "matches_local": True},
        enumeration={"template": "T4-square-rare", "count_seconds": 0.1,
                     "materialize_seconds": 0.3, "n_embeddings": 12,
                     "automorphisms": 2, "count_matches_materialize": True},
        distributed_join={"P": 4, "replicated_seconds": 0.02,
                          "rowsharded_seconds": 0.006, "counts_match": True,
                          "peak_rows_replicated": 37,
                          "peak_shard_rows_rowsharded": 21},
        load_balance={"P": 64, "shards_holding_half_before": 9,
                      "shards_holding_half_after": 27,
                      "max_over_mean_before": 4.1,
                      "max_over_mean_after": 1.2,
                      "reshuffle_evens_load": True},
        resilience={"P": 4, "restart_P": 2, "phases_checkpointed": 3,
                    "checkpoint_overhead_seconds": 0.02,
                    "recovery_seconds": 0.4, "scratch_seconds": 2.1,
                    "parity_ok": True,
                    "recovered_faster_than_scratch": True},
        path=str(tmp_path / "BENCH_pipeline.json"),
    )
    payload = json.load(open(path))
    bench_common.validate_rollup(payload)  # schema-stable after JSON round-trip
    assert payload["schema_version"] == bench_common.ROLLUP_SCHEMA_VERSION
    assert payload["scale"] == "small"
    assert payload["graph"] == {"n": 2048, "m": 25316}
    assert payload["suites"]["dispatch_policy"]["ok"] is True
    assert payload["sharded_prune"]["matches_local"] is True
    assert payload["enumeration"]["count_matches_materialize"] is True
    assert payload["distributed_join"]["counts_match"] is True
    assert payload["load_balance"]["reshuffle_evens_load"] is True
    assert payload["resilience"]["parity_ok"] is True
    assert payload["resilience"]["recovered_faster_than_scratch"] is True
    route_key = f"{LCC_ROUTE}|cpu|{registry.BUCKET_ANY}"
    assert payload["policy"]["routes"][route_key]["choice"] == registry.ROUTE_PACKED


@pytest.mark.parametrize("mutate,match", [
    (lambda p: p.pop("suites"), "missing key 'suites'"),
    (lambda p: p.pop("phases"), "missing key 'phases'"),
    (lambda p: p.update(schema_version=99), "schema_version"),
    (lambda p: p["suites"]["dispatch_policy"].pop("seconds"),
     "missing key 'seconds'"),
    (lambda p: p["phases"].append({"seconds": 1.0}), "missing key 'phase'"),
    (lambda p: p.update(sharded_prune={"P": 4, "seconds": 1.0}),
     "missing key 'matches_local'"),
    (lambda p: p.update(sharded_prune=[1]), "sharded_prune must be a dict"),
    (lambda p: p.update(enumeration={"count_seconds": 0.1}),
     "missing key 'materialize_seconds'"),
    (lambda p: p.update(enumeration=[1]), "enumeration must be a dict"),
    (lambda p: p.update(distributed_join={"P": 4, "counts_match": True}),
     "missing key 'replicated_seconds'"),
    (lambda p: p.update(distributed_join=[1]),
     "distributed_join must be a dict"),
    (lambda p: p.update(load_balance={"P": 64}),
     "missing key 'shards_holding_half_before'"),
    (lambda p: p.update(load_balance=[1]), "load_balance must be a dict"),
    (lambda p: p.update(resilience={"P": 4, "restart_P": 2}),
     "missing key 'phases_checkpointed'"),
    (lambda p: p.update(resilience=[1]), "resilience must be a dict"),
])
def test_rollup_schema_violations_are_rejected(tmp_path, mutate, match):
    registry.set_policy(None)
    path = bench_common.write_rollup(
        _minimal_rollup_suites(), "small",
        phases=[{"phase": "LCC", "seconds": 0.5}],
        path=str(tmp_path / "r.json"),
    )
    payload = json.load(open(path))
    mutate(payload)
    with pytest.raises(ValueError, match=match):
        bench_common.validate_rollup(payload)


# --------------------------------------------------------------- plan cache
def _plan_setup():
    """Planted-square graph + template with a tuned plan recorded for its
    exact (template-sig, graph-stats) bucket."""
    from repro.core import plan_query, record_plan
    from repro.core.template import generate_constraints
    from repro.graph import collect_graph_stats
    from repro.graph.structs import Graph

    pattern = Graph.from_undirected_pairs(
        4, [(0, 1), (1, 2), (2, 3), (3, 0)], [2, 3, 4, 3])
    bg = gen.rmat_graph(7, edge_factor=4, seed=3, labeler="random",
                        n_labels=6)
    g = gen.planted_pattern_graph(bg, pattern, n_copies=2, seed=5)
    tmpl = Template([2, 3, 4, 3], [(0, 1), (1, 2), (2, 3), (3, 0)])
    st = collect_graph_stats(g)
    cs = generate_constraints(tmpl, label_freq=g.label_frequency())
    pol = registry.DispatchPolicy()
    qp = plan_query(tmpl, st, backend="cpu", policy=pol)
    record_plan(pol, tmpl, st, qp, backend="cpu")
    return g, tmpl, st, cs, pol, qp


def test_untuned_policy_runs_heuristic_plan():
    """Zero-overhead rule: an active policy with routes but NO plans must
    leave prune on the heuristic order without ever touching graph stats."""
    g, tmpl, st, cs, _, _ = _plan_setup()
    pol = registry.DispatchPolicy()  # routes only, plans empty
    pol.set_route(LCC_ROUTE, "cpu", registry.BUCKET_ANY,
                  registry.ROUTE_PACKED)
    registry.set_policy(pol)
    out = prune(g, tmpl)
    assert out.stats["plan"]["source"] == "heuristic"
    from repro.core import planner
    assert registry.resolve_plan(planner.plan_bucket(tmpl, st),
                                 [planner.constraint_signature(c)
                                  for c in cs]) is None


def test_plan_entry_json_roundtrip(tmp_path):
    _, tmpl, st, cs, pol, qp = _plan_setup()
    path = str(tmp_path / "plans.json")
    pol.save(path)
    reloaded = registry.DispatchPolicy.load(path)
    assert reloaded.to_json() == pol.to_json()
    [key] = [k for k in reloaded.plans]
    entry = reloaded.plans[key]
    assert entry.signatures() == qp.signatures()
    assert entry.predicted_s == pytest.approx(qp.predicted_s)
    # a plan-free policy omits the additive "plans" field entirely
    assert "plans" not in registry.DispatchPolicy().to_json()


def test_stale_plan_signature_ignored_with_warning():
    """A cached plan whose constraint signatures no longer match what the
    template generates (constraint generation changed) is ignored — with a
    warning — and prune falls back to the heuristic order."""
    from repro.core import planner

    g, tmpl, st, cs, pol, _ = _plan_setup()
    [key] = list(pol.plans)
    for p in pol.plans[key].phases:
        p["sig"] = p["sig"] + ":v999"  # no longer generated by anything
    registry.set_policy(pol)
    sigs = [planner.constraint_signature(c) for c in cs]
    with pytest.warns(RuntimeWarning, match="stale plan cache entry"):
        got = registry.resolve_plan(planner.plan_bucket(tmpl, st), sigs)
    assert got is None
    with pytest.warns(RuntimeWarning, match="stale plan cache entry"):
        out = prune(g, tmpl)
    assert out.stats["plan"]["source"] == "heuristic"


def test_malformed_plan_cache_entry_skipped_with_warning(tmp_path):
    """One corrupt plan entry must not take down the whole policy: it is
    skipped with a warning; routes/modes and intact plans still load."""
    _, _, _, _, pol, _ = _plan_setup()
    pol.set_route(LCC_ROUTE, "cpu", registry.BUCKET_ANY,
                  registry.ROUTE_PACKED)
    payload = pol.to_json()
    [key] = list(payload["plans"])
    payload["plans"]["prune.plan|cpu|brokenxbucket"] = {
        "phases": [{"engine": "nlcc"}]}  # no "sig" — malformed
    with pytest.warns(RuntimeWarning, match="malformed plan cache entry"):
        reloaded = registry.DispatchPolicy.from_json(payload)
    assert key in reloaded.plans  # the intact entry survived
    assert "prune.plan|cpu|brokenxbucket" not in reloaded.plans
    assert f"{LCC_ROUTE}|cpu|{registry.BUCKET_ANY}" in reloaded.routes


def test_tune_preserves_plan_entries(tmp_path):
    """registry.tune() load-and-extend must carry tuned plans through: a
    re-tune that measures unrelated routes leaves the plan table intact."""
    _, _, _, _, pol, qp = _plan_setup()
    path = str(tmp_path / "tuned.json")
    pol.save(path)
    tuned = registry.tune(
        routes=[("test.route", registry.BUCKET_ANY, {"a": lambda: None})],
        repeat=1, path=path,
    )
    [key] = list(tuned.plans)
    assert tuned.plans[key].signatures() == qp.signatures()
    reloaded = registry.DispatchPolicy.load(path)
    assert reloaded.to_json() == tuned.to_json()

"""Logical-axis sharding rules -> PartitionSpec on the production meshes.

Every parameter / activation in the framework is annotated with a tuple of
*logical* axis names; `logical_to_physical` maps them onto mesh axes
according to a rule table. This decouples model code from mesh topology:
the same model lowers on (data, model), (pod, data, model), or a single
device (all rules resolve to None).

Parallelism encoded by the default rules:
  FSDP  — parameter "embed"/"ff_in" dims sharded over the data axis(es)
  TP    — "heads" / "ff_out" / "vocab" sharded over the model axis
          (Megatron column/row pairing falls out of the rule table)
  EP    — "expert" over the model axis (experts live where their TP shard is)
  SP    — "seq" over the model axis for sequence-parallel activations
  DP    — "batch" over (pod, data)
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes, or None = replicated)
DEFAULT_RULES: Dict[str, object] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": "model",         # sequence-parallel regions
    "act_embed": None,
    "act_heads": "model",
    "act_kv": "model",
    "act_ff": "model",         # Megatron TP: ff activation column-sharded
    "act_tokens": ("pod", "data"),  # flattened token dim (MoE dispatch)
    # params: attention
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "qk_rope": None,
    "kv_lora": None,
    # params: mlp
    "embed": "data",           # FSDP shard dim
    "ff": "model",             # TP shard dim (column for in-proj, row for out-proj)
    # moe
    "expert": "model",
    "expert_ff": None,
    "expert_embed": "data",
    # embeddings
    "vocab": "model",
    "item": "model",
    "candidates": "model",
    # gnn / engine
    "nodes": ("pod", "data"),
    "edges": ("pod", "data", "model"),
    "feat": None,
    "words": None,
    "classes": None,
    # misc
    "table_rows": "model",     # recsys embedding tables: row (vocab)-sharded
    "table_dim": None,
}


def _axes_in_mesh(mesh: Mesh) -> set:
    return set(mesh.axis_names)


def logical_to_physical(
    logical: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Optional[Dict[str, object]] = None,
) -> P:
    """Map a tuple of logical axis names to a PartitionSpec valid on `mesh`."""
    rules = rules or DEFAULT_RULES
    avail = _axes_in_mesh(mesh)
    used = set()
    out = []
    for name in logical:
        if name is None:
            out.append(None)
            continue
        phys = rules.get(name)
        if phys is None:
            out.append(None)
            continue
        if isinstance(phys, str):
            phys = (phys,)
        sel = tuple(a for a in phys if a in avail and a not in used)
        used.update(sel)
        if not sel:
            out.append(None)
        elif len(sel) == 1:
            out.append(sel[0])
        else:
            out.append(sel)
    return P(*out)


def named_sharding(mesh: Mesh, *logical: Optional[str], rules=None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_physical(logical, mesh, rules))


def tree_shardings(spec_tree, mesh: Mesh, rules=None):
    """Map a pytree of logical-axis tuples to a pytree of NamedShardings."""
    return jax.tree.map(
        lambda logical: NamedSharding(mesh, logical_to_physical(logical, mesh, rules)),
        spec_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x
        ),
    )


# ---------------------------------------------------------- active mesh ctx
# Model code annotates activations with logical axes via constrain(); the
# launcher (cells.py / train.py) installs the concrete mesh here so those
# annotations become real with_sharding_constraint ops during jit tracing.
# Without an active mesh (unit tests, single device) constrain is a no-op.
_ACTIVE_MESH: Optional[Mesh] = None


def set_active_mesh(mesh: Optional[Mesh]):
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


class active_mesh:
    def __init__(self, mesh: Optional[Mesh]):
        self.mesh = mesh

    def __enter__(self):
        self.prev = _ACTIVE_MESH
        set_active_mesh(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        set_active_mesh(self.prev)


def resolve_axis_spec(shape, logical: Sequence[Optional[str]], mesh: Mesh,
                      rules=None) -> P:
    """logical axes -> PartitionSpec with a divisibility guard: mesh axes that
    do not divide the dimension are dropped (prefix-kept for tuples)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec = logical_to_physical(logical, mesh, rules)
    fixed = []
    for i, ax in enumerate(spec):
        if ax is None or i >= len(shape):
            fixed.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        kept = ()
        for a in axes:
            size = 1
            for b in kept + (a,):
                size *= sizes[b]
            if shape[i] % size == 0 and shape[i] > 0:
                kept = kept + (a,)
            else:
                break
        if not kept:
            fixed.append(None)
        elif len(kept) == 1:
            fixed.append(kept[0])
        else:
            fixed.append(kept)
    fixed = fixed[: len(shape)]
    fixed += [None] * (len(shape) - len(fixed))
    return P(*fixed)


def constrain(x, *logical: Optional[str], rules=None):
    """with_sharding_constraint by logical axes against the active mesh;
    no-op when no mesh is installed."""
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    spec = resolve_axis_spec(x.shape, logical, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

"""Synthetic interaction sequences + Cloze masking for BERT4Rec."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


class MaskedSequenceStream:
    """Deterministic (seed, step) -> masked-item batches.

    Sessions follow a random-walk over a hidden item-item graph so the Cloze
    task is learnable. Item id 0 = padding; id n_items+1 = [MASK].
    """

    def __init__(self, n_items: int, batch: int, seq_len: int,
                 mask_prob: float = 0.2, seed: int = 0):
        self.n_items, self.batch, self.seq_len = n_items, batch, seq_len
        self.mask_prob, self.seed = mask_prob, seed

    def batch_at(self, step: int):
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        start = rng.integers(1, self.n_items + 1, size=(self.batch, 1))
        steps = rng.integers(1, 7, size=(self.batch, self.seq_len))
        items = ((start + np.cumsum(steps, axis=1) * 97) % self.n_items) + 1
        # truncate sessions to random lengths (pad with 0 on the left)
        lengths = rng.integers(self.seq_len // 4, self.seq_len + 1, size=self.batch)
        pos = np.arange(self.seq_len)[None, :]
        pad = pos < (self.seq_len - lengths[:, None])
        items = np.where(pad, 0, items)
        mlm = (rng.random((self.batch, self.seq_len)) < self.mask_prob) & ~pad
        masked = np.where(mlm, self.n_items + 1, items)
        return {
            "items": jnp.asarray(masked, jnp.int32),
            "labels": jnp.asarray(items, jnp.int32),
            "mlm_mask": jnp.asarray(mlm),
        }

    def __call__(self, step: int):
        return self.batch_at(step)

"""Graph data pipelines: full-graph batches, block-diagonal molecule batches,
sampled GraphSAGE batches, and the paper-technique integration —
`PatternFilteredDataset` (PruneJuice pruning as a subgraph-selection stage
before GNN training).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np
import jax.numpy as jnp

from repro.graph.structs import Graph
from repro.graph.sampler import NeighborSampler
from repro.core.template import Template
from repro.core.pipeline import prune


def full_graph_batch(g: Graph, d_feat: int, n_classes: int, seed: int = 0) -> Dict:
    rng = np.random.default_rng(seed)
    deg = g.degrees()
    return {
        "x": jnp.asarray(rng.standard_normal((g.n, d_feat)), jnp.float32),
        "src": jnp.asarray(g.src),
        "dst": jnp.asarray(g.dst),
        "labels": jnp.asarray(g.labels % n_classes),
        "train_mask": jnp.asarray(rng.random(g.n) < 0.5),
        "log_deg_avg": float(np.mean(np.log(deg + 1)) + 1e-6),
    }


def molecule_batch(n_graphs: int, nodes_per: int, edges_per: int, d_feat: int,
                   n_classes: int, seed: int = 0) -> Dict:
    """Batched small graphs, block-diagonal: one big disconnected graph."""
    rng = np.random.default_rng(seed)
    srcs, dsts = [], []
    for i in range(n_graphs):
        base = i * nodes_per
        pairs = rng.integers(0, nodes_per, size=(edges_per // 2, 2))
        pairs = pairs[pairs[:, 0] != pairs[:, 1]]
        srcs.append(base + np.concatenate([pairs[:, 0], pairs[:, 1]]))
        dsts.append(base + np.concatenate([pairs[:, 1], pairs[:, 0]]))
    n = n_graphs * nodes_per
    src = np.concatenate(srcs).astype(np.int32)
    dst = np.concatenate(dsts).astype(np.int32)
    deg = np.bincount(src, minlength=n)
    return {
        "x": jnp.asarray(rng.standard_normal((n, d_feat)), jnp.float32),
        "src": jnp.asarray(src),
        "dst": jnp.asarray(dst),
        "labels": jnp.asarray(rng.integers(0, n_classes, n), jnp.int32),
        "graph_of": jnp.asarray(np.repeat(np.arange(n_graphs), nodes_per)),
        "log_deg_avg": float(np.mean(np.log(deg + 1)) + 1e-6),
    }


class SampledBatchStream:
    """GraphSAGE minibatch pipeline: real neighbor sampling over CSR, emitting
    static-shape dense fanout tensors (the minibatch_lg regime)."""

    def __init__(self, g: Graph, feats: np.ndarray, labels: np.ndarray,
                 fanouts: Sequence[int], batch: int, seed: int = 0):
        assert len(fanouts) == 2, "2-layer sampled pipeline"
        self.sampler = NeighborSampler(g, fanouts, seed=seed)
        self.feats, self.labels = feats, labels
        self.fanouts, self.batch = tuple(fanouts), batch

    def batch_at(self, step: int):
        self.sampler.rng = np.random.default_rng(
            np.random.SeedSequence([self.sampler.n, step])
        )
        layers = self.sampler.sample_batch(self.batch)
        f1, f2 = self.fanouts
        b = self.batch
        return {
            "x_self": jnp.asarray(self.feats[layers[0]], jnp.float32),
            "x_nbr": jnp.asarray(self.feats[layers[1]].reshape(b, f1, -1), jnp.float32),
            "x_nbr2": jnp.asarray(self.feats[layers[2]].reshape(b, f1, f2, -1), jnp.float32),
            "labels": jnp.asarray(self.labels[layers[0]], jnp.int32),
        }

    def __call__(self, step: int):
        return self.batch_at(step)


class PatternFilteredDataset:
    """Beyond-paper integration: prune the background graph to the union of
    matches of a search template (the paper's engine), then serve the pruned
    graph as GNN training data — 'train on the subgraph where the pattern of
    interest occurs'."""

    def __init__(self, g: Graph, template: Template, d_feat: int, n_classes: int,
                 seed: int = 0):
        res = prune(g, template)
        self.prune_counts = res.counts()
        order = np.lexsort((g.src, g.dst))
        inv = np.empty_like(order)
        inv[order] = np.arange(order.size)
        emask = np.asarray(res.edge_mask)[inv]  # back to g's arc order
        self.pruned = g.subgraph(res.vertex_mask, emask)
        self.omega = np.asarray(res.omega)[res.vertex_mask]
        self._batch = full_graph_batch(self.pruned, d_feat, n_classes, seed)
        # the engine's per-vertex template-match annotation as extra features
        self._batch["x"] = jnp.concatenate(
            [self._batch["x"], jnp.asarray(self.omega, jnp.float32)], axis=1
        )

    def batch_at(self, step: int):
        return self._batch

    def __call__(self, step: int):
        return self.batch_at(step)

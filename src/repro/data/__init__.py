from repro.data.tokens import SyntheticTokenStream  # noqa: F401
from repro.data.recsys import MaskedSequenceStream  # noqa: F401
from repro.data.graphs import (  # noqa: F401
    full_graph_batch, molecule_batch, SampledBatchStream, PatternFilteredDataset,
)

"""Deterministic synthetic token streams.

Each batch is a pure function of (seed, step) via the threefry counter —
this is what makes checkpoint-resume skip-ahead exact (trainer contract) and
lets any host of the fleet regenerate any shard of any step without
coordination. A Zipf-ish marginal + a linear-congruential 'grammar' make the
stream learnable (loss decreases), so convergence tests are meaningful.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


class SyntheticTokenStream:
    def __init__(self, vocab: int, batch: int, seq_len: int, seed: int = 0):
        self.vocab, self.batch, self.seq_len, self.seed = vocab, batch, seq_len, seed

    def batch_at(self, step: int):
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        # zipf-flavored unigram draw, then a deterministic bigram transform so
        # that token t+1 is predictable from t 75% of the time
        z = rng.zipf(1.3, size=(self.batch, self.seq_len)).astype(np.int64)
        toks = (z - 1) % self.vocab
        follow = (toks * 2654435761 + 12345) % self.vocab
        use_follow = rng.random((self.batch, self.seq_len)) < 0.75
        toks[:, 1:] = np.where(use_follow[:, 1:], follow[:, :-1], toks[:, 1:])
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = 0
        return {
            "tokens": jnp.asarray(toks, jnp.int32),
            "labels": jnp.asarray(labels, jnp.int32),
        }

    def __call__(self, step: int):
        return self.batch_at(step)

"""Fault-tolerant checkpointing.

Design goals for the 1000+ node posture (checkpoint/restart is the paper's own
load-balancing mechanism *and* the framework's failure recovery):

  - atomic: write to `<dir>/tmp.<step>` then `os.replace` to `<dir>/step_<k>`
    (a crashed writer never corrupts the latest checkpoint),
  - self-describing: a JSON manifest records the pytree structure, global
    shapes, and the mesh the state was saved under,
  - elastic: arrays are saved as *global* host arrays (gathered), so a restore
    may target a different device count / mesh shape — resharding happens at
    load via the caller's shardings (the paper's LB-16 / LB-1 scenario),
  - retention: keep the last `keep` checkpoints, delete older ones,
  - deterministic resume: the manifest stores data-pipeline cursors so streams
    skip ahead instead of replaying,
  - torn-write safe: the manifest is written (and fsynced) LAST inside the
    tmp dir, so a directory whose manifest parses is complete by
    construction; `restore_checkpoint(step=None)` / `restore_latest`
    additionally validate each candidate (manifest vs arrays.npz shapes and
    dtypes) and SKIP corrupt/partial directories with a warning, falling
    back to the newest valid one instead of crashing.

Storage is .npz per checkpoint (numpy is the only offline dependency).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import warnings
import zipfile
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import jax


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(p) for p in path).replace("[", "").replace("]", "")
        out.append((key, leaf))
    return out, treedef


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    extra_meta: Optional[Dict] = None,
    keep: int = 3,
) -> str:
    os.makedirs(directory, exist_ok=True)
    (pairs, treedef) = _flatten_with_paths(tree)
    arrays = {}
    for i, (key, leaf) in enumerate(pairs):
        arrays[f"a{i}"] = np.asarray(jax.device_get(leaf))
    manifest = {
        "step": step,
        "keys": [k for k, _ in pairs],
        "shapes": [list(arrays[f"a{i}"].shape) for i in range(len(pairs))],
        "dtypes": [str(arrays[f"a{i}"].dtype) for i in range(len(pairs))],
        "treedef": str(treedef),
        "meta": extra_meta or {},
    }
    final = os.path.join(directory, f"step_{step:012d}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=f".tmp{step}_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        # manifest last + fsynced: its presence certifies the arrays landed
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _retain(directory, keep)
    return final


def _retain(directory: str, keep: int):
    steps = sorted(
        d for d in os.listdir(directory) if d.startswith("step_")
    )
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def _all_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    return sorted(
        int(d.split("_")[1]) for d in os.listdir(directory) if d.startswith("step_")
    )


def latest_step(directory: str) -> Optional[int]:
    steps = _all_steps(directory)
    return steps[-1] if steps else None


# every failure mode a torn/truncated checkpoint can surface as: unparseable
# JSON, a truncated or missing npz (BadZipFile/OSError/EOFError), manifest
# keys absent, or per-leaf shape/dtype records contradicting the arrays
_CORRUPT_ERRORS = (OSError, ValueError, KeyError, EOFError,
                   json.JSONDecodeError, zipfile.BadZipFile)


def checkpoint_valid(path: str) -> bool:
    """Deep-validate one checkpoint directory: the manifest parses AND every
    array in arrays.npz is readable with the recorded shape/dtype. Reading
    each member forces zlib to walk the compressed payload, so a truncated
    file fails here rather than mid-restore."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        keys = manifest["keys"]
        shapes = manifest["shapes"]
        dtypes = manifest["dtypes"]
        with np.load(os.path.join(path, "arrays.npz"),
                     allow_pickle=False) as data:
            for i in range(len(keys)):
                arr = data[f"a{i}"]
                if list(arr.shape) != list(shapes[i]):
                    return False
                if str(arr.dtype) != dtypes[i]:
                    return False
        return True
    except _CORRUPT_ERRORS:
        return False


def latest_valid_step(directory: str) -> Optional[int]:
    """Newest step whose checkpoint passes deep validation; corrupt/partial
    directories are skipped with a warning (a torn write must cost one
    checkpoint of progress, never the run)."""
    for step in reversed(_all_steps(directory)):
        path = os.path.join(directory, f"step_{step:012d}")
        if checkpoint_valid(path):
            return step
        warnings.warn(
            f"skipping corrupt/partial checkpoint {path} (failed "
            "manifest/array validation)", RuntimeWarning, stacklevel=2)
    return None


def restore_checkpoint(
    directory: str,
    like_tree: Any,
    step: Optional[int] = None,
    shardings: Any = None,
) -> Tuple[Any, Dict]:
    """Restore into the structure of `like_tree`; commit every restored array
    onto the caller's `shardings` (a pytree of the target mesh's
    NamedShardings, or a single sharding) with jax.device_put BEFORE any
    pjit'd step sees it — this is where elastic re-sharding onto a different
    device count / mesh shape happens. Restored global shapes are validated
    against `like_tree` so a config/topology mismatch fails here with a
    named leaf instead of deep inside pjit.

    With step=None the newest VALID checkpoint is used — corrupt or partial
    directories (torn writes) are skipped with a warning. An explicit step
    is restored as-is and raises on corruption."""
    if step is None:
        step = latest_valid_step(directory)
        if step is None:
            raise FileNotFoundError(
                f"no valid checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:012d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
    n = len(leaves_like)
    if n != len(manifest["keys"]):
        raise ValueError(
            f"checkpoint has {len(manifest['keys'])} leaves, expected {n}"
        )
    shapes = manifest.get("shapes")
    dtypes = manifest.get("dtypes")
    leaves = []
    for i, like in enumerate(leaves_like):
        arr = data[f"a{i}"]
        # manifest vs npz: on-disk corruption / partial write, independent
        # of what the caller asks for
        if shapes is not None and list(arr.shape) != list(shapes[i]):
            raise ValueError(
                f"checkpoint leaf {manifest['keys'][i]!r}: arrays.npz has "
                f"shape {tuple(arr.shape)} but the manifest recorded "
                f"{tuple(shapes[i])} — corrupt checkpoint"
            )
        if dtypes is not None and str(arr.dtype) != dtypes[i]:
            raise ValueError(
                f"checkpoint leaf {manifest['keys'][i]!r}: arrays.npz has "
                f"dtype {arr.dtype} but the manifest recorded {dtypes[i]} — "
                f"corrupt checkpoint"
            )
        # checkpoint vs restore target: a config/topology mismatch
        want = getattr(like, "shape", None)
        if want is not None and tuple(arr.shape) != tuple(want):
            raise ValueError(
                f"checkpoint leaf {manifest['keys'][i]!r} has global shape "
                f"{tuple(arr.shape)}, expected {tuple(want)} — the restore "
                f"target was built from a different config"
            )
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        # Leaf-wise put when the shardings tree mirrors the state tree (the
        # shardings_for output), whole-tree put for a single sharding.
        try:
            flat_sh = treedef.flatten_up_to(shardings)
        except (ValueError, TypeError):
            flat_sh = None
        if flat_sh is not None:
            tree = jax.tree_util.tree_unflatten(
                treedef,
                [jax.device_put(l, s) for l, s in zip(leaves, flat_sh)],
            )
        else:
            tree = jax.device_put(tree, shardings)
    return tree, manifest["meta"] | {"step": manifest["step"]}


class CheckpointManager:
    """Step-cadence manager with failure-injection-friendly semantics."""

    def __init__(self, directory: str, interval: int = 100, keep: int = 3):
        self.directory = directory
        self.interval = interval
        self.keep = keep

    def maybe_save(self, step: int, tree: Any, extra_meta: Optional[Dict] = None):
        if self.interval > 0 and step % self.interval == 0:
            return save_checkpoint(self.directory, step, tree, extra_meta, self.keep)
        return None

    def restore_latest(self, like_tree: Any, shardings: Any = None):
        return restore_checkpoint(self.directory, like_tree, shardings=shardings)

from repro.checkpoint.ckpt import (
    save_checkpoint,
    restore_checkpoint,
    latest_step,
    latest_valid_step,
    checkpoint_valid,
    CheckpointManager,
)

__all__ = [
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "latest_valid_step",
    "checkpoint_valid",
    "CheckpointManager",
]

"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-tensor-scale quantization applied to gradients *before* the
cross-replica reduction; the quantization residual is carried in an error-
feedback buffer so the compressed SGD direction stays unbiased over time
(Karimireddy et al., "Error Feedback Fixes SignSGD", arXiv:1901.09847).

Under pjit the all-reduce of DP gradients is implicit (psum inserted by the
partitioner); compressing the gradient tensor shrinks the reduced payload
4x for fp32 training. Exposed as a pluggable transform in the train step.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads, ef):
    """Returns (decompressed grads as seen by every replica, new ef)."""

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        deq = dequantize(q, scale)
        return deq.astype(g.dtype), corrected - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))

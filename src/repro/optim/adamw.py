"""AdamW with dtype-configurable state, decoupled weight decay and global-norm
clipping. Pure pytree functions (no optax dependency — everything in-repo).

State dtype matters at scale: bf16 first/second moments halve optimizer HBM
(the deepseek-v3 configuration needs this to fit 512 chips; see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: str = "float32"   # "float32" | "bfloat16"


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


def init_state(params, cfg: AdamWConfig):
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda x: jnp.zeros(x.shape, dt)  # noqa: E731
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def state_specs(param_specs):
    """Optimizer state shards exactly like its parameter."""
    return {
        "mu": param_specs,
        "nu": param_specs,
        "count": (),
    }


def update(grads, state, params, cfg: AdamWConfig,
           lr_scale: jnp.ndarray | float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    metrics = {}
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        metrics["grad_norm"] = gnorm
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu32 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g32
        nu32 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        step = (mu32 / b1c) / (jnp.sqrt(nu32 / b2c) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (step + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), mu32.astype(mu.dtype), nu32.astype(nu.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_params, {"mu": new_mu, "nu": new_nu, "count": count}, metrics

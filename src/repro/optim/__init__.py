from repro.optim.adamw import (  # noqa: F401
    AdamWConfig, init_state, state_specs, update, global_norm, clip_by_global_norm,
)
from repro.optim import schedules, compression  # noqa: F401

"""Architecture registry: --arch <id> -> (CONFIG, SHAPES, smoke)."""
from __future__ import annotations

import importlib
from typing import Dict

_MODULES: Dict[str, str] = {
    "starcoder2-15b": "repro.configs.starcoder2_15b",
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "pna": "repro.configs.pna",
    "graphsage-reddit": "repro.configs.graphsage_reddit",
    "gin-tu": "repro.configs.gin_tu",
    "gat-cora": "repro.configs.gat_cora",
    "bert4rec": "repro.configs.bert4rec",
}

ARCH_IDS = tuple(_MODULES)


def get_arch(arch_id: str):
    """Returns the arch's config module (CONFIG, SHAPES, smoke())."""
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(_MODULES[arch_id])


def get_config(arch_id: str):
    return get_arch(arch_id).CONFIG


def get_shapes(arch_id: str):
    return get_arch(arch_id).SHAPES

"""GraphSAGE-Reddit [arXiv:1706.02216]: 2 layers, d_hidden=128, mean
aggregator, fanouts 25-10 (minibatch_lg uses the assignment's 15-10)."""
from repro.configs.base import GNNConfig, GNN_SHAPES

CONFIG = GNNConfig(
    name="graphsage-reddit", model="graphsage", n_layers=2, d_hidden=128,
    aggregators=("mean",), sample_sizes=(25, 10),
)

SHAPES = dict(GNN_SHAPES)


def smoke():
    return GNNConfig(
        name="graphsage-smoke", model="graphsage", n_layers=2, d_hidden=16,
        aggregators=("mean",), sample_sizes=(5, 3),
    )

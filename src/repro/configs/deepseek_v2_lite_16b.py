"""DeepSeek-V2-Lite 16B [arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2-Lite].

MLA (no q compression, kv_lora 512, rope 64), MoE 2 shared + 64 routed top-6
(expert d_ff 1408; first layer dense with d_ff 10944). NOTE: the assignment
line says "160 routed"; both the cited paper and the HF config say 64 — we
follow the primary sources (see DESIGN.md §5). long_500k skipped (quadratic).
"""
from repro.configs.base import LMConfig, LM_SHAPES
import dataclasses

CONFIG = LMConfig(
    name="deepseek-v2-lite-16b",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400,
    attention="mla", q_lora_rank=None, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    moe=True, n_routed=64, n_shared=2, top_k=6,
    first_dense_layers=1, dense_d_ff=10944,
    rope_theta=10_000.0,
)

SHAPES = {
    k: (v if k != "long_500k" else dataclasses.replace(v, skip="full quadratic (MLA) attention"))
    for k, v in LM_SHAPES.items()
}


def smoke():
    return LMConfig(
        name="deepseek-v2-lite-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=32, vocab=128, attention="mla", kv_lora_rank=16,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        moe=True, n_routed=8, n_shared=2, top_k=2, first_dense_layers=1,
        dense_d_ff=64, dtype="float32",
        capacity_factor=8.0,  # dropless at smoke scale
    )

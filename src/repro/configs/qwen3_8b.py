"""Qwen3-8B [hf:Qwen/Qwen3-8B].

GQA (8 kv heads), qk-norm (RMSNorm on per-head q/k), head_dim=128, SwiGLU,
no biases. Full quadratic attention -> long_500k skipped.
"""
from repro.configs.base import LMConfig, LM_SHAPES
import dataclasses

CONFIG = LMConfig(
    name="qwen3-8b",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=12288, vocab=151936,
    qk_norm=True, rope_theta=1_000_000.0,
)

SHAPES = {
    k: (v if k != "long_500k" else dataclasses.replace(v, skip="full quadratic attention"))
    for k, v in LM_SHAPES.items()
}


def smoke():
    return LMConfig(
        name="qwen3-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=160, vocab=128, qk_norm=True, dtype="float32",
    )

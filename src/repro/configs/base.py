"""Config dataclasses for all architecture families + input-shape descriptors.

One module per assigned architecture lives next to this file; each exposes
  CONFIG  — the exact published configuration
  SHAPES  — the arch's own input-shape set (assignment cells)
  smoke() — a reduced same-family config for CPU tests
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


# ---------------------------------------------------------------- LM family
@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    attention: str = "gqa"                  # "gqa" | "mla"
    qkv_bias: bool = False                  # qwen2
    qk_norm: bool = False                   # qwen3
    window: Optional[int] = None            # starcoder2 sliding window
    mlp: str = "swiglu"                     # "swiglu" | "gelu"
    norm: str = "rmsnorm"                   # "rmsnorm" | "layernorm"
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # MLA (deepseek)
    q_lora_rank: Optional[int] = None
    kv_lora_rank: Optional[int] = None
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # MoE (deepseek)
    moe: bool = False
    n_routed: int = 0
    n_shared: int = 0
    top_k: int = 0
    first_dense_layers: int = 0
    dense_d_ff: Optional[int] = None        # d_ff of the leading dense layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    # perf knobs (EXPERIMENTS.md §Perf): 0/False = paper-faithful baseline
    moe_groups: int = 0          # >0: per-DP-group dispatch (local sort/scatter,
    #                              expert movement becomes one all-to-all)
    moe_gather_weights: bool = False  # ZeRO-3 style: all-gather expert weights
    #                              at use instead of contracting sharded dims
    fused_ce: int = 0            # >0: blockwise cross-entropy over vocab chunks
    remat_policy: str = "full"   # "full" | "dots" (save matmul outputs)
    train_microbatches: int = 0  # 0 = launcher default (8); fewer microbatches
    #                              = fewer per-layer weight gathers, more
    #                              activation memory per pass
    # MTP (deepseek-v3)
    mtp: bool = False
    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6

    @property
    def kind(self) -> str:
        return "lm"

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def n_params(self) -> int:
        """Total parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, hd = self.d_model, self.hd
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.attention == "mla":
            qin = (self.q_lora_rank or 0)
            if self.q_lora_rank:
                per_layer += d * self.q_lora_rank
                per_layer += self.q_lora_rank * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
            else:
                per_layer += d * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
            per_layer += d * (self.kv_lora_rank + self.qk_rope_dim)
            per_layer += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
            per_layer += self.n_heads * self.v_head_dim * d
        else:
            per_layer += d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
            per_layer += self.n_heads * hd * d
        mlp_mult = 3 if self.mlp == "swiglu" else 2
        total = emb + self.n_layers * per_layer
        if self.moe:
            dense_ff = self.dense_d_ff or self.d_ff
            n_dense = self.first_dense_layers
            n_moe = self.n_layers - n_dense
            total += n_dense * mlp_mult * d * dense_ff
            total += n_moe * (self.n_routed + self.n_shared) * mlp_mult * d * self.d_ff
            total += n_moe * d * self.n_routed  # router
        else:
            total += self.n_layers * mlp_mult * d * self.d_ff
        return total

    def n_active_params(self) -> int:
        """Activated params per token (MoE: routed top-k + shared only)."""
        if not self.moe:
            return self.n_params()
        d = self.d_model
        mlp_mult = 3 if self.mlp == "swiglu" else 2
        total = self.n_params()
        n_moe = self.n_layers - self.first_dense_layers
        total -= n_moe * (self.n_routed + self.n_shared) * mlp_mult * d * self.d_ff
        total += n_moe * (self.top_k + self.n_shared) * mlp_mult * d * self.d_ff
        return total


# --------------------------------------------------------------- GNN family
@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    model: str                 # "pna" | "graphsage" | "gin" | "gat"
    n_layers: int
    d_hidden: int
    n_heads: int = 1           # gat
    aggregators: Tuple[str, ...] = ("mean",)
    scalers: Tuple[str, ...] = ("identity",)
    sample_sizes: Tuple[int, ...] = ()   # graphsage fanouts
    eps_learnable: bool = False          # gin
    dtype: str = "float32"
    # perf knobs (§Perf): full-graph message passing over the engine's edge
    # partition (shard_map + bucketed all_to_all) instead of GSPMD placement
    distributed: bool = False
    message_dtype: str = "float32"  # "bfloat16" halves the all_to_all payload

    @property
    def kind(self) -> str:
        return "gnn"


# ------------------------------------------------------------ RecSys family
@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    embed_dim: int
    n_blocks: int
    n_heads: int
    seq_len: int
    n_items: int = 1_000_000   # embedding table rows
    dtype: str = "bfloat16"
    # perf knobs (§Perf): 0 = paper-faithful full-catalog softmax
    fused_ce: int = 0          # >0: blockwise CE over item chunks (exact)
    n_negatives: int = 0       # >0: sampled-softmax with shared negatives

    @property
    def kind(self) -> str:
        return "recsys"

    def n_params(self) -> int:
        d = self.embed_dim
        per_block = 4 * d * d + 8 * d * d  # attn + 4x ffn
        return self.n_items * d + self.n_blocks * per_block + self.seq_len * d


# ------------------------------------------------------------------- shapes
@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assignment cell: what program to lower and with which sizes."""

    name: str
    step: str                  # "train" | "prefill" | "decode" | "serve" | "retrieval"
    # lm
    seq_len: int = 0
    global_batch: int = 0
    # gnn
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: Tuple[int, ...] = ()
    n_graphs: int = 0
    # recsys
    batch: int = 0
    n_candidates: int = 0
    skip: Optional[str] = None  # reason this cell is skipped (long_500k on full attn)


LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    "decode_32k": ShapeSpec("decode_32k", "decode", seq_len=32768, global_batch=128),
    "long_500k": ShapeSpec("long_500k", "decode", seq_len=524288, global_batch=1),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec("full_graph_sm", "train", n_nodes=2708, n_edges=10556, d_feat=1433),
    "minibatch_lg": ShapeSpec(
        "minibatch_lg", "train", n_nodes=232965, n_edges=114615892,
        batch_nodes=1024, fanout=(15, 10), d_feat=602,
    ),
    "ogb_products": ShapeSpec("ogb_products", "train", n_nodes=2449029, n_edges=61859140, d_feat=100),
    "molecule": ShapeSpec("molecule", "train", n_nodes=30, n_edges=64, n_graphs=128, d_feat=16),
}

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", batch=65536),
    "serve_p99": ShapeSpec("serve_p99", "serve", batch=512),
    "serve_bulk": ShapeSpec("serve_bulk", "serve", batch=262144),
    "retrieval_cand": ShapeSpec("retrieval_cand", "retrieval", batch=1, n_candidates=1_000_000),
}

"""Qwen2-1.5B [arXiv:2407.10671; hf:Qwen/Qwen2-1.5B].

GQA (2 kv heads), QKV bias, SwiGLU, RMSNorm, tied embeddings.
Full quadratic attention -> long_500k is skipped (see DESIGN.md).
"""
from repro.configs.base import LMConfig, LM_SHAPES
import dataclasses

CONFIG = LMConfig(
    name="qwen2-1.5b",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
    d_ff=8960, vocab=151936,
    qkv_bias=True, tie_embeddings=True, rope_theta=1_000_000.0,
)

SHAPES = {
    k: (v if k != "long_500k" else dataclasses.replace(v, skip="full quadratic attention"))
    for k, v in LM_SHAPES.items()
}


def smoke():
    return LMConfig(
        name="qwen2-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=160, vocab=128, qkv_bias=True, tie_embeddings=True, dtype="float32",
    )

"""GIN [arXiv:1810.00826]: 5 layers, d_hidden=64, sum aggregator, learnable eps."""
from repro.configs.base import GNNConfig, GNN_SHAPES

CONFIG = GNNConfig(
    name="gin-tu", model="gin", n_layers=5, d_hidden=64,
    aggregators=("sum",), eps_learnable=True,
)

SHAPES = dict(GNN_SHAPES)


def smoke():
    return GNNConfig(
        name="gin-smoke", model="gin", n_layers=2, d_hidden=8,
        aggregators=("sum",), eps_learnable=True,
    )

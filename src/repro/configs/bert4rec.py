"""BERT4Rec [arXiv:1904.06690]: embed 64, 2 blocks, 2 heads, seq 200,
bidirectional self-attention. Table sized 1M items (retrieval_cand cell)."""
from repro.configs.base import RecsysConfig, RECSYS_SHAPES

CONFIG = RecsysConfig(
    name="bert4rec", embed_dim=64, n_blocks=2, n_heads=2, seq_len=200,
    n_items=1_000_000,
)

SHAPES = dict(RECSYS_SHAPES)


def smoke():
    return RecsysConfig(
        name="bert4rec-smoke", embed_dim=32, n_blocks=2, n_heads=2, seq_len=16,
        n_items=500, dtype="float32",
    )

"""StarCoder2-15B [arXiv:2402.19173; hf:bigcode/starcoder2-15b].

GQA (4 kv heads), RoPE, sliding-window attention (4096), GELU MLP with bias,
LayerNorm. The sliding window makes decode sub-quadratic -> this is the ONLY
LM arch that runs the long_500k cell (ring-buffer KV cache of window size).
"""
from repro.configs.base import LMConfig, LM_SHAPES, ShapeSpec

CONFIG = LMConfig(
    name="starcoder2-15b",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
    d_ff=24576, vocab=49152,
    window=4096, mlp="gelu", norm="layernorm", qkv_bias=True,
    rope_theta=100_000.0,
)

SHAPES = dict(LM_SHAPES)  # all four cells, including long_500k


def smoke():
    return LMConfig(
        name="starcoder2-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=256, vocab=128, window=16, mlp="gelu", norm="layernorm",
        qkv_bias=True, dtype="float32",
    )

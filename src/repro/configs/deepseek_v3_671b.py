"""DeepSeek-V3 671B [arXiv:2412.19437; hf:deepseek-ai/DeepSeek-V3].

MLA (q_lora 1536, kv_lora 512, decoupled rope 64), MoE with 1 shared + 256
routed experts top-8 (expert d_ff 2048; first 3 layers dense with d_ff 18432),
MTP head. 128 heads. Full quadratic attention -> long_500k skipped.
"""
from repro.configs.base import LMConfig, LM_SHAPES
import dataclasses

CONFIG = LMConfig(
    name="deepseek-v3-671b",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=2048, vocab=129280,
    attention="mla", q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    moe=True, n_routed=256, n_shared=1, top_k=8,
    first_dense_layers=3, dense_d_ff=18432,
    mtp=True, rope_theta=10_000.0,
)

SHAPES = {
    k: (v if k != "long_500k" else dataclasses.replace(v, skip="full quadratic (MLA) attention"))
    for k, v in LM_SHAPES.items()
}


def smoke():
    return LMConfig(
        name="deepseek-v3-smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=32, vocab=128, attention="mla", q_lora_rank=24, kv_lora_rank=16,
        qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        moe=True, n_routed=8, n_shared=1, top_k=2, first_dense_layers=1,
        dense_d_ff=64, mtp=True, dtype="float32",
        capacity_factor=8.0,  # dropless at smoke scale
    )

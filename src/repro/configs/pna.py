"""PNA [arXiv:2004.05718]: 4 layers, d_hidden=75, aggregators mean/max/min/std,
scalers identity/amplification/attenuation."""
from repro.configs.base import GNNConfig, GNN_SHAPES

CONFIG = GNNConfig(
    name="pna", model="pna", n_layers=4, d_hidden=75,
    aggregators=("mean", "max", "min", "std"),
    scalers=("identity", "amplification", "attenuation"),
)

SHAPES = dict(GNN_SHAPES)


def smoke():
    return GNNConfig(
        name="pna-smoke", model="pna", n_layers=2, d_hidden=8,
        aggregators=("mean", "max", "min", "std"),
        scalers=("identity", "amplification", "attenuation"),
    )

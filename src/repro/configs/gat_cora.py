"""GAT-Cora [arXiv:1710.10903]: 2 layers, d_hidden=8, 8 heads, attention
aggregator (final layer averages heads)."""
from repro.configs.base import GNNConfig, GNN_SHAPES

CONFIG = GNNConfig(
    name="gat-cora", model="gat", n_layers=2, d_hidden=8, n_heads=8,
    aggregators=("attn",),
)

SHAPES = dict(GNN_SHAPES)


def smoke():
    return GNNConfig(
        name="gat-smoke", model="gat", n_layers=2, d_hidden=4, n_heads=2,
        aggregators=("attn",),
    )

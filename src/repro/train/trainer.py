"""Host-side training loop: checkpoint/restart, deterministic resume, and
failure handling — the fault-tolerance layer over the jitted train step.

Recovery contract (1000+ node posture):
  - state is checkpointed every `ckpt_interval` steps (atomic, manifest'd);
  - on (re)start the trainer restores the latest checkpoint and *skips the
    data stream ahead* — batches are a pure function of (seed, step), so no
    replay buffer is needed and every restart is bitwise deterministic;
  - `max_failures` transient step failures are retried from the last
    checkpoint (the jitted step is pure, so retry is safe);
  - elastic restarts onto a different mesh re-shard at restore time via the
    shardings argument (checkpoints store global arrays).

Straggler mitigation is structural in SPMD (no parameter server): the only
stragglers are hardware; the trainer exposes per-step wall times so the
launcher can evict slow hosts and relaunch on the survivors (elastic path).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, List, Optional

import jax

from repro.checkpoint import ckpt


@dataclasses.dataclass
class TrainerReport:
    steps_run: int
    final_step: int
    losses: List[float]
    restarts: int
    step_times: List[float]


def run(
    state,
    train_step: Callable,
    batch_fn: Callable[[int], Any],
    *,
    num_steps: int,
    ckpt_dir: Optional[str] = None,
    ckpt_interval: int = 50,
    keep: int = 3,
    shardings=None,
    max_failures: int = 3,
    fail_hook: Optional[Callable[[int], None]] = None,
    log_every: int = 0,
) -> TrainerReport:
    """Run `num_steps` steps of `train_step`, resuming from ckpt_dir if present.

    `batch_fn(step)` must be deterministic in `step` (skip-ahead resume).
    `fail_hook(step)` lets tests inject failures at chosen steps.
    """
    start_step = 0
    restarts = 0
    if ckpt_dir is not None:
        last = ckpt.latest_step(ckpt_dir)
        if last is not None:
            state, meta = ckpt.restore_checkpoint(ckpt_dir, state, shardings=shardings)
            start_step = int(meta["step"])
    losses: List[float] = []
    times: List[float] = []
    step = start_step
    failures = 0
    while step < num_steps:
        t0 = time.perf_counter()
        try:
            if fail_hook is not None:
                fail_hook(step)
            batch = batch_fn(step)
            state, metrics = train_step(state, batch)
            loss = float(metrics["loss"])
        except ckpt_failure_types() as e:  # transient failure -> restore + retry
            failures += 1
            restarts += 1
            if ckpt_dir is None or failures > max_failures:
                raise
            last = ckpt.latest_step(ckpt_dir)
            if last is not None:
                state, meta = ckpt.restore_checkpoint(ckpt_dir, state, shardings=shardings)
                step = int(meta["step"])
            else:
                step = 0
            continue
        losses.append(loss)
        times.append(time.perf_counter() - t0)
        step += 1
        if log_every and step % log_every == 0:
            print(f"step {step}: loss={loss:.4f} ({times[-1]*1e3:.0f} ms)")
        if ckpt_dir is not None and ckpt_interval > 0 and step % ckpt_interval == 0:
            ckpt.save_checkpoint(ckpt_dir, step, state, {"data_cursor": step}, keep=keep)
    if ckpt_dir is not None:
        ckpt.save_checkpoint(ckpt_dir, step, state, {"data_cursor": step}, keep=keep)
    return TrainerReport(
        steps_run=step - start_step, final_step=step, losses=losses,
        restarts=restarts, step_times=times,
    )


class SimulatedFailure(RuntimeError):
    """Raised by fail_hook in fault-tolerance tests."""


def ckpt_failure_types():
    return (SimulatedFailure,)

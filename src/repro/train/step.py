"""Train-step builder: loss -> grads (remat) -> microbatch accumulation ->
(optional) gradient compression -> AdamW. One builder for all three families.

The returned step is a pure function
    (state, batch) -> (state, metrics)
suitable for jax.jit with in/out shardings derived from the model's logical
specs (launch/dryrun.py, launch/train.py).

Grad accumulation: the global batch is reshaped to [K, micro, ...] and scanned
— activation memory is bounded by one microbatch, the paper-scale MoE configs
depend on this (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import adamw, schedules, compression
from repro.configs.base import LMConfig, GNNConfig, RecsysConfig


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    microbatches: int = 1
    # True: batches arrive pre-shaped [K, micro, ...] from the data pipeline
    # (the distributed layout — avoids a resharding reshape inside the step).
    pre_microbatched: bool = False
    # False | True (full remat) | "dots" (save matmul outputs, recompute
    # elementwise — trades HBM for recompute traffic; §Perf iteration knob)
    remat: object = False
    compress_grads: bool = False
    warmup_steps: int = 100
    total_steps: int = 10_000


def _loss_for(cfg) -> Callable:
    if isinstance(cfg, LMConfig):
        from repro.models import transformer
        return transformer.loss_fn
    if isinstance(cfg, GNNConfig):
        from repro.models import gnn
        return gnn.loss_fn
    if isinstance(cfg, RecsysConfig):
        from repro.models import bert4rec
        return bert4rec.loss_fn
    raise TypeError(type(cfg))


def init_state(rng, model_cfg, tc: TrainConfig, model_init=None, **init_kw):
    """Returns (state pytree, spec pytree mirroring it)."""
    if model_init is None:
        if isinstance(model_cfg, LMConfig):
            from repro.models import transformer as m
            model_init = m.init
        elif isinstance(model_cfg, GNNConfig):
            from repro.models import gnn as m
            model_init = m.init
        else:
            from repro.models import bert4rec as m
            model_init = m.init
    params, pspecs = model_init(rng, model_cfg, **init_kw)
    state = {
        "params": params,
        "opt": adamw.init_state(params, tc.optimizer),
        "step": jnp.zeros((), jnp.int32),
    }
    specs = {
        "params": pspecs,
        "opt": adamw.state_specs(pspecs),
        "step": (),
    }
    if tc.compress_grads:
        state["ef"] = compression.init_error_feedback(params)
        specs["ef"] = pspecs
    return state, specs


def build_train_step(model_cfg, tc: TrainConfig) -> Callable:
    loss_fn = _loss_for(model_cfg)
    k = tc.microbatches

    def micro_loss(params, mb):
        if isinstance(model_cfg, LMConfig):
            loss, metrics = loss_fn(params, model_cfg, mb, remat=tc.remat)
        else:
            loss, metrics = loss_fn(params, model_cfg, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(micro_loss, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if k > 1:
            if tc.pre_microbatched:
                micro = batch
            else:
                micro = jax.tree.map(
                    lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch
                )

            def body(carry, mb):
                gacc, lacc = carry
                (loss, _), grads = grad_fn(params, mb)
                gacc = jax.tree.map(jnp.add, gacc, grads)
                return (gacc, lacc + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(body, (zeros, jnp.float32(0.0)), micro)
            grads = jax.tree.map(lambda g: g / k, grads)
            loss = loss_sum / k
        else:
            (loss, _), grads = grad_fn(params, batch)

        new_state = dict(state)
        if tc.compress_grads:
            grads, new_state["ef"] = compression.compress_grads(grads, state["ef"])

        lr_scale = schedules.warmup_cosine(
            state["step"], warmup_steps=tc.warmup_steps, total_steps=tc.total_steps
        )
        new_params, new_opt, om = adamw.update(
            grads, state["opt"], params, tc.optimizer, lr_scale=lr_scale
        )
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        new_state["step"] = state["step"] + 1
        metrics = {"loss": loss, "lr_scale": lr_scale, **om}
        return new_state, metrics

    return train_step

from repro.train.step import TrainConfig, build_train_step, init_state  # noqa: F401
from repro.train import trainer  # noqa: F401

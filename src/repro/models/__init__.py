"""Model zoo: the assigned architectures as composable JAX modules.

  transformer — GQA/MLA attention, dense/MoE MLP, MTP (all 5 LM archs)
  gnn         — PNA / GraphSAGE / GIN / GAT (segment-op message passing)
  bert4rec    — bidirectional sequential recommender
"""
from repro.models import common, transformer, gnn, bert4rec  # noqa: F401

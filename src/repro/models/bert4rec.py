"""BERT4Rec — bidirectional self-attention sequential recommender
(Sun et al., arXiv:1904.06690).

Item embedding table (the recsys-scale sparse state, row-sharded over the
model axis) + learned positional embeddings + N bidirectional transformer
blocks (post-LN, GELU FFN, per the paper) + tied output projection.

Training: masked-item prediction (Cloze). Serving:
  serve scoring   — logits over the full catalog for the next position
  retrieval_cand  — one user vs n_candidates item embeddings: a single
                    [1, D] x [D, C] matmul, candidates sharded over model
                    (never a loop).
"""
from __future__ import annotations

from typing import Any, Dict

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import RecsysConfig
from repro.models import common
from repro.models.common import dense_init
from repro.sharding import constrain
from repro.kernels import ops as kops

MASK_OFFSET = 1  # item id 0 = padding; vocab row n_items+1 = [MASK]


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def init(rng, cfg: RecsysConfig):
    d = cfg.embed_dim
    dt = _dt(cfg)
    ks = jax.random.split(rng, 2 + cfg.n_blocks)
    params: Dict[str, Any] = {
        "items": jax.random.normal(ks[0], (cfg.n_items + 2, d), dt) * 0.02,
        "pos": jax.random.normal(ks[1], (cfg.seq_len, d), dt) * 0.02,
        "out_bias": jnp.zeros((cfg.n_items + 2,), dt),
        "blocks": [],
    }
    specs: Dict[str, Any] = {
        "items": ("item", "table_dim"),
        "pos": (None, None),
        "out_bias": ("item",),
        "blocks": [],
    }
    for i in range(cfg.n_blocks):
        kk = jax.random.split(ks[2 + i], 6)
        p = {
            "wq": dense_init(kk[0], d, d, dtype=dt), "wk": dense_init(kk[1], d, d, dtype=dt),
            "wv": dense_init(kk[2], d, d, dtype=dt), "wo": dense_init(kk[3], d, d, dtype=dt),
            "ln1_g": jnp.ones((d,), dt), "ln1_b": jnp.zeros((d,), dt),
            "w_in": dense_init(kk[4], d, 4 * d, dtype=dt), "b_in": jnp.zeros((4 * d,), dt),
            "w_out": dense_init(kk[5], 4 * d, d, dtype=dt), "b_out": jnp.zeros((d,), dt),
            "ln2_g": jnp.ones((d,), dt), "ln2_b": jnp.zeros((d,), dt),
        }
        s = {
            "wq": ("embed", "heads"), "wk": ("embed", "heads"),
            "wv": ("embed", "heads"), "wo": ("heads", "embed"),
            "ln1_g": (None,), "ln1_b": (None,),
            "w_in": ("embed", "ff"), "b_in": ("ff",),
            "w_out": ("ff", "embed"), "b_out": (None,),
            "ln2_g": (None,), "ln2_b": (None,),
        }
        params["blocks"].append(p)
        specs["blocks"].append(s)
    return params, specs


def _block(p, cfg: RecsysConfig, x, pad_mask):
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    q = (x @ p["wq"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(hd)
    logits = jnp.where(pad_mask[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32)).astype(x.dtype)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
    x = common.layer_norm(x + o @ p["wo"], p["ln1_g"], p["ln1_b"])
    y = common.gelu(x @ p["w_in"] + p["b_in"]) @ p["w_out"] + p["b_out"]
    return common.layer_norm(x + y, p["ln2_g"], p["ln2_b"])


def encode(params, cfg: RecsysConfig, item_ids):
    """item_ids int32[B, S] (0 = pad) -> hidden [B, S, D]."""
    pad_mask = item_ids > 0
    x = jnp.take(params["items"], item_ids, axis=0) + params["pos"][None]
    x = constrain(x, "batch", None, None)
    for p in params["blocks"]:
        x = _block(p, cfg, x, pad_mask)
    return x


def logits_all_items(params, cfg: RecsysConfig, h):
    out = h @ params["items"].T + params["out_bias"]
    return constrain(out, "batch", None, "act_heads")


def loss_fn(params, cfg: RecsysConfig, batch):
    """Cloze objective: batch = {items [B,S], labels [B,S], mlm_mask [B,S]}.

    Full-catalog softmax is the paper-faithful objective (BERT4Rec evaluated
    catalogs <= 300k items). At 10^6-row tables the [B,S,V] logits tensor is
    the memory roofline (§Perf): fused_ce streams it in chunks (exact),
    n_negatives switches to sampled softmax with shared negatives (the
    industry-standard approximation for 10^6+ catalogs)."""
    h = encode(params, cfg, batch["items"])
    labels, mask = batch["labels"], batch["mlm_mask"]
    if cfg.n_negatives:
        # shared-negative sampled softmax: deterministic per-batch negatives
        # drawn from a hash of the batch contents (stateless, SPMD-friendly)
        seed = jnp.sum(batch["items"].astype(jnp.uint32)) % jnp.uint32(2**31 - 1)
        key = jax.random.fold_in(jax.random.key(0), seed)
        negs = jax.random.randint(
            key, (cfg.n_negatives,), 1, cfg.n_items + 1)        # [N]
        t = labels.size
        hf = h.reshape(t, -1)
        emb_pos = jnp.take(params["items"], labels.reshape(-1), axis=0)  # [T, D]
        pos = (jnp.sum(hf.astype(jnp.float32) * emb_pos.astype(jnp.float32), -1)
               + jnp.take(params["out_bias"], labels.reshape(-1)).astype(jnp.float32))
        emb_neg = jnp.take(params["items"], negs, axis=0)       # [N, D]
        neg = (hf.astype(jnp.float32) @ emb_neg.T.astype(jnp.float32)
               + jnp.take(params["out_bias"], negs).astype(jnp.float32))  # [T, N]
        logz = jax.nn.logsumexp(
            jnp.concatenate([pos[:, None], neg], axis=1), axis=-1)
        nll = logz - pos
        mk = mask.reshape(-1).astype(jnp.float32)
        loss = jnp.sum(nll * mk) / jnp.maximum(jnp.sum(mk), 1.0)
    elif cfg.fused_ce:
        head = jnp.concatenate(
            [params["items"].T,
             params["out_bias"][None, :].astype(params["items"].dtype)], axis=0)
        ones = jnp.ones(h.shape[:-1] + (1,), h.dtype)
        loss = common.blockwise_cross_entropy(
            jnp.concatenate([h, ones], axis=-1), head, labels, mask,
            block=cfg.fused_ce)
    else:
        logits = logits_all_items(params, cfg, h)
        loss = common.cross_entropy(logits, labels, mask)
    return loss, {"ce": loss}


def serve_scores(params, cfg: RecsysConfig, item_ids):
    """Next-item logits over the full catalog from the last position."""
    h = encode(params, cfg, item_ids)
    return logits_all_items(params, cfg, h[:, -1])


def retrieval_scores(params, cfg: RecsysConfig, item_ids, candidate_ids):
    """One (or few) user(s) vs a large candidate set.

    item_ids [B, S]; candidate_ids int32[C]. The candidate embedding gather
    routes through the embedding_bag kernel path on TPU (bags of size 1), and
    the scoring is a single [B, D] x [D, C] matmul sharded over model."""
    h = encode(params, cfg, item_ids)[:, -1]                        # [B, D]
    cand = kops.embedding_bag(
        params["items"], candidate_ids[:, None],
        jnp.ones((candidate_ids.shape[0], 1), jnp.float32),
    )                                                               # [C, D]
    cand = constrain(cand, "candidates", None)
    return h.astype(jnp.float32) @ cand.T.astype(jnp.float32) + jnp.take(
        params["out_bias"], candidate_ids
    ).astype(jnp.float32)

"""GNN architectures: PNA, GraphSAGE, GIN, GAT.

All message passing is `jnp.take` over edge endpoints + `jax.ops.segment_*`
by destination (JAX sparse is BCOO-only — the segment-op formulation IS the
system, per the assignment). Two input regimes:

  full-graph   batch = {x [n,F], src [m], dst [m]}  (dst need not be sorted)
  sampled      batch = {x_self [B,F], x_nbr [B,f1,F], x_nbr2 [B,f1,f2,F]}
               (GraphSAGE minibatch_lg; the dense fanout tensors route
               through the fused `segment_agg` Pallas kernel)

Batched small graphs (molecule) are block-diagonal: the same full-graph code
runs unchanged on the concatenated node/edge arrays.

These models are also the integration point for the paper's technique: the
pattern-matching engine prunes the background graph to the solution subgraph
G*, and the GNN trains on the pruned graph (see examples/pattern_gnn.py).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig
from repro.graph import segment_ops
from repro.models.common import dense_init
from repro.kernels import ops as kops


def _mlp_init(rng, d_in, d_hidden, d_out, dtype=jnp.float32):
    k1, k2 = jax.random.split(rng)
    return {
        "w1": dense_init(k1, d_in, d_hidden, dtype=dtype),
        "b1": jnp.zeros((d_hidden,), dtype),
        "w2": dense_init(k2, d_hidden, d_out, dtype=dtype),
        "b2": jnp.zeros((d_out,), dtype),
    }


def _mlp_spec():
    return {"w1": ("feat", None), "b1": (None,), "w2": (None, "feat"), "b2": (None,)}


def _mlp(p, x):
    return jax.nn.relu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


# --------------------------------------------------------------------- init
def init(rng, cfg: GNNConfig, d_in: int, n_classes: int):
    keys = jax.random.split(rng, cfg.n_layers + 1)
    layers, lspecs = [], []
    d_prev = d_in
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        d_out = cfg.d_hidden
        if cfg.model == "graphsage":
            p = {"w_self": dense_init(keys[i], d_prev, d_out),
                 "w_nbr": dense_init(jax.random.fold_in(keys[i], 1), d_prev, d_out)}
            s = {"w_self": ("feat", None), "w_nbr": ("feat", None)}
        elif cfg.model == "gin":
            p = {"mlp": _mlp_init(keys[i], d_prev, d_out, d_out),
                 "eps": jnp.zeros(()) if cfg.eps_learnable else None}
            p = {k: v for k, v in p.items() if v is not None}
            s = {"mlp": _mlp_spec()}
            if cfg.eps_learnable:
                s["eps"] = ()
        elif cfg.model == "gat":
            h = cfg.n_heads
            p = {"w": dense_init(keys[i], d_prev, h * d_out),
                 "a_src": jax.random.normal(jax.random.fold_in(keys[i], 1), (h, d_out)) * 0.1,
                 "a_dst": jax.random.normal(jax.random.fold_in(keys[i], 2), (h, d_out)) * 0.1}
            s = {"w": ("feat", None), "a_src": (None, None), "a_dst": (None, None)}
            d_prev = h * d_out if not last else d_out
        elif cfg.model == "pna":
            n_in = d_prev * len(cfg.aggregators) * len(cfg.scalers) + d_prev
            p = {"w": dense_init(keys[i], n_in, d_out), "b": jnp.zeros((d_out,))}
            s = {"w": ("feat", None), "b": (None,)}
        else:
            raise ValueError(cfg.model)
        layers.append(p)
        lspecs.append(s)
        if cfg.model != "gat":
            d_prev = d_out
    d_repr = d_prev
    params = {
        "layers": layers,
        "head": {"w": dense_init(keys[-1], d_repr, n_classes),
                 "b": jnp.zeros((n_classes,))},
    }
    specs = {
        "layers": lspecs,
        "head": {"w": ("feat", "classes"), "b": ("classes",)},
    }
    return params, specs


# --------------------------------------------------------- full-graph layers
def _agg_stats(x, src, dst, n):
    """sum / mean / min / max / std by destination (shared by PNA)."""
    msgs = jnp.take(x, src, axis=0)
    s = segment_ops.segment_sum(msgs, dst, n, sorted=False)
    mn = jax.ops.segment_min(msgs, dst, num_segments=n)
    mx = jax.ops.segment_max(msgs, dst, num_segments=n)
    sq = segment_ops.segment_sum(msgs * msgs, dst, n, sorted=False)
    deg = segment_ops.segment_count(dst, n, sorted=False)
    degc = jnp.maximum(deg, 1.0)[:, None]
    mean = s / degc
    # +eps inside sqrt: d/dx sqrt(x) -> inf at 0 would NaN the backward pass
    std = jnp.sqrt(jnp.maximum(sq / degc - mean * mean, 0.0) + 1e-12)
    empty = (deg <= 0)[:, None]
    big = jnp.float32(np.finfo(np.float32).max)
    mn = jnp.where(empty | (mn >= big), 0.0, mn)
    mx = jnp.where(empty | (mx <= -big), 0.0, mx)
    return {"sum": s, "mean": mean, "min": mn, "max": mx, "std": std}, deg


def _pna_layer(p, cfg: GNNConfig, x, src, dst, n, log_deg_avg):
    stats, deg = _agg_stats(x, src, dst, n)
    aggs = [stats[a] for a in cfg.aggregators]
    logd = jnp.log(deg + 1.0)[:, None]
    scaled = []
    for a in aggs:
        for sc in cfg.scalers:
            if sc in ("identity", "id"):
                scaled.append(a)
            elif sc in ("amplification", "amp"):
                scaled.append(a * (logd / log_deg_avg))
            elif sc in ("attenuation", "atten"):
                scaled.append(a * (log_deg_avg / jnp.maximum(logd, 1e-6)))
            else:
                raise ValueError(sc)
    h = jnp.concatenate(scaled + [x], axis=-1)
    return jax.nn.relu(h @ p["w"] + p["b"])


def _sage_layer(p, x, src, dst, n):
    nbr = segment_ops.segment_mean(jnp.take(x, src, axis=0), dst, n, sorted=False)
    return jax.nn.relu(x @ p["w_self"] + nbr @ p["w_nbr"])


def _gin_layer(p, cfg: GNNConfig, x, src, dst, n):
    agg = segment_ops.segment_sum(jnp.take(x, src, axis=0), dst, n, sorted=False)
    eps = p.get("eps", 0.0)
    return _mlp(p["mlp"], (1.0 + eps) * x + agg)


def _gat_layer(p, cfg: GNNConfig, x, src, dst, n, last: bool):
    h, f = cfg.n_heads, p["a_src"].shape[1]
    z = (x @ p["w"]).reshape(n, h, f)
    e_src = jnp.sum(z * p["a_src"], axis=-1)       # [n, H]
    e_dst = jnp.sum(z * p["a_dst"], axis=-1)
    scores = jax.nn.leaky_relu(
        jnp.take(e_src, src, axis=0) + jnp.take(e_dst, dst, axis=0), 0.2)  # [m, H]
    alpha = segment_ops.segment_softmax(scores, dst, n, sorted=False)
    msgs = jnp.take(z, src, axis=0) * alpha[..., None]        # [m, H, F]
    out = segment_ops.segment_sum(msgs, dst, n, sorted=False)  # [n, H, F]
    if last:
        return out.mean(axis=1)                                # average heads
    return jax.nn.elu(out.reshape(n, h * f))


def apply(params, cfg: GNNConfig, batch: Dict[str, Any]):
    """Full-graph forward -> per-node logits [n, n_classes]."""
    x, src, dst = batch["x"], batch["src"], batch["dst"]
    n = x.shape[0]
    log_deg_avg = batch.get("log_deg_avg", 1.0)
    for i, p in enumerate(params["layers"]):
        last = i == len(params["layers"]) - 1
        if cfg.model == "pna":
            x = _pna_layer(p, cfg, x, src, dst, n, log_deg_avg)
        elif cfg.model == "graphsage":
            x = _sage_layer(p, x, src, dst, n)
        elif cfg.model == "gin":
            x = _gin_layer(p, cfg, x, src, dst, n)
        elif cfg.model == "gat":
            x = _gat_layer(p, cfg, x, src, dst, n, last)
    return x @ params["head"]["w"] + params["head"]["b"]


# ------------------------------------------------------- sampled (GraphSAGE)
def apply_sampled(params, cfg: GNNConfig, batch: Dict[str, Any]):
    """Two-layer sampled forward (fanouts f1, f2) — the minibatch_lg regime.

    batch: x_self [B,F], x_nbr [B,f1,F], x_nbr2 [B,f1,f2,F]
           (+ optional masks m_nbr [B,f1], m_nbr2 [B,f1,f2])
    The inner aggregations run through the fused segment_agg kernel path.
    """
    assert cfg.model == "graphsage" and len(params["layers"]) == 2
    x_self, x_nbr, x_nbr2 = batch["x_self"], batch["x_nbr"], batch["x_nbr2"]
    b, f1, f2, d = x_nbr2.shape
    m_nbr = batch.get("m_nbr", jnp.ones((b, f1), bool))
    m_nbr2 = batch.get("m_nbr2", jnp.ones((b, f1, f2), bool))
    l1, l2 = params["layers"]

    # layer 1 on each sampled neighbor: agg its own f2 neighbors
    feats = x_nbr2.reshape(b * f1, f2, d)
    deg2 = jnp.sum(m_nbr2.reshape(b * f1, f2), axis=1).astype(jnp.float32)
    agg2 = kops.neighborhood_agg(feats, m_nbr2.reshape(b * f1, f2), deg2)["mean"]
    h_nbr = jax.nn.relu(
        x_nbr.reshape(b * f1, d) @ l1["w_self"] + agg2 @ l1["w_nbr"]
    ).reshape(b, f1, -1)
    # layer 1 on self: agg direct neighbors' raw features
    deg1 = jnp.sum(m_nbr, axis=1).astype(jnp.float32)
    agg1 = kops.neighborhood_agg(x_nbr, m_nbr, deg1)["mean"]
    h_self = jax.nn.relu(x_self @ l1["w_self"] + agg1 @ l1["w_nbr"])
    # layer 2 on self: agg layer-1 neighbor representations
    aggh = kops.neighborhood_agg(h_nbr, m_nbr, deg1)["mean"]
    h = jax.nn.relu(h_self @ l2["w_self"] + aggh @ l2["w_nbr"])
    return h @ params["head"]["w"] + params["head"]["b"]


def loss_fn(params, cfg: GNNConfig, batch):
    """Node-classification CE; `train_mask` selects supervised nodes."""
    if "x_self" in batch:
        logits = apply_sampled(params, cfg, batch)
    else:
        logits = apply(params, cfg, batch)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = batch.get("train_mask")
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0), {}
    return jnp.mean(nll), {}

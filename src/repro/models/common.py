"""Shared model building blocks (pure functions over param pytrees).

Every `init_*` returns (params, specs) where `specs` mirrors the param tree
with tuples of logical sharding axes (see repro/sharding.py). Models are
plain functions — no framework dependency — so pjit sees the whole program.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp


def dense_init(rng, d_in: int, d_out: int, scale: Optional[float] = None,
               dtype=jnp.float32):
    # float(): a np.float64 scalar is not weak-typed and would promote bf16
    # params to f32; a python float keeps the param dtype.
    scale = float(scale) if scale is not None else float(1.0 / np.sqrt(d_in))
    return jax.random.normal(rng, (d_in, d_out), dtype) * scale


def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def layer_norm(x, gamma, beta, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma + beta


# ------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """x: [..., S, D] with D even; positions: int[..., S] or int[S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # [D/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def causal_mask(s: int) -> jnp.ndarray:
    return jnp.tril(jnp.ones((s, s), dtype=bool))


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token-level CE in fp32. logits [..., V], labels int[...]."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def blockwise_cross_entropy(h: jnp.ndarray, head: jnp.ndarray,
                            labels: jnp.ndarray,
                            mask: Optional[jnp.ndarray] = None,
                            block: int = 8192) -> jnp.ndarray:
    """Fused softmax-CE streamed over vocab blocks (perf path, §Perf).

    Never materializes the [T, V] logits in fp32: a lax.scan over V/block
    chunks carries a running (max, denom, gold-logit) per token — the same
    online-softmax recurrence as flash attention, applied to the loss. h can
    stay bf16; each chunk matmul accumulates in fp32.

    h [..., D], head [D, V], labels int[...]. Returns mean token NLL."""
    d, v = head.shape
    t_shape = labels.shape
    ht = h.reshape(-1, d)
    lab = labels.reshape(-1)
    tn = ht.shape[0]
    nb = -(-v // block)
    pad = nb * block - v
    if pad:
        head = jnp.pad(head, ((0, 0), (0, pad)))
    head_b = head.reshape(d, nb, block).transpose(1, 0, 2)  # [nb, D, block]

    def body(carry, xs):
        m, l, gold = carry
        bi, hb = xs
        logits = jnp.einsum("td,db->tb", ht, hb,
                            preferred_element_type=jnp.float32)
        off = bi * block
        col = jax.lax.broadcasted_iota(jnp.int32, (tn, block), 1) + off
        live = col < v
        logits = jnp.where(live, logits, -1e30)
        m_cur = jnp.max(logits, axis=1)
        m_new = jnp.maximum(m, m_cur)
        l_new = l * jnp.exp(m - m_new) + jnp.sum(
            jnp.exp(logits - m_new[:, None]), axis=1)
        in_blk = (lab >= off) & (lab < off + block)
        idx = jnp.clip(lab - off, 0, block - 1)
        gold_new = gold + jnp.where(
            in_blk, jnp.take_along_axis(logits, idx[:, None], axis=1)[:, 0], 0.0)
        return (m_new, l_new, gold_new), None

    init = (jnp.full((tn,), -1e30, jnp.float32), jnp.zeros((tn,), jnp.float32),
            jnp.zeros((tn,), jnp.float32))
    (m, l, gold), _ = jax.lax.scan(body, init, (jnp.arange(nb), head_b))
    nll = (m + jnp.log(jnp.maximum(l, 1e-30))) - gold
    nll = nll.reshape(t_shape)
    if mask is not None:
        mk = mask.astype(jnp.float32)
        return jnp.sum(nll * mk) / jnp.maximum(jnp.sum(mk), 1.0)
    return jnp.mean(nll)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def swiglu(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down

"""Distributed GNN message passing over the paper's edge partition (§Perf
hillclimb, pna x ogb_products).

Baseline full-graph GNN cells let GSPMD place the segment ops, which lowers
to per-layer all-reduces of full [n, F] node tensors. This module reuses the
engine's HavoqGT-style partition (graph/partition.py): every arc lives on its
source shard, pre-bucketed by destination shard with static padded sizes, so
one `all_to_all` per aggregation sweep moves exactly the per-arc messages and
the reduction happens locally on the destination shard — the same sweep the
bitset engine uses, carrying GNN features instead of omega words.

PNA's 4 aggregators (sum/mean/min/max/std) reuse ONE message exchange: the
payload is sent once and reduced four ways on arrival (the work-aggregation
idea applied to GNN training). Everything is differentiable: gathers,
all_to_all and jax.ops.segment_* all have transposes, so jax.grad works
through the shard_map.

Layout (leading axis = shard):
  x_local        f32[P, n_local, F]
  send_src_local int32[P, P, B]     (n_local = padding sink)
  recv_dst_local int32[P, P*B]      (arrival order; n_local = padding)
  labels/mask    [P, n_local]
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import GNNConfig
from repro.kernels import compat


def aggregate_sweep(x_local, send_src_local, recv_dst_local, n_local, axes,
                    message_dtype=jnp.float32):
    """One message exchange + fused 4-way reduction.

    x_local [n_local, F] -> dict of [n_local, F] aggregates + degree [n_local].
    message_dtype=bf16 halves the wire payload; reductions happen in fp32 on
    arrival (§Perf iteration 2)."""
    f = x_local.shape[-1]
    x_sink = jnp.concatenate([x_local, jnp.zeros((1, f), x_local.dtype)], axis=0)
    msgs = jnp.take(x_sink.astype(message_dtype), send_src_local, axis=0)
    recv = jax.lax.all_to_all(
        msgs.reshape(-1, f), axes, 0, 0, tiled=True).astype(jnp.float32)
    seg = recv_dst_local                                      # [P*B], n_local = pad
    ns = n_local + 1
    valid = (seg < n_local)[:, None]
    big = jnp.float32(3.0e38)
    s = jax.ops.segment_sum(jnp.where(valid, recv, 0.0), seg, num_segments=ns)
    sq = jax.ops.segment_sum(jnp.where(valid, recv * recv, 0.0), seg, num_segments=ns)
    mn = jax.ops.segment_min(jnp.where(valid, recv, big), seg, num_segments=ns)
    mx = jax.ops.segment_max(jnp.where(valid, recv, -big), seg, num_segments=ns)
    deg = jax.ops.segment_sum(valid[:, 0].astype(jnp.float32), seg, num_segments=ns)
    s, sq, mn, mx, deg = s[:-1], sq[:-1], mn[:-1], mx[:-1], deg[:-1]
    degc = jnp.maximum(deg, 1.0)[:, None]
    mean = s / degc
    std = jnp.sqrt(jnp.maximum(sq / degc - mean * mean, 0.0) + 1e-12)
    empty = (deg <= 0)[:, None]
    mn = jnp.where(empty | (mn >= big), 0.0, mn)
    mx = jnp.where(empty | (mx <= -big), 0.0, mx)
    return {"sum": s, "mean": mean, "min": mn, "max": mx, "std": std}, deg


def pna_layer_local(p, cfg: GNNConfig, x_local, aggs, deg, log_deg_avg):
    logd = jnp.log(deg + 1.0)[:, None]
    scaled = []
    for a in cfg.aggregators:
        v = aggs[a]
        for sc in cfg.scalers:
            if sc in ("identity", "id"):
                scaled.append(v)
            elif sc in ("amplification", "amp"):
                scaled.append(v * (logd / log_deg_avg))
            else:
                scaled.append(v * (log_deg_avg / jnp.maximum(logd, 1e-6)))
    h = jnp.concatenate(scaled + [x_local], axis=-1)
    return jax.nn.relu(h @ p["w"] + p["b"])


def build_distributed_pna_loss(cfg: GNNConfig, mesh: Mesh, axes: Tuple[str, ...],
                               n_local: int):
    """Returns loss_fn(params, batch) running under shard_map on `mesh`.

    batch: x [P, n_local, F], send_src_local [P, P, B],
    recv_dst_local [P, P*B], labels [P, n_local], train_mask [P, n_local],
    log_deg_avg f32[].
    """
    spec_shard = P(axes)
    spec_rep = P()

    def local_loss(params, x, send_src_local, recv_dst_local, labels,
                   train_mask, log_deg_avg):
        # shard_map gives local views with the leading P axis of size 1
        x, labels, train_mask = x[0], labels[0], train_mask[0]
        send_src_local, recv_dst_local = send_src_local[0], recv_dst_local[0]
        mdt = jnp.bfloat16 if cfg.message_dtype == "bfloat16" else jnp.float32
        h = x
        for p in params["layers"]:
            aggs, deg = aggregate_sweep(
                h, send_src_local, recv_dst_local, n_local, axes,
                message_dtype=mdt)
            h = pna_layer_local(p, cfg, h, aggs, deg, log_deg_avg)
        logits = h @ params["head"]["w"] + params["head"]["b"]
        logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32), labels[:, None], axis=1)[:, 0]
        mk = train_mask.astype(jnp.float32)
        num = jax.lax.psum(jnp.sum((logz - gold) * mk), axes)
        den = jax.lax.psum(jnp.sum(mk), axes)
        return num / jnp.maximum(den, 1.0)

    sharded = compat.shard_map(
        local_loss,
        mesh=mesh,
        in_specs=(spec_rep, spec_shard, spec_shard, spec_shard, spec_shard,
                  spec_shard, spec_rep),
        out_specs=spec_rep,
        check_vma=False,
    )

    def loss_fn(params, batch):
        loss = sharded(params, batch["x"], batch["send_src_local"],
                       batch["recv_dst_local"], batch["labels"],
                       batch["train_mask"], batch["log_deg_avg"])
        return loss, {}

    return loss_fn


def partitioned_batch_shapes(n: int, m: int, p_shards: int, d_feat: int,
                             pad_multiple: int = 8, skew: float = 2.0) -> Dict:
    """Analytic ShapeDtypeStruct shapes for the dry-run (no data)."""
    n_local = -(-n // p_shards)
    b = -(-int(skew * m / (p_shards * p_shards)) // pad_multiple) * pad_multiple
    return {
        "x": ((p_shards, n_local, d_feat), jnp.float32),
        "send_src_local": ((p_shards, p_shards, b), jnp.int32),
        "recv_dst_local": ((p_shards, p_shards * b), jnp.int32),
        "labels": ((p_shards, n_local), jnp.int32),
        "train_mask": ((p_shards, n_local), jnp.bool_),
        "log_deg_avg": ((), jnp.float32),
    }


def partitioned_batch_from_graph(g, d_feat: int, n_classes: int, p_shards: int,
                                 seed: int = 0) -> Dict:
    """Host-side construction of the partitioned batch (small-graph tests)."""
    from repro.graph.partition import partition_graph
    part = partition_graph(g, p_shards)
    rng = np.random.default_rng(seed)
    n_local = part.n_local
    x = np.zeros((p_shards, n_local, d_feat), np.float32)
    feats = rng.standard_normal((g.n, d_feat)).astype(np.float32)
    ids = np.arange(g.n)
    x[ids // n_local, ids % n_local] = feats
    labels = np.zeros((p_shards, n_local), np.int32)
    labels[ids // n_local, ids % n_local] = g.labels % n_classes
    mask = np.zeros((p_shards, n_local), bool)
    mask[ids // n_local, ids % n_local] = rng.random(g.n) < 0.5
    # arrival-order destination ids: undo the partition's sort permutation
    recv_dst_local = np.stack([
        part.recv_sorted_dst_local[p][_invert(part.recv_perm[p])]
        for p in range(p_shards)
    ]).astype(np.int32)
    deg = g.degrees()
    return {
        "x": jnp.asarray(x),
        "send_src_local": jnp.asarray(part.send_src_local),
        "recv_dst_local": jnp.asarray(recv_dst_local),
        "labels": jnp.asarray(labels),
        "train_mask": jnp.asarray(mask),
        "log_deg_avg": jnp.float32(np.mean(np.log(deg + 1)) + 1e-6),
    }, feats, part


def _invert(perm: np.ndarray) -> np.ndarray:
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.size)
    return inv

"""Decoder-only transformer family covering all five assigned LM archs.

Features (config-selected):
  - GQA attention with RoPE, optional QKV bias (qwen2), qk-norm (qwen3),
    sliding window (starcoder2), LayerNorm or RMSNorm
  - MLA attention (deepseek v2/v3): low-rank q (optional) and kv compression,
    decoupled rope dims; decode uses the *absorbed* formulation over the
    latent cache (the MLA memory win — cache is [S, kv_lora + rope], not
    per-head)
  - dense MLP (gelu / swiglu) or MoE with shared + routed top-k experts,
    sort-based dispatch with static capacity, leading dense layers
  - MTP (deepseek-v3): one extra transformer block predicting token t+2
  - layers stacked for lax.scan (compile time O(1) in depth); params carry a
    parallel tree of logical sharding axes

Pure functions: init(rng, cfg) -> (params, specs); forward / loss_fn /
decode_step consume the param pytree directly.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.models import common
from repro.sharding import constrain
from repro.kernels import ops as kops


def _dt(cfg: LMConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------- init
def _norm_init(cfg, d):
    if cfg.norm == "layernorm":
        return {"g": jnp.ones((d,), _dt(cfg)), "b": jnp.zeros((d,), _dt(cfg))}
    return {"g": jnp.ones((d,), _dt(cfg))}


def _norm_spec(cfg):
    if cfg.norm == "layernorm":
        return {"g": (None,), "b": (None,)}
    return {"g": (None,)}


def _apply_norm(cfg, p, x):
    if cfg.norm == "layernorm":
        return common.layer_norm(x, p["g"], p["b"], cfg.norm_eps)
    return common.rms_norm(x, p["g"], cfg.norm_eps)


def _attn_init(rng, cfg: LMConfig):
    d, hd = cfg.d_model, cfg.hd
    dt = _dt(cfg)
    ks = jax.random.split(rng, 8)
    if cfg.attention == "mla":
        qk_dim = cfg.qk_nope_dim + cfg.qk_rope_dim
        p: Dict[str, Any] = {}
        s: Dict[str, Any] = {}
        if cfg.q_lora_rank:
            p["wq_a"] = common.dense_init(ks[0], d, cfg.q_lora_rank, dtype=dt)
            p["q_a_norm"] = jnp.ones((cfg.q_lora_rank,), dt)
            p["wq_b"] = common.dense_init(ks[1], cfg.q_lora_rank, cfg.n_heads * qk_dim, dtype=dt)
            s.update({"wq_a": ("embed", None), "q_a_norm": (None,),
                      "wq_b": (None, "heads")})
        else:
            p["wq"] = common.dense_init(ks[0], d, cfg.n_heads * qk_dim, dtype=dt)
            s["wq"] = ("embed", "heads")
        p["wkv_a"] = common.dense_init(ks[2], d, cfg.kv_lora_rank + cfg.qk_rope_dim, dtype=dt)
        p["kv_a_norm"] = jnp.ones((cfg.kv_lora_rank,), dt)
        p["wkv_b"] = common.dense_init(
            ks[3], cfg.kv_lora_rank, cfg.n_heads * (cfg.qk_nope_dim + cfg.v_head_dim), dtype=dt)
        p["wo"] = common.dense_init(ks[4], cfg.n_heads * cfg.v_head_dim, d, dtype=dt)
        s.update({
            "wkv_a": ("embed", None), "kv_a_norm": (None,),
            "wkv_b": (None, "heads"), "wo": ("heads", "embed"),
        })
        return p, s
    p = {
        "wq": common.dense_init(ks[0], d, cfg.n_heads * hd, dtype=dt),
        "wk": common.dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype=dt),
        "wv": common.dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype=dt),
        "wo": common.dense_init(ks[3], cfg.n_heads * hd, d, dtype=dt),
    }
    s = {"wq": ("embed", "heads"), "wk": ("embed", "kv_heads"),
         "wv": ("embed", "kv_heads"), "wo": ("heads", "embed")}
    if cfg.qkv_bias:
        p.update({"bq": jnp.zeros((cfg.n_heads * hd,), dt),
                  "bk": jnp.zeros((cfg.n_kv_heads * hd,), dt),
                  "bv": jnp.zeros((cfg.n_kv_heads * hd,), dt)})
        s.update({"bq": ("heads",), "bk": ("kv_heads",), "bv": ("kv_heads",)})
    if cfg.qk_norm:
        p.update({"q_norm": jnp.ones((hd,), dt), "k_norm": jnp.ones((hd,), dt)})
        s.update({"q_norm": (None,), "k_norm": (None,)})
    return p, s


def _mlp_init(rng, cfg: LMConfig, d_ff: int):
    d = cfg.d_model
    dt = _dt(cfg)
    k1, k2, k3 = jax.random.split(rng, 3)
    if cfg.mlp == "gelu":
        p = {"w_in": common.dense_init(k1, d, d_ff, dtype=dt),
             "b_in": jnp.zeros((d_ff,), dt),
             "w_out": common.dense_init(k2, d_ff, d, dtype=dt),
             "b_out": jnp.zeros((d,), dt)}
        s = {"w_in": ("embed", "ff"), "b_in": ("ff",),
             "w_out": ("ff", "embed"), "b_out": (None,)}
    else:
        p = {"w_gate": common.dense_init(k1, d, d_ff, dtype=dt),
             "w_up": common.dense_init(k2, d, d_ff, dtype=dt),
             "w_down": common.dense_init(k3, d_ff, d, dtype=dt)}
        s = {"w_gate": ("embed", "ff"), "w_up": ("embed", "ff"),
             "w_down": ("ff", "embed")}
    return p, s


def _moe_init(rng, cfg: LMConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_routed
    dt = _dt(cfg)
    ks = jax.random.split(rng, 5)
    p = {
        "router": common.dense_init(ks[0], d, e, dtype=jnp.float32),
        "w_gate": jax.random.normal(ks[1], (e, d, f), dt) / float(np.sqrt(d)),
        "w_up": jax.random.normal(ks[2], (e, d, f), dt) / float(np.sqrt(d)),
        "w_down": jax.random.normal(ks[3], (e, f, d), dt) / float(np.sqrt(f)),
    }
    s = {
        "router": ("embed", None),
        "w_gate": ("expert", "expert_embed", None),
        "w_up": ("expert", "expert_embed", None),
        "w_down": ("expert", None, "expert_embed"),
    }
    if cfg.n_shared:
        sp, ss = _mlp_init(ks[4], cfg, cfg.n_shared * f)
        p["shared"], s["shared"] = sp, ss
    return p, s


def _layer_init(rng, cfg: LMConfig, moe: bool):
    k1, k2 = jax.random.split(rng)
    attn_p, attn_s = _attn_init(k1, cfg)
    if moe:
        mlp_p, mlp_s = _moe_init(k2, cfg)
    else:
        d_ff = (cfg.dense_d_ff or cfg.d_ff) if cfg.moe else cfg.d_ff
        mlp_p, mlp_s = _mlp_init(k2, cfg, d_ff)
    p = {"ln1": _norm_init(cfg, cfg.d_model), "attn": attn_p,
         "ln2": _norm_init(cfg, cfg.d_model), "mlp": mlp_p}
    s = {"ln1": _norm_spec(cfg), "attn": attn_s,
         "ln2": _norm_spec(cfg), "mlp": mlp_s}
    return p, s


def _stack(rng, cfg, n, moe):
    """n layers with stacked (scan-ready) params."""
    keys = jax.random.split(rng, max(n, 1))
    layers = [_layer_init(keys[i], cfg, moe) for i in range(n)]
    p = jax.tree.map(lambda *xs: jnp.stack(xs), *[l[0] for l in layers])
    s = jax.tree.map(
        lambda spec: (None,) + spec,
        layers[0][1],
        is_leaf=lambda x: isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x),
    )
    return p, s


def init(rng, cfg: LMConfig):
    dt = _dt(cfg)
    ks = jax.random.split(rng, 6)
    params: Dict[str, Any] = {
        "embed": jax.random.normal(ks[0], (cfg.vocab, cfg.d_model), dt) * 0.02,
        "final_norm": _norm_init(cfg, cfg.d_model),
    }
    specs: Dict[str, Any] = {
        "embed": ("vocab", "embed"),
        "final_norm": _norm_spec(cfg),
    }
    n_dense = cfg.first_dense_layers if cfg.moe else cfg.n_layers
    n_moe = cfg.n_layers - n_dense if cfg.moe else 0
    if n_dense:
        params["dense_layers"], specs["dense_layers"] = _stack(ks[1], cfg, n_dense, moe=False)
    if n_moe:
        params["moe_layers"], specs["moe_layers"] = _stack(ks[2], cfg, n_moe, moe=True)
    if not cfg.tie_embeddings:
        params["lm_head"] = common.dense_init(ks[3], cfg.d_model, cfg.vocab, dtype=dt)
        specs["lm_head"] = ("embed", "vocab")
    if cfg.mtp:
        mtp_layer_p, mtp_layer_s = _layer_init(ks[4], cfg, moe=False)
        params["mtp"] = {
            "proj": common.dense_init(ks[5], 2 * cfg.d_model, cfg.d_model, dtype=dt),
            "norm_h": _norm_init(cfg, cfg.d_model),
            "norm_e": _norm_init(cfg, cfg.d_model),
            "layer": mtp_layer_p,
        }
        specs["mtp"] = {
            "proj": ("embed", None), "norm_h": _norm_spec(cfg),
            "norm_e": _norm_spec(cfg), "layer": mtp_layer_s,
        }
    return params, specs


# ------------------------------------------------------------------ attention
def _split_heads(x, n_heads, hd):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)  # [B, H, S, hd]


def _gqa_attention(p, cfg: LMConfig, x, positions):
    b, s, d = x.shape
    hd = cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = _split_heads(q, cfg.n_heads, hd)
    k = _split_heads(k, cfg.n_kv_heads, hd)
    v = _split_heads(v, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = common.rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = common.apply_rope(q, positions[:, None, :], cfg.rope_theta)
    k = common.apply_rope(k, positions[:, None, :], cfg.rope_theta)
    q = constrain(q, "batch", "act_heads", None, None)
    o = kops.attention(q, k, v, causal=True, window=cfg.window)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * hd)
    return o @ p["wo"]


def _mla_qkv(p, cfg: LMConfig, x, positions):
    """Returns q_nope, q_rope, k_nope, k_rope, v (full, training/prefill)."""
    b, s, _ = x.shape
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    if cfg.q_lora_rank:
        cq = common.rms_norm(x @ p["wq_a"], p["q_a_norm"], cfg.norm_eps)
        q = cq @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(b, s, h, dn + dr).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    kv_a = x @ p["wkv_a"]                                   # [B, S, kv_lora + dr]
    c_kv = common.rms_norm(kv_a[..., :cfg.kv_lora_rank], p["kv_a_norm"], cfg.norm_eps)
    k_rope = kv_a[..., cfg.kv_lora_rank:][:, None]          # [B, 1, S, dr] shared
    kv = (c_kv @ p["wkv_b"]).reshape(b, s, h, dn + dv).transpose(0, 2, 1, 3)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    q_rope = common.apply_rope(q_rope, positions[:, None, :], cfg.rope_theta)
    k_rope = common.apply_rope(k_rope, positions[:, None, :], cfg.rope_theta)
    return q_nope, q_rope, k_nope, k_rope, v, c_kv


def _mla_attention(p, cfg: LMConfig, x, positions):
    b, s, d = x.shape
    q_nope, q_rope, k_nope, k_rope, v, _ = _mla_qkv(p, cfg, x, positions)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:-1] + (cfg.qk_rope_dim,))], axis=-1)
    q = constrain(q, "batch", "act_heads", None, None)
    o = kops.attention(q, k, v, causal=True, window=None)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.v_head_dim)
    return o @ p["wo"]


def _attention(p, cfg, x, positions):
    if cfg.attention == "mla":
        return _mla_attention(p, cfg, x, positions)
    return _gqa_attention(p, cfg, x, positions)


# ------------------------------------------------------------------------ MoE
def moe_dispatch(x2d: jnp.ndarray, router: jnp.ndarray, cfg: LMConfig,
                 dropless: bool = False):
    """Sort-based top-k dispatch with static capacity.

    dropless=True sizes every expert for the worst case (capacity = T) — used
    by the decode path, where a capacity drop would silently corrupt a user's
    token (training tolerates drops; serving must not).

    Returns (slot int32[T*k], token_of int32[T*k], keep bool[T*k],
    gate f32[T*k], aux_loss, capacity)."""
    t = x2d.shape[0]
    e, k = cfg.n_routed, cfg.top_k
    logits = (x2d.astype(jnp.float32) @ router)             # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                  # [T, k]
    top_p = top_p / jnp.maximum(jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    inv = jnp.mean(probs, axis=0)
    frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i, e, dtype=jnp.float32), axis=1), axis=0) / k
    aux = e * jnp.sum(frac * inv)
    flat_e = top_i.reshape(-1)                               # [T*k]
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    token_of = order // k
    capacity = t if dropless else int(np.ceil(t * k / e * cfg.capacity_factor))
    starts = jnp.searchsorted(sorted_e, jnp.arange(e, dtype=sorted_e.dtype))
    pos_in_e = jnp.arange(t * k) - starts[sorted_e]
    keep = pos_in_e < capacity
    # dropped entries go to a dedicated trash slot (e*capacity) — writing them
    # to a clipped in-range slot would clobber a kept token's buffer row
    slot = jnp.where(keep, sorted_e * capacity + jnp.clip(pos_in_e, 0, capacity - 1),
                     e * capacity)
    gate = top_p.reshape(-1)[order]
    return slot, token_of, keep, gate, aux, capacity


def _moe_block_grouped(p, cfg: LMConfig, x2d: jnp.ndarray):
    """Per-DP-group dispatch (perf path, EXPERIMENTS.md §Perf iteration 1).

    Tokens are grouped [G, T/G] with G sharded over (pod, data); sort /
    capacity / scatter / gather run *within* each group (vmapped — local per
    shard, no collectives), and the only cross-device movement of expert
    inputs is the canonical MoE all-to-all produced by resharding
    [G@dp, E, C, D] -> [E@model, G@dp, C, D]. With moe_gather_weights the
    expert weights are all-gathered over their FSDP axis at use (ZeRO-3), so
    the expert einsums contract unsharded dims locally."""
    t, d = x2d.shape
    e, f = cfg.n_routed, cfg.d_ff
    g = cfg.moe_groups
    tl = t // g
    xg = constrain(x2d.reshape(g, tl, d), "act_tokens", None, None)

    def dispatch_one(xb):
        slot, token_of, keep, gate, aux, cap = moe_dispatch(xb, p["router"], cfg)
        buf = jnp.zeros((e * cap + 1, d), xb.dtype)
        buf = buf.at[slot].set(jnp.where(keep[:, None], xb[token_of], 0))
        return buf[:-1].reshape(e, cap, d), (slot, token_of, keep, gate), aux

    xe, meta, aux = jax.vmap(dispatch_one)(xg)          # xe [G, E, C, D]
    xe = constrain(xe.transpose(1, 0, 2, 3), "expert", "act_tokens", None, None)

    wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]
    if cfg.moe_gather_weights:
        wg = constrain(wg, "expert", None, None)
        wu = constrain(wu, "expert", None, None)
        wd = constrain(wd, "expert", None, None)
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe, wg)) * jnp.einsum(
        "egcd,edf->egcf", xe, wu)
    ye = jnp.einsum("egcf,efd->egcd", h, wd)            # [E, G, C, D]
    ye = constrain(ye.transpose(1, 0, 2, 3), "act_tokens", None, None, None)

    def combine_one(ye_g, meta_g):
        slot, token_of, keep, gate = meta_g
        flat = jnp.concatenate(
            [ye_g.reshape(e * ye_g.shape[1], d), jnp.zeros((1, d), ye_g.dtype)])
        contrib = flat[slot] * (gate * keep)[:, None].astype(ye_g.dtype)
        return jax.ops.segment_sum(contrib, token_of, num_segments=tl)

    y = jax.vmap(combine_one)(ye, meta).reshape(t, d)
    y = constrain(y, "act_tokens", None)
    if cfg.n_shared:
        sp = p["shared"]
        hidden = constrain(
            jax.nn.silu(x2d @ sp["w_gate"]) * (x2d @ sp["w_up"]),
            "act_tokens", "act_ff")
        y = y + hidden @ sp["w_down"]
    return y, jnp.mean(aux)


def _moe_block(p, cfg: LMConfig, x2d: jnp.ndarray, dropless: bool = False):
    if cfg.moe_groups > 1 and not dropless and x2d.shape[0] % cfg.moe_groups == 0:
        return _moe_block_grouped(p, cfg, x2d)
    t, d = x2d.shape
    e, f = cfg.n_routed, cfg.d_ff
    x2d = constrain(x2d, "act_tokens", None)
    slot, token_of, keep, gate, aux, capacity = moe_dispatch(
        x2d, p["router"], cfg, dropless=dropless)
    buf = jnp.zeros((e * capacity + 1, d), x2d.dtype)  # +1 trash slot for drops
    buf = buf.at[slot].set(jnp.where(keep[:, None], x2d[token_of], 0))
    xe = buf[:-1].reshape(e, capacity, d)
    xe = constrain(xe, "expert", None, None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * jnp.einsum(
        "ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(e * capacity, d)
    contrib = ye[slot] * (gate * keep)[:, None].astype(ye.dtype)
    y = jax.ops.segment_sum(contrib, token_of, num_segments=t)
    y = constrain(y, "act_tokens", None)
    if cfg.n_shared:
        sp = p["shared"]
        hidden = constrain(
            jax.nn.silu(x2d @ sp["w_gate"]) * (x2d @ sp["w_up"]),
            "act_tokens", "act_ff")
        y = y + hidden @ sp["w_down"]
    return y, aux


# ---------------------------------------------------------------------- block
def _block(p, cfg: LMConfig, x, positions, moe: bool):
    h = x + _attention(p["attn"], cfg, _apply_norm(cfg, p["ln1"], x), positions)
    h = constrain(h, "batch", None, None)
    hn = _apply_norm(cfg, p["ln2"], h)
    if moe:
        b, s, d = hn.shape
        y, aux = _moe_block(p["mlp"], cfg, hn.reshape(b * s, d))
        y = y.reshape(b, s, d)
    else:
        mp = p["mlp"]
        if cfg.mlp == "gelu" and "w_in" in mp:
            # Megatron pairing: w_in column-parallel, w_out row-parallel
            hidden = constrain(common.gelu(hn @ mp["w_in"] + mp["b_in"]),
                               "batch", None, "act_ff")
            y = hidden @ mp["w_out"] + mp["b_out"]
        else:
            hidden = constrain(
                jax.nn.silu(hn @ mp["w_gate"]) * (hn @ mp["w_up"]),
                "batch", None, "act_ff")
            y = hidden @ mp["w_down"]
        aux = jnp.float32(0.0)
    return constrain(h + y, "batch", None, None), aux


# -------------------------------------------------------------------- forward
def forward_hidden(params, cfg: LMConfig, tokens, positions=None, remat: bool = False):
    """Token ids -> final hidden states [B, S, D] (+ router aux loss)."""
    b, s = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "batch", None, None)
    aux_total = jnp.float32(0.0)

    def run_stack(x, aux_total, stack, moe):
        def body(carry, lp):
            h, aux = carry
            h2, a = _block(lp, cfg, h, positions, moe)
            return (h2, aux + a), None
        if remat == "dots" or remat == "dots_with_no_batch_dims":
            body = jax.checkpoint(
                body, prevent_cse=False,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        elif remat:  # full remat: save only the layer boundaries
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), stack)
        return x, aux_total

    if "dense_layers" in params:
        x, aux_total = run_stack(x, aux_total, params["dense_layers"], moe=False)
    if "moe_layers" in params:
        x, aux_total = run_stack(x, aux_total, params["moe_layers"], moe=True)
    x = _apply_norm(cfg, params["final_norm"], x)
    return x, aux_total


def logits_from_hidden(params, cfg: LMConfig, h):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = h @ head
    return constrain(logits, "batch", None, "act_heads")


def forward(params, cfg: LMConfig, tokens, positions=None, remat: bool = False):
    h, aux = forward_hidden(params, cfg, tokens, positions, remat)
    return logits_from_hidden(params, cfg, h), aux


def loss_fn(params, cfg: LMConfig, batch, remat: bool = False):
    """Next-token CE (+ MTP head loss + router aux)."""
    tokens, labels = batch["tokens"], batch["labels"]
    h, aux = forward_hidden(params, cfg, tokens, remat=remat)
    if cfg.fused_ce:
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        loss = common.blockwise_cross_entropy(
            h, head, labels, batch.get("mask"), block=cfg.fused_ce)
    else:
        logits = logits_from_hidden(params, cfg, h)
        loss = common.cross_entropy(logits, labels, batch.get("mask"))
    if cfg.mtp and "mtp" in params:
        mp = params["mtp"]
        # predict t+2: combine h_t with the embedding of the (t+1) label
        emb_next = jnp.take(params["embed"], labels, axis=0)
        comb = jnp.concatenate(
            [_apply_norm(cfg, mp["norm_h"], h), _apply_norm(cfg, mp["norm_e"], emb_next)],
            axis=-1) @ mp["proj"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        h2, _ = _block(mp["layer"], cfg, comb, positions, moe=False)
        logits2 = logits_from_hidden(params, cfg, _apply_norm(cfg, params["final_norm"], h2))
        labels2 = jnp.roll(labels, -1, axis=1)
        mask2 = jnp.ones_like(labels2, jnp.float32).at[:, -1:].set(0.0)
        loss = loss + 0.3 * common.cross_entropy(logits2, labels2, mask2)
    return loss + cfg.router_aux_coef * aux, {"ce": loss, "aux": aux}


# --------------------------------------------------------------------- decode
def init_cache(cfg: LMConfig, batch: int, max_seq: int):
    """KV cache pytree. GQA: per-head k/v (ring buffer when windowed);
    MLA: latent c_kv + shared k_rope only."""
    dt = _dt(cfg)
    if cfg.attention == "mla":
        per_layer = {
            "ckv": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dt),
            "kr": jnp.zeros((batch, max_seq, cfg.qk_rope_dim), dt),
        }
    else:
        s_cache = min(max_seq, cfg.window) if cfg.window else max_seq
        per_layer = {
            "k": jnp.zeros((batch, cfg.n_kv_heads, s_cache, cfg.hd), dt),
            "v": jnp.zeros((batch, cfg.n_kv_heads, s_cache, cfg.hd), dt),
        }
    return {
        "layers": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), per_layer
        ),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_specs(cfg: LMConfig):
    if cfg.attention == "mla":
        per_layer = {"ckv": (None, "batch", None, None), "kr": (None, "batch", None, None)}
    else:
        per_layer = {"k": (None, "batch", "kv_heads", None, None),
                     "v": (None, "batch", "kv_heads", None, None)}
    return {"layers": per_layer, "pos": ()}


def _gqa_decode_layer(p, cfg, x, kcache, vcache, pos):
    """x: [B, 1, D]. Returns (out [B, 1, D], k_new, v_new)."""
    b = x.shape[0]
    hd = cfg.hd
    s_cache = kcache.shape[2]
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = _split_heads(q, cfg.n_heads, hd)       # [B, H, 1, hd]
    k = _split_heads(k, cfg.n_kv_heads, hd)
    v = _split_heads(v, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = common.rms_norm(k, p["k_norm"], cfg.norm_eps)
    posb = jnp.full((b, 1, 1), pos, jnp.int32)
    q = common.apply_rope(q, posb, cfg.rope_theta)
    k = common.apply_rope(k, posb, cfg.rope_theta)
    write = pos % s_cache if cfg.window else pos
    kcache = jax.lax.dynamic_update_slice(kcache, k, (0, 0, write, 0))
    vcache = jax.lax.dynamic_update_slice(vcache, v, (0, 0, write, 0))
    # GQA: fold the group into the q batch for a single matvec
    group = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(b, cfg.n_kv_heads, group, hd)
    scores = jnp.einsum("bkgd,bksd->bkgs", qg.astype(jnp.float32),
                        kcache.astype(jnp.float32)) / np.sqrt(hd)
    idx = jnp.arange(s_cache)
    if cfg.window:
        age = pos - jnp.where(idx <= pos % s_cache, pos - pos % s_cache + idx,
                              pos - pos % s_cache - s_cache + idx)
        valid = (age >= 0) & (age < cfg.window) & (idx < jnp.minimum(pos + 1, s_cache))
    else:
        valid = idx <= pos
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", probs, vcache.astype(jnp.float32))
    o = o.reshape(b, 1, cfg.n_heads * hd).astype(x.dtype)
    return o @ p["wo"], kcache, vcache


def _mla_decode_layer(p, cfg, x, ckv_cache, kr_cache, pos):
    """Absorbed-MLA decode over the latent cache."""
    b = x.shape[0]
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    if cfg.q_lora_rank:
        cq = common.rms_norm(x @ p["wq_a"], p["q_a_norm"], cfg.norm_eps)
        q = cq @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(b, 1, h, dn + dr).transpose(0, 2, 1, 3)    # [B, H, 1, dn+dr]
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    posb = jnp.full((b, 1, 1), pos, jnp.int32)
    q_rope = common.apply_rope(q_rope, posb, cfg.rope_theta)
    kv_a = x @ p["wkv_a"]                                    # [B, 1, r + dr]
    c_new = common.rms_norm(kv_a[..., :r], p["kv_a_norm"], cfg.norm_eps)
    kr_new = common.apply_rope(kv_a[:, None, :, r:], posb, cfg.rope_theta)[:, 0]
    ckv_cache = jax.lax.dynamic_update_slice(ckv_cache, c_new, (0, pos, 0))
    kr_cache = jax.lax.dynamic_update_slice(kr_cache, kr_new, (0, pos, 0))
    # absorb W_uk into q: q_lat[b,h,r] = q_nope . W_uk[r, h, dn]
    wkv_b = p["wkv_b"].reshape(r, h, dn + dv)
    w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, :, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scores = (
        jnp.einsum("bhr,bsr->bhs", q_lat, ckv_cache.astype(jnp.float32))
        + jnp.einsum("bhd,bsd->bhs", q_rope[:, :, 0].astype(jnp.float32),
                     kr_cache.astype(jnp.float32))
    ) / np.sqrt(dn + dr)
    valid = jnp.arange(ckv_cache.shape[1]) <= pos
    scores = jnp.where(valid[None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", probs, ckv_cache.astype(jnp.float32))
    o = jnp.einsum("bhr,rhd->bhd", o_lat, w_uv.astype(jnp.float32))
    o = o.reshape(b, 1, h * dv).astype(x.dtype)
    return o @ p["wo"], ckv_cache, kr_cache


def decode_step(params, cfg: LMConfig, token, cache):
    """One decode step: token int32[B] -> (logits [B, V], new cache)."""
    b = token.shape[0]
    pos = cache["pos"]
    x = jnp.take(params["embed"], token, axis=0)[:, None, :]  # [B, 1, D]
    x = constrain(x, "batch", None, None)
    def layer_step(x, lp, lc, moe):
        hn = _apply_norm(cfg, lp["ln1"], x)
        if cfg.attention == "mla":
            o, c1, c2 = _mla_decode_layer(lp["attn"], cfg, hn, lc["ckv"], lc["kr"], pos)
            new_c = {"ckv": c1, "kr": c2}
        else:
            o, c1, c2 = _gqa_decode_layer(lp["attn"], cfg, hn, lc["k"], lc["v"], pos)
            new_c = {"k": c1, "v": c2}
        h = x + o
        hn2 = _apply_norm(cfg, lp["ln2"], h)
        if moe:
            y, _ = _moe_block(lp["mlp"], cfg, hn2.reshape(b, -1), dropless=True)
            y = y.reshape(b, 1, -1)
        else:
            mp = lp["mlp"]
            if cfg.mlp == "gelu" and "w_in" in mp:
                y = common.gelu(hn2 @ mp["w_in"] + mp["b_in"]) @ mp["w_out"] + mp["b_out"]
            else:
                y = common.swiglu(hn2, mp["w_gate"], mp["w_up"], mp["w_down"])
        return h + y, new_c

    # scan over the dense stack then the moe stack, threading the cache slices
    cache_layers = cache["layers"]
    consumed = 0
    updated_caches = []
    for stack_name, moe in (("dense_layers", False), ("moe_layers", True)):
        if stack_name not in params:
            continue
        stack = params[stack_name]
        n_stack = jax.tree.leaves(stack)[0].shape[0]
        cslice = jax.tree.map(lambda c: c[consumed:consumed + n_stack], cache_layers)

        def body(carry, xs, moe=moe):
            lp, lc = xs
            h, new_c = layer_step(carry, lp, lc, moe)
            return h, new_c

        x, new_cache = jax.lax.scan(body, x, (stack, cslice))
        updated_caches.append(new_cache)
        consumed += n_stack
    new_cache_layers = jax.tree.map(
        lambda *xs: jnp.concatenate(xs, axis=0), *updated_caches
    ) if len(updated_caches) > 1 else updated_caches[0]
    h = _apply_norm(cfg, params["final_norm"], x)
    logits = logits_from_hidden(params, cfg, h)[:, 0]
    return logits, {"layers": new_cache_layers, "pos": pos + 1}

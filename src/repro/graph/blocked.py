"""Block-sparse bitmask adjacency — the TPU-native layout for the LCC/NLCC
edge sweep (`bitset_spmm` kernel).

The paper's hot loop is "for every active arc (u -> v): omega-words of u are
OR-ed into an aggregate at v". On TPU we reformulate the dst-sorted arc sweep
as a *block-sparse boolean matmul*:

  - vertices are grouped in blocks of BN,
  - only nonempty (dst_block, src_block) adjacency blocks are materialized,
    each as a packed bitmask uint32[BN, BN/32] (bit j of row i = arc
    (src_block*BN + j) -> (dst_block*BN + i)),
  - the OR-aggregation  out[v] |= vals[u]  becomes, per block,
    unpack(mask) @ unpack(vals) > 0 on the MXU,
  - *edge elimination* clears bits in the dynamic mask; cleared bits
    contribute the OR identity — exactly the paper's "no messages are sent
    over eliminated edges".

The static structure (block list, per-arc bit coordinates) is host-built once
per graph; the dynamic bitmasks are recomputed on device from the per-arc
active vector with one segment_sum (bits are disjoint, so sum == OR).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class BlockedStructure:
    """Static (per-graph) block structure. Host numpy; small relative to edges."""

    n: int  # original vertex count
    bn: int  # block size (vertices per block)
    n_pad: int  # padded vertex count = n_blocks_v * bn
    pairs: np.ndarray  # int32[nnzb, 2] (dst_block, src_block), sorted
    edge_block: np.ndarray  # int32[m] block index of each arc (dst-sorted arc order)
    edge_word: np.ndarray  # int32[m] flat word index within the mask tensor
    edge_bit: np.ndarray  # uint32[m] bit value (1 << (src % 32))
    row_first: np.ndarray  # bool[nnzb] first block of its dst row
    row_last: np.ndarray  # bool[nnzb] last block of its dst row

    @property
    def nnzb(self) -> int:
        return int(self.pairs.shape[0])

    @property
    def bnw(self) -> int:
        return self.bn // 32

    @property
    def words_per_block(self) -> int:
        return self.bn * self.bnw


def build_blocked_structure(src: np.ndarray, dst: np.ndarray, n: int, bn: int = 256) -> BlockedStructure:
    """Build from dst-sorted arcs. bn must be a multiple of 32 (one lane word)."""
    assert bn % 32 == 0
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    n_blocks_v = max((n + bn - 1) // bn, 1)
    n_pad = n_blocks_v * bn
    db, sb = dst // bn, src // bn
    key = db * n_blocks_v + sb
    order = np.argsort(key, kind="stable")
    uk, first_idx = np.unique(key[order], return_index=True)
    pairs = np.stack([uk // n_blocks_v, uk % n_blocks_v], axis=1).astype(np.int32)
    # per-arc block index (in the original dst-sorted arc order)
    edge_block = np.searchsorted(uk, key).astype(np.int32)
    bnw = bn // 32
    row = (dst % bn).astype(np.int64)
    col = (src % bn).astype(np.int64)
    edge_word = (edge_block.astype(np.int64) * (bn * bnw) + row * bnw + col // 32).astype(np.int64)
    edge_bit = (np.uint32(1) << (col % 32).astype(np.uint32)).astype(np.uint32)
    row_first = np.ones(len(uk), dtype=bool)
    row_first[1:] = pairs[1:, 0] != pairs[:-1, 0]
    row_last = np.ones(len(uk), dtype=bool)
    row_last[:-1] = pairs[1:, 0] != pairs[:-1, 0]
    return BlockedStructure(
        n=n, bn=bn, n_pad=n_pad, pairs=pairs,
        edge_block=edge_block, edge_word=edge_word, edge_bit=edge_bit,
        row_first=row_first, row_last=row_last,
    )


def masks_from_active(bs: BlockedStructure, edge_active: jnp.ndarray) -> jnp.ndarray:
    """Dynamic block bitmasks uint32[nnzb, bn, bnw] from the per-arc active
    vector (dst-sorted order). Bits are disjoint per word, so segment-sum of
    the selected bit values equals the bitwise OR."""
    total_words = bs.nnzb * bs.words_per_block
    bits = jnp.where(edge_active, jnp.asarray(bs.edge_bit), jnp.uint32(0))
    flat = jax.ops.segment_sum(
        bits, jnp.asarray(bs.edge_word, dtype=jnp.int32), num_segments=total_words
    )
    return flat.reshape(bs.nnzb, bs.bn, bs.bnw)


def pad_values(vals: jnp.ndarray, bs: BlockedStructure) -> jnp.ndarray:
    """Pad packed value rows [n, W] -> [n_pad, W]."""
    if vals.shape[0] == bs.n_pad:
        return vals
    pad = bs.n_pad - vals.shape[0]
    return jnp.concatenate([vals, jnp.zeros((pad,) + vals.shape[1:], vals.dtype)], axis=0)

"""Graph substrate: structures, generators, partitioning, sampling, segment ops.

This layer is shared by the paper's pattern-matching engine (repro.core) and the
GNN model family — both are edge-sweep message-passing workloads on TPU.
"""
from repro.graph.structs import Graph, DeviceGraph
from repro.graph.generators import (
    rmat_graph,
    erdos_renyi_graph,
    cycle_graph,
    torus_graph,
    star_graph,
    degree_labels,
    random_labels,
)
from repro.graph.partition import EdgePartition, partition_graph
from repro.graph.sampler import NeighborSampler
from repro.graph.stats import GraphStats, collect_graph_stats
from repro.graph import segment_ops

__all__ = [
    "Graph",
    "DeviceGraph",
    "rmat_graph",
    "erdos_renyi_graph",
    "cycle_graph",
    "torus_graph",
    "star_graph",
    "degree_labels",
    "random_labels",
    "EdgePartition",
    "partition_graph",
    "NeighborSampler",
    "GraphStats",
    "collect_graph_stats",
    "segment_ops",
]

"""Layered uniform neighbor sampler (GraphSAGE-style fanout sampling).

Host-side numpy over CSR — this is the real data-pipeline component feeding
`minibatch_lg` GNN training. Output blocks have *static* shapes (padded) so the
jitted train step compiles once; `block_shapes` gives the same shapes for
dry-runs without touching data.

Block layout for L layers with fanouts (f_1 .. f_L), seed batch size S:
  layer 0 nodes: S seeds
  layer l nodes: S * f_1 * ... * f_l sampled endpoints (with replacement when
                 degree > 0; repeated nodes allowed, exactly like the original
                 GraphSAGE sampler), padded with a sentinel when degree == 0.
Edges between layer l and l-1 are implicit: child i at layer l connects to
parent i // f_l at layer l-1 — a static segment structure, so aggregation in
the model is a plain reshape + mean/max, no scatter needed.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from repro.graph.structs import Graph


class NeighborSampler:
    def __init__(self, g: Graph, fanouts: Sequence[int], seed: int = 0):
        self.fanouts = tuple(int(f) for f in fanouts)
        self.offsets, self.neighbors = g.csr()
        self.n = g.n
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray) -> List[np.ndarray]:
        """Returns [layer0 nodes, layer1 nodes, ...]; layer l has S * prod(f_1..f_l) ids.

        Zero-degree nodes self-sample (their own id), which the models treat as a
        mean over a single self message — standard practice.
        """
        layers = [np.asarray(seeds, dtype=np.int32)]
        for f in self.fanouts:
            parents = layers[-1]
            deg = (self.offsets[parents + 1] - self.offsets[parents]).astype(np.int64)
            r = self.rng.integers(0, 1 << 62, size=(parents.shape[0], f))
            pick = np.where(deg[:, None] > 0, r % np.maximum(deg, 1)[:, None], 0)
            base = self.offsets[parents][:, None]
            idx = base + pick
            sampled = np.where(
                deg[:, None] > 0,
                self.neighbors[np.minimum(idx, self.neighbors.shape[0] - 1)],
                parents[:, None],
            ).astype(np.int32)
            layers.append(sampled.reshape(-1))
        return layers

    def sample_batch(self, batch_size: int) -> List[np.ndarray]:
        seeds = self.rng.integers(0, self.n, size=batch_size).astype(np.int32)
        return self.sample(seeds)


def block_shapes(batch: int, fanouts: Sequence[int]) -> List[Tuple[int]]:
    shapes, size = [], batch
    out = [(size,)]
    for f in fanouts:
        size *= f
        out.append((size,))
    del shapes
    return out

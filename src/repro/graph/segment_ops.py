"""Segment reduction primitives over edge indices.

JAX sparse support is BCOO-only, so message passing here IS the system:
gather endpoint features with `jnp.take`, reduce by destination with
`jax.ops.segment_*`. The pattern-matching engine (bitwise OR over packed
candidate words) and every GNN aggregator route through these.

Bitwise OR has no native XLA scatter combiner, so `segment_or` uses a
*segmented associative scan* over dst-sorted edges with host-precomputed
segment boundaries (static per graph). On TPU the `bitset_spmm` Pallas kernel
replaces this path with a single VMEM-tiled edge sweep.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp


class SegmentMeta(NamedTuple):
    """Static metadata for dst-sorted edge arrays (host-precomputed)."""

    is_start: jnp.ndarray  # bool[m]  edge i starts a new dst segment
    last_edge_of_vertex: jnp.ndarray  # int32[n]  index of v's last in-edge, -1 if none


def build_segment_meta(dst_sorted: np.ndarray, n: int) -> SegmentMeta:
    dst_sorted = np.asarray(dst_sorted)
    m = dst_sorted.shape[0]
    if m == 0:
        return SegmentMeta(
            is_start=jnp.zeros((0,), bool),
            last_edge_of_vertex=jnp.full((n,), -1, jnp.int32),
        )
    is_start = np.ones(m, dtype=bool)
    is_start[1:] = dst_sorted[1:] != dst_sorted[:-1]
    last = np.full(n, -1, dtype=np.int32)
    last[dst_sorted] = np.arange(m, dtype=np.int32)  # later writes win = last edge
    return SegmentMeta(is_start=jnp.asarray(is_start), last_edge_of_vertex=jnp.asarray(last))


def _seg_or_op(a, b):
    va, fa = a
    vb, fb = b
    return jnp.where(fb, vb, va | vb), fa | fb


def segment_or(values: jnp.ndarray, meta: SegmentMeta, num_segments: int) -> jnp.ndarray:
    """OR-reduce uint words [m, W] by destination -> [num_segments, W].

    `values` must be ordered like the dst-sorted edge array `meta` was built from.
    """
    m = values.shape[0]
    if m == 0:
        return jnp.zeros((num_segments,) + values.shape[1:], values.dtype)
    flags = meta.is_start.reshape((m,) + (1,) * (values.ndim - 1))
    scanned, _ = jax.lax.associative_scan(_seg_or_op, (values, flags))
    idx = meta.last_edge_of_vertex
    out = jnp.take(scanned, jnp.clip(idx, 0, m - 1), axis=0)
    mask = (idx >= 0).reshape((num_segments,) + (1,) * (values.ndim - 1))
    return jnp.where(mask, out, jnp.zeros_like(out))


def segment_or_bool(values: jnp.ndarray, segment_ids: jnp.ndarray, num_segments: int,
                    sorted: bool = True) -> jnp.ndarray:
    """Boolean-plane OR-reduce (reference path; 8x the bytes of the packed path).

    Note: segment_max yields INT_MIN for empty segments, so compare > 0 rather
    than casting — empty segments must aggregate to False.
    """
    return jax.ops.segment_max(
        values.astype(jnp.int32), segment_ids, num_segments=num_segments,
        indices_are_sorted=sorted,
    ) > 0


def segment_sum(values, segment_ids, num_segments, sorted: bool = True):
    return jax.ops.segment_sum(
        values, segment_ids, num_segments=num_segments, indices_are_sorted=sorted
    )


def segment_max(values, segment_ids, num_segments, sorted: bool = True):
    return jax.ops.segment_max(
        values, segment_ids, num_segments=num_segments, indices_are_sorted=sorted
    )


def segment_min(values, segment_ids, num_segments, sorted: bool = True):
    return jax.ops.segment_min(
        values, segment_ids, num_segments=num_segments, indices_are_sorted=sorted
    )


def segment_count(segment_ids, num_segments, sorted: bool = True, dtype=jnp.float32):
    return segment_sum(
        jnp.ones(segment_ids.shape[:1], dtype), segment_ids, num_segments, sorted
    )


def segment_mean(values, segment_ids, num_segments, sorted: bool = True):
    s = segment_sum(values, segment_ids, num_segments, sorted)
    cnt = segment_count(segment_ids, num_segments, sorted, values.dtype)
    return s / jnp.maximum(cnt, 1).reshape((-1,) + (1,) * (values.ndim - 1))


def segment_softmax(scores, segment_ids, num_segments, sorted: bool = True):
    """Edge-softmax (GAT): softmax over edges grouped by destination."""
    mx = segment_max(scores, segment_ids, num_segments, sorted)
    ex = jnp.exp(scores - jnp.take(mx, segment_ids, axis=0))
    den = segment_sum(ex, segment_ids, num_segments, sorted)
    return ex / jnp.maximum(jnp.take(den, segment_ids, axis=0), 1e-16)

"""Static distributed edge partition with all_to_all buckets.

Adaptation of HavoqGT's distributed delegate-partitioned message queues to the
SPMD/static-shape world of XLA:

- vertices are block-partitioned over P shards (shard = v // n_local),
- every arc (u -> v) lives on shard(u) ("push" layout),
- per shard, arcs are grouped into P buckets by shard(v), padded to a uniform
  static bucket size B, so one `jax.lax.all_to_all` per sweep exchanges exactly
  the per-arc payloads (omega words / GNN messages) for cut and local edges,
- the receiving shard aggregates with a static dst-sorted permutation +
  segmented scan (see graph.segment_ops.segment_or).

High-degree vertices' arcs spread across the *source* shards of their
neighbors, so no shard owns a hub's full traffic — the same load-spreading
effect as HavoqGT's delegates, achieved statically.

Everything here is host-side numpy executed once per graph; the resulting
arrays are static inputs to the jitted sweeps. `partition_shapes` computes the
same shapes analytically for dry-runs (no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.graph.structs import Graph


def _ceil_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass
class EdgePartition:
    """Static partition arrays. Leading axis P is the shard axis for shard_map."""

    P: int
    n: int
    n_local: int  # vertices per shard (padded block)
    B: int  # bucket size (arcs per (src_shard, dst_shard) bucket, padded)

    # send layout [P, P, B]: bucket (p, q) holds arcs from shard p to shard q
    send_src_local: np.ndarray  # int32, gather index into local omega (n_local = pad row)
    send_pad: np.ndarray  # bool, True for padding slots
    twin_recv_flat: np.ndarray  # int32, flat index of the twin arc's message in OUR recv buffer

    # receive layout [P, P*B] (flattened (src_shard, slot)); static dst-sorted metadata
    recv_perm: np.ndarray  # int32[P, P*B] sorts received messages by local dst
    recv_sorted_dst_local: np.ndarray  # int32[P, P*B] (n_local for pads)
    recv_is_start: np.ndarray  # bool[P, P*B]
    recv_last_edge: np.ndarray  # int32[P, n_local], -1 if vertex has no in-arc

    labels_local: np.ndarray  # int32[P, n_local]
    vertex_valid: np.ndarray  # bool[P, n_local]

    # bookkeeping for mapping answers back
    global_of_local: np.ndarray  # int32[P, n_local] global vertex id (or -1)

    # per-arc slot in the flattened [P, P, B] bucket tensor, in the host
    # Graph's arc order — the execution backends' edge_active gather/scatter
    # map (None only on partitions built by pre-engine code)
    arc_flat_slot: Optional[np.ndarray] = None  # int64[m]

    # destination local index per send bucket slot (n_local for pads) — the
    # device-resident enumeration join reads arc destinations from it
    # (None only on partitions built by pre-join code)
    send_dst_local: Optional[np.ndarray] = None  # int32[P, P, B]

    def __post_init__(self):
        self._join_plan: Optional["JoinPlan"] = None
        self._join_plan_dev: Optional[Dict[str, jnp.ndarray]] = None
        self._row_plan: Optional["RowPlan"] = None

    @property
    def total_slots(self) -> int:
        return self.P * self.B

    def meta(self) -> Dict[str, int]:
        """JSON-serializable partition facts (shard count + block geometry) —
        recorded in checkpoint manifests so an elastic restore knows what
        deployment the state was saved under."""
        return {"P": int(self.P), "n": int(self.n),
                "n_local": int(self.n_local), "B": int(self.B)}

    def join_plan(self) -> "JoinPlan":
        """The (cached) shard-local arc plan the device-resident enumeration
        join expands over — see `build_join_plan`."""
        if self._join_plan is None:
            self._join_plan = build_join_plan(self)
        return self._join_plan

    def join_plan_dev(self) -> Dict[str, jnp.ndarray]:
        """Device-resident copies of the join plan's static arrays, uploaded
        ONCE per partition: repeated `enumerate_matches` calls against the
        same partition reuse the same device buffers instead of re-staging
        the CSR every call."""
        if self._join_plan_dev is None:
            plan = self.join_plan()
            self._join_plan_dev = {
                "perm": jnp.asarray(plan.perm),
                "csr_off": jnp.asarray(plan.csr_off),
                "arc_dst": jnp.asarray(plan.arc_dst),
                "deg": jnp.asarray(plan.deg),
            }
        return self._join_plan_dev

    def row_plan(self) -> "RowPlan":
        """The (cached) row-ownership plan of the distributed-rows join —
        see `build_row_plan`."""
        if self._row_plan is None:
            self._row_plan = build_row_plan(self)
        return self._row_plan

    def device_arrays(self) -> Dict[str, jnp.ndarray]:
        return {
            "send_src_local": jnp.asarray(self.send_src_local),
            "send_pad": jnp.asarray(self.send_pad),
            "twin_recv_flat": jnp.asarray(self.twin_recv_flat),
            "recv_perm": jnp.asarray(self.recv_perm),
            "recv_sorted_dst_local": jnp.asarray(self.recv_sorted_dst_local),
            "recv_is_start": jnp.asarray(self.recv_is_start),
            "recv_last_edge": jnp.asarray(self.recv_last_edge),
            "labels_local": jnp.asarray(self.labels_local),
            "vertex_valid": jnp.asarray(self.vertex_valid),
        }


def partition_graph(g: Graph, P: int, pad_multiple: int = 8) -> EdgePartition:
    n_local = (g.n + P - 1) // P
    src_shard = g.src // n_local
    dst_shard = g.dst // n_local

    # bucket sizes -> uniform B
    counts = np.zeros((P, P), dtype=np.int64)
    np.add.at(counts, (src_shard, dst_shard), 1)
    B = max(int(counts.max()), 1)
    B = _ceil_to(B, pad_multiple)

    send_src_local = np.full((P, P, B), n_local, dtype=np.int32)
    send_dst_local = np.full((P, P, B), n_local, dtype=np.int32)
    send_pad = np.ones((P, P, B), dtype=bool)
    slot_of_arc = np.zeros(g.m, dtype=np.int64)

    # deterministic order: sort arcs by (src_shard, dst_shard, dst_local, src_local)
    order = np.lexsort((g.src % n_local, g.dst % n_local, dst_shard, src_shard))
    s_sh, d_sh = src_shard[order], dst_shard[order]
    s_lo, d_lo = (g.src % n_local)[order], (g.dst % n_local)[order]
    # position within bucket
    bucket_key = s_sh * P + d_sh
    new_bucket = np.ones(g.m, dtype=bool)
    new_bucket[1:] = bucket_key[1:] != bucket_key[:-1]
    bucket_start = np.maximum.accumulate(np.where(new_bucket, np.arange(g.m), 0))
    pos = np.arange(g.m) - bucket_start
    send_src_local[s_sh, d_sh, pos] = s_lo
    send_dst_local[s_sh, d_sh, pos] = d_lo
    send_pad[s_sh, d_sh, pos] = False
    slot_of_arc[order] = pos
    arc_flat_slot = np.empty(g.m, dtype=np.int64)
    arc_flat_slot[order] = (s_sh.astype(np.int64) * P + d_sh) * B + pos

    # twin lookup: arc i=(u,v); twin=(v,u) lives at (dst_sh[i], src_sh[i], slot_of_twin).
    # The receiving shard for arc i's dst-side omega is shard(u)=src_sh[i]; in its recv
    # buffer, source-shard axis = shard(v)=dst_sh[i], slot = twin's slot.
    twin_idx = _twin_index(g)
    twin_recv_flat = np.full((P, P, B), P * B, dtype=np.int32)  # pad -> sink slot
    tslot = slot_of_arc[twin_idx]
    twin_recv_flat[s_sh, d_sh, pos] = (d_sh * B + tslot[order]).astype(np.int32)

    # receive metadata per shard p: messages arrive as [P(src_shard q), B]; message at
    # (q, b) is the arc in bucket (q, p, b), destined to local vertex send_dst_local[q, p, b].
    recv_dst = np.transpose(send_dst_local, (1, 0, 2)).reshape(P, P * B)  # [p, q*B]
    recv_perm = np.argsort(recv_dst, axis=1, kind="stable").astype(np.int32)
    recv_sorted = np.take_along_axis(recv_dst, recv_perm, axis=1)
    recv_is_start = np.ones((P, P * B), dtype=bool)
    recv_is_start[:, 1:] = recv_sorted[:, 1:] != recv_sorted[:, :-1]
    recv_last_edge = np.full((P, n_local), -1, dtype=np.int32)
    for p in range(P):
        valid = recv_sorted[p] < n_local
        recv_last_edge[p, recv_sorted[p, valid]] = np.arange(P * B, dtype=np.int32)[valid]

    labels_local = np.zeros((P, n_local), dtype=np.int32)
    vertex_valid = np.zeros((P, n_local), dtype=bool)
    global_of_local = np.full((P, n_local), -1, dtype=np.int32)
    ids = np.arange(g.n)
    labels_local[ids // n_local, ids % n_local] = g.labels
    vertex_valid[ids // n_local, ids % n_local] = True
    global_of_local[ids // n_local, ids % n_local] = ids

    return EdgePartition(
        P=P, n=g.n, n_local=n_local, B=B,
        send_src_local=send_src_local, send_pad=send_pad,
        twin_recv_flat=twin_recv_flat,
        recv_perm=recv_perm, recv_sorted_dst_local=recv_sorted.astype(np.int32),
        recv_is_start=recv_is_start, recv_last_edge=recv_last_edge,
        labels_local=labels_local, vertex_valid=vertex_valid,
        global_of_local=global_of_local,
        arc_flat_slot=arc_flat_slot,
        send_dst_local=send_dst_local,
    )


@dataclasses.dataclass
class JoinPlan:
    """Static per-shard arc plan for the device-resident enumeration join
    (core/join.py): every shard's arcs re-sorted by (src_local, dst_global)
    so row expansion is a shard-local CSR walk, in an order that is IDENTICAL
    to the single-device plan's (src, dst) sort — the join's slot layout (and
    therefore its row tables) is bit-identical across shard counts because
    all arcs of a vertex live on exactly its owner shard.

    `deg` is the STATIC per-vertex out-degree in the padded global id space
    (sink row n_pad has degree 0): the join sizes its expansion buffers from
    it, so capacity math never depends on the pruned state and matches the
    local plan exactly.
    """

    A: int  # arcs per shard (P*B, padded)
    n_pad: int  # padded global vertex space (P * n_local)
    perm: np.ndarray  # int32[P, A]: sorted order -> flat bucket slot (gather map)
    csr_off: np.ndarray  # int32[P, n_local + 1] CSR over sorted non-pad arcs
    arc_dst: np.ndarray  # int32[P, A] dst global id in sorted order (n_pad for pads)
    deg: np.ndarray  # int32[n_pad + 1]


def build_join_plan(part: EdgePartition) -> JoinPlan:
    if part.send_dst_local is None:
        raise ValueError(
            "EdgePartition lacks send_dst_local (built by a pre-join "
            "partition_graph?); rebuild the partition")
    P, B, n_local = part.P, part.B, part.n_local
    A = P * B
    n_pad = P * n_local
    src_lo = part.send_src_local.reshape(P, A)  # [P, (q, b)] flat
    dst_sh = np.broadcast_to(
        np.repeat(np.arange(P, dtype=np.int64), B)[None, :], (P, A))
    dst_glob = dst_sh * n_local + part.send_dst_local.reshape(P, A)
    pad = part.send_pad.reshape(P, A)
    dst_glob = np.where(pad, n_pad, dst_glob)
    perm = np.empty((P, A), dtype=np.int32)
    csr_off = np.zeros((P, n_local + 1), dtype=np.int64)
    arc_dst = np.empty((P, A), dtype=np.int32)
    deg = np.zeros(n_pad + 1, dtype=np.int64)
    for p in range(P):
        # pads carry src_local == n_local, so they sort after every real arc
        order = np.lexsort((dst_glob[p], src_lo[p]))
        perm[p] = order.astype(np.int32)
        arc_dst[p] = dst_glob[p][order].astype(np.int32)
        counts = np.bincount(src_lo[p][~pad[p]], minlength=n_local + 1)[:n_local]
        csr_off[p, 1:] = np.cumsum(counts)
        deg[p * n_local : p * n_local + n_local] = counts
    return JoinPlan(A=A, n_pad=n_pad, perm=perm,
                    csr_off=csr_off.astype(np.int32), arc_dst=arc_dst,
                    deg=deg.astype(np.int32))


@dataclasses.dataclass
class RowPlan:
    """Row-ownership plan for the distributed-rows join (core/join.py).

    Ownership rule: a partial-embedding row lives on the shard that owns the
    row's NEXT frontier vertex — owner(v) = v // n_local, the same block rule
    the edge partition uses — because that shard holds every arc of v in its
    join-plan CSR, so expansion is purely local once rows are routed. The
    plan is derived from `join_plan()` (its static per-vertex degrees in the
    padded global id space), so slot layout and capacity math are identical
    on every shard count: only row PLACEMENT varies with P, never row
    content or order-insensitive results.

    `deg` is a host int64 copy of the join plan's static degree table (sink
    vertex n_pad has degree 0) — the host sizes each step's expansion slots
    and exchange buckets from it without touching device data.
    """

    P: int
    n_local: int
    n_pad: int
    deg: np.ndarray  # int64[n_pad + 1]

    def owner_of(self, v: np.ndarray) -> np.ndarray:
        """Owner shard per global vertex id; the sink id n_pad maps to P
        (the 'nowhere' bucket pads route around)."""
        return np.minimum(np.asarray(v, np.int64) // self.n_local, self.P)

    def shard_rows(self, rows: np.ndarray, owner_col: int,
                   pow2_pad) -> Tuple[np.ndarray, np.ndarray]:
        """Bucket host rows [K, C] by the owner of column `owner_col` into a
        padded [P, Rb, C] block (sink rows = n_pad) + per-shard counts.
        Order within a shard preserves the input order (stable), so the
        layout is deterministic."""
        rows = np.asarray(rows, np.int32)
        owner = self.owner_of(rows[:, owner_col])
        counts = np.bincount(owner, minlength=self.P)[: self.P]
        rb = pow2_pad(int(counts.max()) if counts.size else 0)
        out = np.full((self.P, rb, rows.shape[1]), self.n_pad, np.int32)
        for p in range(self.P):
            sel = rows[owner == p]
            out[p, : sel.shape[0]] = sel
        return out, counts.astype(np.int64)


def build_row_plan(part: EdgePartition) -> RowPlan:
    plan = part.join_plan()
    return RowPlan(P=part.P, n_local=part.n_local, n_pad=plan.n_pad,
                   deg=plan.deg.astype(np.int64))


def _twin_index(g: Graph) -> np.ndarray:
    """For each arc i=(u,v), index j of its twin (v,u). Graph must be undirected."""
    key = g.src.astype(np.int64) * g.n + g.dst
    tkey = g.dst.astype(np.int64) * g.n + g.src
    order = np.argsort(key)
    pos = np.searchsorted(key[order], tkey)
    twin = order[pos]
    if not np.array_equal(key[twin], tkey):
        raise ValueError("graph is not undirected (missing twin arcs)")
    return twin


def partition_shapes(n: int, m: int, P: int, W: int, pad_multiple: int = 8,
                     skew: float = 2.0) -> Dict[str, Tuple[Tuple[int, ...], str]]:
    """Analytic shapes of partition arrays + per-sweep message buffers for dry-runs.

    skew models bucket imbalance (B = skew * m / P^2). Returns name -> (shape, dtype).
    """
    n_local = (n + P - 1) // P
    B = _ceil_to(max(int(skew * m / (P * P)), 1), pad_multiple)
    return {
        "send_src_local": ((P, P, B), "int32"),
        "send_pad": ((P, P, B), "bool"),
        "twin_recv_flat": ((P, P, B), "int32"),
        "recv_perm": ((P, P * B), "int32"),
        "recv_sorted_dst_local": ((P, P * B), "int32"),
        "recv_is_start": ((P, P * B), "bool"),
        "recv_last_edge": ((P, n_local), "int32"),
        "labels_local": ((P, n_local), "int32"),
        "vertex_valid": ((P, n_local), "bool"),
        "omega": ((P, n_local + 1, W), "uint32"),
        "edge_active": ((P, P, B), "bool"),
    }

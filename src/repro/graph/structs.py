"""Graph data structures.

Host-side `Graph` is numpy (simple, undirected, vertex-labeled, stored as a
directed edge list with both (u,v) and (v,u) present, matching the paper's
"two directed edges represent each undirected edge" convention).

Device-side `DeviceGraph` is a pytree of jnp arrays with edges sorted by
destination — the layout required by the segment-reduce edge sweep that both
the pattern-matching engine and the GNN models use. Metadata (labels) is kept
in a separate array, independent of topology, per the paper's metadata store.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass
class Graph:
    """Host-side labeled graph. Directed edge list; undirected graphs store both arcs."""

    n: int
    src: np.ndarray  # int32[m]
    dst: np.ndarray  # int32[m]
    labels: np.ndarray  # int32[n]

    def __post_init__(self):
        self.src = np.asarray(self.src, dtype=np.int32)
        self.dst = np.asarray(self.dst, dtype=np.int32)
        self.labels = np.asarray(self.labels, dtype=np.int32)
        assert self.labels.shape == (self.n,)
        assert self.src.shape == self.dst.shape

    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    @property
    def n_labels(self) -> int:
        return int(self.labels.max()) + 1 if self.n else 0

    @staticmethod
    def from_undirected_pairs(n: int, pairs, labels) -> "Graph":
        """Build from unique undirected pairs (u < v); adds both arcs, dedups, drops self-loops."""
        pairs = np.asarray(pairs, dtype=np.int64).reshape(-1, 2)
        pairs = pairs[pairs[:, 0] != pairs[:, 1]]
        both = np.concatenate([pairs, pairs[:, ::-1]], axis=0)
        both = np.unique(both, axis=0)
        return Graph(n=n, src=both[:, 0], dst=both[:, 1], labels=np.asarray(labels))

    def csr(self):
        """Return (offsets int64[n+1], neighbors int32[m]) sorted by (src, dst)."""
        order = np.lexsort((self.dst, self.src))
        s, d = self.src[order], self.dst[order]
        offsets = np.zeros(self.n + 1, dtype=np.int64)
        np.add.at(offsets, s + 1, 1)
        np.cumsum(offsets, out=offsets)
        return offsets, d

    def degrees(self) -> np.ndarray:
        deg = np.zeros(self.n, dtype=np.int64)
        np.add.at(deg, self.src, 1)
        return deg

    def label_frequency(self) -> np.ndarray:
        """freq[l] = number of vertices with label l (paper's token-ordering heuristic input)."""
        return np.bincount(self.labels, minlength=self.n_labels)

    def subgraph(self, vmask: np.ndarray, emask: Optional[np.ndarray] = None) -> "Graph":
        """Induced subgraph on active vertices (and optionally active edges), re-indexed."""
        vmask = np.asarray(vmask, dtype=bool)
        keep = vmask[self.src] & vmask[self.dst]
        if emask is not None:
            keep &= np.asarray(emask, dtype=bool)
        new_id = np.cumsum(vmask, dtype=np.int64) - 1
        return Graph(
            n=int(vmask.sum()),
            src=new_id[self.src[keep]],
            dst=new_id[self.dst[keep]],
            labels=self.labels[vmask],
        )

    def validate_undirected(self) -> bool:
        fw = set(zip(self.src.tolist(), self.dst.tolist()))
        return all((d, s) in fw for (s, d) in fw)


@dataclasses.dataclass
class DeviceGraph:
    """Device-side graph in dst-sorted COO layout (+ labels). A pytree of jnp arrays.

    Edges are sorted by dst so per-destination aggregation is a segment reduce over
    contiguous runs — the layout the `bitset_spmm` / `segment_agg` kernels tile.
    """

    n: int
    src: jnp.ndarray  # int32[m] sorted by dst
    dst: jnp.ndarray  # int32[m]
    labels: jnp.ndarray  # int32[n]

    @property
    def m(self) -> int:
        return int(self.src.shape[0])

    @staticmethod
    def dst_sort_order(g: Graph) -> np.ndarray:
        """The dst-sort permutation `from_host` applies — exposed so callers
        that also need the order (e.g. the sharded backends' edge gather map)
        compute it once and stay in sync with this layout."""
        return np.lexsort((g.src, g.dst))

    @staticmethod
    def from_host(g: Graph, order: Optional[np.ndarray] = None) -> "DeviceGraph":
        if order is None:
            order = DeviceGraph.dst_sort_order(g)
        return DeviceGraph(
            n=g.n,
            src=jnp.asarray(g.src[order]),
            dst=jnp.asarray(g.dst[order]),
            labels=jnp.asarray(g.labels),
        )

    def tree_flatten(self):
        return (self.src, self.dst, self.labels), self.n

    @classmethod
    def tree_unflatten(cls, aux, children):
        src, dst, labels = children
        return cls(n=aux, src=src, dst=dst, labels=labels)


import jax.tree_util as _jtu  # noqa: E402

_jtu.register_pytree_node(
    DeviceGraph, DeviceGraph.tree_flatten, DeviceGraph.tree_unflatten
)

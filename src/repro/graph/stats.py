"""Cheap device-side graph statistics for plan-level query optimization.

The planner (core/planner.py) costs candidate constraint orders with a
survival model driven by two histograms: how many vertices carry each label
(the selectivity of a label-candidacy test) and how degrees are distributed
(the fan-out of a token-forwarding step). Both are computed on device in one
fused dispatch and read back together — one host sync regardless of graph
size — so collecting stats at admission time costs no more than a single
count readback the pipeline already does per phase.

Stats are summarised into a coarse *bucket* string (same spirit as
`kernels.registry.shape_bucket`): plans are tuned per (template signature,
stats bucket), so a plan tuned on one R-MAT instance transfers to any graph
with the same rough scale, density, and label skew.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np
import jax.numpy as jnp

from repro.graph.structs import DeviceGraph, Graph

# log2-bucketed degree histogram width: bucket i holds vertices with
# out-degree in [2^(i-1), 2^i), bucket 0 holds isolated vertices. 32 buckets
# cover any int32-indexable graph.
DEGREE_BUCKETS = 32


@dataclasses.dataclass(frozen=True)
class GraphStats:
    """Host-side summary of one readback: label + degree histograms."""

    n: int
    m: int
    label_hist: np.ndarray  # int64[n_labels], count of vertices per label
    degree_hist: np.ndarray  # int64[DEGREE_BUCKETS], log2-bucketed out-degree

    @property
    def n_labels(self) -> int:
        return int(self.label_hist.shape[0])

    @property
    def avg_degree(self) -> float:
        return self.m / max(self.n, 1)

    def label_frequency(self) -> np.ndarray:
        """Alias matching `Graph.label_frequency` (the heuristic-order input)."""
        return self.label_hist

    def degree_p90(self) -> float:
        """Upper edge of the bucket holding the 90th-percentile vertex degree."""
        if self.n == 0:
            return 0.0
        cum = np.cumsum(self.degree_hist)
        idx = int(np.searchsorted(cum, 0.9 * self.n))
        return float(2 ** min(idx, DEGREE_BUCKETS - 1))

    def label_skew(self) -> float:
        """max/mean label frequency — 1.0 for uniform labels, large when one
        label dominates (and label tests stop discriminating)."""
        nz = self.label_hist[self.label_hist > 0]
        if nz.size == 0:
            return 1.0
        return float(nz.max() / nz.mean())

    def bucket(self) -> str:
        """Coarse bucket key for the plan cache: power-of-two vertex count,
        power-of-two average degree, power-of-two label-skew class. Renders
        as e.g. ``n2048xd8xs2``."""
        return "n%dxd%dxs%d" % (
            _pow2(self.n),
            _pow2(int(round(self.avg_degree))),
            _pow2(int(round(self.label_skew()))),
        )


def _pow2(d: int) -> int:
    d = max(int(d), 1)
    b = 1
    while b < d:
        b <<= 1
    return b


def collect_graph_stats(
    g: Union[Graph, DeviceGraph], n_labels: Optional[int] = None
) -> GraphStats:
    """Compute label + degree histograms in one device dispatch, one readback.

    The two histograms are packed into a single flat int32 vector on device
    and read back together, so cost is one host sync. Accepts the host Graph
    too (numpy path) for callers that never built a DeviceGraph.
    """
    if isinstance(g, Graph):
        nl = int(n_labels) if n_labels is not None else g.n_labels
        label_hist = np.bincount(g.labels, minlength=max(nl, 1)).astype(np.int64)
        deg = g.degrees()
        buckets = np.where(deg > 0, np.ceil(np.log2(deg + 1)), 0).astype(np.int64)
        buckets = np.clip(buckets, 0, DEGREE_BUCKETS - 1)
        degree_hist = np.bincount(buckets, minlength=DEGREE_BUCKETS).astype(np.int64)
        return GraphStats(n=g.n, m=g.m, label_hist=label_hist,
                          degree_hist=degree_hist[:DEGREE_BUCKETS])

    dg = g
    if n_labels is None:
        raise ValueError("n_labels is required for DeviceGraph stats "
                         "(labels.max() would be an extra readback)")
    nl = max(int(n_labels), 1)
    packed = _device_histograms(dg.labels, dg.src, dg.n, nl)
    flat = np.asarray(packed)  # the single readback
    return GraphStats(
        n=dg.n,
        m=dg.m,
        label_hist=flat[:nl].astype(np.int64),
        degree_hist=flat[nl:nl + DEGREE_BUCKETS].astype(np.int64),
    )


def _device_histograms(labels: jnp.ndarray, src: jnp.ndarray, n: int, nl: int):
    """Fused label histogram + log2 degree histogram → one flat int32 vector."""
    label_hist = jnp.zeros((nl,), dtype=jnp.int32).at[labels].add(1)
    deg = jnp.zeros((n,), dtype=jnp.int32).at[src].add(1)
    buckets = jnp.where(
        deg > 0,
        jnp.ceil(jnp.log2(deg.astype(jnp.float32) + 1.0)).astype(jnp.int32),
        0,
    )
    buckets = jnp.clip(buckets, 0, DEGREE_BUCKETS - 1)
    degree_hist = jnp.zeros((DEGREE_BUCKETS,), dtype=jnp.int32).at[buckets].add(1)
    return jnp.concatenate([label_hist, degree_hist])

"""Synthetic graph generators used throughout the paper's evaluation.

- R-MAT (Graph500 / Chakrabarti / Uniform probability presets, §5.7 + Appendix A)
- Erdos-Renyi (the R-MAT uniform limit)
- pathological structures from Fig. 2 (unrolled cycles, tori) used to prove that
  local constraint checking alone is insufficient
- the paper's degree-based labeling  l(v) = ceil(log2(deg(v) + 1))  (§5 Datasets)
"""
from __future__ import annotations

import numpy as np

from repro.graph.structs import Graph

# R-MAT presets from Appendix A, Fig. 13.
RMAT_PRESETS = {
    "graph500": (0.57, 0.19, 0.19, 0.05),
    "chakrabarti": (0.45, 0.15, 0.15, 0.25),
    "uniform": (0.25, 0.25, 0.25, 0.25),
}


def rmat_edges(
    scale: int,
    edge_factor: int = 16,
    preset: str = "graph500",
    seed: int = 0,
    noise: float = 0.1,
) -> np.ndarray:
    """Generate directed R-MAT edge endpoints, Graph500-style, vectorized.

    Returns int64[(edge_factor << scale), 2]. Self-loops/duplicates retained here;
    `rmat_graph` dedups when building the undirected Graph.
    """
    rng = np.random.default_rng(seed)
    a, b, c, d = RMAT_PRESETS[preset]
    m = edge_factor << scale
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        # Per-level probability noise keeps the degree distribution from being
        # perfectly self-similar (standard Graph500 tweak).
        r = rng.random(m)
        jitter = 1.0 + noise * (rng.random(4) - 0.5) if noise else np.ones(4)
        aa, bb, cc, dd = a * jitter[0], b * jitter[1], c * jitter[2], d * jitter[3]
        norm = aa + bb + cc + dd
        aa, bb, cc = aa / norm, bb / norm, cc / norm
        ab, abc = aa + bb, aa + bb + cc
        right = r >= ab  # in quadrant c or d -> src high bit set? (row = src)
        low = (r >= aa) & (r < ab) | (r >= abc)  # quadrant b or d -> dst high bit
        src |= right.astype(np.int64) << bit
        dst |= low.astype(np.int64) << bit
    return np.stack([src, dst], axis=1)


def rmat_graph(
    scale: int,
    edge_factor: int = 16,
    preset: str = "graph500",
    seed: int = 0,
    labeler: str = "degree",
    n_labels: int = 0,
) -> Graph:
    """Undirected R-MAT graph with paper-style labels."""
    pairs = rmat_edges(scale, edge_factor, preset, seed)
    n = 1 << scale
    lo = np.minimum(pairs[:, 0], pairs[:, 1])
    hi = np.maximum(pairs[:, 0], pairs[:, 1])
    keep = lo != hi
    und = np.unique(np.stack([lo[keep], hi[keep]], axis=1), axis=0)
    g = Graph.from_undirected_pairs(n, und, np.zeros(n, dtype=np.int32))
    if labeler == "degree":
        g.labels = degree_labels(g)
    elif labeler == "random":
        assert n_labels > 0
        g.labels = random_labels(n, n_labels, seed=seed + 1)
    return g


def erdos_renyi_graph(n: int, avg_degree: float, seed: int = 0, n_labels: int = 8) -> Graph:
    rng = np.random.default_rng(seed)
    m_target = int(n * avg_degree / 2)
    pairs = rng.integers(0, n, size=(int(m_target * 1.1), 2), dtype=np.int64)
    lo, hi = np.minimum(pairs[:, 0], pairs[:, 1]), np.maximum(pairs[:, 0], pairs[:, 1])
    keep = lo != hi
    und = np.unique(np.stack([lo[keep], hi[keep]], axis=1), axis=0)[:m_target]
    return Graph.from_undirected_pairs(n, und, random_labels(n, n_labels, seed + 1))


def degree_labels(g: Graph) -> np.ndarray:
    """Paper's weak-scaling labeler: l(v) = ceil(log2(d(v)+1))."""
    deg = g.degrees()
    return np.ceil(np.log2(deg + 1)).astype(np.int32)


def random_labels(n: int, n_labels: int, seed: int = 0) -> np.ndarray:
    """Uniform random labels (paper's Twitter / UK Web labeling, §5.7)."""
    return np.random.default_rng(seed).integers(0, n_labels, size=n, dtype=np.int32)


def cycle_graph(length: int, labels) -> Graph:
    """A single cycle (e.g. the unrolled 3k-cycle of Fig. 2(a))."""
    labels = np.asarray(labels, dtype=np.int32)
    assert labels.shape[0] == length
    idx = np.arange(length, dtype=np.int64)
    pairs = np.stack([idx, (idx + 1) % length], axis=1)
    return Graph.from_undirected_pairs(length, pairs, labels)


def path_graph(length: int, labels) -> Graph:
    labels = np.asarray(labels, dtype=np.int32)
    idx = np.arange(length - 1, dtype=np.int64)
    pairs = np.stack([idx, idx + 1], axis=1)
    return Graph.from_undirected_pairs(length, pairs, labels)


def torus_graph(rows: int, cols: int, labels) -> Graph:
    """Doubly-periodic grid (Fig. 2(c)'s 4x3 torus that defeats cycle checking)."""
    labels = np.asarray(labels, dtype=np.int32).reshape(rows * cols)
    vid = np.arange(rows * cols).reshape(rows, cols)
    pairs = []
    for r in range(rows):
        for c in range(cols):
            pairs.append((vid[r, c], vid[r, (c + 1) % cols]))
            pairs.append((vid[r, c], vid[(r + 1) % rows, c]))
    return Graph.from_undirected_pairs(rows * cols, np.asarray(pairs), labels)


def star_graph(n_leaves: int, center_label: int, leaf_label: int) -> Graph:
    labels = np.full(n_leaves + 1, leaf_label, dtype=np.int32)
    labels[0] = center_label
    pairs = np.stack(
        [np.zeros(n_leaves, dtype=np.int64), np.arange(1, n_leaves + 1, dtype=np.int64)],
        axis=1,
    )
    return Graph.from_undirected_pairs(n_leaves + 1, pairs, labels)


def clique_graph(k: int, labels) -> Graph:
    labels = np.asarray(labels, dtype=np.int32)
    pairs = [(i, j) for i in range(k) for j in range(i + 1, k)]
    return Graph.from_undirected_pairs(k, np.asarray(pairs), labels)


def planted_pattern_graph(
    background: Graph, pattern: Graph, n_copies: int, seed: int = 0
) -> Graph:
    """Plant `n_copies` disjoint copies of `pattern` into `background` (needle-in-haystack
    scenarios, §1(iii)). Pattern copies attach to random background vertices by one edge."""
    rng = np.random.default_rng(seed)
    n0 = background.n
    all_pairs = list(zip(background.src.tolist(), background.dst.tolist()))
    labels = [background.labels]
    extra = []
    for c in range(n_copies):
        base = n0 + c * pattern.n
        extra.extend(
            (base + int(s), base + int(d)) for s, d in zip(pattern.src, pattern.dst)
        )
        anchor = int(rng.integers(0, n0))
        extra.append((anchor, base))
        extra.append((base, anchor))
        labels.append(pattern.labels)
    src = np.concatenate([background.src, np.asarray([p[0] for p in extra], np.int32)])
    dst = np.concatenate([background.dst, np.asarray([p[1] for p in extra], np.int32)])
    del all_pairs
    return Graph(
        n=n0 + n_copies * pattern.n,
        src=src,
        dst=dst,
        labels=np.concatenate(labels),
    )

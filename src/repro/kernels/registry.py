"""Declarative kernel registry — one `dispatch()` for every Pallas kernel,
plus the benchmark-driven dispatch policy that tunes its decisions.

Each kernel registers four things:

  pallas_fn   the Pallas entrypoint, called as pallas_fn(*args, interpret=…, **kw)
  ref_fn      the pure-jnp oracle from ref.py with the same call signature
              (minus `interpret`) and identical numerics contract
  eligible    a shape-eligibility predicate over the same arguments: False
              means the Pallas formulation cannot express this call (missing
              blocked structure, tile-misaligned shapes, d_qk != d_v, …)
  bucket      a shape-bucketing function over the same arguments: calls in the
              same bucket share one tuned dispatch decision (default: a single
              bucket per kernel)

`dispatch(name, *args, force_pallas=…, backend=…, **kw)` then picks exactly
one of three modes (`resolve_mode` exposes the decision for tests):

  "pallas"     compiled Pallas — eligible call on a TPU backend
  "interpret"  Pallas interpreter — eligible call, force_pallas=True off-TPU
               (the kernel-parity test path)
  "ref"        reference oracle — ineligible shapes, or off-TPU without
               force_pallas

A Pallas attempt that dies with an API-drift error (compat.PALLAS_TRAP_ERRORS)
is trapped and re-run through the reference oracle — unless force_pallas was
set, in which case the error propagates so parity tests stay strict.

Dispatch policy
---------------

On top of the eligibility rules sits a measured-cost policy (`DispatchPolicy`):
a per-(kernel, backend, shape-bucket) table of tuned decisions, produced by
`tune()` (which times every candidate variant on the live backend) and
persisted to a JSON cache (`policy_path()`, overridable via the
``REPRO_DISPATCH_POLICY`` env var). `resolve_mode` consults the active policy
first; with no policy (or no entry for the bucket) it falls back to the
eligibility/trap behavior above, so an untuned checkout behaves exactly like
the pre-policy registry. `force_pallas` always bypasses the policy — parity
tests pin the kernel path.

The policy also stores *route* decisions for choices that live above a single
kernel call — today the `prune` routing (route names ``prune.lcc`` and
``prune.nlcc``, see core/lcc.py and core/nlcc.py): packed vs unpacked sweeps,
plus the fused multi-hop wave engine for NLCC (``ROUTE_FUSED``,
kernels/bitset_wave.py). `resolve_route` serves these to the hot loops.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import time
import warnings
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import jax

from repro.kernels import compat

MODE_PALLAS = "pallas"
MODE_INTERPRET = "interpret"
MODE_REF = "ref"
MODES = (MODE_PALLAS, MODE_INTERPRET, MODE_REF)

ROUTE_PACKED = "packed"
ROUTE_UNPACKED = "unpacked"
# the fused multi-hop NLCC wave (kernels/bitset_wave.py): one kernel call per
# wave instead of one bitset_spmm launch per hop
ROUTE_FUSED = "fused"
# the enumeration join (route name ``enumerate.join``, core/enumerate.py):
# host = the numpy row-table join over the compacted subgraph; device = the
# device-resident join over the execution-backend prims (core/join.py)
ROUTE_HOST = "host"
ROUTE_DEVICE = "device"
# row placement of the SHARDED device join (bucket ("sharded", mode)):
# replicated = every shard holds the full row table, slots psum-combined;
# rowsharded = rows live on their frontier-vertex owner shard and move via
# the keyed `exchange_rows` collective — per-shard memory ~1/P (the default)
ROUTE_REPLICATED = "replicated"
ROUTE_ROWSHARDED = "rowsharded"

# wildcard bucket: one decision for every shape of a (kernel, backend) pair
BUCKET_ANY = "*"


def _always_eligible(*args, **kwargs) -> bool:
    return True


def _single_bucket(*args, **kwargs) -> Tuple:
    return ()


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    name: str
    pallas_fn: Callable[..., Any]
    ref_fn: Callable[..., Any]
    eligible: Callable[..., bool]
    bucket: Callable[..., Tuple] = _single_bucket
    doc: str = ""


_REGISTRY: Dict[str, KernelSpec] = {}


def register(
    name: str,
    *,
    pallas: Callable[..., Any],
    ref: Callable[..., Any],
    eligible: Callable[..., bool] = _always_eligible,
    bucket: Callable[..., Tuple] = _single_bucket,
    doc: str = "",
) -> KernelSpec:
    """Register (or re-register) a kernel under `name`."""
    spec = KernelSpec(name=name, pallas_fn=pallas, ref_fn=ref,
                      eligible=eligible, bucket=bucket, doc=doc)
    _REGISTRY[name] = spec
    return spec


def get(name: str) -> KernelSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no kernel {name!r} registered; known: {sorted(_REGISTRY)}"
        ) from None


def names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ------------------------------------------------------------------ buckets
def shape_bucket(*dims: int) -> Tuple[int, ...]:
    """Round each dimension up to the next power of two. Calls whose dims land
    in the same bucket share one tuned decision — the autotuner measures one
    representative per bucket, not every exact shape."""
    out = []
    for d in dims:
        d = max(int(d), 1)
        b = 1
        while b < d:
            b <<= 1
        out.append(b)
    return tuple(out)


def shard_bucket(P: int, *dims: int) -> Tuple:
    """Shard-aware shape bucket for decisions made inside the sharded
    execution backends (core/engine.py): keyed by the shard count AND the
    shard-LOCAL dimensions (power-of-two rounded), so a tuned choice for
    "p4 shards, 512 local vertices, wave 1024" never leaks onto a different
    mesh decomposition of the same global graph. Renders as e.g.
    ``p4x512x1024`` in policy-table keys."""
    return (f"p{int(P)}",) + shape_bucket(*dims)


def batch_bucket(B: int, bucket) -> Tuple:
    """Template-batched variant of an existing bucket: a leading ``b<B>``
    segment (power-of-two rounded batch size) so batched routes tune
    separately from single-query ones — renders as e.g. ``b8x2048x1024``.
    Lookups for batch size 1 (``b1x...``) fall back to the unbatched key
    (`DispatchPolicy._lookup`), so a pre-batching policy cache keeps
    resolving without re-tuning."""
    b = shape_bucket(B)[0]
    if bucket == BUCKET_ANY:
        return (f"b{b}",)
    return (f"b{b}",) + tuple(bucket)


def bucket_key(bucket) -> str:
    """Render a shape bucket the way policy-table keys spell it ("2048x32",
    "*", "scalar") — for reading measurements back out of a policy."""
    if bucket == BUCKET_ANY:
        return BUCKET_ANY
    return "x".join(str(b) for b in tuple(bucket)) or "scalar"


_bucket_key = bucket_key


def _entry_key(name: str, backend: str, bucket) -> str:
    return f"{name}|{backend}|{_bucket_key(bucket)}"


# ------------------------------------------------------------------- policy
@dataclasses.dataclass
class PolicyEntry:
    """One tuned decision: the winning variant plus the measurements behind
    it (candidate -> best wall seconds over the tuning repeats)."""

    choice: str
    measured_s: Dict[str, float] = dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict:
        return {"choice": self.choice, "measured_s": self.measured_s}

    @staticmethod
    def from_json(d: Dict) -> "PolicyEntry":
        return PolicyEntry(
            choice=str(d["choice"]),
            measured_s={k: float(v) for k, v in d.get("measured_s", {}).items()},
        )


@dataclasses.dataclass
class PlanEntry:
    """One tuned *query plan* for a (template-signature, graph-stats) bucket:
    the ordered constraint phases — each a dict with the constraint signature
    (``"cycle:0,1,2,0"``), the engine choice (``"nlcc"``/``"tds"``), and the
    walk-direction choice (``"default"``/``"fwd"``/``"rev"``/``"head"``) —
    plus the cost model's prediction and any measured comparison."""

    phases: List[Dict] = dataclasses.field(default_factory=list)
    predicted_s: float = 0.0
    measured_s: Dict[str, float] = dataclasses.field(default_factory=dict)

    def signatures(self) -> List[str]:
        return [str(p["sig"]) for p in self.phases]

    def to_json(self) -> Dict:
        return {
            "phases": self.phases,
            "predicted_s": self.predicted_s,
            "measured_s": self.measured_s,
        }

    @staticmethod
    def from_json(d: Dict) -> "PlanEntry":
        phases = [dict(p) for p in d["phases"]]
        for p in phases:
            p["sig"]  # KeyError on malformed phase → entry skipped by caller
        return PlanEntry(
            phases=phases,
            predicted_s=float(d.get("predicted_s", 0.0)),
            measured_s={k: float(v) for k, v in d.get("measured_s", {}).items()},
        )


# The single plan-table route name: plan keys render as
# ``prune.plan|<backend>|<template-sig>x<stats-bucket>``.
PLAN_ROUTE = "prune.plan"

POLICY_SCHEMA_VERSION = 1


@dataclasses.dataclass
class DispatchPolicy:
    """Measured-cost dispatch table, keyed "<name>|<backend>|<bucket>".

    `modes` holds per-kernel mode decisions ("pallas"/"interpret"/"ref");
    `routes` holds above-kernel routing decisions ("packed"/"unpacked"/
    "fused"). Lookup tries the exact bucket first, then the ``*`` wildcard
    bucket.
    """

    modes: Dict[str, PolicyEntry] = dataclasses.field(default_factory=dict)
    routes: Dict[str, PolicyEntry] = dataclasses.field(default_factory=dict)
    plans: Dict[str, PlanEntry] = dataclasses.field(default_factory=dict)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # -- lookup
    def _lookup(self, table: Dict[str, PolicyEntry], name, backend, bucket):
        entry = table.get(_entry_key(name, backend, bucket))
        if (entry is None and isinstance(bucket, tuple)
                and bucket[:1] == ("b1",)):
            # batch-size-1 forward-compat: a pre-batching cache has no
            # ``b1`` entries, but its unbatched decision is exactly the
            # B=1 decision — resolve it before falling to the wildcard
            unbatched = bucket[1:] if len(bucket) > 1 else BUCKET_ANY
            entry = table.get(_entry_key(name, backend, unbatched))
        if entry is None and bucket != BUCKET_ANY:
            entry = table.get(_entry_key(name, backend, BUCKET_ANY))
        return entry

    def mode_for(self, name: str, backend: str, bucket) -> Optional[str]:
        entry = self._lookup(self.modes, name, backend, bucket)
        return entry.choice if entry is not None else None

    def route_for(self, name: str, backend: str, bucket) -> Optional[str]:
        entry = self._lookup(self.routes, name, backend, bucket)
        return entry.choice if entry is not None else None

    def route_entry_for(self, name: str, backend: str, bucket
                        ) -> Optional[PolicyEntry]:
        """Full tuned route entry (choice + measured_s), with the same
        exact-then-wildcard bucket lookup as `route_for` — the public way to
        read measurements back out (benchmarks, roll-ups)."""
        return self._lookup(self.routes, name, backend, bucket)

    def plan_for(self, backend: str, bucket) -> Optional["PlanEntry"]:
        """Tuned plan for a (template-sig, stats-bucket) bucket — exact key
        only: a plan never transfers across templates or graph-stats classes,
        so there is no wildcard fallback."""
        return self.plans.get(_entry_key(PLAN_ROUTE, backend, bucket))

    # -- mutation
    def set_mode(self, name: str, backend: str, bucket, choice: str,
                 measured_s: Optional[Dict[str, float]] = None):
        if choice not in MODES:
            raise ValueError(f"unknown mode {choice!r}; expected one of {MODES}")
        self.modes[_entry_key(name, backend, bucket)] = PolicyEntry(
            choice, dict(measured_s or {}))

    def set_route(self, name: str, backend: str, bucket, choice: str,
                  measured_s: Optional[Dict[str, float]] = None):
        self.routes[_entry_key(name, backend, bucket)] = PolicyEntry(
            choice, dict(measured_s or {}))

    def set_plan(self, backend: str, bucket, entry: "PlanEntry"):
        self.plans[_entry_key(PLAN_ROUTE, backend, bucket)] = entry

    # -- persistence
    def to_json(self) -> Dict:
        out = {
            "schema_version": POLICY_SCHEMA_VERSION,
            "meta": self.meta,
            "modes": {k: e.to_json() for k, e in sorted(self.modes.items())},
            "routes": {k: e.to_json() for k, e in sorted(self.routes.items())},
        }
        if self.plans:
            # additive field: a pre-plan reader's from_json ignores unknown
            # keys, so schema_version stays 1
            out["plans"] = {k: e.to_json() for k, e in sorted(self.plans.items())}
        return out

    @staticmethod
    def from_json(d: Dict) -> "DispatchPolicy":
        ver = d.get("schema_version")
        if ver != POLICY_SCHEMA_VERSION:
            raise ValueError(
                f"dispatch policy schema_version {ver!r} != "
                f"{POLICY_SCHEMA_VERSION}; re-run registry.tune()"
            )
        plans: Dict[str, PlanEntry] = {}
        for k, e in d.get("plans", {}).items():
            try:
                plans[k] = PlanEntry.from_json(e)
            except (KeyError, TypeError, ValueError) as err:
                # a malformed plan entry must not take down the mode/route
                # tables it rides along with — skip just the entry
                warnings.warn(
                    f"ignoring malformed plan cache entry {k!r}: {err}",
                    RuntimeWarning, stacklevel=2,
                )
        return DispatchPolicy(
            modes={k: PolicyEntry.from_json(e) for k, e in d.get("modes", {}).items()},
            routes={k: PolicyEntry.from_json(e) for k, e in d.get("routes", {}).items()},
            plans=plans,
            meta=dict(d.get("meta", {})),
        )

    def save(self, path: Optional[str] = None) -> str:
        path = path or policy_path()
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1, sort_keys=True)
        return path

    @staticmethod
    def load(path: Optional[str] = None) -> "DispatchPolicy":
        path = path or policy_path()
        with open(path) as f:
            return DispatchPolicy.from_json(json.load(f))


DEFAULT_POLICY_PATH = os.path.join("experiments", "policy", "dispatch_policy.json")


def policy_path() -> str:
    """Where the persisted policy cache lives (env REPRO_DISPATCH_POLICY wins)."""
    return os.environ.get("REPRO_DISPATCH_POLICY", DEFAULT_POLICY_PATH)


_POLICY_UNSET = object()
_POLICY: Any = _POLICY_UNSET


def set_policy(policy: Optional[DispatchPolicy]) -> None:
    """Install `policy` as the active dispatch policy (None = explicitly no
    policy: pure eligibility/trap fallback, no lazy cache load)."""
    global _POLICY
    _POLICY = policy


def clear_policy() -> None:
    """Forget the active policy; the next lookup lazily re-reads the cache."""
    global _POLICY
    _POLICY = _POLICY_UNSET


def get_policy() -> Optional[DispatchPolicy]:
    """The active policy: whatever `set_policy` installed, else the persisted
    cache at `policy_path()` if one exists (loaded once), else None."""
    global _POLICY
    if _POLICY is _POLICY_UNSET:
        path = policy_path()
        if os.path.exists(path):
            try:
                _POLICY = DispatchPolicy.load(path)
            except (ValueError, KeyError, json.JSONDecodeError, OSError) as e:
                warnings.warn(
                    f"ignoring unreadable dispatch policy cache {path!r}: {e}",
                    RuntimeWarning, stacklevel=2,
                )
                _POLICY = None
        else:
            _POLICY = None
    return _POLICY


# ------------------------------------------------------ resilience seam
# `mode_override` is the degradation ladder's "ref rung" (core/resilience.py):
# every dispatch inside the context resolves to the given mode (in practice
# MODE_REF), sidestepping a kernel that keeps failing. force_pallas still
# wins — parity tests pin the kernel path even under an active ladder.
_MODE_OVERRIDE: Optional[str] = None

# `set_dispatch_hook` installs a callable invoked as hook(name, mode) right
# before every kernel executes; it may raise (the fault-injection seam). One
# hook at a time — dispatch is a global choke point.
_DISPATCH_HOOK: Optional[Callable[[str, str], None]] = None


@contextlib.contextmanager
def mode_override(mode: str):
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}; expected one of {MODES}")
    global _MODE_OVERRIDE
    prev = _MODE_OVERRIDE
    _MODE_OVERRIDE = mode
    try:
        yield
    finally:
        _MODE_OVERRIDE = prev


def set_dispatch_hook(hook: Optional[Callable[[str, str], None]]) -> None:
    global _DISPATCH_HOOK
    _DISPATCH_HOOK = hook


def get_dispatch_hook() -> Optional[Callable[[str, str], None]]:
    return _DISPATCH_HOOK


@contextlib.contextmanager
def dispatch_hook(hook: Callable[[str, str], None]):
    prev = _DISPATCH_HOOK
    set_dispatch_hook(hook)
    try:
        yield
    finally:
        set_dispatch_hook(prev)


def _modes_runnable(backend: str) -> Tuple[str, ...]:
    """Modes that can actually execute on `backend` (for an eligible call)."""
    if backend == "tpu":
        return (MODE_PALLAS, MODE_INTERPRET, MODE_REF)
    return (MODE_INTERPRET, MODE_REF)


# ----------------------------------------------------------------- routing
def resolve_mode(
    name: str,
    *args,
    force_pallas: bool = False,
    backend: Optional[str] = None,
    **kwargs,
) -> str:
    """The routing decision `dispatch` will take, without executing anything.

    Order: eligibility (a shape the kernel cannot express is always "ref"),
    then the tuned policy for this (kernel, backend, bucket) — skipped under
    force_pallas, which pins the kernel path for parity tests — then the
    untuned fallback (TPU -> pallas, forced -> interpret, else ref)."""
    spec = get(name)
    if not spec.eligible(*args, **kwargs):
        return MODE_REF
    be = backend or jax.default_backend()
    if _MODE_OVERRIDE is not None and not force_pallas:
        return _MODE_OVERRIDE
    if not force_pallas:
        policy = get_policy()
        if policy is not None:
            choice = policy.mode_for(name, be, spec.bucket(*args, **kwargs))
            if choice is not None and choice in _modes_runnable(be):
                return choice
    if be == "tpu":
        return MODE_PALLAS
    if force_pallas:
        return MODE_INTERPRET
    return MODE_REF


def resolve_route(
    name: str,
    bucket=BUCKET_ANY,
    *,
    default: str,
    backend: Optional[str] = None,
    allowed: Optional[Sequence[str]] = None,
) -> str:
    """Above-kernel routing decision (e.g. packed vs unpacked `prune` paths):
    the tuned policy's choice for (name, backend, bucket) when one exists,
    else `default` — which callers set to today's hardcoded behavior, so an
    untuned checkout routes exactly as before. With `allowed` set, a cache
    entry outside it (hand-edited typo, stale candidate name) falls back to
    `default` deterministically instead of leaking into comparisons."""
    be = backend or jax.default_backend()
    policy = get_policy()
    if policy is not None:
        choice = policy.route_for(name, be, bucket)
        if choice is not None and (allowed is None or choice in allowed):
            return choice
    return default


def resolve_plan(
    bucket,
    signatures: Sequence[str],
    *,
    backend: Optional[str] = None,
) -> Optional[PlanEntry]:
    """Tuned query plan for a (template-sig, stats-bucket) bucket, validated
    against the constraint signatures the template *currently* generates.

    Returns None (→ caller uses the paper's heuristic order) when there is no
    active policy, the policy has no plan for this bucket, or the cached plan
    is *stale*: its phase-signature multiset no longer matches `signatures`
    (the template changed, or constraint generation itself changed). Stale
    entries are ignored with a warning rather than half-applied — a plan that
    drops or invents a constraint is unsound, not just slow."""
    policy = get_policy()
    if policy is None or not policy.plans:
        return None
    be = backend or jax.default_backend()
    entry = policy.plan_for(be, bucket)
    if entry is None:
        return None
    if sorted(entry.signatures()) != sorted(str(s) for s in signatures):
        warnings.warn(
            f"ignoring stale plan cache entry for bucket "
            f"{_bucket_key(bucket)!r}: cached constraint signatures "
            f"{sorted(entry.signatures())} != current "
            f"{sorted(str(s) for s in signatures)}; re-run the planner",
            RuntimeWarning, stacklevel=2,
        )
        return None
    return entry


def dispatch(
    name: str,
    *args,
    force_pallas: bool = False,
    backend: Optional[str] = None,
    **kwargs,
):
    """Run kernel `name` through the mode `resolve_mode` picks."""
    spec = get(name)
    mode = resolve_mode(
        name, *args, force_pallas=force_pallas, backend=backend, **kwargs
    )
    if _DISPATCH_HOOK is not None:
        _DISPATCH_HOOK(name, mode)  # may raise: the fault-injection seam
    if mode == MODE_REF:
        return spec.ref_fn(*args, **kwargs)
    try:
        return spec.pallas_fn(*args, interpret=(mode == MODE_INTERPRET), **kwargs)
    except compat.PALLAS_TRAP_ERRORS as e:
        if force_pallas:
            raise
        warnings.warn(
            f"pallas kernel {name!r} failed on jax=={jax.__version__} "
            f"({type(e).__name__}: {e}); falling back to the reference oracle",
            RuntimeWarning,
            stacklevel=2,
        )
        return spec.ref_fn(*args, **kwargs)


# ---------------------------------------------------------------- autotune
def _time_thunk(thunk: Callable[[], Any], repeat: int) -> float:
    """Best wall-time over `repeat` runs, after one warmup (compile) run;
    device work is synchronized out via block_until_ready."""

    def run_once():
        out = thunk()
        try:
            jax.block_until_ready(out)
        except TypeError:  # non-array output (host dict / python scalar)
            pass
        return out

    run_once()
    best = float("inf")
    for _ in range(max(repeat, 1)):
        t0 = time.perf_counter()
        run_once()
        best = min(best, time.perf_counter() - t0)
    return best


def _mode_thunk(spec: KernelSpec, mode: str, args, kwargs) -> Callable[[], Any]:
    if mode == MODE_REF:
        return lambda: spec.ref_fn(*args, **kwargs)
    return lambda: spec.pallas_fn(
        *args, interpret=(mode == MODE_INTERPRET), **kwargs)


def tune(
    cases: Iterable[Tuple[str, Sequence[Any], Dict[str, Any]]] = (),
    routes: Iterable[Tuple[str, Any, Dict[str, Callable[[], Any]]]] = (),
    *,
    repeat: int = 3,
    policy: Optional[DispatchPolicy] = None,
    path: Optional[str] = None,
    persist: bool = True,
    backend: Optional[str] = None,
) -> DispatchPolicy:
    """Microbenchmark autotuner: measure every runnable variant on the live
    backend and record the winners in a `DispatchPolicy`.

    cases   iterable of (kernel_name, args, kwargs): for each, every mode that
            can run here (ref everywhere; interpret when eligible; compiled
            pallas only on TPU) is timed and the fastest becomes the decision
            for that call's shape bucket.
    routes  iterable of (route_name, bucket, {candidate: thunk}): each thunk
            is timed as-is; the fastest candidate becomes the route decision
            (e.g. "packed"/"unpacked" prune routing).
    repeat  timing repeats per candidate (best-of, after a warmup run).
    policy  extend this policy instead of starting fresh; when omitted, an
            existing readable cache at the target path is loaded and
            extended — tune() never invalidates decisions it didn't re-measure
            (an unreadable/stale-schema cache is still replaced).
    path/persist  where (and whether) to save the JSON cache; the tuned
            policy is installed as the active one either way.

    An interpret-mode candidate that traps on API drift is recorded as
    unrunnable (inf) rather than aborting the tune.
    """
    be = backend or jax.default_backend()
    pol = policy
    if pol is None:
        target = path or policy_path()
        if os.path.exists(target):
            try:
                pol = DispatchPolicy.load(target)
            except (ValueError, KeyError, json.JSONDecodeError, OSError):
                pol = None  # unreadable cache: tune from scratch, overwrite
    if pol is None:
        pol = DispatchPolicy()
    pol.meta.update({
        "backend": be,
        "jax": jax.__version__,
        "repeat": int(repeat),
        "tuned_unix": time.time(),
    })

    for name, args, kwargs in cases:
        spec = get(name)
        if not spec.eligible(*args, **kwargs):
            continue  # ineligible shapes are always "ref"; nothing to decide
        bucket = spec.bucket(*args, **kwargs)
        measured: Dict[str, float] = {}
        for mode in _modes_runnable(be):
            try:
                measured[mode] = _time_thunk(
                    _mode_thunk(spec, mode, args, kwargs), repeat)
            except compat.PALLAS_TRAP_ERRORS:
                measured[mode] = float("inf")
        winner = min(measured, key=measured.get)
        pol.set_mode(name, be, bucket, winner, measured)

    # install the tuned kernel modes BEFORE timing routes: route thunks go
    # through dispatch(), so packed-vs-unpacked must be measured under the
    # kernel modes that will actually serve the winning route
    set_policy(pol)

    for name, bucket, candidates in routes:
        measured = {}
        for cand, thunk in candidates.items():
            measured[cand] = _time_thunk(thunk, repeat)
        winner = min(measured, key=measured.get)
        pol.set_route(name, be, bucket, winner, measured)

    if persist:
        pol.save(path)
    set_policy(pol)
    return pol

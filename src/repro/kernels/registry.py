"""Declarative kernel registry — one `dispatch()` for every Pallas kernel.

Each kernel registers three things:

  pallas_fn   the Pallas entrypoint, called as pallas_fn(*args, interpret=…, **kw)
  ref_fn      the pure-jnp oracle from ref.py with the same call signature
              (minus `interpret`) and identical numerics contract
  eligible    a shape-eligibility predicate over the same arguments: False
              means the Pallas formulation cannot express this call (missing
              blocked structure, tile-misaligned shapes, d_qk != d_v, …)

`dispatch(name, *args, force_pallas=…, backend=…, **kw)` then picks exactly
one of three modes (`resolve_mode` exposes the decision for tests):

  "pallas"     compiled Pallas — eligible call on a TPU backend
  "interpret"  Pallas interpreter — eligible call, force_pallas=True off-TPU
               (the kernel-parity test path)
  "ref"        reference oracle — ineligible shapes, or off-TPU without
               force_pallas

A Pallas attempt that dies with an API-drift error (compat.PALLAS_TRAP_ERRORS)
is trapped and re-run through the reference oracle — unless force_pallas was
set, in which case the error propagates so parity tests stay strict.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from repro.kernels import compat

MODE_PALLAS = "pallas"
MODE_INTERPRET = "interpret"
MODE_REF = "ref"


def _always_eligible(*args, **kwargs) -> bool:
    return True


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    name: str
    pallas_fn: Callable[..., Any]
    ref_fn: Callable[..., Any]
    eligible: Callable[..., bool]
    doc: str = ""


_REGISTRY: Dict[str, KernelSpec] = {}


def register(
    name: str,
    *,
    pallas: Callable[..., Any],
    ref: Callable[..., Any],
    eligible: Callable[..., bool] = _always_eligible,
    doc: str = "",
) -> KernelSpec:
    """Register (or re-register) a kernel under `name`."""
    spec = KernelSpec(name=name, pallas_fn=pallas, ref_fn=ref,
                      eligible=eligible, doc=doc)
    _REGISTRY[name] = spec
    return spec


def get(name: str) -> KernelSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no kernel {name!r} registered; known: {sorted(_REGISTRY)}"
        ) from None


def names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def resolve_mode(
    name: str,
    *args,
    force_pallas: bool = False,
    backend: Optional[str] = None,
    **kwargs,
) -> str:
    """The routing decision `dispatch` will take, without executing anything."""
    spec = get(name)
    if not spec.eligible(*args, **kwargs):
        return MODE_REF
    if (backend or jax.default_backend()) == "tpu":
        return MODE_PALLAS
    if force_pallas:
        return MODE_INTERPRET
    return MODE_REF


def dispatch(
    name: str,
    *args,
    force_pallas: bool = False,
    backend: Optional[str] = None,
    **kwargs,
):
    """Run kernel `name` through the mode `resolve_mode` picks."""
    spec = get(name)
    mode = resolve_mode(
        name, *args, force_pallas=force_pallas, backend=backend, **kwargs
    )
    if mode == MODE_REF:
        return spec.ref_fn(*args, **kwargs)
    try:
        return spec.pallas_fn(*args, interpret=(mode == MODE_INTERPRET), **kwargs)
    except compat.PALLAS_TRAP_ERRORS as e:
        if force_pallas:
            raise
        warnings.warn(
            f"pallas kernel {name!r} failed on jax=={jax.__version__} "
            f"({type(e).__name__}: {e}); falling back to the reference oracle",
            RuntimeWarning,
            stacklevel=2,
        )
        return spec.ref_fn(*args, **kwargs)

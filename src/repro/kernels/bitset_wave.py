"""`bitset_wave` — fused multi-hop bit-packed OR-SpMM, the NLCC wave on TPU.

The NLCC token-passing wave (paper Alg. 5/6) is L repetitions of the same
blocked OR-SpMM as `bitset_spmm`, each followed by a per-hop candidacy mask:

    F_r = (OR_{arc (u -> v) active} F_{r-1}[u]) & cand[r]        r = 1..L

The single-hop route launches one `bitset_spmm` per hop, so every hop pays
kernel-boundary traffic around the frontier (and, off-TPU, a pack/unpack
round-trip through the oracle). Here the whole wave runs inside ONE
`pallas_call` with the packed frontier resident in VMEM across all hops:

  grid = (L, nnzb) — hops major, dst-sorted adjacency blocks minor.
  `cur` scratch uint32[n_pad, W] holds frontier F_{h}; the output block
  (constant index map, VMEM-resident for the whole grid) accumulates F_{h+1}.
  Per (h, b) step the (dst_block, src_block) bitmask is unpacked and
  contracted against the cur rows of the src block on the MXU, exactly like
  `bitset_spmm`; at each step the dst row of the output is rewritten as
  pack(acc > 0) & cand[h] (final at the row's last block). At the first step
  of hop h+1 the output buffer is copied into `cur` and zeroed — the only
  frontier movement between hops is VMEM -> VMEM.

Pack/unpack therefore happens ONCE per wave (in the caller), not once per
hop, and the per-hop block bitmasks are shared across hops (edge_active is
constant within a wave).

VMEM budget per step (bn=256, W=32, n_pad=2048):
  cur + out 2 x 256 KiB, vals 256 KiB, acc 256x1024 f32 = 1 MiB,
  mask block 8 KiB, cand row 8 KiB — ~1.8 MiB, comfortably inside 16 MiB.
The ops-layer eligibility predicate rejects shapes whose resident frontier
would blow the budget (huge n_pad x W), routing them to the oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat
from repro.kernels.bitset_spmm import _pack_bool_u32, _unpack_words_f32


def _kernel(pairs_ref, vals_ref, cand_ref, mask_ref, out_ref, cur_ref, acc_ref):
    h = pl.program_id(0)
    b = pl.program_id(1)
    bn = acc_ref.shape[0]

    # hop boundary: load the initial frontier (hop 0) or advance the wave
    # (copy last hop's completed output into cur), then clear the output —
    # dst blocks no adjacency block touches must aggregate to zero.
    @pl.when(jnp.logical_and(h == 0, b == 0))
    def _load_initial():
        cur_ref[...] = vals_ref[...]
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(jnp.logical_and(h > 0, b == 0))
    def _advance_hop():
        cur_ref[...] = out_ref[...]
        out_ref[...] = jnp.zeros_like(out_ref)

    db = pairs_ref[b, 0]
    sb = pairs_ref[b, 1]
    prev_db = pairs_ref[jnp.maximum(b, 1) - 1, 0]
    first = jnp.logical_or(b == 0, db != prev_db)

    mask_f = _unpack_words_f32(mask_ref[0])                     # [BN, BN]
    src_rows = cur_ref[pl.ds(pl.multiple_of(sb * bn, bn), bn), :]
    vals_f = _unpack_words_f32(src_rows)                        # [BN, 32W]
    partial = jax.lax.dot_general(
        mask_f, vals_f, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                           # [BN, 32W]

    @pl.when(first)
    def _reset():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += partial
    # Rewritten every step of the dst row; final (and masked by this hop's
    # candidacy) at the row's last block — nothing reads it before hop h+1.
    row = pl.ds(pl.multiple_of(db * bn, bn), bn)
    cw = cand_ref[0, row]
    out_ref[row, :] = _pack_bool_u32(acc_ref[...] > 0.5) & cw[:, None]


@functools.partial(jax.jit, static_argnames=("bn", "n_pad", "interpret"))
def bitset_wave(
    pairs: jnp.ndarray,   # int32[nnzb, 2] (dst_block, src_block), dst-sorted
    masks: jnp.ndarray,   # uint32[nnzb, BN, BN//32] dynamic active bitmasks
    vals: jnp.ndarray,    # uint32[n_pad, W] packed initial frontier (hop 0)
    cand: jnp.ndarray,    # uint32[L, n_pad] per-hop candidacy, 0 / 0xFFFFFFFF
    *,
    bn: int,
    n_pad: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """Run the full L-hop wave; returns the hop-L frontier uint32[n_pad, W]."""
    nnzb = masks.shape[0]
    n_hops = cand.shape[0]
    w = vals.shape[1]
    grid_spec = compat.prefetch_scalar_grid_spec(
        num_scalar_prefetch=1,
        grid=(n_hops, nnzb),
        in_specs=[
            pl.BlockSpec((n_pad, w), lambda h, b, pairs: (0, 0)),
            pl.BlockSpec((1, n_pad), lambda h, b, pairs: (h, 0)),
            pl.BlockSpec((1, bn, bn // 32), lambda h, b, pairs: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((n_pad, w), lambda h, b, pairs: (0, 0)),
        scratch_shapes=[
            compat.vmem((n_pad, w), jnp.uint32),
            compat.vmem((bn, 32 * w), jnp.float32),
        ],
    )
    return compat.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad, w), jnp.uint32),
        interpret=interpret,
        dimension_semantics=("arbitrary", "arbitrary"),
    )(pairs, vals, cand, masks)

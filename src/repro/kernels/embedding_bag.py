"""`embedding_bag` — gather + in-VMEM bag reduction for the recsys hot path.

JAX has no native EmbeddingBag; the reference path is jnp.take +
segment_sum, which round-trips the gathered [B*L, D] tensor through HBM.
This kernel streams table rows straight into a VMEM accumulator:

  grid = (B * L,)  — one (bag, slot) per step, sequential
  the ids are *scalar-prefetched*, and the table BlockSpec index map uses
  ids[i] directly: the pipeline prefetches exactly the rows it needs from the
  (huge, HBM-resident, vocab-sharded) table. The bag accumulator lives in
  VMEM scratch; the out block (index i // L) is revisited for L consecutive
  steps and written each step — final at the bag's last slot.

Padding slots use id 0 with weight 0 (host-side contract), so they add the
identity. `mode="mean"` divides by the (prefetched) bag length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat


def _kernel(ids_ref, weights_ref, counts_ref, row_ref, out_ref, acc_ref, *, l, mean):
    i = pl.program_id(0)
    slot = i % l

    @pl.when(slot == 0)
    def _reset():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = weights_ref[i]
    acc_ref[...] += row_ref[...].astype(jnp.float32) * w
    scale = 1.0
    if mean:
        scale = 1.0 / jnp.maximum(counts_ref[i // l].astype(jnp.float32), 1.0)
    out_ref[...] = (acc_ref[...] * scale).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def embedding_bag(
    table: jnp.ndarray,    # [V, D]
    ids: jnp.ndarray,      # int32[B, L]   (padding: id 0)
    weights: jnp.ndarray,  # f32[B, L]     (padding: 0.0)
    *,
    mode: str = "sum",
    interpret: bool = False,
) -> jnp.ndarray:
    assert mode in ("sum", "mean")
    bsz, l = ids.shape
    v, d = table.shape
    flat_ids = ids.reshape(-1)
    flat_w = weights.reshape(-1)
    counts = jnp.sum((weights != 0.0).astype(jnp.int32), axis=1)

    grid_spec = compat.prefetch_scalar_grid_spec(
        num_scalar_prefetch=3,  # ids, weights, counts
        grid=(bsz * l,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, ids, w, c: (ids[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, ids, w, c: (i // l, 0)),
        scratch_shapes=[compat.vmem((1, d), jnp.float32)],
    )
    kernel = functools.partial(_kernel, l=l, mean=(mode == "mean"))
    return compat.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, d), table.dtype),
        dimension_semantics=("arbitrary",),
        interpret=interpret,
    )(flat_ids, flat_w, counts, table)

"""Version-adaptive JAX/Pallas compatibility layer — the ONE choke point.

Invariant (recorded in ROADMAP.md): every version-gated or backend-specific
JAX API surface is resolved here and nowhere else. Concretely:

  - the TPU Pallas compiler-params class (``CompilerParams`` on newer JAX,
    ``TPUCompilerParams`` on the 0.4.x line) — use :func:`tpu_compiler_params`
    or, better, pass ``dimension_semantics=`` to :func:`pallas_call`,
  - scratch/memory-space constructors (:func:`vmem`, :func:`smem`) and
    :func:`prefetch_scalar_grid_spec`,
  - mesh construction (:func:`make_mesh` accepts ``axis_types`` names on every
    version and silently drops them where ``jax.sharding.AxisType`` does not
    exist yet),
  - :func:`shard_map` (moved from ``jax.experimental.shard_map`` to
    ``jax.shard_map``; ``check_rep`` was renamed ``check_vma``).

Kernel modules call :func:`pallas_call`; the dispatch registry
(``repro.kernels.registry``) decides compiled / interpret / reference per
call. Nothing outside this file may import ``jax.experimental.pallas.tpu``
symbols that differ across versions, spell a compiler-params class name, or
touch ``jax.sharding.AxisType`` directly.
"""
from __future__ import annotations

import functools
import inspect
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _version_tuple(v: str) -> Tuple[int, ...]:
    parts = []
    for p in v.split(".")[:3]:
        digits = "".join(ch for ch in p if ch.isdigit())
        parts.append(int(digits) if digits else 0)
    return tuple(parts)


JAX_VERSION: Tuple[int, ...] = _version_tuple(jax.__version__)


# ------------------------------------------------------------------ pallas
# The TPU compiler-params class was renamed across the 0.4 -> 0.5 line.
_TPU_COMPILER_PARAMS_CLS = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)


def tpu_compiler_params(
    *, dimension_semantics: Optional[Sequence[str]] = None, **kwargs
):
    """Build the TPU compiler-params object for this JAX, or None when the
    installed version exposes no such class (the kwarg is then omitted)."""
    if _TPU_COMPILER_PARAMS_CLS is None:
        return None
    if dimension_semantics is not None:
        kwargs["dimension_semantics"] = tuple(dimension_semantics)
    return _TPU_COMPILER_PARAMS_CLS(**kwargs)


def vmem(shape: Sequence[int], dtype) -> Any:
    """VMEM scratch-shape constructor (pltpu.VMEM resolved here)."""
    return pltpu.VMEM(tuple(shape), dtype)


def smem(shape: Sequence[int], dtype) -> Any:
    """SMEM scratch-shape constructor (pltpu.SMEM resolved here)."""
    return pltpu.SMEM(tuple(shape), dtype)


def prefetch_scalar_grid_spec(
    *,
    num_scalar_prefetch: int,
    grid: Sequence[int],
    in_specs: Sequence[Any],
    out_specs: Any,
    scratch_shapes: Sequence[Any] = (),
):
    """Scalar-prefetch grid spec (index maps may read the prefetched operands)."""
    cls = getattr(pltpu, "PrefetchScalarGridSpec", None)
    if cls is None:  # pragma: no cover - future JAX where it merges into pl
        raise NotImplementedError(
            "this JAX exposes no scalar-prefetch grid spec; extend "
            "repro.kernels.compat.prefetch_scalar_grid_spec for "
            f"jax=={jax.__version__}"
        )
    return cls(
        num_scalar_prefetch=num_scalar_prefetch,
        grid=tuple(grid),
        in_specs=list(in_specs),
        out_specs=out_specs,
        scratch_shapes=list(scratch_shapes),
    )


def pallas_call(
    kernel,
    *,
    out_shape,
    grid: Optional[Sequence[int]] = None,
    grid_spec=None,
    in_specs=None,
    out_specs=None,
    scratch_shapes: Sequence[Any] = (),
    dimension_semantics: Optional[Sequence[str]] = None,
    interpret: bool = False,
    **extra,
):
    """`pl.pallas_call` with the version differences absorbed.

    Pass ``dimension_semantics`` directly; it is wrapped into whichever
    compiler-params class this JAX spells. ``interpret=True`` runs the kernel
    in the Pallas interpreter (the non-TPU path the registry dispatches for
    ``force_pallas`` tests); a compiled call on a TPU backend leaves it False.
    """
    kwargs = dict(out_shape=out_shape, interpret=interpret, **extra)
    if grid_spec is not None:
        kwargs["grid_spec"] = grid_spec
    else:
        if grid is not None:
            kwargs["grid"] = tuple(grid)
        if in_specs is not None:
            kwargs["in_specs"] = list(in_specs)
        if out_specs is not None:
            kwargs["out_specs"] = out_specs
        if scratch_shapes:
            kwargs["scratch_shapes"] = list(scratch_shapes)
    try:
        # constructed inside the try: a params class that lost the
        # dimension_semantics field is the same signature-drift case as a
        # pallas_call that rejects compiler_params — both retry without it
        params = tpu_compiler_params(dimension_semantics=dimension_semantics)
        if params is not None:
            kwargs["compiler_params"] = params
        return pl.pallas_call(kernel, **kwargs)
    except TypeError:
        kwargs.pop("compiler_params", None)
        return pl.pallas_call(kernel, **kwargs)


# Exceptions that mean "this Pallas/JAX combination cannot express the kernel"
# (renamed/removed API symbols, missing lowering) rather than a caller bug.
# The registry traps these and falls back to the reference oracle unless
# force_pallas is set. TypeError is deliberately NOT trapped: signature drift
# is already absorbed by the pallas_call wrapper's own retry above, so a
# TypeError escaping a kernel is almost always a real shape/dtype bug that
# must surface, not be silently downgraded to the 8-32x-slower oracle.
PALLAS_TRAP_ERRORS: Tuple[type, ...] = (
    AttributeError,
    NotImplementedError,
)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# -------------------------------------------------------------------- mesh
_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


_AXIS_TYPE_NAMES = ("auto", "explicit", "manual")


def axis_type(kind: str = "auto"):
    """Resolve an axis-type name ("auto" | "explicit" | "manual") to this
    version's jax.sharding.AxisType member, or None where the enum does not
    exist (pre-sharding-in-types JAX treats every axis as auto). Names are
    validated on EVERY version so a typo fails identically everywhere."""
    if kind not in _AXIS_TYPE_NAMES:
        raise ValueError(
            f"unknown axis type {kind!r}; expected one of {_AXIS_TYPE_NAMES}"
        )
    if not _HAS_AXIS_TYPE:
        return None
    enum = jax.sharding.AxisType
    return {
        "auto": enum.Auto,
        "explicit": enum.Explicit,
        "manual": enum.Manual,
    }[kind]


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    axis_types: Optional[Sequence[str]] = None,
    devices=None,
):
    """`jax.make_mesh` that accepts axis-type *names* on every JAX version.

    ``axis_types`` entries are strings ("auto"/"explicit"/"manual"); they are
    resolved against this version's enum and dropped entirely where the
    installed JAX predates typed mesh axes (its meshes are implicitly auto).
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    maker = getattr(jax, "make_mesh", None)
    if maker is None:  # pragma: no cover - ancient JAX
        from jax.experimental import mesh_utils

        devs = devices if devices is not None else mesh_utils.create_device_mesh(
            tuple(axis_shapes)
        )
        return jax.sharding.Mesh(devs, tuple(axis_names))
    if axis_types is not None:
        # resolve on every version: validates the names even where the enum
        # is absent and the annotation is ultimately dropped
        resolved = tuple(axis_type(t) for t in axis_types)
    if axis_types is not None and _HAS_AXIS_TYPE:
        if "axis_types" in inspect.signature(maker).parameters:
            try:
                return maker(
                    tuple(axis_shapes), tuple(axis_names),
                    axis_types=resolved, **kwargs,
                )
            except TypeError:
                pass
    return maker(tuple(axis_shapes), tuple(axis_names), **kwargs)


# --------------------------------------------------------------- shard_map
def shard_map(f, *, mesh, in_specs, out_specs, check_vma: Optional[bool] = None):
    """shard_map across its module move and the check_rep->check_vma rename."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm

    base = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    if check_vma is None:
        return sm(f, **base)
    # the replication-check kwarg was renamed check_rep -> check_vma; try the
    # new spelling, then the old, and only then drop it (a caller passing
    # False usually has a function that is NOT replication-safe, so silently
    # re-enabling the check would break them at trace time)
    for key in ("check_vma", "check_rep"):
        try:
            return sm(f, **base, **{key: check_vma})
        except TypeError:
            continue
    return sm(f, **base)

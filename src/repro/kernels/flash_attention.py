"""Flash attention (forward) — Pallas TPU kernel with BlockSpec VMEM tiling.

Supports causal masking, GQA (kv_heads <= q_heads resolved in the K/V
BlockSpec index maps — no materialized head repeat), and sliding-window
attention (StarCoder2's sub-quadratic regime for long_500k).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) with the kv dimension
innermost ("arbitrary" — carries the online-softmax state); the first three
dims are embarrassingly parallel. Online softmax state per q block:
  m   f32[bq, MIN_LANE]  running row max (lane-replicated)
  l   f32[bq, MIN_LANE]  running denominator
  acc f32[bq, d]         unnormalized output
Output is normalized and written at the last kv step of each q block.

VMEM per step (bq=bk=128, d=128): q/k/v tiles 3x64 KiB bf16 + acc 64 KiB f32
+ state — well inside VMEM; both matmuls are 128x128x128 MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat

MIN_LANE = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                 *, scale, causal, window, bq, bk, num_kv_blocks):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    live = jnp.ones((bq, bk), dtype=bool)
    if causal:
        live &= q_pos >= k_pos
    if window is not None:
        live &= k_pos > q_pos - window

    # Entire tile masked out (strict upper triangle / outside the window):
    # skip the matmuls, state is unchanged.
    block_live = True
    if causal:
        block_live = jnp.logical_and(block_live, qi * bq + bq - 1 >= ki * bk)
    if window is not None:
        block_live = jnp.logical_and(block_live, ki * bk + bk - 1 > qi * bq - window)

    @pl.when(block_live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)                  # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [bq, bk]
        s = jnp.where(live, s, NEG_INF)
        m_prev = m_ref[...]                                  # [bq, MIN_LANE]
        m_cur = jnp.max(s, axis=1, keepdims=True)            # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.broadcast_to(m_cur, m_prev.shape))
        alpha = jnp.exp(m_prev[:, :1] - m_new[:, :1])        # [bq, 1]
        p = jnp.exp(s - m_new[:, :1])                        # [bq, bk]
        l_ref[...] = l_ref[...] * alpha + jnp.broadcast_to(
            jnp.sum(p, axis=1, keepdims=True), l_ref.shape)
        v = v_ref[0, 0].astype(jnp.float32)                  # [bk, d]
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = m_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,  # [B, Hq, S, D]
    k: jnp.ndarray,  # [B, Hkv, S, D]
    v: jnp.ndarray,  # [B, Hkv, S, D]
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, "GQA requires q_heads % kv_heads == 0"
    group = hq // hkv
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq, nk = s // block_q, s // block_k
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        bq=block_q, bk=block_k, num_kv_blocks=nk,
    )
    return compat.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            compat.vmem((block_q, MIN_LANE), jnp.float32),
            compat.vmem((block_q, MIN_LANE), jnp.float32),
            compat.vmem((block_q, d), jnp.float32),
        ],
        dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(q, k, v)

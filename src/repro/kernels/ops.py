"""Jit'd public wrappers for the Pallas kernels, routed through the registry.

Dispatch contract (single choke point — `repro.kernels.registry.dispatch`):
every wrapper below registers its Pallas entrypoint, its pure-jnp oracle from
ref.py, and a shape-eligibility predicate; per call the registry picks exactly
one of pallas-compiled (eligible + TPU backend), pallas-interpret (eligible +
force_pallas off-TPU — the kernel-parity test path), or the reference oracle
(ineligible shapes, or off-TPU without force_pallas). A Pallas failure caused
by JAX/Pallas API drift is trapped to the oracle unless force_pallas is set.

The wrappers own only pre/post-processing that is mode-independent (blocked
mask construction, PNA mean/std derivation, long-sequence blockwise choice).
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.graph.blocked import BlockedStructure, masks_from_active, pad_values
from repro.kernels import ref as _ref
from repro.kernels import registry
from repro.kernels.bitset_spmm import bitset_spmm as _bitset_spmm_pallas
from repro.kernels.bitset_wave import bitset_wave as _bitset_wave_pallas
from repro.kernels.segment_agg import (
    TILE_F as SEGMENT_AGG_TILE_F,
    TILE_N as SEGMENT_AGG_TILE_N,
    segment_agg as _segment_agg_pallas,
)
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.embedding_bag import embedding_bag as _embedding_bag_pallas

# Sequences longer than this lower the flash-semantics XLA path on the ref
# side (O(S * block) live memory) instead of the materialized S x S oracle.
ATTENTION_BLOCKWISE_CUTOFF = 2048


# ------------------------------------------------------------- bitset_spmm
def _bitset_pallas(vals, dg_src, dg_dst, n, edge_active, blocked, *, interpret):
    masks = masks_from_active(blocked, edge_active)
    out = _bitset_spmm_pallas(
        jnp.asarray(blocked.pairs), masks, pad_values(vals, blocked),
        bn=blocked.bn, n_pad=blocked.n_pad, interpret=interpret,
    )
    # dst blocks with no adjacency block are never visited by the grid
    touched = np.zeros(blocked.n_pad // blocked.bn, dtype=bool)
    touched[blocked.pairs[:, 0]] = True
    trow = jnp.repeat(jnp.asarray(touched), blocked.bn)[:, None]
    return jnp.where(trow, out, jnp.uint32(0))[:n]


def _bitset_ref(vals, dg_src, dg_dst, n, edge_active, blocked):
    return _ref.bitset_spmm_ref(vals, dg_src, dg_dst, n, edge_active)


registry.register(
    "bitset_spmm",
    pallas=_bitset_pallas,
    ref=_bitset_ref,
    eligible=lambda vals, dg_src, dg_dst, n, edge_active, blocked: (
        blocked is not None
    ),
    # tuned decisions are shared per (vertex-count, packed-width) bucket: the
    # LCC sweep (W = ceil(n0/32)) and the NLCC wave hop (W = wave/32) land in
    # different buckets and may legitimately pick different modes
    bucket=lambda vals, dg_src, dg_dst, n, edge_active, blocked: (
        registry.shape_bucket(n) + (int(vals.shape[-1]),)
    ),
    doc="blocked bit-packed OR-SpMM (LCC/NLCC edge sweep)",
)


def bitset_or_aggregate(
    vals: jnp.ndarray,          # uint32[n, W] packed per-vertex words
    dg_src: jnp.ndarray,        # int32[m] dst-sorted
    dg_dst: jnp.ndarray,
    n: int,
    edge_active: jnp.ndarray,   # bool[m]
    blocked: Optional[BlockedStructure] = None,
    force_pallas: bool = False,
) -> jnp.ndarray:
    """OR-aggregate packed words along active arcs -> uint32[n, W]."""
    return registry.dispatch(
        "bitset_spmm", vals, dg_src, dg_dst, n, edge_active, blocked,
        force_pallas=force_pallas,
    )


# ------------------------------------------------------------- bitset_wave
# Resident state the fused wave keeps in VMEM: cur + out + vals frontiers
# (uint32[n_pad, W] each), the f32 accumulator, one mask block, one candidacy
# row. Shapes past this budget route to the oracle.
BITSET_WAVE_VMEM_BUDGET = 12 * 2**20


def _wave_pallas(vals, dg_src, dg_dst, n, edge_active, cand, blocked,
                 *, interpret):
    # masks are built ONCE per wave — edge_active is constant across hops —
    # where the per-hop route rebuilds them around every bitset_spmm launch
    if blocked.nnzb == 0 or cand.shape[0] == 0:
        return jnp.zeros_like(vals) if cand.shape[0] else vals
    masks = masks_from_active(blocked, edge_active)
    cand_pad = jnp.zeros((cand.shape[0], blocked.n_pad), jnp.uint32)
    cand_pad = cand_pad.at[:, :n].set(cand)
    out = _bitset_wave_pallas(
        jnp.asarray(blocked.pairs), masks, pad_values(vals, blocked), cand_pad,
        bn=blocked.bn, n_pad=blocked.n_pad, interpret=interpret,
    )
    return out[:n]


def _wave_eligible(vals, dg_src, dg_dst, n, edge_active, cand, blocked):
    if blocked is None:
        return False
    w = int(vals.shape[-1])
    resident = (
        3 * blocked.n_pad * w * 4          # vals + cur scratch + out frontier
        + blocked.bn * 32 * w * 4          # f32 accumulator
        + blocked.bn * blocked.bnw * 4     # one mask block
        + blocked.n_pad * 4                # one candidacy row
    )
    return resident <= BITSET_WAVE_VMEM_BUDGET


registry.register(
    "bitset_wave",
    pallas=_wave_pallas,
    ref=lambda vals, dg_src, dg_dst, n, edge_active, cand, blocked: (
        _ref.bitset_wave_ref(vals, dg_src, dg_dst, n, edge_active, cand)
    ),
    eligible=_wave_eligible,
    # one decision per (vertex-count, packed-width, hop-count) bucket — the
    # NLCC wave width (W = wave/32) and walk length both shape the cost
    bucket=lambda vals, dg_src, dg_dst, n, edge_active, cand, blocked: (
        registry.shape_bucket(n) + (int(vals.shape[-1]), int(cand.shape[0]))
    ),
    doc="fused multi-hop bit-packed OR-SpMM (NLCC wave engine)",
)


def bitset_wave(
    vals: jnp.ndarray,          # uint32[n, W] packed initial frontier
    dg_src: jnp.ndarray,        # int32[m] dst-sorted
    dg_dst: jnp.ndarray,
    n: int,
    edge_active: jnp.ndarray,   # bool[m]
    cand: jnp.ndarray,          # uint32[L, n] per-hop candidacy, 0 / 0xFFFFFFFF
    blocked: Optional[BlockedStructure] = None,
    force_pallas: bool = False,
) -> jnp.ndarray:
    """Run the full L-hop NLCC wave in one kernel call -> uint32[n, W]."""
    if cand.shape[0] == 0:
        return vals
    return registry.dispatch(
        "bitset_wave", vals, dg_src, dg_dst, n, edge_active, cand, blocked,
        force_pallas=force_pallas,
    )


# ------------------------------------------------------------- segment_agg
def _segment_agg_eligible(feats, mask):
    nt, _, f = feats.shape
    return nt % SEGMENT_AGG_TILE_N == 0 and f % SEGMENT_AGG_TILE_F == 0


registry.register(
    "segment_agg",
    pallas=lambda feats, mask, *, interpret: _segment_agg_pallas(
        feats, mask, interpret=interpret
    ),
    ref=_ref.segment_agg_ref,
    eligible=_segment_agg_eligible,
    doc="fused sum/min/max/sumsq neighborhood aggregation (PNA bank)",
)


def neighborhood_agg(
    feats: jnp.ndarray,   # [NT, D, F] gathered neighbor features
    mask: jnp.ndarray,    # bool[NT, D]
    degrees: jnp.ndarray,  # f32[NT] true degrees (for mean/std)
    force_pallas: bool = False,
) -> dict:
    """Fused sum/mean/min/max/std neighborhood aggregation (PNA's bank)."""
    raw = registry.dispatch("segment_agg", feats, mask, force_pallas=force_pallas)
    s, mn, mx, sq = raw[:, 0], raw[:, 1], raw[:, 2], raw[:, 3]
    deg = jnp.maximum(degrees, 1.0)[:, None]
    empty = (degrees <= 0)[:, None]
    mean = s / deg
    var = jnp.maximum(sq / deg - mean * mean, 0.0)
    zero = jnp.zeros_like(s)
    return {
        "sum": s,
        "mean": mean,
        "min": jnp.where(empty, zero, mn),
        "max": jnp.where(empty, zero, mx),
        # +eps: sqrt has an infinite derivative at 0 (NaN in backward)
        "std": jnp.sqrt(var + 1e-12),
    }


# --------------------------------------------------------- flash_attention
def _attention_eligible(q, k, v, *, causal=True, window=None,
                        block_q=128, block_k=128):
    s = q.shape[2]
    return (
        s % block_q == 0 and s % block_k == 0
        and q.shape[3] >= 128 and q.shape[3] == v.shape[3]
    )


def _attention_ref(q, k, v, *, causal=True, window=None,
                   block_q=128, block_k=128):
    if q.shape[2] > ATTENTION_BLOCKWISE_CUTOFF:
        # flash-semantics XLA path: O(S * block) live memory; this is what the
        # dry-run lowers for long sequences on non-TPU backends (and the MLA
        # d_qk != d_v case everywhere).
        return _ref.attention_blockwise(q, k, v, causal=causal, window=window)
    return _ref.attention_ref(q, k, v, causal=causal, window=window)


registry.register(
    "flash_attention",
    pallas=lambda q, k, v, *, interpret, causal=True, window=None,
    block_q=128, block_k=128: _flash_pallas(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret,
    ),
    ref=_attention_ref,
    eligible=_attention_eligible,
    doc="causal/GQA/sliding-window flash attention (LM hot loop)",
)


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    force_pallas: bool = False,
) -> jnp.ndarray:
    return registry.dispatch(
        "flash_attention", q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, force_pallas=force_pallas,
    )


# ----------------------------------------------------------- embedding_bag
registry.register(
    "embedding_bag",
    pallas=lambda table, ids, weights, *, interpret, mode="sum": (
        _embedding_bag_pallas(table, ids, weights, mode=mode, interpret=interpret)
    ),
    ref=lambda table, ids, weights, *, mode="sum": (
        _ref.embedding_bag_ref(table, ids, weights, mode=mode)
    ),
    doc="scalar-prefetch gather + VMEM bag reduce (recsys hot loop)",
)


def embedding_bag(
    table: jnp.ndarray,
    ids: jnp.ndarray,
    weights: Optional[jnp.ndarray] = None,
    *,
    mode: str = "sum",
    force_pallas: bool = False,
) -> jnp.ndarray:
    if weights is None:
        weights = jnp.ones(ids.shape, jnp.float32)
    return registry.dispatch(
        "embedding_bag", table, ids, weights, mode=mode,
        force_pallas=force_pallas,
    )

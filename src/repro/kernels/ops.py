"""Jit'd public wrappers for the Pallas kernels.

Dispatch contract: on TPU backends the `pl.pallas_call` kernels run compiled;
everywhere else the pure-jnp oracle from ref.py is used (identical numerics
contract — kernel tests enforce allclose). Tests may force the kernel path in
interpret mode with force_pallas=True.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.graph.blocked import BlockedStructure, masks_from_active, pad_values
from repro.kernels import ref as _ref
from repro.kernels.bitset_spmm import bitset_spmm as _bitset_spmm_pallas
from repro.kernels.segment_agg import segment_agg as _segment_agg_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.embedding_bag import embedding_bag as _embedding_bag_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ------------------------------------------------------------- bitset_spmm
def bitset_or_aggregate(
    vals: jnp.ndarray,          # uint32[n, W] packed per-vertex words
    dg_src: jnp.ndarray,        # int32[m] dst-sorted
    dg_dst: jnp.ndarray,
    n: int,
    edge_active: jnp.ndarray,   # bool[m]
    blocked: Optional[BlockedStructure] = None,
    force_pallas: bool = False,
) -> jnp.ndarray:
    """OR-aggregate packed words along active arcs -> uint32[n, W]."""
    if blocked is not None and (force_pallas or _on_tpu()):
        masks = masks_from_active(blocked, edge_active)
        out = _bitset_spmm_pallas(
            jnp.asarray(blocked.pairs), masks, pad_values(vals, blocked),
            bn=blocked.bn, n_pad=blocked.n_pad, interpret=not _on_tpu(),
        )
        # dst blocks with no adjacency block are never visited by the grid
        touched = np.zeros(blocked.n_pad // blocked.bn, dtype=bool)
        touched[blocked.pairs[:, 0]] = True
        trow = jnp.repeat(jnp.asarray(touched), blocked.bn)[:, None]
        return jnp.where(trow, out, jnp.uint32(0))[:n]
    return _ref.bitset_spmm_ref(vals, dg_src, dg_dst, n, edge_active)


# ------------------------------------------------------------- segment_agg
def neighborhood_agg(
    feats: jnp.ndarray,   # [NT, D, F] gathered neighbor features
    mask: jnp.ndarray,    # bool[NT, D]
    degrees: jnp.ndarray,  # f32[NT] true degrees (for mean/std)
    force_pallas: bool = False,
) -> dict:
    """Fused sum/mean/min/max/std neighborhood aggregation (PNA's bank)."""
    nt, d, f = feats.shape
    use_kernel = force_pallas or _on_tpu()
    if use_kernel and nt % 8 == 0 and f % 128 == 0:
        raw = _segment_agg_pallas(feats, mask, interpret=not _on_tpu())
    else:
        raw = _ref.segment_agg_ref(feats, mask)
    s, mn, mx, sq = raw[:, 0], raw[:, 1], raw[:, 2], raw[:, 3]
    deg = jnp.maximum(degrees, 1.0)[:, None]
    empty = (degrees <= 0)[:, None]
    mean = s / deg
    var = jnp.maximum(sq / deg - mean * mean, 0.0)
    zero = jnp.zeros_like(s)
    return {
        "sum": s,
        "mean": mean,
        "min": jnp.where(empty, zero, mn),
        "max": jnp.where(empty, zero, mx),
        # +eps: sqrt has an infinite derivative at 0 (NaN in backward)
        "std": jnp.sqrt(var + 1e-12),
    }


# --------------------------------------------------------- flash_attention
def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    force_pallas: bool = False,
) -> jnp.ndarray:
    s = q.shape[2]
    same_dims = q.shape[3] == v.shape[3]
    usable = s % block_q == 0 and s % block_k == 0 and q.shape[3] >= 128 and same_dims
    if (force_pallas or _on_tpu()) and usable:
        return _flash_pallas(
            q, k, v, causal=causal, window=window,
            block_q=block_q, block_k=block_k, interpret=not _on_tpu(),
        )
    if s > 2048:
        # flash-semantics XLA path: O(S * block) live memory; this is what the
        # dry-run lowers for long sequences on non-TPU backends (and the MLA
        # d_qk != d_v case everywhere).
        return _ref.attention_blockwise(q, k, v, causal=causal, window=window)
    return _ref.attention_ref(q, k, v, causal=causal, window=window)


# ----------------------------------------------------------- embedding_bag
def embedding_bag(
    table: jnp.ndarray,
    ids: jnp.ndarray,
    weights: Optional[jnp.ndarray] = None,
    *,
    mode: str = "sum",
    force_pallas: bool = False,
) -> jnp.ndarray:
    if weights is None:
        weights = jnp.ones(ids.shape, jnp.float32)
    if force_pallas or _on_tpu():
        return _embedding_bag_pallas(
            table, ids, weights, mode=mode, interpret=not _on_tpu()
        )
    return _ref.embedding_bag_ref(table, ids, weights, mode=mode)

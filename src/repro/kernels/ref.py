"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references (kernel tests sweep shapes/dtypes and
assert_allclose against them) AND the CPU/GPU fallback paths dispatched by
ops.py — the dry-run lowers these on non-TPU backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.state import pack_bits, unpack_bits


# ------------------------------------------------------------- bitset_spmm
def bitset_spmm_ref(
    vals: jnp.ndarray,         # uint32[n, W] packed
    src: jnp.ndarray,          # int32[m] dst-sorted
    dst: jnp.ndarray,          # int32[m]
    n: int,
    edge_active: jnp.ndarray,  # bool[m]
) -> jnp.ndarray:
    """out[v] = OR over active arcs (u -> v) of vals[u]."""
    w = vals.shape[1]
    bits = unpack_bits(vals, w * 32)                      # bool[n, 32W]
    msgs = jnp.take(bits, src, axis=0) & edge_active[:, None]
    agg = jax.ops.segment_max(
        msgs.astype(jnp.int32), dst, num_segments=n, indices_are_sorted=True
    ) > 0
    return pack_bits(agg)


# ------------------------------------------------------------- bitset_wave
@functools.partial(jax.jit, static_argnames=("n",))
def bitset_wave_ref(
    vals: jnp.ndarray,         # uint32[n, W] packed initial frontier (hop 0)
    src: jnp.ndarray,          # int32[m] dst-sorted
    dst: jnp.ndarray,          # int32[m]
    n: int,
    edge_active: jnp.ndarray,  # bool[m]
    cand: jnp.ndarray,         # uint32[L, n] per-hop candidacy, 0 / 0xFFFFFFFF
) -> jnp.ndarray:
    """Fused L-hop wave: F_r = OR-aggregate(F_{r-1}) & cand[r], r = 1..L.

    Scan-based and pack/unpack-free: hops are a `lax.scan` over the hop-indexed
    candidacy stack, and the per-hop aggregation stays in packed uint32 words
    (a segmented associative OR-scan over the dst-sorted arcs — 32x fewer
    aggregation bytes than the boolean-plane hop, with no bitset round-trip
    per hop). The whole wave is one jitted XLA computation.
    """
    from repro.graph import segment_ops

    m = src.shape[0]
    if cand.shape[0] == 0:
        return vals
    if m == 0:
        return jnp.zeros_like(vals)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), dst[1:] != dst[:-1]])
    last_edge = jnp.full((n,), -1, jnp.int32).at[dst].max(
        jnp.arange(m, dtype=jnp.int32))
    meta = segment_ops.SegmentMeta(
        is_start=is_start, last_edge_of_vertex=last_edge)
    ea_word = jnp.where(edge_active, jnp.uint32(0xFFFFFFFF), jnp.uint32(0))

    def hop(packed, cw):
        msgs = jnp.take(packed, src, axis=0) & ea_word[:, None]
        agg = segment_ops.segment_or(msgs, meta, n)
        return agg & cw[:, None], None

    out, _ = jax.lax.scan(hop, vals, cand)
    return out


# ------------------------------------------------------------- segment_agg
def segment_agg_ref(feats: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """feats [NT, D, F], mask [NT, D] -> [NT, 4, F] sum/min/max/sumsq."""
    big = jnp.float32(3.0e38)
    x = feats.astype(jnp.float32)
    valid = mask[:, :, None]
    s = jnp.sum(jnp.where(valid, x, 0.0), axis=1)
    mn = jnp.min(jnp.where(valid, x, big), axis=1)
    mx = jnp.max(jnp.where(valid, x, -big), axis=1)
    sq = jnp.sum(jnp.where(valid, x * x, 0.0), axis=1)
    return jnp.stack([s, mn, mx, sq], axis=1)


# --------------------------------------------------------- flash_attention
def attention_ref(
    q: jnp.ndarray,  # [B, Hq, S, D]
    k: jnp.ndarray,  # [B, Hkv, S, D]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
) -> jnp.ndarray:
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    if hkv != hq:
        k = jnp.repeat(k, hq // hkv, axis=1)
        v = jnp.repeat(v, hq // hkv, axis=1)
    scale = 1.0 / (d ** 0.5)
    logits = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(s)[None, :]
    live = jnp.ones((s, s), dtype=bool)
    if causal:
        live &= q_pos >= k_pos
    if window is not None:
        live &= k_pos > q_pos - window
    logits = jnp.where(live[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32)).astype(q.dtype)


def attention_blockwise(
    q: jnp.ndarray,  # [B, Hq, S, D]
    k: jnp.ndarray,  # [B, Hkv, S, Dk]
    v: jnp.ndarray,  # [B, Hkv, S, Dv]
    *,
    causal: bool = True,
    window: int | None = None,
    block_k: int = 1024,
) -> jnp.ndarray:
    """Flash-semantics attention in pure XLA: lax.scan over KV blocks with an
    online-softmax carry — O(S * block_k) live memory instead of O(S^2).

    This is what the dry-run lowers on non-TPU backends for long sequences, so
    the reported memory/roofline profile matches the Pallas kernel's algorithm
    (same FLOPs, same O(S) working set), not a materialized S x S matrix.
    Also handles d_qk != d_v (MLA)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    if hkv != hq:
        k = jnp.repeat(k, hq // hkv, axis=1)
        v = jnp.repeat(v, hq // hkv, axis=1)
    dv = v.shape[-1]
    scale = 1.0 / (d ** 0.5)
    nk = -(-s // block_k)
    pad = nk * block_k - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kb = k.reshape(b, hq, nk, block_k, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hq, nk, block_k, dv).transpose(2, 0, 1, 3, 4)
    q_pos = jnp.arange(s)

    def body(carry, xs):
        m, l, acc = carry
        ki, kblk, vblk = xs
        k_pos = ki * block_k + jnp.arange(block_k)
        # dots in the input dtype (bf16 on the MXU) with fp32 accumulation —
        # matches the Pallas kernel's numerics and byte traffic
        logits = jnp.einsum("bhqd,bhkd->bhqk", q, kblk,
                            preferred_element_type=jnp.float32) * scale
        live = (k_pos[None, :] < s)
        if causal:
            live = live & (q_pos[:, None] >= k_pos[None, :])
        if window is not None:
            live = live & (k_pos[None, :] > q_pos[:, None] - window)
        logits = jnp.where(live[None, None], logits, -1e30)
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m, m_cur)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(v.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((b, hq, s), -1e30, jnp.float32),
        jnp.zeros((b, hq, s), jnp.float32),
        jnp.zeros((b, hq, s, dv), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, (jnp.arange(nk), kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


# ----------------------------------------------------------- embedding_bag
def embedding_bag_ref(
    table: jnp.ndarray,    # [V, D]
    ids: jnp.ndarray,      # int32[B, L]
    weights: jnp.ndarray,  # f32[B, L]
    *,
    mode: str = "sum",
) -> jnp.ndarray:
    rows = jnp.take(table, ids, axis=0).astype(jnp.float32)   # [B, L, D]
    out = jnp.sum(rows * weights[:, :, None], axis=1)
    if mode == "mean":
        counts = jnp.sum((weights != 0.0).astype(jnp.float32), axis=1)
        out = out / jnp.maximum(counts, 1.0)[:, None]
    return out.astype(table.dtype)

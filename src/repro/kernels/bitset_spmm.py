"""`bitset_spmm` — blocked bit-packed OR-SpMM, the LCC/NLCC hot loop on TPU.

Computes, over a block-sparse boolean adjacency (see graph/blocked.py):

    out[v, w] = OR_{u : arc (u -> v) active} vals[u, w]        (uint32 words)

TPU mapping: each nonzero (dst_block, src_block) pair is one grid step.
The packed block mask uint32[BN, BN/32] and the packed source values
uint32[BN, W] are unpacked to {0,1} float planes in VREGs and contracted on
the MXU:

    acc[BN, 32W] (+)= unpack(mask)[BN, BN] @ unpack(vals)[BN, 32W]

`acc > 0` is the OR. The accumulator lives in VMEM scratch across the grid
steps of one dst row (grid is ordered by dst block; "arbitrary" semantics);
the packed result is written on every step and is final at the row's last
step. Scalar-prefetched `pairs` drive both BlockSpec index maps — this is a
gather/scatter-free formulation: all indirection is resolved by the grid.

VMEM budget per step (BN=256, W<=32):
  mask 256x8 u32 = 8 KiB, vals 256x32 u32 = 32 KiB, acc 256x1024 f32 = 1 MiB,
  unpacked planes ~2 x 1 MiB in VREG/VMEM — comfortably inside 16 MiB VMEM.
MXU work per step: 2 * BN^2 * 32W FLOP (BN=256, W=2: 8.4 MFLOP) against
BN*BN/8 + BN*4W bytes read — compute-dense for a "sparse" op, which is the
point of the blocked reformulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat


def _unpack_words_f32(words: jnp.ndarray) -> jnp.ndarray:
    """uint32[R, W] -> float32[R, 32W] of {0., 1.} (bit b of word w -> column 32w+b)."""
    r, w = words.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (r, w, 32), 2)
    bits = (words[:, :, None] >> shifts) & jnp.uint32(1)
    return bits.reshape(r, w * 32).astype(jnp.float32)


def _pack_bool_u32(bits: jnp.ndarray) -> jnp.ndarray:
    """bool[R, 32W] -> uint32[R, W]."""
    r, c = bits.shape
    w = c // 32
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (r, w, 32), 2)
    vals = bits.reshape(r, w, 32).astype(jnp.uint32) << shifts
    return jnp.sum(vals, axis=2, dtype=jnp.uint32)


def _kernel(pairs_ref, mask_ref, vals_ref, out_ref, acc_ref):
    b = pl.program_id(0)
    prev_db = pairs_ref[jnp.maximum(b, 1) - 1, 0]
    first = jnp.logical_or(b == 0, pairs_ref[b, 0] != prev_db)

    mask_f = _unpack_words_f32(mask_ref[0])           # [BN, BN]
    vals_f = _unpack_words_f32(vals_ref[...])         # [BN, 32W]
    partial = jax.lax.dot_general(
        mask_f, vals_f, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                 # [BN, 32W]

    @pl.when(first)
    def _reset():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += partial
    # Written every step; final at the last step of the dst row.
    out_ref[...] = _pack_bool_u32(acc_ref[...] > 0.5)


@functools.partial(jax.jit, static_argnames=("bn", "n_pad", "interpret"))
def bitset_spmm(
    pairs: jnp.ndarray,    # int32[nnzb, 2] (dst_block, src_block), dst-sorted
    masks: jnp.ndarray,    # uint32[nnzb, BN, BN//32] dynamic active bitmasks
    vals: jnp.ndarray,     # uint32[n_pad, W] packed per-vertex values
    *,
    bn: int,
    n_pad: int,
    interpret: bool = False,
) -> jnp.ndarray:
    """OR-aggregate packed words along active arcs; returns uint32[n_pad, W]."""
    nnzb = masks.shape[0]
    w = vals.shape[1]
    grid_spec = compat.prefetch_scalar_grid_spec(
        num_scalar_prefetch=1,
        grid=(nnzb,),
        in_specs=[
            pl.BlockSpec((1, bn, bn // 32), lambda b, pairs: (b, 0, 0)),
            pl.BlockSpec((bn, w), lambda b, pairs: (pairs[b, 1], 0)),
        ],
        out_specs=pl.BlockSpec((bn, w), lambda b, pairs: (pairs[b, 0], 0)),
        scratch_shapes=[compat.vmem((bn, 32 * w), jnp.float32)],
    )
    return compat.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad, w), jnp.uint32),
        interpret=interpret,
        dimension_semantics=("arbitrary",),
    )(pairs, masks, vals)

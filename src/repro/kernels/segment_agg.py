"""`segment_agg` — fused 4-way neighborhood aggregation for GNNs.

PNA needs mean/min/max/std per destination; computed naively that is four
passes over the gathered neighbor features. This kernel reduces a padded
dense neighborhood tensor (the sampled-fanout regime of GraphSAGE, and the
degree-bucketed regime for full-graph PNA/GIN/GAT) in ONE pass:

  inputs  feats [NT, D, F]   gathered neighbor features (XLA gather feeds it)
          mask  [NT, D]      valid-neighbor mask (padding rows are dead)
  output  out   [NT, 4, F]   sum / min / max / sumsq  (mean & std derived
                             outside with the degree vector)

Grid: (NT/tile_n, F/tile_f); each step loads a [tile_n, D, tile_f] brick into
VMEM and reduces the middle axis on the VPU. Identities are 0 for sum/sumsq
and +/-inf for min/max; empty segments are cleaned up by the ops wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat

BIG = 3.0e38

# Default VPU tile: 8 sublanes x 128 lanes (f32). The ops-layer eligibility
# predicate imports these — retune here and dispatch stays consistent.
TILE_N = 8
TILE_F = 128


def _kernel(feats_ref, mask_ref, out_ref):
    x = feats_ref[...].astype(jnp.float32)          # [tn, D, tf]
    valid = mask_ref[...][:, :, None]               # [tn, D, 1]
    zero = jnp.zeros_like(x)
    s = jnp.sum(jnp.where(valid, x, zero), axis=1)
    mn = jnp.min(jnp.where(valid, x, jnp.full_like(x, BIG)), axis=1)
    mx = jnp.max(jnp.where(valid, x, jnp.full_like(x, -BIG)), axis=1)
    sq = jnp.sum(jnp.where(valid, x * x, zero), axis=1)
    out_ref[...] = jnp.stack([s, mn, mx, sq], axis=1).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_f", "interpret"))
def segment_agg(
    feats: jnp.ndarray,  # [NT, D, F]
    mask: jnp.ndarray,   # bool[NT, D]
    *,
    tile_n: int = TILE_N,
    tile_f: int = TILE_F,
    interpret: bool = False,
) -> jnp.ndarray:
    nt, d, f = feats.shape
    assert nt % tile_n == 0 and f % tile_f == 0, (nt, f, tile_n, tile_f)
    return compat.pallas_call(
        _kernel,
        grid=(nt // tile_n, f // tile_f),
        in_specs=[
            pl.BlockSpec((tile_n, d, tile_f), lambda i, j: (i, 0, j)),
            pl.BlockSpec((tile_n, d), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, 4, tile_f), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((nt, 4, f), jnp.float32),
        dimension_semantics=("parallel", "parallel"),
        interpret=interpret,
    )(feats, mask)

"""Pallas TPU kernels for the performance-critical compute layers.

  bitset_spmm     — blocked bit-packed OR-SpMM: the LCC/NLCC edge sweep
  segment_agg     — fused 4-way GNN neighborhood aggregation (PNA bank)
  flash_attention — causal/GQA/sliding-window attention (LM hot loop)
  embedding_bag   — scalar-prefetch gather + VMEM bag reduce (recsys hot loop)

Use through `repro.kernels.ops` (jit'd wrappers, TPU->pallas / CPU->ref
dispatch); `repro.kernels.ref` holds the pure-jnp oracles.
"""
from repro.kernels import ops, ref  # noqa: F401

"""Pallas TPU kernels for the performance-critical compute layers.

  bitset_spmm     — blocked bit-packed OR-SpMM: the LCC/NLCC edge sweep
  segment_agg     — fused 4-way GNN neighborhood aggregation (PNA bank)
  flash_attention — causal/GQA/sliding-window attention (LM hot loop)
  embedding_bag   — scalar-prefetch gather + VMEM bag reduce (recsys hot loop)

Dispatch contract
-----------------
Every kernel is declared in the registry (`repro.kernels.registry`) with
three parts: its Pallas entrypoint, its pure-jnp oracle from `ref.py`
(identical numerics contract — parity tests enforce allclose), and a
shape-eligibility predicate. Public callers go through the jit'd wrappers in
`repro.kernels.ops`; per call, `registry.dispatch()` picks exactly one of:

  pallas-compiled    eligible call on a TPU backend
  pallas-interpret   eligible call with force_pallas=True off-TPU (tests)
  reference oracle   ineligible shapes, or off-TPU without force_pallas

A Pallas attempt that dies with an API-drift error is trapped back to the
oracle (with a RuntimeWarning) unless force_pallas pins the kernel path.

Compat invariant
----------------
No module outside `repro.kernels.compat` may touch version-gated JAX API
surface: the TPU compiler-params class (renamed across 0.4.x -> 0.5), the
mesh axis-type enum, mesh-construction kwargs, or the shard_map
location/signature. Kernels use `compat.pallas_call` / `compat.vmem` /
`compat.prefetch_scalar_grid_spec`; engine and launch code use
`compat.make_mesh` / `compat.shard_map`.
"""
from repro.kernels import compat, ops, ref, registry  # noqa: F401

"""Batched serving: prefill + decode with a static KV cache, plus recsys
scoring paths. `build_serve_step` returns the jittable one-token step that the
multi-pod dry-run lowers for the decode_* / long_* shape cells.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig, RecsysConfig
from repro.models import transformer, bert4rec


# ------------------------------------------------------------------ LM decode
def build_decode_step(cfg: LMConfig) -> Callable:
    """(params, cache, token int32[B]) -> (next_token int32[B], logits, cache)."""

    def serve_step(params, cache, token):
        logits, cache = transformer.decode_step(params, cfg, token, cache)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, logits, cache

    return serve_step


def build_prefill(cfg: LMConfig) -> Callable:
    """(params, tokens [B, S]) -> (cache, last logits). Full-sequence forward +
    cache fill: runs the training forward for hiddens, then writes K/V with one
    vectorized pass per layer (no per-token loop)."""

    def prefill(params, tokens, max_seq: int):
        b, s = tokens.shape
        cache = transformer.init_cache(cfg, b, max_seq)
        # teacher-forced sequential fill (correct for any attention variant)
        def body(cache, tok):
            logits, cache = transformer.decode_step(params, cfg, tok, cache)
            return cache, logits
        cache, logits = jax.lax.scan(body, cache, tokens.T)
        return cache, logits[-1]

    return prefill


def greedy_generate(params, cfg: LMConfig, prompt, max_new: int, max_seq: int):
    """Simple generation driver used by the serving example."""
    prefill = build_prefill(cfg)
    step = build_decode_step(cfg)
    cache, logits = prefill(params, prompt, max_seq)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    for _ in range(max_new - 1):
        tok, _, cache = step(params, cache, tok)
        out.append(tok)
    return jnp.stack(out, axis=1)


# --------------------------------------------------------------- recsys serve
def build_recsys_scorer(cfg: RecsysConfig, kind: str) -> Callable:
    if kind == "serve":
        return lambda params, items: bert4rec.serve_scores(params, cfg, items)
    if kind == "retrieval":
        return lambda params, items, cands: bert4rec.retrieval_scores(
            params, cfg, items, cands)
    raise ValueError(kind)

from repro.serve.engine import (  # noqa: F401
    build_decode_step, build_prefill, build_recsys_scorer, greedy_generate,
)

from repro.serve.engine import (  # noqa: F401
    build_decode_step, build_prefill, build_recsys_scorer, greedy_generate,
)
from repro.serve.graph_query import (  # noqa: F401
    GraphQueryEngine, GraphQuery, QueryResult, example_workload,
    MODE_PRUNE, MODE_COUNT, MODE_STREAM,
)

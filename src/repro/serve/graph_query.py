"""Graph-query serving: admission, shape-bucket batching, deadlines,
streamed emission — the multi-tenant front end of `prune_batch`.

The production shape this models: ONE resident background metadata graph,
MANY analysts submitting search templates. Queries enter an admission queue;
a shape-bucket batcher groups compatible queries (same pow2 template bucket)
and launches a template-batched prune — one kernel-dispatch sequence for the
whole batch (core/batch.py) — when either the batch is full (`max_batch`) or
the oldest compatible query has waited `max_wait_s`. Per-query deadlines
cancel by masking: a query whose deadline passes while queued is emitted as
deadline_missed without consuming device time; one that expires mid-batch is
zeroed at the next phase boundary inside the batched run (never a batch
abort). Matches stream out through `stream_matches` block by block, so the
whole result table never materializes.

The structure follows the jitted-step + host-driver split of the LM decode
loop in serve/engine.py: everything device-side lives in BatchedEngine's
jitted programs; this module is the host driver — queueing, batching,
deadlines, emission — and owns no device state of its own.

Routing is policy-cache-driven at startup: pass `policy=` (a path or a
DispatchPolicy) and every batched prune resolves its kernel routes through
the tuned cache under BATCHED bucket keys (`b8xp4x...`), falling back to
unbatched entries for batch-size-1 lookups.

Deliberately synchronous and single-threaded: `submit()` enqueues, `pump()`
launches every due batch, `drain()` runs the queue dry. Determinism is the
point — the serving tests and the multi_tenant benchmark drive the engine
with a fake clock and assert exact admission/batching decisions.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Dict, Iterator, List, Optional, Sequence, Union

import numpy as np

from repro.graph.structs import Graph
from repro.core.template import Template
from repro.core.batch import (prune_batch, BatchedPruneResult,
                              STATUS_OK, STATUS_DEADLINE_MISSED)
from repro.core.enumerate import count_matches, stream_matches
from repro.core.pipeline import PruneResult

MODE_PRUNE = "prune"    # deliver the pruned solution subgraph only
MODE_COUNT = "count"    # also count matches (symmetry-broken)
MODE_STREAM = "stream"  # prune now, caller pulls embedding blocks later


@dataclasses.dataclass
class GraphQuery:
    """One admitted query: a template plus its serving metadata."""
    query_id: int
    template: Template
    mode: str
    deadline: Optional[float]  # absolute clock() time, None = no deadline
    submitted_at: float
    bucket: tuple
    # plan identity resolved AT ADMISSION: batched lanes must share a batch
    # only with same-plan queries (the lockstep driver handles mixed plans,
    # but grouping by plan keeps wave shapes aligned). "heuristic" when the
    # policy holds no tuned plan for this (template, graph-stats) bucket.
    plan_group: str = "heuristic"


@dataclasses.dataclass
class QueryResult:
    query_id: int
    status: str  # STATUS_OK | STATUS_DEADLINE_MISSED
    mode: str
    result: Optional[PruneResult]  # None for queries cancelled while queued
    n_embeddings: Optional[int]  # filled for MODE_COUNT ok queries
    batch_id: Optional[int]  # None if never launched
    batch_size: int
    wait_s: float
    seconds: float  # batched prune wall time (shared by the batch)


class GraphQueryEngine:
    """The serving front end: one resident graph, a queue of template
    queries, shape-bucketed batched execution."""

    def __init__(self, graph: Graph, *, partition=None, mesh=None,
                 wave: int = 1024, max_batch: int = 8,
                 max_wait_s: float = 0.05,
                 policy: Union[None, str, "object"] = None,
                 clock=time.monotonic, **prune_kw):
        from repro.kernels import registry

        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.graph = graph
        self.partition = partition
        self.mesh = mesh
        self.wave = wave
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.clock = clock
        self.prune_kw = prune_kw
        self._label_freq = graph.label_frequency()
        self._gstats = None  # graph stats, computed once iff plans are tuned
        self._queue: deque = deque()
        self._done: Dict[int, QueryResult] = {}
        self._ids = itertools.count()
        self._batch_ids = itertools.count()
        self.stats: Dict = {"n_submitted": 0, "n_batches": 0,
                            "n_completed": 0, "n_deadline_missed": 0}
        if policy is not None:  # tuned kernel-mode decisions from startup on
            if isinstance(policy, (str, bytes)):
                policy = registry.DispatchPolicy.load(policy)
            registry.set_policy(policy)
            self.stats["policy_active"] = True

    # ------------------------------------------------------------- admission
    def submit(self, template: Template, *, mode: str = MODE_COUNT,
               timeout_s: Optional[float] = None) -> int:
        """Admit one query; returns its query_id. `timeout_s` is a serving
        deadline relative to now — a query that cannot finish by then is
        cancelled (masked), never silently dropped."""
        from repro.kernels import registry

        if mode not in (MODE_PRUNE, MODE_COUNT, MODE_STREAM):
            raise ValueError(f"unknown query mode {mode!r}")
        if template.n0 < 2:
            raise ValueError("single-vertex templates are a label filter, "
                             "not a pattern query")
        now = self.clock()
        q = GraphQuery(
            query_id=next(self._ids), template=template, mode=mode,
            deadline=(now + timeout_s) if timeout_s is not None else None,
            submitted_at=now, bucket=registry.shape_bucket(template.n0),
            plan_group=self._plan_group(template))
        self._queue.append(q)
        self.stats["n_submitted"] += 1
        return q.query_id

    def _plan_group(self, template: Template) -> str:
        """Plan lookup at admission: the planned phase order identifies the
        batch group. Untuned (no plans in the active policy) every query is
        "heuristic" — grouping, and therefore batching behavior, is exactly
        the pre-planner shape-bucket-only rule."""
        from repro.kernels import registry

        policy = registry.get_policy()
        if policy is None or not policy.plans:
            return "heuristic"
        from repro.core import planner
        from repro.core.template import generate_constraints
        from repro.graph.stats import collect_graph_stats

        if self._gstats is None:
            self._gstats = collect_graph_stats(self.graph)
        cs = generate_constraints(
            template, label_freq=self._label_freq,
            guarantee_precision=self.prune_kw.get(
                "guarantee_precision", True))
        qp = planner.resolve_query_plan(template, cs, self._gstats)
        if qp is None or qp.is_heuristic():
            return "heuristic"
        return ";".join(qp.identities())

    @property
    def n_pending(self) -> int:
        return len(self._queue)

    def result(self, query_id: int) -> Optional[QueryResult]:
        return self._done.get(query_id)

    # ------------------------------------------------------------- batching
    def _expire_queued(self) -> List[QueryResult]:
        now = self.clock()
        live = deque()
        expired = []
        for q in self._queue:
            if q.deadline is not None and now > q.deadline:
                expired.append(self._finish_cancelled(q))
            else:
                live.append(q)
        self._queue = live
        return expired

    def _ready_bucket(self, force: bool):
        """The shape-bucket batcher's launch decision: a bucket is due when
        it holds max_batch queries or its oldest query has waited
        max_wait_s (or the caller is draining)."""
        now = self.clock()
        groups: Dict[tuple, List[GraphQuery]] = {}
        for q in self._queue:  # FIFO within a group by construction
            # lanes batch by (shape bucket, plan group): same-plan queries
            # share wave shapes; untuned this degenerates to bucket-only
            groups.setdefault((q.bucket, q.plan_group), []).append(q)
        for bucket, qs in groups.items():
            full = len(qs) >= self.max_batch
            overdue = (now - qs[0].submitted_at) >= self.max_wait_s
            if full or overdue or force:
                return bucket, qs[:self.max_batch]
        return None

    def pump(self, *, force: bool = False) -> List[QueryResult]:
        """Launch every due batch; returns the results it completed. With
        force=True, waiting policies are bypassed (drain semantics)."""
        out: List[QueryResult] = []
        while True:
            out.extend(self._expire_queued())
            due = self._ready_bucket(force)
            if due is None:
                break
            _, batch = due
            for q in batch:
                self._queue.remove(q)
            out.extend(self._execute(batch))
        return out

    def drain(self) -> List[QueryResult]:
        """Run the queue dry (no max-wait idling); returns all results."""
        out: List[QueryResult] = []
        while self._queue:
            out.extend(self.pump(force=True))
        return out

    # ------------------------------------------------------------- execution
    def _execute(self, batch: Sequence[GraphQuery]) -> List[QueryResult]:
        batch_id = next(self._batch_ids)
        now = self.clock()
        bres: BatchedPruneResult = prune_batch(
            self.graph, [q.template for q in batch],
            partition=self.partition, mesh=self.mesh, wave=self.wave,
            label_freq=self._label_freq,
            deadlines=[q.deadline for q in batch], clock=self.clock,
            **self.prune_kw)
        seconds = bres.stats["batched"]["seconds"]
        self.stats["n_batches"] += 1
        self.stats.setdefault("batches", []).append({
            "batch_id": batch_id, "B": len(batch),
            "bucket": bres.stats["batched"]["bucket"], "seconds": seconds})
        out = []
        for q, lane_res, status in zip(batch, bres.results, bres.status):
            n_emb = None
            if status == STATUS_OK and q.mode == MODE_COUNT:
                n_emb = int(count_matches(
                    lane_res.dg, lane_res.state, q.template,
                    label_freq=self._label_freq).n_embeddings)
            qr = QueryResult(
                query_id=q.query_id, status=status, mode=q.mode,
                result=lane_res if status == STATUS_OK else None,
                n_embeddings=n_emb, batch_id=batch_id,
                batch_size=len(batch), wait_s=now - q.submitted_at,
                seconds=seconds)
            self._finish(qr)
            out.append(qr)
        return out

    def _finish_cancelled(self, q: GraphQuery) -> QueryResult:
        qr = QueryResult(
            query_id=q.query_id, status=STATUS_DEADLINE_MISSED, mode=q.mode,
            result=None, n_embeddings=None, batch_id=None, batch_size=0,
            wait_s=self.clock() - q.submitted_at, seconds=0.0)
        self._finish(qr)
        return qr

    def _finish(self, qr: QueryResult) -> None:
        self._done[qr.query_id] = qr
        if qr.status == STATUS_DEADLINE_MISSED:
            self.stats["n_deadline_missed"] += 1
        else:
            self.stats["n_completed"] += 1

    # ------------------------------------------------------------- emission
    def stream(self, query_id: int, *, chunk: int = 4096,
               max_rows: int = 1_000_000) -> Iterator[np.ndarray]:
        """Stream a completed query's embeddings block by block
        (`stream_matches` over the lane's pruned subgraph — bounded memory,
        the full row table never exists at once). A deadline-missed query
        streams nothing."""
        qr = self._done.get(query_id)
        if qr is None:
            raise KeyError(f"query {query_id} has no result yet")
        if qr.status != STATUS_OK:
            return iter(())
        return stream_matches(qr.result, label_freq=self._label_freq,
                              chunk=chunk, max_rows=max_rows)


def example_workload(n: int, seed: int = 0,
                     labels_max: int = 7) -> List[Template]:
    """A mixed cyclic/path/counted template workload (all in the pow2-4
    shape bucket) for demos, benchmarks, and serving tests."""
    shapes = [
        ([0, 1, 2, 3], [(0, 1), (1, 2), (2, 3), (3, 0)]),  # square
        ([0, 1, 2, 3], [(0, 1), (1, 2), (2, 3)]),          # path
        ([0, 1, 2], [(0, 1), (1, 2), (2, 0)]),             # triangle
        ([0, 0, 1], [(0, 1), (1, 2), (2, 0)]),             # counted triangle
    ]
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        labels, edges = shapes[i % len(shapes)]
        base = int(rng.integers(0, max(labels_max - 3, 1)))
        out.append(Template([min(base + l, labels_max) for l in labels],
                            edges))
    return out

"""Production meshes. Functions, not module constants — importing this module
never touches jax device state (required by smoke tests that must see 1 CPU
device). Construction goes through the version-adaptive compat layer so
axis-type annotations degrade gracefully on JAX lines without
typed mesh axes."""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.kernels import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 (data, model) single pod; 2x16x16 (pod, data, model) multi-pod.

    One pod = 256 chips (TPU v5e-256); the pod axis crosses DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes, axis_types=("auto",) * len(axes))


def make_local_mesh() -> Mesh:
    """Whatever this host has — used by examples and tests."""
    n = len(jax.devices())
    return compat.make_mesh((n, 1), ("data", "model"))


def mesh_chips(mesh: Mesh) -> int:
    return mesh.devices.size

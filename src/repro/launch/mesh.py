"""Production meshes. Functions, not module constants — importing this module
never touches jax device state (required by smoke tests that must see 1 CPU
device). Construction goes through the version-adaptive compat layer so
axis-type annotations degrade gracefully on JAX lines without
typed mesh axes."""
from __future__ import annotations

from typing import Optional

import numpy as np

import jax
from jax.sharding import Mesh

from repro.kernels import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 (data, model) single pod; 2x16x16 (pod, data, model) multi-pod.

    One pod = 256 chips (TPU v5e-256); the pod axis crosses DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes, axis_types=("auto",) * len(axes))


def make_shard_mesh(P: Optional[int] = None) -> Mesh:
    """Flat ("shards",) mesh over the first P devices — the mesh the sharded
    constraint-checking backends (core/engine.py) run the full prune pipeline
    on. Defaults to every device this process sees (e.g. 8 under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)."""
    devs = jax.devices()
    P = len(devs) if P is None else P
    if P > len(devs):
        raise ValueError(f"asked for {P} shards but only {len(devs)} devices")
    return compat.make_mesh(
        (P,), ("shards",), axis_types=("auto",),
        devices=np.asarray(devs[:P]))


def make_local_mesh() -> Mesh:
    """Whatever this host has — used by examples and tests."""
    n = len(jax.devices())
    return compat.make_mesh((n, 1), ("data", "model"))


def mesh_chips(mesh: Mesh) -> int:
    return mesh.devices.size

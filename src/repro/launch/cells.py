"""Cell builders: (arch x input-shape x mesh) -> a lowerable jitted program
with allocation-free ShapeDtypeStruct arguments and resolved shardings.

Every assignment cell maps to one of:
  lm train      — build_train_step over microbatched token batches (FSDP+TP,
                  remat, grad accumulation; bf16 optimizer state for the
                  largest configs)
  lm prefill    — forward_hidden + last-position logits
  lm decode     — one serve_step over the KV cache (ring buffer when windowed)
  gnn train     — full-graph segment-op step (node/edge arrays padded to the
                  mesh size) or the sampled-fanout step (graphsage) /
                  sampled-subgraph step (other GNNs) for minibatch_lg
  recsys train  — masked-item step; serve — top-k catalog scoring;
                  retrieval — 1 user x 1M candidates matmul
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_arch
from repro.configs.base import LMConfig, GNNConfig, RecsysConfig, ShapeSpec
from repro.launch.abstract import abstract_init, shardings_for, resolve_spec
from repro.launch.mesh import mesh_chips
from repro.optim.adamw import AdamWConfig
from repro import train as train_lib
from repro.models import transformer, gnn, bert4rec
from repro import serve as serve_lib


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    step_kind: str
    fn: Callable
    args_sds: Tuple
    in_shardings: Tuple
    out_shardings: Any
    mesh: Optional[Mesh] = None
    donate_argnums: Tuple[int, ...] = ()
    # roofline bookkeeping
    model_flops_fn: Optional[Callable[[], float]] = None
    note: str = ""

    def lower(self):
        from repro.sharding import active_mesh
        jitted = jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            out_shardings=self.out_shardings,
            donate_argnums=self.donate_argnums,
        )
        # install the mesh so the models' logical-axis constrain() annotations
        # become real with_sharding_constraint ops during tracing
        with active_mesh(self.mesh):
            return jitted.lower(*self.args_sds)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


# per-arch training knobs (microbatches chosen so DP shards divide)
LM_TRAIN_MICROBATCHES = 8
LM_STATE_DTYPE = {  # bf16 moments for the config that must fit 512 chips
    "deepseek-v3-671b": "bfloat16",
}
GNN_CLASSES = {"full_graph_sm": 7, "minibatch_lg": 41, "ogb_products": 47, "molecule": 2}


# ------------------------------------------------------------------ LM cells
def _lm_train_cell(arch: str, cfg: LMConfig, shape: ShapeSpec, mesh: Mesh) -> Cell:
    k = cfg.train_microbatches or LM_TRAIN_MICROBATCHES
    gb, s = shape.global_batch, shape.seq_len
    mb = gb // k
    tc = train_lib.TrainConfig(
        optimizer=AdamWConfig(state_dtype=LM_STATE_DTYPE.get(arch, "float32")),
        microbatches=k, pre_microbatched=True,
        remat=("dots" if cfg.remat_policy == "dots" else True),
    )
    state_sds, state_specs = abstract_init(
        train_lib.init_state, jax.random.key(0), cfg, tc
    )
    batch_sds = {
        "tokens": _sds((k, mb, s), jnp.int32),
        "labels": _sds((k, mb, s), jnp.int32),
    }
    batch_specs = {"tokens": (None, "batch", None), "labels": (None, "batch", None)}
    state_sh = shardings_for(state_sds, state_specs, mesh)
    batch_sh = shardings_for(batch_sds, batch_specs, mesh)
    step = train_lib.build_train_step(cfg, tc)
    metrics_sds = jax.eval_shape(step, state_sds, batch_sds)[1]
    metrics_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), metrics_sds)
    tokens_per_step = gb * s
    return Cell(
        mesh=mesh, arch=arch, shape=shape.name, step_kind="train_step",
        fn=step, args_sds=(state_sds, batch_sds),
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metrics_sh),
        donate_argnums=(0,),
        model_flops_fn=lambda: 6.0 * cfg.n_active_params() * tokens_per_step,
    )


def _lm_prefill_cell(arch: str, cfg: LMConfig, shape: ShapeSpec, mesh: Mesh) -> Cell:
    params_sds, pspecs = abstract_init(transformer.init, jax.random.key(0), cfg)
    params_sh = shardings_for(params_sds, pspecs, mesh)
    b, s = shape.global_batch, shape.seq_len

    def prefill(params, tokens):
        h, _ = transformer.forward_hidden(params, cfg, tokens, remat=True)
        return transformer.logits_from_hidden(params, cfg, h[:, -1:, :])[:, 0]

    tok_sds = _sds((b, s), jnp.int32)
    tok_sh = NamedSharding(mesh, resolve_spec(tok_sds, ("batch", None), mesh))
    out_sh = NamedSharding(mesh, resolve_spec(
        _sds((b, cfg.vocab), jnp.float32), ("batch", None), mesh))
    return Cell(
        mesh=mesh, arch=arch, shape=shape.name, step_kind="prefill",
        fn=prefill, args_sds=(params_sds, tok_sds),
        in_shardings=(params_sh, tok_sh), out_shardings=out_sh,
        model_flops_fn=lambda: 2.0 * cfg.n_active_params() * b * s,
    )


def _lm_decode_cell(arch: str, cfg: LMConfig, shape: ShapeSpec, mesh: Mesh) -> Cell:
    params_sds, pspecs = abstract_init(transformer.init, jax.random.key(0), cfg)
    params_sh = shardings_for(params_sds, pspecs, mesh)
    b, s = shape.global_batch, shape.seq_len
    cache_sds = jax.eval_shape(lambda: transformer.init_cache(cfg, b, s))
    cache_sh = shardings_for(cache_sds, transformer.cache_specs(cfg), mesh)
    tok_sds = _sds((b,), jnp.int32)
    tok_sh = NamedSharding(mesh, resolve_spec(tok_sds, ("batch",), mesh))
    step = serve_lib.build_decode_step(cfg)
    logits_sh = NamedSharding(mesh, resolve_spec(
        _sds((b, cfg.vocab), jnp.float32), ("batch", None), mesh))
    return Cell(
        mesh=mesh, arch=arch, shape=shape.name, step_kind="serve_step",
        fn=step, args_sds=(params_sds, cache_sds, tok_sds),
        in_shardings=(params_sh, cache_sh, tok_sh),
        out_shardings=(tok_sh, logits_sh, cache_sh),
        donate_argnums=(1,),
        model_flops_fn=lambda: 2.0 * cfg.n_active_params() * b,
        note="one new token against a KV cache of seq_len",
    )


# ----------------------------------------------------------------- GNN cells
def _gnn_batch_sds(shape: ShapeSpec, mesh: Mesh, n_classes: int):
    chips = mesh_chips(mesh)
    if shape.name == "molecule":
        n = _pad_to(shape.n_graphs * shape.n_nodes, chips)
        m = _pad_to(shape.n_graphs * shape.n_edges * 2, chips)
    else:
        n = _pad_to(shape.n_nodes, chips)
        m = _pad_to(shape.n_edges, chips)
    sds = {
        "x": _sds((n, shape.d_feat), jnp.float32),
        "src": _sds((m,), jnp.int32),
        "dst": _sds((m,), jnp.int32),
        "labels": _sds((n,), jnp.int32),
        "train_mask": _sds((n,), jnp.bool_),
        "log_deg_avg": _sds((), jnp.float32),
    }
    specs = {
        "x": ("nodes", None), "src": ("edges",), "dst": ("edges",),
        "labels": ("nodes",), "train_mask": ("nodes",), "log_deg_avg": (),
    }
    return sds, specs


def _gnn_sampled_sds(cfg: GNNConfig, shape: ShapeSpec):
    b = shape.batch_nodes
    f1, f2 = shape.fanout
    d = shape.d_feat
    sds = {
        "x_self": _sds((b, d), jnp.float32),
        "x_nbr": _sds((b, f1, d), jnp.float32),
        "x_nbr2": _sds((b, f1, f2, d), jnp.float32),
        "labels": _sds((b,), jnp.int32),
    }
    specs = {
        "x_self": ("batch", None), "x_nbr": ("batch", None, None),
        "x_nbr2": ("batch", None, None, None), "labels": ("batch",),
    }
    return sds, specs


def _gnn_sampled_subgraph_sds(shape: ShapeSpec, mesh: Mesh):
    """Non-graphsage archs on minibatch_lg: block-diagonal sampled subgraph."""
    b = shape.batch_nodes
    f1, f2 = shape.fanout
    chips = mesh_chips(mesh)
    n = _pad_to(b * (1 + f1 + f1 * f2), chips)
    m = _pad_to(b * f1 + b * f1 * f2, chips)
    sds = {
        "x": _sds((n, shape.d_feat), jnp.float32),
        "src": _sds((m,), jnp.int32),
        "dst": _sds((m,), jnp.int32),
        "labels": _sds((n,), jnp.int32),
        "train_mask": _sds((n,), jnp.bool_),
        "log_deg_avg": _sds((), jnp.float32),
    }
    specs = {
        "x": ("nodes", None), "src": ("edges",), "dst": ("edges",),
        "labels": ("nodes",), "train_mask": ("nodes",), "log_deg_avg": (),
    }
    return sds, specs


def _gnn_distributed_cell(arch: str, cfg: GNNConfig, shape: ShapeSpec,
                          mesh: Mesh) -> Cell:
    """Full-graph GNN over the engine's edge partition (§Perf optimized path):
    shard_map + one bucketed all_to_all per aggregation sweep."""
    from repro.models import gnn_distributed as gd
    from repro.optim import adamw

    n_classes = GNN_CLASSES[shape.name]
    chips = mesh_chips(mesh)
    axes = tuple(mesh.axis_names)
    n = shape.n_nodes if shape.name != "molecule" else shape.n_graphs * shape.n_nodes
    m = shape.n_edges if shape.name != "molecule" else shape.n_graphs * shape.n_edges * 2
    shapes = gd.partitioned_batch_shapes(n, m, chips, shape.d_feat)
    n_local = shapes["x"][0][1]
    batch_sds = {k: _sds(*v) for k, v in shapes.items()}
    spec_shard = tuple(axes)
    batch_specs = {
        "x": ("part_shard", None, None), "send_src_local": ("part_shard", None, None),
        "recv_dst_local": ("part_shard", None), "labels": ("part_shard", None),
        "train_mask": ("part_shard", None), "log_deg_avg": (),
    }
    rules = dict()
    from repro.sharding import DEFAULT_RULES
    rules.update(DEFAULT_RULES)
    rules["part_shard"] = axes
    loss_fn = gd.build_distributed_pna_loss(cfg, mesh, axes, n_local)
    oc = AdamWConfig(weight_decay=0.0)

    def init_fn(rng):
        from repro.models import gnn as gnn_mod
        params, specs = gnn_mod.init(rng, cfg, shape.d_feat, n_classes)
        state = {"params": params, "opt": adamw.init_state(params, oc),
                 "step": jnp.zeros((), jnp.int32)}
        sspec = {"params": specs, "opt": adamw.state_specs(specs), "step": ()}
        return state, sspec

    state_sds, state_specs = abstract_init(init_fn, jax.random.key(0))

    def step(state, batch):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], batch)
        new_params, new_opt, om = adamw.update(
            grads, state["opt"], state["params"], oc)
        return ({"params": new_params, "opt": new_opt, "step": state["step"] + 1},
                {"loss": loss, **om})

    state_sh = shardings_for(state_sds, state_specs, mesh, rules=rules)
    batch_sh = shardings_for(batch_sds, batch_specs, mesh, rules=rules)
    metrics_sds = jax.eval_shape(step, state_sds, batch_sds)[1]
    metrics_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), metrics_sds)
    dh = cfg.d_hidden
    flops = 2.0 * cfg.n_layers * (m * dh + n * dh * dh) * 3
    return Cell(
        mesh=mesh, arch=arch, shape=shape.name, step_kind="train_step",
        fn=step, args_sds=(state_sds, batch_sds),
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metrics_sh),
        donate_argnums=(0,),
        model_flops_fn=lambda: flops,
        note="edge-partition shard_map message passing",
    )


def _gnn_train_cell(arch: str, cfg: GNNConfig, shape: ShapeSpec, mesh: Mesh) -> Cell:
    if (cfg.distributed and cfg.model == "pna"
            and shape.name in ("full_graph_sm", "ogb_products")):
        return _gnn_distributed_cell(arch, cfg, shape, mesh)
    n_classes = GNN_CLASSES[shape.name]
    tc = train_lib.TrainConfig(optimizer=AdamWConfig(weight_decay=0.0))
    sampled = shape.name == "minibatch_lg" and cfg.model == "graphsage"
    if sampled:
        batch_sds, batch_specs = _gnn_sampled_sds(cfg, shape)
    elif shape.name == "minibatch_lg":
        batch_sds, batch_specs = _gnn_sampled_subgraph_sds(shape, mesh)
    else:
        batch_sds, batch_specs = _gnn_batch_sds(shape, mesh, n_classes)
    state_sds, state_specs = abstract_init(
        train_lib.init_state, jax.random.key(0), cfg, tc,
        d_in=shape.d_feat, n_classes=n_classes,
    )
    state_sh = shardings_for(state_sds, state_specs, mesh)
    batch_sh = shardings_for(batch_sds, batch_specs, mesh)
    step = train_lib.build_train_step(cfg, tc)
    metrics_sds = jax.eval_shape(step, state_sds, batch_sds)[1]
    metrics_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), metrics_sds)
    # model flops: per edge, d_hidden MACs per layer (order of magnitude)
    m = batch_sds["src"].shape[0] if "src" in batch_sds else (
        shape.batch_nodes * (shape.fanout[0] + shape.fanout[0] * shape.fanout[1]))
    nn = batch_sds["x"].shape[0] if "x" in batch_sds else shape.batch_nodes
    dh = cfg.d_hidden
    flops = 2.0 * cfg.n_layers * (m * dh + nn * dh * dh) * 3  # fwd+bwd
    return Cell(
        mesh=mesh, arch=arch, shape=shape.name, step_kind="train_step",
        fn=step, args_sds=(state_sds, batch_sds),
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metrics_sh),
        donate_argnums=(0,),
        model_flops_fn=lambda: flops,
    )


# -------------------------------------------------------------- recsys cells
def _recsys_train_cell(arch: str, cfg: RecsysConfig, shape: ShapeSpec, mesh: Mesh) -> Cell:
    k = 8
    mb = shape.batch // k
    tc = train_lib.TrainConfig(
        optimizer=AdamWConfig(), microbatches=k, pre_microbatched=True)
    state_sds, state_specs = abstract_init(train_lib.init_state, jax.random.key(0), cfg, tc)
    batch_sds = {
        "items": _sds((k, mb, cfg.seq_len), jnp.int32),
        "labels": _sds((k, mb, cfg.seq_len), jnp.int32),
        "mlm_mask": _sds((k, mb, cfg.seq_len), jnp.bool_),
    }
    batch_specs = {k2: (None, "batch", None) for k2 in batch_sds}
    state_sh = shardings_for(state_sds, state_specs, mesh)
    batch_sh = shardings_for(batch_sds, batch_specs, mesh)
    step = train_lib.build_train_step(cfg, tc)
    metrics_sds = jax.eval_shape(step, state_sds, batch_sds)[1]
    metrics_sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), metrics_sds)
    # useful flops: encoder matmuls per token + the head of the *lowered*
    # algorithm (full catalog or 1+N sampled candidates)
    per_tok = cfg.n_blocks * 12 * cfg.embed_dim ** 2
    v_eff = (1 + cfg.n_negatives) if cfg.n_negatives else (cfg.n_items + 2)
    tokens = shape.batch * cfg.seq_len
    return Cell(
        mesh=mesh, arch=arch, shape=shape.name, step_kind="train_step",
        fn=step, args_sds=(state_sds, batch_sds),
        in_shardings=(state_sh, batch_sh), out_shardings=(state_sh, metrics_sh),
        donate_argnums=(0,),
        model_flops_fn=lambda: 6.0 * tokens * (per_tok + cfg.embed_dim * v_eff),
    )


def _recsys_serve_cell(arch: str, cfg: RecsysConfig, shape: ShapeSpec, mesh: Mesh) -> Cell:
    params_sds, pspecs = abstract_init(bert4rec.init, jax.random.key(0), cfg)
    params_sh = shardings_for(params_sds, pspecs, mesh)
    b = shape.batch

    def serve(params, items):
        scores = bert4rec.serve_scores(params, cfg, items)
        vals, ids = jax.lax.top_k(scores, 100)
        return {"scores": vals, "ids": ids}

    items_sds = _sds((b, cfg.seq_len), jnp.int32)
    items_sh = NamedSharding(mesh, resolve_spec(items_sds, ("batch", None), mesh))
    topk_sds = _sds((b, 100), jnp.float32)
    topk_sh = NamedSharding(mesh, resolve_spec(topk_sds, ("batch", None), mesh))
    out_sh = {"scores": topk_sh, "ids": topk_sh}
    per_tok = cfg.n_blocks * 12 * cfg.embed_dim ** 2
    return Cell(
        mesh=mesh, arch=arch, shape=shape.name, step_kind="serve_step",
        fn=serve, args_sds=(params_sds, items_sds),
        in_shardings=(params_sh, items_sh), out_shardings=out_sh,
        model_flops_fn=lambda: 2.0 * b * (
            cfg.seq_len * per_tok + cfg.embed_dim * (cfg.n_items + 2)),
    )


def _recsys_retrieval_cell(arch: str, cfg: RecsysConfig, shape: ShapeSpec, mesh: Mesh) -> Cell:
    params_sds, pspecs = abstract_init(bert4rec.init, jax.random.key(0), cfg)
    params_sh = shardings_for(params_sds, pspecs, mesh)
    b, c = shape.batch, shape.n_candidates

    def retrieve(params, items, cands):
        return bert4rec.retrieval_scores(params, cfg, items, cands)

    items_sds = _sds((b, cfg.seq_len), jnp.int32)
    cands_sds = _sds((c,), jnp.int32)
    items_sh = NamedSharding(mesh, resolve_spec(items_sds, ("batch", None), mesh))
    cands_sh = NamedSharding(mesh, resolve_spec(cands_sds, ("candidates",), mesh))
    out_sds = _sds((b, c), jnp.float32)
    out_sh = NamedSharding(mesh, resolve_spec(out_sds, (None, "candidates"), mesh))
    per_tok = cfg.n_blocks * 12 * cfg.embed_dim ** 2
    return Cell(
        mesh=mesh, arch=arch, shape=shape.name, step_kind="retrieval",
        fn=retrieve, args_sds=(params_sds, items_sds, cands_sds),
        in_shardings=(params_sh, items_sh, cands_sh), out_shardings=out_sh,
        model_flops_fn=lambda: 2.0 * (
            b * cfg.seq_len * per_tok + b * c * cfg.embed_dim),
    )


# ------------------------------------------------------------------ dispatch
def build_cell(arch: str, shape_name: str, mesh: Mesh,
               cfg_overrides: Optional[Dict] = None) -> Optional[Cell]:
    """Returns None when the cell is marked skipped for this arch.

    The active mesh is installed for the whole build: jax's trace cache is
    shared between the eval_shape calls here and the later jit .lower(), so
    the FIRST trace must already carry the constrain() annotations. (Each
    builder creates a fresh step function, so traces never leak between
    meshes.)

    cfg_overrides (perf iterations): dataclasses.replace fields on the arch
    config, e.g. {"moe_groups": 32}."""
    from repro.sharding import active_mesh
    with active_mesh(mesh):
        return _build_cell(arch, shape_name, mesh, cfg_overrides)


def _build_cell(arch: str, shape_name: str, mesh: Mesh,
                cfg_overrides: Optional[Dict] = None) -> Optional[Cell]:
    mod = get_arch(arch)
    cfg = mod.CONFIG
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = mod.SHAPES[shape_name]
    if shape.skip:
        return None
    if isinstance(cfg, LMConfig):
        if shape.step == "train":
            return _lm_train_cell(arch, cfg, shape, mesh)
        if shape.step == "prefill":
            return _lm_prefill_cell(arch, cfg, shape, mesh)
        if shape.step == "decode":
            return _lm_decode_cell(arch, cfg, shape, mesh)
    if isinstance(cfg, GNNConfig):
        return _gnn_train_cell(arch, cfg, shape, mesh)
    if isinstance(cfg, RecsysConfig):
        if shape.step == "train":
            return _recsys_train_cell(arch, cfg, shape, mesh)
        if shape.step == "serve":
            return _recsys_serve_cell(arch, cfg, shape, mesh)
        if shape.step == "retrieval":
            return _recsys_retrieval_cell(arch, cfg, shape, mesh)
    raise ValueError((arch, shape_name))

"""Serving entry point: graph-query serving (the paper's multi-tenant
pattern-matching scenario), batched greedy generation (LM), or catalog
scoring (recsys) on the smoke configs.

  PYTHONPATH=src python -m repro.launch.serve --graph-queries 32 \
      --graph-scale 9 --max-batch 8
  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-15b \
      --batch 4 --prompt-len 16 --max-new 32

Kernel calls in the serving hot loop (batched prune waves, attention,
embedding_bag) route through the dispatch registry; `--policy` loads a tuned
dispatch-policy cache (from `registry.tune()` / `python -m benchmarks.run`)
so serving uses the measured kernel-mode decisions for this host instead of
the untuned fallback — graph serving resolves batched routes under
b<B>-prefixed bucket keys.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_arch
from repro.configs.base import LMConfig, RecsysConfig
from repro.kernels import registry
from repro.models import transformer, bert4rec
from repro import serve as serve_lib
from repro.data import MaskedSequenceStream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS,
                    help="LM/recsys smoke-config serving (mutually "
                         "exclusive with --graph-queries)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--graph-queries", type=int, default=0, metavar="N",
                    help="serve N template queries against a synthetic "
                         "metadata graph through the batched prune engine")
    ap.add_argument("--graph-scale", type=int, default=9,
                    help="rmat graph scale (2^scale vertices)")
    ap.add_argument("--partition", type=int, default=None,
                    help="shard the background graph P ways")
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-wait", type=float, default=0.05,
                    help="batcher max wait (seconds) before launching a "
                         "partial batch")
    ap.add_argument("--timeout", type=float, default=None,
                    help="per-query serving deadline in seconds")
    ap.add_argument("--policy", default=None, metavar="PATH",
                    help="dispatch-policy cache to serve under "
                         "(default: the registry's lazy policy_path() load)")
    args = ap.parse_args()

    if args.policy:
        registry.set_policy(registry.DispatchPolicy.load(args.policy))
        print(f"dispatch policy: {args.policy} "
              f"({len(registry.get_policy().modes)} tuned kernel modes)")

    if args.graph_queries:
        _serve_graph(args)
        return
    if not args.arch:
        raise SystemExit("pass --arch (LM/recsys) or --graph-queries N")

    cfg = get_arch(args.arch).smoke()
    if isinstance(cfg, LMConfig):
        params, _ = transformer.init(jax.random.key(0), cfg)
        prompt = jax.random.randint(
            jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab)
        t0 = time.perf_counter()
        out = serve_lib.greedy_generate(
            params, cfg, prompt, args.max_new, args.prompt_len + args.max_new)
        dt = time.perf_counter() - t0
        toks = args.batch * args.max_new
        print(f"generated {out.shape} in {dt:.2f}s "
              f"({toks / dt:.1f} tok/s batched greedy)")
        print(out[:2, :16])
    elif isinstance(cfg, RecsysConfig):
        params, _ = bert4rec.init(jax.random.key(0), cfg)
        items = MaskedSequenceStream(cfg.n_items, args.batch, cfg.seq_len)(0)["items"]
        t0 = time.perf_counter()
        scores = bert4rec.serve_scores(params, cfg, items)
        top = jax.lax.top_k(scores, 10)[1]
        print(f"scored {scores.shape} in {time.perf_counter()-t0:.2f}s; "
              f"top-10 for user 0: {top[0]}")
    else:
        raise SystemExit("GNN archs serve through examples/pattern_gnn.py")


def _serve_graph(args):
    from repro.graph import rmat_graph
    from repro.serve import GraphQueryEngine, example_workload, MODE_COUNT

    g = rmat_graph(args.graph_scale, edge_factor=8, seed=5)
    print(f"background graph: n={g.n} m={g.m} "
          f"(rmat scale {args.graph_scale})")
    eng = GraphQueryEngine(
        g, partition=args.partition, max_batch=args.max_batch,
        max_wait_s=args.max_wait)
    templates = example_workload(args.graph_queries, seed=1,
                                 labels_max=int(g.labels.max()))
    t0 = time.perf_counter()
    ids = [eng.submit(t, mode=MODE_COUNT, timeout_s=args.timeout)
           for t in templates]
    results = eng.drain()
    dt = time.perf_counter() - t0
    assert len(results) == len(ids)
    ok = [r for r in results if r.status == "ok"]
    missed = len(results) - len(ok)
    print(f"served {len(results)} queries in {dt:.2f}s "
          f"({len(results) / dt:.1f} q/s) across "
          f"{eng.stats['n_batches']} batches; deadline_missed={missed}")
    for b in eng.stats["batches"]:
        print(f"  batch {b['batch_id']}: B={b['B']} bucket={b['bucket']} "
              f"{b['seconds']:.2f}s")
    for r in ok[:4]:
        print(f"  query {r.query_id}: {r.n_embeddings} matches "
              f"(batch {r.batch_id}, waited {r.wait_s * 1e3:.0f}ms)")


if __name__ == "__main__":
    main()

"""Serving entry point: batched greedy generation (LM) or catalog scoring
(recsys) on the smoke configs.

  PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-15b \
      --batch 4 --prompt-len 16 --max-new 32

Kernel calls in the serving hot loop (attention, embedding_bag) route through
the dispatch registry; `--policy` loads a tuned dispatch-policy cache (from
`registry.tune()` / `python -m benchmarks.run`) so serving uses the measured
kernel-mode decisions for this host instead of the untuned fallback.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_arch
from repro.configs.base import LMConfig, RecsysConfig
from repro.kernels import registry
from repro.models import transformer, bert4rec
from repro import serve as serve_lib
from repro.data import MaskedSequenceStream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--policy", default=None, metavar="PATH",
                    help="dispatch-policy cache to serve under "
                         "(default: the registry's lazy policy_path() load)")
    args = ap.parse_args()

    if args.policy:
        registry.set_policy(registry.DispatchPolicy.load(args.policy))
        print(f"dispatch policy: {args.policy} "
              f"({len(registry.get_policy().modes)} tuned kernel modes)")

    cfg = get_arch(args.arch).smoke()
    if isinstance(cfg, LMConfig):
        params, _ = transformer.init(jax.random.key(0), cfg)
        prompt = jax.random.randint(
            jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab)
        t0 = time.perf_counter()
        out = serve_lib.greedy_generate(
            params, cfg, prompt, args.max_new, args.prompt_len + args.max_new)
        dt = time.perf_counter() - t0
        toks = args.batch * args.max_new
        print(f"generated {out.shape} in {dt:.2f}s "
              f"({toks / dt:.1f} tok/s batched greedy)")
        print(out[:2, :16])
    elif isinstance(cfg, RecsysConfig):
        params, _ = bert4rec.init(jax.random.key(0), cfg)
        items = MaskedSequenceStream(cfg.n_items, args.batch, cfg.seq_len)(0)["items"]
        t0 = time.perf_counter()
        scores = bert4rec.serve_scores(params, cfg, items)
        top = jax.lax.top_k(scores, 10)[1]
        print(f"scored {scores.shape} in {time.perf_counter()-t0:.2f}s; "
              f"top-10 for user 0: {top[0]}")
    else:
        raise SystemExit("GNN archs serve through examples/pattern_gnn.py")


if __name__ == "__main__":
    main()

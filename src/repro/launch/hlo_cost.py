"""Loop-aware HLO cost model (flops / bytes / collective bytes).

XLA's `compiled.cost_analysis()` counts each `while` body ONCE regardless of
trip count (verified empirically — a scan of 8 matmuls reports 1 matmul of
flops), which silently undercounts every scanned-layer model by ~n_layers x.
This walker parses the compiled (SPMD-partitioned, per-device) HLO text and
computes:

  flops            dot ops: 2 * out_elems * contracted_size; elementwise ~1/elem
  bytes            per instruction: operand bytes + output bytes (fusion
                   internals excluded — fused intermediates stay in registers,
                   matching XLA's model)
  collective bytes operand bytes of all-gather / all-reduce / reduce-scatter /
                   all-to-all / collective-permute, BY KIND

multiplying every `while` body/condition by its `known_trip_count` from
backend_config (fallback: largest integer constant in the condition). All
shapes in compiled SPMD HLO are per-device, so all numbers are per-device.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3b11fnuz": 1, "token": 0, "opaque": 0, "f8e4m3": 1, "f8e5m2fnuz": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPERAND_REF_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_INT_RE = re.compile(r"constant\((\d+)\)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")

_COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "opt-barrier", "partition-id", "replica-id",
}


def _shape_list(text: str) -> List[Tuple[str, List[int]]]:
    return [(d, [int(x) for x in dims.split(",")] if dims else [])
            for d, dims in _SHAPE_RE.findall(text)]


def _bytes_of(text: str) -> int:
    total = 0
    for dtype, dims in _shape_list(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _elems_of_first(text: str) -> int:
    shapes = [s for s in _shape_list(text) if s[0] in _DTYPE_BYTES]
    if not shapes:
        return 0
    n = 1
    for d in shapes[0][1]:
        n *= d
    return n


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    out_text: str        # output type string
    attrs: str           # everything after the operand parens
    operands: List[str]
    raw_operands: str = ""  # literal operand text (parameter indices etc.)

    @property
    def out_bytes(self) -> int:
        return _bytes_of(self.out_text)

    @property
    def out_elems(self) -> int:
        return _elems_of_first(self.out_text)


def _parse_instruction(line: str) -> Optional[Instr]:
    m = _INSTR_RE.match(line)
    if not m:
        return None
    name, rhs = m.groups()
    # type: either "(tuple...)" or a single token
    if rhs.startswith("("):
        depth, i = 0, 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        out_text = rhs[: i + 1]
        rest = rhs[i + 1:].strip()
    else:
        sp = rhs.find(" ")
        out_text = rhs[:sp]
        rest = rhs[sp + 1:].strip()
    om = re.match(r"([\w\-]+)\(", rest)
    if not om:
        return None
    op = om.group(1)
    # operand list = up to the matching close paren
    depth, j = 0, om.end() - 1
    for j in range(om.end() - 1, len(rest)):
        if rest[j] == "(":
            depth += 1
        elif rest[j] == ")":
            depth -= 1
            if depth == 0:
                break
    operand_text = rest[om.end(): j]
    attrs = rest[j + 1:]
    operands = _OPERAND_REF_RE.findall(operand_text)
    return Instr(name=name, op=op, out_text=out_text, attrs=attrs,
                 operands=operands, raw_operands=operand_text)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", times: float = 1.0):
        self.flops += other.flops * times
        self.bytes += other.bytes * times
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * times

    @property
    def coll_total(self) -> float:
        return sum(v for k, v in self.coll.items() if not k.startswith("n_"))


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[str, Cost] = {}

    def _parse(self, text: str):
        cur: Optional[str] = None
        for line in text.splitlines():
            h = _HDR_RE.match(line)
            if h:
                cur = h.group(2)
                self.computations[cur] = []
                if h.group(1):
                    self.entry = cur
                continue
            if cur is None:
                continue
            if line.strip() == "}":
                cur = None
                continue
            ins = _parse_instruction(line)
            if ins is not None:
                self.computations[cur].append(ins)

    # ------------------------------------------------------------- dot flops
    def _dot_flops(self, ins: Instr, defs: Dict[str, Instr]) -> float:
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
        cdims = [int(x) for x in m.group(1).split(",")] if (m and m.group(1)) else []
        lhs = defs.get(ins.operands[0]) if ins.operands else None
        contract = 1
        if lhs is not None:
            shapes = [s for s in _shape_list(lhs.out_text) if s[0] in _DTYPE_BYTES]
            if shapes:
                dims = shapes[0][1]
                for c in cdims:
                    if c < len(dims):
                        contract *= dims[c]
        return 2.0 * ins.out_elems * max(contract, 1)

    def _trip_count(self, ins: Instr) -> int:
        m = _TRIP_RE.search(ins.attrs)
        if m:
            return int(m.group(1))
        cm = _COND_RE.search(ins.attrs)
        if cm and cm.group(1) in self.computations:
            consts = []
            for ci in self.computations[cm.group(1)]:
                consts += [int(x) for x in _CONST_INT_RE.findall(
                    ci.op + "(" + ins.attrs + ")") if int(x) > 0]
                consts += [int(x) for x in _CONST_INT_RE.findall(ci.attrs)]
                if ci.op == "constant":
                    mm = re.search(r"constant\((\d+)\)", ci.out_text + " " + ci.attrs)
                    if mm:
                        consts.append(int(mm.group(1)))
            if consts:
                return max(consts)
        return 1

    _SLICE_OPS = {"dynamic-slice", "slice", "gather"}

    def _fusion_operand_bytes(self, called: str, operand_bytes: List[int]) -> float:
        """Bytes actually read from each fusion operand.

        A fusion that only *slices* a parameter (dynamic-slice / slice /
        gather applied directly to it) reads the slice, not the whole array —
        charging full operand bytes would overcount per-bucket gathers by the
        number of buckets. For such parameters we charge the summed slice
        outputs (capped at the full size)."""
        instrs = self.computations.get(called)
        if instrs is None:
            return float(sum(operand_bytes))
        uses: Dict[str, List[Instr]] = {}
        for ins in instrs:
            for o in ins.operands:
                uses.setdefault(o, []).append(ins)
        total = 0.0
        seen_idx = set()
        for p in instrs:
            if p.op != "parameter":
                continue
            m = re.match(r"\s*(\d+)", p.raw_operands)
            idx = int(m.group(1)) if m else -1
            if not (0 <= idx < len(operand_bytes)):
                continue
            seen_idx.add(idx)
            full = operand_bytes[idx]
            pu = uses.get(p.name, [])
            if pu and all(u.op in self._SLICE_OPS and u.operands
                          and u.operands[0] == p.name for u in pu):
                sliced = sum(u.out_bytes for u in pu)
                total += min(sliced, full)
            else:
                total += full
        # operands without a parsed parameter — charge fully
        total += sum(b for i, b in enumerate(operand_bytes) if i not in seen_idx)
        return total

    def cost(self, comp: Optional[str] = None) -> Cost:
        comp = comp or self.entry
        if comp in self._memo:
            return self._memo[comp]
        total = Cost()
        self._memo[comp] = total  # recursion guard (shouldn't recurse)
        instrs = self.computations.get(comp, [])
        defs = {i.name: i for i in instrs}
        for ins in instrs:
            op = ins.op
            site_bytes = 0.0
            if op not in _SKIP_BYTES_OPS:
                operand_bytes = [defs[o].out_bytes for o in ins.operands if o in defs]
                if op == "fusion":
                    cm0 = _CALLS_RE.search(ins.attrs)
                    ob = self._fusion_operand_bytes(
                        cm0.group(1) if cm0 else "", operand_bytes)
                else:
                    ob = float(sum(operand_bytes))
                site_bytes = ob + float(ins.out_bytes)
            base_kind = re.sub(r"-(start|done)$", "", op)
            if op == "while":
                trip = self._trip_count(ins)
                bm = _BODY_RE.search(ins.attrs)
                cm = _COND_RE.search(ins.attrs)
                if bm and bm.group(1) in self.computations:
                    total.add(self.cost(bm.group(1)), times=trip)
                if cm and cm.group(1) in self.computations:
                    total.add(self.cost(cm.group(1)), times=trip)
                total.bytes += site_bytes
            elif op in ("fusion", "call", "async-start"):
                cm = _CALLS_RE.search(ins.attrs)
                inner = None
                if cm and cm.group(1) in self.computations:
                    inner = self.cost(cm.group(1))
                    total.flops += inner.flops
                    for k, v in inner.coll.items():
                        total.coll[k] = total.coll.get(k, 0.0) + v
                if op == "call" and inner is not None:
                    # a resolved call is a transparent wrapper: the callee
                    # charged its own instruction bytes (incl. slice-aware
                    # fusion operand accounting) — charging the call site's
                    # operands again would re-bill whole arrays per call
                    total.bytes += inner.bytes
                else:
                    total.bytes += site_bytes
            elif op == "conditional":
                bm = _BRANCHES_RE.search(ins.attrs)
                if bm:
                    branches = _OPERAND_REF_RE.findall(bm.group(1)) or [
                        b.strip().lstrip("%") for b in bm.group(1).split(",")]
                    costs = [self.cost(b) for b in branches if b in self.computations]
                    if costs:
                        worst = max(costs, key=lambda c: c.flops + c.bytes)
                        total.add(worst)
                total.bytes += site_bytes
            elif base_kind in _COLLECTIVE_KINDS:
                if not op.endswith("-done"):
                    opb = float(sum(
                        defs[o].out_bytes for o in ins.operands if o in defs))
                    if opb == 0.0:
                        opb = float(ins.out_bytes)
                    total.coll[base_kind] = total.coll.get(base_kind, 0.0) + opb
                    total.coll[f"n_{base_kind}"] = total.coll.get(f"n_{base_kind}", 0.0) + 1
                total.bytes += site_bytes
            elif op == "dot":
                total.flops += self._dot_flops(ins, defs)
                total.bytes += site_bytes
            elif op == "convolution":
                # rough: 2 * out_elems * kernel_elems (no convs in this repo)
                total.flops += 2.0 * ins.out_elems
                total.bytes += site_bytes
            elif op in ("custom-call",):
                total.bytes += site_bytes
            else:
                total.flops += float(ins.out_elems)
                total.bytes += site_bytes
        self._memo[comp] = total
        return total


def analyze(hlo_text: str) -> Dict:
    """Loop-aware per-device cost summary of a compiled HLO module."""
    model = HloCostModel(hlo_text)
    c = model.cost()
    out = {
        "flops_per_device": c.flops,
        "bytes_per_device": c.bytes,
        "collective_bytes_per_device": c.coll_total,
        "collectives": dict(c.coll),
    }
    return out

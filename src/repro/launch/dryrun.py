import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable (e)).

For every (architecture x input shape x mesh) cell:
  jax.jit(step, in_shardings, out_shardings).lower(**ShapeDtypeStructs)
      .compile()
then records memory_analysis(), cost_analysis(), and the collective schedule
parsed from the compiled SPMD HLO, and derives the three roofline terms.

Meshes: 16x16 (data, model) single pod — the roofline table — and
2x16x16 (pod, data, model) — proves the pod axis shards. Results stream to
experiments/dryrun/<mesh>/<arch>__<shape>.json as they complete (the full
sweep is ~75 compiles of production-size programs).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--out DIR]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_arch
from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.launch.cells import build_cell
from repro.launch.hlo_cost import analyze as hlo_analyze
from repro.launch.roofline import Roofline


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str, out_dir: str):
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh)
    if cell is None:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped",
               "reason": get_arch(arch).SHAPES[shape_name].skip}
        _dump(rec, out_dir, mesh_name, arch, shape_name)
        return rec
    lowered = cell.lower()
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    # loop-aware cost model (XLA's cost_analysis counts while bodies once —
    # see hlo_cost.py; the raw XLA numbers are kept for cross-checking)
    cost = hlo_analyze(hlo)
    rl = Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=mesh_chips(mesh),
        hlo_flops_per_device=cost["flops_per_device"],
        hlo_bytes_per_device=cost["bytes_per_device"],
        collective_bytes_per_device=cost["collective_bytes_per_device"],
        model_flops=cell.model_flops_fn() if cell.model_flops_fn else None,
    )
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "step_kind": cell.step_kind,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_per_device_gib": round(
                (ma.argument_size_in_bytes + ma.output_size_in_bytes
                 + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3),
        },
        "collectives": cost["collectives"],
        "xla_cost_analysis_raw": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "note": "XLA counts while bodies once; roofline uses hlo_cost.py",
        },
        "roofline": rl.to_dict(),
        "note": cell.note,
    }
    _dump(rec, out_dir, mesh_name, arch, shape_name)
    return rec


def _dump(rec, out_dir, mesh_name, arch, shape_name):
    d = os.path.join(out_dir, mesh_name)
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, f"{arch}__{shape_name}.json"), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape name (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, "dry-run requires 512 forced host devices"
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    archs = [args.arch] if args.arch else list(ARCH_IDS)
    failures = []
    for mesh_name, mesh in meshes:
        for arch in archs:
            shapes = [args.shape] if args.shape else list(get_arch(arch).SHAPES)
            for shape_name in shapes:
                tag = f"{mesh_name} {arch} x {shape_name}"
                path = os.path.join(args.out, mesh_name, f"{arch}__{shape_name}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip-existing] {tag}")
                    continue
                try:
                    rec = run_cell(arch, shape_name, mesh, mesh_name, args.out)
                except Exception as e:  # a dry-run failure is a bug in the system
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e}")
                    traceback.print_exc()
                    continue
                if rec["status"] == "skipped":
                    print(f"[skipped] {tag}: {rec['reason']}")
                else:
                    r = rec["roofline"]
                    print(
                        f"[ok] {tag}: {rec['step_kind']} "
                        f"compile={rec['compile_s']}s "
                        f"mem/dev={rec['memory']['peak_per_device_gib']}GiB "
                        f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                        f"coll={r['collective_s']:.3e}s -> {r['bottleneck']}"
                    )
    print(f"\n{len(failures)} failures")
    for tag, err in failures:
        print(f"  {tag}: {err}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()

"""Training entry point.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --steps 100 \
      [--smoke] [--ckpt-dir DIR] [--batch 8] [--seq 128]

--smoke uses the reduced config (CPU-runnable); the full configs are meant
for real accelerator fleets — on this host they are exercised through the
dry-run. The loop is the fault-tolerant trainer (checkpoint/restart,
deterministic skip-ahead).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_IDS, get_arch
from repro.configs.base import LMConfig, GNNConfig, RecsysConfig
from repro.train import TrainConfig, build_train_step, init_state, trainer
from repro.optim.adamw import AdamWConfig
from repro.data import (
    SyntheticTokenStream, MaskedSequenceStream, full_graph_batch,
)
from repro.graph import generators as gen
from repro.sharding import active_mesh
from repro.launch.mesh import make_local_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    mod = get_arch(args.arch)
    cfg = mod.smoke() if args.smoke else mod.CONFIG
    tc = TrainConfig(optimizer=AdamWConfig(lr=args.lr),
                     warmup_steps=max(args.steps // 10, 1), total_steps=args.steps)

    if isinstance(cfg, LMConfig):
        state, specs = init_state(jax.random.key(0), cfg, tc)
        batch_fn = SyntheticTokenStream(cfg.vocab, args.batch, args.seq, seed=0)
    elif isinstance(cfg, GNNConfig):
        g = gen.rmat_graph(11, edge_factor=8, seed=0)
        batch = full_graph_batch(g, d_feat=32, n_classes=8, seed=0)
        state, specs = init_state(jax.random.key(0), cfg, tc, d_in=32, n_classes=8)
        batch_fn = lambda step: batch  # noqa: E731
    else:
        state, specs = init_state(jax.random.key(0), cfg, tc)
        batch_fn = MaskedSequenceStream(cfg.n_items, args.batch, cfg.seq_len, seed=0)

    step = jax.jit(build_train_step(cfg, tc))
    report = trainer.run(
        state, step, batch_fn, num_steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_interval=args.ckpt_interval,
        log_every=args.log_every,
    )
    print(f"done: {report.steps_run} steps, loss {report.losses[0]:.4f} -> "
          f"{report.losses[-1]:.4f}, "
          f"{1e3 * sum(report.step_times)/max(len(report.step_times),1):.1f} ms/step")


if __name__ == "__main__":
    main()

"""Three-term roofline from a compiled dry-run artifact (TPU v5e targets).

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw

(`cost_analysis()` on this JAX version reports per-device numbers for SPMD
modules — verified empirically in launch/dryrun.py's self-check — so the
per-chip division of the assignment formulas is already applied.)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 per chip (TPU v5e)
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective_bytes_per_device: float
    model_flops: Optional[float]  # 6*N*D / 2*N*D analytic, global

    @property
    def compute_s(self) -> float:
        return self.hlo_flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_device / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        """MODEL_FLOPS / HLO_FLOPs (global) — remat/redundancy waste detector."""
        if not self.model_flops:
            return None
        total = self.hlo_flops_per_device * self.chips
        return self.model_flops / total if total else None

    @property
    def roofline_fraction(self) -> Optional[float]:
        """Fraction of the chip's peak the dominant-term time would realize on
        useful model FLOPs — the headline §Perf score."""
        if not self.model_flops:
            return None
        t = max(self.compute_s, self.memory_s, self.collective_s)
        if t <= 0:
            return None
        return (self.model_flops / self.chips) / (t * PEAK_FLOPS)

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_device": self.hlo_flops_per_device,
            "hlo_bytes_per_device": self.hlo_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }

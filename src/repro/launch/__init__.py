"""Launch layer: meshes, cell builders, the multi-pod dry-run, and the
train/serve entry points. NOTE: importing this package must not initialize
jax devices (dryrun.py sets XLA_FLAGS before any jax import)."""

"""Abstract (allocation-free) state construction + sharding resolution.

`abstract_init` traces an init function with jax.eval_shape so the full
production-scale state exists only as ShapeDtypeStructs; the logical spec
tree (static python, built during tracing) is captured via a side box.

`shardings_for` resolves logical axes -> NamedShardings against a mesh with a
divisibility guard: a mesh axis that does not divide the dimension is dropped
(e.g. 4 kv heads cannot shard over model=16; batch=1 cannot shard at all).
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding import DEFAULT_RULES, logical_to_physical


def abstract_init(fn: Callable, rng, *static_args, **static_kwargs) -> Tuple[Any, Any]:
    """fn(rng, *static_args, **static_kwargs) must return
    (arrays_pytree, spec_pytree). Returns (sds_tree, specs) without allocating
    anything — only the rng is traced; configs stay static (closed over)."""
    box = {}

    def wrapper(k):
        out, specs = fn(k, *static_args, **static_kwargs)
        box["specs"] = specs
        return out

    sds = jax.eval_shape(wrapper, rng)
    return sds, box["specs"]


def _is_spec_leaf(x):
    return (isinstance(x, tuple)
            and all(a is None or isinstance(a, str) for a in x))


def resolve_spec(sds, logical, mesh: Mesh, rules=None) -> P:
    """Logical axes -> PartitionSpec, dropping axes that don't divide dims."""
    from repro.sharding import resolve_axis_spec
    return resolve_axis_spec(getattr(sds, "shape", ()), logical, mesh, rules)


def shardings_for(sds_tree, spec_tree, mesh: Mesh, rules=None):
    """Pytree of NamedShardings matching sds_tree's structure."""
    flat_sds, treedef = jax.tree_util.tree_flatten(sds_tree)
    flat_spec = treedef.flatten_up_to(spec_tree) if spec_tree is not None else [
        () for _ in flat_sds]
    out = []
    for sds, logical in zip(flat_sds, flat_spec):
        if not _is_spec_leaf(logical):
            logical = ()
        out.append(NamedSharding(mesh, resolve_spec(sds, logical, mesh, rules)))
    return jax.tree_util.tree_unflatten(treedef, out)


def replicated(sds_tree, mesh: Mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), sds_tree)

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-iteration harness (§Perf hypothesis -> change -> measure loop).

Lowers one cell with config/knob overrides and reports the three roofline
terms + the collective breakdown, against the recorded baseline.

  PYTHONPATH=src python -m repro.launch.perf_iter --arch deepseek-v3-671b \
      --shape train_4k --set moe_groups=32 --set moe_gather_weights=1 \
      --tag iter1
"""
import argparse
import json

import jax

from repro.launch.mesh import make_production_mesh, mesh_chips
from repro.launch.cells import build_cell
from repro.launch.hlo_cost import analyze as hlo_analyze
from repro.launch.roofline import Roofline


def parse_val(v: str):
    if v.lower() in ("true", "false"):
        return v.lower() == "true"
    try:
        return int(v)
    except ValueError:
        try:
            return float(v)
        except ValueError:
            return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg field override key=value")
    ap.add_argument("--tag", default="iter")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = parse_val(v)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_name = "multi_pod_2x16x16" if args.multi_pod else "single_pod_16x16"
    cell = build_cell(args.arch, args.shape, mesh, cfg_overrides=overrides)
    lowered = cell.lower()
    compiled = lowered.compile()
    ma = compiled.memory_analysis()
    cost = hlo_analyze(compiled.as_text())
    rl = Roofline(
        arch=args.arch, shape=args.shape, mesh=mesh_name, chips=mesh_chips(mesh),
        hlo_flops_per_device=cost["flops_per_device"],
        hlo_bytes_per_device=cost["bytes_per_device"],
        collective_bytes_per_device=cost["collective_bytes_per_device"],
        model_flops=cell.model_flops_fn() if cell.model_flops_fn else None,
    )
    rec = {
        "tag": args.tag, "overrides": overrides,
        "memory_peak_per_device_gib": round(
            (ma.argument_size_in_bytes + ma.output_size_in_bytes
             + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30, 3),
        "collectives": cost["collectives"],
        "roofline": rl.to_dict(),
    }
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(
        args.out, f"{args.arch}__{args.shape}__{args.tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)

    # diff vs the baseline dry-run record
    base_path = os.path.join("experiments/dryrun", mesh_name,
                             f"{args.arch}__{args.shape}.json")
    r = rec["roofline"]
    print(f"{args.tag}: compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
          f"coll={r['collective_s']:.3e}s -> {r['bottleneck']} "
          f"(mem/dev {rec['memory_peak_per_device_gib']} GiB, "
          f"roofline_frac={r['roofline_fraction']})")
    if os.path.exists(base_path):
        b = json.load(open(base_path))["roofline"]
        for term in ("compute_s", "memory_s", "collective_s"):
            if b[term] > 0:
                print(f"  {term}: {b[term]:.3e} -> {r[term]:.3e} "
                      f"({b[term]/max(r[term],1e-30):.2f}x better)")


if __name__ == "__main__":
    main()

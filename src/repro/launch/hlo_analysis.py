"""Collective-traffic extraction from compiled (SPMD-partitioned) HLO text.

`compiled.cost_analysis()` has no collective accounting, so the roofline's
third term is derived here: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction is located in the HLO module and
its *operand* sizes summed (per the assignment). HLO operands are %name
references, so a first pass builds a name -> bytes map from instruction
definitions. All shapes in compiled SPMD HLO are per-device (partitioned)
shapes, so the sum is bytes-per-device.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s4": 1, "u4": 1, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(text: str) -> int:
    """Total bytes of every shape literal in `text` (handles tuples)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        size = 1
        if dims:
            for d in dims.split(","):
                size *= int(d)
        total += size * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device operand bytes of each collective kind (+ 'total').

    -start/-done async pairs are counted once (at -start)."""
    defs: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        # bytes of the defined value = shapes before the op name (output type)
        paren = rhs.find("(")
        head = rhs[:paren] if paren > 0 else rhs
        defs[name] = _shape_bytes(head)

    out: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        cm = _COLL_RE.search(line)
        if cm is None or "-done(" in line:
            continue
        kind = cm.group(1)
        # operands: %refs inside the call parens
        call = line[cm.end():]
        call = call.split(", channel_id")[0].split(", replica_groups")[0]
        nbytes = 0
        for ref in _OPERAND_RE.findall(call):
            nbytes += defs.get(ref, 0)
        if nbytes == 0:
            # fall back to the output size (operand defined out of scope)
            m = _DEF_RE.match(line)
            if m:
                paren = m.group(2).find("(")
                nbytes = _shape_bytes(m.group(2)[:paren])
        out[kind] += float(nbytes)
        counts[kind] += 1
    out["total"] = float(sum(v for k, v in out.items() if k != "total"))
    for k, c in counts.items():
        out[f"n_{k}"] = float(c)
    return dict(out)

"""Non-local Constraint Checking for cycle and path constraints (Alg. 5 + 6).

TPU adaptation of token passing: a *multi-source boolean frontier*
F_r[v, s] = "a token that originated at source s sits at v after r hops".
One hop is the same edge sweep as LCC (gather over arcs, OR by destination),
masked per hop by the candidacy of the walk's r-th template vertex.

Work aggregation (paper Alg. 6 line 14) is implicit and *maximal* here: the
boolean frontier can represent a (vertex, source, hop) at most once, so a
duplicate token can never be forwarded — the OR absorbs it. This is strictly
stronger aggregation than the unordered-set dedup in the paper.

Memory-pressure control (the paper's "ability to control processing rate"):
sources are processed in fixed-size waves (`wave` bits), bounding frontier
state at n x wave booleans per hop.

Cycle constraints: token must return to its source after |C0| hops
  -> survivor s iff F_L[source_s, s].
Path constraints: token must reach a *different* vertex with the same label
  -> survivor s iff exists v != source_s with F_L[v, s] (the paper's `ack`).

Wave execution (`verify_constraint`) is batched: every walk of a constraint
(all rotations of a cycle, both directions of a path) shares one candidacy
stack built from the constraint-entry omega, per-wave survivors accumulate
into a device-side `keep` plane, and the head-column eliminations are applied
on device — the only host round-trips per constraint are the head-candidacy
read that sizes the wave loop and (under `count_messages`) one message-count
readback. Three tunable routes execute a wave: `unpacked` boolean planes
(scan-based hops), `packed` per-hop bitset_spmm launches, and the `fused`
multi-hop bitset_wave kernel (pack/unpack once per wave, frontier resident
across hops).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.graph.structs import DeviceGraph
from repro.graph import segment_ops
from repro.core.template import NonLocalConstraint
from repro.core.state import PruneState


def _frontier_hop(
    dg: DeviceGraph,
    frontier: jnp.ndarray,  # bool[n, S]
    edge_active: jnp.ndarray,  # bool[m]
    cand_next: jnp.ndarray,  # bool[n] candidacy for the next walk vertex
    count_messages: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    msgs = jnp.take(frontier, dg.src, axis=0) & edge_active[:, None]
    agg = segment_ops.segment_or_bool(msgs, dg.dst, frontier.shape[0])
    nxt = agg & cand_next[:, None]
    n_msgs = jnp.sum(msgs) if count_messages else jnp.asarray(0)
    return nxt, n_msgs


def wave_batches(sources: np.ndarray, wave: int):
    """Pad wave-source ids into fixed-width batches (-1 = pad) — the one
    batching rule shared by the local and sharded wave executors, so every
    route sees identical static shapes and identical pad semantics."""
    for off in range(0, sources.size, wave):
        ids = sources[off: off + wave]
        pad = wave - ids.size
        idsp = (np.concatenate([ids, np.full(pad, -1, np.int64)])
                if pad else ids)
        yield idsp.astype(np.int32), int(ids.size)


NLCC_ROUTE = "prune.nlcc"

# Walk-direction choices a query plan may pin per constraint (core/planner.py).
# "default" is the paper's expansion — every rotation of a cycle, both
# directions of a path — and is what every untuned run executes. The others
# run a SUBSET of those walks: strictly cheaper, strictly weaker, and still
# sound (a true match certifies every walk, so skipping checks never prunes
# one). The planner only emits non-default directions when a complete-walk
# TDS phase runs last and restores exactness.
PLAN_DIRECTIONS = ("default", "fwd", "rev", "head")


def expand_walks(constraint: NonLocalConstraint, direction: str = "default"):
    """The walk set a direction choice executes — the ONE expansion rule
    shared by the local wave executor, the sharded backends, and the batched
    lane driver, so a plan means the same thing everywhere."""
    if constraint.is_cyclic:
        base = constraint.walk[:-1]
        if direction == "default":
            # a cycle constraint prunes the head only; verify every rotation
            return [
                tuple(base[i:] + base[:i]) + (base[i],)
                for i in range(len(base))
            ]
        if direction == "rev":
            rb = tuple(reversed(base))
            return [rb + (rb[0],)]
        return [tuple(base) + (base[0],)]  # "head"/"fwd": stored rotation only
    if direction in ("fwd", "head"):
        return [constraint.walk]
    if direction == "rev":
        return [tuple(reversed(constraint.walk))]
    return [constraint.walk, tuple(reversed(constraint.walk))]


def nlcc_route_bucket(state: PruneState, wave: int):
    """Shape bucket for packed-vs-unpacked NLCC wave routing: vertex count and
    wave width drive the per-hop cost (each hop moves n x wave frontier bits —
    wave/32 packed words per vertex)."""
    from repro.kernels import registry
    return registry.shape_bucket(state.omega.shape[0], wave)


def nlcc_resolved_route(
    state: PruneState,
    wave: int,
    blocked,
    *,
    count_messages: bool = False,
    force_pallas: bool = False,
) -> str:
    """The route CC/PC waves will actually take (packed / unpacked / fused) —
    the single source of truth for both execution (`verify_constraint`) and
    reporting (`prune`'s stats["dispatch_routes"]). Packed and fused waves
    need a blocked structure, a word-aligned wave, and no message counting
    (the packed OR absorbs duplicates before they can be counted); within
    that envelope force_pallas pins packed (parity tests) and otherwise the
    tuned policy picks the measured-fastest of the three, defaulting to the
    old hardcoded choice — packed on TPU where the kernel compiles, boolean
    planes elsewhere (off-TPU the per-hop packed route is the same survivors
    with extra pack/unpack per hop; the fused route pays that once per
    wave)."""
    from repro.kernels import compat, registry

    if blocked is None or count_messages or wave % 32 != 0:
        return registry.ROUTE_UNPACKED
    if force_pallas:
        return registry.ROUTE_PACKED
    untuned = (
        registry.ROUTE_PACKED if compat.on_tpu() else registry.ROUTE_UNPACKED
    )
    return registry.resolve_route(
        NLCC_ROUTE, nlcc_route_bucket(state, wave), default=untuned,
        allowed=(registry.ROUTE_PACKED, registry.ROUTE_UNPACKED,
                 registry.ROUTE_FUSED))


def _initial_frontier(
    n: int,
    cand0: jnp.ndarray,       # bool[n] candidacy of the walk head
    source_ids: jnp.ndarray,  # int32[S], -1 = pad
    safe_src: jnp.ndarray,    # int32[S] = clip(source_ids, 0, n-1)
) -> jnp.ndarray:
    """F_0: one token plane per wave source, seeded at candidate sources."""
    S = source_ids.shape[0]
    frontier = jnp.zeros((n, S), dtype=bool)
    return frontier.at[safe_src, jnp.arange(S)].set(
        (source_ids >= 0) & jnp.take(cand0, safe_src)
    )


def _wave_survivors(
    frontier: jnp.ndarray,    # bool[n, S] hop-L frontier
    source_ids: jnp.ndarray,  # int32[S], -1 = pad
    safe_src: jnp.ndarray,
    is_cyclic: bool,
) -> jnp.ndarray:
    """CC: token returned to its source. PC: the paper's `ack` — token reached
    some vertex other than its source."""
    S = source_ids.shape[0]
    if is_cyclic:
        survived = frontier[safe_src, jnp.arange(S)]
    else:
        arrived_any = jnp.any(frontier, axis=0)
        arrived_self = frontier[safe_src, jnp.arange(S)]
        arrived_elsewhere = (
            jnp.sum(frontier, axis=0) > arrived_self.astype(jnp.int32))
        survived = arrived_any & arrived_elsewhere
    return survived & (source_ids >= 0)


def check_walk_constraint_fused(
    dg: DeviceGraph,
    state: PruneState,
    walk_candidacy: jnp.ndarray,  # bool[L+1, n] candidacy per walk position
    is_cyclic: bool,
    source_ids: jnp.ndarray,  # int32[S] wave source ids, -1 = pad; S % 32 == 0
    blocked,
    force_pallas: bool = False,
) -> jnp.ndarray:
    """One CC/PC wave through the fused multi-hop wave engine: the packed
    frontier is built ONCE, all L hops run inside a single `bitset_wave`
    dispatch (Pallas kernel on TPU with the frontier VMEM-resident across
    hops, the scan-based packed-word oracle elsewhere), and the result is
    unpacked ONCE. Returns survived bool[S]."""
    from repro.core.state import pack_bits, unpack_bits
    from repro.kernels import ops as kops

    n = state.omega.shape[0]
    S = source_ids.shape[0]
    assert S % 32 == 0, "packed frontier needs a word-aligned wave size"
    safe_src = jnp.clip(source_ids, 0, n - 1)

    packed = pack_bits(
        _initial_frontier(n, walk_candidacy[0], source_ids, safe_src))
    cand = jnp.where(
        walk_candidacy[1:], jnp.uint32(0xFFFFFFFF), jnp.uint32(0))
    packed = kops.bitset_wave(
        packed, dg.src, dg.dst, n, state.edge_active, cand,
        blocked=blocked, force_pallas=force_pallas,
    )
    frontier = unpack_bits(packed, S)
    return _wave_survivors(frontier, source_ids, safe_src, is_cyclic)


def check_walk_constraint_packed(
    dg: DeviceGraph,
    state: PruneState,
    walk_candidacy: jnp.ndarray,  # bool[L+1, n] candidacy per walk position
    is_cyclic: bool,
    source_ids: jnp.ndarray,  # int32[S] wave source ids, -1 = pad; S % 32 == 0
    blocked,
    force_pallas: bool = False,
) -> jnp.ndarray:
    """One CC/PC wave with the S token planes bit-packed into uint32 words:
    each hop is a single bitset OR-SpMM through the kernel registry — the
    same blocked kernel as the LCC sweep, 32x fewer aggregation bytes than
    the boolean-plane hop. Returns survived bool[S] (no message counting —
    the packed OR absorbs duplicates before they can be counted)."""
    from repro.core.state import pack_bits, unpack_bits
    from repro.kernels import ops as kops

    n = state.omega.shape[0]
    S = source_ids.shape[0]
    assert S % 32 == 0, "packed frontier needs a word-aligned wave size"
    L = walk_candidacy.shape[0] - 1
    safe_src = jnp.clip(source_ids, 0, n - 1)

    packed = pack_bits(
        _initial_frontier(n, walk_candidacy[0], source_ids, safe_src))
    for r in range(1, L + 1):
        agg = kops.bitset_or_aggregate(
            packed, dg.src, dg.dst, n, state.edge_active,
            blocked=blocked, force_pallas=force_pallas,
        )
        packed = jnp.where(walk_candidacy[r][:, None], agg, jnp.uint32(0))
    frontier = unpack_bits(packed, S)
    return _wave_survivors(frontier, source_ids, safe_src, is_cyclic)


@functools.partial(jax.jit, static_argnames=("is_cyclic", "count_messages"))
def check_walk_constraint(
    dg: DeviceGraph,
    state: PruneState,
    walk_candidacy: jnp.ndarray,  # bool[L+1, n] candidacy per walk position
    is_cyclic: bool,
    source_ids: jnp.ndarray,  # int32[S] background vertex ids (wave), -1 = pad
    count_messages: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Verify one CC/PC wave. Returns (survived bool[S], message_count).

    The hop loop is a `lax.scan` over the hop-indexed candidacy stack — one
    XLA while-loop instead of L unrolled sweeps, so waves of any walk length
    share a compiled body and trace time stays O(1) in L."""
    n = state.omega.shape[0]
    safe_src = jnp.clip(source_ids, 0, n - 1)
    frontier = _initial_frontier(n, walk_candidacy[0], source_ids, safe_src)

    def hop(carry, cand_r):
        f, total = carry
        f, nm = _frontier_hop(dg, f, state.edge_active, cand_r, count_messages)
        return (f, total + nm), None

    (frontier, total_msgs), _ = jax.lax.scan(
        hop, (frontier, jnp.asarray(0)), walk_candidacy[1:])
    return _wave_survivors(frontier, source_ids, safe_src, is_cyclic), total_msgs


@functools.partial(jax.jit, static_argnames=("is_cyclic",))
def walk_frontiers_and_edges(
    dg: DeviceGraph,
    state: PruneState,
    walk_candidacy: jnp.ndarray,  # bool[L+1, n]
    is_cyclic: bool,
    source_ids: jnp.ndarray,      # int32[S], -1 = pad
):
    """Forward + backward frontiers for one wave (beyond-paper edge pruning).

    F_r[v, s] = a token from source s sits at v after r hops (prefix exists).
    B_r[v, s] = from v a valid suffix of length L-r completes for a SURVIVING
                source s (computed by sweeping the reversed arcs, intersected
                with F_r so only realizable states remain).

    Returns (survived bool[S],
             fwd_live bool[L, m]  — arc used at hop r lies on a full walk,
             rev_live bool[L, m]  — the twin-direction usage of the same arc).
    """
    n = state.omega.shape[0]
    S = source_ids.shape[0]
    L = walk_candidacy.shape[0] - 1
    safe_src = jnp.clip(source_ids, 0, n - 1)

    frontier = jnp.zeros((n, S), dtype=bool)
    frontier = frontier.at[safe_src, jnp.arange(S)].set(
        (source_ids >= 0) & jnp.take(walk_candidacy[0], safe_src))
    fwd = [frontier]
    for r in range(1, L + 1):
        frontier, _ = _frontier_hop(
            dg, frontier, state.edge_active, walk_candidacy[r])
        fwd.append(frontier)

    if is_cyclic:
        survived = fwd[L][safe_src, jnp.arange(S)] & (source_ids >= 0)
        # walk must terminate at its own source
        B = jnp.zeros((n, S), dtype=bool)
        B = B.at[safe_src, jnp.arange(S)].set(survived)
    else:
        arrived_self = fwd[L][safe_src, jnp.arange(S)]
        arrived_elsewhere = jnp.sum(fwd[L], axis=0) > arrived_self.astype(jnp.int32)
        survived = jnp.any(fwd[L], axis=0) & arrived_elsewhere & (source_ids >= 0)
        B = fwd[L] & survived[None, :]
        B = B.at[safe_src, jnp.arange(S)].set(False)  # end vertex != source

    fwd_live = []
    rev_live = []
    for r in range(L, 0, -1):
        # arc (u -> v) used at hop r: prefix at u, suffix from v
        fu = jnp.take(fwd[r - 1], dg.src, axis=0)
        bv = jnp.take(B, dg.dst, axis=0)
        live = jnp.any(fu & bv, axis=1) & state.edge_active
        fwd_live.append(live)
        # the twin arc (v -> u) realizes the same matched pair reversed
        fu_t = jnp.take(fwd[r - 1], dg.dst, axis=0)
        bv_t = jnp.take(B, dg.src, axis=0)
        rev_live.append(jnp.any(fu_t & bv_t, axis=1) & state.edge_active)
        # backward hop: B_{r-1}[u] = OR over out-arcs (u->v) of B_r[v], & F_{r-1}
        # (src is NOT sorted in the dst-sorted arc order)
        msgs = jnp.take(B, dg.dst, axis=0) & state.edge_active[:, None]
        agg = segment_ops.segment_or_bool(msgs, dg.src, n, sorted=False)
        B = agg & fwd[r - 1]
    fwd_live = jnp.stack(fwd_live[::-1])   # [L, m], index r-1 = hop r
    rev_live = jnp.stack(rev_live[::-1])
    return survived, fwd_live, rev_live


def verify_constraint(
    dg: DeviceGraph,
    state: PruneState,
    constraint: NonLocalConstraint,
    template_labels: np.ndarray,
    wave: int = 1024,
    stats: Optional[Dict] = None,
    count_messages: bool = False,
    edge_prune: bool = False,
    template=None,
    blocked=None,
    force_pallas: bool = False,
    direction: str = "default",
) -> PruneState:
    """Alg. 5 for CC/PC (+ each rotation for cycles): eliminate the head
    template vertex from omega of every failing token source.

    Batched wave executor: every walk of the constraint (all rotations of a
    cycle, both directions of a path) is a row of one candidacy stack built
    from the constraint-entry omega; the walks' waves all run against that
    shared state, per-wave survivors accumulate into a device-side `keep`
    plane, and the head-column eliminations (Alg. 5 line 8 — the heads are
    distinct template vertices across a constraint's walks) are applied on
    device at the end. Host round-trips per constraint: one head-candidacy
    read to size the wave loop, plus one message-count readback under
    `count_messages` — never a per-wave `survived` transfer. Always sound (a
    token only survives by certifying a full walk, so no true match is ever
    pruned). For cycle rotations it is also exactly as strong as the old
    sequential per-rotation pass: a token completing rotation j through a
    vertex rotation i eliminated would itself certify that vertex's cycle
    candidacy, contradicting the elimination — so the narrowing the batch
    skips could only have killed tokens that cannot complete anyway. For the
    two directions of a path constraint on a *directed* graph that argument
    does not apply (a reversed-walk arrival does not certify a forward walk)
    and one batched pass may prune marginally less than the old sequential
    pass; on this repo's undirected both-arc graphs the passes coincide, and
    either way exactness is restored downstream (complete-TDS annotation /
    enumeration).

    With `blocked` set (and message counting off), the tuned policy routes
    waves onto the `fused` multi-hop wave engine (`check_walk_constraint_fused`
    — one bitset_wave dispatch per wave, pack/unpack once) or the per-hop
    `packed` bitset_spmm route; the boolean-plane scan is the unpacked
    fallback.

    edge_prune=True (requires template) additionally eliminates arcs that lie
    on NO completing walk for the template arcs this constraint covers — a
    sound beyond-paper refinement (see walk_frontiers_and_edges): a true
    match realizes every hop of the walk, so an arc that is never
    (prefix-live, suffix-live) at any covering hop supports no match via
    those template arcs."""
    if edge_prune and template is not None:
        state = _edge_prune_pass(dg, state, constraint, template, wave, stats)
    walks = expand_walks(constraint, direction)

    from repro.kernels import registry as _registry

    route = nlcc_resolved_route(
        state, wave, blocked,
        count_messages=count_messages, force_pallas=force_pallas,
    )
    wave_stat = {
        _registry.ROUTE_FUSED: "nlcc_fused_waves",
        _registry.ROUTE_PACKED: "nlcc_packed_waves",
        _registry.ROUTE_UNPACKED: "nlcc_plane_waves",
    }[route]
    omega = state.omega
    n = omega.shape[0]
    heads = [w[0] for w in walks]
    # ONE host sync per constraint: the head-candidacy columns size the wave
    # loop (everything downstream stays on device)
    head_cols = np.asarray(omega[:, jnp.asarray(heads, jnp.int32)])
    host_syncs = 1
    keep = jnp.zeros((len(walks), n), dtype=bool)
    total_msgs = jnp.asarray(0)
    n_waves = 0
    for wi, walk in enumerate(walks):
        cand = jnp.stack([omega[:, q] for q in walk], axis=0)  # bool[L+1, n]
        sources = np.flatnonzero(head_cols[:, wi])
        if sources.size == 0:
            continue
        for ids_padded, n_real in wave_batches(sources, wave):
            ids_dev = jnp.asarray(ids_padded, jnp.int32)
            wave_state = PruneState(omega=omega, edge_active=state.edge_active)
            if route == _registry.ROUTE_FUSED:
                survived = check_walk_constraint_fused(
                    dg, wave_state, cand, walk[0] == walk[-1], ids_dev,
                    blocked, force_pallas=force_pallas,
                )
            elif route == _registry.ROUTE_PACKED:
                survived = check_walk_constraint_packed(
                    dg, wave_state, cand, walk[0] == walk[-1], ids_dev,
                    blocked, force_pallas=force_pallas,
                )
            else:
                survived, n_msgs = check_walk_constraint(
                    dg, wave_state, cand, walk[0] == walk[-1], ids_dev,
                    count_messages=count_messages,
                )
                total_msgs = total_msgs + n_msgs
            # pads clip to vertex 0 with survived=False — max() cannot unset
            keep = keep.at[wi, jnp.clip(ids_dev, 0, n - 1)].max(survived)
            n_waves += 1
            if stats is not None:
                stats["nlcc_tokens"] = stats.get("nlcc_tokens", 0) + n_real
                stats[wave_stat] = stats.get(wave_stat, 0) + 1
    # remove head candidacy from failing sources (Alg. 5 line 8), on device
    for wi, q0 in enumerate(heads):
        omega = omega.at[:, q0].set(omega[:, q0] & keep[wi])
    if stats is not None:
        if count_messages:
            stats["nlcc_messages"] = stats.get("nlcc_messages", 0) + int(total_msgs)
            host_syncs += 1
        stats["nlcc_constraints"] = stats.get("nlcc_constraints", 0) + 1
        stats["nlcc_waves"] = stats.get("nlcc_waves", 0) + n_waves
        # the acceptance contract: survivors never cross to the host per wave
        stats["nlcc_host_syncs"] = stats.get("nlcc_host_syncs", 0) + host_syncs
    return PruneState(omega=omega, edge_active=state.edge_active)


def _edge_prune_pass(
    dg: DeviceGraph,
    state: PruneState,
    constraint: NonLocalConstraint,
    template,
    wave: int,
    stats: Optional[Dict],
) -> PruneState:
    """Forward-backward frontier edge elimination for one CC/PC constraint."""
    walk = list(constraint.walk)
    l = len(walk) - 1
    omega = state.omega
    cand = jnp.stack([omega[:, q] for q in walk], axis=0)
    sources = np.flatnonzero(np.asarray(omega[:, walk[0]]))
    if sources.size == 0:
        return state
    m = dg.m
    live_f = np.zeros((l, m), dtype=bool)
    live_r = np.zeros((l, m), dtype=bool)
    for idsp, _ in wave_batches(sources, wave):
        _, fl, rl = walk_frontiers_and_edges(
            dg, state, cand, constraint.is_cyclic, jnp.asarray(idsp, jnp.int32))
        live_f |= np.asarray(fl)
        live_r |= np.asarray(rl)

    pairs = list(zip(walk[:-1], walk[1:]))
    covered: Dict[tuple, list] = {}
    for i, (qa, qb) in enumerate(pairs):
        covered.setdefault((qa, qb), []).append(("f", i))
        covered.setdefault((qb, qa), []).append(("r", i))

    om = np.asarray(omega)
    src, dst = np.asarray(dg.src), np.asarray(dg.dst)
    support = np.zeros(m, dtype=bool)
    for qa in range(template.n0):
        for qb in template.adj[qa]:
            lcc_rule = om[src, qa] & om[dst, qb]
            if (qa, qb) in covered:
                live = np.zeros(m, dtype=bool)
                for kind, i in covered[(qa, qb)]:
                    live |= live_f[i] if kind == "f" else live_r[i]
                support |= lcc_rule & live
            else:
                support |= lcc_rule
    new_ea = np.asarray(state.edge_active) & support
    if stats is not None:
        stats["nlcc_edges_pruned"] = stats.get("nlcc_edges_pruned", 0) + int(
            np.sum(np.asarray(state.edge_active)) - np.sum(new_ea))
    return PruneState(omega=omega, edge_active=jnp.asarray(new_ea))

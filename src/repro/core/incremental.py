"""Interactive incremental search (paper §5.4).

Two enablers from the paper:

  *candidate set* — a superset of the matches of every template obtainable from
  the initial template by edge deletions, computed with local constraints only.
  We realize it as a *relaxed LCC fixpoint*: a vertex keeps candidacy for q if
  its label matches and at least one template neighbor of q is covered among
  its neighbors (>=1 instead of all — every connected edge-deleted sub-template
  still requires each non-isolated vertex to have >=1 matching neighbor, so
  this is a sound superset). Searches then run inside the candidate set (PJI-X).

  *work reuse* — non-local constraint outcomes are cached per constraint key:
  a source that once satisfied constraint C on a *smaller* active state still
  satisfies it on any superset state (walks only gain feasibility), so cached
  PASS sets skip re-verification; only unknown sources are re-checked (PJI-Y).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Set, Tuple

import numpy as np
import jax.numpy as jnp

from repro.graph.structs import Graph, DeviceGraph
from repro.core.template import Template, generate_constraints, NonLocalConstraint
from repro.core.state import PruneState, init_state
from repro.core.lcc import TemplateDev, lcc_fixpoint
from repro.graph import segment_ops
from repro.core import nlcc as nlcc_mod
from repro.core import tds as tds_mod


def candidate_set(dg: DeviceGraph, template: Template, max_iters: int = 100) -> PruneState:
    """Relaxed-LCC fixpoint: the paper's candidate set (union over edge-deleted
    sub-templates, local constraints only)."""
    import jax

    tdev = TemplateDev(template)
    state = init_state(dg, template)

    def body(carry):
        st, _, it = carry
        msgs = jnp.take(st.omega, dg.src, axis=0) & st.edge_active[:, None]
        M = segment_ops.segment_or_bool(msgs, dg.dst, dg.n)
        covered = M.astype(jnp.float32) @ tdev.adj0.T.astype(jnp.float32)  # [n, n0]
        ok = covered > 0.5  # >=1 matching neighbor (relaxation)
        omega = st.omega & ok
        side = omega.astype(jnp.float32) @ tdev.adj0.astype(jnp.float32)
        compat = (
            jnp.sum(
                jnp.take(side, dg.src, axis=0)
                * jnp.take(omega, dg.dst, axis=0).astype(jnp.float32),
                axis=-1,
            )
            > 0.5
        )
        ea = st.edge_active & compat
        changed = jnp.any(omega != st.omega) | jnp.any(ea != st.edge_active)
        return PruneState(omega=omega, edge_active=ea), changed, it + 1

    def cond(carry):
        _, changed, it = carry
        return jnp.logical_and(changed, it < max_iters)

    final, _, _ = jax.lax.while_loop(cond, body, (state, jnp.asarray(True), jnp.asarray(0)))
    return final


@dataclasses.dataclass
class QueryStat:
    template_edges: int
    seconds: float
    matched_vertices: int
    constraints_checked: int
    constraints_reused: int


class IncrementalSession:
    """Holds graph + candidate set + the non-local work-reuse cache."""

    def __init__(
        self,
        graph: Graph,
        base_template: Template,
        use_candidate_set: bool = True,
        use_work_reuse: bool = True,
        wave: int = 1024,
    ):
        self.graph = graph
        self.dg = DeviceGraph.from_host(graph)
        self.label_freq = graph.label_frequency()
        self.base = base_template
        self.use_candidate_set = use_candidate_set
        self.use_work_reuse = use_work_reuse
        self.wave = wave
        self._cand: Optional[PruneState] = (
            candidate_set(self.dg, base_template) if use_candidate_set else None
        )
        # constraint key -> set of sources known to PASS (sound under state growth)
        self._pass_cache: Dict[tuple, np.ndarray] = {}
        self.history: List[QueryStat] = []

    def _verify_with_reuse(
        self, state: PruneState, c: NonLocalConstraint, template: Template
    ) -> Tuple[PruneState, bool]:
        """Verify one constraint, skipping cached-pass sources. Returns (state, reused?)."""
        key = c.key()
        cached = self._pass_cache.get(key) if self.use_work_reuse else None
        omega = np.asarray(state.omega)
        q0 = c.walk[0]
        sources = np.flatnonzero(omega[:, q0])
        unknown = sources if cached is None else sources[~np.isin(sources, cached)]
        reused = cached is not None and unknown.size < sources.size

        passed = np.zeros(self.dg.n, dtype=bool)
        if cached is not None:
            passed[cached[np.isin(cached, sources)]] = True
        if unknown.size:
            if c.kind in ("cycle", "path"):
                # restrict token generation to unknown sources
                st = state
                cand = jnp.stack([st.omega[:, q] for q in c.walk], axis=0)
                for off in range(0, unknown.size, self.wave):
                    ids = unknown[off : off + self.wave]
                    pad = self.wave - ids.size
                    idsp = np.concatenate([ids, np.full(pad, -1, np.int64)]) if pad else ids
                    surv, _ = nlcc_mod.check_walk_constraint(
                        self.dg, st, cand, c.is_cyclic, jnp.asarray(idsp, jnp.int32)
                    )
                    surv = np.asarray(surv)[: ids.size]
                    passed[ids[surv]] = True
            else:
                sub = tds_mod.compact_active(self.dg, state)
                surv, _, _ = tds_mod.tds_walk(sub, c.walk, unknown)
                passed[unknown[surv]] = True
        if self.use_work_reuse:
            prev = self._pass_cache.get(key, np.zeros(0, np.int64))
            self._pass_cache[key] = np.union1d(prev, np.flatnonzero(passed))
        new_omega = state.omega.at[:, q0].set(state.omega[:, q0] & jnp.asarray(passed))
        return PruneState(omega=new_omega, edge_active=state.edge_active), reused

    def search(self, template: Template) -> Tuple[PruneState, QueryStat]:
        """Prune for the (revised) template, reusing candidate set + cache."""
        t0 = time.perf_counter()
        tdev = TemplateDev(template)
        if self._cand is not None and template.n0 == self.base.n0:
            # paper's restriction: revisions add/remove edges over the same
            # vertex set, so candidate-set omega columns align.
            state = PruneState(
                omega=self._cand.omega & init_state(self.dg, template).omega,
                edge_active=self._cand.edge_active,
            )
        else:
            state = init_state(self.dg, template)
        state = lcc_fixpoint(self.dg, tdev, state)
        constraints = generate_constraints(
            template, label_freq=self.label_freq, guarantee_precision=False
        )
        reused_n = 0
        for c in constraints:
            before = state.counts()
            state, reused = self._verify_with_reuse(state, c, template)
            reused_n += int(reused)
            if state.counts() != before:
                state = lcc_fixpoint(self.dg, tdev, state)
        stat = QueryStat(
            template_edges=template.m0,
            seconds=time.perf_counter() - t0,
            matched_vertices=int(jnp.sum(jnp.any(state.omega, axis=1))),
            constraints_checked=len(constraints),
            constraints_reused=reused_n,
        )
        self.history.append(stat)
        return state, stat

"""Fault-tolerant elastic execution (paper §4/§5.3 production posture).

The pruning pipeline is a sequence of *monotone* phases (LCC fixpoints and
NLCC/TDS constraint sweeps: omega/edge bits only ever clear), so every phase
boundary is a consistency point — a snapshot taken there, replayed through the
remaining phases, lands on the bit-identical fixpoint a fault-free run
reaches. This module supplies the three pieces `pipeline.prune` threads
through the execution-backend seam:

  FaultInjector      a deterministic, seedable harness that raises simulated
                     failures (shard loss, collective timeout, transient
                     kernel failure, TdsOverflow-style resource exhaustion)
                     at chosen phase / wave indices. Backends call
                     `injector.event(site, ...)` at their host dispatch seams
                     (constraint entry, each NLCC wave, the TDS bridge) and
                     `registry.dispatch` forwards through the dispatch hook;
                     `instrument_prims` additionally wraps the 6-prim
                     collective layer for trace-time accounting and
                     prim-seam injection.
  run_phase_with_ladder
                     the degradation ladder around one phase:
                     retry (from an in-memory device snapshot, with backoff)
                     -> ref kernels (registry.mode_override)
                     -> chunk back-off (halve the TDS chunk)
                     -> checkpoint-and-raise (PhaseFailed).
                     Shard loss is never absorbed here — it escapes to the
                     pipeline's elastic-restart path.
  ResilienceConfig   checkpoint cadence + retry policy + elastic restart
                     (restore the last phase snapshot onto a different —
                     typically smaller — shard count, or trigger the same
                     compact-and-reshuffle from device-side imbalance stats
                     at a phase boundary even without a fault).

Faults are plain Python exceptions raised from HOST code between device
dispatches — the sharded programs themselves are pure jitted collectives, so
the failure surface the paper describes (a rank dying between bulk steps)
maps exactly onto the phase/wave dispatch loop.
"""
from __future__ import annotations

import dataclasses
import time
from collections import Counter
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.tds import TdsOverflow


# ---------------------------------------------------------------- fault kinds
FAULT_SHARD_LOSS = "shard_loss"
FAULT_COLLECTIVE_TIMEOUT = "collective_timeout"
FAULT_TRANSIENT_KERNEL = "transient_kernel"
FAULT_RESOURCE_EXHAUSTED = "resource_exhausted"
FAULT_KINDS = (FAULT_SHARD_LOSS, FAULT_COLLECTIVE_TIMEOUT,
               FAULT_TRANSIENT_KERNEL, FAULT_RESOURCE_EXHAUSTED)


class InjectedFault(RuntimeError):
    """Base of every simulated failure the harness raises."""

    kind = "injected"

    def __init__(self, site: str, phase: Optional[int], wave: Optional[int]):
        super().__init__(
            f"injected {self.kind} at site={site!r} phase={phase} wave={wave}")
        self.site = site
        self.phase = phase
        self.wave = wave


class ShardLost(InjectedFault):
    """A shard's device state is gone — unrecoverable in place; the pipeline
    must restore the last phase checkpoint (possibly onto fewer shards)."""

    kind = FAULT_SHARD_LOSS


class CollectiveTimeout(InjectedFault):
    """A collective failed transiently (network hiccup): retryable in place
    from the phase-entry device snapshot."""

    kind = FAULT_COLLECTIVE_TIMEOUT


class TransientKernelFailure(InjectedFault):
    """A kernel produced an error (compile flake, numerics trap): retryable,
    then degradable to the reference oracle."""

    kind = FAULT_TRANSIENT_KERNEL


class ResourceExhausted(InjectedFault):
    """TdsOverflow-style resource exhaustion: handled by chunk back-off."""

    kind = FAULT_RESOURCE_EXHAUSTED


_EXC_OF_KIND = {
    FAULT_SHARD_LOSS: ShardLost,
    FAULT_COLLECTIVE_TIMEOUT: CollectiveTimeout,
    FAULT_TRANSIENT_KERNEL: TransientKernelFailure,
    FAULT_RESOURCE_EXHAUSTED: ResourceExhausted,
}


class PhaseFailed(RuntimeError):
    """The degradation ladder ran out of rungs for one phase. The pipeline
    treats this like shard loss: checkpoint-restore (elastic) or give up."""


class ResilienceExhausted(RuntimeError):
    """No recovery path left: no checkpointing configured, or the restart
    budget is spent. Carries the original failure as __cause__."""


class PlanMismatch(RuntimeError):
    """A checkpoint was written under a different query plan (different
    constraint order / phase identity) than the recovering run executes.
    Phase identity is keyed by constraint signature, not positional index —
    replaying phase k of plan A inside plan B would re-run the WRONG
    constraint and silently corrupt the trajectory, so recovery refuses
    cleanly instead. Re-prune from scratch or restore the original plan."""


# ---------------------------------------------------------------- fault specs
# Ladder rungs in escalation order. A spec's `cleared_by` names the rung that
# makes the fault stop firing — e.g. cleared_by="retry" simulates a hiccup
# that a simple re-run fixes, cleared_by="ref" a kernel bug the reference
# oracle sidesteps. None = the fault fires whenever it matches (a hard fault).
RUNG_FIRST = "first"
RUNG_RETRY = "retry"
RUNG_REF = "ref"
RUNG_CHUNK = "chunk"
RUNGS = (RUNG_FIRST, RUNG_RETRY, RUNG_REF, RUNG_CHUNK)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault: fire `times` times (<=0 = every match) at
    events matching (site, phase, wave), skipping the first `after` matches.

    Sites are the host dispatch seams: "lcc", "nlcc", "wave" (per NLCC wave,
    with a 0-based `wave` index within the constraint), "tds", "dispatch"
    (any registry.dispatch call; `kernel` narrows to one kernel name), and
    "prim:<name>" (trace-time, via `instrument_prims`). site=None matches
    any driver-seam event."""

    kind: str
    phase: Optional[int] = None
    site: Optional[str] = None
    wave: Optional[int] = None
    kernel: Optional[str] = None
    after: int = 0
    times: int = 1
    cleared_by: Optional[str] = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of {FAULT_KINDS}")
        if self.cleared_by is not None and self.cleared_by not in RUNGS[1:]:
            raise ValueError(
                f"cleared_by={self.cleared_by!r} is not a ladder rung "
                f"{RUNGS[1:]}")


@dataclasses.dataclass
class _Armed:
    spec: FaultSpec
    seen: int = 0  # matching events observed (drives `after`)
    fired: int = 0  # times actually raised


class FaultInjector:
    """Deterministic fault plan evaluated at the host dispatch seams.

    The pipeline announces phase starts (`begin_phase`) and the current
    ladder rung (`set_rung`); backends and the registry hook report events
    (`event`). A spec whose filters match raises the corresponding
    InjectedFault. All state is explicit — replaying the same prune with the
    same injector plan fires the same faults at the same events."""

    def __init__(self, specs: Sequence[FaultSpec] = ()):
        self.armed: List[_Armed] = [_Armed(s) for s in specs]
        self.phase: Optional[int] = None
        self.rung: str = RUNG_FIRST
        self.fired: List[Dict] = []  # audit log of raised faults
        self.events: Counter = Counter()  # every event seen, by site
        self.prim_trace: Counter = Counter()  # trace-time prim usage

    # -- plan construction
    @staticmethod
    def random(seed: int, n_phases: int, *, n_faults: int = 1,
               kinds: Sequence[str] = (FAULT_SHARD_LOSS,),
               sites: Sequence[str] = ("lcc", "nlcc", "wave", "tds")
               ) -> "FaultInjector":
        """A seeded random fault plan (deterministic: same seed, same plan)."""
        import numpy as np

        rng = np.random.default_rng(seed)
        specs = []
        for _ in range(n_faults):
            site = sites[int(rng.integers(len(sites)))]
            specs.append(FaultSpec(
                kind=kinds[int(rng.integers(len(kinds)))],
                phase=int(rng.integers(n_phases)),
                site=site,
                wave=int(rng.integers(2)) if site == "wave" else None,
            ))
        return FaultInjector(specs)

    # -- pipeline-driven context
    def begin_phase(self, phase: int) -> None:
        self.phase = phase

    def set_rung(self, rung: str) -> None:
        self.rung = rung

    # -- event seams
    def event(self, site: str, *, wave: Optional[int] = None,
              kernel: Optional[str] = None) -> None:
        """Report one host-seam event; raises if an armed spec matches."""
        self.events[site] += 1
        for a in self.armed:
            s = a.spec
            if s.site is not None and s.site != site:
                continue
            if s.phase is not None and s.phase != self.phase:
                continue
            if s.wave is not None and s.wave != wave:
                continue
            if s.kernel is not None and s.kernel != kernel:
                continue
            a.seen += 1
            if a.seen <= s.after:
                continue
            if s.times > 0 and a.fired >= s.times:
                continue
            if s.cleared_by is not None and (
                    RUNGS.index(self.rung) >= RUNGS.index(s.cleared_by)):
                continue  # the ladder escalated past this fault's cause
            a.fired += 1
            self.fired.append({"kind": s.kind, "site": site,
                               "phase": self.phase, "wave": wave,
                               "kernel": kernel, "rung": self.rung})
            raise _EXC_OF_KIND[s.kind](site, self.phase, wave)

    def on_dispatch(self, name: str, mode: str) -> None:
        """The registry.dispatch hook: every kernel dispatch is an event."""
        self.event("dispatch", kernel=name)

    def trace_prim(self, name: str) -> None:
        """Trace-time prim accounting + prim-seam injection (fires when a
        program USING the prim is first traced — deterministic per program
        cache, not per execution)."""
        self.prim_trace[name] += 1
        self.event(f"prim:{name}")


def instrument_prims(prims, injector: FaultInjector):
    """Wrap every collective of a `Prims` bundle so the injector sees each
    trace-time use. Returns the same NamedTuple type."""

    def wrap(name, fn):
        def wrapped(*args, **kwargs):
            injector.trace_prim(name)
            return fn(*args, **kwargs)

        return wrapped

    return type(prims)(*(wrap(f, getattr(prims, f)) for f in prims._fields))


# ------------------------------------------------------------- configuration
@dataclasses.dataclass
class RetryPolicy:
    """Bounds of the degradation ladder."""

    max_retries: int = 2
    backoff_s: float = 0.0  # sleep before retry r is backoff_s * factor**(r-1)
    backoff_factor: float = 2.0
    chunk_backoff_factor: int = 4  # TDS chunk divisor per back-off step
    max_chunk_backoffs: int = 2


@dataclasses.dataclass
class ElasticConfig:
    """Elastic restart / rebalance targets.

    restart_P          shard count to restore onto after a fatal fault
                       (None = keep the current count). The paper's
                       recover-onto-smaller-deployment (LB-16/LB-1).
    imbalance_trigger  max-over-mean active-edge threshold checked from
                       device-side shard counts at every phase boundary;
                       exceeding it triggers compact-and-reshuffle with NO
                       fault (None = off).
    rebalance_P        shard count after a triggered rebalance (None = keep).
    seed               the balanced_shuffle seed (deterministic reshuffles).
    """

    restart_P: Optional[int] = None
    imbalance_trigger: Optional[float] = None
    rebalance_P: Optional[int] = None
    seed: int = 0


@dataclasses.dataclass
class ResilienceConfig:
    """Everything `pipeline.prune(..., resilience=...)` needs."""

    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 1  # phases between checkpoints
    keep: int = 3  # checkpoint retention
    injector: Optional[FaultInjector] = None
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    elastic: Optional[ElasticConfig] = None
    max_restarts: int = 4


# --------------------------------------------------------- degradation ladder
def run_phase_with_ladder(
    run: Callable[[], None],
    *,
    snapshot: Callable[[], object],
    restore: Callable[[object], None],
    retry: RetryPolicy,
    injector: Optional[FaultInjector] = None,
    on_chunk_backoff: Optional[Callable[[int], None]] = None,
    ladder_log: Optional[List[Tuple[str, str]]] = None,
    sleep: Callable[[float], None] = time.sleep,
) -> None:
    """Execute one phase under the degradation ladder.

    retry      transient collective/kernel faults re-run the phase from the
               phase-entry device snapshot, with bounded backoff;
    ref        exhausted retries force the reference-oracle kernel mode
               (registry.mode_override) for one more attempt;
    chunk      resource exhaustion (TdsOverflow or injected) restores the
               snapshot and shrinks the TDS chunk via `on_chunk_backoff`;
    raise      anything still failing surfaces as PhaseFailed — the caller
               checkpoints state up to the previous boundary and either
               elastically restarts or gives up.

    ShardLost is never absorbed: lost device state cannot be retried in
    place, so it propagates to the pipeline's restore path directly."""
    from repro.kernels import registry

    set_rung = injector.set_rung if injector is not None else (lambda r: None)
    snap = snapshot()
    retries = 0
    chunk_backoffs = 0
    tried_ref = False
    rung = RUNG_FIRST
    try:
        while True:
            set_rung(rung)
            try:
                if rung == RUNG_REF:
                    with registry.mode_override(registry.MODE_REF):
                        run()
                else:
                    run()
                return
            except ShardLost:
                raise
            except (TdsOverflow, ResourceExhausted) as e:
                if chunk_backoffs >= retry.max_chunk_backoffs:
                    raise PhaseFailed(
                        f"chunk back-off exhausted after {chunk_backoffs} "
                        f"steps: {e!r}") from e
                chunk_backoffs += 1
                if ladder_log is not None:
                    ladder_log.append((RUNG_CHUNK, repr(e)))
                restore(snap)
                if on_chunk_backoff is not None:
                    on_chunk_backoff(retry.chunk_backoff_factor)
                rung = RUNG_CHUNK
            except (CollectiveTimeout, TransientKernelFailure) as e:
                if retries < retry.max_retries:
                    retries += 1
                    if ladder_log is not None:
                        ladder_log.append((RUNG_RETRY, repr(e)))
                    restore(snap)
                    if retry.backoff_s > 0:
                        sleep(retry.backoff_s
                              * retry.backoff_factor ** (retries - 1))
                    rung = RUNG_RETRY
                elif not tried_ref:
                    tried_ref = True
                    if ladder_log is not None:
                        ladder_log.append((RUNG_REF, repr(e)))
                    restore(snap)
                    rung = RUNG_REF
                else:
                    raise PhaseFailed(
                        f"retries and ref fallback exhausted: {e!r}") from e
    finally:
        set_rung(RUNG_FIRST)

"""Template-batched multi-tenant execution: one dispatch for B queries.

The production scenario is many analysts holding many search templates
against ONE background metadata graph. Per-query execution wastes the
machine — the tuned kernels run in milliseconds while per-dispatch/host-sync
overhead dominates. This module stacks B same-bucket templates along a new
leading batch ("lane") axis and runs the whole prune pipeline for all B
queries through shared kernel dispatches:

  - state grows a lane axis: omega [P, B, n_local+1, W], edge_active
    [P, B, P, B_arcs] — the per-shard program bodies of core/engine.py are
    reused VERBATIM under an inner (unnamed) ``jax.vmap`` over lanes, nested
    inside the backend's shard-axis wrapper (sim vmap-with-axis-name or spmd
    shard_map). vmap's collective batching rules make the lane axis free:
    the all_to_all/psum collectives of a lane see only that lane's data.
  - template constants (adjacency, multiplicity requirements) become TRACED
    per-lane arrays instead of closed-over constants, zero-padded to the
    common bucket width n0p — padding is bit-inert through the LCC math
    (zero adjacency rows satisfy coverage vacuously, zero requirements are
    trivially met, padded omega columns start 0 and stay 0).
  - per-lane convergence is handled by MASKING, not exiting: the batched LCC
    while_loop runs until every lane converges, freezing already-converged
    lanes via lax.while_loop's select semantics (bit-exact per-lane iterate
    sequences); NLCC wave loops run in lockstep with exhausted lanes
    supplying all-pad (-1) wave sources, which are inert in the survivor
    reduction and the keep-column scatter.
  - the lockstep driver runs phase k of every lane in one batch: cycle/path
    constraints grouped by (walk length, cyclicity) execute as job-axis
    vmapped wave programs with ONE stacked head-planes readback per phase
    and ONE host bool (did anything change?) gating the joint LCC re-run —
    a lane whose constraint changed nothing is at LCC fixpoint, so the
    joint re-run is a no-op for it (bit parity with sequential execution).
  - TDS constraints stay host-side row joins (as in every backend), bridged
    per lane through a lane gather/scatter.
  - per-query deadlines cancel by masking: a deadline-missed lane's state is
    zeroed at a phase boundary and it goes inert for the rest of the batch —
    never a batch abort.

Routing: the batched wave executor resolves ``prune.nlcc`` through the
dispatch policy under a BATCHED bucket key (`registry.batch_bucket`, e.g.
``b8xp4x512x1024``), so batched routes tune separately from single-query
ones; batch-size-1 lookups fall back to unbatched cache entries. Batched
waves always execute as one dispatch per wave (seed + lax.scan over hops —
the fused shape); the route choice picks the frontier representation
(packed uint32 words vs boolean planes). The one-wave-deep overlap pipeline
of the single-query executor is intentionally skipped: with B queries per
dispatch the batch axis already amortizes what the overlap hid.

Bit-parity contract (tests/test_batch.py): for any mix of cyclic / path /
TDS-bearing same-bucket templates, each lane's final omega, edge mask, and
match counts are bit-identical to running that template alone through
``prune`` on the same backend.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.graph.structs import Graph, DeviceGraph
from repro.graph.partition import EdgePartition, partition_graph
from repro.core.state import PruneState, pack_bits, unpack_bits, packed_words
from repro.core.lcc import TemplateDev
from repro.core.template import (Template, NonLocalConstraint,
                                 generate_constraints)
from repro.core import engine as engine_mod
from repro.core import nlcc as nlcc_mod
from repro.core import tds as tds_mod
from repro.core.engine import (SHARD_AXIS, ShardArrays, axis_prims,
                               lcc_shard_fixpoint, frontier_shard_hop,
                               frontier_shard_hop_unpacked,
                               _seed_frontier_planes, _sharded_wave_survivors,
                               _scatter_keep)
from repro.core.pipeline import PruneResult

STATUS_OK = "ok"
STATUS_DEADLINE_MISSED = "deadline_missed"


class _LaneMasks:
    """TemplateMasks duck-type whose constants are TRACED per-lane arrays —
    what lets one traced program serve every lane of the batch. `n0` and
    `needs_counts` stay static (shared across the batch: the padded bucket
    width and the any-lane counts flag)."""

    def __init__(self, n0: int, needs_counts: bool, adj0, req, vhcl):
        self.n0 = n0
        self.needs_counts = needs_counts
        self.adj0 = adj0
        self.req = req
        self.vertex_has_counted_label = vhcl


def _stack_template_consts(tdevs: Sequence[TemplateDev], n0p: int):
    """Stack per-lane template constants zero-padded to [B, n0p, ...].

    Lanes whose template does not need multiplicity counts get an all-zero
    requirement row — ``cnt >= 0`` is trivially true, which is exactly the
    single-template engine's "skip the counts check" branch, bit for bit."""
    B = len(tdevs)
    C = max(int(td.req.shape[1]) for td in tdevs)
    needs_counts = any(td.needs_counts for td in tdevs)
    adj0 = np.zeros((B, n0p, n0p), np.float32)
    req = np.zeros((B, n0p, C), np.int32)
    vhcl = np.zeros((B, n0p, C), np.float32)
    for i, td in enumerate(tdevs):
        n0 = td.n0
        adj0[i, :n0, :n0] = np.asarray(td.adj0, np.float32)
        if td.needs_counts:
            ci = int(td.req.shape[1])
            req[i, :n0, :ci] = np.asarray(td.req)
            vhcl[i, :n0, :ci] = np.asarray(
                td.vertex_has_counted_label, np.float32)
    return jnp.asarray(adj0), jnp.asarray(req), jnp.asarray(vhcl), needs_counts


def _make_sim(program: Callable, n_sharded: int) -> Callable:
    def call(*args):
        in_axes = (0,) * n_sharded + (None,) * (len(args) - n_sharded)
        return jax.vmap(program, in_axes=in_axes, axis_name=SHARD_AXIS)(*args)

    return jax.jit(call)


def _make_spmd(mesh, program: Callable, n_sharded: int) -> Callable:
    from repro.kernels import compat

    spec = P(tuple(mesh.axis_names))

    def per_shard(*args):
        local = [jax.tree_util.tree_map(lambda x: x[0], a)
                 for a in args[:n_sharded]]
        out = program(*local, *args[n_sharded:])
        return jax.tree_util.tree_map(lambda x: jnp.asarray(x)[None], out)

    def call(*args):
        in_specs = (spec,) * n_sharded + (P(),) * (len(args) - n_sharded)
        fn = compat.shard_map(per_shard, mesh=mesh, in_specs=in_specs,
                              out_specs=spec, check_vma=False)
        return fn(*args)

    return jax.jit(call)


class BatchedEngine:
    """The lane-stacked execution engine: B same-bucket templates, one
    partitioned background graph, shared dispatches. Drives the same
    per-shard program bodies as `_ShardedBackend` under an inner lane vmap;
    P=1 (the default) is the batched analogue of the local backend (sim with
    one shard is pinned bit-identical to local by the parity suite)."""

    def __init__(self, graph: Graph, templates: Sequence[Template], *,
                 partition=None, mesh=None, wave: int = 1024,
                 tds_chunk: int = 4096, tds_max_rows: int = 2_000_000,
                 work_aggregation: bool = True,
                 guarantee_precision: bool = True):
        from repro.kernels import registry

        if not templates:
            raise ValueError("prune_batch needs at least one template")
        if not isinstance(graph, Graph):
            raise TypeError("prune_batch needs the host Graph — the edge "
                            "partition is built from host arrays")
        buckets = {registry.shape_bucket(t.n0) for t in templates}
        if len(buckets) != 1:
            raise ValueError(
                f"templates span shape buckets {sorted(buckets)}; a batch "
                "must be same-bucket (the serving batcher groups by bucket)")
        if any(t.n0 < 2 for t in templates):
            raise ValueError("n0 == 1 templates are LCC-only degenerate "
                             "cases; run them through prune()")
        self.templates = list(templates)
        self.Bq = len(self.templates)
        if mesh is not None and partition is None:
            partition = int(np.prod(tuple(mesh.shape.values())))
        if partition is None:
            partition = 1
        if isinstance(partition, int):
            partition = partition_graph(graph, partition)
        self.part: EdgePartition = partition
        self.mesh = mesh
        if mesh is not None:
            md = int(np.prod(tuple(mesh.shape.values())))
            if md != self.part.P:
                raise ValueError(f"mesh has {md} devices but the partition "
                                 f"has P={self.part.P} shards")
        order = DeviceGraph.dst_sort_order(graph)
        self.dg = DeviceGraph.from_host(graph, order=order)
        if self.part.P * self.part.P * self.part.B >= 2**31:
            raise NotImplementedError(
                "bucket tensor >= 2^31 slots; the int32 edge gather/scatter "
                "map would overflow — shard the graph coarser")
        self._arc_slot = jnp.asarray(self.part.arc_flat_slot[order], jnp.int32)
        self.P = self.part.P
        self.B = self.part.B
        self.n_local = self.part.n_local
        self.wave = wave
        self.tds_chunk = tds_chunk
        self.tds_max_rows = tds_max_rows
        self.work_aggregation = work_aggregation
        self.guarantee_precision = guarantee_precision
        self.tdevs = [TemplateDev(t) for t in self.templates]
        self.n0p = max(t.n0 for t in self.templates)
        self.W = packed_words(self.n0p)
        (self.adj0_b, self.req_b, self.vhcl_b,
         self.needs_counts) = _stack_template_consts(self.tdevs, self.n0p)
        self.arrs = self.part.device_arrays()
        self._fns: Dict = {}
        self._routes_taken: set = set()
        self.omega_b: Optional[jnp.ndarray] = None
        self.ea_b: Optional[jnp.ndarray] = None
        self.name = "sim" if mesh is None else "spmd"

    # -- program wrapping ---------------------------------------------------
    def _fn(self, key, program: Callable, n_sharded: int) -> Callable:
        if key not in self._fns:
            self._fns[key] = (_make_sim(program, n_sharded)
                              if self.mesh is None
                              else _make_spmd(self.mesh, program, n_sharded))
        return self._fns[key]

    # -- state --------------------------------------------------------------
    def init(self, stats: Optional[Dict] = None) -> None:
        lanes = []
        labels_local = np.asarray(self.part.labels_local)
        vertex_valid = np.asarray(self.part.vertex_valid)
        # shared-LCC prefix: ONE label-candidacy plane per DISTINCT template
        # label across the whole batch — lanes with identical label multisets
        # (the common case: many analysts, few schemas) assemble their
        # initial omega from the same planes instead of recomputing per lane.
        # Column q of a lane is exactly (labels_local == t.labels[q]) &
        # vertex_valid, the same boolean plane the per-lane label_matrix
        # construction produced — bit-identical by construction.
        planes: Dict[int, np.ndarray] = {}

        def plane(l: int) -> np.ndarray:
            if l not in planes:
                planes[l] = (labels_local == l) & vertex_valid
            return planes[l]

        zero = np.zeros(labels_local.shape, bool)
        for t in self.templates:
            cols = [plane(int(t.labels[q])) for q in range(t.n0)]
            cols += [zero] * (self.n0p - t.n0)  # pad to common bucket width
            bits = np.stack(cols, axis=-1)  # [P, n_local, n0p]
            om = np.asarray(pack_bits(jnp.asarray(bits)))
            om = np.concatenate(
                [om, np.zeros((self.P, 1, self.W), np.uint32)], axis=1)
            lanes.append(om)
        if stats is not None:
            stats["shared_candidacy_planes"] = {
                "distinct": len(planes),
                "lane_columns": int(sum(t.n0 for t in self.templates)),
            }
        self.omega_b = jnp.asarray(np.stack(lanes, axis=1))
        ea = np.asarray(~self.part.send_pad)  # [P, P, B]
        self.ea_b = jnp.asarray(
            np.broadcast_to(ea[:, None], (self.P, self.Bq) + ea.shape[1:]))

    def gather_lane(self, lane: int) -> PruneState:
        """One lane's global PruneState in the lane template's own width."""
        flat = self.omega_b[:, lane, :self.n_local].reshape(
            self.P * self.n_local, -1)
        omega = unpack_bits(flat, self.n0p)[:self.part.n,
                                            :self.templates[lane].n0]
        ea = jnp.take(self.ea_b[:, lane].reshape(-1), self._arc_slot)
        return PruneState(omega=omega, edge_active=ea)

    def scatter_lane(self, lane: int, state: PruneState) -> None:
        n0 = self.templates[lane].n0
        bits = jnp.asarray(state.omega, bool)
        if self.n0p > n0:
            bits = jnp.concatenate([bits, jnp.zeros(
                (bits.shape[0], self.n0p - n0), bool)], axis=1)
        pad = self.P * self.n_local - self.part.n
        if pad:
            bits = jnp.concatenate(
                [bits, jnp.zeros((pad, self.n0p), bool)], axis=0)
        om = pack_bits(bits).reshape(self.P, self.n_local, self.W)
        om = jnp.concatenate(
            [om, jnp.zeros((self.P, 1, self.W), jnp.uint32)], axis=1)
        ea_flat = jnp.zeros((self.P * self.P * self.B,), bool)
        ea_flat = ea_flat.at[self._arc_slot].set(
            jnp.asarray(state.edge_active, bool))
        self.omega_b = self.omega_b.at[:, lane].set(om)
        self.ea_b = self.ea_b.at[:, lane].set(
            ea_flat.reshape(self.P, self.P, self.B))

    def cancel_lane(self, lane: int) -> None:
        """Deadline cancellation = masking the lane inert: zeroed candidacy
        and edge bits are fixpoints of every sweep, so the lane rides the
        remaining batched dispatches as a no-op instead of aborting them."""
        self.omega_b = self.omega_b.at[:, lane].set(jnp.uint32(0))
        self.ea_b = self.ea_b.at[:, lane].set(False)

    # -- batched LCC ---------------------------------------------------------
    def lcc(self, stats: Optional[Dict] = None) -> None:
        prims = axis_prims(SHARD_AXIS)
        n0p, needs_counts = self.n0p, self.needs_counts

        def program(sa_dict, omega_b, ea_b, adj0_b, req_b, vhcl_b):
            sa = ShardArrays(**sa_dict)

            def lane(om, ea, adj0, req, vhcl):
                tm = _LaneMasks(n0p, needs_counts, adj0, req, vhcl)
                return lcc_shard_fixpoint(om, ea, sa, tm, prims)

            return jax.vmap(lane)(omega_b, ea_b, adj0_b, req_b, vhcl_b)

        fn = self._fn("lcc_b", program, n_sharded=3)
        self.omega_b, self.ea_b, it = fn(
            self.arrs, self.omega_b, self.ea_b,
            self.adj0_b, self.req_b, self.vhcl_b)
        if stats is not None:
            stats["lcc_calls"] = stats.get("lcc_calls", 0) + 1
            stats["lcc_iterations"] = (
                stats.get("lcc_iterations", 0) + int(jnp.max(it)))

    # -- batched NLCC waves ---------------------------------------------------
    def _omega_column_b(self, lane: int, q: int) -> jnp.ndarray:
        w, b = q // 32, q % 32
        word = self.omega_b[:, lane, :self.n_local, w]
        return ((word >> jnp.uint32(b)) & 1).astype(bool)

    def _cand_stack_b(self, lane: int, walk: Sequence[int]) -> jnp.ndarray:
        return jnp.stack([self._omega_column_b(lane, q) for q in walk],
                         axis=1)  # [P, L+1, n_local]

    def _route(self, L: int) -> str:
        from repro.kernels import registry

        if self.wave % 32 != 0:
            return registry.ROUTE_UNPACKED
        eligible = engine_mod.sharded_fused_eligible(
            self.n_local, self.P, self.B, self.wave, L)
        default = (registry.ROUTE_FUSED if eligible
                   else registry.ROUTE_PACKED)
        return registry.resolve_route(
            nlcc_mod.NLCC_ROUTE, self.route_bucket(),
            default=default,
            allowed=(registry.ROUTE_FUSED, registry.ROUTE_PACKED,
                     registry.ROUTE_UNPACKED))

    def route_bucket(self):
        from repro.kernels import registry

        return registry.batch_bucket(
            self.Bq, registry.shard_bucket(self.P, self.n_local, self.wave))

    def _frontier_program_b(self, L: int, packed: bool) -> Callable:
        n_local = self.n_local
        prims = axis_prims(SHARD_AXIS)

        def program(sa_dict, ea_j, cand_j, ids_j):
            sa = ShardArrays(**sa_dict)

            def job(ea, cand_stack, ids):
                planes = _seed_frontier_planes(
                    cand_stack[0], ids, n_local, prims.axis_index())
                f = pack_bits(planes) if packed else planes

                def hop(fr, cand_r):
                    if packed:
                        return frontier_shard_hop(
                            fr, ea, sa, cand_r, prims), None
                    return frontier_shard_hop_unpacked(
                        fr, ea, sa, cand_r, prims), None

                f, _ = jax.lax.scan(hop, f, cand_stack[1:])
                return f

            return jax.vmap(job)(ea_j, cand_j, ids_j)

        return program

    def _finish_program_b(self, packed: bool, is_cyclic: bool) -> Callable:
        n_local = self.n_local
        prims = axis_prims(SHARD_AXIS)

        def finish(f_j, keep_j, ids_j):
            def job(f, keep, ids):
                if packed:
                    planes = jnp.concatenate([
                        unpack_bits(f[:n_local], ids.shape[0]),
                        jnp.zeros((1, ids.shape[0]), bool)], axis=0)
                else:
                    planes = f
                survived = _sharded_wave_survivors(
                    planes, ids, n_local, is_cyclic, prims)
                return _scatter_keep(keep, survived, ids, n_local,
                                     prims.axis_index())

            return jax.vmap(job)(f_j, keep_j, ids_j)

        return finish

    def nlcc_phase(self, lane_constraints: Sequence[
            Tuple[int, NonLocalConstraint, str]],
            cstats: Optional[Dict] = None):
        """Run one lockstep phase of token-passing constraints — one
        (lane, constraint, direction) entry per lane — through job-axis
        batched wave dispatches. Returns a DEVICE bool (did any lane's omega
        change); the driver converts it to the phase's single host sync."""
        from repro.kernels import registry

        omega_before = self.omega_b
        jobs: List[Tuple[int, Tuple[int, ...]]] = []
        for lane, c, direction in lane_constraints:
            jobs.extend((lane, w)
                        for w in nlcc_mod.expand_walks(c, direction))

        # ONE stacked head-planes readback sizes every wave loop of the phase
        head = np.asarray(jnp.stack(
            [self._omega_column_b(lane, w[0]) for lane, w in jobs]))
        head_global = head.reshape(len(jobs), -1)[:, :self.part.n]

        groups: Dict[Tuple[int, bool], List[int]] = {}
        for ji, (lane, w) in enumerate(jobs):
            groups.setdefault((len(w) - 1, w[0] == w[-1]), []).append(ji)

        keep_cols: Dict[int, jnp.ndarray] = {}
        n_waves = n_tokens = n_padded = 0
        for (L, is_cyclic), members in groups.items():
            route = self._route(L)
            self._routes_taken.add(route)
            packed = route in (registry.ROUTE_FUSED, registry.ROUTE_PACKED)
            J = len(members)
            lanes = jnp.asarray([jobs[ji][0] for ji in members], jnp.int32)
            cand_j = jnp.stack(
                [self._cand_stack_b(*jobs[ji]) for ji in members], axis=1)
            ea_j = jnp.take(self.ea_b, lanes, axis=1)  # [P, J, P, B]
            keep_j = jnp.zeros((self.P, J, self.n_local + 1), bool)
            batches = [list(nlcc_mod.wave_batches(
                np.flatnonzero(head_global[ji]), self.wave))
                for ji in members]
            front = self._fn(("wave_front_b", L, packed, J),
                             self._frontier_program_b(L, packed), n_sharded=3)
            finish = self._fn(("wave_finish_b", packed, is_cyclic, J),
                              self._finish_program_b(packed, is_cyclic),
                              n_sharded=2)
            pad_ids = np.full(self.wave, -1, np.int32)
            n_rounds = max((len(b) for b in batches), default=0)
            # lockstep wave rounds: a job whose sources ran dry supplies
            # all-pad ids — inert in seed, survivors, and keep scatter —
            # so stragglers keep the batch running without exiting it
            for r in range(n_rounds):
                ids = np.stack([b[r][0] if r < len(b) else pad_ids
                                for b in batches])
                ids_dev = jnp.asarray(ids, jnp.int32)
                f = front(self.arrs, ea_j, cand_j, ids_dev)
                keep_j = finish(f, keep_j, ids_dev)
                n_waves += 1
                n_tokens += sum(b[r][1] for b in batches if r < len(b))
                n_padded += sum(1 for b in batches if r >= len(b))
            for jj, ji in enumerate(members):
                keep_cols[ji] = keep_j[:, jj]

        # head eliminations (Alg. 5 line 8), per job on its own lane
        omega = self.omega_b
        for ji, (lane, w) in enumerate(jobs):
            q0 = w[0]
            wd, b = q0 // 32, q0 % 32
            word = omega[:, lane, :, wd]
            cleared = word & jnp.uint32(~np.uint32(1 << b))
            omega = omega.at[:, lane, :, wd].set(
                jnp.where(keep_cols[ji], word, cleared))
        self.omega_b = omega
        if cstats is not None:
            cstats["nlcc_waves"] = cstats.get("nlcc_waves", 0) + n_waves
            cstats["nlcc_tokens"] = cstats.get("nlcc_tokens", 0) + n_tokens
            cstats["nlcc_lockstep_padded"] = (
                cstats.get("nlcc_lockstep_padded", 0) + n_padded)
            cstats["nlcc_constraints"] = (
                cstats.get("nlcc_constraints", 0) + len(lane_constraints))
            cstats["nlcc_host_syncs"] = cstats.get("nlcc_host_syncs", 0) + 1
        return jnp.any(omega_before != self.omega_b)

    # -- TDS lane bridge ------------------------------------------------------
    def tds_lane(self, lane: int, c: NonLocalConstraint,
                 cstats: Optional[Dict] = None) -> bool:
        state = self.gather_lane(lane)
        new = tds_mod.verify_tds_constraint(
            self.dg, state, c, chunk=self.tds_chunk,
            max_rows=self.tds_max_rows, stats=cstats,
            annotate=(c.complete and self.guarantee_precision),
            dedup=self.work_aggregation)
        changed = bool(engine_mod._state_changed(state, new))
        if changed:
            self.scatter_lane(lane, new)
        if cstats is not None:
            cstats["tds_gather_bridge"] = (
                cstats.get("tds_gather_bridge", 0) + 1)
        return changed

    def sync(self) -> None:
        jax.block_until_ready((self.omega_b, self.ea_b))


@dataclasses.dataclass
class BatchedPruneResult:
    """Per-lane prune results of one batched execution. `results[i]` is a
    standard PruneResult for templates[i] (backend-free: enumeration over it
    routes through the local device/host join); `status[i]` is "ok" or
    "deadline_missed" (a cancelled lane's state is all-zero)."""

    results: List[PruneResult]
    status: List[str]
    stats: Dict

    @property
    def n_lanes(self) -> int:
        return len(self.results)


def prune_batch(
    graph: Graph,
    templates: Sequence[Template],
    *,
    partition=None,
    mesh=None,
    wave: int = 1024,
    guarantee_precision: bool = True,
    work_aggregation: bool = True,
    tds_chunk: int = 4096,
    tds_max_rows: int = 2_000_000,
    label_freq: Optional[np.ndarray] = None,
    deadlines: Optional[Sequence[Optional[float]]] = None,
    clock: Optional[Callable[[], float]] = None,
) -> BatchedPruneResult:
    """Prune B same-bucket templates against one graph in one batched run.

    partition/mesh select the backend exactly as in `prune` — the default
    (both None) runs the batch on one shard (P=1), the batched analogue of
    the local backend. `deadlines[i]` is an absolute `clock()` time after
    which lane i is cancelled at the next phase boundary (masked inert, not
    a batch abort); clock defaults to time.monotonic.
    """
    eng = BatchedEngine(
        graph, templates, partition=partition, mesh=mesh, wave=wave,
        tds_chunk=tds_chunk, tds_max_rows=tds_max_rows,
        work_aggregation=work_aggregation,
        guarantee_precision=guarantee_precision)
    from repro.kernels import registry

    if label_freq is None:
        label_freq = graph.label_frequency()
    cons = [generate_constraints(t, label_freq=label_freq,
                                 guarantee_precision=guarantee_precision)
            for t in templates]
    # per-lane plan resolution (core/planner.py): tuned plans reorder a
    # lane's phases; untuned (no plans in the active policy) every lane runs
    # the heuristic order byte-identically, with zero stats collection
    from repro.core import planner as planner_mod

    phase_lists: List[List[planner_mod.PlanPhase]] = []
    plan_sources: List[str] = []
    policy = registry.get_policy()
    if policy is not None and policy.plans:
        from repro.graph import stats as gstats

        gstat = gstats.collect_graph_stats(graph)
        for t, cs in zip(templates, cons):
            qp = planner_mod.resolve_query_plan(t, cs, gstat)
            if qp is None:
                qp = planner_mod.heuristic_plan(cs)
            phase_lists.append(qp.phases)
            plan_sources.append(qp.source)
    else:
        for cs in cons:
            phase_lists.append(planner_mod.heuristic_plan(cs).phases)
            plan_sources.append("heuristic")
    if deadlines is not None and len(deadlines) != len(templates):
        raise ValueError("deadlines must align with templates")
    clock = clock or time.monotonic
    status = [STATUS_OK] * eng.Bq
    stats: Dict = {
        "n_constraints": [len(c) for c in cons],
        "plan": {"sources": plan_sources},
        "batched": {
            "B": eng.Bq, "P": eng.P, "backend": eng.name,
            "bucket": registry.bucket_key(eng.route_bucket()),
        },
    }

    def cancel_expired():
        if deadlines is None:
            return
        now = clock()
        for i, dl in enumerate(deadlines):
            if dl is not None and status[i] == STATUS_OK and now > dl:
                status[i] = STATUS_DEADLINE_MISSED
                eng.cancel_lane(i)
                stats["deadline_cancelled"] = (
                    stats.get("deadline_cancelled", 0) + 1)

    t0 = time.perf_counter()
    eng.init(stats)
    cancel_expired()
    eng.lcc(stats)
    # lockstep over PLANNED phase lists: phase identity is per-lane (lane i's
    # phase k is phase_lists[i][k]), so differently-ordered lanes coexist in
    # one batch — the engine split is by the planned engine, not the kind
    for k in range(max((len(pl) for pl in phase_lists), default=0)):
        cancel_expired()
        wave_lanes = []
        tds_lanes = []
        for i, pl in enumerate(phase_lists):
            if status[i] != STATUS_OK or k >= len(pl):
                continue
            p = pl[k]
            if p.engine == planner_mod.ENGINE_NLCC:
                wave_lanes.append((i, p.constraint, p.direction))
            else:
                tds_lanes.append((i, p.constraint))
        changed_dev = eng.nlcc_phase(wave_lanes, stats) if wave_lanes else None
        # the phase's ONE host sync: did any lane change?
        changed = bool(changed_dev) if changed_dev is not None else False
        for i, c in tds_lanes:  # host-bridged row joins (as in every backend)
            changed = eng.tds_lane(i, c, stats) or changed
        if changed:
            # joint re-run: lanes the phase left unchanged sit at LCC
            # fixpoint, so the sweep is a bit-exact no-op for them
            eng.lcc(stats)
    eng.sync()
    stats["batched"]["seconds"] = time.perf_counter() - t0
    stats["dispatch_routes"] = {
        nlcc_mod.NLCC_ROUTE: ("+".join(sorted(eng._routes_taken))
                              if eng._routes_taken else "none")}

    results = []
    for i, t in enumerate(templates):
        state = eng.gather_lane(i)
        results.append(PruneResult(
            state=state, template=t, dg=eng.dg, phases=[],
            stats=dict(stats, lane=i, lane_status=status[i])))
    return BatchedPruneResult(results=results, status=status, stats=stats)

"""Distributed constraint-checking engine (shard_map over the production mesh).

The TPU adaptation of HavoqGT's asynchronous visitor queues (DESIGN.md §2):

  - vertex candidate state `omega` is bit-packed uint32[n_local+1, W] per shard
    (last row = padding sink),
  - one LCC iteration = gather local omega over the static send buckets, mask by
    per-arc active bits, ONE `all_to_all` (the only collective), then a static
    dst-sorted permutation + segmented-scan OR on the receive side,
  - edge elimination reads the twin arc's omega out of the *same* receive
    buffer (`twin_recv_flat`) — no extra collective,
  - the LCC fixpoint is a single on-device `while_loop` whose convergence flag
    is `psum`-reduced — the BSP replacement for distributed quiescence
    detection,
  - NLCC cycle/path checks reuse the identical sweep with frontier words.

Every function is written against an `exchange` callable so the same math runs
(a) under shard_map with `jax.lax.all_to_all` on real meshes / dry-runs and
(b) under vmap with a transpose standing in for the collective — which is how
single-process tests prove the distributed math equals the single-device
engine bit-for-bit.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.graph.partition import EdgePartition
from repro.graph.segment_ops import SegmentMeta, segment_or
from repro.core.state import pack_bits, unpack_bits
from repro.core.lcc import TemplateDev


@dataclasses.dataclass
class ShardArrays:
    """Per-shard static partition arrays (local views, leading shard axis removed)."""

    send_src_local: jnp.ndarray  # int32[P, B]
    send_pad: jnp.ndarray  # bool[P, B]
    twin_recv_flat: jnp.ndarray  # int32[P, B]
    recv_perm: jnp.ndarray  # int32[P*B]
    recv_sorted_dst_local: jnp.ndarray  # int32[P*B]
    recv_is_start: jnp.ndarray  # bool[P*B]
    recv_last_edge: jnp.ndarray  # int32[n_local]
    labels_local: jnp.ndarray  # int32[n_local]
    vertex_valid: jnp.ndarray  # bool[n_local]


jax.tree_util.register_dataclass(ShardArrays)


def _local_views(arrs: Dict[str, jnp.ndarray]) -> ShardArrays:
    return ShardArrays(**{k: arrs[k] for k in ShardArrays.__dataclass_fields__})


class TemplateMasks:
    """Packed template constants for the distributed sweep."""

    def __init__(self, tdev: TemplateDev):
        self.n0 = tdev.n0
        self.adj0 = tdev.adj0.astype(jnp.float32)  # [n0, n0]
        self.needs_counts = tdev.needs_counts
        self.req = tdev.req
        self.vertex_has_counted_label = tdev.vertex_has_counted_label.astype(jnp.float32)


def _sweep_recv(
    msgs: jnp.ndarray,  # [P, B, W] packed, already masked
    sa: ShardArrays,
    n_local: int,
    exchange: Callable,
) -> jnp.ndarray:
    """Exchange + static sort; returns recv buffer [P*B, W] in arrival order."""
    Pp, B, W = msgs.shape
    return exchange(msgs.reshape(Pp * B, W))


def _aggregate_or(recv: jnp.ndarray, sa: ShardArrays, n_local: int) -> jnp.ndarray:
    sortedv = jnp.take(recv, sa.recv_perm, axis=0)
    meta = SegmentMeta(is_start=sa.recv_is_start, last_edge_of_vertex=sa.recv_last_edge)
    return segment_or(sortedv, meta, n_local)  # [n_local, W]


def lcc_shard_iteration(
    omega: jnp.ndarray,  # uint32[n_local+1, W]
    edge_active: jnp.ndarray,  # bool[P, B]
    sa: ShardArrays,
    tm: TemplateMasks,
    exchange: Callable,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    n_local = omega.shape[0] - 1
    send_mask = edge_active & ~sa.send_pad
    msgs = jnp.take(omega, sa.send_src_local, axis=0)  # [P, B, W]
    msgs = jnp.where(send_mask[..., None], msgs, jnp.uint32(0))
    recv = _sweep_recv(msgs, sa, n_local, exchange)  # [P*B, W]
    return _lcc_from_recv(omega, edge_active, recv, sa, tm)


def lcc_shard_fixpoint(
    omega: jnp.ndarray,
    edge_active: jnp.ndarray,
    sa: ShardArrays,
    tm: TemplateMasks,
    exchange: Callable,
    all_reduce_or: Callable,
    max_iters: int = 64,
):
    def cond(c):
        _, _, changed, it = c
        return jnp.logical_and(changed, it < max_iters)

    def body(c):
        om, ea, _, it = c
        om2, ea2, ch = lcc_shard_iteration(om, ea, sa, tm, exchange)
        return om2, ea2, all_reduce_or(ch), it + 1

    om, ea, _, it = jax.lax.while_loop(
        cond, body, (omega, edge_active, jnp.asarray(True), jnp.asarray(0))
    )
    return om, ea, it


def frontier_shard_hop(
    frontier: jnp.ndarray,  # uint32[n_local+1, Wf]
    cand_next: jnp.ndarray,  # bool[n_local]
    edge_active: jnp.ndarray,  # bool[P, B]
    sa: ShardArrays,
    exchange: Callable,
) -> jnp.ndarray:
    """One NLCC token hop (paper Alg. 6 forward) on packed multi-source words."""
    n_local = frontier.shape[0] - 1
    Wf = frontier.shape[1]
    send_mask = edge_active & ~sa.send_pad
    msgs = jnp.take(frontier, sa.send_src_local, axis=0)
    msgs = jnp.where(send_mask[..., None], msgs, jnp.uint32(0))
    recv = exchange(msgs.reshape(-1, Wf))
    agg = _aggregate_or(recv, sa, n_local)
    nxt = jnp.where(cand_next[:, None], agg, jnp.uint32(0))
    return jnp.concatenate([nxt, jnp.zeros((1, Wf), jnp.uint32)], axis=0)


# --------------------------------------------------------------------------
# Execution wrappers
# --------------------------------------------------------------------------
def make_shard_map_engine(mesh, axis_names, part_arrays: Dict[str, jnp.ndarray],
                          tm: TemplateMasks, max_iters: int = 64):
    """Builds the jit-able distributed LCC fixpoint over a mesh.

    `axis_names` may be a tuple (e.g. ("pod", "data", "model")) — the engine
    treats the flattened product as the shard axis (pure data-parallel
    irregular workload; see DESIGN.md §4).
    """
    from repro.kernels import compat

    ax = axis_names if isinstance(axis_names, tuple) else (axis_names,)
    spec_shard = P(ax)

    def exchange(x):
        return jax.lax.all_to_all(x, ax, 0, 0, tiled=True)

    def all_reduce_or(flag):
        return jax.lax.psum(flag.astype(jnp.int32), ax) > 0

    shard_specs = {
        "send_src_local": spec_shard, "send_pad": spec_shard,
        "twin_recv_flat": spec_shard, "recv_perm": spec_shard,
        "recv_sorted_dst_local": spec_shard, "recv_is_start": spec_shard,
        "recv_last_edge": spec_shard, "labels_local": spec_shard,
        "vertex_valid": spec_shard,
    }

    def step(omega, edge_active, arrs):
        sa = _local_views({k: v[0] for k, v in arrs.items()})
        om, ea, it = lcc_shard_fixpoint(
            omega[0], edge_active[0], sa, tm, exchange, all_reduce_or, max_iters
        )
        return om[None], ea[None], it

    fn = compat.shard_map(
        step,
        mesh=mesh,
        in_specs=(spec_shard, spec_shard, shard_specs),
        out_specs=(spec_shard, spec_shard, P()),
        check_vma=False,
    )
    return jax.jit(fn)


def make_vmap_engine(part: EdgePartition, tm: TemplateMasks, max_iters: int = 64):
    """Single-process simulation: vmap over the shard axis, transpose = all_to_all.
    Used to prove distributed math == single-device engine."""
    arrs = part.device_arrays()
    Pn, B = part.P, part.B

    def run(omega_all, edge_active_all):
        # omega_all: [P, n_local+1, W]; edge_active_all: [P, P, B]
        def one_fixpoint_iter(carry):
            om, ea, _, it = carry
            msgs = jax.vmap(
                lambda o, e, ssl, sp: jnp.where(
                    (e & ~sp)[..., None], jnp.take(o, ssl, axis=0), jnp.uint32(0)
                )
            )(om, ea, arrs["send_src_local"], arrs["send_pad"])  # [P, P, B, W]
            recv = jnp.transpose(msgs, (1, 0, 2, 3)).reshape(Pn, Pn * B, -1)

            def compute(o, e, recv_p, *locals_):
                sa = ShardArrays(*locals_)
                return _lcc_from_recv(o, e, recv_p, sa, tm)

            om2, ea2, ch = jax.vmap(compute)(
                om, ea, recv,
                arrs["send_src_local"], arrs["send_pad"], arrs["twin_recv_flat"],
                arrs["recv_perm"], arrs["recv_sorted_dst_local"], arrs["recv_is_start"],
                arrs["recv_last_edge"], arrs["labels_local"], arrs["vertex_valid"],
            )
            return om2, ea2, jnp.any(ch), it + 1

        def cond(carry):
            _, _, changed, it = carry
            return jnp.logical_and(changed, it < max_iters)

        om, ea, _, it = jax.lax.while_loop(
            cond, one_fixpoint_iter,
            (omega_all, edge_active_all, jnp.asarray(True), jnp.asarray(0)),
        )
        return om, ea, it

    return jax.jit(run)


def _lcc_from_recv(omega, edge_active, recv, sa: ShardArrays, tm: TemplateMasks):
    """lcc_shard_iteration with the exchange already performed (shared math)."""
    n_local = omega.shape[0] - 1
    W = omega.shape[1]
    send_mask = edge_active & ~sa.send_pad

    M_packed = _aggregate_or(recv, sa, n_local)
    M = unpack_bits(M_packed, tm.n0)
    omega_bits = unpack_bits(omega[:n_local], tm.n0)
    missing = (~M).astype(jnp.float32) @ tm.adj0.T
    ok = missing < 0.5
    if tm.needs_counts:
        rbits = unpack_bits(jnp.take(recv, sa.recv_perm, axis=0), tm.n0)
        ind = (rbits.astype(jnp.float32) @ tm.vertex_has_counted_label) > 0.5
        cnt = jax.ops.segment_sum(
            ind.astype(jnp.int32),
            jnp.minimum(sa.recv_sorted_dst_local, n_local),
            num_segments=n_local + 1, indices_are_sorted=True,
        )[:n_local]
        ok = ok & jnp.all(cnt[:, None, :] >= tm.req[None, :, :], axis=-1)
    new_bits = omega_bits & ok & sa.vertex_valid[:, None]
    deg_pos = jnp.any(tm.adj0 > 0.5, axis=1)
    new_bits = new_bits & (~deg_pos[None, :] | jnp.any(M, axis=1)[:, None])

    recv_sink = jnp.concatenate([recv, jnp.zeros((1, W), jnp.uint32)], axis=0)
    dst_words = jnp.take(recv_sink, sa.twin_recv_flat, axis=0)
    src_bits = unpack_bits(jnp.take(omega, sa.send_src_local, axis=0), tm.n0)
    dst_bits = unpack_bits(dst_words, tm.n0)
    side = src_bits.astype(jnp.float32) @ tm.adj0
    compat = jnp.sum(side * dst_bits.astype(jnp.float32), axis=-1) > 0.5
    ea_new = send_mask & compat
    omega_new = jnp.concatenate([pack_bits(new_bits), jnp.zeros((1, W), jnp.uint32)], axis=0)
    changed = jnp.any(omega_new != omega) | jnp.any(ea_new != edge_active)
    return omega_new, ea_new, changed


def init_distributed_state(part: EdgePartition, template) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """omega_all [P, n_local+1, W] from labels; edge_active_all [P, P, B]."""
    from repro.core.state import packed_words

    n0 = template.n0
    W = packed_words(n0)
    n_labels = int(max(template.labels.max() + 1, part.labels_local.max() + 1))
    lm = template.label_matrix(n_labels)  # [n0, L]
    bits = lm.T[np.asarray(part.labels_local)]  # [P, n_local, n0]
    bits &= np.asarray(part.vertex_valid)[..., None]
    omega = np.asarray(pack_bits(jnp.asarray(bits)))
    omega = np.concatenate(
        [omega, np.zeros((part.P, 1, W), np.uint32)], axis=1
    )
    return jnp.asarray(omega), jnp.asarray(~part.send_pad)

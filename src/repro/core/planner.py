"""Plan-level query optimizer: cost-modeled constraint ordering per
(template, graph-stats) bucket — GraphPi's schedule half for constraint
pipelines (PR 5's automorphism restrictions are the other half).

The paper runs constraints in one fixed heuristic order (template.py §3
ordering). But the order in which constraints eliminate vertices dominates
end-to-end prune cost: an early high-selectivity walk collapses the frontier
before the expensive cycles ever issue a token. This module enumerates
candidate *plans* — a permutation of the constraint list, a walk-direction
choice per CC/PC constraint, and a TDS-vs-NLCC engine choice where both are
sound — costs each with a calibrated model, and picks the argmin. Chosen
plans persist in the dispatch-policy cache (`kernels/registry.py`, additive
``plans`` table) keyed by (template signature, graph-stats bucket), so
serving startup loads tuned plans for free and an untuned checkout runs the
paper's order byte-identically.

Soundness — when may a plan deviate from the heuristic order at all?
Every phase is *reductive* and *monotone*: omega/edge bits only clear, and a
bit is cleared only by certifying that no true match uses it (given the
current sound superset state). So ANY phase order ends at a sound superset
of the exact match state — but not necessarily the SAME superset: order A
may eliminate a vertex whose removal strips support that order B never
re-checks. Two things restore bit-identity:

1. With ``guarantee_precision``, the COMPLETE edge-cover TDS walk
   (annotate mode) maps any sound superset to the EXACT match set — exact
   omega (Def. 1 zero false positives) and exact match-participating edges
   — regardless of which superset it started from.
2. The driver's conditional LCC fixpoint after the final phase makes the
   edge mask a pure function of the final omega.

Hence the planner's gate: a plan may permute constraints, weaken walk
directions, or swap engines ONLY when the constraint list ends in a complete
TDS phase, and that phase stays pinned last. Otherwise the heuristic order
is the only sound plan and the planner returns it unchanged. Direction and
engine deviations are all *sound relaxations or strengthenings* (a subset of
the default walk checks, or a row join at least as strong as token passing):
they can only move the intermediate state within the sound-superset lattice
that the complete phase collapses to the same exact point.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.template import (
    Template,
    NonLocalConstraint,
    generate_constraints,
    estimate_constraint_selectivity,
)
from repro.core import nlcc as nlcc_mod
from repro.graph.stats import GraphStats

ENGINE_NLCC = "nlcc"
ENGINE_TDS = "tds"

# enumeration budget: permute at most this many distinct cost classes
# exhaustively (6! = 720 candidate orders); larger templates fall back to a
# greedy cheapest-rank ordering
MAX_ENUM_CLASSES = 6


# ----------------------------------------------------------------- signatures
def constraint_signature(c: NonLocalConstraint) -> str:
    """Stable string identity of one constraint: kind, walk, completeness —
    the unit of phase identity for plan entries and checkpoint metadata."""
    sig = f"{c.kind}:{','.join(str(q) for q in c.walk)}"
    return sig + ":complete" if c.complete else sig


def template_signature(t: Template) -> str:
    """Stable string identity of a template (labels + edge set) — the
    template half of the plan bucket key."""
    labels = ".".join(str(int(l)) for l in t.labels)
    edges = ".".join(f"{a}-{b}" for a, b in sorted(t.edge_set))
    return f"l{labels}_e{edges}"


def plan_bucket(template: Template, stats: GraphStats) -> Tuple[str, str]:
    """The (template-sig, stats-bucket) plan cache bucket — renders inside a
    policy key as ``prune.plan|<backend>|<tsig>x<stats-bucket>``."""
    return (template_signature(template), stats.bucket())


# ----------------------------------------------------------------------- plan
@dataclasses.dataclass(frozen=True)
class PlanPhase:
    """One planned pipeline phase: which constraint, on which engine, with
    which walk-direction choice (nlcc engine only; see nlcc.expand_walks)."""

    constraint: NonLocalConstraint
    engine: str = ENGINE_NLCC  # "nlcc" | "tds"
    direction: str = "default"

    @property
    def signature(self) -> str:
        return constraint_signature(self.constraint)

    @property
    def identity(self) -> str:
        """Full execution identity: constraint signature plus engine and
        direction. Two phases with equal identity compute the same state
        transition; checkpoints and batch groups key on this, not on the
        bare signature (a direction change alters the committed state)."""
        return f"{self.signature}@{self.engine}.{self.direction}"

    def is_default(self) -> bool:
        return (self.engine == default_engine(self.constraint)
                and self.direction == "default")


@dataclasses.dataclass
class QueryPlan:
    phases: List[PlanPhase]
    predicted_s: float = 0.0
    # "heuristic" (paper order, untuned / reorder unsound), "planner" (cost
    # model picked it), "policy" (loaded from the persisted plan cache)
    source: str = "heuristic"
    # per-phase model predictions (seconds), aligned with `phases`; the
    # driver reports these next to actuals in stats["plan"]
    per_phase_s: Optional[List[float]] = None

    def signatures(self) -> List[str]:
        return [p.signature for p in self.phases]

    def identities(self) -> List[str]:
        return [p.identity for p in self.phases]

    def constraints(self) -> List[NonLocalConstraint]:
        return [p.constraint for p in self.phases]

    def is_heuristic(self) -> bool:
        return all(p.is_default() for p in self.phases)


def default_engine(c: NonLocalConstraint) -> str:
    """The engine the unplanned driver dispatches this constraint to."""
    return ENGINE_NLCC if c.kind in ("cycle", "path") else ENGINE_TDS


def heuristic_plan(constraints: Sequence[NonLocalConstraint]) -> QueryPlan:
    """The paper's §3 order with default engines/directions — what every
    untuned run executes, byte-identically to a plan-less checkout."""
    return QueryPlan(
        phases=[PlanPhase(c, default_engine(c), "default")
                for c in constraints],
        source="heuristic",
    )


def reorder_is_sound(constraints: Sequence[NonLocalConstraint]) -> bool:
    """Plans may deviate from the heuristic order only when a complete
    edge-cover TDS phase exists to restore exactness (module docstring). The
    generator always emits it LAST when `guarantee_precision` asked for one."""
    return bool(constraints) and constraints[-1].complete


# ----------------------------------------------------------------- cost model
@functools.lru_cache(maxsize=None)
def static_dispatch_seconds(backend: str, wave: int, m_bucket: int) -> float:
    """Static per-dispatch cost of one token-forward hop at `wave` width over
    ~`m_bucket` arcs, from the HLO cost model of a representative lowered hop
    (launch/hlo_cost.py) — the fixed term the calibrated model adds per wave
    dispatch. Falls back to an analytic estimate when lowering fails (no
    compiler for `backend` in this process, unparsable HLO, ...)."""
    try:
        import jax
        import jax.numpy as jnp
        from repro.launch.hlo_cost import analyze

        def hop(frontier, src, dst):
            return jnp.zeros_like(frontier).at[dst].max(frontier[src])

        m = max(int(m_bucket), 1)
        lowered = jax.jit(hop).lower(
            jax.ShapeDtypeStruct((max(wave, 1),), jnp.bool_),
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((m,), jnp.int32),
        )
        cost = analyze(lowered.compile().as_text())
        # nominal single-device throughputs — only the RELATIVE magnitude
        # across plans matters, and every plan shares these constants
        flops_s, bytes_s = 1e12, 1e11
        secs = (cost["flops_per_device"] / flops_s
                + cost["bytes_per_device"] / bytes_s)
        return max(float(secs), 1e-7)
    except Exception:  # pragma: no cover - depends on jax build
        return 1e-5 + 1e-9 * max(int(m_bucket), 1)


def measured_wave_seconds(
    policy, backend: str, n: int, wave: int
) -> Optional[float]:
    """Per-wave measured seconds from the tuned policy's NLCC route entry for
    this (n, wave) shape bucket — the calibrated term of the cost model.
    None when the policy never measured this bucket."""
    if policy is None:
        return None
    from repro.kernels import registry

    entry = policy.route_entry_for(
        nlcc_mod.NLCC_ROUTE, backend, registry.shape_bucket(n, wave))
    if entry is None or not entry.measured_s:
        return None
    return min(float(v) for v in entry.measured_s.values())


class _CostModel:
    """Predict seconds per phase. Calibration: measured per-wave seconds from
    the policy table when available (assumed to time a reference-length hop
    loop), else the HLO static term; frontier survival estimated from the
    graph's label histogram + average degree, updated per phase by the
    constraint's selectivity — the mechanism that rewards running selective
    constraints first."""

    REF_HOPS = 4.0  # measured NLCC route entries time ~length-4 walks
    TDS_FACTOR = 2.0  # row joins move more bytes per token than bit-planes

    def __init__(self, template: Template, stats: GraphStats, *,
                 backend: str, wave: int, policy=None):
        self.t = template
        self.stats = stats
        self.wave = max(int(wave), 1)
        freq = np.asarray(stats.label_hist, dtype=np.float64)
        need = int(template.labels.max()) + 1
        if freq.size < need:
            freq = np.concatenate([freq, np.zeros(need - freq.size)])
        self.freq = freq
        self.total = max(float(stats.n), 1.0)
        self.avg_deg = max(float(stats.avg_degree), 1.0)
        ws = measured_wave_seconds(policy, backend, stats.n, wave)
        static = static_dispatch_seconds(
            backend, wave, 1 << max(int(stats.m), 1).bit_length())
        self.hop_s = (ws / self.REF_HOPS) if ws is not None else static
        self.dispatch_s = static

    def _f(self, q: int) -> float:
        return float(self.freq[int(self.t.labels[q])]) / self.total

    def phase_seconds(self, phase: PlanPhase, survival: float) -> float:
        c = phase.constraint
        if phase.engine == ENGINE_NLCC:
            walks = nlcc_mod.expand_walks(c, phase.direction)
            total = 0.0
            for walk in walks:
                src_est = self._f(walk[0]) * self.total * survival
                n_waves = max(1.0, math.ceil(src_est / self.wave))
                total += n_waves * (
                    len(walk) * self.hop_s + self.dispatch_s)
            return total
        # TDS row join: rows grow along the walk; model total row volume as
        # the token-message estimate and charge the heavier per-row constant
        rows = self._f(c.walk[0]) * self.total * survival
        volume = 0.0
        for q in c.walk[1:]:
            volume += rows
            rows = rows * self.avg_deg * self._f(q)
        n_chunks = max(1.0, volume / self.wave)
        return self.TDS_FACTOR * n_chunks * self.hop_s + self.dispatch_s

    def survival_after(self, phase: PlanPhase, survival: float) -> float:
        c = phase.constraint
        sel = estimate_constraint_selectivity(self.t, c, self.freq)
        if phase.engine == ENGINE_NLCC:
            ran = len(nlcc_mod.expand_walks(c, phase.direction))
            full = len(nlcc_mod.expand_walks(c, "default"))
            sel *= ran / max(full, 1)  # fewer walk checks eliminate less
        return max(survival * (1.0 - sel), 0.01)

    def plan_seconds(self, phases: Sequence[PlanPhase]
                     ) -> Tuple[float, List[float]]:
        survival, total, per = 1.0, 0.0, []
        for p in phases:
            s = self.phase_seconds(p, survival)
            per.append(s)
            total += s
            survival = self.survival_after(p, survival)
        return total, per


# ---------------------------------------------------------------- enumeration
def _phase_variants(c: NonLocalConstraint) -> List[PlanPhase]:
    """Sound (engine, direction) variants of one non-complete constraint.
    Every variant either runs a subset of the default walk checks (weaker,
    sound) or a row join at least as strong as token passing (stronger,
    sound) — exactness is restored by the pinned complete phase."""
    if c.complete:
        return [PlanPhase(c, ENGINE_TDS, "default")]
    if c.kind in ("cycle", "path"):
        variants = [PlanPhase(c, ENGINE_NLCC, "default")]
        if c.is_cyclic:
            variants.append(PlanPhase(c, ENGINE_NLCC, "head"))
        else:
            variants.append(PlanPhase(c, ENGINE_NLCC, "fwd"))
            variants.append(PlanPhase(c, ENGINE_NLCC, "rev"))
        return variants
    # partial TDS: the row join is the default; token passing over the same
    # walk is the cheap relaxation
    return [PlanPhase(c, ENGINE_TDS, "default"),
            PlanPhase(c, ENGINE_NLCC, "default")]


def enumerate_orders(
    model: _CostModel, constraints: Sequence[NonLocalConstraint]
) -> List[List[NonLocalConstraint]]:
    """Candidate orders of the non-complete prefix. Constraints with equal
    (cost, selectivity) estimates are interchangeable — permuting within such
    a class yields an equivalent plan, so only class orders are enumerated
    (the symmetric-order pruning). Beyond MAX_ENUM_CLASSES classes the space
    is sampled greedily: ascending cost-to-selectivity rank."""
    prefix = list(constraints)
    if not prefix:
        return [[]]
    key_of = {}
    for c in prefix:
        base = model.phase_seconds(
            PlanPhase(c, default_engine(c), "default"), 1.0)
        sel = estimate_constraint_selectivity(model.t, c, model.freq)
        key_of[constraint_signature(c)] = (round(base, 9), round(sel, 9))
    classes: Dict[tuple, List[NonLocalConstraint]] = {}
    for c in prefix:
        classes.setdefault(key_of[constraint_signature(c)], []).append(c)
    keys = list(classes)
    if len(keys) > MAX_ENUM_CLASSES:
        # greedy: cheapest-per-unit-eliminated first, single candidate order
        ranked = sorted(
            keys, key=lambda k: (k[0] / max(k[1], 1e-9), k))
        return [[c for k in ranked for c in classes[k]]]
    orders = []
    for perm in itertools.permutations(keys):
        orders.append([c for k in perm for c in classes[k]])
    return orders


def _greedy_variants(
    model: _CostModel,
    order: Sequence[NonLocalConstraint],
    last: PlanPhase,
) -> Tuple[List[PlanPhase], float]:
    """Pick the (engine, direction) variant per phase of a fixed order.
    Greedy with one-step lookahead: phase costs are ~linear in frontier
    survival, so a variant is scored by its own cost plus the default cost
    of everything after it scaled by the survival it leaves behind — a weak
    cheap variant that barely shrinks the frontier pays for itself downstream
    and loses to the full-strength check where it should."""
    rem_default: List[float] = []
    acc = model.phase_seconds(last, 1.0)
    for c in reversed(order):
        rem_default.append(acc)
        acc += model.phase_seconds(
            PlanPhase(c, default_engine(c), "default"), 1.0)
    rem_default.reverse()
    survival, phases, cost = 1.0, [], 0.0
    for i, c in enumerate(order):
        best = None
        for p in _phase_variants(c):
            pc = model.phase_seconds(p, survival)
            sa = model.survival_after(p, survival)
            score = pc + sa * rem_default[i]
            if best is None or score < best[0]:
                best = (score, p, pc, sa)
        _, p, pc, sa = best
        phases.append(p)
        cost += pc
        survival = sa
    phases.append(last)
    cost += model.phase_seconds(last, survival)
    return phases, cost


def plan_query(
    template: Template,
    stats: GraphStats,
    *,
    backend: Optional[str] = None,
    wave: int = 1024,
    policy=None,
    guarantee_precision: bool = True,
    label_freq: Optional[np.ndarray] = None,
    constraints: Optional[List[NonLocalConstraint]] = None,
) -> QueryPlan:
    """Enumerate sound plans, cost each, return the argmin.

    When reordering is unsound (no pinned complete phase) the heuristic plan
    comes back unchanged — `source == "heuristic"` — so callers can persist
    or skip it. Per-phase variant choice is greedy under the current
    survival estimate (the model is separable per phase given survival), and
    order choice is exhaustive over distinct cost classes."""
    if constraints is None:
        constraints = generate_constraints(
            template,
            label_freq=(label_freq if label_freq is not None
                        else stats.label_hist),
            guarantee_precision=guarantee_precision,
        )
    base = heuristic_plan(constraints)
    if backend is None:
        backend = jax.default_backend()
    model = _CostModel(template, stats, backend=backend, wave=wave,
                       policy=policy)
    if not reorder_is_sound(constraints):
        base.predicted_s, base.per_phase_s = model.plan_seconds(base.phases)
        return base
    last = PlanPhase(constraints[-1], ENGINE_TDS, "default")
    best_phases, best_cost = base.phases, None
    for order in enumerate_orders(model, constraints[:-1]):
        phases, cost = _greedy_variants(model, order, last)
        if best_cost is None or cost < best_cost:
            best_phases, best_cost = phases, cost
    # the heuristic order itself is always in the candidate set via its cost
    heur_cost, heur_per = model.plan_seconds(base.phases)
    if best_cost is None or heur_cost <= best_cost:
        base.predicted_s, base.per_phase_s = heur_cost, heur_per
        return base
    total, per = model.plan_seconds(best_phases)
    return QueryPlan(phases=best_phases, predicted_s=float(total),
                     source="planner", per_phase_s=per)


# --------------------------------------------------------- policy round-trip
def plan_to_entry(plan: QueryPlan, *,
                  measured_s: Optional[Dict[str, float]] = None):
    from repro.kernels.registry import PlanEntry

    per = plan.per_phase_s or [0.0] * len(plan.phases)
    return PlanEntry(
        phases=[{"sig": p.signature, "engine": p.engine,
                 "direction": p.direction, "predicted_s": float(s)}
                for p, s in zip(plan.phases, per)],
        predicted_s=float(plan.predicted_s),
        measured_s=dict(measured_s or {}),
    )


def entry_to_plan(entry, constraints: Sequence[NonLocalConstraint]
                  ) -> QueryPlan:
    """Rehydrate a cached PlanEntry against the constraints the template
    generates TODAY. Caller must have validated signatures match
    (registry.resolve_plan does)."""
    by_sig = {constraint_signature(c): c for c in constraints}
    phases = [
        PlanPhase(by_sig[str(p["sig"])],
                  str(p.get("engine", ENGINE_NLCC)),
                  str(p.get("direction", "default")))
        for p in entry.phases
    ]
    return QueryPlan(
        phases=phases, predicted_s=float(entry.predicted_s), source="policy",
        per_phase_s=[float(p.get("predicted_s", 0.0)) for p in entry.phases])


def record_plan(policy, template: Template, stats: GraphStats,
                plan: QueryPlan, *, backend: str,
                measured_s: Optional[Dict[str, float]] = None) -> None:
    """Write `plan` into a DispatchPolicy's plan table (caller persists)."""
    policy.set_plan(backend, plan_bucket(template, stats),
                    plan_to_entry(plan, measured_s=measured_s))


def resolve_query_plan(
    template: Template,
    constraints: Sequence[NonLocalConstraint],
    stats: GraphStats,
    *,
    backend: Optional[str] = None,
) -> Optional[QueryPlan]:
    """The serving/pipeline lookup: the active policy's cached plan for this
    (template, stats) bucket, validated against the current constraint
    signatures and the soundness gate. None → run the heuristic order."""
    from repro.kernels import registry

    entry = registry.resolve_plan(
        plan_bucket(template, stats),
        [constraint_signature(c) for c in constraints],
        backend=backend,
    )
    if entry is None:
        return None
    plan = entry_to_plan(entry, constraints)
    if plan.is_heuristic():
        return plan
    if not (plan.phases and plan.phases[-1].constraint.complete):
        # a non-default plan is sound only under the complete-last gate;
        # a cache written by a buggy/foreign tool must not bypass it
        return None
    return plan

"""Local Constraint Checking (paper §3/§4, Alg. 3 + 4).

One iteration, expressed as a dense edge sweep (the TPU adaptation of the
HavoqGT `alive` visitor wave):

  1. messages:   each active arc (u -> v) carries omega(u) — packed words on
                 the distributed path, boolean planes here,
  2. aggregate:  M[v, q'] = OR over active in-arcs of omega(u)[q']
                 C[v, q'] = #   over active in-arcs of omega(u)[q']   (counts,
                 only materialized for templates with same-label multiplicity),
  3. vertex elim: keep q in omega(v) iff every template neighbor q' of q is
                 covered by M[v] and per-label distinct-neighbor counts meet
                 the template's multiplicity (Alg. 3 line 16),
  4. edge elim:  arc stays iff endpoints stay and some template edge (qi, qj)
                 has qi in omega(u), qj in omega(v) (Alg. 3 line 9).

Iterated to fixpoint by `lcc_fixpoint` (Alg. 3's do-while). All shapes static;
jitted once per (graph, template) pair.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.graph.structs import DeviceGraph
from repro.graph import segment_ops
from repro.core.template import Template
from repro.core.state import PruneState


class TemplateDev:
    """Template constants staged to device once (static per pipeline run)."""

    def __init__(self, template: Template):
        self.n0 = template.n0
        self.adj0 = jnp.asarray(template.adjacency_matrix())  # bool[n0, n0]
        # multiplicity: req[q, l_idx] over the template's distinct neighbor labels
        mult = template.multiplicity_requirements()
        counted = sorted({l for q, c in mult.items() for l, k in c.items() if k >= 1})
        self.counted_labels = jnp.asarray(counted, dtype=jnp.int32) if counted else None
        req = np.zeros((template.n0, max(len(counted), 1)), dtype=np.int32)
        for q, c in mult.items():
            for li, l in enumerate(counted):
                req[q, li] = c.get(l, 0)
        self.req = jnp.asarray(req)  # int32[n0, C]
        # label_of_counted[q] -> bool[n0, C]: template vertex q' has counted label c
        has = np.zeros((template.n0, max(len(counted), 1)), dtype=bool)
        for q in range(template.n0):
            for li, l in enumerate(counted):
                has[q, li] = int(template.labels[q]) == l
        self.vertex_has_counted_label = jnp.asarray(has)  # bool[n0, C]
        self.needs_counts = bool(
            any(k >= 2 for c in mult.values() for k in c.values())
        )


def lcc_iteration(
    dg: DeviceGraph,
    tdev: TemplateDev,
    state: PruneState,
) -> Tuple[PruneState, jnp.ndarray]:
    """One LCC sweep. Returns (new_state, changed)."""
    n, n0 = state.omega.shape
    src, dst = dg.src, dg.dst

    # 1. messages over active arcs
    msgs = jnp.take(state.omega, src, axis=0) & state.edge_active[:, None]

    # 2a. OR aggregation: which template vertices are covered among v's neighbors
    M = segment_ops.segment_or_bool(msgs, dst, n)  # bool[n, n0]

    # 3. neighborhood requirement per candidate q: adj0[q] subseteq M[v]
    #    missing[v, q] = exists q' with adj0[q, q'] and not M[v, q']
    missing = (~M).astype(jnp.float32) @ tdev.adj0.T.astype(jnp.float32)  # [n, n0]
    ok = missing < 0.5

    if tdev.needs_counts:
        # 2b. distinct active neighbors per counted label:
        # neighbor u contributes to counted label c iff omega(u) intersects the
        # template vertices carrying label c.
        ind = (
            msgs.astype(jnp.float32) @ tdev.vertex_has_counted_label.astype(jnp.float32)
            > 0.5
        )  # bool[m, C]
        cnt = segment_ops.segment_sum(ind.astype(jnp.int32), dst, n)  # [n, C]
        meets = jnp.all(cnt[:, None, :] >= tdev.req[None, :, :], axis=-1)  # [n, n0]
        ok = ok & meets

    omega = state.omega & ok

    # 4. edge elimination: some template arc (qi -> qj) with qi in omega(u), qj in omega(v)
    side = omega.astype(jnp.float32) @ tdev.adj0.astype(jnp.float32)  # [n, n0]
    compat = jnp.sum(jnp.take(side, src, axis=0) * jnp.take(omega, dst, axis=0).astype(jnp.float32), axis=-1) > 0.5
    edge_active = state.edge_active & compat

    # a vertex with no active in-arc cannot match any q with degree >= 1
    has_edge = segment_ops.segment_or_bool(
        edge_active[:, None], dst, n
    )[:, 0]
    deg_pos = jnp.asarray(jnp.any(tdev.adj0, axis=1))  # [n0] template degree >= 1
    omega = omega & (~deg_pos[None, :] | has_edge[:, None])

    changed = jnp.logical_or(
        jnp.any(omega != state.omega), jnp.any(edge_active != state.edge_active)
    )
    return PruneState(omega=omega, edge_active=edge_active), changed


def lcc_iteration_packed(
    dg: DeviceGraph,
    tdev: TemplateDev,
    state: PruneState,
    blocked,
    force_pallas: bool = False,
) -> Tuple[PruneState, jnp.ndarray]:
    """One LCC sweep through the packed-word path (the bitset_spmm kernel on
    TPU; 8x fewer aggregation bytes than the boolean-plane reference).

    Falls back to the reference for templates needing same-label multiplicity
    counts (the OR kernel carries no counts)."""
    if tdev.needs_counts:
        return lcc_iteration(dg, tdev, state)
    from repro.core.state import pack_bits, unpack_bits
    from repro.kernels import ops as kops

    n, n0 = state.omega.shape
    packed = pack_bits(state.omega)
    m_packed = kops.bitset_or_aggregate(
        packed, dg.src, dg.dst, n, state.edge_active,
        blocked=blocked, force_pallas=force_pallas)
    M = unpack_bits(m_packed, n0)

    missing = (~M).astype(jnp.float32) @ tdev.adj0.T.astype(jnp.float32)
    omega = state.omega & (missing < 0.5)
    side = omega.astype(jnp.float32) @ tdev.adj0.astype(jnp.float32)
    compat = jnp.sum(
        jnp.take(side, dg.src, axis=0)
        * jnp.take(omega, dg.dst, axis=0).astype(jnp.float32), axis=-1) > 0.5
    edge_active = state.edge_active & compat
    has_edge = segment_ops.segment_or_bool(edge_active[:, None], dg.dst, n)[:, 0]
    deg_pos = jnp.asarray(jnp.any(tdev.adj0, axis=1))
    omega = omega & (~deg_pos[None, :] | has_edge[:, None])
    changed = jnp.logical_or(
        jnp.any(omega != state.omega), jnp.any(edge_active != state.edge_active))
    return PruneState(omega=omega, edge_active=edge_active), changed


def _fixpoint(iter_fn, state: PruneState, max_iters: int,
              stats: Optional[dict], extra_stat: Optional[str] = None
              ) -> PruneState:
    """Shared do-while driver: device while_loop so the whole fixpoint is a
    single XLA computation (one dispatch). `iter_fn(state) -> (state, changed)`."""

    def cond(carry):
        st, changed, it = carry
        return jnp.logical_and(changed, it < max_iters)

    def body(carry):
        st, _, it = carry
        st2, changed = iter_fn(st)
        return st2, changed, it + 1

    init = (state, jnp.asarray(True), jnp.asarray(0))
    final_state, _, iters = jax.lax.while_loop(cond, body, init)
    if stats is not None:
        stats["lcc_iterations"] = stats.get("lcc_iterations", 0) + int(iters)
        stats["lcc_calls"] = stats.get("lcc_calls", 0) + 1
        if extra_stat is not None:
            stats[extra_stat] = stats.get(extra_stat, 0) + 1
    return final_state


def lcc_fixpoint(
    dg: DeviceGraph,
    tdev: TemplateDev,
    state: PruneState,
    max_iters: int = 1000,
    stats: Optional[dict] = None,
) -> PruneState:
    """Iterate LCC to fixpoint (Alg. 3 do-while)."""
    return _fixpoint(
        lambda st: lcc_iteration(dg, tdev, st), state, max_iters, stats)


LCC_ROUTE = "prune.lcc"


def lcc_route_bucket(state: PruneState, dg: DeviceGraph):
    """Shape bucket for the packed-vs-unpacked LCC routing decision: vertex
    count and arc count dominate the sweep cost (the packed width is ~1 word
    for every template since n0 <= 64)."""
    from repro.kernels import registry
    return registry.shape_bucket(state.omega.shape[0], dg.m)


def lcc_resolved_route(
    state: PruneState,
    dg: DeviceGraph,
    tdev: TemplateDev,
    blocked,
    *,
    collect_stats: bool = False,
    force_pallas: bool = False,
) -> str:
    """The packed-vs-unpacked route the LCC fixpoint will actually take — the
    single source of truth for both execution (`lcc_fixpoint_packed`) and
    reporting (`prune`'s stats["dispatch_routes"]). Capability gates come
    first (no blocked structure, per-iteration message counting, or
    multiplicity counts force the boolean planes); within the packed-capable
    envelope force_pallas pins packed (parity tests) and otherwise the tuned
    policy decides, defaulting to packed — a caller passing `blocked` opted
    in, matching the pre-policy behavior."""
    from repro.kernels import registry

    if blocked is None or collect_stats or tdev.needs_counts:
        return registry.ROUTE_UNPACKED
    if force_pallas:
        return registry.ROUTE_PACKED
    return registry.resolve_route(
        LCC_ROUTE, lcc_route_bucket(state, dg),
        default=registry.ROUTE_PACKED,
        allowed=(registry.ROUTE_PACKED, registry.ROUTE_UNPACKED))


def lcc_fixpoint_packed(
    dg: DeviceGraph,
    tdev: TemplateDev,
    state: PruneState,
    blocked,
    max_iters: int = 1000,
    stats: Optional[dict] = None,
    force_pallas: bool = False,
) -> PruneState:
    """LCC fixpoint through the packed-word sweep (the bitset_spmm kernel via
    the registry dispatch on TPU, its oracle elsewhere).

    Degrades to the boolean-plane `lcc_fixpoint` when `lcc_resolved_route`
    says so: no blocked structure, same-label multiplicity counts (the OR
    kernel carries no counts), or the tuned dispatch policy routing this
    shape bucket to the unpacked sweep. `force_pallas` pins the packed
    kernel path for parity tests."""
    from repro.kernels import registry

    route = lcc_resolved_route(
        state, dg, tdev, blocked, force_pallas=force_pallas)
    if route == registry.ROUTE_UNPACKED:
        if stats is not None and blocked is not None and not tdev.needs_counts:
            stats["lcc_routed_unpacked"] = stats.get(
                "lcc_routed_unpacked", 0) + 1
        return lcc_fixpoint(dg, tdev, state, max_iters, stats)
    return _fixpoint(
        lambda st: lcc_iteration_packed(
            dg, tdev, st, blocked, force_pallas=force_pallas),
        state, max_iters, stats, extra_stat="lcc_packed_calls")

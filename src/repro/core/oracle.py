"""Brute-force exact-matching oracle (tree-search in the Ullmann tradition).

Serves two roles:
  1. correctness oracle for the property tests — the paper's central claim is
     100% precision AND 100% recall of the pruned solution subgraph, which we
     verify against this enumerator on small random graphs,
  2. the stand-in for the direct-enumeration competitor class (QFrag's
     TurboISO, Arabesque's TLE) in the comparison benchmarks — no external
     systems are available offline, so benchmarks compare pruning+enumeration
     against this tree search on the *unpruned* graph, which is exactly the
     algorithmic difference the paper measures.
"""
from __future__ import annotations

from typing import List, Optional, Set, Tuple

import numpy as np

from repro.graph.structs import Graph
from repro.core.template import Template


def enumerate_matches_bruteforce(
    g: Graph,
    template: Template,
    limit: Optional[int] = None,
    count_nodes: bool = False,
) -> List[Tuple[int, ...]]:
    """All embeddings phi: V0 -> V (Def. 1 (i)+(ii)). Backtracking with
    label pruning and adjacency checks. Returns list of tuples (phi(q0..))."""
    offsets, neighbors = g.csr()
    nbr_sets = [set() for _ in range(g.n)]
    for v in range(g.n):
        nbr_sets[v] = set(neighbors[offsets[v]:offsets[v + 1]].tolist())
    labels = g.labels
    t = template
    # order template vertices to keep partial assignments connected
    order = _connected_order(t)
    candidates = [np.flatnonzero(labels == t.labels[q]).tolist() for q in range(t.n0)]

    results: List[Tuple[int, ...]] = []
    assign = [-1] * t.n0
    used: Set[int] = set()
    steps = [0]

    def bt(i: int) -> bool:
        if limit is not None and len(results) >= limit:
            return True
        if i == len(order):
            results.append(tuple(assign))
            return False
        q = order[i]
        # anchored candidates: neighbors of an already-assigned template neighbor
        anchor = next((p for p in t.adj[q] if assign[p] >= 0), None)
        pool = candidates[q] if anchor is None else nbr_sets[assign[anchor]]
        for v in pool:
            steps[0] += 1
            if v in used or labels[v] != t.labels[q]:
                continue
            ok = True
            for p in t.adj[q]:
                if assign[p] >= 0 and assign[p] not in nbr_sets[v]:
                    ok = False
                    break
            if ok:
                assign[q] = v
                used.add(v)
                if bt(i + 1):
                    return True
                used.discard(v)
                assign[q] = -1
        return False

    bt(0)
    if count_nodes:
        return results, steps[0]  # type: ignore[return-value]
    return results


def _connected_order(t: Template) -> List[int]:
    if t.n0 == 1:
        return [0]
    order, seen = [0], {0}
    frontier = list(t.adj[0])
    while len(order) < t.n0:
        nxt = next((q for q in frontier if q not in seen), None)
        if nxt is None:  # disconnected template would have raised earlier
            nxt = next(q for q in range(t.n0) if q not in seen)
        order.append(nxt)
        seen.add(nxt)
        frontier.extend(t.adj[nxt])
    return order


def solution_subgraph_oracle(g: Graph, template: Template):
    """(vertex mask, arc mask over g's arc list) of the union of all matches."""
    matches = enumerate_matches_bruteforce(g, template)
    vmask = np.zeros(g.n, dtype=bool)
    ekeys: Set[int] = set()
    omega = np.zeros((g.n, template.n0), dtype=bool)
    for m in matches:
        for q, v in enumerate(m):
            vmask[v] = True
            omega[v, q] = True
        for a, b in template.edge_set:
            u, v = m[a], m[b]
            ekeys.add(u * g.n + v)
            ekeys.add(v * g.n + u)
    arc_keys = g.src.astype(np.int64) * g.n + g.dst
    emask = np.isin(arc_keys, np.asarray(sorted(ekeys), dtype=np.int64)) if ekeys else np.zeros(g.m, bool)
    return vmask, emask, omega, matches

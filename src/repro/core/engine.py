"""Unified execution-backend layer: the full constraint-checking pipeline on
sharded meshes.

One set of LCC-sweep / NLCC-wave / edge-elimination primitives is written
against a tiny collective interface (`Prims`: ``exchange`` = the bucketed
all_to_all, ``all_reduce_or`` / ``psum`` = the convergence and survivor
reductions, ``axis_index`` = which shard am I). Three backends execute them:

  local   today's single-device path — the identity exchange. Delegates to the
          optimized core/{lcc,nlcc,tds} routes (packed kernels, fused wave,
          dispatch-policy routing) since with P=1 every message is local.
  spmd    shard_map + ``jax.lax.all_to_all`` over an `EdgePartition` on a real
          mesh (or a host-platform-forced multi-device CPU). The whole LCC
          fixpoint and every NLCC wave run where the partitioned state lives;
          convergence flags are psum-reduced on device.
  sim     the SAME per-shard programs under ``jax.vmap(..., axis_name=...)``
          — vmap's collective rules turn the all_to_all into a transpose, so
          single-process tests prove the distributed math equals the
          single-device engine bit-for-bit on any shard count.

The spmd and sim backends share every line of program code; only the wrapper
differs (shard_map vs vmap). This file absorbs what used to be
core/distributed.py (a stranded second implementation of the LCC math with no
NLCC verification, no TDS, and no wave executor).

Sharded NLCC waves are routed per shard-local shape by the tuned dispatch
policy (`registry.resolve_route` with `registry.shard_bucket` keys):

  fused     one program dispatch per wave — the hop loop is a lax.scan over
            the candidacy stack, packed uint32 frontier words throughout
            (the sharded analogue of the bitset_wave kernel). Gated by the
            same resident-bytes eligibility rule as the kernel, evaluated on
            SHARD-LOCAL shapes (`sharded_fused_eligible`).
  packed    one program dispatch per hop, packed words on the wire.
  unpacked  one dispatch per hop, boolean token planes (32x the exchange
            bytes; the parity/debug route).

All three compute identical survivors; the parity suite
(tests/test_sharded_engine.py) pins prune() on 1/2/4/8 shards bit-for-bit
against the local engine across cyclic, path, and TDS-bearing templates.

TDS constraints (and the beyond-paper frontier edge-prune pass) are host-side
row-table joins over the *already heavily pruned* G in every backend; on the
sharded backends they run through an explicit gather -> verify -> scatter
bridge (`gather_state`/`scatter_state`), which keeps them bit-identical to the
local engine by construction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.graph.structs import Graph, DeviceGraph
from repro.graph.partition import EdgePartition, partition_graph
from repro.graph.segment_ops import SegmentMeta, segment_or
from repro.core.state import PruneState, init_state, pack_bits, unpack_bits, packed_words
from repro.core.lcc import TemplateDev
from repro.core.template import Template, NonLocalConstraint

SHARD_AXIS = "shards"


# ---------------------------------------------------------------------------
# The collective interface every sharded program is written against
# ---------------------------------------------------------------------------
class Prims(NamedTuple):
    """The collective primitives of one execution backend."""

    exchange: Callable  # [P*B, W] per-shard send buckets -> received buckets
    all_reduce_or: Callable  # bool scalar -> OR over shards (convergence)
    psum: Callable  # int array -> sum over shards (wave survivors)
    axis_index: Callable  # () -> this shard's index
    # [P, Br, C] keyed row buckets (leading axis = destination shard) ->
    # received buckets (slice q = what shard q sent here). The distributed-
    # rows join routes pow2-padded row blocks by frontier-vertex owner
    # through this instead of psum-combining full-width slot tensors.
    exchange_rows: Callable
    # overlap(step, carry, max_iters) -> (carry, iters): the software-
    # pipelined fixpoint. `step: carry -> (carry, changed)`. On the sharded
    # backends convergence is checked on a LAGGED all_reduce_or — iteration
    # i's flag gates iteration i+2, so the reduction is in flight while the
    # next iteration computes. Sound for monotone sweeps: the (at most one)
    # extra iteration past the fixpoint is a no-op by definition of the
    # change flag.
    overlap: Callable


def _exchange_rows_over(axis_name: str) -> Callable:
    """Keyed row exchange over a named axis: the same bucketed all_to_all as
    `exchange`, shaped for [P, Br, C] row blocks (bucket q -> shard q)."""

    def xr(x: jnp.ndarray) -> jnp.ndarray:
        flat = x.reshape((x.shape[0] * x.shape[1],) + x.shape[2:])
        out = jax.lax.all_to_all(flat, axis_name, 0, 0, tiled=True)
        return out.reshape(x.shape)

    return xr


def _overlap_lagged(all_reduce_or: Callable) -> Callable:
    """The lagged-convergence pipelined fixpoint: each iteration issues the
    reduction of the PREVIOUS iteration's change flag before computing, so
    the collective overlaps the sweep instead of fencing it. Converges one
    (idempotent) iteration later than the eager schedule."""

    def overlap(step: Callable, carry, max_iters: int = 1000):
        def cond(c):
            _, pending, _, it = c
            return jnp.logical_and(pending, it < max_iters)

        def body(c):
            carry, _pending, ch_prev, it = c
            pending = all_reduce_or(ch_prev)  # lagged: flag of iteration i-1
            carry2, ch = step(carry)
            return carry2, pending, ch, it + 1

        carry, _, _, it = jax.lax.while_loop(
            cond, body,
            (carry, jnp.asarray(True), jnp.asarray(True), jnp.asarray(0)))
        return carry, it

    return overlap


def _overlap_eager(step: Callable, carry, max_iters: int = 1000):
    """P=1 pipelining degenerates to the eager do-while (reductions are
    identities, there is nothing to overlap — and nothing to lag)."""

    def cond(c):
        _, ch, it = c
        return jnp.logical_and(ch, it < max_iters)

    def body(c):
        carry, _, it = c
        carry2, ch = step(carry)
        return carry2, ch, it + 1

    carry, _, it = jax.lax.while_loop(
        cond, body, (carry, jnp.asarray(True), jnp.asarray(0)))
    return carry, it


def axis_prims(axis_name: str = SHARD_AXIS) -> Prims:
    """Prims over a named axis — valid under BOTH shard_map (spmd) and
    vmap-with-axis-name (sim); jax lowers the same collectives either way."""
    all_reduce_or = lambda f: jax.lax.psum(f.astype(jnp.int32), axis_name) > 0
    return Prims(
        exchange=lambda x: jax.lax.all_to_all(x, axis_name, 0, 0, tiled=True),
        all_reduce_or=all_reduce_or,
        psum=lambda x: jax.lax.psum(x, axis_name),
        axis_index=lambda: jax.lax.axis_index(axis_name),
        exchange_rows=_exchange_rows_over(axis_name),
        overlap=_overlap_lagged(all_reduce_or),
    )


def local_prims() -> Prims:
    """The identity exchange (P=1): every bucket is local, reductions are
    no-ops. The degenerate case the local backend embodies."""
    return Prims(
        exchange=lambda x: x,
        all_reduce_or=lambda f: f,
        psum=lambda x: x,
        axis_index=lambda: jnp.asarray(0, jnp.int32),
        exchange_rows=lambda x: x,
        overlap=_overlap_eager,
    )


# ---------------------------------------------------------------------------
# Shared partition-sweep math (absorbed from core/distributed.py)
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class ShardArrays:
    """Per-shard static partition arrays (local views, leading shard axis removed)."""

    send_src_local: jnp.ndarray  # int32[P, B]
    send_pad: jnp.ndarray  # bool[P, B]
    twin_recv_flat: jnp.ndarray  # int32[P, B]
    recv_perm: jnp.ndarray  # int32[P*B]
    recv_sorted_dst_local: jnp.ndarray  # int32[P*B]
    recv_is_start: jnp.ndarray  # bool[P*B]
    recv_last_edge: jnp.ndarray  # int32[n_local]
    labels_local: jnp.ndarray  # int32[n_local]
    vertex_valid: jnp.ndarray  # bool[n_local]


jax.tree_util.register_dataclass(ShardArrays)


class TemplateMasks:
    """Packed template constants for the sharded sweep."""

    def __init__(self, tdev: TemplateDev):
        self.n0 = tdev.n0
        self.adj0 = tdev.adj0.astype(jnp.float32)  # [n0, n0]
        self.needs_counts = tdev.needs_counts
        self.req = tdev.req
        self.vertex_has_counted_label = tdev.vertex_has_counted_label.astype(jnp.float32)


def _aggregate_or(recv: jnp.ndarray, sa: ShardArrays, n_local: int) -> jnp.ndarray:
    sortedv = jnp.take(recv, sa.recv_perm, axis=0)
    meta = SegmentMeta(is_start=sa.recv_is_start, last_edge_of_vertex=sa.recv_last_edge)
    return segment_or(sortedv, meta, n_local)  # [n_local, W]


def lcc_shard_iteration(
    omega: jnp.ndarray,  # uint32[n_local+1, W]
    edge_active: jnp.ndarray,  # bool[P, B]
    sa: ShardArrays,
    tm: TemplateMasks,
    prims: Prims,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One LCC sweep: gather local omega over the static send buckets, mask by
    per-arc active bits, ONE exchange (the only collective), then the static
    dst-sorted permutation + segmented OR on the receive side."""
    n_local = omega.shape[0] - 1
    W = omega.shape[1]
    send_mask = edge_active & ~sa.send_pad
    msgs = jnp.take(omega, sa.send_src_local, axis=0)  # [P, B, W]
    msgs = jnp.where(send_mask[..., None], msgs, jnp.uint32(0))
    recv = prims.exchange(msgs.reshape(-1, W))  # [P*B, W]
    return _lcc_from_recv(omega, edge_active, recv, sa, tm)


def lcc_shard_fixpoint(
    omega: jnp.ndarray,
    edge_active: jnp.ndarray,
    sa: ShardArrays,
    tm: TemplateMasks,
    prims: Prims,
    max_iters: int = 1000,
):
    """The LCC do-while as one on-device while_loop, scheduled by the
    backend's `overlap` combinator: on the sharded backends the convergence
    psum is LAGGED one iteration behind the sweep it gates, so the reduction
    is in flight while the next sweep computes instead of fencing it. The
    sweep is monotone (omega / edge bits only clear), so the one extra
    iteration past the fixpoint recomputes the fixpoint — a no-op."""

    def step(c):
        om, ea = c
        om2, ea2, ch = lcc_shard_iteration(om, ea, sa, tm, prims)
        return (om2, ea2), ch

    (om, ea), it = prims.overlap(step, (omega, edge_active), max_iters)
    return om, ea, it


def _lcc_from_recv(omega, edge_active, recv, sa: ShardArrays, tm: TemplateMasks):
    """lcc_shard_iteration with the exchange already performed (shared math).

    Edge elimination reads the twin arc's omega out of the *same* receive
    buffer (`twin_recv_flat`) — no extra collective."""
    n_local = omega.shape[0] - 1
    W = omega.shape[1]
    send_mask = edge_active & ~sa.send_pad

    M_packed = _aggregate_or(recv, sa, n_local)
    M = unpack_bits(M_packed, tm.n0)
    omega_bits = unpack_bits(omega[:n_local], tm.n0)
    missing = (~M).astype(jnp.float32) @ tm.adj0.T
    ok = missing < 0.5
    if tm.needs_counts:
        rbits = unpack_bits(jnp.take(recv, sa.recv_perm, axis=0), tm.n0)
        ind = (rbits.astype(jnp.float32) @ tm.vertex_has_counted_label) > 0.5
        cnt = jax.ops.segment_sum(
            ind.astype(jnp.int32),
            jnp.minimum(sa.recv_sorted_dst_local, n_local),
            num_segments=n_local + 1, indices_are_sorted=True,
        )[:n_local]
        ok = ok & jnp.all(cnt[:, None, :] >= tm.req[None, :, :], axis=-1)
    new_bits = omega_bits & ok & sa.vertex_valid[:, None]
    deg_pos = jnp.any(tm.adj0 > 0.5, axis=1)
    new_bits = new_bits & (~deg_pos[None, :] | jnp.any(M, axis=1)[:, None])

    recv_sink = jnp.concatenate([recv, jnp.zeros((1, W), jnp.uint32)], axis=0)
    dst_words = jnp.take(recv_sink, sa.twin_recv_flat, axis=0)
    src_bits = unpack_bits(jnp.take(omega, sa.send_src_local, axis=0), tm.n0)
    dst_bits = unpack_bits(dst_words, tm.n0)
    side = src_bits.astype(jnp.float32) @ tm.adj0
    compat_ = jnp.sum(side * dst_bits.astype(jnp.float32), axis=-1) > 0.5
    ea_new = send_mask & compat_
    omega_new = jnp.concatenate([pack_bits(new_bits), jnp.zeros((1, W), jnp.uint32)], axis=0)
    changed = jnp.any(omega_new != omega) | jnp.any(ea_new != edge_active)
    return omega_new, ea_new, changed


def frontier_shard_hop(
    frontier: jnp.ndarray,  # uint32[n_local+1, Wf] packed token words
    edge_active: jnp.ndarray,  # bool[P, B]
    sa: ShardArrays,
    cand_next: jnp.ndarray,  # bool[n_local] candidacy of the next walk vertex
    prims: Prims,
) -> jnp.ndarray:
    """One NLCC token hop (paper Alg. 6 forward) on packed multi-source words."""
    n_local = frontier.shape[0] - 1
    Wf = frontier.shape[1]
    send_mask = edge_active & ~sa.send_pad
    msgs = jnp.take(frontier, sa.send_src_local, axis=0)
    msgs = jnp.where(send_mask[..., None], msgs, jnp.uint32(0))
    recv = prims.exchange(msgs.reshape(-1, Wf))
    agg = _aggregate_or(recv, sa, n_local)
    nxt = jnp.where(cand_next[:, None], agg, jnp.uint32(0))
    return jnp.concatenate([nxt, jnp.zeros((1, Wf), jnp.uint32)], axis=0)


def frontier_shard_hop_unpacked(
    frontier: jnp.ndarray,  # bool[n_local+1, S] token planes
    edge_active: jnp.ndarray,  # bool[P, B]
    sa: ShardArrays,
    cand_next: jnp.ndarray,  # bool[n_local]
    prims: Prims,
) -> jnp.ndarray:
    """The boolean-plane hop: same sweep, 32x the exchange bytes (uint8 on the
    wire — collectives do not carry packed semantics for bools)."""
    n_local = frontier.shape[0] - 1
    S = frontier.shape[1]
    send_mask = edge_active & ~sa.send_pad
    msgs = jnp.take(frontier, sa.send_src_local, axis=0) & send_mask[..., None]
    recv = prims.exchange(msgs.reshape(-1, S).astype(jnp.uint8)).astype(bool)
    agg = _aggregate_or(recv, sa, n_local)
    nxt = agg & cand_next[:, None]
    return jnp.concatenate([nxt, jnp.zeros((1, S), bool)], axis=0)


def init_sharded_state(part: EdgePartition, template) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """omega_all uint32[P, n_local+1, W] from labels (last row = padding sink);
    edge_active_all bool[P, P, B] (real arcs active)."""
    n0 = template.n0
    W = packed_words(n0)
    n_labels = int(max(template.labels.max() + 1, part.labels_local.max() + 1))
    lm = template.label_matrix(n_labels)  # [n0, L]
    bits = lm.T[np.asarray(part.labels_local)]  # [P, n_local, n0]
    bits &= np.asarray(part.vertex_valid)[..., None]
    omega = np.asarray(pack_bits(jnp.asarray(bits)))
    omega = np.concatenate([omega, np.zeros((part.P, 1, W), np.uint32)], axis=1)
    return jnp.asarray(omega), jnp.asarray(~part.send_pad)


# ---------------------------------------------------------------------------
# Sharded NLCC wave programs (per-shard bodies; wrapped by the backends)
# ---------------------------------------------------------------------------
def _owner_local(source_ids: jnp.ndarray, n_local: int, p) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Map global wave-source ids to this shard's local rows; non-owned and
    padded (-1) sources land on the padding-sink row n_local."""
    valid = source_ids >= 0
    owner = jnp.where(valid, source_ids // n_local, -1)
    local = jnp.where(owner == p, source_ids % n_local, n_local)
    return local, valid


def _seed_frontier_planes(cand0, source_ids, n_local: int, p) -> jnp.ndarray:
    """F_0 token planes bool[n_local+1, S]: one plane per wave source, seeded
    at candidate sources on their owner shard."""
    S = source_ids.shape[0]
    local, valid = _owner_local(source_ids, n_local, p)
    cand0x = jnp.concatenate([cand0, jnp.zeros((1,), bool)])
    seed = valid & jnp.take(cand0x, local)
    f = jnp.zeros((n_local + 1, S), bool)
    return f.at[local, jnp.arange(S)].set(seed)


def _sharded_wave_survivors(
    planes: jnp.ndarray,  # bool[n_local+1, S] hop-L token planes
    source_ids: jnp.ndarray,  # int32[S], -1 = pad
    n_local: int,
    is_cyclic: bool,
    prims: Prims,
) -> jnp.ndarray:
    """CC: token returned to its source. PC: the paper's `ack` — token reached
    some vertex other than its source. Per-shard partials are psum-combined so
    the decision is replicated without leaving the device."""
    S = source_ids.shape[0]
    p = prims.axis_index()
    local, valid = _owner_local(source_ids, n_local, p)
    self_bits = planes[local, jnp.arange(S)].astype(jnp.int32)  # pad row -> 0
    self_tot = prims.psum(self_bits)
    if is_cyclic:
        return (self_tot > 0) & valid
    cnt_tot = prims.psum(jnp.sum(planes[:n_local].astype(jnp.int32), axis=0))
    return (cnt_tot > 0) & (cnt_tot > self_tot) & valid


def _scatter_keep(keep_col, survived, source_ids, n_local: int, p):
    """OR the replicated survivor bits into this shard's keep column; pads and
    non-owned sources hit the padding-sink row (max cannot unset)."""
    local, _ = _owner_local(source_ids, n_local, p)
    return keep_col.at[local].max(survived)


def sharded_fused_resident_bytes(n_local: int, Pn: int, B: int, wave: int, L: int) -> int:
    """Per-shard resident working set of the fused (single-dispatch) wave: the
    ping/pong frontier + aggregate words, the exchange receive buffer, and the
    candidacy stack — the shard-local analogue of the bitset_wave kernel's
    VMEM accounting."""
    Wf = max(wave // 32, 1)
    return (
        3 * (n_local + 1) * Wf * 4  # frontier in/out + aggregate
        + Pn * B * Wf * 4           # exchange receive buffer
        + (L + 1) * n_local         # candidacy stack (bool)
    )


def sharded_fused_eligible(n_local: int, Pn: int, B: int, wave: int, L: int) -> bool:
    """The bitset_wave eligibility gate composed with shard-local shapes: the
    fused route only runs where its resident state fits the same budget the
    kernel enforces (`ops.BITSET_WAVE_VMEM_BUDGET`)."""
    from repro.kernels import ops as kops

    return sharded_fused_resident_bytes(n_local, Pn, B, wave, L) <= kops.BITSET_WAVE_VMEM_BUDGET


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------
class LocalBackend:
    """Today's single-device path: the identity exchange. Delegates to the
    optimized core/{lcc,nlcc,tds} implementations — packed kernels, the fused
    bitset_wave engine, and dispatch-policy routing all compose here."""

    name = "local"

    def __init__(
        self,
        dg: DeviceGraph,
        template: Template,
        *,
        wave: int = 1024,
        blocked=None,
        force_pallas: bool = False,
        edge_elimination: bool = True,
        collect_stats: bool = False,
        nlcc_edge_prune: bool = False,
        tds_chunk: int = 4096,
        tds_max_rows: int = 2_000_000,
        work_aggregation: bool = True,
        guarantee_precision: bool = True,
        injector=None,
    ):
        self.dg = dg
        self.template = template
        self.tdev = TemplateDev(template)
        self.wave = wave
        self.blocked = blocked
        self.injector = injector
        self.force_pallas = force_pallas
        self.edge_elimination = edge_elimination
        self.collect_stats = collect_stats
        self.nlcc_edge_prune = nlcc_edge_prune
        self.tds_chunk = tds_chunk
        self.tds_max_rows = tds_max_rows
        self.work_aggregation = work_aggregation
        self.guarantee_precision = guarantee_precision
        self.state: Optional[PruneState] = None

    # -- state
    def init(self, initial_state: Optional[PruneState]) -> None:
        self.state = initial_state if initial_state is not None else init_state(
            self.dg, self.template)

    def final_state(self) -> PruneState:
        return self.state

    def snapshot(self):
        """In-memory device snapshot for the degradation ladder's retry rung
        (jnp arrays are immutable — holding the references is enough)."""
        return self.state

    def restore_snapshot(self, snap) -> None:
        self.state = snap

    def _fire(self, site: str, **ctx) -> None:
        if self.injector is not None:
            self.injector.event(site, **ctx)

    # -- reporting
    def record_routes(self, stats: Dict) -> None:
        if self.blocked is None:
            return
        from repro.kernels import registry as _registry
        from repro.core.lcc import LCC_ROUTE, lcc_resolved_route
        from repro.core.nlcc import NLCC_ROUTE, nlcc_resolved_route

        stats["dispatch_routes"] = {
            # the Fig-6a ablation (_lcc_no_edge_elim) never reaches the
            # packed path, whatever the policy says
            LCC_ROUTE: (_registry.ROUTE_UNPACKED if not self.edge_elimination
                        else lcc_resolved_route(
                self.state, self.dg, self.tdev, self.blocked,
                collect_stats=self.collect_stats,
                force_pallas=self.force_pallas)),
            NLCC_ROUTE: nlcc_resolved_route(
                self.state, self.wave, self.blocked,
                count_messages=self.collect_stats,
                force_pallas=self.force_pallas),
        }
        stats["dispatch_policy_active"] = _registry.get_policy() is not None

    def counts_dev(self) -> jnp.ndarray:
        """[active_vertices, active_edges, omega_bits] as one device vector —
        phase snapshots accumulate these lazily (no per-phase host sync)."""
        om, ea = self.state.omega, self.state.edge_active
        return jnp.stack([
            jnp.sum(jnp.any(om, axis=1), dtype=jnp.int32),
            jnp.sum(ea, dtype=jnp.int32),
            jnp.sum(om, dtype=jnp.int32),
        ])

    def counts_host(self) -> Dict[str, int]:
        return self.state.counts()

    def sync(self) -> None:
        """Fence the device stream (no transfer): phase wall-times must
        include the phase's own device work even though snapshot counts stay
        lazy."""
        jax.block_until_ready((self.state.omega, self.state.edge_active))

    def finalize_stats(self, stats: Dict) -> None:
        """Local routes are resolved once up front (`record_routes` is the
        single source of truth shared with execution) — nothing to amend."""

    # -- phases
    def lcc(self, stats: Dict) -> None:
        from repro.core.lcc import lcc_fixpoint, lcc_fixpoint_packed, lcc_iteration

        self._fire("lcc")
        dg, tdev, state = self.dg, self.tdev, self.state
        if not self.edge_elimination:
            self.state = self._lcc_no_edge_elim(stats)
            return
        if self.blocked is not None and not self.collect_stats and not tdev.needs_counts:
            self.state = lcc_fixpoint_packed(
                dg, tdev, state, self.blocked, stats=stats,
                force_pallas=self.force_pallas)
            return
        if self.collect_stats:
            # python loop to count per-iteration messages (active arcs at send time)
            it = 0
            while True:
                stats["lcc_messages"] = stats.get("lcc_messages", 0) + int(
                    jnp.sum(state.edge_active))
                new_state, changed = lcc_iteration(dg, tdev, state)
                it += 1
                state = new_state
                if not bool(changed) or it > 1000:
                    break
            stats["lcc_iterations"] = stats.get("lcc_iterations", 0) + it
            self.state = state
            return
        self.state = lcc_fixpoint(dg, tdev, state, stats=stats)

    def _lcc_no_edge_elim(self, stats: Dict) -> PruneState:
        """Vertex-elimination-only LCC (Fig. 6a baseline): edges stay active
        while both endpoints are active, regardless of label compatibility."""
        from repro.core.lcc import lcc_iteration

        dg, tdev, state = self.dg, self.tdev, self.state
        it = 0
        while True:
            new_state, changed = lcc_iteration(dg, tdev, state)
            vact = jnp.any(new_state.omega, axis=1)
            ea = jnp.take(vact, dg.src) & jnp.take(vact, dg.dst)
            new_state = PruneState(omega=new_state.omega, edge_active=ea)
            changed = jnp.any(new_state.omega != state.omega) | jnp.any(
                new_state.edge_active != state.edge_active
            )
            state = new_state
            it += 1
            stats["lcc_messages"] = stats.get("lcc_messages", 0) + int(jnp.sum(ea))
            if not bool(changed) or it > 1000:
                break
        stats["lcc_iterations"] = stats.get("lcc_iterations", 0) + it
        return state

    def nlcc(self, c: NonLocalConstraint, cstats: Dict,
             direction: str = "default"):
        from repro.core import nlcc as nlcc_mod

        self._fire("nlcc")
        before = self.state
        self.state = nlcc_mod.verify_constraint(
            self.dg, before, c, self.template.labels, wave=self.wave,
            stats=cstats, count_messages=self.collect_stats,
            edge_prune=self.nlcc_edge_prune, template=self.template,
            blocked=self.blocked, force_pallas=self.force_pallas,
            direction=direction,
        )
        return _state_changed(before, self.state)

    def tds(self, c: NonLocalConstraint, cstats: Dict):
        from repro.core import tds as tds_mod

        self._fire("tds")
        before = self.state
        self.state = tds_mod.verify_tds_constraint(
            self.dg, before, c, chunk=self.tds_chunk,
            max_rows=self.tds_max_rows, stats=cstats,
            annotate=(c.complete and self.guarantee_precision),
            dedup=self.work_aggregation,
        )
        return _state_changed(before, self.state)


def _state_changed(before: PruneState, after: PruneState) -> jnp.ndarray:
    """Device-side change flag: omega/edge bits are monotone decreasing, so a
    bitwise compare is exactly the old counts-based `after != before` check —
    one device bool instead of six blocking count reads."""
    return jnp.any(before.omega != after.omega) | jnp.any(
        before.edge_active != after.edge_active)


class _ShardedBackend:
    """Shared machinery of the spmd and sim backends: state layout, the
    gather/scatter bridge, the wave executor, and the program cache. The only
    subclass hook is `_make(program, n_sharded)` — how a per-shard program is
    wrapped into a callable over global [P, ...] arrays."""

    name = "sharded"

    def __init__(
        self,
        graph: Graph,
        dg: DeviceGraph,
        template: Template,
        part: EdgePartition,
        *,
        wave: int = 1024,
        collect_stats: bool = False,
        nlcc_edge_prune: bool = False,
        tds_chunk: int = 4096,
        tds_max_rows: int = 2_000_000,
        work_aggregation: bool = True,
        guarantee_precision: bool = True,
        edge_elimination: bool = True,
        arc_order: Optional[np.ndarray] = None,
        injector=None,
    ):
        if not edge_elimination:
            raise ValueError(
                "edge_elimination=False (the Fig-6a ablation) is a "
                "local-backend-only mode; run it without mesh=/partition=")
        if part.arc_flat_slot is None:
            raise ValueError(
                "EdgePartition lacks arc_flat_slot (built by an old "
                "partition_graph?); rebuild the partition")
        self.dg = dg
        self.template = template
        self.tdev = TemplateDev(template)
        self.tm = TemplateMasks(self.tdev)
        self.part = part
        self.P = part.P
        self.B = part.B
        self.n_local = part.n_local
        self.wave = wave
        self.collect_stats = collect_stats
        self.nlcc_edge_prune = nlcc_edge_prune
        self.tds_chunk = tds_chunk
        self.tds_max_rows = tds_max_rows
        self.work_aggregation = work_aggregation
        self.guarantee_precision = guarantee_precision
        self.arrs = part.device_arrays()
        # per-arc slot of the DeviceGraph's dst-sorted arcs inside the
        # flattened [P, P, B] bucket tensor — the edge_active gather/scatter
        # map (`arc_order` = the dst-sort permutation the caller already
        # computed building the DeviceGraph; avoids a second O(m log m) sort)
        order = (arc_order if arc_order is not None
                 else DeviceGraph.dst_sort_order(graph))
        if part.P * part.P * part.B >= 2**31:
            # the device-side map below is int32 (x64 is off by default); a
            # bucket tensor past 2^31 slots would silently wrap — refuse
            raise NotImplementedError(
                f"bucket tensor has {part.P * part.P * part.B} >= 2^31 slots;"
                " the int32 edge gather/scatter map would overflow — shard"
                " the graph coarser or add a 64-bit map")
        self._arc_slot = jnp.asarray(part.arc_flat_slot[order], jnp.int32)
        self._fns: Dict[Any, Callable] = {}
        self._nlcc_routes_taken: set = set()
        self.omega_all: Optional[jnp.ndarray] = None
        self.ea_all: Optional[jnp.ndarray] = None
        self.injector = injector

    # -- resilience seam ----------------------------------------------------
    def _fire(self, site: str, **ctx) -> None:
        """Host-seam fault-injection point: the sharded programs are pure
        jitted collectives, so simulated failures fire between device
        dispatches — exactly where a real rank loss would surface."""
        if self.injector is not None:
            self.injector.event(site, **ctx)

    def _prims(self) -> Prims:
        """The collective bundle, wrapped for trace-time accounting (and
        prim-seam injection) when a fault injector is attached."""
        p = axis_prims(SHARD_AXIS)
        if self.injector is not None:
            from repro.core import resilience as _res

            p = _res.instrument_prims(p, self.injector)
        return p

    def snapshot(self):
        """Phase-entry device snapshot for in-place retry (immutable jnp
        arrays: two references, no copy)."""
        return (self.omega_all, self.ea_all)

    def restore_snapshot(self, snap) -> None:
        self.omega_all, self.ea_all = snap

    # -- wrapper hook -------------------------------------------------------
    def _make(self, program: Callable, n_sharded: int) -> Callable:
        raise NotImplementedError

    def _fn(self, key, program: Callable, n_sharded: int) -> Callable:
        if key not in self._fns:
            self._fns[key] = self._make(program, n_sharded)
        return self._fns[key]

    # -- state --------------------------------------------------------------
    def init(self, initial_state: Optional[PruneState]) -> None:
        if initial_state is None:
            self.omega_all, self.ea_all = init_sharded_state(self.part, self.template)
        else:
            self.omega_all, self.ea_all = self.scatter_state(initial_state)

    def gather_state(self) -> PruneState:
        """Global PruneState (dst-sorted DeviceGraph arc order) from the
        sharded arrays — the bridge TDS / edge-prune / the final result use."""
        n, n0 = self.part.n, self.tdev.n0
        flat = self.omega_all[:, :self.n_local].reshape(self.P * self.n_local, -1)
        omega = unpack_bits(flat, n0)[:n]
        ea = jnp.take(self.ea_all.reshape(-1), self._arc_slot)
        return PruneState(omega=omega, edge_active=ea)

    def scatter_state(self, state: PruneState) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Inverse of gather_state: block-partition a global PruneState."""
        n, n0 = self.part.n, self.tdev.n0
        W = packed_words(n0)
        bits = jnp.asarray(state.omega, bool)
        pad = self.P * self.n_local - n
        if pad:
            bits = jnp.concatenate([bits, jnp.zeros((pad, n0), bool)], axis=0)
        omega = pack_bits(bits).reshape(self.P, self.n_local, W)
        omega = jnp.concatenate(
            [omega, jnp.zeros((self.P, 1, W), jnp.uint32)], axis=1)
        ea_flat = jnp.zeros((self.P * self.P * self.B,), bool)
        ea_flat = ea_flat.at[self._arc_slot].set(jnp.asarray(state.edge_active, bool))
        return omega, ea_flat.reshape(self.P, self.P, self.B)

    def final_state(self) -> PruneState:
        return self.gather_state()

    # -- reporting ----------------------------------------------------------
    def record_routes(self, stats: Dict) -> None:
        from repro.kernels import registry
        from repro.core.nlcc import NLCC_ROUTE
        from repro.core.lcc import LCC_ROUTE

        stats["dispatch_routes"] = {
            # the partition exchange layout is packed words by construction.
            # prune.nlcc starts as the a-priori estimate for a 3-hop wave;
            # finalize_stats overwrites it with the route(s) actually taken
            # once the constraint lengths are known (the fused eligibility
            # gate depends on L)
            LCC_ROUTE: registry.ROUTE_PACKED,
            NLCC_ROUTE: self._nlcc_route(),
        }
        stats["dispatch_policy_active"] = registry.get_policy() is not None
        stats["sharded"] = {
            "backend": self.name,
            "P": self.P,
            "bucket": registry.bucket_key(
                registry.shard_bucket(self.P, self.n_local, self.wave)),
        }

    def counts_dev(self) -> jnp.ndarray:
        om = self.omega_all[:, :self.n_local]
        return jnp.stack([
            jnp.sum(jnp.any(om != 0, axis=-1), dtype=jnp.int32),
            jnp.sum(self.ea_all, dtype=jnp.int32),
            jnp.sum(jax.lax.population_count(om).astype(jnp.int32), dtype=jnp.int32),
        ])

    def shard_counts_dev(self) -> jnp.ndarray:
        """int32[P, 2] per-shard [active vertices, active arcs], computed
        SHARD-LOCALLY: vertices from each shard's omega block, arcs from each
        shard's send buckets (every arc lives at its src shard; padding slots
        are never active). No exchange, no full gather — the phase-boundary
        imbalance trigger reads this with one small transfer. Post-LCC an
        active arc already implies both endpoints active and compatible, so
        these equal the host oracle's endpoint-masked counts
        (loadbalance.imbalance_stats) at every phase boundary."""
        om = self.omega_all[:, :self.n_local]
        v = jnp.sum(jnp.any(om != 0, axis=-1), axis=-1, dtype=jnp.int32)
        e = jnp.sum(self.ea_all, axis=(1, 2), dtype=jnp.int32)
        return jnp.stack([v, e], axis=-1)

    def counts_host(self) -> Dict[str, int]:
        c = np.asarray(self.counts_dev())
        return {"active_vertices": int(c[0]), "active_edges": int(c[1]),
                "omega_bits": int(c[2])}

    def sync(self) -> None:
        """Fence the device stream (no transfer) so phase wall-times include
        the phase's own device work."""
        jax.block_until_ready((self.omega_all, self.ea_all))

    def finalize_stats(self, stats: Dict) -> None:
        """Replace the a-priori prune.nlcc route estimate with the route(s)
        the wave executor actually took (constraints of different walk
        lengths can resolve differently through the fused eligibility gate;
        multiple distinct routes render joined, e.g. "fused+packed"). A run
        whose constraints never reached the wave executor (TDS-only) reports
        "none" — never a route that did not execute."""
        if "dispatch_routes" in stats:
            from repro.core.nlcc import NLCC_ROUTE

            stats["dispatch_routes"][NLCC_ROUTE] = (
                "+".join(sorted(self._nlcc_routes_taken))
                if self._nlcc_routes_taken else "none")

    # -- LCC ----------------------------------------------------------------
    def lcc(self, stats: Dict) -> None:
        self._fire("lcc")
        tm, n_local = self.tm, self.n_local
        prims = self._prims()

        def program(sa_dict, omega, ea):
            sa = ShardArrays(**sa_dict)
            om, ea2, it = lcc_shard_fixpoint(omega, ea, sa, tm, prims)
            return om, ea2, it

        fn = self._fn("lcc", program, n_sharded=3)
        self.omega_all, self.ea_all, it = fn(self.arrs, self.omega_all, self.ea_all)
        if stats is not None:
            stats["lcc_iterations"] = stats.get("lcc_iterations", 0) + int(it[0])
            stats["lcc_calls"] = stats.get("lcc_calls", 0) + 1

    # -- NLCC cycle/path ----------------------------------------------------
    def _nlcc_route(self, length: int = 3) -> str:
        from repro.kernels import registry

        if self.wave % 32 != 0:
            return registry.ROUTE_UNPACKED
        eligible = sharded_fused_eligible(
            self.n_local, self.P, self.B, self.wave, length)
        default = registry.ROUTE_FUSED if eligible else registry.ROUTE_PACKED
        route = registry.resolve_route(
            "prune.nlcc", registry.shard_bucket(self.P, self.n_local, self.wave),
            default=default,
            allowed=(registry.ROUTE_FUSED, registry.ROUTE_PACKED,
                     registry.ROUTE_UNPACKED))
        if route == registry.ROUTE_FUSED and not eligible:
            # the kernel's eligibility gate, composed with shard-local shapes
            route = registry.ROUTE_PACKED
        return route

    def _omega_column(self, q: int) -> jnp.ndarray:
        """bool[P, n_local] candidacy plane of template vertex q."""
        w, b = q // 32, q % 32
        return ((self.omega_all[:, :self.n_local, w] >> jnp.uint32(b)) & 1).astype(bool)

    def _cand_stack(self, walk: Sequence[int]) -> jnp.ndarray:
        return jnp.stack([self._omega_column(q) for q in walk], axis=1)  # [P, L+1, n_local]

    def nlcc(self, c: NonLocalConstraint, cstats: Dict,
             direction: str = "default"):
        from repro.kernels import registry as _registry
        from repro.core import nlcc as nlcc_mod

        self._fire("nlcc")
        # captured BEFORE the edge-prune bridge: its edge eliminations must
        # count toward the change flag that triggers the LCC re-run
        omega_before, ea_before = self.omega_all, self.ea_all
        if self.nlcc_edge_prune:
            # beyond-paper frontier edge pruning is a host-side pass — bridge it
            state = self.gather_state()
            new = nlcc_mod._edge_prune_pass(
                self.dg, state, c, self.template, self.wave, cstats)
            if new is not state:
                self.omega_all, self.ea_all = self.scatter_state(new)

        walks = nlcc_mod.expand_walks(c, direction)
        heads = [w[0] for w in walks]
        L = len(walks[0]) - 1
        route = self._nlcc_route(L)
        self._nlcc_routes_taken.add(route)
        wave_stat = {
            _registry.ROUTE_FUSED: "nlcc_fused_waves",
            _registry.ROUTE_PACKED: "nlcc_packed_waves",
            _registry.ROUTE_UNPACKED: "nlcc_plane_waves",
        }[route]

        # ONE host sync per constraint: the head-candidacy planes size the wave
        # loops; everything downstream stays on device
        head_planes = np.asarray(
            jnp.stack([self._omega_column(q) for q in heads]))  # [H, P, n_local]
        head_global = head_planes.reshape(len(heads), -1)[:, :self.part.n]
        keep_cols = [jnp.zeros((self.P, self.n_local + 1), bool) for _ in walks]
        n_waves = 0
        n_tokens = 0
        n_overlapped = 0
        for wi, walk in enumerate(walks):
            cand = self._cand_stack(walk)
            is_cyclic = walk[0] == walk[-1]
            sources = np.flatnonzero(head_global[wi])
            # one-wave-deep software pipeline (the `overlap` schedule): wave
            # i's survivor reduction (the only psum) is dispatched together
            # with / after wave i+1's hop exchanges — the two touch disjoint
            # state, so the collective overlaps the next wave's compute
            # instead of fencing it. `pending` = the frontier awaiting its
            # survivor decision; flushed at the walk boundary.
            pending = None
            for idsp, n_real in nlcc_mod.wave_batches(sources, self.wave):
                self._fire("wave", wave=n_waves)
                ids_dev = jnp.asarray(idsp, jnp.int32)
                if route == _registry.ROUTE_FUSED and pending is not None:
                    keep_cols[wi], f = self._wave_overlapped(
                        L, is_cyclic, cand, keep_cols[wi],
                        pending[0], pending[1], ids_dev)
                    n_overlapped += 1
                else:
                    f = self._wave_frontier(route, L, cand, ids_dev)
                    if pending is not None:
                        keep_cols[wi] = self._wave_finish(
                            route, is_cyclic, pending[0], keep_cols[wi],
                            pending[1])
                        n_overlapped += 1
                pending = (f, ids_dev)
                n_waves += 1
                n_tokens += n_real
            if pending is not None:
                keep_cols[wi] = self._wave_finish(
                    route, is_cyclic, pending[0], keep_cols[wi], pending[1])
        # remove head candidacy from failing sources (Alg. 5 line 8), on device
        omega = self.omega_all
        for wi, q0 in enumerate(heads):
            w, b = q0 // 32, q0 % 32
            word = omega[..., w]
            cleared = word & jnp.uint32(~np.uint32(1 << b))
            omega = omega.at[..., w].set(
                jnp.where(keep_cols[wi], word, cleared))
        self.omega_all = omega
        if cstats is not None:
            cstats["nlcc_tokens"] = cstats.get("nlcc_tokens", 0) + n_tokens
            cstats[wave_stat] = cstats.get(wave_stat, 0) + n_waves
            cstats["nlcc_constraints"] = cstats.get("nlcc_constraints", 0) + 1
            cstats["nlcc_waves"] = cstats.get("nlcc_waves", 0) + n_waves
            cstats["nlcc_overlapped_waves"] = (
                cstats.get("nlcc_overlapped_waves", 0) + n_overlapped)
            cstats["nlcc_host_syncs"] = cstats.get("nlcc_host_syncs", 0) + 1
        return jnp.any(omega_before != self.omega_all) | jnp.any(
            ea_before != self.ea_all)

    # -- wave pipeline stages ----------------------------------------------
    def _frontier_program(self, L):
        """Per-shard hop phase of one wave: seed + L hops, returning the
        hop-L packed frontier WITHOUT the survivor decision (that belongs to
        the pipelined finish stage)."""
        n_local, prims = self.n_local, self._prims()

        def program(sa_dict, ea, cand_stack, source_ids):
            sa = ShardArrays(**sa_dict)
            p = prims.axis_index()
            fp = pack_bits(_seed_frontier_planes(
                cand_stack[0], source_ids, n_local, p))

            def hop(f, cand_r):
                return frontier_shard_hop(f, ea, sa, cand_r, prims), None

            fp, _ = jax.lax.scan(hop, fp, cand_stack[1:])
            return fp

        return program

    def _finish_program(self, packed, is_cyclic):
        """Survivor decision + keep-column scatter for one completed wave
        frontier (the wave's only psum)."""
        n_local, prims = self.n_local, self._prims()

        def finish(f, keep, source_ids):
            p = prims.axis_index()
            if packed:
                planes = jnp.concatenate([
                    unpack_bits(f[:n_local], source_ids.shape[0]),
                    jnp.zeros((1, source_ids.shape[0]), bool)], axis=0)
            else:
                planes = f
            survived = _sharded_wave_survivors(
                planes, source_ids, n_local, is_cyclic, prims)
            return _scatter_keep(keep, survived, source_ids, n_local, p)

        return finish

    def _wave_frontier(self, route, L, cand, ids_dev):
        """Dispatch the hop phase of one wave; returns the hop-L frontier
        (packed words or boolean planes)."""
        from repro.kernels import registry as _registry

        n_local, prims = self.n_local, self._prims()
        if route == _registry.ROUTE_FUSED:
            fn = self._fn(("wave_front_fused", L),
                          self._frontier_program(L), n_sharded=3)
            return fn(self.arrs, self.ea_all, cand, ids_dev)

        packed = route == _registry.ROUTE_PACKED

        def seed(cand0, source_ids):
            p = prims.axis_index()
            planes = _seed_frontier_planes(cand0, source_ids, n_local, p)
            return pack_bits(planes) if packed else planes

        def hop(sa_dict, ea, f, cand_r):
            sa = ShardArrays(**sa_dict)
            if packed:
                return frontier_shard_hop(f, ea, sa, cand_r, prims)
            return frontier_shard_hop_unpacked(f, ea, sa, cand_r, prims)

        seed_fn = self._fn(("wave_seed", packed), seed, n_sharded=1)
        hop_fn = self._fn(("wave_hop", packed), hop, n_sharded=4)
        f = seed_fn(cand[:, 0], ids_dev)
        for r in range(1, L + 1):
            f = hop_fn(self.arrs, self.ea_all, f, cand[:, r])
        return f

    def _wave_finish(self, route, is_cyclic, f, keep_col, ids_dev):
        from repro.kernels import registry as _registry

        packed = route in (_registry.ROUTE_FUSED, _registry.ROUTE_PACKED)
        fn = self._fn(("wave_finish", packed, is_cyclic),
                      self._finish_program(packed, is_cyclic), n_sharded=2)
        return fn(f, keep_col, ids_dev)

    def _wave_overlapped(self, L, is_cyclic, cand, keep_col, f_prev, ids_prev,
                         ids_cur):
        """Fused route, steady state: ONE dispatch that finishes wave i-1
        (its survivor psum) AND runs wave i's seed + hop scan. The two
        dataflows are independent inside the program, so XLA schedules the
        reduction concurrently with the hop exchanges — the wave-level
        `overlap` schedule."""
        front = self._frontier_program(L)
        finish = self._finish_program(True, is_cyclic)

        def program(sa_dict, ea, cand_stack, keep, f_pending, prev_ids,
                    cur_ids):
            keep2 = finish(f_pending, keep, prev_ids)
            f_cur = front(sa_dict, ea, cand_stack, cur_ids)
            return keep2, f_cur

        fn = self._fn(("wave_fused_ov", L, is_cyclic), program, n_sharded=5)
        return fn(self.arrs, self.ea_all, cand, keep_col, f_prev,
                  ids_prev, ids_cur)

    # -- enumeration join ---------------------------------------------------
    def join_context(self):
        """Context for the device-resident enumeration join (core/join.py):
        the join programs run through this backend's program wrapper (vmap /
        shard_map) against the partition's join plan, reading the
        device-resident omega_all / ea_all directly — the reduced subgraph is
        never gathered to the host for enumeration."""
        from repro.core import join as join_mod

        return join_mod.ShardedJoinContext(self)

    # -- TDS (gather bridge) ------------------------------------------------
    def tds(self, c: NonLocalConstraint, cstats: Dict):
        from repro.core import tds as tds_mod

        self._fire("tds")
        state = self.gather_state()
        new = tds_mod.verify_tds_constraint(
            self.dg, state, c, chunk=self.tds_chunk,
            max_rows=self.tds_max_rows, stats=cstats,
            annotate=(c.complete and self.guarantee_precision),
            dedup=self.work_aggregation,
        )
        # the bridge is host-synced anyway, so force the flag here and skip
        # the full repack/scatter for a no-op constraint
        changed = bool(_state_changed(state, new))
        if changed:
            self.omega_all, self.ea_all = self.scatter_state(new)
        if cstats is not None:
            cstats["tds_gather_bridge"] = cstats.get("tds_gather_bridge", 0) + 1
        return changed


class SimBackend(_ShardedBackend):
    """Single-process simulation: the per-shard programs run under
    ``jax.vmap(..., axis_name=SHARD_AXIS)`` — vmap's collective batching rules
    turn the all_to_all into a transpose and psum into a batch sum, so the
    sharded math is provable against the local engine on one device."""

    name = "sim"

    def _make(self, program: Callable, n_sharded: int) -> Callable:
        def call(*args):
            in_axes = (0,) * n_sharded + (None,) * (len(args) - n_sharded)
            return jax.vmap(program, in_axes=in_axes, axis_name=SHARD_AXIS)(*args)

        return jax.jit(call)


class SpmdBackend(_ShardedBackend):
    """shard_map over a real mesh: one `jax.lax.all_to_all` per sweep/hop, the
    convergence flag psum-reduced on device. `axis_names` of the mesh may be a
    tuple — the flattened product is the shard axis (pure data-parallel
    irregular workload)."""

    name = "spmd"

    def __init__(self, graph, dg, template, part, *, mesh, **kw):
        super().__init__(graph, dg, template, part, **kw)
        self.mesh = mesh
        if int(np.prod(tuple(mesh.shape.values()))) != part.P:
            raise ValueError(
                f"mesh has {int(np.prod(tuple(mesh.shape.values())))} devices "
                f"but the partition has P={part.P} shards")
        self._axes = tuple(mesh.axis_names)

    def _make(self, program: Callable, n_sharded: int) -> Callable:
        from repro.kernels import compat

        ax = self._axes
        spec = P(ax)

        def per_shard(*args):
            local = [jax.tree_util.tree_map(lambda x: x[0], a)
                     for a in args[:n_sharded]]
            out = program(*local, *args[n_sharded:])
            return jax.tree_util.tree_map(lambda x: jnp.asarray(x)[None], out)

        def call(*args):
            in_specs = (spec,) * n_sharded + (P(),) * (len(args) - n_sharded)
            fn = compat.shard_map(
                per_shard, mesh=self.mesh, in_specs=in_specs,
                out_specs=spec, check_vma=False)
            return fn(*args)

        return jax.jit(call)


def make_backend(
    graph,
    template: Template,
    *,
    mesh=None,
    partition=None,
    **kw,
):
    """Build the execution backend `prune` drives.

    mesh=None, partition=None        -> local (single device, identity exchange)
    partition=EdgePartition|int      -> sim   (vmap-simulated shards)
    mesh=Mesh [, partition=...]      -> spmd  (shard_map on the mesh)
    """
    if mesh is None and partition is None:
        if isinstance(graph, Graph):
            dg = DeviceGraph.from_host(graph)
        else:
            dg = graph
        return LocalBackend(dg, template, **kw)

    if not isinstance(graph, Graph):
        raise TypeError(
            "sharded prune (mesh=/partition=) needs the host Graph — the "
            "edge partition is built from host arrays")
    # local-only knobs are meaningless on the sharded backends
    for k in ("blocked", "force_pallas"):
        if kw.pop(k, None):
            raise ValueError(
                f"{k}= composes with the local backend only; the sharded "
                "engine routes by shard-local shape buckets instead")
    if partition is None:
        partition = int(np.prod(tuple(mesh.shape.values())))
    if isinstance(partition, int):
        partition = partition_graph(graph, partition)
    # ONE dst-sort serves both the DeviceGraph build and the backend's
    # edge_active gather/scatter map
    order = DeviceGraph.dst_sort_order(graph)
    dg = DeviceGraph.from_host(graph, order=order)
    kw["arc_order"] = order
    if mesh is None:
        return SimBackend(graph, dg, template, partition, **kw)
    return SpmdBackend(graph, dg, template, partition, mesh=mesh, **kw)

"""Template-Driven Search: constrained walks with history (paper §3 + Alg. 6).

TDS verifies walks whose tokens carry the ordered list `t` of visited vertices
so that revisits ("previously visited vertices are revisited as expected") and
bijectivity (distinct template vertices -> distinct background vertices) can be
enforced — the part of Def. 1 that bitset frontiers cannot express.

TPU/SPMD adaptation: by the time TDS runs, the graph has been pruned by
LCC/CC/PC (the paper's whole point — TDS operates on the much smaller G*), so
we *compact the active subgraph* and run a vectorized multi-source join:

  rows = partial assignments  int32[K, n_seen]
  step r: expand the frontier column along active CSR edges (np.repeat-based
          ragged expansion), filter by omega-candidacy + injectivity, or check
          the revisit edge when walk[r] was already assigned,
  then work-aggregate: np.unique(rows) — dedup of identical partial
  assignments, the exact analogue of Alg. 6's tau(v) dedup set.

Memory-pressure control (paper's token-generation rate control): sources are
processed in chunks; a chunk aborts with `TdsOverflow` if rows exceed
`max_rows`, and the caller retries with a smaller chunk.

The same engine powers full match enumeration (complete template walk,
keep all completions) — see core/enumerate.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.graph.structs import DeviceGraph
from repro.core.state import PruneState
from repro.core.template import Template, NonLocalConstraint


class TdsOverflow(RuntimeError):
    pass


@dataclasses.dataclass
class ActiveSubgraph:
    """Host-side compacted view of the current solution subgraph G*."""

    n: int  # original vertex count (ids are NOT re-numbered; keeps omega alignment)
    offsets: np.ndarray  # int64[n+1] CSR over active arcs
    neighbors: np.ndarray  # int32[#active arcs]
    omega: np.ndarray  # bool[n, n0]
    edge_keys: np.ndarray  # sorted int64 keys src*n+dst of active arcs


def compact_active(dg: DeviceGraph, state: PruneState) -> ActiveSubgraph:
    src = np.asarray(dg.src)
    dst = np.asarray(dg.dst)
    omega = np.asarray(state.omega)
    ea = np.asarray(state.edge_active)
    vact = omega.any(axis=1)
    keep = ea & vact[src] & vact[dst]
    s, d = src[keep], dst[keep]
    order = np.lexsort((d, s))
    s, d = s[order], d[order]
    n = dg.n
    offsets = np.zeros(n + 1, dtype=np.int64)
    np.add.at(offsets, s + 1, 1)
    np.cumsum(offsets, out=offsets)
    keys = s.astype(np.int64) * n + d
    return ActiveSubgraph(n=n, offsets=offsets, neighbors=d, omega=omega,
                          edge_keys=np.sort(keys))


def _ragged_ranges(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Concatenate [starts[i], starts[i]+counts[i]) ranges — vectorized."""
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    reset = np.repeat(starts - np.concatenate([[0], np.cumsum(counts)[:-1]]), counts)
    return np.arange(total, dtype=np.int64) + reset


def _has_edge(sub: ActiveSubgraph, u: np.ndarray, v: np.ndarray) -> np.ndarray:
    keys = u.astype(np.int64) * sub.n + v
    pos = np.searchsorted(sub.edge_keys, keys)
    pos = np.minimum(pos, sub.edge_keys.shape[0] - 1)
    return (sub.edge_keys.shape[0] > 0) & (sub.edge_keys[pos] == keys)


# ------------------------------------------------------- join step primitives
# One constrained-walk step over a row table (partial assignments). These are
# the single source of truth for the join semantics: `tds_walk` (the pruning
# path) and the enumeration engines in core/join.py both run them. `restr` is
# a tuple of GraphPi-style partial-order checks ((col, op) with op "gt"/"lt"):
# the newly assigned vertex must compare that way against the named column —
# symmetry breaking enforced IN-FLIGHT, so counting needs no post-hoc dedup.
def expand_rows(
    sub: ActiveSubgraph,
    rows: np.ndarray,
    c_prev: int,
    q_next: int,
    n_cols: int,
    restr: Tuple[Tuple[int, str], ...] = (),
) -> np.ndarray:
    """Expand the frontier column along active CSR arcs, filter by
    omega-candidacy + injectivity (+ optional symmetry restrictions), and
    append the new assignment column."""
    cur = rows[:, c_prev]
    starts = sub.offsets[cur]
    counts = (sub.offsets[cur + 1] - starts).astype(np.int64)
    flat = _ragged_ranges(starts, counts)
    rep = np.repeat(np.arange(rows.shape[0], dtype=np.int64), counts)
    nbr = sub.neighbors[flat]
    keep = sub.omega[nbr, q_next]
    # injectivity: new vertex differs from every assigned one
    for c in range(n_cols):
        keep &= nbr != rows[rep, c]
    for col, op in restr:
        ref = rows[rep, col]
        keep &= (nbr > ref) if op == "gt" else (nbr < ref)
    return np.concatenate(
        [rows[rep[keep]], nbr[keep, None].astype(np.int32)], axis=1
    )


def revisit_rows(sub: ActiveSubgraph, rows: np.ndarray, c_prev: int,
                 c_tgt: int) -> np.ndarray:
    """Keep rows whose revisit edge (frontier -> already-assigned target)
    exists in the active subgraph."""
    keep = _has_edge(sub, rows[:, c_prev], rows[:, c_tgt])
    return rows[keep]


def expand_capacity(sub: ActiveSubgraph, rows: np.ndarray,
                    c_prev: int) -> np.ndarray:
    """Per-row expansion fan-out (active CSR degree of the frontier vertex) —
    what the streaming emitter splits row blocks by."""
    cur = rows[:, c_prev]
    return (sub.offsets[cur + 1] - sub.offsets[cur]).astype(np.int64)


def expansion_slots(deg: np.ndarray) -> Tuple[np.ndarray, int]:
    """Slot layout of one expansion step from STATIC per-row degrees: the
    int64 inclusive running capacity and the total slot count. Shared by the
    replicated and row-sharded device joins (core/join.py) — the layout
    depends on static degrees only, so it is identical on every shard
    count."""
    cum = np.cumsum(np.asarray(deg, np.int64))
    return cum, (int(cum[-1]) if cum.size else 0)


def slot_parents(cum: np.ndarray, deg: np.ndarray,
                 n_slots: int) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side (parent row, within-frontier arc j) per expansion slot for
    the layout `expansion_slots` produced. Slots past the real capacity
    (pow2 padding) land on the last row with j >= its degree, so every
    filter rejects them."""
    cum = np.asarray(cum, np.int64)
    deg = np.asarray(deg, np.int64)
    t = np.arange(n_slots, dtype=np.int64)
    parent = np.searchsorted(cum, t, side="right")
    parent = np.minimum(parent, max(cum.shape[0] - 1, 0))
    j = t - (cum[parent] - deg[parent])
    return parent.astype(np.int32), j.astype(np.int32)


def tds_walk(
    sub: ActiveSubgraph,
    walk: Sequence[int],
    sources: np.ndarray,
    max_rows: int = 2_000_000,
    collect_rows: bool = False,
    stats: Optional[Dict] = None,
    dedup: bool = True,
) -> Tuple[np.ndarray, Optional[np.ndarray], List[int]]:
    """Run one TDS walk from the given sources.

    Returns (survived mask over `sources`, completed rows or None, seen_q order).
    Rows columns follow `seen_q` = template vertices in order of first visit.
    """
    walk = list(walk)
    q0 = walk[0]
    seen_q: List[int] = [q0]
    src_ok = sub.omega[sources, q0]
    rows = sources[src_ok].astype(np.int32).reshape(-1, 1)

    for r in range(1, len(walk)):
        if rows.shape[0] == 0:
            break
        q_prev, q_next = walk[r - 1], walk[r]
        c_prev = seen_q.index(q_prev)
        if q_next in seen_q:
            rows = revisit_rows(sub, rows, c_prev, seen_q.index(q_next))
        else:
            rows = expand_rows(sub, rows, c_prev, q_next, len(seen_q))
            seen_q.append(q_next)
            if rows.shape[0] > max_rows:
                raise TdsOverflow(
                    f"TDS frontier {rows.shape[0]} > max_rows={max_rows} at step {r}"
                )
        # work aggregation: dedup identical partial assignments
        if dedup and rows.shape[0] > 1:
            before = rows.shape[0]
            rows = np.unique(rows, axis=0)
            if stats is not None:
                stats["tds_dedup_dropped"] = stats.get("tds_dedup_dropped", 0) + (
                    before - rows.shape[0]
                )
        if stats is not None:
            stats["tds_rows_max"] = max(stats.get("tds_rows_max", 0), int(rows.shape[0]))
            stats["tds_expansions"] = stats.get("tds_expansions", 0) + int(rows.shape[0])

    survived_src = np.unique(rows[:, 0]) if rows.shape[0] else np.zeros(0, np.int32)
    survived = np.isin(sources, survived_src)
    return survived, (rows if collect_rows else None), seen_q


def verify_tds_constraint(
    dg: DeviceGraph,
    state: PruneState,
    constraint: NonLocalConstraint,
    chunk: int = 4096,
    max_rows: int = 2_000_000,
    stats: Optional[Dict] = None,
    annotate: bool = False,
    dedup: bool = True,
) -> PruneState:
    """Alg. 5 with a TDS walk: prune head candidacy of failing sources.

    With annotate=True (complete walks only) omega is *replaced* by the exact
    set of (v, q) pairs participating in completed walks — the paper's
    'list of possible matches' by-product that guarantees zero false positives.
    """
    import jax.numpy as jnp

    sub = compact_active(dg, state)
    q0 = constraint.walk[0]
    sources = np.flatnonzero(sub.omega[:, q0])
    survived_all = np.zeros(sub.n, dtype=bool)
    confirmed = np.zeros_like(sub.omega) if annotate else None
    confirmed_arc_keys: list = []

    walk_pairs = sorted({(min(a, b), max(a, b))
                         for a, b in zip(constraint.walk[:-1], constraint.walk[1:])})

    off = 0
    cur_chunk = chunk
    while off < sources.size:
        ids = sources[off : off + cur_chunk]
        try:
            surv, rows, seen_q = tds_walk(
                sub, constraint.walk, ids, max_rows=max_rows,
                collect_rows=annotate, stats=stats, dedup=dedup,
            )
        except TdsOverflow:
            if cur_chunk == 1:
                raise
            cur_chunk = max(1, cur_chunk // 4)  # paper's rate control
            continue
        survived_all[ids[surv]] = True
        if annotate and rows is not None and rows.shape[0]:
            col = {q: c for c, q in enumerate(seen_q)}
            for c, q in enumerate(seen_q):
                confirmed[rows[:, c], q] = True
            # confirmed edges: every template edge of every completed walk
            for a, b in walk_pairs:
                u, v = rows[:, col[a]].astype(np.int64), rows[:, col[b]].astype(np.int64)
                confirmed_arc_keys.append(np.unique(u * sub.n + v))
                confirmed_arc_keys.append(np.unique(v * sub.n + u))
        off += ids.size
    omega = np.asarray(state.omega).copy()
    omega[:, q0] &= survived_all
    edge_active = state.edge_active
    if annotate:
        if not constraint.complete:
            raise ValueError("annotate requires a complete walk")
        omega = confirmed & np.asarray(state.omega)
        # exact edge set (paper: the output G* contains only edges of matches)
        keys = (
            np.unique(np.concatenate(confirmed_arc_keys))
            if confirmed_arc_keys
            else np.zeros(0, np.int64)
        )
        arc_keys = np.asarray(dg.src).astype(np.int64) * sub.n + np.asarray(dg.dst)
        pos = np.searchsorted(keys, arc_keys)
        pos = np.minimum(pos, max(keys.shape[0] - 1, 0))
        exact = (keys.shape[0] > 0) & (keys[pos] == arc_keys) if keys.shape[0] else np.zeros(arc_keys.shape[0], bool)
        edge_active = state.edge_active & jnp.asarray(exact)
    return PruneState(omega=jnp.asarray(omega), edge_active=edge_active)

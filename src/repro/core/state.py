"""Per-vertex / per-edge pruning state (paper Alg. 2) and pack/unpack helpers.

Canonical single-device representation:
  omega:       bool[n, n0]   — candidate template vertices per background vertex
  edge_active: bool[m]       — per arc, in the dst-sorted DeviceGraph order

The distributed engine and the `bitset_spmm` kernel use the packed form
uint32[n, W] with W = ceil(n0/32) (<= 2 since n0 <= 64).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np
import jax
import jax.numpy as jnp

from repro.graph.structs import DeviceGraph
from repro.core.template import Template


def packed_words(n0: int) -> int:
    return (n0 + 31) // 32


def pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """bool[..., n0] -> uint32[..., W]."""
    n0 = bits.shape[-1]
    W = packed_words(n0)
    pad = W * 32 - n0
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), bits.dtype)], axis=-1
        )
    b = bits.reshape(bits.shape[:-1] + (W, 32)).astype(jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(b << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jnp.ndarray, n0: int) -> jnp.ndarray:
    """uint32[..., W] -> bool[..., n0]."""
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(words.shape[:-1] + (words.shape[-1] * 32,))
    return bits[..., :n0].astype(bool)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PruneState:
    omega: jnp.ndarray  # bool[n, n0]
    edge_active: jnp.ndarray  # bool[m] (dst-sorted arc order)

    @property
    def vertex_active(self) -> jnp.ndarray:
        return jnp.any(self.omega, axis=1)

    def counts(self) -> Dict[str, int]:
        return {
            "active_vertices": int(jnp.sum(jnp.any(self.omega, axis=1))),
            "active_edges": int(jnp.sum(self.edge_active)),
            "omega_bits": int(jnp.sum(self.omega)),
        }


def init_state(dg: DeviceGraph, template: Template) -> PruneState:
    """Alg. 2 initialization: omega(v) = {q : l(q) == l(v)}; all edges active."""
    n_labels = max(int(template.labels.max()) + 1, int(jnp.max(dg.labels)) + 1)
    lm = jnp.asarray(template.label_matrix(n_labels))  # [n0, L]
    omega = jnp.take(lm.T, dg.labels, axis=0)  # [n, n0]
    edge_active = jnp.ones((dg.m,), dtype=bool)
    return PruneState(omega=omega, edge_active=edge_active)


def solution_counts(state: PruneState) -> Dict[str, int]:
    return state.counts()

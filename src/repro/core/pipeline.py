"""The main pruning loop (paper Alg. 1) — PruneJuice in JAX.

    G* <- LCC(G, G0)
    for C0 in K0 (ordered: CC/PC by length, then TDS):
        G* <- NLCC(G*, G0, C0)
        if anything was eliminated: G* <- LCC(G*, G0)

One driver serves every execution backend (core/engine.py): `local` (single
device — today's optimized path), `spmd` (`mesh=` — shard_map + all_to_all
over an `EdgePartition`; the whole pipeline runs where the partitioned state
lives) and `sim` (`partition=` without a mesh — vmap-simulated shards for
single-process parity tests). The driver's control decisions (run LCC after a
constraint?) read ONE device bool per constraint; phase snapshots accumulate
device-side and materialize once at the end (eager under collect_stats=True).

Flags expose the paper's ablations:
  edge_elimination=False  — vertex-elimination-only baseline (Fig. 6a)
  work_aggregation=False  — TDS token dedup off (Fig. 6b)
  guarantee_precision     — generate + annotate the complete-walk TDS
                            constraint (zero false positives, Def. 1) vs. the
                            heuristic CC/PC/partial-TDS pipeline only.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Union

import numpy as np
import jax.numpy as jnp

from repro.graph.structs import Graph, DeviceGraph
from repro.core.template import Template, generate_constraints, NonLocalConstraint
from repro.core.state import PruneState
from repro.core import engine as engine_mod


@dataclasses.dataclass
class PhaseStat:
    phase: str
    constraint: Optional[str]
    seconds: float
    active_vertices: int
    active_edges: int
    omega_bits: int
    extra: Dict


@dataclasses.dataclass
class PruneResult:
    state: PruneState
    template: Template
    dg: DeviceGraph
    phases: List[PhaseStat]
    stats: Dict
    # the execution backend that ran the prune — a sharded result hands its
    # device-resident shard arrays straight to the enumeration join, so
    # `enumerate_matches(result)` never gathers the reduced subgraph
    backend: Optional[object] = None

    # The masks are device->host materializations hit repeatedly by benchmarks
    # and enumeration — computed once, cached on the instance.
    @functools.cached_property
    def vertex_mask(self) -> np.ndarray:
        return self.omega.any(axis=1)

    @functools.cached_property
    def edge_mask(self) -> np.ndarray:
        """Arc mask in the dst-sorted DeviceGraph order, endpoint-consistent."""
        vm = self.vertex_mask
        ea = np.asarray(self.state.edge_active)
        return ea & vm[np.asarray(self.dg.src)] & vm[np.asarray(self.dg.dst)]

    @functools.cached_property
    def omega(self) -> np.ndarray:
        return np.asarray(self.state.omega)

    def counts(self):
        return {
            "V*": int(self.vertex_mask.sum()),
            "E*": int(self.edge_mask.sum()),
        }


def prune(
    graph: Union[Graph, DeviceGraph],
    template: Template,
    *,
    guarantee_precision: bool = True,
    edge_elimination: bool = True,
    work_aggregation: bool = True,
    nlcc_edge_prune: bool = False,
    wave: int = 1024,
    tds_chunk: int = 4096,
    tds_max_rows: int = 2_000_000,
    label_freq: Optional[np.ndarray] = None,
    constraints: Optional[List[NonLocalConstraint]] = None,
    initial_state: Optional[PruneState] = None,
    collect_stats: bool = False,
    blocked=None,
    force_pallas: bool = False,
    mesh=None,
    partition=None,
) -> PruneResult:
    """Run the full pruning pipeline on the chosen execution backend.

    `mesh=` (a jax Mesh) runs the ENTIRE pipeline sharded under shard_map —
    the initial LCC, the ordered NLCC constraint loop with the batched wave
    executor, psum-reduced convergence — over an `EdgePartition` built from
    the host graph (or passed via `partition=`, an EdgePartition or a shard
    count). `partition=` without a mesh uses the vmap-simulated `sim` backend
    (bit-identical math, single process). The result is the gathered global
    state, directly consumable by `enumerate_matches`.

    On the local backend, `blocked` (a graph.blocked.BlockedStructure) makes
    every LCC sweep and eligible NLCC wave *packed-capable*: the tuned
    dispatch policy (repro.kernels.registry, `registry.tune()` / the
    persisted policy cache) then picks the route per shape bucket — packed vs
    unpacked for LCC; packed, unpacked, or the fused multi-hop wave engine
    (one `bitset_wave` kernel call per NLCC wave, frontier resident across
    hops) for NLCC — and the kernel registry decides pallas / interpret / ref
    per call. Untuned, the routing matches the historical hardcoded choice
    (LCC: packed whenever `blocked` is given; NLCC: packed only where the
    kernel compiles, i.e. on TPU). On the sharded backends routes resolve per
    SHARD-LOCAL shape bucket (`registry.shard_bucket`) among the fused /
    packed / unpacked wave programs. The routes actually taken land in
    `stats["dispatch_routes"]`. `force_pallas` pins the packed interpret-mode
    kernel path for parity testing (local backend only)."""
    if isinstance(graph, Graph) and label_freq is None:
        label_freq = graph.label_frequency()

    backend = engine_mod.make_backend(
        graph, template, mesh=mesh, partition=partition,
        wave=wave, blocked=blocked, force_pallas=force_pallas,
        edge_elimination=edge_elimination, collect_stats=collect_stats,
        nlcc_edge_prune=nlcc_edge_prune, tds_chunk=tds_chunk,
        tds_max_rows=tds_max_rows, work_aggregation=work_aggregation,
        guarantee_precision=guarantee_precision,
    )
    dg = backend.dg
    stats: Dict = {"edge_elimination": edge_elimination,
                   "work_aggregation": work_aggregation,
                   "backend": backend.name}
    raw_phases: List[tuple] = []

    backend.init(initial_state)
    if template.n0 == 1:
        return PruneResult(backend.final_state(), template, dg, [], stats,
                           backend=backend)

    backend.record_routes(stats)  # each backend decides what (if anything) to record

    def snap(phase, cname, t0, extra):
        # the phase's wall time must include its device work (the recorded
        # perf trajectory compares PR-over-PR), so fence the stream — a sync
        # with NO transfer — before timestamping. The snapshot counts stay a
        # lazy device value until ONE materialization at the end of the run;
        # eager host counts only under collect_stats=True (satellite of PR 4)
        backend.sync()
        secs = time.perf_counter() - t0
        counts = backend.counts_host() if collect_stats else backend.counts_dev()
        raw_phases.append((phase, cname, secs, extra, counts))

    # --- initial LCC
    t0 = time.perf_counter()
    backend.lcc(stats)
    snap("LCC", None, t0, {})

    # --- NLCC loop
    # Beyond-paper fast path: with forward-backward frontier edge pruning,
    # CC alone yields the exact edge set for unique-label edge-monocyclic
    # templates (every surviving edge lies on a completing label-cycle, and
    # unique labels make any such cycle a true match) — the complete-walk TDS
    # becomes unnecessary. Validated against the oracle in the property tests.
    skip_complete = (
        nlcc_edge_prune and guarantee_precision
        and not template.is_acyclic()
        and template.is_edge_monocyclic() and not template.repeated_labels()
    )
    if skip_complete:
        stats["tds_skipped_via_frontier_edge_prune"] = True
    if constraints is None:
        constraints = generate_constraints(
            template, label_freq=label_freq,
            guarantee_precision=guarantee_precision and not skip_complete,
        )
    stats["n_constraints"] = len(constraints)
    for c in constraints:
        t0 = time.perf_counter()
        cstats: Dict = {}
        if c.kind in ("cycle", "path"):
            changed = backend.nlcc(c, cstats)
        else:
            changed = backend.tds(c, cstats)
        snap(f"NLCC-{c.kind}", str(c.walk), t0, cstats)
        # ONE device bool decides the re-run — not six blocking count reads
        if bool(changed):
            t0 = time.perf_counter()
            backend.lcc(stats)
            snap("LCC", None, t0, {})

    backend.finalize_stats(stats)
    return PruneResult(
        backend.final_state(), template, dg, _materialize(raw_phases), stats,
        backend=backend)


def _materialize(raw_phases: List[tuple]) -> List[PhaseStat]:
    """Turn accumulated snapshots into PhaseStats. Deferred (device-array)
    counts are stacked and transferred in ONE host sync."""
    deferred = [c for *_, c in raw_phases if not isinstance(c, dict)]
    if deferred:
        mat = iter(np.asarray(jnp.stack(deferred)))
    phases: List[PhaseStat] = []
    for phase, cname, secs, extra, counts in raw_phases:
        if isinstance(counts, dict):
            av, ae, ob = (counts["active_vertices"], counts["active_edges"],
                          counts["omega_bits"])
        else:
            av, ae, ob = (int(x) for x in next(mat))
        phases.append(PhaseStat(
            phase=phase, constraint=cname, seconds=secs,
            active_vertices=av, active_edges=ae, omega_bits=ob, extra=extra))
    return phases

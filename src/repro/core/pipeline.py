"""The main pruning loop (paper Alg. 1) — PruneJuice in JAX.

    G* <- LCC(G, G0)
    for C0 in K0 (ordered: CC/PC by length, then TDS):
        G* <- NLCC(G*, G0, C0)
        if anything was eliminated: G* <- LCC(G*, G0)

Flags expose the paper's ablations:
  edge_elimination=False  — vertex-elimination-only baseline (Fig. 6a)
  work_aggregation=False  — TDS token dedup off (Fig. 6b)
  guarantee_precision     — generate + annotate the complete-walk TDS
                            constraint (zero false positives, Def. 1) vs. the
                            heuristic CC/PC/partial-TDS pipeline only.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Union

import numpy as np
import jax.numpy as jnp

from repro.graph.structs import Graph, DeviceGraph
from repro.core.template import Template, generate_constraints, NonLocalConstraint
from repro.core.state import PruneState, init_state
from repro.core.lcc import (
    TemplateDev, lcc_iteration, lcc_fixpoint, lcc_fixpoint_packed,
)
from repro.core import nlcc as nlcc_mod
from repro.core import tds as tds_mod


@dataclasses.dataclass
class PhaseStat:
    phase: str
    constraint: Optional[str]
    seconds: float
    active_vertices: int
    active_edges: int
    omega_bits: int
    extra: Dict


@dataclasses.dataclass
class PruneResult:
    state: PruneState
    template: Template
    dg: DeviceGraph
    phases: List[PhaseStat]
    stats: Dict

    @property
    def vertex_mask(self) -> np.ndarray:
        return np.asarray(self.state.omega).any(axis=1)

    @property
    def edge_mask(self) -> np.ndarray:
        """Arc mask in the dst-sorted DeviceGraph order, endpoint-consistent."""
        vm = self.vertex_mask
        ea = np.asarray(self.state.edge_active)
        return ea & vm[np.asarray(self.dg.src)] & vm[np.asarray(self.dg.dst)]

    @property
    def omega(self) -> np.ndarray:
        return np.asarray(self.state.omega)

    def counts(self):
        return {
            "V*": int(self.vertex_mask.sum()),
            "E*": int(self.edge_mask.sum()),
        }


def _snapshot(state: PruneState, phase, cname, secs, extra) -> PhaseStat:
    c = state.counts()
    return PhaseStat(
        phase=phase, constraint=cname, seconds=secs,
        active_vertices=c["active_vertices"], active_edges=c["active_edges"],
        omega_bits=c["omega_bits"], extra=extra,
    )


def prune(
    graph: Union[Graph, DeviceGraph],
    template: Template,
    *,
    guarantee_precision: bool = True,
    edge_elimination: bool = True,
    work_aggregation: bool = True,
    nlcc_edge_prune: bool = False,
    wave: int = 1024,
    tds_chunk: int = 4096,
    tds_max_rows: int = 2_000_000,
    label_freq: Optional[np.ndarray] = None,
    constraints: Optional[List[NonLocalConstraint]] = None,
    initial_state: Optional[PruneState] = None,
    collect_stats: bool = False,
    blocked=None,
    force_pallas: bool = False,
) -> PruneResult:
    """`blocked` (a graph.blocked.BlockedStructure) makes every LCC sweep and
    eligible NLCC wave *packed-capable*: the tuned dispatch policy
    (repro.kernels.registry, `registry.tune()` / the persisted policy cache)
    then picks the route per shape bucket — packed vs unpacked for LCC;
    packed, unpacked, or the fused multi-hop wave engine (one `bitset_wave`
    kernel call per NLCC wave, frontier resident across hops) for NLCC — and
    the kernel registry decides pallas / interpret / ref per call. Untuned,
    the routing matches the historical hardcoded choice (LCC: packed whenever
    `blocked` is given; NLCC: packed only where the kernel compiles, i.e. on
    TPU). The routes actually taken land in `stats["dispatch_routes"]`.
    `force_pallas` pins the packed interpret-mode kernel path for parity
    testing."""
    if isinstance(graph, Graph):
        if label_freq is None:
            label_freq = graph.label_frequency()
        dg = DeviceGraph.from_host(graph)
    else:
        dg = graph
    tdev = TemplateDev(template)
    stats: Dict = {"edge_elimination": edge_elimination, "work_aggregation": work_aggregation}
    phases: List[PhaseStat] = []

    state = initial_state if initial_state is not None else init_state(dg, template)
    if template.n0 == 1:
        return PruneResult(state, template, dg, phases, stats)

    if blocked is not None:
        # record the packed-vs-unpacked routing the sweeps below will actually
        # take — same helpers, same gates (benchmarks surface this in the
        # BENCH_pipeline.json roll-up)
        from repro.kernels import registry as _registry
        from repro.core.lcc import LCC_ROUTE, lcc_resolved_route
        from repro.core.nlcc import NLCC_ROUTE, nlcc_resolved_route

        stats["dispatch_routes"] = {
            # the Fig-6a ablation (_lcc_no_edge_elim) never reaches the
            # packed path, whatever the policy says
            LCC_ROUTE: (_registry.ROUTE_UNPACKED if not edge_elimination
                        else lcc_resolved_route(
                state, dg, tdev, blocked,
                collect_stats=collect_stats, force_pallas=force_pallas)),
            NLCC_ROUTE: nlcc_resolved_route(
                state, wave, blocked,
                count_messages=collect_stats, force_pallas=force_pallas),
        }
        stats["dispatch_policy_active"] = _registry.get_policy() is not None

    # --- initial LCC
    t0 = time.perf_counter()
    state = _lcc(dg, tdev, state, edge_elimination, stats, collect_stats,
                 blocked=blocked, force_pallas=force_pallas)
    phases.append(_snapshot(state, "LCC", None, time.perf_counter() - t0, {}))

    # --- NLCC loop
    # Beyond-paper fast path: with forward-backward frontier edge pruning,
    # CC alone yields the exact edge set for unique-label edge-monocyclic
    # templates (every surviving edge lies on a completing label-cycle, and
    # unique labels make any such cycle a true match) — the complete-walk TDS
    # becomes unnecessary. Validated against the oracle in the property tests.
    skip_complete = (
        nlcc_edge_prune and guarantee_precision
        and not template.is_acyclic()
        and template.is_edge_monocyclic() and not template.repeated_labels()
    )
    if skip_complete:
        stats["tds_skipped_via_frontier_edge_prune"] = True
    if constraints is None:
        constraints = generate_constraints(
            template, label_freq=label_freq,
            guarantee_precision=guarantee_precision and not skip_complete,
        )
    stats["n_constraints"] = len(constraints)
    for c in constraints:
        t0 = time.perf_counter()
        before = state.counts()
        cstats: Dict = {}
        if c.kind in ("cycle", "path"):
            state = nlcc_mod.verify_constraint(
                dg, state, c, template.labels, wave=wave, stats=cstats,
                count_messages=collect_stats,
                edge_prune=nlcc_edge_prune, template=template,
                blocked=blocked, force_pallas=force_pallas,
            )
        else:
            state = tds_mod.verify_tds_constraint(
                dg, state, c, chunk=tds_chunk, max_rows=tds_max_rows,
                stats=cstats, annotate=(c.complete and guarantee_precision),
                dedup=work_aggregation,
            )
        after = state.counts()
        phases.append(
            _snapshot(state, f"NLCC-{c.kind}", str(c.walk), time.perf_counter() - t0, cstats)
        )
        if after != before:
            t0 = time.perf_counter()
            state = _lcc(dg, tdev, state, edge_elimination, stats, collect_stats,
                         blocked=blocked, force_pallas=force_pallas)
            phases.append(_snapshot(state, "LCC", None, time.perf_counter() - t0, {}))

    return PruneResult(state, template, dg, phases, stats)


def _lcc(dg, tdev, state, edge_elimination, stats, collect_stats,
         blocked=None, force_pallas=False):
    if not edge_elimination:
        # ablation: run vertex elimination but keep every endpoint-active edge
        return _lcc_no_edge_elim(dg, tdev, state, stats)
    if blocked is not None and not collect_stats and not tdev.needs_counts:
        return lcc_fixpoint_packed(
            dg, tdev, state, blocked, stats=stats, force_pallas=force_pallas)
    if collect_stats:
        # python loop to count per-iteration messages (active arcs at send time)
        it = 0
        while True:
            stats["lcc_messages"] = stats.get("lcc_messages", 0) + int(
                jnp.sum(state.edge_active)
            )
            new_state, changed = lcc_iteration(dg, tdev, state)
            it += 1
            state = new_state
            if not bool(changed) or it > 1000:
                break
        stats["lcc_iterations"] = stats.get("lcc_iterations", 0) + it
        return state
    return lcc_fixpoint(dg, tdev, state, stats=stats)


def _lcc_no_edge_elim(dg, tdev, state, stats):
    """Vertex-elimination-only LCC (Fig. 6a baseline): edges stay active while
    both endpoints are active, regardless of label compatibility."""
    it = 0
    while True:
        new_state, changed = lcc_iteration(dg, tdev, state)
        vact = jnp.any(new_state.omega, axis=1)
        ea = jnp.take(vact, dg.src) & jnp.take(vact, dg.dst)
        new_state = PruneState(omega=new_state.omega, edge_active=ea)
        changed = jnp.any(new_state.omega != state.omega) | jnp.any(
            new_state.edge_active != state.edge_active
        )
        state = new_state
        it += 1
        stats["lcc_messages"] = stats.get("lcc_messages", 0) + int(jnp.sum(ea))
        if not bool(changed) or it > 1000:
            break
    stats["lcc_iterations"] = stats.get("lcc_iterations", 0) + it
    return state

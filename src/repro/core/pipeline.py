"""The main pruning loop (paper Alg. 1) — PruneJuice in JAX.

    G* <- LCC(G, G0)
    for C0 in K0 (ordered: CC/PC by length, then TDS):
        G* <- NLCC(G*, G0, C0)
        if anything was eliminated: G* <- LCC(G*, G0)

One driver serves every execution backend (core/engine.py): `local` (single
device — today's optimized path), `spmd` (`mesh=` — shard_map + all_to_all
over an `EdgePartition`; the whole pipeline runs where the partitioned state
lives) and `sim` (`partition=` without a mesh — vmap-simulated shards for
single-process parity tests). The driver's control decisions (run LCC after a
constraint?) read ONE device bool per constraint; phase snapshots accumulate
device-side and materialize once at the end (eager under collect_stats=True).

The driver is structured as a RE-ENTERABLE phase loop: phase 0 is the initial
LCC, phase k (1..K) is constraint k plus its conditional LCC re-run. Pruning
is monotone, so phase boundaries are consistency points — with
`resilience=` (core/resilience.py) the driver snapshots state there through
`repro.checkpoint`, wraps each phase in the degradation ladder
(retry -> ref kernels -> chunk back-off -> checkpoint-and-raise), and on
shard loss restores the last valid checkpoint onto a possibly *smaller*
shard count via `loadbalance.elastic_handoff` (the paper's LB-16/LB-1
recover-on-smaller-deployment). The same compact-and-reshuffle triggers from
device-side per-shard imbalance counts at phase boundaries even without a
fault. Checkpoints and results always live in ORIGINAL graph coordinates, so
a recovered run is bit-identical to a fault-free one (pinned in
tests/test_resilience.py). NOTE: informational counters (lcc_iterations,
nlcc_tokens, ...) accumulate across retried attempts; the phase trajectory
commits only successful attempts and stays exact.

Flags expose the paper's ablations:
  edge_elimination=False  — vertex-elimination-only baseline (Fig. 6a)
  work_aggregation=False  — TDS token dedup off (Fig. 6b)
  guarantee_precision     — generate + annotate the complete-walk TDS
                            constraint (zero false positives, Def. 1) vs. the
                            heuristic CC/PC/partial-TDS pipeline only.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple, Union

import numpy as np
import jax.numpy as jnp

from repro.graph.structs import Graph, DeviceGraph
from repro.core.template import Template, generate_constraints, NonLocalConstraint
from repro.core.state import PruneState
from repro.core import engine as engine_mod
from repro.core import planner as planner_mod
from repro.core import resilience as resilience_mod


@dataclasses.dataclass
class PhaseStat:
    phase: str
    constraint: Optional[str]
    seconds: float
    active_vertices: int
    active_edges: int
    omega_bits: int
    extra: Dict


@dataclasses.dataclass
class PruneResult:
    state: PruneState
    template: Template
    dg: DeviceGraph
    phases: List[PhaseStat]
    stats: Dict
    # the execution backend that ran the prune — a sharded result hands its
    # device-resident shard arrays straight to the enumeration join, so
    # `enumerate_matches(result)` never gathers the reduced subgraph. A run
    # that restarted elastically finishes on a COMPACTED graph whose shard
    # arrays no longer describe `dg`; it reports backend=None and enumeration
    # takes the host route over the original-coordinate state.
    backend: Optional[object] = None

    # The masks are device->host materializations hit repeatedly by benchmarks
    # and enumeration — computed once, cached on the instance.
    @functools.cached_property
    def vertex_mask(self) -> np.ndarray:
        return self.omega.any(axis=1)

    @functools.cached_property
    def edge_mask(self) -> np.ndarray:
        """Arc mask in the dst-sorted DeviceGraph order, endpoint-consistent."""
        vm = self.vertex_mask
        ea = np.asarray(self.state.edge_active)
        return ea & vm[np.asarray(self.dg.src)] & vm[np.asarray(self.dg.dst)]

    @functools.cached_property
    def omega(self) -> np.ndarray:
        return np.asarray(self.state.omega)

    def counts(self):
        return {
            "V*": int(self.vertex_mask.sum()),
            "E*": int(self.edge_mask.sum()),
        }


def prune(
    graph: Union[Graph, DeviceGraph],
    template: Template,
    *,
    guarantee_precision: bool = True,
    edge_elimination: bool = True,
    work_aggregation: bool = True,
    nlcc_edge_prune: bool = False,
    wave: int = 1024,
    tds_chunk: int = 4096,
    tds_max_rows: int = 2_000_000,
    label_freq: Optional[np.ndarray] = None,
    constraints: Optional[List[NonLocalConstraint]] = None,
    plan: Optional["planner_mod.QueryPlan"] = None,
    initial_state: Optional[PruneState] = None,
    collect_stats: bool = False,
    blocked=None,
    force_pallas: bool = False,
    mesh=None,
    partition=None,
    resilience: Optional[resilience_mod.ResilienceConfig] = None,
) -> PruneResult:
    """Run the full pruning pipeline on the chosen execution backend.

    `mesh=` (a jax Mesh) runs the ENTIRE pipeline sharded under shard_map —
    the initial LCC, the ordered NLCC constraint loop with the batched wave
    executor, psum-reduced convergence — over an `EdgePartition` built from
    the host graph (or passed via `partition=`, an EdgePartition or a shard
    count). `partition=` without a mesh uses the vmap-simulated `sim` backend
    (bit-identical math, single process). The result is the gathered global
    state, directly consumable by `enumerate_matches`.

    On the local backend, `blocked` (a graph.blocked.BlockedStructure) makes
    every LCC sweep and eligible NLCC wave *packed-capable*: the tuned
    dispatch policy (repro.kernels.registry, `registry.tune()` / the
    persisted policy cache) then picks the route per shape bucket — packed vs
    unpacked for LCC; packed, unpacked, or the fused multi-hop wave engine
    (one `bitset_wave` kernel call per NLCC wave, frontier resident across
    hops) for NLCC — and the kernel registry decides pallas / interpret / ref
    per call. Untuned, the routing matches the historical hardcoded choice
    (LCC: packed whenever `blocked` is given; NLCC: packed only where the
    kernel compiles, i.e. on TPU). On the sharded backends routes resolve per
    SHARD-LOCAL shape bucket (`registry.shard_bucket`) among the fused /
    packed / unpacked wave programs. The routes actually taken land in
    `stats["dispatch_routes"]`. `force_pallas` pins the packed interpret-mode
    kernel path for parity testing (local backend only).

    `resilience=` (a core/resilience.ResilienceConfig) turns on phase-boundary
    checkpointing, the per-phase degradation ladder, deterministic fault
    injection (when the config carries a FaultInjector), and elastic
    restart/rebalance — see the module docstring and core/resilience.py."""
    if isinstance(graph, Graph) and label_freq is None:
        label_freq = graph.label_frequency()

    backend_kw = dict(
        wave=wave, blocked=blocked, force_pallas=force_pallas,
        edge_elimination=edge_elimination, collect_stats=collect_stats,
        nlcc_edge_prune=nlcc_edge_prune, tds_chunk=tds_chunk,
        tds_max_rows=tds_max_rows, work_aggregation=work_aggregation,
        guarantee_precision=guarantee_precision,
    )
    if resilience is not None and resilience.injector is not None:
        backend_kw["injector"] = resilience.injector
    backend = engine_mod.make_backend(
        graph, template, mesh=mesh, partition=partition, **backend_kw)
    dg = backend.dg
    stats: Dict = {"edge_elimination": edge_elimination,
                   "work_aggregation": work_aggregation,
                   "backend": backend.name}
    if resilience is not None:
        stats["resilience"] = {
            "checkpoints": 0, "checkpoint_seconds": [], "restarts": [],
            "rebalances": [], "ladder": [], "recovery_seconds": 0.0,
        }

    backend.init(initial_state)
    if template.n0 == 1:
        return PruneResult(backend.final_state(), template, dg, [], stats,
                           backend=backend)

    backend.record_routes(stats)  # each backend decides what (if anything) to record

    # Beyond-paper fast path: with forward-backward frontier edge pruning,
    # CC alone yields the exact edge set for unique-label edge-monocyclic
    # templates (every surviving edge lies on a completing label-cycle, and
    # unique labels make any such cycle a true match) — the complete-walk TDS
    # becomes unnecessary. Validated against the oracle in the property tests.
    skip_complete = (
        nlcc_edge_prune and guarantee_precision
        and not template.is_acyclic()
        and template.is_edge_monocyclic() and not template.repeated_labels()
    )
    if skip_complete:
        stats["tds_skipped_via_frontier_edge_prune"] = True
    # The constraint list is fixed ONCE, from the original graph's label
    # frequencies — an elastic restart must replay the identical phases.
    if constraints is None:
        constraints = generate_constraints(
            template, label_freq=label_freq,
            guarantee_precision=guarantee_precision and not skip_complete,
        )
        if plan is None:
            # plan-level optimizer lookup (core/planner.py): only when the
            # active policy carries tuned plans — an untuned checkout never
            # touches graph stats and runs the heuristic order byte-identically
            plan = _maybe_resolve_plan(graph, dg, template, constraints,
                                       label_freq)
    if plan is not None:
        _check_plan(plan, constraints)
        constraints = plan.constraints()
    else:
        plan = planner_mod.heuristic_plan(constraints)
    stats["n_constraints"] = len(constraints)
    stats["plan"] = {
        "source": plan.source,
        "phases": [
            {"sig": p.signature, "engine": p.engine,
             "direction": p.direction,
             "predicted_s": (plan.per_phase_s[i] if plan.per_phase_s
                             else None),
             "actual_s": None}
            for i, p in enumerate(plan.phases)
        ],
    }

    driver = _Driver(
        graph=graph, template=template, backend=backend, dg=dg, stats=stats,
        plan=plan, res=resilience, collect_stats=collect_stats,
        mesh=mesh, backend_kw=backend_kw, initial_state=initial_state,
    )
    driver.run()
    return driver.finish()


def _maybe_resolve_plan(graph, dg, template, constraints, label_freq):
    from repro.kernels import registry

    policy = registry.get_policy()
    if policy is None or not policy.plans:
        return None
    from repro.graph import stats as gstats

    if isinstance(graph, Graph):
        st = gstats.collect_graph_stats(graph)
    else:
        nl = (len(label_freq) if label_freq is not None
              else int(np.asarray(dg.labels).max()) + 1)
        st = gstats.collect_graph_stats(dg, n_labels=nl)
    return planner_mod.resolve_query_plan(template, constraints, st)


def _check_plan(plan, constraints):
    """An explicit/cached plan must cover exactly the constraints this run
    generates — same multiset of signatures — or phase identity is broken."""
    want = sorted(planner_mod.constraint_signature(c) for c in constraints)
    got = sorted(plan.signatures())
    if want != got:
        raise ValueError(
            f"query plan does not match generated constraints: plan phases "
            f"{got} != constraints {want}")


class _Driver:
    """The re-enterable phase loop. Phase 0 = initial LCC; phase k (1..K) =
    constraint k + conditional LCC. `completed` is the last committed phase;
    a fault rolls it back to the restored checkpoint's phase and the loop
    simply re-enters. Phase snapshots are STAGED per attempt and committed
    only on success, so retried/replayed work never duplicates trajectory
    entries."""

    def __init__(self, *, graph, template, backend, dg, stats, plan,
                 res, collect_stats, mesh, backend_kw, initial_state):
        self.graph = graph
        self.template = template
        self.backend = backend
        self.dg = dg  # ORIGINAL DeviceGraph — result/checkpoint coordinates
        self.stats = stats
        self.plan = plan
        self.phases = plan.phases
        self.constraints = plan.constraints()
        # phase identity BY SIGNATURE (not positional index): checkpoints of
        # one plan must never resume under another (core/resilience.py).
        # Identity includes engine+direction — a direction change alters the
        # committed state, so same-order different-direction plans differ.
        self.plan_sigs = plan.identities()
        self.res = res
        self.inj = res.injector if res is not None else None
        self.collect_stats = collect_stats
        self.mesh = mesh
        self.backend_kw = backend_kw
        self.initial_state = initial_state
        self.K = len(self.constraints)
        self.completed = -1
        self.committed: List[Tuple[int, tuple]] = []  # (phase idx, raw entry)
        self._stage: List[tuple] = []
        # coordinate map back to the original graph after an elastic
        # compact-and-reshuffle; None = still in original coordinates
        self.remap: Optional["loadbalance.ElasticRemap"] = None
        self.restarts = 0
        self._recovery_t0: Optional[float] = None

    # -- phase bodies -------------------------------------------------------
    def _phase_initial(self):
        t0 = time.perf_counter()
        self.backend.lcc(self.stats)
        self._snap("LCC", None, t0, {})

    def _phase_constraint(self, k: int):
        p = self.phases[k - 1]
        c = p.constraint
        t0 = time.perf_counter()
        cstats: Dict = {}
        if p.engine == planner_mod.ENGINE_NLCC:
            changed = self.backend.nlcc(c, cstats, direction=p.direction)
        else:
            changed = self.backend.tds(c, cstats)
        self._snap(f"NLCC-{c.kind}", str(c.walk), t0, cstats)
        # predicted-vs-actual for the plan report; assignment (not +=) so a
        # resilience replay of the phase records only the committed attempt
        self.stats["plan"]["phases"][k - 1]["actual_s"] = (
            time.perf_counter() - t0)
        # ONE device bool decides the re-run — not six blocking count reads
        if bool(changed):
            t0 = time.perf_counter()
            self.backend.lcc(self.stats)
            self._snap("LCC", None, t0, {})

    def _snap(self, phase, cname, t0, extra):
        # the phase's wall time must include its device work (the recorded
        # perf trajectory compares PR-over-PR), so fence the stream — a sync
        # with NO transfer — before timestamping. The snapshot counts stay a
        # lazy device value until ONE materialization at the end of the run;
        # eager host counts only under collect_stats=True (satellite of PR 4)
        self.backend.sync()
        secs = time.perf_counter() - t0
        counts = (self.backend.counts_host() if self.collect_stats
                  else self.backend.counts_dev())
        self._stage.append((phase, cname, secs, extra, counts))

    # -- driver loop --------------------------------------------------------
    def run(self):
        if self.inj is None:
            return self._loop()
        from repro.kernels import registry

        # every registry.dispatch anywhere in the run reports to the
        # injector (the "dispatch" site / per-kernel fault seam)
        with registry.dispatch_hook(self.inj.on_dispatch):
            return self._loop()

    def _loop(self):
        while True:
            try:
                while self.completed < self.K:
                    k = self.completed + 1
                    self._run_phase(k)
                    self._after_phase(k)
                return
            except (resilience_mod.ShardLost,
                    resilience_mod.PhaseFailed) as e:
                self._recover(e)

    def _run_phase(self, k: int):
        if self.inj is not None:
            self.inj.begin_phase(k)
        if k == 0:
            body = self._phase_initial
        else:
            body = functools.partial(self._phase_constraint, k)

        def attempt():
            self._stage = []
            body()

        if self.res is None:
            attempt()
        else:
            resilience_mod.run_phase_with_ladder(
                attempt,
                snapshot=self.backend.snapshot,
                restore=self.backend.restore_snapshot,
                retry=self.res.retry,
                injector=self.inj,
                on_chunk_backoff=self._chunk_backoff,
                ladder_log=self.stats["resilience"]["ladder"],
            )
        self.committed.extend((k, entry) for entry in self._stage)
        self._stage = []
        self.completed = k

    def _chunk_backoff(self, factor: int):
        # shrink the TDS chunk on the live backend AND in the restart kwargs,
        # so a later elastic restart keeps the backed-off size
        self.backend.tds_chunk = max(1, self.backend.tds_chunk // factor)
        self.backend_kw["tds_chunk"] = self.backend.tds_chunk

    def _after_phase(self, k: int):
        res = self.res
        if res is None:
            return
        every = max(res.checkpoint_every, 1)
        if res.checkpoint_dir is not None and k % every == 0:
            self._checkpoint(k)
        el = res.elastic
        if (el is not None and el.imbalance_trigger is not None
                and k < self.K and self._sharded()):
            # satellite: shard-local device counts, ONE small [P,2] readback
            counts = np.asarray(self.backend.shard_counts_dev())
            from repro.core import loadbalance

            bs = loadbalance.imbalance_stats_from_counts(
                counts[:, 0], counts[:, 1])
            if (counts[:, 1].sum() > 0
                    and bs.max_over_mean_edges > el.imbalance_trigger):
                self._rebalance(k, bs)

    def _sharded(self) -> bool:
        return isinstance(self.backend, engine_mod._ShardedBackend)

    def _freeze_committed(self):
        """Materialize committed deferred phase counts to host values. Called
        before the backend is swapped: the lazy device counts of already-
        committed phases live on the OLD backend's mesh and cannot be stacked
        with the new one's in the final one-sync materialization."""
        frozen = []
        for k, (phase, cname, secs, extra, counts) in self.committed:
            if not isinstance(counts, dict):
                c = np.asarray(counts)
                counts = {"active_vertices": int(c[0]),
                          "active_edges": int(c[1]),
                          "omega_bits": int(c[2])}
            frozen.append((k, (phase, cname, secs, extra, counts)))
        self.committed = frozen

    # -- checkpointing ------------------------------------------------------
    def _state_np_original(self) -> Tuple[np.ndarray, np.ndarray]:
        """(omega, edge_active) as host arrays in ORIGINAL coordinates."""
        from repro.core import loadbalance

        state = self.backend.final_state()
        omega = np.asarray(state.omega, bool)
        ea = np.asarray(state.edge_active, bool)
        if self.remap is not None:
            st = loadbalance.remap_state_to_original(
                PruneState(omega=omega, edge_active=ea), self.remap,
                self.template.n0)
            omega, ea = np.asarray(st.omega), np.asarray(st.edge_active)
        return omega, ea

    def _checkpoint(self, k: int):
        from repro.checkpoint import ckpt

        t0 = time.perf_counter()
        omega, ea = self._state_np_original()
        meta = {"phase": int(k), "backend": self.backend.name,
                "n": int(self.dg.n), "m": int(ea.size),
                "n0": int(self.template.n0),
                # phase identity BY CONSTRAINT SIGNATURE: a resumed run under
                # a different (e.g. newly tuned) plan must refuse cleanly
                # rather than replay the wrong phase at position k
                "phase_sig": self._phase_sig(k),
                "plan_sigs": list(self.plan_sigs)}
        part = getattr(self.backend, "part", None)
        if part is not None:
            meta["partition"] = part.meta()
        ckpt.save_checkpoint(
            self.res.checkpoint_dir, k, {"omega": omega, "edge_active": ea},
            extra_meta=meta, keep=self.res.keep)
        rs = self.stats["resilience"]
        rs["checkpoints"] += 1
        rs["checkpoint_seconds"].append(time.perf_counter() - t0)

    def _phase_sig(self, k: int) -> str:
        """Signature identity of phase k: the initial LCC for k=0, else the
        planned constraint the phase verified."""
        return "lcc:init" if k == 0 else self.plan_sigs[k - 1]

    def _check_ckpt_plan(self, meta: Dict, phase0: int):
        """Refuse to resume a checkpoint written under a different plan.
        Checkpoints predating the plan field (no "plan_sigs") fall back to
        the old positional-index identity."""
        stored = meta.get("plan_sigs")
        if stored is not None and list(stored) != list(self.plan_sigs):
            raise resilience_mod.PlanMismatch(
                f"checkpoint at phase {phase0} was written under plan "
                f"{list(stored)} but this run executes {list(self.plan_sigs)}"
                " — phases are keyed by constraint signature; delete the "
                "checkpoint or re-run under the original plan")
        stored_sig = meta.get("phase_sig")
        if (stored_sig is not None and 0 <= phase0 <= len(self.plan_sigs)
                and str(stored_sig) != self._phase_sig(phase0)):
            raise resilience_mod.PlanMismatch(
                f"checkpoint phase {phase0} is {stored_sig!r} but this "
                f"run's phase {phase0} is {self._phase_sig(phase0)!r}")

    # -- recovery -----------------------------------------------------------
    def _recover(self, cause: BaseException):
        from repro.checkpoint import ckpt

        res = self.res
        if res.checkpoint_dir is None:
            raise resilience_mod.ResilienceExhausted(
                "phase failed and no checkpoint_dir is configured — "
                "cannot recover") from cause
        if self.restarts >= res.max_restarts:
            raise resilience_mod.ResilienceExhausted(
                f"restart budget exhausted after {self.restarts} "
                "restarts") from cause
        self.restarts += 1
        t0 = time.perf_counter()
        if self._recovery_t0 is None:
            self._recovery_t0 = t0
        n, n0 = int(self.dg.n), self.template.n0
        m = int(np.asarray(self.dg.src).size)
        like = {"omega": np.zeros((n, n0), bool),
                "edge_active": np.zeros((m,), bool)}
        try:
            # torn/corrupt checkpoint dirs are skipped inside (satellite)
            tree, meta = ckpt.restore_checkpoint(res.checkpoint_dir, like)
            state0 = PruneState(
                omega=np.asarray(tree["omega"], bool),
                edge_active=np.asarray(tree["edge_active"], bool))
            phase0 = int(meta["phase"])
            self._check_ckpt_plan(meta, phase0)
        except FileNotFoundError:
            state0, phase0 = None, -1  # nothing saved yet: re-prune fresh
        P_old = int(getattr(self.backend, "P", 1))
        P_new = P_old
        if res.elastic is not None and res.elastic.restart_P:
            P_new = int(res.elastic.restart_P)
        self._switch_backend(state0, P_new)
        # phases past the snapshot will be re-run — drop their entries
        self.committed = [(k, e) for k, e in self.committed if k <= phase0]
        self.completed = phase0
        self.stats["resilience"]["restarts"].append({
            "cause": type(cause).__name__,
            "restored_phase": phase0,
            "from_P": P_old, "to_P": P_new,
            "seconds": time.perf_counter() - t0,
        })

    def _switch_backend(self, state0: Optional[PruneState], P_new: int):
        """Rebuild the execution backend after a fatal fault: compact the
        restored original-coordinate snapshot onto P_new shards (elastic),
        or — when nothing was pruned yet / the active subgraph is degenerate
        / the backend is local — plainly repartition the original graph."""
        from repro.core import loadbalance

        self._freeze_committed()
        was_sharded = self._sharded()
        kw = dict(self.backend_kw)
        seed = self.res.elastic.seed if self.res.elastic is not None else 0
        handoff = None
        if was_sharded and isinstance(self.graph, Graph) and state0 is not None:
            handoff = loadbalance.elastic_handoff(
                self.graph, self.dg, state0, P_new, seed=seed)
        if handoff is not None:
            g_new, part_new, state_new, remap = handoff
            self.backend = engine_mod.make_backend(
                g_new, self.template, mesh=self._mesh_for(P_new),
                partition=part_new, **kw)
            self.backend.init(PruneState(
                omega=jnp.asarray(state_new.omega),
                edge_active=jnp.asarray(state_new.edge_active)))
            self.remap = remap
        else:
            mesh_new = self._mesh_for(P_new) if was_sharded else None
            partition = (P_new if (was_sharded and mesh_new is None)
                         else None)
            self.backend = engine_mod.make_backend(
                self.graph, self.template, mesh=mesh_new,
                partition=partition, **kw)
            if state0 is not None:
                self.backend.init(PruneState(
                    omega=jnp.asarray(state0.omega),
                    edge_active=jnp.asarray(state0.edge_active)))
            else:
                self.backend.init(self.initial_state)
            self.remap = None
        self.backend.record_routes(self.stats)

    def _mesh_for(self, P_new: int):
        """The mesh a restarted spmd backend runs on: the original mesh when
        the shard count is unchanged, else a fresh flat mesh over the first
        P_new devices (the recover-onto-smaller-mesh path)."""
        if self.mesh is None:
            return None
        if int(np.prod(tuple(self.mesh.shape.values()))) == P_new:
            return self.mesh
        from repro.launch.mesh import make_shard_mesh

        return make_shard_mesh(P_new)

    # -- imbalance-triggered rebalance (no fault) ---------------------------
    def _rebalance(self, k: int, bs):
        from repro.core import loadbalance

        if not isinstance(self.graph, Graph):
            return
        el = self.res.elastic
        t0 = time.perf_counter()
        omega, ea = self._state_np_original()
        P_old = int(self.backend.P)
        P_new = int(el.rebalance_P) if el.rebalance_P else P_old
        handoff = loadbalance.elastic_handoff(
            self.graph, self.dg,
            PruneState(omega=omega, edge_active=ea), P_new, seed=el.seed)
        if handoff is None:
            return  # degenerate active subgraph: nothing to balance
        self._freeze_committed()
        g_new, part_new, state_new, remap = handoff
        self.backend = engine_mod.make_backend(
            g_new, self.template, mesh=self._mesh_for(P_new),
            partition=part_new, **dict(self.backend_kw))
        self.backend.init(PruneState(
            omega=jnp.asarray(state_new.omega),
            edge_active=jnp.asarray(state_new.edge_active)))
        self.remap = remap
        self.backend.record_routes(self.stats)
        self.stats["resilience"]["rebalances"].append({
            "phase": k, "from_P": P_old, "to_P": P_new,
            "max_over_mean_before": float(bs.max_over_mean_edges),
            "seconds": time.perf_counter() - t0,
        })

    # -- finalization -------------------------------------------------------
    def finish(self) -> PruneResult:
        self.backend.finalize_stats(self.stats)
        if self.res is not None and self._recovery_t0 is not None:
            self.stats["resilience"]["recovery_seconds"] = (
                time.perf_counter() - self._recovery_t0)
        raw = [entry for _, entry in self.committed]
        if self.remap is None:
            state = self.backend.final_state()
            result_backend = self.backend
        else:
            # the run finished on a compacted/reshuffled graph: express the
            # state in original coordinates (bit-identical to fault-free by
            # monotonicity) and drop the backend — its shard arrays no
            # longer describe `dg`, so enumeration takes the host route
            omega, ea = self._state_np_original()
            state = PruneState(omega=jnp.asarray(omega),
                               edge_active=jnp.asarray(ea))
            result_backend = None
        return PruneResult(state, self.template, self.dg,
                           _materialize(raw), self.stats,
                           backend=result_backend)


def _materialize(raw_phases: List[tuple]) -> List[PhaseStat]:
    """Turn accumulated snapshots into PhaseStats. Deferred (device-array)
    counts are stacked and transferred in ONE host sync."""
    deferred = [c for *_, c in raw_phases if not isinstance(c, dict)]
    if deferred:
        mat = iter(np.asarray(jnp.stack(deferred)))
    phases: List[PhaseStat] = []
    for phase, cname, secs, extra, counts in raw_phases:
        if isinstance(counts, dict):
            av, ae, ob = (counts["active_vertices"], counts["active_edges"],
                          counts["omega_bits"])
        else:
            av, ae, ob = (int(x) for x in next(mat))
        phases.append(PhaseStat(
            phase=phase, constraint=cname, seconds=secs,
            active_vertices=av, active_edges=ae, omega_bits=ob, extra=extra))
    return phases

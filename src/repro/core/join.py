"""Match-enumeration join engines over the pruned solution subgraph (§4).

Two engines run the same constrained-walk join (expand the frontier column
along active arcs; filter by omega-candidacy, injectivity, revisit-edge
existence, and GraphPi-style symmetry restrictions):

  HostJoin    the numpy row-table join over the compacted active subgraph
              (`core/tds.py` step primitives) — the single-host path.
  DeviceJoin  jnp programs written against the execution-backend prims
              (core/engine.py): the row table is REPLICATED across shards,
              each expansion slot is produced by exactly one shard (the owner
              of the frontier vertex expands over its shard-local CSR arcs),
              and the per-slot results are psum-combined — the only
              collectives are psum (slot exchange + completion counts) and
              the once-per-join psum all-gather of the walk's candidacy
              columns ("frontier columns") from their owner shards. With
              `local_prims` (P=1, identity collectives) the same programs are
              the single-device device-resident join.

Both engines share the slot layout: expansion capacity comes from STATIC
per-vertex degrees and arcs are ordered by (src, dst-global), so the row
tables agree row-for-row between the local plan and any shard count — the
basis of the sharded-vs-local enumeration bit-parity suite.

Walk-step metadata (`walk_steps`) attaches each symmetry restriction
phi(a) < phi(b) (template.symmetry_restrictions) to the join step that
assigns the later of the two vertices, so restricted counting needs no
post-hoc dedup: restricted_count * |Aut| == the embedding count.

`stream_join` is the bounded-memory streaming emitter: a depth-first walk
over row blocks, splitting each block before expansion so no step's output
exceeds the row budget (single rows whose fan-out alone exceeds the budget
are the only exception); completed blocks are yielded to the caller instead
of materializing every row at once.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.graph.structs import DeviceGraph
from repro.core.state import PruneState
from repro.core.template import Template
from repro.core import tds as tds_mod
from repro.core.tds import ActiveSubgraph, TdsOverflow


# --------------------------------------------------------------- walk steps
@dataclasses.dataclass(frozen=True)
class JoinStep:
    kind: str  # "expand" | "revisit"
    c_prev: int  # row column holding the frontier vertex
    c_tgt: int  # expand: the new column's index; revisit: the target column
    q_next: int  # template vertex this step lands on
    n_cols: int  # columns assigned before this step (injectivity scope)
    restr: Tuple[Tuple[int, str], ...] = ()  # (col, "gt"/"lt") checks vs new vertex

    def key(self) -> Tuple:
        return (self.kind, self.c_prev, self.c_tgt, self.n_cols, self.restr)


def walk_steps(
    walk: Sequence[int],
    restrictions: Tuple[Tuple[int, int], ...] = (),
) -> Tuple[List[JoinStep], List[int]]:
    """Per-step join metadata for a walk. Each restriction pair (a, b) —
    phi(a) < phi(b) — is checked at the step that assigns the LATER of the
    two vertices (the earlier one is then a bound row column), so a walk
    covering every template vertex enforces every restriction in-flight.
    Returns (steps, seen_q = template vertices in first-visit order)."""
    seen: List[int] = [walk[0]]
    steps: List[JoinStep] = []
    for r in range(1, len(walk)):
        q_prev, q_next = walk[r - 1], walk[r]
        c_prev = seen.index(q_prev)
        if q_next in seen:
            steps.append(JoinStep("revisit", c_prev, seen.index(q_next),
                                  q_next, len(seen)))
        else:
            checks = []
            for a, b in restrictions:
                if q_next == b and a in seen:
                    checks.append((seen.index(a), "gt"))
                elif q_next == a and b in seen:
                    checks.append((seen.index(b), "lt"))
            steps.append(JoinStep("expand", c_prev, len(seen), q_next,
                                  len(seen), tuple(checks)))
            seen.append(q_next)
    return steps, seen


def _pow2(x: int) -> int:
    b = 1
    while b < x:
        b <<= 1
    return b


_INT32_MAX = 2**31 - 1


def _guard_int32(count: int, what: str) -> None:
    """Slot indices, psum-combined keep bits, and exchange bucket offsets are
    int32 on device (x64 is off by default) — a count past 2^31 would wrap
    silently. Mirror of the engine's slot-map guard: fail loudly and name the
    remedies instead of returning garbage."""
    if count > _INT32_MAX:
        raise NotImplementedError(
            f"{what} = {count} exceeds int32; the join's device-side slot "
            "indices and psum-combined counts would overflow — shard finer, "
            "lower max_rows / the streaming budget, or add a 64-bit slot map")


# -------------------------------------------------- per-shard join programs
def _prims(axis_name: Optional[str]):
    from repro.core import engine as engine_mod

    return (engine_mod.axis_prims(axis_name) if axis_name
            else engine_mod.local_prims())


def _expand_program(axis_name: Optional[str], step: JoinStep, n_local: int):
    """One expansion step: slot t belongs to (parent row, within-frontier arc
    j); the frontier vertex's owner shard reads the arc from its local CSR,
    applies every filter, and contributes (vertex, keep) to the psum — all
    other shards contribute zeros, so the psum IS the owner-shard exchange."""

    def program(plan, arc_active, cand_col, deg, rows, parent, j):
        prims = _prims(axis_name)
        p = prims.axis_index()
        A = plan["arc_dst"].shape[0]
        up = jnp.take(rows[:, step.c_prev], parent)  # frontier vertex per slot
        own = (up // n_local) == p
        u_lo = jnp.where(own, up % n_local, n_local)
        start = jnp.take(plan["csr_off"], u_lo)
        idx = jnp.minimum(start + j, A - 1)
        v = jnp.take(plan["arc_dst"], idx)
        ok = own & (j < jnp.take(deg, up)) & jnp.take(arc_active, idx)
        ok &= jnp.take(cand_col, jnp.minimum(v, cand_col.shape[0] - 1))
        for c in range(step.n_cols):  # injectivity vs every assigned column
            ok &= v != jnp.take(rows[:, c], parent)
        for col, op in step.restr:  # symmetry restrictions, in-flight
            ref = jnp.take(rows[:, col], parent)
            ok &= (v > ref) if op == "gt" else (v < ref)
        vi = jnp.where(ok, v, 0).astype(jnp.int32)
        return prims.psum(vi), prims.psum(ok.astype(jnp.int32))

    return program


def _revisit_program(axis_name: Optional[str], step: JoinStep, n_local: int,
                     iters: int):
    """One revisit step: the frontier vertex's owner shard binary-searches its
    local (src, dst-global)-sorted arcs for the revisit edge; per-row keep
    bits are psum-combined (non-owners contribute zero)."""

    def program(plan, arc_active, deg, rows):
        prims = _prims(axis_name)
        p = prims.axis_index()
        A = plan["arc_dst"].shape[0]
        u = rows[:, step.c_prev]
        v = rows[:, step.c_tgt]
        own = (u // n_local) == p
        u_lo = jnp.where(own, u % n_local, n_local)
        lo0 = jnp.take(plan["csr_off"], u_lo)
        dv = jnp.where(own, jnp.take(deg, u), 0)
        lo, hi = lo0, lo0 + dv
        for _ in range(iters):  # vectorized lower_bound over the CSR segment
            cont = lo < hi
            mid = (lo + hi) // 2
            less = jnp.take(plan["arc_dst"], jnp.minimum(mid, A - 1)) < v
            lo = jnp.where(cont & less, mid + 1, lo)
            hi = jnp.where(cont & ~less, mid, hi)
        li = jnp.minimum(lo, A - 1)
        found = own & (lo < lo0 + dv) & (jnp.take(plan["arc_dst"], li) == v)
        keep = found & jnp.take(arc_active, li)
        return prims.psum(keep.astype(jnp.int32))

    return program


def _cols_program(axis_name: Optional[str], qs: Tuple[int, ...], n_local: int,
                  n_pad: int):
    """Frontier-column exchange: each shard scatters its slice of the
    requested omega candidacy columns into the global id space; the psum
    replicates the full columns on every shard (one collective per join)."""

    def program(omega_shard):
        prims = _prims(axis_name)
        p = prims.axis_index()
        cols = []
        for q in qs:
            w, b = q // 32, q % 32
            col = ((omega_shard[:n_local, w] >> jnp.uint32(b)) & 1).astype(
                jnp.int32)
            full = jnp.zeros((n_pad + 1,), jnp.int32)
            full = jax.lax.dynamic_update_slice(full, col, (p * n_local,))
            cols.append(full)
        return prims.psum(jnp.stack(cols)) > 0

    return program


# ----------------------------------------------- row-sharded join programs
# The distributed-rows join (RowShardedJoin): each shard holds ONLY the rows
# whose next frontier vertex it owns (owner = v // n_local — the partition's
# block rule, so the owner also holds every arc of v in its join-plan CSR).
# Expansion is then purely local — no psum over full-width slot tensors; the
# only per-step collective is ONE `exchange_rows` routing the surviving rows
# to their next owners in pow2-padded buckets sized by host-readable counts.
# The once-per-join candidacy-column all-gather (`_cols_program`) stays the
# only replicated state.
def _owner_stats(vals, ok, deg, n_local: int, P: int) -> jnp.ndarray:
    """int32[2, P] per next-owner shard: surviving-row counts (the bucket
    sizes of the next `exchange_rows`) AND the summed degree of the next
    frontier column (the next expansion's slot capacity). Both ride one
    handshake readback — the capacity half is what lets the NEXT step skip
    its own frontier-column readback entirely."""
    owner = jnp.where(ok, vals // n_local, P).astype(jnp.int32)
    oh = (owner[:, None] == jnp.arange(P, dtype=jnp.int32)[None, :]
          ).astype(jnp.int32)
    dw = jnp.take(deg, jnp.where(ok, vals, 0)) * ok.astype(jnp.int32)
    return jnp.stack([jnp.sum(oh, axis=0), jnp.sum(oh * dw[:, None], axis=0)])


def _rowshard_expand_program(axis_name: Optional[str], step: JoinStep,
                             n_local: int, P: int, oc: Optional[int],
                             Tb: int):
    """One expansion step over the OWNED row block: by the ownership
    invariant every real row's frontier vertex is shard-local, so the CSR
    read needs no collective at all. The slot layout (parent row, arc j) is
    computed ON DEVICE from the static degree table — an exact mirror of
    `tds.slot_parents`, so the row sets stay bit-identical to the replicated
    engine — sized by `Tb`, the pow2 capacity the PREVIOUS step's folded
    handshake reported. Returns per-slot (vertex, keep, parent) plus the
    next-owner (count, capacity) matrix (`oc` = next frontier column in the
    widened row layout; None on the walk's last step, where the count is
    scalar)."""

    def program(plan, arc_active, rows, cand_col, deg):
        prims = _prims(axis_name)
        p = prims.axis_index()
        A = plan["arc_dst"].shape[0]
        Rb = rows.shape[0]
        # device slot layout (mirror of tds.slot_parents: padding slots land
        # on the last row with j >= its degree, so every filter rejects them)
        degrow = jnp.take(deg, rows[:, step.c_prev])  # sink rows -> 0
        cum = jnp.cumsum(degrow)
        t = jnp.arange(Tb, dtype=jnp.int32)
        parent = jnp.minimum(
            jnp.searchsorted(cum, t, side="right"), Rb - 1).astype(jnp.int32)
        j = t - jnp.take(cum - degrow, parent)
        up = jnp.take(rows[:, step.c_prev], parent)  # frontier vertex, local
        u_lo = jnp.clip(up - p * n_local, 0, n_local)  # sink rows -> pad row
        start = jnp.take(plan["csr_off"], u_lo)
        idx = jnp.minimum(start + j, A - 1)
        v = jnp.take(plan["arc_dst"], idx)
        ok = (j < jnp.take(deg, up)) & jnp.take(arc_active, idx)
        ok &= jnp.take(cand_col, jnp.minimum(v, cand_col.shape[0] - 1))
        for c in range(step.n_cols):  # injectivity vs every assigned column
            ok &= v != jnp.take(rows[:, c], parent)
        for col, op in step.restr:  # symmetry restrictions, in-flight
            ref = jnp.take(rows[:, col], parent)
            ok &= (v > ref) if op == "gt" else (v < ref)
        vi = jnp.where(ok, v, 0).astype(jnp.int32)
        if oc is None:
            cm = jnp.sum(ok.astype(jnp.int32))[None]
        else:
            nf = vi if oc == step.n_cols else jnp.take(rows[:, oc], parent)
            cm = _owner_stats(nf, ok, deg, n_local, P)
        return vi, ok, parent, cm

    return program


def _rowshard_revisit_program(axis_name: Optional[str], step: JoinStep,
                              n_local: int, iters: int, P: int,
                              oc: Optional[int]):
    """One revisit step over the OWNED row block: shard-local binary search
    of the (src, dst-global)-sorted CSR — no psum of keep bits."""

    def program(plan, arc_active, rows, deg):
        prims = _prims(axis_name)
        p = prims.axis_index()
        A = plan["arc_dst"].shape[0]
        u = rows[:, step.c_prev]
        v = rows[:, step.c_tgt]
        u_lo = jnp.clip(u - p * n_local, 0, n_local)
        lo0 = jnp.take(plan["csr_off"], u_lo)
        dv = jnp.take(deg, u)  # sink rows -> degree 0
        lo, hi = lo0, lo0 + dv
        for _ in range(iters):  # vectorized lower_bound over the CSR segment
            cont = lo < hi
            mid = (lo + hi) // 2
            less = jnp.take(plan["arc_dst"], jnp.minimum(mid, A - 1)) < v
            lo = jnp.where(cont & less, mid + 1, lo)
            hi = jnp.where(cont & ~less, mid, hi)
        li = jnp.minimum(lo, A - 1)
        found = (lo < lo0 + dv) & (jnp.take(plan["arc_dst"], li) == v)
        keep = found & jnp.take(arc_active, li)
        if oc is None:
            cm = jnp.sum(keep.astype(jnp.int32))[None]
        else:
            cm = _owner_stats(rows[:, oc], keep, deg, n_local, P)
        return keep, cm

    return program


def _rowshard_route_program(axis_name: Optional[str], n_local: int, P: int,
                            Br: int, Rb2: int, oc: int, expand: bool):
    """Route surviving rows to their next-owner shards: stable-sort slots by
    owner, lay them into [P, Br] buckets sized from the host-read count
    matrix (pad — NEVER drop: Br >= every bucket's occupancy by
    construction), ONE `exchange_rows`, then compact the received buckets
    into the next pow2 block. Bucket layout is derived from `cnt` alone, so
    shapes are static and the layout is deterministic."""
    n_pad = P * n_local

    def route(cand_rows, ok, cnt, prims):
        p = prims.axis_index()
        Cw = cand_rows.shape[1]
        nf = cand_rows[:, oc]
        owner = jnp.where(ok, nf // n_local, P).astype(jnp.int32)
        order = jnp.argsort(owner)  # stable: kept rows by owner, pads last
        cnt_out = cnt[p]  # [P] rows this shard sends to each owner
        start = jnp.concatenate([
            jnp.zeros((1,), jnp.int32),
            jnp.cumsum(cnt_out)[:-1].astype(jnp.int32)])
        b = jnp.arange(Br, dtype=jnp.int32)
        src = start[:, None] + b[None, :]  # [P, Br] slot in sorted order
        valid = b[None, :] < cnt_out[:, None]
        idx = jnp.take(order, jnp.minimum(src, order.shape[0] - 1))
        send = jnp.take(cand_rows, idx.reshape(-1), axis=0).reshape(P, Br, Cw)
        send = jnp.where(valid[..., None], send, jnp.int32(n_pad))
        recv = prims.exchange_rows(send)  # [P, Br]: slice q = from shard q
        cnt_in = cnt[:, p]  # [P] rows each shard sent here
        mask = (b[None, :] < cnt_in[:, None]).reshape(-1)
        sel = jnp.nonzero(mask, size=Rb2, fill_value=P * Br)[0]
        flat = jnp.concatenate([
            recv.reshape(P * Br, Cw),
            jnp.full((1, Cw), n_pad, jnp.int32)], axis=0)
        return jnp.take(flat, sel, axis=0)  # [Rb2, Cw], sinks past the count

    if expand:
        def program(rows, parent, newv, ok, cnt):
            prims = _prims(axis_name)
            prow = jnp.take(rows, parent, axis=0)
            cand_rows = jnp.concatenate([prow, newv[:, None]], axis=1)
            return route(cand_rows, ok, cnt, prims)
    else:
        def program(rows, ok, cnt):
            return route(rows, ok, cnt, _prims(axis_name))
    return program


def _rowshard_tail_program(axis_name: Optional[str], n_local: int, P: int,
                           Kp: int, expand: bool):
    """The walk's last step has no next owner: compact the surviving slots
    into the final per-shard block in slot order (no exchange)."""
    n_pad = P * n_local

    def compact(cand_rows, ok):
        sel = jnp.nonzero(ok, size=Kp, fill_value=ok.shape[0])[0]
        flat = jnp.concatenate([
            cand_rows,
            jnp.full((1, cand_rows.shape[1]), n_pad, jnp.int32)], axis=0)
        return jnp.take(flat, sel, axis=0)

    if expand:
        def program(rows, parent, newv, ok):
            prow = jnp.take(rows, parent, axis=0)
            return compact(jnp.concatenate([prow, newv[:, None]], axis=1), ok)
    else:
        def program(rows, ok):
            return compact(rows, ok)
    return program


# ------------------------------------------------------------ join contexts
# Compiled local join programs, shared across LocalJoinContext instances
# (one context is built per enumerate_matches call — without this cache every
# call would re-jit and recompile every step program from scratch). Keys are
# (program key, n_local, n_pad, A): everything a program factory closes over
# beyond its arguments. Bounded: cleared wholesale when it outgrows the cap.
_LOCAL_FN_CACHE: Dict = {}
_LOCAL_FN_CACHE_CAP = 512


class LocalJoinContext:
    """Single-device context for the device join: the identity-exchange
    degenerate case (P=1). Built from static topology (one (src, dst) arc
    sort) plus device gathers of the pruned state — the reduced subgraph is
    never materialized on the host."""

    axis_name: Optional[str] = None

    def __init__(self, dg: DeviceGraph, state: PruneState):
        src = np.asarray(dg.src)
        dst = np.asarray(dg.dst)
        n = dg.n
        self.n_local = n
        self.n_pad = n
        order = np.lexsort((dst, src))  # by (src, dst): the canonical layout
        counts = np.bincount(src, minlength=n) if src.size else np.zeros(n, np.int64)
        off = np.zeros(n + 1, np.int64)
        off[1:] = np.cumsum(counts)
        deg = np.zeros(n + 1, np.int64)
        deg[:n] = counts
        if src.size:
            arc_dst = dst[order].astype(np.int32)
            arc_active = jnp.take(jnp.asarray(state.edge_active),
                                  jnp.asarray(order.astype(np.int32)))
        else:  # degenerate edgeless graph: one inactive sink arc
            arc_dst = np.asarray([n], np.int32)
            arc_active = jnp.zeros((1,), bool)
        self.A = int(arc_dst.shape[0])
        self.plan = {
            "csr_off": jnp.asarray(off.astype(np.int32)),
            "arc_dst": jnp.asarray(arc_dst),
        }
        self.deg = jnp.asarray(deg.astype(np.int32))
        self.arc_active = arc_active
        self._omega = state.omega

    def cols(self, qs: Tuple[int, ...]) -> jnp.ndarray:
        cols = jnp.stack([self._omega[:, q] for q in qs], axis=0)
        return jnp.concatenate(
            [cols, jnp.zeros((len(qs), 1), bool)], axis=1)

    def wrap(self, key, factory: Callable, n_sharded: int) -> Callable:
        cache_key = (key, self.n_local, self.n_pad, self.A)
        if cache_key not in _LOCAL_FN_CACHE:
            if len(_LOCAL_FN_CACHE) >= _LOCAL_FN_CACHE_CAP:
                _LOCAL_FN_CACHE.clear()
            _LOCAL_FN_CACHE[cache_key] = jax.jit(factory(self.axis_name))
        return _LOCAL_FN_CACHE[cache_key]


class ShardedJoinContext:
    """Context over a sharded execution backend (core/engine.py sim/spmd):
    the join programs run through the backend's program wrapper (vmap or
    shard_map) against the partition's join plan, reading the DEVICE-RESIDENT
    pruned state (omega_all / ea_all) directly — no gather of the reduced
    subgraph, no host-side compaction."""

    def __init__(self, backend):
        from repro.core import engine as engine_mod

        self.axis_name = engine_mod.SHARD_AXIS
        self._backend = backend
        part = backend.part
        plan = part.join_plan()
        dev = part.join_plan_dev()  # static arrays uploaded once per partition
        self.part = part
        self.P = part.P
        self.n_local = part.n_local
        self.n_pad = plan.n_pad
        self.A = plan.A
        self.plan = {
            "csr_off": dev["csr_off"],
            "arc_dst": dev["arc_dst"],
        }
        self.deg = dev["deg"]
        self.row_plan = part.row_plan()
        ea_flat = backend.ea_all.reshape(part.P, plan.A)
        self.arc_active = jnp.take_along_axis(ea_flat, dev["perm"], axis=1)
        self._fns: Dict = {}

    def cols(self, qs: Tuple[int, ...]) -> jnp.ndarray:
        fn = self.wrap(
            ("join_cols", tuple(qs)),
            lambda axis: _cols_program(axis, tuple(qs), self.n_local,
                                       self.n_pad),
            n_sharded=1,
        )
        return fn(self._backend.omega_all)

    def wrap(self, key, factory: Callable, n_sharded: int) -> Callable:
        if key not in self._fns:
            inner = self._backend._fn(key, factory(self.axis_name), n_sharded)
            # replicated outputs: every shard holds the same psum result
            self._fns[key] = lambda *a: jax.tree_util.tree_map(
                lambda x: x[0], inner(*a))
        return self._fns[key]

    def wrap_rows(self, key, factory: Callable, n_sharded: int) -> Callable:
        """Like `wrap`, but outputs stay PER-SHARD [P, ...] — the row-sharded
        join's blocks differ across shards by construction (that is the
        point), so nothing may be collapsed to shard 0's copy."""
        rkey = ("rows",) + (key if isinstance(key, tuple) else (key,))
        return self._backend._fn(rkey, factory(self.axis_name), n_sharded)


# ------------------------------------------------------------- join engines
class RowBlock:
    """A device row table padded to a power-of-two height: `data[k:]` are
    inert sink rows (every column = the padding-sink vertex, degree 0, no
    owner shard), so each join program compiles once per pow2 bucket instead
    of once per exact row count."""

    __slots__ = ("data", "k")

    def __init__(self, data, k: int):
        self.data = data
        self.k = int(k)


class DeviceJoin:
    """The device-resident join over a LocalJoinContext / ShardedJoinContext.

    Rows live on device; the host sees only scalar sizes (capacity / kept-row
    counts — the static-shape handshake XLA requires) and, in count mode,
    nothing else: completion counts accumulate from the psum-combined keep
    bits without ever materializing rows."""

    route = "device"

    def __init__(self, ctx, template: Template, walk: Sequence[int],
                 max_rows: int, symmetry_break: bool = False,
                 stats: Optional[Dict] = None):
        restr = template.symmetry_restrictions() if symmetry_break else ()
        self.steps, self.seen_q = walk_steps(walk, restr)
        self.ctx = ctx
        self.template = template
        self.max_rows = max_rows
        self.stats = stats
        self.walk0 = walk[0]
        self.cand = ctx.cols(tuple(self.seen_q))  # bool[n_seen, n_pad+1]
        self._rv_iters = max(int(np.ceil(np.log2(max(ctx.A, 2)))) + 1, 1)

    def _pad(self, data, k: int) -> RowBlock:
        kp = _pow2(max(k, 1))
        if kp > data.shape[0]:
            sink = jnp.full((kp - data.shape[0], data.shape[1]),
                            self.ctx.n_pad, jnp.int32)
            data = jnp.concatenate([data, sink], axis=0)
        return RowBlock(data, k)

    # -- engine API
    def sources(self) -> np.ndarray:
        return np.flatnonzero(np.asarray(self.cand[0][:-1]))

    def seed(self, ids: np.ndarray) -> RowBlock:
        ids = np.asarray(ids).astype(np.int32)
        return self._pad(jnp.asarray(ids).reshape(-1, 1), ids.size)

    def nrows(self, rows: RowBlock) -> int:
        return rows.k

    def step(self, rows: RowBlock, r: int, enforce: bool = True) -> RowBlock:
        s = self.steps[r - 1]
        if s.kind == "revisit":
            fn = self.ctx.wrap(
                ("join_rv",) + s.key(),
                lambda axis: _revisit_program(axis, s, self.ctx.n_local,
                                              self._rv_iters),
                n_sharded=2,
            )
            keep = fn(self.ctx.plan, self.ctx.arc_active, self.ctx.deg,
                      rows.data)
            return self._compact(rows, keep, None, None, s, enforce=enforce)

        # expansion: slot layout from STATIC degrees (identical on any shard
        # count); the exact capacity is read back as one scalar per step.
        # Sink pad rows have degree 0 — they occupy no slots.
        deg_h = np.asarray(jnp.take(self.ctx.deg, rows.data[:, s.c_prev]))
        cum_h, T = tds_mod.expansion_slots(deg_h)
        if enforce and T > self.max_rows:
            raise TdsOverflow(
                f"join capacity {T} > max_rows={self.max_rows} at step {r}")
        _guard_int32(T, f"join expansion capacity at step {r}")
        if T == 0:
            return RowBlock(jnp.zeros((0, s.n_cols + 1), jnp.int32), 0)
        cum = jnp.asarray(cum_h.astype(np.int32))
        t = jnp.arange(_pow2(T), dtype=jnp.int32)
        parent = jnp.clip(jnp.searchsorted(cum, t, side="right"),
                          0, rows.data.shape[0] - 1).astype(jnp.int32)
        j = t - jnp.take(cum - jnp.asarray(deg_h.astype(np.int32)), parent)
        fn = self.ctx.wrap(
            ("join_ex",) + s.key(),
            lambda axis: _expand_program(axis, s, self.ctx.n_local),
            n_sharded=2,
        )
        newv, keep = fn(self.ctx.plan, self.ctx.arc_active,
                        self.cand[s.c_tgt], self.ctx.deg, rows.data, parent, j)
        if self.stats is not None:
            self.stats["join_expansions"] = (
                self.stats.get("join_expansions", 0) + T)
        return self._compact(rows, keep, newv, parent, s, enforce=enforce)

    def _compact(self, rows: RowBlock, keep, newv, parent, s: JoinStep,
                 enforce: bool = True) -> RowBlock:
        k_new = int(jnp.sum(keep))  # sink/pad slots contribute 0
        if enforce and k_new > self.max_rows:
            raise TdsOverflow(
                f"join rows {k_new} > max_rows={self.max_rows}")
        width = s.n_cols + (1 if s.kind == "expand" else 0)
        if k_new == 0:
            return RowBlock(jnp.zeros((0, width), jnp.int32), 0)
        sel = jnp.nonzero(keep, size=_pow2(k_new), fill_value=keep.shape[0])[0]
        if s.kind == "revisit":
            sink = jnp.full((1, width), self.ctx.n_pad, jnp.int32)
            out = jnp.take(jnp.concatenate([rows.data, sink]), sel, axis=0)
        else:
            sinkv = jnp.concatenate([newv, jnp.asarray([self.ctx.n_pad],
                                                       jnp.int32)])
            parent_sink = jnp.concatenate(
                [parent, jnp.asarray([0], jnp.int32)])
            prow = jnp.take(rows.data, jnp.take(parent_sink, sel), axis=0)
            col = jnp.take(sinkv, sel)[:, None]
            pad_row = sel >= keep.shape[0]
            prow = jnp.where(pad_row[:, None], jnp.int32(self.ctx.n_pad), prow)
            out = jnp.concatenate([prow, col], axis=1)
        if self.stats is not None:
            self.stats["join_rows_max"] = max(
                self.stats.get("join_rows_max", 0), k_new)
        return RowBlock(out, k_new)

    def split(self, rows: RowBlock, r: int, budget: int) -> List[RowBlock]:
        s = self.steps[r - 1]
        if s.kind == "revisit" or rows.k <= 1:
            return [rows]
        deg_h = np.asarray(
            jnp.take(self.ctx.deg, rows.data[:rows.k, s.c_prev])
        ).astype(np.int64)
        return [self._pad(piece, piece.shape[0]) for piece in
                _split_by_capacity(rows.data[:rows.k], deg_h, budget)]

    def emit(self, rows: RowBlock) -> np.ndarray:
        perm = [self.seen_q.index(q) for q in range(self.template.n0)]
        return np.asarray(rows.data[:rows.k])[:, perm].astype(np.int32)

    def count(self, rows: RowBlock) -> int:
        return rows.k


class ShardedRowBlock:
    """The distributed row table: device data [P, Rb, C] (per-shard pow2
    blocks, rows past a shard's count are inert sink rows) + host per-shard
    counts. Peak per-shard resident rows = Rb = pow2(max_p k_p) — for a
    balanced frontier ~1/P of the replicated table's height. `cap` carries
    the per-shard expansion capacity of the NEXT step's frontier column
    (summed static degrees), read back in the SAME handshake that sized this
    block — so the next expand step never re-reads the frontier column."""

    __slots__ = ("data", "counts", "cap")

    def __init__(self, data, counts: np.ndarray, cap=None):
        self.data = data
        self.counts = np.asarray(counts, np.int64)
        self.cap = (np.zeros(self.counts.shape[0], np.int64)
                    if cap is None else np.asarray(cap, np.int64))

    @property
    def k(self) -> int:
        return int(self.counts.sum())


class RowShardedJoin:
    """The distributed-rows device join over a ShardedJoinContext.

    Invariant: every real row lives on the shard owning its NEXT frontier
    vertex (RowPlan's block rule), so each step's CSR expansion / revisit
    probe is purely shard-local. Per step the host performs exactly ONE
    readback — a folded [2, P, P] (or [1, P] on the tail) handshake carrying
    both the next-owner bucket counts (sizing `exchange_rows`) AND the
    next frontier column's expansion capacity (sizing the NEXT step's slot
    layout), so the old separate frontier-column readback is gone: one host
    sync per step instead of two. Slot layout is computed on device from the
    same static degrees as the replicated engine (an exact mirror of
    `tds.slot_parents`), so counts and row SETS are bit-identical to
    `DeviceJoin` / `HostJoin` on any shard count — only placement (and
    therefore emission order, erased by the caller's np.unique) differs.
    The candidacy-column all-gather (`ctx.cols`) is the only replicated
    state."""

    route = "device"
    engine = "rowsharded"

    def __init__(self, ctx, template: Template, walk: Sequence[int],
                 max_rows: int, symmetry_break: bool = False,
                 stats: Optional[Dict] = None):
        if not hasattr(ctx, "row_plan"):
            raise ValueError(
                "RowShardedJoin needs a ShardedJoinContext (a row-ownership "
                "plan); the local backend has no rows to distribute")
        restr = template.symmetry_restrictions() if symmetry_break else ()
        self.steps, self.seen_q = walk_steps(walk, restr)
        self.ctx = ctx
        self.template = template
        self.max_rows = max_rows
        self.stats = stats
        self.walk0 = walk[0]
        self.cand = ctx.cols(tuple(self.seen_q))  # the one replicated state
        self.P = ctx.P
        self.n_local = ctx.n_local
        self.n_pad = ctx.n_pad
        self.rp = ctx.row_plan
        self._rv_iters = max(int(np.ceil(np.log2(max(ctx.A, 2)))) + 1, 1)
        self._deg_max = int(self.rp.deg.max()) if self.rp.deg.size else 0

    # -- step metadata ------------------------------------------------------
    def _next_owner_col(self, r: int) -> Optional[int]:
        """Column (in the row layout AFTER step r) holding step r+1's
        frontier vertex — the routing key; None after the last step."""
        if r >= len(self.steps):
            return None
        return self.steps[r].c_prev

    def _stat_max(self, key: str, val) -> None:
        if self.stats is not None:
            self.stats[key] = max(self.stats.get(key, 0), val)

    def _record_block(self, counts: np.ndarray, resident: int) -> None:
        total = int(counts.sum())
        self._stat_max("join_rows_max", total)
        self._stat_max("rowshard_resident_rows_max", resident)
        self._stat_max("rowshard_peak_shard_rows", int(counts.max()))
        if self.stats is not None and total:
            frac = float(counts.max()) / float(total)
            self.stats["rowshard_owner_frac_max"] = max(
                self.stats.get("rowshard_owner_frac_max", 0.0), frac)

    def _shard_host_rows(self, rows_np: np.ndarray,
                         owner_col: int) -> ShardedRowBlock:
        data, counts = self.rp.shard_rows(rows_np, owner_col, _pow2)
        self._record_block(counts, data.shape[1])
        fcol = rows_np[:, owner_col]  # host rows are real vertices
        cap = np.bincount(fcol // self.n_local,
                          weights=self.rp.deg[fcol].astype(np.float64),
                          minlength=self.P).astype(np.int64)
        return ShardedRowBlock(jnp.asarray(data), counts, cap)

    # -- engine API ---------------------------------------------------------
    def sources(self) -> np.ndarray:
        return np.flatnonzero(np.asarray(self.cand[0][:-1]))

    def seed(self, ids: np.ndarray) -> ShardedRowBlock:
        rows = np.asarray(ids, np.int32).reshape(-1, 1)
        # step 1's frontier is column 0 — seeds go straight to their owner
        return self._shard_host_rows(rows, 0)

    def nrows(self, rows: ShardedRowBlock) -> int:
        return rows.k

    def count(self, rows: ShardedRowBlock) -> int:
        return rows.k

    def _empty(self, width: int) -> ShardedRowBlock:
        data = jnp.full((self.P, 1, width), self.n_pad, jnp.int32)
        return ShardedRowBlock(data, np.zeros(self.P, np.int64))

    def step(self, rows: ShardedRowBlock, r: int,
             enforce: bool = True) -> ShardedRowBlock:
        s = self.steps[r - 1]
        oc = self._next_owner_col(r)
        expand = s.kind == "expand"
        width = s.n_cols + (1 if expand else 0)
        if expand:
            # slot capacity came back in the PREVIOUS step's folded
            # handshake (or the host sharding for seeds/splits) — no
            # frontier-column readback here
            cap_p = rows.cap
            T = int(cap_p.sum())
            if enforce and T > self.max_rows:
                raise TdsOverflow(
                    f"join capacity {T} > max_rows={self.max_rows} "
                    f"at step {r}")
            _guard_int32(int(cap_p.max()) if cap_p.size else 0,
                         f"per-shard join expansion capacity at step {r}")
            if T == 0:
                return self._empty(width)
            if oc is not None:
                # the NEXT capacity is summed on device in int32; bound it
                # conservatively before it can wrap (slots * max degree)
                _guard_int32(int(cap_p.max()) * max(self._deg_max, 1),
                             f"device capacity partial sums at step {r}")
            Tb = _pow2(max(int(cap_p.max()), 1))
            fn = self.ctx.wrap_rows(
                ("rsj_ex",) + s.key() + (oc, Tb),
                lambda axis: _rowshard_expand_program(
                    axis, s, self.n_local, self.P, oc, Tb),
                n_sharded=3,
            )
            newv, ok, parent, cm = fn(self.ctx.plan, self.ctx.arc_active,
                                      rows.data, self.cand[s.c_tgt],
                                      self.ctx.deg)
            if self.stats is not None:
                self.stats["join_expansions"] = (
                    self.stats.get("join_expansions", 0) + T)
            args = (rows.data, parent, newv, ok)
        else:
            if oc is not None:
                _guard_int32(int(rows.counts.max()) * max(self._deg_max, 1),
                             f"device capacity partial sums at step {r}")
            fn = self.ctx.wrap_rows(
                ("rsj_rv",) + s.key() + (oc,),
                lambda axis: _rowshard_revisit_program(
                    axis, s, self.n_local, self._rv_iters, self.P, oc),
                n_sharded=3,
            )
            ok, cm = fn(self.ctx.plan, self.ctx.arc_active, rows.data,
                        self.ctx.deg)
            args = (rows.data, ok)

        # the ONE host sync of this step: counts + next-capacity together
        cm = np.asarray(cm, np.int64)  # [P, 2, P] ([P, 1] on the tail)
        if self.stats is not None:
            self.stats["rowshard_host_syncs"] = (
                self.stats.get("rowshard_host_syncs", 0) + 1)
        if oc is None:
            cnt = cm  # [P, 1] per-shard survivor counts
            cap_next = None
        else:
            cnt = cm[:, 0, :]  # [P, P] sender-by-owner counts
            cap_next = cm[:, 1, :].sum(axis=0)  # [P] per-owner capacity
        k_total = int(cnt.sum())
        if enforce and k_total > self.max_rows:
            raise TdsOverflow(
                f"join rows {k_total} > max_rows={self.max_rows}")
        if k_total == 0:
            return self._empty(width)

        if oc is None:  # last step: per-shard compaction, no exchange
            k_p = cnt[:, 0]
            Kp = _pow2(max(int(k_p.max()), 1))
            tail = self.ctx.wrap_rows(
                ("rsj_tail", expand, width, Kp),
                lambda axis: _rowshard_tail_program(
                    axis, self.n_local, self.P, Kp, expand),
                n_sharded=len(args),
            )
            out = ShardedRowBlock(tail(*args), k_p)
            self._record_block(k_p, Kp)
            return out

        # exchange buckets sized from the count matrix: Br bounds every
        # (sender, owner) bucket, Rb2 every shard's received total — rows
        # are PADDED into place, never dropped
        k_in = cnt.sum(axis=0)  # rows each owner receives
        Br = _pow2(max(int(cnt.max()), 1))
        Rb2 = _pow2(max(int(k_in.max()), 1))
        _guard_int32(self.P * Br, f"exchange bucket slots at step {r}")
        route_fn = self.ctx.wrap_rows(
            ("rsj_route", expand, width, oc, Br, Rb2),
            lambda axis: _rowshard_route_program(
                axis, self.n_local, self.P, Br, Rb2, oc, expand),
            n_sharded=len(args),
        )
        out = ShardedRowBlock(route_fn(*args, jnp.asarray(cnt, jnp.int32)),
                              k_in, cap_next)
        self._record_block(k_in, Rb2)
        if self.stats is not None:
            off_shard = k_total - int(np.trace(cnt))
            self.stats["rowshard_exchanged_rows"] = (
                self.stats.get("rowshard_exchanged_rows", 0) + off_shard)
            self._stat_max("rowshard_bucket_cap", Br)
            self._stat_max("rowshard_bucket_occupancy_max", int(cnt.max()))
        return out

    def split(self, rows: ShardedRowBlock, r: int,
              budget: int) -> List[ShardedRowBlock]:
        s = self.steps[r - 1]
        if s.kind == "revisit" or rows.k <= 1:
            return [rows]
        # the streaming path is host-synced per block anyway (blocks are
        # emitted to the host): gather, split by global capacity with the
        # shared planner, re-shard each piece by its current owner column
        host = self._gather(rows)
        cap = self.rp.deg[host[:, s.c_prev]]
        return [self._shard_host_rows(piece, s.c_prev)
                for piece in _split_by_capacity(host, cap, budget)]

    def _gather(self, rows: ShardedRowBlock) -> np.ndarray:
        d = np.asarray(rows.data)
        return np.concatenate(
            [d[p, :int(c)] for p, c in enumerate(rows.counts)], axis=0)

    def emit(self, rows: ShardedRowBlock) -> np.ndarray:
        perm = [self.seen_q.index(q) for q in range(self.template.n0)]
        return self._gather(rows)[:, perm].astype(np.int32)


class HostJoin:
    """The numpy row-table join over the compacted active subgraph, exposed
    through the same engine API (the tds.py step primitives underneath)."""

    route = "host"

    def __init__(self, sub: ActiveSubgraph, template: Template,
                 walk: Sequence[int], max_rows: int,
                 symmetry_break: bool = False,
                 stats: Optional[Dict] = None):
        restr = template.symmetry_restrictions() if symmetry_break else ()
        self.steps, self.seen_q = walk_steps(walk, restr)
        self.sub = sub
        self.template = template
        self.max_rows = max_rows
        self.stats = stats
        self.walk0 = walk[0]

    # -- engine API
    def sources(self) -> np.ndarray:
        return np.flatnonzero(self.sub.omega[:, self.walk0])

    def seed(self, ids: np.ndarray) -> np.ndarray:
        return np.asarray(ids).astype(np.int32).reshape(-1, 1)

    def nrows(self, rows) -> int:
        return int(rows.shape[0])

    def step(self, rows, r: int, enforce: bool = True):
        s = self.steps[r - 1]
        if s.kind == "revisit":
            return tds_mod.revisit_rows(self.sub, rows, s.c_prev, s.c_tgt)
        rows = tds_mod.expand_rows(self.sub, rows, s.c_prev, s.q_next,
                                   s.n_cols, s.restr)
        if enforce and rows.shape[0] > self.max_rows:
            raise TdsOverflow(
                f"join rows {rows.shape[0]} > max_rows={self.max_rows} "
                f"at step {r}")
        if self.stats is not None:
            self.stats["join_rows_max"] = max(
                self.stats.get("join_rows_max", 0), int(rows.shape[0]))
        return rows

    def split(self, rows, r: int, budget: int) -> List:
        s = self.steps[r - 1]
        if s.kind == "revisit" or rows.shape[0] <= 1:
            return [rows]
        cap = tds_mod.expand_capacity(self.sub, rows, s.c_prev)
        return _split_by_capacity(rows, cap, budget)

    def emit(self, rows) -> np.ndarray:
        perm = [self.seen_q.index(q) for q in range(self.template.n0)]
        return np.asarray(rows)[:, perm].astype(np.int32)

    def count(self, rows) -> int:
        return int(rows.shape[0])


def _split_by_capacity(rows, cap: np.ndarray, budget: int) -> List:
    """Partition a row block so each piece's expansion capacity stays within
    `budget` (single rows are never split: a lone row whose fan-out exceeds
    the budget expands in one piece)."""
    cum = np.cumsum(cap, dtype=np.int64)
    if cum.size == 0 or cum[-1] <= budget:
        return [rows]
    pieces = []
    start, base = 0, 0
    n = int(cum.shape[0])
    while start < n:
        end = int(np.searchsorted(cum, base + budget, side="right"))
        end = min(max(end, start + 1), n)
        pieces.append(rows[start:end])
        base = int(cum[end - 1])
        start = end
    return pieces


# -------------------------------------------------------- streaming emitter
def stream_join(engine, sources: np.ndarray, chunk: int,
                budget: int) -> Iterator[np.ndarray]:
    """Bounded-memory streaming enumeration: source chunks are walked
    depth-first, splitting row blocks before each expansion so no step's
    output exceeds `budget` rows; completed blocks (template-vertex column
    order) are yielded as they finish. Peak live rows ~ walk_length * budget
    (one in-flight block per depth level)."""

    def dfs(rows, r: int) -> Iterator[np.ndarray]:
        if engine.nrows(rows) == 0:
            return
        if r > len(engine.steps):
            yield engine.emit(rows)
            return
        for piece in engine.split(rows, r, budget):
            yield from dfs(engine.step(piece, r, enforce=False), r + 1)

    sources = np.asarray(sources)
    for off in range(0, sources.size, chunk):
        yield from dfs(engine.seed(sources[off: off + chunk]), 1)

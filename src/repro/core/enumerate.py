"""Full match enumeration, counting, and streaming on the pruned solution
subgraph (§4).

Per the paper: "Alg. 6 can be slightly modified to obtain the enumeration of
the matches: the constraint used is the full template, work aggregation is
turned off, and each possible match is verified." The join engines
(core/join.py) realize this as a row-table walk over the complete edge-cover
walk of the template; the per-vertex match lists omega collected during
pruning accelerate the join (candidacy filters), exactly as in the paper.

Three result modes:
  materialize  the classic full enumeration: every embedding as a row of
               `EnumerationResult.embeddings` (template-vertex column order).
  count        the counting fast path: completion counts only, rows are never
               materialized host-side; symmetry restrictions derived from the
               template's automorphism group (GraphPi-style, see
               `Template.symmetry_restrictions`) are enforced IN-FLIGHT, so
               the join does 1/|Aut| of the work and needs no post-hoc
               `np.unique` — `n_embeddings` is reported exactly as
               restricted_count * |Aut|.
  stream       `stream_matches`: a generator of embedding blocks under a
               fixed row budget (bounded memory, Choudhury et al.-style
               continuous emission).

Two join routes serve every mode, resolved through the kernel registry's
dispatch policy (route name ``enumerate.join``, buckets
``<local|sharded>x<mode>``):
  host    the numpy row-table join over the compacted active subgraph.
  device  the device-resident join (core/join.py) — on a sharded PruneResult
          (prune(..., mesh=/partition=)) it runs against the backend's
          device-resident shard arrays directly: the reduced subgraph is
          NEVER materialized on the host before the join.

On a TdsOverflow that survives chunk back-off to a single source, the
enumeration falls back to the streaming emitter for that source instead of
raising out of an otherwise-valid run.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.core.state import PruneState
from repro.core.template import Template, _edge_cover_walk
from repro.core.tds import compact_active, tds_walk, TdsOverflow
from repro.core import join as join_mod

# dispatch-policy route name for the enumeration join (host vs device),
# bucketed by backend kind and result mode: "<local|sharded>x<mode>"
ENUM_ROUTE = "enumerate.join"

MODE_MATERIALIZE = "materialize"
MODE_COUNT = "count"
MODE_STREAM = "stream"


@dataclasses.dataclass
class EnumerationResult:
    embeddings: np.ndarray  # int32[count, n0]: column q = background vertex for q
    n_embeddings: int
    n_distinct_vertex_sets: int  # -1 in count mode (needs materialized rows)
    automorphisms: int
    mode: str = MODE_MATERIALIZE
    route: str = "host"
    n_canonical: Optional[int] = None  # symmetry-restricted row count, if broken

    @property
    def n_matches_up_to_automorphism(self) -> float:
        return self.n_embeddings / max(self.automorphisms, 1)


def template_walk(template: Template, label_freq: Optional[np.ndarray] = None):
    freq = label_freq if label_freq is not None else np.ones(int(template.labels.max()) + 1)
    rank = {q: float(freq[template.labels[q]]) for q in range(template.n0)}
    start = min(range(template.n0), key=lambda q: (rank[q], q))
    return _edge_cover_walk(
        set(range(template.n0)), set(template.edge_set), start,
        {q: list(template.adj[q]) for q in range(template.n0)}, rank,
    )


def count_automorphisms(template: Template) -> int:
    """|Aut(T)| — cached on the template (orbit-refined backtracking search,
    `Template.automorphisms`; the old path re-ran a brute-force
    self-enumeration on every call)."""
    return max(template.automorphism_count(), 1)


def _resolve_route(kind: str, mode: str, route: Optional[str]) -> str:
    """Resolve the join route. Local kind: "host" | "device". Sharded kind:
    always device-resident, refined to a row-placement flavor —
    "rowsharded" (rows live on their frontier-owner shard, exchanged per
    step; ~1/P per-shard memory, the default) or "replicated" (full row
    table on every shard, slots psum-combined) — via the dispatch policy's
    ("sharded", mode) bucket. route= pins any of the four explicitly."""
    from repro.kernels import registry

    flavors = (registry.ROUTE_ROWSHARDED, registry.ROUTE_REPLICATED)
    if route is not None:
        if route not in (registry.ROUTE_HOST, registry.ROUTE_DEVICE) + flavors:
            raise ValueError(f"unknown enumerate.join route {route!r}")
        if kind == "sharded" and route == registry.ROUTE_HOST:
            raise ValueError(
                "the sharded enumeration join is device-resident; route="
                "'host' would gather the reduced subgraph")
        if kind != "sharded" and route in flavors:
            raise ValueError(
                f"route={route!r} is a sharded row placement; the local "
                "backend has no shards to place rows on")
        if route in flavors:
            return route
        if kind == "sharded":  # route="device": the policy picks the flavor
            route = None
        else:
            return route
    if kind == "sharded":
        # always device-resident (the whole point is never gathering G*);
        # the tunable decision is the row placement
        return registry.resolve_route(
            ENUM_ROUTE, (kind, mode), default=registry.ROUTE_ROWSHARDED,
            allowed=flavors)
    return registry.resolve_route(
        ENUM_ROUTE, (kind, mode), default=registry.ROUTE_HOST,
        allowed=(registry.ROUTE_HOST, registry.ROUTE_DEVICE))


def _unpack_args(dg, state, template, backend):
    """Accept either (dg, state, template) or a PruneResult first argument —
    a sharded PruneResult carries its execution backend, which the device
    join enumerates against with no gather of the reduced subgraph."""
    if state is None and hasattr(dg, "dg") and hasattr(dg, "state"):
        result = dg
        if template is None:
            template = result.template
        if backend is None:
            backend = getattr(result, "backend", None)
        return result.dg, result.state, template, backend
    return dg, state, template, backend


def _backend_kind(backend) -> str:
    return ("sharded"
            if backend is not None and getattr(backend, "name", "local")
            in ("sim", "spmd", "sharded") else "local")


def _public_route(route: str) -> str:
    """What `EnumerationResult.route` / stats report: the sharded row
    placements are flavors of the device route, not separate routes."""
    from repro.kernels import registry

    if route in (registry.ROUTE_ROWSHARDED, registry.ROUTE_REPLICATED):
        return registry.ROUTE_DEVICE
    return route


def _make_engine(route, kind, dg, state, template, walk, max_rows,
                 symmetry_break, backend, stats):
    from repro.kernels import registry

    if route == registry.ROUTE_ROWSHARDED:
        return join_mod.RowShardedJoin(
            backend.join_context(), template, walk, max_rows,
            symmetry_break=symmetry_break, stats=stats)
    if route in (registry.ROUTE_DEVICE, registry.ROUTE_REPLICATED):
        ctx = (backend.join_context() if kind == "sharded"
               else join_mod.LocalJoinContext(dg, state))
        return join_mod.DeviceJoin(ctx, template, walk, max_rows,
                                   symmetry_break=symmetry_break, stats=stats)
    sub = compact_active(dg, state)
    return join_mod.HostJoin(sub, template, walk, max_rows,
                             symmetry_break=symmetry_break, stats=stats)


def _run_engine(engine, chunk: int, max_rows: int, count_only: bool,
                stats: Optional[Dict]):
    """Chunked source loop with overflow back-off shared by the engine-based
    paths; at cur_chunk == 1 an overflowing source falls back to the
    streaming emitter (bounded memory) instead of raising."""
    sources = engine.sources()
    blocks = []
    total = 0
    off, cur_chunk = 0, chunk
    while off < sources.size:
        ids = sources[off: off + cur_chunk]
        try:
            rows = engine.seed(ids)
            for r in range(1, len(engine.steps) + 1):
                if engine.nrows(rows) == 0:
                    break
                rows = engine.step(rows, r)
            if engine.nrows(rows):
                if count_only:
                    total += engine.count(rows)
                else:
                    blocks.append(engine.emit(rows))
        except TdsOverflow:
            if cur_chunk == 1:
                # streaming fallback: finish this source depth-first under
                # the same row budget instead of aborting the enumeration
                if stats is not None:
                    stats["enum_stream_fallbacks"] = (
                        stats.get("enum_stream_fallbacks", 0) + 1)
                for blk in join_mod.stream_join(engine, ids, 1, max_rows):
                    if count_only:
                        total += blk.shape[0]
                    else:
                        blocks.append(blk)
                off += ids.size
                continue
            cur_chunk = max(1, cur_chunk // 4)  # paper's rate control
            continue
        off += ids.size
        if cur_chunk < chunk:  # recover toward the configured chunk
            cur_chunk = min(chunk, cur_chunk * 2)
    return total, blocks


def enumerate_matches(
    dg,
    state: Optional[PruneState] = None,
    template: Optional[Template] = None,
    label_freq: Optional[np.ndarray] = None,
    chunk: int = 4096,
    max_rows: int = 5_000_000,
    stats: Optional[Dict] = None,
    *,
    mode: str = MODE_MATERIALIZE,
    symmetry_break: Optional[bool] = None,
    route: Optional[str] = None,
    backend=None,
) -> EnumerationResult:
    """Enumerate (or count) all template embeddings in the pruned graph.

    `dg` may be a `PruneResult` (then `state`/`template` default from it);
    a sharded result routes onto the device-resident join automatically.
    `mode` is "materialize" (default) or "count"; `symmetry_break` defaults
    to True exactly in count mode. `route` pins "host"/"device" (tests);
    otherwise the dispatch policy decides for the local backend.
    """
    from repro.kernels import registry

    dg, state, template, backend = _unpack_args(dg, state, template, backend)
    if mode not in (MODE_MATERIALIZE, MODE_COUNT):
        raise ValueError(f"unknown enumeration mode {mode!r}")
    aut = count_automorphisms(template)
    if template.n0 == 1:
        verts = np.flatnonzero(np.asarray(state.omega)[:, 0])
        emb = verts.astype(np.int32).reshape(-1, 1)
        if mode == MODE_COUNT:
            return EnumerationResult(
                np.zeros((0, 1), np.int32), emb.shape[0], -1, 1,
                mode=mode, route="host")
        return EnumerationResult(emb, emb.shape[0], emb.shape[0], 1)

    kind = _backend_kind(backend)
    route = _resolve_route(kind, mode, route)
    public = _public_route(route)
    sb = symmetry_break if symmetry_break is not None else (mode == MODE_COUNT)
    if stats is not None:
        stats["enumerate_route"] = public
        stats["enumerate_mode"] = mode
        if kind == "sharded":
            stats["enumerate_join_engine"] = route
    walk = template_walk(template, label_freq)

    if (mode == MODE_MATERIALIZE and not sb
            and route == registry.ROUTE_HOST):
        # the legacy single-host materialize join (per-chunk tds_walk with
        # the same back-off/recovery loop), kept as the host route
        return _materialize_host_legacy(
            dg, state, template, walk, chunk, max_rows, stats, aut)

    engine = _make_engine(route, kind, dg, state, template, walk, max_rows,
                          sb, backend, stats)
    total, blocks = _run_engine(engine, chunk, max_rows,
                                count_only=(mode == MODE_COUNT), stats=stats)
    if mode == MODE_COUNT:
        n_emb = total * aut if sb else total
        return EnumerationResult(
            np.zeros((0, template.n0), np.int32), n_emb, -1, aut,
            mode=mode, route=public, n_canonical=(total if sb else None))
    if blocks:
        emb = np.unique(np.concatenate(blocks, axis=0), axis=0)
    else:
        emb = np.zeros((0, template.n0), np.int32)
    vsets = np.unique(np.sort(emb, axis=1), axis=0)
    n_emb = emb.shape[0] * aut if sb else emb.shape[0]
    return EnumerationResult(
        embeddings=emb,
        n_embeddings=n_emb,
        n_distinct_vertex_sets=vsets.shape[0],
        automorphisms=aut,
        mode=mode, route=public,
        n_canonical=(emb.shape[0] if sb else None),
    )


def count_matches(dg, state=None, template=None, **kw) -> EnumerationResult:
    """The counting-only fast path: `enumerate_matches(..., mode="count")` —
    symmetry-broken in-flight, rows never materialized."""
    return enumerate_matches(dg, state, template, mode=MODE_COUNT, **kw)


def stream_matches(
    dg,
    state: Optional[PruneState] = None,
    template: Optional[Template] = None,
    label_freq: Optional[np.ndarray] = None,
    chunk: int = 4096,
    max_rows: int = 1_000_000,
    stats: Optional[Dict] = None,
    *,
    symmetry_break: bool = False,
    route: Optional[str] = None,
    backend=None,
) -> Iterator[np.ndarray]:
    """Stream embedding blocks (int32[k, n0], template-vertex column order)
    under a fixed `max_rows` budget instead of materializing every match:
    source chunks are walked depth-first, row blocks split before each
    expansion (core/join.py `stream_join`). Bounded memory — the whole-result
    row table never exists at once."""
    dg, state, template, backend = _unpack_args(dg, state, template, backend)
    if template.n0 == 1:
        verts = np.flatnonzero(np.asarray(state.omega)[:, 0]).astype(np.int32)
        for off in range(0, verts.size, max(max_rows, 1)):
            yield verts[off: off + max_rows].reshape(-1, 1)
        return
    kind = _backend_kind(backend)
    route = _resolve_route(kind, MODE_STREAM, route)
    if stats is not None:
        stats["enumerate_route"] = _public_route(route)
        stats["enumerate_mode"] = MODE_STREAM
        if kind == "sharded":
            stats["enumerate_join_engine"] = route
    walk = template_walk(template, label_freq)
    engine = _make_engine(route, kind, dg, state, template, walk, max_rows,
                          symmetry_break, backend, stats)
    yield from join_mod.stream_join(engine, engine.sources(), chunk, max_rows)


def _materialize_host_legacy(dg, state, template, walk, chunk, max_rows,
                             stats, aut) -> EnumerationResult:
    # Kept separate from _run_engine on purpose: the host materialize default
    # must keep issuing module-level `tds_walk` calls per source chunk — that
    # call contract (and the exact back-off/recovery sequence) is pinned by
    # tests monkeypatching it, and the per-step np.unique dedup inside
    # tds_walk is part of the measured legacy baseline the `enumeration`
    # roll-up point compares the counting fast path against.
    sub = compact_active(dg, state)
    q0 = walk[0]
    sources = np.flatnonzero(sub.omega[:, q0])
    all_rows = []
    # first-visit column order, derived from the walk itself: a chunk whose
    # rows empty mid-walk returns a TRUNCATED seen_q from tds_walk, so the
    # last chunk's value must never drive the column permutation
    _, seen_q = join_mod.walk_steps(walk)
    off, cur_chunk = 0, chunk
    while off < sources.size:
        ids = sources[off : off + cur_chunk]
        try:
            _, rows, _ = tds_walk(
                sub, walk, ids, max_rows=max_rows, collect_rows=True, stats=stats
            )
        except TdsOverflow:
            if cur_chunk == 1:
                # streaming fallback for this source (satellite of the
                # device-resident engine PR): bounded-memory DFS instead of
                # raising out of an otherwise-valid enumeration
                if stats is not None:
                    stats["enum_stream_fallbacks"] = (
                        stats.get("enum_stream_fallbacks", 0) + 1)
                engine = join_mod.HostJoin(sub, template, walk, max_rows,
                                           stats=stats)
                for blk in join_mod.stream_join(engine, ids, 1, max_rows):
                    # blk is already in template-vertex column order; convert
                    # to the walk's seen order used below
                    if blk.shape[0]:
                        all_rows.append((blk, True))
                off += ids.size
                continue
            cur_chunk = max(1, cur_chunk // 4)
            continue
        if rows is not None and rows.shape[0]:
            all_rows.append((rows, False))
        off += ids.size
        # a TdsOverflow quarters cur_chunk (back off fast); each successful
        # wave doubles it back toward the configured chunk so one dense
        # source region cannot pin every later wave at a tiny chunk
        if cur_chunk < chunk:
            cur_chunk = min(chunk, cur_chunk * 2)

    if not all_rows:
        emb = np.zeros((0, template.n0), np.int32)
        return EnumerationResult(emb, 0, 0, aut)

    col_of_q = {q: c for c, q in enumerate(seen_q)}
    perm = [col_of_q[q] for q in range(template.n0)]
    parts = [rows if in_template_order else rows[:, perm]
             for rows, in_template_order in all_rows]
    emb = np.unique(np.concatenate(parts, axis=0), axis=0)
    vsets = np.unique(np.sort(emb, axis=1), axis=0)
    return EnumerationResult(
        embeddings=emb,
        n_embeddings=emb.shape[0],
        n_distinct_vertex_sets=vsets.shape[0],
        automorphisms=aut,
    )

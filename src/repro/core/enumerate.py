"""Full match enumeration and counting on the pruned solution subgraph (§4).

Per the paper: "Alg. 6 can be slightly modified to obtain the enumeration of
the matches: the constraint used is the full template, work aggregation is
turned off, and each possible match is verified." Here the TDS join already
keeps one row per distinct partial assignment, so 'work aggregation off'
simply means *collect completed rows* instead of reducing them to an
existence bit. The per-vertex match lists omega collected during pruning
accelerate the join (candidacy filters), exactly as in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.graph.structs import DeviceGraph
from repro.core.state import PruneState
from repro.core.template import Template, _edge_cover_walk
from repro.core.tds import compact_active, tds_walk, TdsOverflow


@dataclasses.dataclass
class EnumerationResult:
    embeddings: np.ndarray  # int32[count, n0]: column q = background vertex for q
    n_embeddings: int
    n_distinct_vertex_sets: int
    automorphisms: int

    @property
    def n_matches_up_to_automorphism(self) -> float:
        return self.n_embeddings / max(self.automorphisms, 1)


def template_walk(template: Template, label_freq: Optional[np.ndarray] = None):
    freq = label_freq if label_freq is not None else np.ones(int(template.labels.max()) + 1)
    rank = {q: float(freq[template.labels[q]]) for q in range(template.n0)}
    start = min(range(template.n0), key=lambda q: (rank[q], q))
    return _edge_cover_walk(
        set(range(template.n0)), set(template.edge_set), start,
        {q: list(template.adj[q]) for q in range(template.n0)}, rank,
    )


def count_automorphisms(template: Template) -> int:
    """Enumerate the template against itself (tiny)."""
    from repro.core.oracle import enumerate_matches_bruteforce

    res = enumerate_matches_bruteforce(template.to_graph(), template)
    return max(len(res), 1)


def enumerate_matches(
    dg: DeviceGraph,
    state: PruneState,
    template: Template,
    label_freq: Optional[np.ndarray] = None,
    chunk: int = 4096,
    max_rows: int = 5_000_000,
    stats: Optional[Dict] = None,
) -> EnumerationResult:
    if template.n0 == 1:
        verts = np.flatnonzero(np.asarray(state.omega)[:, 0])
        emb = verts.astype(np.int32).reshape(-1, 1)
        return EnumerationResult(emb, emb.shape[0], emb.shape[0], 1)

    sub = compact_active(dg, state)
    walk = template_walk(template, label_freq)
    q0 = walk[0]
    sources = np.flatnonzero(sub.omega[:, q0])
    all_rows = []
    seen_q = None
    off, cur_chunk = 0, chunk
    while off < sources.size:
        ids = sources[off : off + cur_chunk]
        try:
            _, rows, seen_q = tds_walk(
                sub, walk, ids, max_rows=max_rows, collect_rows=True, stats=stats
            )
        except TdsOverflow:
            if cur_chunk == 1:
                raise
            cur_chunk = max(1, cur_chunk // 4)
            continue
        if rows is not None and rows.shape[0]:
            all_rows.append(rows)
        off += ids.size
        # a TdsOverflow quarters cur_chunk (back off fast); each successful
        # wave doubles it back toward the configured chunk so one dense
        # source region cannot pin every later wave at a tiny chunk
        if cur_chunk < chunk:
            cur_chunk = min(chunk, cur_chunk * 2)

    if not all_rows:
        emb = np.zeros((0, template.n0), np.int32)
        return EnumerationResult(emb, 0, 0, count_automorphisms(template))

    rows = np.concatenate(all_rows, axis=0)
    # reorder columns from first-visit order to template vertex order
    col_of_q = {q: c for c, q in enumerate(seen_q)}
    emb = rows[:, [col_of_q[q] for q in range(template.n0)]]
    emb = np.unique(emb, axis=0)
    vsets = np.unique(np.sort(emb, axis=1), axis=0)
    return EnumerationResult(
        embeddings=emb,
        n_embeddings=emb.shape[0],
        n_distinct_vertex_sets=vsets.shape[0],
        automorphisms=count_automorphisms(template),
    )

"""Exploratory search (paper §5.4, Fig. 10): start from an over-constrained
template and progressively relax it by removing edges until matches appear.

Level k searches every connected k-edge-deleted variant; the system returns
the union of matches at the first level with any match. Shares the candidate
set and the non-local work-reuse cache across variants via IncrementalSession
(the same constraint walks recur across variants — the paper's key enabler).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np
import jax.numpy as jnp

from repro.graph.structs import Graph
from repro.core.template import Template
from repro.core.incremental import IncrementalSession


@dataclasses.dataclass
class LevelStat:
    k: int
    n_variants: int
    matched_vertices: int
    seconds: float
    avg_seconds_per_variant: float


@dataclasses.dataclass
class ExploratoryResult:
    found_level: Optional[int]
    vertex_mask: np.ndarray
    levels: List[LevelStat]
    candidate_vertices: int


def exploratory_search(
    graph: Graph,
    template: Template,
    max_removals: Optional[int] = None,
    max_variants_per_level: int = 4096,
) -> ExploratoryResult:
    session = IncrementalSession(graph, template)
    cand_v = int(jnp.sum(jnp.any(session._cand.omega, axis=1)))
    if max_removals is None:
        max_removals = template.m0 - max(template.n0 - 1, 1)

    levels: List[LevelStat] = []

    # level 0: the original template
    for k in range(0, max_removals + 1):
        t0 = time.perf_counter()
        variants = [template] if k == 0 else template.edge_deletion_variants(k)
        variants = variants[:max_variants_per_level]
        union = np.zeros(graph.n, dtype=bool)
        for var in variants:
            state, _ = session.search(var)
            union |= np.asarray(jnp.any(state.omega, axis=1))
        secs = time.perf_counter() - t0
        levels.append(
            LevelStat(
                k=k, n_variants=len(variants),
                matched_vertices=int(union.sum()), seconds=secs,
                avg_seconds_per_variant=secs / max(len(variants), 1),
            )
        )
        if union.any():
            return ExploratoryResult(
                found_level=k, vertex_mask=union, levels=levels,
                candidate_vertices=cand_v,
            )
    return ExploratoryResult(
        found_level=None, vertex_mask=np.zeros(graph.n, bool), levels=levels,
        candidate_vertices=cand_v,
    )

"""Pseudo-dynamic load balancing (paper §4 + §5.3).

The paper checkpoints the pruned state (active vertices/edges + omega),
reshuffles the vertex-to-processor assignment to evenly distribute the
*active* workload, and resumes — possibly on a smaller deployment (LB-16 /
LB-1). Here:

  - `imbalance_stats` quantifies the skew the paper characterizes ("half of
    the matching edges reside on only 20 of 2,304 partitions"),
  - `compact_and_repartition` rebuilds a balanced EdgePartition over only the
    active subgraph, for the same or a different shard count P (elastic
    scale-down/up = the paper's smaller-deployment scenario),
  - checkpoint/restore round-trips through repro.checkpoint (atomic, manifest).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.graph.structs import Graph, DeviceGraph
from repro.graph.partition import EdgePartition, partition_graph
from repro.core.state import PruneState


@dataclasses.dataclass
class BalanceStats:
    P: int
    edges_per_shard: np.ndarray
    vertices_per_shard: np.ndarray
    max_over_mean_edges: float
    gini_edges: float
    shards_holding_half: int  # smallest #shards covering 50% of active edges


def _gini(x: np.ndarray) -> float:
    x = np.sort(x.astype(np.float64))
    n = x.size
    if n == 0 or x.sum() == 0:
        return 0.0
    cum = np.cumsum(x)
    return float((n + 1 - 2 * (cum / cum[-1]).sum()) / n)


def imbalance_stats_from_counts(vertices_per_shard: np.ndarray,
                                edges_per_shard: np.ndarray) -> BalanceStats:
    """BalanceStats from per-shard active counts alone — the device path.

    The sharded backends compute these counts shard-locally on device
    (`backend.shard_counts_dev()`: a [P, 2] readback, no full state gather),
    so the phase-boundary rebalance trigger costs one small transfer. After
    an LCC fixpoint an active edge implies both endpoints are active and
    compatible, so the device per-shard edge counts equal the host oracle's
    endpoint-masked counts at every phase boundary — `imbalance_stats`
    remains the oracle and the parity is pinned in tests."""
    e_shard = np.asarray(edges_per_shard, np.int64)
    v_shard = np.asarray(vertices_per_shard, np.int64)
    P = int(e_shard.size)
    order = np.sort(e_shard)[::-1]
    cum = np.cumsum(order)
    half = int(np.searchsorted(cum, cum[-1] * 0.5) + 1) if cum.size and cum[-1] > 0 else 0
    return BalanceStats(
        P=P,
        edges_per_shard=e_shard,
        vertices_per_shard=v_shard,
        max_over_mean_edges=float(e_shard.max() / max(e_shard.mean(), 1e-9)),
        gini_edges=_gini(e_shard),
        shards_holding_half=half,
    )


def imbalance_stats(g: Graph, state: Optional[PruneState], P: int,
                    dg: Optional[DeviceGraph] = None) -> BalanceStats:
    n_local = (g.n + P - 1) // P
    if state is not None:
        assert dg is not None
        ea = np.asarray(state.edge_active)
        vact = np.asarray(state.omega).any(axis=1)
        src = np.asarray(dg.src)
        dst = np.asarray(dg.dst)
        keep = ea & vact[src] & vact[dst]
        src = src[keep]
        verts = np.flatnonzero(vact)
    else:
        src = g.src
        verts = np.arange(g.n)
    e_shard = np.bincount(src // n_local, minlength=P)
    v_shard = np.bincount(verts // n_local, minlength=P)
    return imbalance_stats_from_counts(v_shard, e_shard)


def compact_active_graph(
    g: Graph, dg: DeviceGraph, state: PruneState
) -> Tuple[Graph, np.ndarray, np.ndarray]:
    """Compact the solution subgraph to a fresh Graph.

    Returns (graph, old_of_new vertex ids, omega over new ids)."""
    vact = np.asarray(state.omega).any(axis=1)
    ea = np.asarray(state.edge_active)
    src, dst = np.asarray(dg.src), np.asarray(dg.dst)
    keep = ea & vact[src] & vact[dst]
    old_ids = np.flatnonzero(vact)
    new_of_old = np.full(g.n, -1, np.int64)
    new_of_old[old_ids] = np.arange(old_ids.size)
    sub = Graph(
        n=old_ids.size,
        src=new_of_old[src[keep]],
        dst=new_of_old[dst[keep]],
        labels=g.labels[old_ids],
    )
    omega_new = np.asarray(state.omega)[old_ids]
    return sub, old_ids, omega_new


def balanced_shuffle(sub: Graph, seed: int = 0) -> Tuple[Graph, np.ndarray]:
    """Random vertex re-id (the paper's reshuffle): destroys the skewed locality
    so block partitioning becomes even. Returns (shuffled graph, perm) where
    perm[new_id] = old_id."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(sub.n)  # new position of old id
    inv = np.empty_like(perm)
    inv[perm] = np.arange(sub.n)
    g2 = Graph(n=sub.n, src=inv[sub.src], dst=inv[sub.dst], labels=sub.labels[perm])
    return g2, perm


def compact_and_repartition(
    g: Graph, dg: DeviceGraph, state: PruneState, P: int, seed: int = 0
) -> Tuple[Graph, EdgePartition, Dict]:
    """Checkpoint-and-reshuffle onto P shards (elastic: any P)."""
    sub, old_ids, omega_new = compact_active_graph(g, dg, state)
    before = imbalance_stats(sub, None, P)
    shuffled, perm = balanced_shuffle(sub, seed)
    after = imbalance_stats(shuffled, None, P)
    part = partition_graph(shuffled, P) if shuffled.m else None
    return shuffled, part, {
        "old_ids": old_ids[perm],
        "omega": omega_new[perm],
        "imbalance_before": before,
        "imbalance_after": after,
    }


# --------------------------------------------------------------- elastic map
@dataclasses.dataclass
class ElasticRemap:
    """Coordinate map from a compact-and-reshuffled graph back to the
    ORIGINAL graph, so a run that restarted elastically still reports (and
    checkpoints) state in original ids — the property that makes recovery
    bit-verifiable against a fault-free run.

    old_of_new[v]  original vertex id of current vertex v
    arc_pos[i]     current dst-sorted arc index of original dst-sorted arc i,
                   or -1 if the arc was inactive at the handoff boundary
                   (monotonicity: it stays inactive in the original
                   coordinates forever after)
    """

    old_of_new: np.ndarray  # int64[n_new]
    arc_pos: np.ndarray  # int64[m_orig]
    n_orig: int
    m_orig: int


def remap_state_to_original(state: PruneState, remap: ElasticRemap,
                            n0: int) -> PruneState:
    """Express a current-coordinate PruneState in original coordinates
    (numpy arrays). Vertices/arcs dropped at the handoff boundary are
    inactive by monotonicity."""
    omega_cur = np.asarray(state.omega, bool)
    ea_cur = np.asarray(state.edge_active, bool)
    omega = np.zeros((remap.n_orig, n0), bool)
    omega[remap.old_of_new] = omega_cur
    ea = np.zeros(remap.m_orig, bool)
    kept = remap.arc_pos >= 0
    ea[kept] = ea_cur[remap.arc_pos[kept]]
    return PruneState(omega=omega, edge_active=ea)


def elastic_handoff(
    g: Graph, dg: DeviceGraph, state: PruneState, P: int, seed: int = 0
) -> Optional[Tuple[Graph, EdgePartition, PruneState, ElasticRemap]]:
    """The elastic-restart handoff: compact the active subgraph of an
    ORIGINAL-coordinate phase snapshot, reshuffle for balance, partition
    onto P shards, and return the state + the map back.

    Continuing the pipeline on the compacted active subgraph is exact: an
    inactive vertex/arc contributes nothing to any LCC sweep, NLCC wave, or
    TDS join (its omega/edge bits are already zero and sweeps are monotone),
    so the remaining phases land on the restriction of the fault-free
    fixpoint — `remap_state_to_original` then reproduces it bit-for-bit.

    Returns None when the active subgraph is degenerate (no active vertices
    or no active arcs) — callers fall back to a plain repartition of the
    original graph, which is always correct."""
    omega = np.asarray(state.omega, bool)
    ea = np.asarray(state.edge_active, bool)
    vact = omega.any(axis=1)
    src, dst = np.asarray(dg.src), np.asarray(dg.dst)
    keep = ea & vact[src] & vact[dst]
    old_ids = np.flatnonzero(vact)
    if old_ids.size == 0 or not keep.any():
        return None
    new_of_old = np.full(g.n, -1, np.int64)
    new_of_old[old_ids] = np.arange(old_ids.size)
    sub = Graph(
        n=old_ids.size,
        src=new_of_old[src[keep]],
        dst=new_of_old[dst[keep]],
        labels=g.labels[old_ids],
    )
    shuffled, perm = balanced_shuffle(sub, seed)
    old_of_new = old_ids[perm]
    part = partition_graph(shuffled, P)
    # arc i of the original dst-sorted order survives as the j-th arc of the
    # compacted host graph (the shuffle re-ids vertices but keeps arc order);
    # the new DeviceGraph dst-sorts those arcs, so the current position of
    # host arc j is the inverse of that sort
    kept_idx = np.flatnonzero(keep)
    order2 = DeviceGraph.dst_sort_order(shuffled)
    inv_order2 = np.empty_like(order2)
    inv_order2[order2] = np.arange(order2.size)
    arc_pos = np.full(ea.size, -1, np.int64)
    arc_pos[kept_idx] = inv_order2
    state_new = PruneState(
        omega=omega[old_of_new],
        edge_active=np.ones(shuffled.m, bool),
    )
    remap = ElasticRemap(old_of_new=old_of_new.astype(np.int64),
                         arc_pos=arc_pos, n_orig=g.n, m_orig=int(ea.size))
    return shuffled, part, state_new, remap

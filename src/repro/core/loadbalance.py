"""Pseudo-dynamic load balancing (paper §4 + §5.3).

The paper checkpoints the pruned state (active vertices/edges + omega),
reshuffles the vertex-to-processor assignment to evenly distribute the
*active* workload, and resumes — possibly on a smaller deployment (LB-16 /
LB-1). Here:

  - `imbalance_stats` quantifies the skew the paper characterizes ("half of
    the matching edges reside on only 20 of 2,304 partitions"),
  - `compact_and_repartition` rebuilds a balanced EdgePartition over only the
    active subgraph, for the same or a different shard count P (elastic
    scale-down/up = the paper's smaller-deployment scenario),
  - checkpoint/restore round-trips through repro.checkpoint (atomic, manifest).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from repro.graph.structs import Graph, DeviceGraph
from repro.graph.partition import EdgePartition, partition_graph
from repro.core.state import PruneState


@dataclasses.dataclass
class BalanceStats:
    P: int
    edges_per_shard: np.ndarray
    vertices_per_shard: np.ndarray
    max_over_mean_edges: float
    gini_edges: float
    shards_holding_half: int  # smallest #shards covering 50% of active edges


def _gini(x: np.ndarray) -> float:
    x = np.sort(x.astype(np.float64))
    n = x.size
    if n == 0 or x.sum() == 0:
        return 0.0
    cum = np.cumsum(x)
    return float((n + 1 - 2 * (cum / cum[-1]).sum()) / n)


def imbalance_stats(g: Graph, state: Optional[PruneState], P: int,
                    dg: Optional[DeviceGraph] = None) -> BalanceStats:
    n_local = (g.n + P - 1) // P
    if state is not None:
        assert dg is not None
        ea = np.asarray(state.edge_active)
        vact = np.asarray(state.omega).any(axis=1)
        src = np.asarray(dg.src)
        dst = np.asarray(dg.dst)
        keep = ea & vact[src] & vact[dst]
        src = src[keep]
        verts = np.flatnonzero(vact)
    else:
        src = g.src
        verts = np.arange(g.n)
    e_shard = np.bincount(src // n_local, minlength=P)
    v_shard = np.bincount(verts // n_local, minlength=P)
    order = np.sort(e_shard)[::-1]
    cum = np.cumsum(order)
    half = int(np.searchsorted(cum, cum[-1] * 0.5) + 1) if cum.size and cum[-1] > 0 else 0
    return BalanceStats(
        P=P,
        edges_per_shard=e_shard,
        vertices_per_shard=v_shard,
        max_over_mean_edges=float(e_shard.max() / max(e_shard.mean(), 1e-9)),
        gini_edges=_gini(e_shard),
        shards_holding_half=half,
    )


def compact_active_graph(
    g: Graph, dg: DeviceGraph, state: PruneState
) -> Tuple[Graph, np.ndarray, np.ndarray]:
    """Compact the solution subgraph to a fresh Graph.

    Returns (graph, old_of_new vertex ids, omega over new ids)."""
    vact = np.asarray(state.omega).any(axis=1)
    ea = np.asarray(state.edge_active)
    src, dst = np.asarray(dg.src), np.asarray(dg.dst)
    keep = ea & vact[src] & vact[dst]
    old_ids = np.flatnonzero(vact)
    new_of_old = np.full(g.n, -1, np.int64)
    new_of_old[old_ids] = np.arange(old_ids.size)
    sub = Graph(
        n=old_ids.size,
        src=new_of_old[src[keep]],
        dst=new_of_old[dst[keep]],
        labels=g.labels[old_ids],
    )
    omega_new = np.asarray(state.omega)[old_ids]
    return sub, old_ids, omega_new


def balanced_shuffle(sub: Graph, seed: int = 0) -> Tuple[Graph, np.ndarray]:
    """Random vertex re-id (the paper's reshuffle): destroys the skewed locality
    so block partitioning becomes even. Returns (shuffled graph, perm) where
    perm[new_id] = old_id."""
    rng = np.random.default_rng(seed)
    perm = rng.permutation(sub.n)  # new position of old id
    inv = np.empty_like(perm)
    inv[perm] = np.arange(sub.n)
    g2 = Graph(n=sub.n, src=inv[sub.src], dst=inv[sub.dst], labels=sub.labels[perm])
    return g2, perm


def compact_and_repartition(
    g: Graph, dg: DeviceGraph, state: PruneState, P: int, seed: int = 0
) -> Tuple[Graph, EdgePartition, Dict]:
    """Checkpoint-and-reshuffle onto P shards (elastic: any P)."""
    sub, old_ids, omega_new = compact_active_graph(g, dg, state)
    before = imbalance_stats(sub, None, P)
    shuffled, perm = balanced_shuffle(sub, seed)
    after = imbalance_stats(shuffled, None, P)
    part = partition_graph(shuffled, P) if shuffled.m else None
    return shuffled, part, {
        "old_ids": old_ids[perm],
        "omega": omega_new[perm],
        "imbalance_before": before,
        "imbalance_after": after,
    }

"""Search templates and non-local constraint generation (paper §3, Table 2).

A `Template` is a small connected labeled graph (n0 <= 64 so candidate sets fit
two uint32 words). `generate_constraints` implements the Table-2 heuristic:

  1. vertex classification  — unique-label leaves are excluded from NLCC,
  2. cycle constraints (CC) — one per cycle-basis cycle,
  3. path constraints (PC)  — shortest path per same-label pair >= 3 hops apart,
                              skipped when fully covered by a cycle constraint,
  4. TDS constraints        — union-of-cycles walk (non-edge-monocyclic),
                              union-of-paths walk (repeated labels),
                              union of both, and — when precision must be
                              guaranteed — a complete walk covering every
                              template edge (paper: "complete-walk TDS
                              constraints are crucial to guarantee zero false
                              positives").

Constraint *ordering* follows §3: CC/PC before TDS, then increasing walk
length. Walks visit rare-label vertices first (token-ordering optimization);
label frequencies of the background graph are passed in when available.

Host-side pure Python/numpy (+ networkx for biconnected components / cycle
basis on the tiny template graph).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import networkx as nx

from repro.graph.structs import Graph

MAX_TEMPLATE_VERTICES = 64


@dataclasses.dataclass(frozen=True)
class NonLocalConstraint:
    """A walk on the template to be verified by token passing (paper Alg. 5/6)."""

    kind: str  # "cycle" | "path" | "tds"
    walk: Tuple[int, ...]  # template vertex ids, consecutive pairs are template edges
    complete: bool = False  # covers every template edge (precision-guaranteeing TDS)

    @property
    def is_cyclic(self) -> bool:
        return self.walk[0] == self.walk[-1]

    @property
    def length(self) -> int:
        return len(self.walk) - 1

    def edges(self) -> set:
        return {
            (min(a, b), max(a, b)) for a, b in zip(self.walk[:-1], self.walk[1:])
        }

    def key(self) -> tuple:
        """Stable identity for work-reuse caches (incremental search)."""
        return (self.kind, self.walk, self.complete)


class Template:
    def __init__(self, labels: Sequence[int], edges: Sequence[Tuple[int, int]]):
        self.labels = np.asarray(labels, dtype=np.int32)
        self.n0 = int(self.labels.shape[0])
        if self.n0 > MAX_TEMPLATE_VERTICES:
            raise ValueError(f"template has {self.n0} > {MAX_TEMPLATE_VERTICES} vertices")
        es = set()
        for a, b in edges:
            a, b = int(a), int(b)
            if a == b:
                raise ValueError("self edges not allowed")
            es.add((min(a, b), max(a, b)))
        self.edge_set = frozenset(es)
        self.adj: List[List[int]] = [[] for _ in range(self.n0)]
        for a, b in sorted(es):
            self.adj[a].append(b)
            self.adj[b].append(a)
        self._nx = nx.Graph()
        self._nx.add_nodes_from(range(self.n0))
        self._nx.add_edges_from(es)
        if self.n0 > 1 and not nx.is_connected(self._nx):
            raise ValueError("template must be connected (paper §2)")
        # lazily computed + cached symmetry data (automorphism group, GraphPi
        # restrictions) — enumeration/counting hit these on every call
        self._automorphisms: Optional[Tuple[Tuple[int, ...], ...]] = None
        self._restrictions: Optional[Tuple[Tuple[int, int], ...]] = None

    # ---------------------------------------------------------------- basics
    @property
    def m0(self) -> int:
        return len(self.edge_set)

    def degree(self, q: int) -> int:
        return len(self.adj[q])

    def has_edge(self, a: int, b: int) -> bool:
        return (min(a, b), max(a, b)) in self.edge_set

    def adjacency_matrix(self) -> np.ndarray:
        A = np.zeros((self.n0, self.n0), dtype=bool)
        for a, b in self.edge_set:
            A[a, b] = A[b, a] = True
        return A

    def label_matrix(self, n_labels: int) -> np.ndarray:
        """one_hot[q, l] — used to initialize omega from background labels."""
        M = np.zeros((self.n0, n_labels), dtype=bool)
        for q in range(self.n0):
            if self.labels[q] < n_labels:
                M[q, self.labels[q]] = True
        return M

    def repeated_labels(self) -> bool:
        return len(set(self.labels.tolist())) < self.n0

    def is_edge_monocyclic(self) -> bool:
        """Cactus test: every biconnected component is a single edge or single cycle."""
        for comp in nx.biconnected_component_edges(self._nx):
            comp = list(comp)
            verts = {v for e in comp for v in e}
            if len(comp) > 1 and len(comp) != len(verts):
                return False
        return True

    def is_acyclic(self) -> bool:
        return self.m0 == self.n0 - 1

    def multiplicity_requirements(self) -> Dict[int, Dict[int, int]]:
        """req[q][label] = number of neighbors of q with that label (paper LCC's
        'minimum number of distinct active neighbors with the same label')."""
        out: Dict[int, Dict[int, int]] = {}
        for q in range(self.n0):
            counts: Dict[int, int] = {}
            for nb in self.adj[q]:
                counts[int(self.labels[nb])] = counts.get(int(self.labels[nb]), 0) + 1
            out[q] = counts
        return out

    # ------------------------------------------------------------- symmetry
    def automorphisms(self) -> Tuple[Tuple[int, ...], ...]:
        """All label-preserving graph automorphisms of the template, as
        permutation tuples (g[q] = image of q). Computed once by a
        backtracking search over invariant-refined candidate sets (label,
        degree, sorted neighbor-label multiset) and cached on the instance —
        the template has <= 64 vertices, so this is tiny. Replaces the old
        brute-force self-enumeration through the matching oracle."""
        if self._automorphisms is None:
            self._automorphisms = tuple(_automorphism_search(self))
        return self._automorphisms

    def automorphism_count(self) -> int:
        return len(self.automorphisms())

    def symmetry_restrictions(self) -> Tuple[Tuple[int, int], ...]:
        """GraphPi/GraphZero-style partial-order restrictions derived from the
        automorphism group by an orbit/stabilizer chain: a pair (a, b) means
        phi(a) < phi(b). An embedding class under Aut(T) has EXACTLY one
        member satisfying every restriction (the minimal-image representative
        at each level of the chain), so a join that enforces them in-flight
        counts matches-up-to-automorphism directly: restricted_count * |Aut|
        equals the unrestricted embedding count, with no post-hoc dedup."""
        if self._restrictions is None:
            group = list(self.automorphisms())
            restr = []
            for q in range(self.n0):
                if len(group) == 1:
                    break
                orbit = sorted({g[q] for g in group})
                restr.extend((q, q2) for q2 in orbit if q2 != q)
                group = [g for g in group if g[q] == q]  # stabilizer of q
            self._restrictions = tuple(restr)
        return self._restrictions

    def remove_edge(self, a: int, b: int) -> "Template":
        es = [e for e in self.edge_set if e != (min(a, b), max(a, b))]
        return Template(self.labels, es)

    def add_edge(self, a: int, b: int) -> "Template":
        return Template(self.labels, list(self.edge_set) + [(a, b)])

    def to_graph(self) -> Graph:
        return Graph.from_undirected_pairs(self.n0, sorted(self.edge_set), self.labels)

    def edge_deletion_variants(self, k: int = 1) -> List["Template"]:
        """All connected templates obtained by removing k edges (exploratory search)."""
        out, seen = [], set()
        for combo in itertools.combinations(sorted(self.edge_set), k):
            remaining = self.edge_set - set(combo)
            key = frozenset(remaining)
            if key in seen:
                continue
            seen.add(key)
            g = nx.Graph()
            g.add_nodes_from(range(self.n0))
            g.add_edges_from(remaining)
            if self.n0 > 1 and (not nx.is_connected(g) or g.number_of_edges() == 0):
                continue
            out.append(Template(self.labels, sorted(remaining)))
        return out

    def __repr__(self):
        return f"Template(n0={self.n0}, m0={self.m0}, labels={self.labels.tolist()})"


def _automorphism_search(t: "Template") -> List[Tuple[int, ...]]:
    """Backtracking search for all label-preserving automorphisms.

    Candidate images are pre-refined by the (label, degree, sorted
    neighbor-label multiset) invariant; the search then assigns images in
    vertex order, checking adjacency AND non-adjacency against every
    already-assigned vertex (a bijection preserving all edges of a finite
    graph with the same edge count preserves non-edges too, but checking both
    prunes the tree earlier)."""
    n0 = t.n0
    inv = []
    for q in range(n0):
        nb_labels = tuple(sorted(int(t.labels[p]) for p in t.adj[q]))
        inv.append((int(t.labels[q]), len(t.adj[q]), nb_labels))
    cand = [[p for p in range(n0) if inv[p] == inv[q]] for q in range(n0)]
    adj = t.adjacency_matrix()

    out: List[Tuple[int, ...]] = []
    img = [-1] * n0
    used = [False] * n0

    def bt(q: int):
        if q == n0:
            out.append(tuple(img))
            return
        for p in cand[q]:
            if used[p]:
                continue
            ok = True
            for q2 in range(q):
                if adj[q, q2] != adj[p, img[q2]]:
                    ok = False
                    break
            if ok:
                img[q] = p
                used[p] = True
                bt(q + 1)
                used[p] = False
                img[q] = -1

    bt(0)
    return out


# ------------------------------------------------------------- walk building
def _edge_cover_walk(
    vertices: set,
    edges: set,
    start: int,
    adj: Dict[int, List[int]],
    rank: Dict[int, float],
) -> Tuple[int, ...]:
    """DFS walk covering every edge of a connected subgraph, visiting
    rare-label neighbors first (paper's walk-orchestration optimization).
    Each edge is traversed at most twice (down + back up)."""
    walk = [start]
    seen = set()

    def dfs(u: int):
        for v in sorted(adj[u], key=lambda x: (rank.get(x, 0.0), x)):
            e = (min(u, v), max(u, v))
            if e in edges and e not in seen:
                seen.add(e)
                walk.append(v)
                dfs(v)
                walk.append(u)

    dfs(start)
    return tuple(walk)


def _subgraph_adj(edges: set) -> Dict[int, List[int]]:
    adj: Dict[int, List[int]] = {}
    for a, b in edges:
        adj.setdefault(a, []).append(b)
        adj.setdefault(b, []).append(a)
    return adj


def generate_constraints(
    template: Template,
    label_freq: Optional[np.ndarray] = None,
    guarantee_precision: bool = True,
) -> List[NonLocalConstraint]:
    """Table-2 heuristic. Returns constraints in verification order (§3 ordering)."""
    t = template
    freq = label_freq if label_freq is not None else np.ones(int(t.labels.max()) + 1)
    if len(freq) <= int(t.labels.max()):
        # template labels absent from the background graph have frequency 0
        freq = np.concatenate([freq, np.zeros(int(t.labels.max()) + 1 - len(freq))])
    rank = {q: float(freq[t.labels[q]]) for q in range(t.n0)}

    # Step 1/2 — vertex classification: unique-label leaves are LCC-only.
    label_counts: Dict[int, int] = {}
    for q in range(t.n0):
        label_counts[int(t.labels[q])] = label_counts.get(int(t.labels[q]), 0) + 1
    constraints: List[NonLocalConstraint] = []

    # Step 3 — cycle constraints, one per basis cycle.
    basis = nx.cycle_basis(t._nx)
    cycle_edge_sets: List[set] = []
    for cyc in basis:
        # rotate so the rarest-label vertex leads (token generation heuristic)
        i = min(range(len(cyc)), key=lambda k: (rank[cyc[k]], cyc[k]))
        cyc = cyc[i:] + cyc[:i]
        walk = tuple(cyc) + (cyc[0],)
        constraints.append(NonLocalConstraint("cycle", walk))
        cycle_edge_sets.append(
            {(min(a, b), max(a, b)) for a, b in zip(walk[:-1], walk[1:])}
        )
    all_cycle_edges = set().union(*cycle_edge_sets) if cycle_edge_sets else set()

    # Step 4 — path constraints for same-label pairs >= 3 hops apart.
    sp = dict(nx.all_pairs_shortest_path(t._nx))
    path_edge_sets: List[set] = []
    path_vertices: set = set()
    for a in range(t.n0):
        for b in range(a + 1, t.n0):
            if t.labels[a] != t.labels[b]:
                continue
            path = sp[a].get(b)
            if path is None or len(path) - 1 < 3:
                continue
            pedges = {(min(x, y), max(x, y)) for x, y in zip(path[:-1], path[1:])}
            if pedges <= all_cycle_edges:
                continue  # optimization (ii): covered by cycle constraints
            constraints.append(NonLocalConstraint("path", tuple(path)))
            path_edge_sets.append(pedges)
            path_vertices |= set(path)

    # Step 5 — TDS constraints.
    tds: List[NonLocalConstraint] = []
    union_cyc: set = set()
    if not t.is_edge_monocyclic():
        # union of edge-sharing cycle groups
        groups: List[set] = []
        for ce in cycle_edge_sets:
            merged = False
            for grp in groups:
                if grp & ce:
                    grp |= ce
                    merged = True
                    break
            if not merged:
                groups.append(set(ce))
        # merge transitively
        changed = True
        while changed:
            changed = False
            for i in range(len(groups)):
                for j in range(i + 1, len(groups)):
                    if groups[i] & groups[j]:
                        groups[i] |= groups[j]
                        del groups[j]
                        changed = True
                        break
                if changed:
                    break
        for grp in groups:
            if len(grp) <= 3:
                continue
            verts = {v for e in grp for v in e}
            start = min(verts, key=lambda q: (rank[q], q))
            walk = _edge_cover_walk(verts, grp, start, _subgraph_adj(grp), rank)
            union_cyc |= grp
            tds.append(NonLocalConstraint("tds", walk))
    union_path: set = set()
    if t.repeated_labels() and path_edge_sets:
        union_path = set().union(*path_edge_sets)
        verts = {v for e in union_path for v in e}
        start = min(verts, key=lambda q: (rank[q], q))
        walk = _edge_cover_walk(verts, union_path, start, _subgraph_adj(union_path), rank)
        tds.append(NonLocalConstraint("tds", walk))
    if union_cyc and union_path:
        both = union_cyc | union_path
        verts = {v for e in both for v in e}
        start = min(verts, key=lambda q: (rank[q], q))
        walk = _edge_cover_walk(verts, both, start, _subgraph_adj(both), rank)
        tds.append(NonLocalConstraint("tds", walk))

    # Zero-false-positive guarantee. The paper needs the complete walk only for
    # non-edge-monocyclic / repeated-label templates to guarantee *vertex*
    # precision; we additionally require it for any cyclic template because the
    # output contract here is the exact edge set too (Def. 1(iii)): a label-
    # compatible cross edge between two disjoint cycles survives LCC+CC but
    # participates in no match. Acyclic unique-label templates are exact after
    # LCC alone (Reza et al. 2017) — vertex injectivity is free when labels are
    # unique and every prescribed edge extends greedily to a full match.
    needs_complete = (not t.is_acyclic()) or t.repeated_labels()
    if guarantee_precision and needs_complete and t.m0 > 0:
        start = min(range(t.n0), key=lambda q: (rank[q], q))
        walk = _edge_cover_walk(
            set(range(t.n0)), set(t.edge_set), start,
            {q: list(t.adj[q]) for q in range(t.n0)}, rank,
        )
        tds.append(NonLocalConstraint("tds", walk, complete=True))

    # drop partial TDS walks identical to the complete one; dedup
    seen_keys = set()
    uniq: List[NonLocalConstraint] = []
    for c in constraints + tds:
        if c.key() in seen_keys:
            continue
        seen_keys.add(c.key())
        uniq.append(c)

    # §3 ordering: CC/PC first, then TDS; within class by increasing walk
    # length, tie-broken by the Tripoul et al. 2018 cost estimate (cheapest
    # verification first — longer walks through frequent labels explode).
    kind_order = {"cycle": 0, "path": 0, "tds": 1}
    total = max(float(np.sum(freq)), 1.0)
    uniq.sort(key=lambda c: (
        kind_order[c.kind], c.complete, c.length,
        estimate_walk_cost(t, c, freq, total),
    ))
    return uniq


def estimate_walk_cost(
    template: Template,
    constraint: NonLocalConstraint,
    label_freq: np.ndarray,
    total_vertices: Optional[float] = None,
) -> float:
    """Cheap a-priori cost model for verifying a walk constraint
    ([Tripoul et al. 2018]: estimate the number of constrained-walk
    extensions from label frequencies).

    Modeled as the expected number of token-forwarding messages when
    token-passing over a graph whose label-l vertices number freq[l]:
    the frontier after hop r scales with the product of the walk's label
    frequencies (normalized), so

        cost ~ freq[l(q_0)] * sum_r prod_{i<=r} (freq[l(q_i)] * d / n)

    with the density term (d/n) dropped — constant across constraints of the
    same background graph, so irrelevant to ORDERING."""
    total = total_vertices if total_vertices is not None else max(
        float(np.sum(label_freq)), 1.0)

    def f(q: int) -> float:
        l = int(template.labels[q])
        return float(label_freq[l]) / total if l < len(label_freq) else 0.0

    cost = 0.0
    level = f(constraint.walk[0]) * total  # tokens issued
    for q in constraint.walk[1:]:
        cost += level
        level = level * f(q)
    return cost


def estimate_constraint_selectivity(
    template: Template,
    constraint: NonLocalConstraint,
    label_freq: np.ndarray,
) -> float:
    """Expected fraction of token sources ELIMINATED by the constraint
    ([Tripoul et al. 2018]'s selectivity primitive): the probability that a
    random walk of this label sequence fails to close. Modeled as
    1 - prod(freq ratios) — rarer interior labels eliminate more sources."""
    total = max(float(np.sum(label_freq)), 1.0)
    p = 1.0
    for q in constraint.walk[1:]:
        l = int(template.labels[q])
        p *= float(label_freq[l]) / total if l < len(label_freq) else 0.0
    return 1.0 - min(p, 1.0)

"""The paper's primary contribution: pattern matching via constraint checking.

Pipeline: Template -> constraints (LCC implicit + CC/PC/TDS) -> iterative
pruning (Alg. 1) -> solution subgraph G* with per-vertex match lists omega ->
optional match enumeration / counting on the pruned graph.
"""
from repro.core.template import Template, NonLocalConstraint, generate_constraints
from repro.core.state import PruneState, init_state, pack_bits, unpack_bits
from repro.core.lcc import TemplateDev, lcc_iteration, lcc_fixpoint
from repro.core.pipeline import prune, PruneResult
from repro.core.batch import prune_batch, BatchedPruneResult, BatchedEngine
from repro.core.engine import (
    LocalBackend, SimBackend, SpmdBackend, make_backend,
)
from repro.core.enumerate import (
    enumerate_matches, count_matches, stream_matches, EnumerationResult,
    template_walk,
)
from repro.core.oracle import enumerate_matches_bruteforce, solution_subgraph_oracle
from repro.core.planner import (
    PlanPhase, QueryPlan, plan_query, heuristic_plan, resolve_query_plan,
    record_plan, constraint_signature, template_signature, plan_bucket,
)
from repro.core.resilience import (
    ResilienceConfig, ElasticConfig, RetryPolicy, FaultInjector, FaultSpec,
    InjectedFault, ShardLost, CollectiveTimeout, TransientKernelFailure,
    ResourceExhausted, PhaseFailed, ResilienceExhausted, PlanMismatch,
)

__all__ = [
    "Template",
    "NonLocalConstraint",
    "generate_constraints",
    "PruneState",
    "init_state",
    "pack_bits",
    "unpack_bits",
    "TemplateDev",
    "lcc_iteration",
    "lcc_fixpoint",
    "prune",
    "PruneResult",
    "prune_batch",
    "BatchedPruneResult",
    "BatchedEngine",
    "LocalBackend",
    "SimBackend",
    "SpmdBackend",
    "make_backend",
    "enumerate_matches",
    "count_matches",
    "stream_matches",
    "EnumerationResult",
    "template_walk",
    "enumerate_matches_bruteforce",
    "solution_subgraph_oracle",
    "ResilienceConfig",
    "ElasticConfig",
    "RetryPolicy",
    "FaultInjector",
    "FaultSpec",
    "InjectedFault",
    "ShardLost",
    "CollectiveTimeout",
    "TransientKernelFailure",
    "ResourceExhausted",
    "PhaseFailed",
    "ResilienceExhausted",
    "PlanMismatch",
    "PlanPhase",
    "QueryPlan",
    "plan_query",
    "heuristic_plan",
    "resolve_query_plan",
    "record_plan",
    "constraint_signature",
    "template_signature",
    "plan_bucket",
]

"""Paper §1(v) / [Reza et al. 2018] §5E — trading search effort for precision:
the pipeline can stop after any prefix of the constraint list; recall stays
100% (pruning only removes non-matching elements) while precision grows with
every checked constraint. We sweep the prefix length and measure vertex
precision against the brute-force oracle."""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.graph import generators as gen
from repro.core.template import Template, generate_constraints
from repro.core.pipeline import prune
from repro.core.oracle import solution_subgraph_oracle
from benchmarks.common import save

# non-edge-monocyclic + repeated labels: needs the full CC/PC/TDS ladder
TEMPLATE = Template(
    [3, 4, 5, 4, 3],
    [(0, 1), (1, 2), (2, 0), (1, 3), (3, 4), (4, 1)])


def run(scale: str = "small") -> Dict:
    sc = {"small": 10, "medium": 12, "large": 14}[scale]
    g = gen.rmat_graph(sc, edge_factor=8, seed=1, labeler="random", n_labels=8)
    tmpl = TEMPLATE
    vm_true, _, _, matches = solution_subgraph_oracle(g, tmpl)
    true_v = int(vm_true.sum())
    all_constraints = generate_constraints(
        tmpl, label_freq=g.label_frequency(), guarantee_precision=True)
    out: Dict = {
        "graph": {"n": g.n, "m": g.m},
        "true_matching_vertices": true_v,
        "n_matches": len(matches),
        "levels": [],
    }
    for k in range(len(all_constraints) + 1):
        t0 = time.perf_counter()
        res = prune(g, tmpl, constraints=all_constraints[:k],
                    tds_max_rows=60_000_000)
        secs = time.perf_counter() - t0
        sel = res.vertex_mask
        selected = int(sel.sum())
        tp = int((sel & vm_true).sum())
        assert tp == true_v, "recall must stay 100% at every level"
        out["levels"].append({
            "constraints_checked": k,
            "kinds": [c.kind for c in all_constraints[:k]],
            "selected_vertices": selected,
            "precision": tp / max(selected, 1),
            "recall": 1.0,
            "seconds": secs,
        })
    save("precision_tradeoff", out)
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1))

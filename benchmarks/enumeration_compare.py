"""Tables 4/5 — comparison with the direct-enumeration competitor class.

QFrag/Arabesque/TriAD are not available offline; their algorithmic core is
tree-search enumeration on the UNPRUNED graph (TurboISO / TLE), which is
exactly our brute-force oracle. We therefore compare:

  prune+enumerate (PruneJuice)  vs  tree-search on the unpruned graph

on Q4/Q6/Q8-flavor labeled patterns and 3/4-clique counting (Table 5),
reporting pruning time, enumeration time, and match counts (counts must be
EQUAL between the two systems — correctness cross-check included)."""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.core.template import Template
from repro.core.pipeline import prune
from repro.core.enumerate import enumerate_matches
from repro.core.oracle import enumerate_matches_bruteforce
from benchmarks.common import graph_for, save
from repro.graph import generators as gen

# Q4/Q6/Q8 flavors (Serafini et al. Fig. 11): labeled, most-frequent labels
PATTERNS = {
    "Q4-star-tail": ([3, 4, 5, 4, 6], [(0, 1), (0, 2), (0, 3), (1, 4)]),
    "Q6-triangle-tail": ([3, 4, 5, 4], [(0, 1), (1, 2), (2, 0), (1, 3)]),
    "Q8-diamond": ([3, 4, 5, 6], [(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)]),
}
CLIQUES = {
    "3-clique": Template([0, 0, 0], [(0, 1), (1, 2), (2, 0)]),
    "4-clique": Template([0, 0, 0, 0],
                         [(0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3)]),
}


def run(scale: str = "small") -> Dict:
    g = graph_for(scale)
    out: Dict = {"graph": {"n": g.n, "m": g.m}, "labeled": {}, "cliques": {}}
    for name, (labels, edges) in PATTERNS.items():
        tmpl = Template(labels, edges)
        prune(g, tmpl, tds_max_rows=60_000_000)  # warm-up (excludes jit compile)
        t0 = time.perf_counter()
        res = prune(g, tmpl, tds_max_rows=60_000_000)
        t_prune = time.perf_counter() - t0
        t0 = time.perf_counter()
        enum = enumerate_matches(res.dg, res.state, tmpl)
        t_enum = time.perf_counter() - t0
        t0 = time.perf_counter()
        oracle = enumerate_matches_bruteforce(g, tmpl)
        t_oracle = time.perf_counter() - t0
        assert enum.n_embeddings == len(oracle), (name, enum.n_embeddings, len(oracle))
        out["labeled"][name] = {
            "prune_seconds": t_prune, "enumerate_seconds": t_enum,
            "treesearch_seconds": t_oracle,
            "count": enum.n_embeddings,
            "pruned": res.counts(),
            "speedup_vs_treesearch": t_oracle / max(t_prune + t_enum, 1e-9),
        }
    # unlabeled clique counting (Table 5): single-label graph
    ug = gen.rmat_graph({"small": 9, "medium": 11, "large": 13}[scale],
                        edge_factor=6, seed=2)
    ug.labels[:] = 0
    for name, tmpl in CLIQUES.items():
        prune(ug, tmpl, tds_max_rows=60_000_000)  # warm-up
        t0 = time.perf_counter()
        res = prune(ug, tmpl, tds_max_rows=60_000_000)
        t_prune = time.perf_counter() - t0
        t0 = time.perf_counter()
        enum = enumerate_matches(res.dg, res.state, tmpl, max_rows=20_000_000)
        t_enum = time.perf_counter() - t0
        t0 = time.perf_counter()
        oracle = enumerate_matches_bruteforce(ug, tmpl)
        t_oracle = time.perf_counter() - t0
        assert enum.n_embeddings == len(oracle)
        out["cliques"][name] = {
            "prune_seconds": t_prune, "enumerate_seconds": t_enum,
            "treesearch_seconds": t_oracle,
            "count_embeddings": enum.n_embeddings,
            "count_up_to_automorphism": enum.n_matches_up_to_automorphism,
            "pruned": res.counts(),
        }
    save("enumeration_compare", out)
    return out


if __name__ == "__main__":
    print(run())

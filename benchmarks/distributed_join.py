"""Beyond-paper: replicated vs distributed-rows placement in the sharded
enumeration join.

Both flavors run the same device-resident TDS join over the sharded
backend's arrays; they differ ONLY in where intermediate rows live:

  replicated  — the full row table on every shard, slot map psum-combined
                (peak per-shard rows = global rows)
  rowsharded  — each row on the shard owning its next frontier vertex, one
                keyed `exchange_rows` per step (peak per-shard rows ~ 1/P)

This suite records the wall-time crossover and the per-shard resident-row
reduction at the benchmark scale; counts must be EQUAL (bit-parity is the
acceptance criterion, enforced here as a hard assert). The roll-up block
feeds BENCH_pipeline.json under the additive "distributed_join" key — the
CI smoke job gates on counts_match and on the memory reduction, which are
shape facts, not timing facts, so host speed cannot flake the gate."""
from __future__ import annotations

import time
from typing import Dict

from repro.core.template import Template
from repro.core.pipeline import prune
from repro.core.enumerate import enumerate_matches
from repro.kernels import registry
from benchmarks.common import graph_for, save

P = 4

# one acyclic (TDS walk) and one cyclic (symmetry-broken count) pattern
PATTERNS = {
    "T1-path-repeat": ([4, 3, 5, 3], [(0, 1), (1, 2), (2, 3)]),
    "T3-square": ([3, 4, 5, 6], [(0, 1), (1, 2), (2, 3), (3, 0)]),
}


def _count(res, flavor: str):
    stats: Dict = {}
    t0 = time.perf_counter()
    out = enumerate_matches(res, mode="count", route=flavor, stats=stats)
    return out, time.perf_counter() - t0, stats


def run(scale: str = "small") -> Dict:
    g = graph_for(scale)
    out: Dict = {"graph": {"n": g.n, "m": g.m}, "P": P, "patterns": {}}
    rollup = None
    for name, (labels, edges) in PATTERNS.items():
        tmpl = Template(labels, edges)
        res = prune(g, tmpl, partition=P, tds_max_rows=60_000_000)
        for flavor in (registry.ROUTE_REPLICATED, registry.ROUTE_ROWSHARDED):
            _count(res, flavor)  # warm-up (excludes jit compile)
        rep, t_rep, s_rep = _count(res, registry.ROUTE_REPLICATED)
        rsh, t_rsh, s_rsh = _count(res, registry.ROUTE_ROWSHARDED)
        assert rep.n_embeddings == rsh.n_embeddings, (
            name, rep.n_embeddings, rsh.n_embeddings)
        peak_rep = int(s_rep.get("join_rows_max", 0))
        peak_rsh = int(s_rsh.get("rowshard_peak_shard_rows", 0))
        row = {
            "replicated_seconds": t_rep,
            "rowsharded_seconds": t_rsh,
            "n_embeddings": rep.n_embeddings,
            "counts_match": rep.n_embeddings == rsh.n_embeddings,
            # peak resident rows per shard: replicated holds the global
            # table everywhere; rowsharded holds one owner block
            "peak_rows_replicated": peak_rep,
            "peak_shard_rows_rowsharded": peak_rsh,
            "resident_reduction": peak_rep / max(peak_rsh, 1),
            "exchanged_rows": int(s_rsh.get("rowshard_exchanged_rows", 0)),
            "owner_frac_max": float(s_rsh.get("rowshard_owner_frac_max", 0.0)),
        }
        out["patterns"][name] = row
        if rollup is None or row["n_embeddings"] > rollup["n_embeddings"]:
            rollup = {"P": P, "template": name, **row}
    out["rollup"] = {
        "P": P,
        "replicated_seconds": rollup["replicated_seconds"],
        "rowsharded_seconds": rollup["rowsharded_seconds"],
        "counts_match": all(r["counts_match"]
                            for r in out["patterns"].values()),
        "peak_rows_replicated": rollup["peak_rows_replicated"],
        "peak_shard_rows_rowsharded": rollup["peak_shard_rows_rowsharded"],
    }
    save("distributed_join", out)
    return out


if __name__ == "__main__":
    print(run())

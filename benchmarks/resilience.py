"""Beyond-paper production posture — fault-tolerant elastic execution.

Measures what the resilience layer (core/resilience.py + the re-enterable
pipeline driver) costs and buys on the sharded backend:

  - checkpoint overhead: a fault-free sharded prune with phase-boundary
    checkpointing on vs off (per-phase snapshot seconds and the total),
  - recovery: shard loss injected at the LAST phase boundary, restored from
    the latest checkpoint onto a SMALLER shard count (the paper's LB-16/LB-1
    recover-on-smaller-deployment), vs re-pruning from scratch,
  - parity: the recovered run must be bit-identical to the fault-free one
    (omega + endpoint-consistent edge mask) — monotone phases make phase
    boundaries exact consistency points.

The roll-up point gates on the two host-speed-immune shape facts
(`parity_ok`, `recovered_faster_than_scratch`); the seconds are trajectory
data.
"""
from __future__ import annotations

import tempfile
import time
from typing import Dict

import numpy as np

from repro.core.template import Template
from repro.core.pipeline import prune
from repro.core import resilience as res
from benchmarks.common import WDC_LIKE_TEMPLATES, graph_for, save, timer

P = 4
RESTART_P = 2


def run(scale: str = "small") -> Dict:
    g = graph_for(scale)
    # T2-bowtie + guarantee_precision: K=3 constraints (CC, CC, complete-walk
    # TDS) -> 4 phase boundaries, so the last-phase fault below restores a
    # real mid-pipeline checkpoint instead of re-pruning from scratch
    labels, edges = WDC_LIKE_TEMPLATES["T2-bowtie"]
    tmpl = Template(labels, edges)
    kw = dict(guarantee_precision=True)

    # fault-free sharded reference (also warms every jit cache so the
    # scratch-vs-recovery comparison below is compile-free on both sides)
    base = prune(g, tmpl, partition=P, **kw)
    n_phases = base.stats["n_constraints"] + 1
    _, scratch_s = timer(lambda: prune(g, tmpl, partition=P, **kw))

    out: Dict = {"graph": {"n": g.n, "m": g.m}, "P": P, "restart_P": RESTART_P,
                 "solution": base.counts(), "scratch_seconds": scratch_s}

    with tempfile.TemporaryDirectory() as ckpt_dir:
        # checkpointing on, no faults: the overhead side
        cfg = res.ResilienceConfig(checkpoint_dir=ckpt_dir)
        ck = prune(g, tmpl, partition=P, resilience=cfg, **kw)
        rs = ck.stats["resilience"]
        out["phases_checkpointed"] = rs["checkpoints"]
        out["checkpoint_seconds_per_phase"] = rs["checkpoint_seconds"]
        out["checkpoint_overhead_seconds"] = float(
            sum(rs["checkpoint_seconds"]))

    with tempfile.TemporaryDirectory() as ckpt_dir:
        # shard loss at the last phase: restore the phase-(K-1) checkpoint
        # onto RESTART_P shards, replay one phase
        inj = res.FaultInjector([res.FaultSpec(
            kind=res.FAULT_SHARD_LOSS, phase=n_phases - 1)])
        cfg = res.ResilienceConfig(
            checkpoint_dir=ckpt_dir, injector=inj,
            elastic=res.ElasticConfig(restart_P=RESTART_P))
        t0 = time.perf_counter()
        rec = prune(g, tmpl, partition=P, resilience=cfg, **kw)
        out["faulted_run_seconds"] = time.perf_counter() - t0
        rrs = rec.stats["resilience"]
        recovery_s = float(rrs["recovery_seconds"])
        parity = bool(
            np.array_equal(base.omega, rec.omega)
            and np.array_equal(base.edge_mask, rec.edge_mask))
        out["recovery_seconds"] = recovery_s
        out["restarts"] = rrs["restarts"]
        out["parity_ok"] = parity

    out["rollup"] = {
        "P": P,
        "restart_P": RESTART_P,
        "phases_checkpointed": int(out["phases_checkpointed"]),
        "checkpoint_overhead_seconds": out["checkpoint_overhead_seconds"],
        "recovery_seconds": recovery_s,
        "scratch_seconds": scratch_s,
        "parity_ok": parity,
        "recovered_faster_than_scratch": bool(recovery_s < scratch_s),
    }
    save("resilience", out)
    return out


if __name__ == "__main__":
    print(run())

"""Fig. 9 — interactive incremental search: naive (re-search from scratch)
vs PJI-X (candidate set) vs PJI-Y (candidate set + non-local work reuse),
over a Fig.-8-style edge-addition sequence."""
from __future__ import annotations

from typing import Dict, List

from repro.core.template import Template
from repro.core.incremental import IncrementalSession
from benchmarks.common import graph_for, save, timer
from repro.core.pipeline import prune


def _query_sequence():
    """Fig. 8 flavor: start under-constrained, add edges step by step."""
    labels = [4, 3, 5, 3, 4]
    seqs = [
        [(0, 1), (1, 2), (2, 3), (3, 4)],
        [(0, 1), (1, 2), (2, 3), (3, 4), (4, 0)],
        [(0, 1), (1, 2), (2, 3), (3, 4), (4, 0), (1, 3)],
        [(0, 1), (1, 2), (2, 3), (3, 4), (4, 0), (1, 3), (0, 2)],
    ]
    return [Template(labels, es) for es in seqs]


def run(scale: str = "small") -> Dict:
    g = graph_for(scale)
    queries = _query_sequence()
    out: Dict = {"graph": {"n": g.n, "m": g.m}, "modes": {}}

    # naive: full precision-less prune per query (same contract as PJI)
    times, verts = [], []
    for q in queries:
        res, secs = timer(prune, g, q, guarantee_precision=False)
        times.append(secs)
        verts.append(res.counts()["V*"])
    out["modes"]["naive"] = {"per_query_seconds": times, "total": sum(times),
                             "matched_vertices": verts}

    for mode, (cand, reuse) in {
        "PJI-X": (True, False), "PJI-Y": (True, True),
    }.items():
        session, setup_secs = timer(
            IncrementalSession, g, queries[0],
            use_candidate_set=cand, use_work_reuse=reuse)
        times, verts, reused = [], [], []
        for q in queries:
            (state, stat), secs = timer(session.search, q)
            times.append(secs)
            verts.append(stat.matched_vertices)
            reused.append(stat.constraints_reused)
        out["modes"][mode] = {
            "setup_seconds": setup_secs,
            "per_query_seconds": times,
            "total": setup_secs + sum(times),
            "matched_vertices": verts,
            "constraints_reused": reused,
        }
    out["speedup_PJI-X"] = out["modes"]["naive"]["total"] / out["modes"]["PJI-X"]["total"]
    out["speedup_PJI-Y"] = out["modes"]["naive"]["total"] / out["modes"]["PJI-Y"]["total"]
    save("incremental", out)
    return out


if __name__ == "__main__":
    print(run())

"""Beyond-paper: template-batched multi-tenant execution vs per-query runs.

The multi-tenant claim (core/batch.py): B same-bucket template queries
stacked along a lane axis run the whole prune pipeline through ONE traced
program set and one kernel-dispatch sequence — vs B sequential `prune` calls
each paying their own trace, compile, dispatch chains, and host syncs. This
suite records that crossover at B=8 plus a serving-engine drain point
(serve/graph_query.py: 32 mixed queries through the admission queue and
shape-bucket batcher, zero dropped).

Both paths run guarantee_precision=False (cycle/path constraints only) so
the measured delta is the device-dispatch economics this PR changed, not the
host-side TDS row joins both paths share. Per-query results must be
BIT-IDENTICAL between the two paths (hard assert -> counts_match); the CI
smoke job gates on counts_match and batched_seconds < sequential_seconds.
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.core.template import Template
from repro.core.pipeline import prune
from repro.core.batch import prune_batch
from repro.core.enumerate import count_matches
from benchmarks.common import graph_for, save

B = 8

# eight same-bucket (pow2 n0 -> 4) WDC-flavored variants: paths, squares,
# triangles, repeated-label (counted) patterns — mid-frequency labels
TEMPLATES = [
    ("path-repeat", [4, 3, 5, 3], [(0, 1), (1, 2), (2, 3)]),
    ("square", [3, 4, 5, 6], [(0, 1), (1, 2), (2, 3), (3, 0)]),
    ("square-rare", [6, 7, 8, 7], [(0, 1), (1, 2), (2, 3), (3, 0)]),
    ("triangle", [5, 4, 4], [(0, 1), (1, 2), (2, 0)]),
    ("path-mid", [4, 5, 6, 5], [(0, 1), (1, 2), (2, 3)]),
    ("square-mid", [5, 6, 4, 3], [(0, 1), (1, 2), (2, 3), (3, 0)]),
    ("triangle-counted", [3, 3, 4], [(0, 1), (1, 2), (2, 0)]),
    ("square-wide", [6, 5, 4, 5], [(0, 1), (1, 2), (2, 3), (3, 0)]),
]

PRUNE_KW = dict(guarantee_precision=False)


def run(scale: str = "small") -> Dict:
    g = graph_for(scale)
    label_freq = g.label_frequency()
    templates = [Template(labels, edges) for _, labels, edges in TEMPLATES]

    # warm-up: populate any persistent compilation caches on both paths so
    # the timed comparison is steady-state, not first-touch
    prune_batch(g, templates, label_freq=label_freq, **PRUNE_KW)
    prune(g, templates[0], label_freq=label_freq, **PRUNE_KW)

    t0 = time.perf_counter()
    bres = prune_batch(g, templates, label_freq=label_freq, **PRUNE_KW)
    batched_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    seq = [prune(g, t, label_freq=label_freq, **PRUNE_KW) for t in templates]
    sequential_s = time.perf_counter() - t0

    counts_match = True
    per_query = {}
    for (name, _, _), t, bl, sl in zip(TEMPLATES, templates,
                                       bres.results, seq):
        bits_ok = (np.array_equal(np.asarray(bl.state.omega),
                                  np.asarray(sl.state.omega))
                   and np.array_equal(np.asarray(bl.state.edge_active),
                                      np.asarray(sl.state.edge_active)))
        cb = int(count_matches(bl.dg, bl.state, t,
                               label_freq=label_freq).n_embeddings)
        cs = int(count_matches(sl.dg, sl.state, t,
                               label_freq=label_freq).n_embeddings)
        ok = bits_ok and cb == cs
        assert ok, (name, bits_ok, cb, cs)
        counts_match &= ok
        per_query[name] = {"n_embeddings": cb, "bit_identical": bits_ok}

    serve = _serve_drain(g)

    out = {
        "graph": {"n": g.n, "m": g.m},
        "B": B,
        "batched_seconds": batched_s,
        "sequential_seconds": sequential_s,
        "speedup": sequential_s / max(batched_s, 1e-9),
        "counts_match": counts_match,
        "bucket": bres.stats["batched"]["bucket"],
        "dispatch_routes": bres.stats["dispatch_routes"],
        "per_query": per_query,
        "serve": serve,
        "rollup": {
            "B": B,
            "batched_seconds": batched_s,
            "sequential_seconds": sequential_s,
            "counts_match": counts_match,
            "serve_queries": serve["n_queries"],
            "serve_dropped": serve["n_dropped"],
            "serve_batches": serve["n_batches"],
        },
    }
    save("multi_tenant", out)
    return out


def _serve_drain(g, n_queries: int = 32) -> Dict:
    """Drain a mixed-template workload through the serving engine: admission
    queue -> shape-bucket batcher -> batched prunes -> results. Every query
    must come back (zero dropped; no deadlines set here, so zero missed)."""
    from repro.serve import GraphQueryEngine, example_workload, MODE_PRUNE

    eng = GraphQueryEngine(g, max_batch=B, max_wait_s=0.0, **PRUNE_KW)
    templates = example_workload(n_queries, seed=1,
                                 labels_max=int(g.labels.max()))
    t0 = time.perf_counter()
    ids = [eng.submit(t, mode=MODE_PRUNE) for t in templates]
    results = eng.drain()
    dt = time.perf_counter() - t0
    assert len(results) == len(ids) and eng.n_pending == 0
    n_ok = sum(r.status == "ok" for r in results)
    return {
        "n_queries": n_queries,
        "n_ok": n_ok,
        "n_dropped": n_queries - len(results),
        "n_deadline_missed": n_queries - n_ok,
        "n_batches": eng.stats["n_batches"],
        "seconds": dt,
        "queries_per_second": n_queries / max(dt, 1e-9),
    }


if __name__ == "__main__":
    print(run())

"""Fig. 6(b) — work aggregation.

The paper's tokens are (source, current-vertex) pairs; without the tau(v)
dedup set, a vertex forwards one copy per distinct walk, and the message
count equals the number of token paths (45B paths vs 71M messages on UK Web
= 3-4 orders of magnitude). In this engine the dedup is *structural*: the
bit-packed multi-source frontier can represent each (source, vertex, hop) at
most once, so the aggregated message count is the frontier-word traffic.

This benchmark therefore measures, per non-local constraint:
  aggregated    — actual frontier messages sent by check_walk_constraint
  unaggregated  — the token-path count of the paper's no-dedup baseline,
                  computed exactly with a per-hop path-count recurrence
                  (counts, not enumeration — no combinatorial blowup)
"""
from __future__ import annotations

from typing import Dict

import numpy as np
import jax.numpy as jnp

from repro.core.template import Template, generate_constraints
from repro.core.pipeline import prune
from repro.core import nlcc as nlcc_mod
from repro.core.state import PruneState
from repro.graph.structs import DeviceGraph
from repro.graph import segment_ops
from benchmarks.common import WDC_LIKE_TEMPLATES, graph_for, save

PATTERNS = {
    "T3-square": WDC_LIKE_TEMPLATES["T3-square"],
    "T1-path-repeat": WDC_LIKE_TEMPLATES["T1-path-repeat"],
    "T6-hex": ([3, 4, 5, 3, 4, 5],
               [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 0)]),
}


def count_token_paths(dg: DeviceGraph, state: PruneState, walk) -> float:
    """Exact number of token-forwarding messages the paper's no-dedup
    baseline would send for this constraint (sum over hops of live walk
    prefixes), via a float path-count recurrence."""
    omega = np.asarray(state.omega)
    cand = [jnp.asarray(omega[:, q]) for q in walk]
    counts = cand[0].astype(jnp.float64)  # one token per source
    total = 0.0
    for r in range(1, len(walk)):
        msgs = jnp.take(counts, dg.src) * state.edge_active
        total += float(jnp.sum(msgs))
        agg = segment_ops.segment_sum(msgs, dg.dst, dg.n)
        counts = agg * cand[r].astype(jnp.float64)
    return total


def _frontier_messages(dg, state, walk) -> int:
    """Messages the aggregated frontier sends for ONE walk (no rotations)."""
    omega = state.omega
    cand = jnp.stack([omega[:, q] for q in walk], axis=0)
    sources = np.flatnonzero(np.asarray(omega[:, walk[0]]))
    total = 0
    wave = 1024
    for off in range(0, sources.size, wave):
        ids = sources[off:off + wave]
        pad = wave - ids.size
        idsp = np.concatenate([ids, np.full(pad, -1, np.int64)]) if pad else ids
        _, n_msgs = nlcc_mod.check_walk_constraint(
            dg, state, cand, walk[0] == walk[-1],
            jnp.asarray(idsp, jnp.int32), count_messages=True)
        total += int(n_msgs)
    return total


def run(scale: str = "small") -> Dict:
    # randomly labeled graph, like the paper's Twitter / UK Web runs (Q8):
    # frequent labels land on hubs, so undeduplicated token paths multiply
    from repro.graph import generators as gen
    sc = {"small": 11, "medium": 14, "large": 16}[scale]
    g = gen.rmat_graph(sc, edge_factor=8, preset="graph500", seed=0,
                       labeler="random", n_labels=10)
    out: Dict = {"graph": {"n": g.n, "m": g.m}, "patterns": {}}
    from repro.core.state import init_state

    for name, (labels, edges) in PATTERNS.items():
        tmpl = Template(labels, edges)
        res = prune(g, tmpl, constraints=[])  # LCC fixpoint only
        label_state = init_state(res.dg, tmpl)  # label filter only (stress)
        constraints = generate_constraints(
            tmpl, label_freq=g.label_frequency(), guarantee_precision=False)
        entries = []
        for c in constraints:
            if c.kind not in ("cycle", "path"):
                continue
            entry = {"constraint": str(c.walk), "kind": c.kind}
            for mode, st in (("post_lcc", res.state), ("label_only", label_state)):
                paths = count_token_paths(res.dg, st, c.walk)
                agg_msgs = _frontier_messages(res.dg, st, c.walk)
                entry[mode] = {
                    "aggregated_messages": int(agg_msgs),
                    "token_paths_no_dedup": paths,
                    "reduction_factor": paths / max(agg_msgs, 1),
                }
            entries.append(entry)
        out["patterns"][name] = {
            "post_lcc_counts": res.counts(),
            "constraints": entries,
        }
    save("work_aggregation", out)
    return out


if __name__ == "__main__":
    print(run())

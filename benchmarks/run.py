"""Run the full benchmark suite (one module per paper table/figure).

  PYTHONPATH=src python -m benchmarks.run [--scale small|medium|large] [--only NAME]

Per-suite results land in experiments/bench/<name>.json; the perf-trajectory
roll-up (per-suite wall time, pipeline phase breakdown, tuned dispatch
decisions, graph scale) is written to the repo-root BENCH_pipeline.json
(schema: benchmarks/common.validate_rollup; docs/BENCHMARKS.md). The default
scale is `small` — the CI-sized run (common.py). Roofline terms come from
the dry-run (launch/dryrun.py), not here.

`dispatch_policy` runs first on purpose: it tunes and installs the dispatch
policy cache, so every later suite (and the recorded phase breakdown) runs
under measured routing rather than the untuned fallback.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import common

SUITES = [
    ("dispatch_policy", "beyond-paper: autotune packed/unpacked + kernel modes"),
    ("strong_scaling", "Fig 5: phase breakdown + per-shard balance"),
    ("edge_elimination", "Fig 6a: edge elimination ablation"),
    ("work_aggregation", "Fig 6b: TDS token dedup ablation"),
    ("load_balance", "Fig 7: reshuffle + smaller deployments"),
    ("incremental", "Fig 9: naive vs PJI-X vs PJI-Y"),
    ("exploratory", "Fig 10: progressive relaxation"),
    ("enumeration_compare", "Tables 4/5: vs tree-search enumeration"),
    ("template_sensitivity", "Table 6: template topology family"),
    ("rmat_distributions", "Table 10: R-MAT skew sweep"),
    ("frontier_edge_prune", "beyond-paper: CC edge-exactness, TDS skipped"),
    ("precision_tradeoff", "Reza'18 §5E: effort vs precision (recall 100%)"),
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small",
                    choices=["small", "medium", "large"])
    ap.add_argument("--only", default=None)
    ap.add_argument("--no-rollup", action="store_true",
                    help="skip writing the repo-root BENCH_pipeline.json")
    args = ap.parse_args(argv)
    known = [name for name, _ in SUITES]
    if args.only and args.only not in known:
        ap.error(f"--only {args.only!r} matches no suite; known: {known}")

    suites = {}
    payloads = {}
    failures = []
    for name, desc in SUITES:
        if args.only and name != args.only:
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.perf_counter()
        try:
            payloads[name] = mod.run(args.scale)
            secs = time.perf_counter() - t0
            suites[name] = {"seconds": secs, "ok": True, "description": desc}
            print(f"[ok]   {name:24s} {desc} ({secs:.1f}s)")
        except Exception as e:
            secs = time.perf_counter() - t0
            suites[name] = {"seconds": secs, "ok": False, "description": desc,
                            "error": repr(e)}
            failures.append((name, repr(e)))
            print(f"[FAIL] {name:24s} {e}")
            traceback.print_exc()

    if suites and not args.no_rollup:
        dp = payloads.get("dispatch_policy", {})
        path = common.write_rollup(
            suites, args.scale,
            graph=dp.get("graph"),
            phases=dp.get("phase_breakdown"),
            nlcc_wave=dp.get("nlcc_wave"),
        )
        print(f"roll-up -> {path}")

    print(f"\n{len(failures)} benchmark failures")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()

"""Run the full benchmark suite (one module per paper table/figure).

  PYTHONPATH=src python -m benchmarks.run [--scale small|medium|large] [--only NAME]

Per-suite results land in experiments/bench/<name>.json; the perf-trajectory
roll-up (per-suite wall time, pipeline phase breakdown, tuned dispatch
decisions, graph scale) is written to the repo-root BENCH_pipeline.json
(schema: benchmarks/common.validate_rollup; docs/BENCHMARKS.md). The default
scale is `small` — the CI-sized run (common.py). Roofline terms come from
the dry-run (launch/dryrun.py), not here.

`dispatch_policy` runs first on purpose: it tunes and installs the dispatch
policy cache, so every later suite (and the recorded phase breakdown) runs
under measured routing rather than the untuned fallback.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

from benchmarks import common

SUITES = [
    ("dispatch_policy", "beyond-paper: autotune packed/unpacked + kernel modes"),
    ("strong_scaling", "Fig 5: phase breakdown + per-shard balance"),
    ("edge_elimination", "Fig 6a: edge elimination ablation"),
    ("work_aggregation", "Fig 6b: TDS token dedup ablation"),
    ("load_balance", "Fig 7: reshuffle + smaller deployments"),
    ("incremental", "Fig 9: naive vs PJI-X vs PJI-Y"),
    ("exploratory", "Fig 10: progressive relaxation"),
    ("enumeration_compare", "Tables 4/5: vs tree-search enumeration"),
    ("distributed_join", "beyond-paper: replicated vs distributed-rows join"),
    ("multi_tenant", "beyond-paper: template-batched B-query execution"),
    ("query_plan", "plan-level optimizer: planned vs heuristic order"),
    ("template_sensitivity", "Table 6: template topology family"),
    ("rmat_distributions", "Table 10: R-MAT skew sweep"),
    ("frontier_edge_prune", "beyond-paper: CC edge-exactness, TDS skipped"),
    ("precision_tradeoff", "Reza'18 §5E: effort vs precision (recall 100%)"),
    ("resilience", "beyond-paper: phase checkpoints + elastic fault recovery"),
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="small",
                    choices=["small", "medium", "large"])
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names to run")
    ap.add_argument("--no-rollup", action="store_true",
                    help="skip writing the repo-root BENCH_pipeline.json")
    args = ap.parse_args(argv)
    known = [name for name, _ in SUITES]
    only = args.only.split(",") if args.only else None
    if only:
        for sel in only:
            if sel not in known:
                ap.error(f"--only {sel!r} matches no suite; known: {known}")

    suites = {}
    payloads = {}
    failures = []
    for name, desc in SUITES:
        if only and name not in only:
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.perf_counter()
        try:
            payloads[name] = mod.run(args.scale)
            secs = time.perf_counter() - t0
            suites[name] = {"seconds": secs, "ok": True, "description": desc}
            print(f"[ok]   {name:24s} {desc} ({secs:.1f}s)")
        except Exception as e:
            secs = time.perf_counter() - t0
            suites[name] = {"seconds": secs, "ok": False, "description": desc,
                            "error": repr(e)}
            failures.append((name, repr(e)))
            print(f"[FAIL] {name:24s} {e}")
            traceback.print_exc()

    if suites and not args.no_rollup:
        dp = payloads.get("dispatch_policy", {})
        carried = {}
        if only:
            # a partial (--only) run refreshes only its own suites: merge into
            # the existing same-scale roll-up so the other recorded suite
            # timings (the PR-over-PR trajectory) are not silently dropped
            try:
                with open(common.rollup_path()) as f:
                    prev = json.load(f)
            except (OSError, ValueError):
                prev = {}
            if prev.get("scale") == args.scale:
                suites = {**prev.get("suites", {}), **suites}
                carried = {k: prev.get(k)
                           for k in ("graph", "phases", "nlcc_wave",
                                     "sharded_prune", "enumeration",
                                     "distributed_join", "load_balance",
                                     "multi_tenant", "query_plan",
                                     "resilience", "policy")}
        path = common.write_rollup(
            suites, args.scale,
            graph=dp.get("graph") or carried.get("graph"),
            phases=dp.get("phase_breakdown") or carried.get("phases"),
            nlcc_wave=dp.get("nlcc_wave") or carried.get("nlcc_wave"),
            sharded_prune=(payloads.get("strong_scaling", {}).get("sharded_prune")
                           or carried.get("sharded_prune")),
            enumeration=dp.get("enumeration") or carried.get("enumeration"),
            distributed_join=(
                payloads.get("distributed_join", {}).get("rollup")
                or carried.get("distributed_join")),
            load_balance=(payloads.get("load_balance", {}).get("rollup")
                          or carried.get("load_balance")),
            multi_tenant=(payloads.get("multi_tenant", {}).get("rollup")
                          or carried.get("multi_tenant")),
            query_plan=(payloads.get("query_plan", {}).get("rollup")
                        or carried.get("query_plan")),
            resilience=(payloads.get("resilience", {}).get("rollup")
                        or carried.get("resilience")),
            policy_fallback=carried.get("policy"),
        )
        print(f"roll-up -> {path}")

    print(f"\n{len(failures)} benchmark failures")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()

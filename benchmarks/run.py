"""Run the full benchmark suite (one module per paper table/figure).

  PYTHONPATH=src python -m benchmarks.run [--scale small|medium] [--only NAME]

Results land in experiments/bench/<name>.json; a compact summary prints at
the end. Roofline terms come from the dry-run (launch/dryrun.py), not here.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

SUITES = [
    ("strong_scaling", "Fig 5: phase breakdown + per-shard balance"),
    ("edge_elimination", "Fig 6a: edge elimination ablation"),
    ("work_aggregation", "Fig 6b: TDS token dedup ablation"),
    ("load_balance", "Fig 7: reshuffle + smaller deployments"),
    ("incremental", "Fig 9: naive vs PJI-X vs PJI-Y"),
    ("exploratory", "Fig 10: progressive relaxation"),
    ("enumeration_compare", "Tables 4/5: vs tree-search enumeration"),
    ("template_sensitivity", "Table 6: template topology family"),
    ("rmat_distributions", "Table 10: R-MAT skew sweep"),
    ("frontier_edge_prune", "beyond-paper: CC edge-exactness, TDS skipped"),
    ("precision_tradeoff", "Reza'18 §5E: effort vs precision (recall 100%)"),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="medium", choices=["small", "medium", "large"])
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    failures = []
    for name, desc in SUITES:
        if args.only and name != args.only:
            continue
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.perf_counter()
        try:
            mod.run(args.scale)
            print(f"[ok]   {name:24s} {desc} ({time.perf_counter()-t0:.1f}s)")
        except Exception as e:
            failures.append((name, repr(e)))
            print(f"[FAIL] {name:24s} {e}")
            traceback.print_exc()
    print(f"\n{len(failures)} benchmark failures")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()

"""Table 6 — template topology sensitivity: the Fig.-12 family (a)-(e):
two monocycles, their union, and +1/+2 chord variants (the last needs the
longest TDS). Reports |V*|, 2|E*| and pruning time; expectation per the
paper: MORE constraints can prune FASTER when the added substructure is rare."""
from __future__ import annotations

import time
from typing import Dict

from repro.core.template import Template
from repro.core.pipeline import prune
from benchmarks.common import graph_for, save

LBL = {"gov": 7, "org": 4, "edu": 6, "net": 5, "com": 3}


def _family():
    # (a) 4-cycle; (b) another 4-cycle sharing the edu vertex; (c) union;
    # (d) +1 chord; (e) +2 chords (contains a 4-clique like the paper's (e))
    a = Template([LBL["org"], LBL["net"], LBL["org"], LBL["edu"]],
                 [(0, 1), (1, 2), (2, 3), (3, 0)])
    b = Template([LBL["edu"], LBL["gov"], LBL["com"], LBL["gov"]],
                 [(0, 1), (1, 2), (2, 3), (3, 0)])
    labels_c = [LBL["org"], LBL["net"], LBL["org"], LBL["edu"],
                LBL["gov"], LBL["com"], LBL["gov"]]
    ec = [(0, 1), (1, 2), (2, 3), (3, 0), (3, 4), (4, 5), (5, 6), (6, 3)]
    c = Template(labels_c, ec)
    d = Template(labels_c, ec + [(4, 6)])
    e = Template(labels_c, ec + [(4, 6), (0, 2)])
    return {"a": a, "b": b, "c": c, "d": d, "e": e}


def run(scale: str = "small") -> Dict:
    g = graph_for(scale)
    out: Dict = {"graph": {"n": g.n, "m": g.m}, "templates": {}}
    for name, tmpl in _family().items():
        t0 = time.perf_counter()
        res = prune(g, tmpl)
        secs = time.perf_counter() - t0
        out["templates"][name] = {
            "n0": tmpl.n0, "m0": tmpl.m0,
            "edge_monocyclic": tmpl.is_edge_monocyclic(),
            "V*": res.counts()["V*"], "2E*": res.counts()["E*"],
            "seconds": secs,
            "n_constraints": res.stats.get("n_constraints"),
        }
    save("template_sensitivity", out)
    return out


if __name__ == "__main__":
    print(run())

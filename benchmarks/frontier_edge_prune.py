"""Beyond-paper (EXPERIMENTS §5.4): forward-backward frontier edge pruning.

For unique-label edge-monocyclic templates, CC + frontier edge elimination
yields the exact solution subgraph and the complete-walk TDS is skipped.
Measures time-to-exact-solution and TDS row expansions with the knob off/on;
outputs must be identical (asserted)."""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.graph import generators as gen
from repro.core.template import Template
from repro.core.pipeline import prune
from benchmarks.common import save

PATTERNS = {
    "hex-unique": ([3, 4, 5, 6, 7, 8],
                   [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 0)]),
    "square-unique": ([3, 4, 5, 6], [(0, 1), (1, 2), (2, 3), (3, 0)]),
    "cactus": ([3, 4, 5, 6, 7],
               [(0, 1), (1, 2), (2, 0), (2, 3), (3, 4)]),
}


def run(scale: str = "small") -> Dict:
    sc = {"small": 10, "medium": 12, "large": 14}[scale]
    g = gen.rmat_graph(sc, edge_factor=8, seed=0, labeler="random", n_labels=10)
    out: Dict = {"graph": {"n": g.n, "m": g.m}, "patterns": {}}
    for name, (labels, edges) in PATTERNS.items():
        tmpl = Template(labels, edges)
        rows = {}
        sols = {}
        for ep in (False, True):
            t0 = time.perf_counter()
            res = prune(g, tmpl, nlcc_edge_prune=ep, collect_stats=True,
                        tds_max_rows=60_000_000)
            dt = time.perf_counter() - t0
            tds_rows = sum(p.extra.get("tds_expansions", 0) for p in res.phases)
            rows["frontier" if ep else "baseline"] = {
                "seconds": dt, "tds_row_expansions": tds_rows,
                "tds_skipped": bool(
                    res.stats.get("tds_skipped_via_frontier_edge_prune", False)),
                "solution": res.counts(),
            }
            sols[ep] = (res.vertex_mask.tobytes(), res.edge_mask.tobytes())
        assert sols[False] == sols[True], f"{name}: outputs differ!"
        rows["speedup"] = rows["baseline"]["seconds"] / max(
            rows["frontier"]["seconds"], 1e-9)
        out["patterns"][name] = rows
    save("frontier_edge_prune", out)
    return out


if __name__ == "__main__":
    print(run())

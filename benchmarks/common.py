"""Shared benchmark infrastructure.

Every benchmark module exposes run(scale) -> dict and maps 1:1 to a paper
table/figure (DESIGN.md §7). Scales:
  small  — CI-sized (seconds; the default for benchmarks.run)
  medium — minutes on one CPU host
Results are appended to experiments/bench/<name>.json.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict

import numpy as np

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")

# WDC-flavored templates over degree-labeled R-MAT graphs. Labels follow
# l(v) = ceil(log2(deg+1)); mid-frequency labels (3..6) are abundant the way
# com/org/net are in WDC.
WDC_LIKE_TEMPLATES = {
    # WDC-1 flavor: acyclic, repeated labels -> PC + TDS
    "T1-path-repeat": ([4, 3, 5, 3], [(0, 1), (1, 2), (2, 3)]),
    # WDC-2 flavor: two cycles sharing an edge -> CC + TDS
    "T2-bowtie": ([4, 5, 3, 5, 4], [(0, 1), (1, 2), (2, 0), (1, 3), (3, 4), (4, 1)]),
    # WDC-3 flavor: monocycle -> CC only
    "T3-square": ([3, 4, 5, 6], [(0, 1), (1, 2), (2, 3), (3, 0)]),
    # WDC-4 flavor: same topology, rarer labels
    "T4-square-rare": ([6, 7, 8, 7], [(0, 1), (1, 2), (2, 3), (3, 0)]),
}


def timer(fn: Callable, *args, repeat: int = 1, **kwargs):
    best, out = float("inf"), None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        best = min(best, time.perf_counter() - t0)
    return out, best


def save(name: str, payload: Dict):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=_np_default)
    return path


def _np_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    return str(o)


def graph_for(scale_name: str, seed: int = 0):
    from repro.graph import generators as gen
    scale = {"small": 11, "medium": 14, "large": 16}[scale_name]
    return gen.rmat_graph(scale, edge_factor=8, preset="graph500", seed=seed)

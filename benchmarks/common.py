"""Shared benchmark infrastructure.

Every benchmark module exposes run(scale) -> dict and maps 1:1 to a paper
table/figure (DESIGN.md §7; docs/BENCHMARKS.md has the full map). Scales —
all three accepted by `graph_for` and `benchmarks.run --scale`:
  small  — R-MAT scale 11 (~2k vertices); CI-sized (seconds; the default
           for benchmarks.run)
  medium — R-MAT scale 14 (~16k vertices); minutes on one CPU host
  large  — R-MAT scale 16 (~65k vertices); tens of minutes on CPU, the
           smallest scale where kernel-mode choices start to matter
Per-suite results land in experiments/bench/<name>.json; `benchmarks.run`
additionally writes the repo-root BENCH_pipeline.json roll-up (see
`write_rollup` — per-suite wall time, phase breakdown, tuned dispatch
decisions, graph scale) so every PR's perf delta is visible in one file.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional

import numpy as np

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")

ROLLUP_SCHEMA_VERSION = 1

# WDC-flavored templates over degree-labeled R-MAT graphs. Labels follow
# l(v) = ceil(log2(deg+1)); mid-frequency labels (3..6) are abundant the way
# com/org/net are in WDC.
WDC_LIKE_TEMPLATES = {
    # WDC-1 flavor: acyclic, repeated labels -> PC + TDS
    "T1-path-repeat": ([4, 3, 5, 3], [(0, 1), (1, 2), (2, 3)]),
    # WDC-2 flavor: two cycles sharing an edge -> CC + TDS
    "T2-bowtie": ([4, 5, 3, 5, 4], [(0, 1), (1, 2), (2, 0), (1, 3), (3, 4), (4, 1)]),
    # WDC-3 flavor: monocycle -> CC only
    "T3-square": ([3, 4, 5, 6], [(0, 1), (1, 2), (2, 3), (3, 0)]),
    # WDC-4 flavor: same topology, rarer labels
    "T4-square-rare": ([6, 7, 8, 7], [(0, 1), (1, 2), (2, 3), (3, 0)]),
}


def timer(fn: Callable, *args, repeat: int = 1, **kwargs):
    best, out = float("inf"), None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        best = min(best, time.perf_counter() - t0)
    return out, best


def save(name: str, payload: Dict):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=_np_default)
    return path


def _np_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    return str(o)


def graph_for(scale_name: str, seed: int = 0):
    """R-MAT background graph for a named scale ("small"/"medium"/"large")."""
    from repro.graph import generators as gen
    scale = {"small": 11, "medium": 14, "large": 16}[scale_name]
    return gen.rmat_graph(scale, edge_factor=8, preset="graph500", seed=seed)


# ------------------------------------------------------- perf-trajectory roll-up
def rollup_path() -> str:
    """Repo-root perf roll-up location (env REPRO_BENCH_ROLLUP overrides)."""
    return os.environ.get("REPRO_BENCH_ROLLUP", "BENCH_pipeline.json")


def validate_rollup(payload: Dict) -> None:
    """Raise ValueError unless `payload` is a schema-valid BENCH_pipeline.json
    roll-up. The schema is load-bearing: tests/test_policy.py pins it and the
    CI smoke-benchmark job gates on it, so additions are fine but renames and
    removals are breaking."""
    def need(d, key, types, where):
        if key not in d:
            raise ValueError(f"roll-up {where} missing key {key!r}")
        if not isinstance(d[key], types):
            raise ValueError(
                f"roll-up {where}[{key!r}] is {type(d[key]).__name__}, "
                f"expected {types}")

    if not isinstance(payload, dict):
        raise ValueError("roll-up payload must be a dict")
    need(payload, "schema_version", int, "root")
    if payload["schema_version"] != ROLLUP_SCHEMA_VERSION:
        raise ValueError(
            f"roll-up schema_version {payload['schema_version']} != "
            f"{ROLLUP_SCHEMA_VERSION}")
    need(payload, "scale", str, "root")
    need(payload, "backend", str, "root")
    need(payload, "jax", str, "root")
    need(payload, "graph", dict, "root")
    need(payload, "suites", dict, "root")
    need(payload, "phases", list, "root")
    need(payload, "policy", dict, "root")
    for name, suite in payload["suites"].items():
        need(suite, "seconds", (int, float), f"suites[{name!r}]")
        need(suite, "ok", bool, f"suites[{name!r}]")
    for i, ph in enumerate(payload["phases"]):
        need(ph, "phase", str, f"phases[{i}]")
        need(ph, "seconds", (int, float), f"phases[{i}]")
    if "sharded_prune" in payload:  # additive (PR 4): sharded end-to-end point
        sp = payload["sharded_prune"]
        if not isinstance(sp, dict):
            raise ValueError("roll-up sharded_prune must be a dict")
        need(sp, "P", int, "sharded_prune")
        need(sp, "seconds", (int, float), "sharded_prune")
        need(sp, "matches_local", bool, "sharded_prune")
    if "enumeration" in payload:  # additive (PR 5): enumeration-engine point
        en = payload["enumeration"]
        if not isinstance(en, dict):
            raise ValueError("roll-up enumeration must be a dict")
        need(en, "count_seconds", (int, float), "enumeration")
        need(en, "materialize_seconds", (int, float), "enumeration")
        need(en, "n_embeddings", int, "enumeration")
        need(en, "count_matches_materialize", bool, "enumeration")
    if "distributed_join" in payload:  # additive (PR 6): row-placement point
        dj = payload["distributed_join"]
        if not isinstance(dj, dict):
            raise ValueError("roll-up distributed_join must be a dict")
        need(dj, "P", int, "distributed_join")
        need(dj, "replicated_seconds", (int, float), "distributed_join")
        need(dj, "rowsharded_seconds", (int, float), "distributed_join")
        need(dj, "counts_match", bool, "distributed_join")
        need(dj, "peak_rows_replicated", int, "distributed_join")
        need(dj, "peak_shard_rows_rowsharded", int, "distributed_join")
    if "load_balance" in payload:  # additive (PR 7): reshuffle-evenness point
        lb = payload["load_balance"]
        if not isinstance(lb, dict):
            raise ValueError("roll-up load_balance must be a dict")
        need(lb, "P", int, "load_balance")
        need(lb, "shards_holding_half_before", int, "load_balance")
        need(lb, "shards_holding_half_after", int, "load_balance")
        need(lb, "max_over_mean_before", (int, float), "load_balance")
        need(lb, "max_over_mean_after", (int, float), "load_balance")
        need(lb, "reshuffle_evens_load", bool, "load_balance")
    if "multi_tenant" in payload:  # additive (PR 9): batched-queries point
        mt = payload["multi_tenant"]
        if not isinstance(mt, dict):
            raise ValueError("roll-up multi_tenant must be a dict")
        need(mt, "B", int, "multi_tenant")
        need(mt, "batched_seconds", (int, float), "multi_tenant")
        need(mt, "sequential_seconds", (int, float), "multi_tenant")
        need(mt, "counts_match", bool, "multi_tenant")
        need(mt, "serve_queries", int, "multi_tenant")
        need(mt, "serve_dropped", int, "multi_tenant")
        need(mt, "serve_batches", int, "multi_tenant")
    if "query_plan" in payload:  # additive (PR 10): plan-level optimizer point
        qp = payload["query_plan"]
        if not isinstance(qp, dict):
            raise ValueError("roll-up query_plan must be a dict")
        need(qp, "heuristic_seconds", (int, float), "query_plan")
        need(qp, "planned_seconds", (int, float), "query_plan")
        need(qp, "heuristic_frontier_bits", int, "query_plan")
        need(qp, "planned_frontier_bits", int, "query_plan")
        need(qp, "heuristic_walks", int, "query_plan")
        need(qp, "planned_walks", int, "query_plan")
        need(qp, "reordered", bool, "query_plan")
        need(qp, "bit_identical", bool, "query_plan")
    if "resilience" in payload:  # additive (PR 7): fault-recovery point
        rs = payload["resilience"]
        if not isinstance(rs, dict):
            raise ValueError("roll-up resilience must be a dict")
        need(rs, "P", int, "resilience")
        need(rs, "restart_P", int, "resilience")
        need(rs, "phases_checkpointed", int, "resilience")
        need(rs, "checkpoint_overhead_seconds", (int, float), "resilience")
        need(rs, "recovery_seconds", (int, float), "resilience")
        need(rs, "scratch_seconds", (int, float), "resilience")
        need(rs, "parity_ok", bool, "resilience")
        need(rs, "recovered_faster_than_scratch", bool, "resilience")


def write_rollup(
    suites: Dict[str, Dict],
    scale: str,
    *,
    graph: Optional[Dict] = None,
    phases: Optional[List[Dict]] = None,
    nlcc_wave: Optional[Dict] = None,
    sharded_prune: Optional[Dict] = None,
    enumeration: Optional[Dict] = None,
    distributed_join: Optional[Dict] = None,
    load_balance: Optional[Dict] = None,
    multi_tenant: Optional[Dict] = None,
    query_plan: Optional[Dict] = None,
    resilience: Optional[Dict] = None,
    policy_fallback: Optional[Dict] = None,
    path: Optional[str] = None,
) -> str:
    """Write the repo-root BENCH_pipeline.json perf-trajectory roll-up.

    suites  {suite_name: {"seconds": wall, "ok": bool, ...}} per-suite timings
    graph   {"n": ..., "m": ...} background-graph scale actually benchmarked
    phases  [{"phase": "LCC", "seconds": ...}, ...] pipeline phase breakdown
    nlcc_wave  {"choice": route, "measured_s": {route: seconds}} — the
    measured NLCC wave time per route (the CI regression gate reads this;
    additive, so older roll-ups without it stay schema-valid)
    sharded_prune  {"P": ..., "seconds": ..., "matches_local": ...} — the
    sharded end-to-end prune point from benchmarks/strong_scaling.py
    (additive, PR 4)
    enumeration  {"count_seconds": ..., "materialize_seconds": ...,
    "n_embeddings": ..., "count_matches_materialize": ...} — the
    enumeration-engine point (counting fast path vs materialize-then-unique)
    from benchmarks/dispatch_policy.py (additive, PR 5; the CI smoke job
    gates the count/materialize ratio)
    distributed_join  {"P": ..., "replicated_seconds": ...,
    "rowsharded_seconds": ..., "counts_match": ...,
    "peak_rows_replicated": ..., "peak_shard_rows_rowsharded": ...} — the
    replicated-vs-distributed-rows placement point from
    benchmarks/distributed_join.py (additive, PR 6; the CI smoke job gates
    counts_match and the per-shard memory reduction)
    load_balance  {"P": ..., "shards_holding_half_before"/"..._after": ...,
    "max_over_mean_before"/"..._after": ..., "reshuffle_evens_load": ...} —
    the Fig. 7 reshuffle-evenness point from benchmarks/load_balance.py
    (additive, PR 7)
    multi_tenant  {"B": ..., "batched_seconds": ..., "sequential_seconds":
    ..., "counts_match": ..., "serve_queries": ..., "serve_dropped": ...,
    "serve_batches": ...} — the template-batched execution point from
    benchmarks/multi_tenant.py (additive, PR 9; the CI smoke job gates
    counts_match and batched_seconds < sequential_seconds)
    query_plan  {"heuristic_seconds": ..., "planned_seconds": ...,
    "heuristic_frontier_bits"/"planned_frontier_bits": ...,
    "heuristic_walks"/"planned_walks": ..., "reordered": ...,
    "bit_identical": ...} — the plan-level optimizer point from
    benchmarks/query_plan.py (additive, PR 10; the CI smoke job gates
    bit_identical plus the planned <= heuristic shape facts — walk
    dispatches and entering-frontier bits, both host-speed-immune)
    resilience  {"P": ..., "restart_P": ..., "phases_checkpointed": ...,
    "checkpoint_overhead_seconds": ..., "recovery_seconds": ...,
    "scratch_seconds": ..., "parity_ok": ...,
    "recovered_faster_than_scratch": ...} — the fault-recovery point from
    benchmarks/resilience.py (additive, PR 7; the CI smoke job gates
    parity_ok and recovered_faster_than_scratch)
    policy_fallback  a previously recorded "policy" block to keep when NO
    policy is active in the registry (partial --only runs on untuned
    checkouts must not wipe the committed tuning trajectory)
    The tuned dispatch decisions (chosen kernel modes + packed/unpacked/fused
    routes) come from the active registry policy. Validates before writing.
    """
    import jax
    from repro.kernels import registry

    policy = registry.get_policy()
    payload = {
        "schema_version": ROLLUP_SCHEMA_VERSION,
        "scale": scale,
        "backend": jax.default_backend(),
        "jax": jax.__version__,
        "graph": dict(graph or {}),
        "suites": suites,
        "phases": list(phases or []),
        "policy": (policy.to_json() if policy is not None
                   else dict(policy_fallback or {})),
    }
    if nlcc_wave:
        payload["nlcc_wave"] = dict(nlcc_wave)
    if sharded_prune:
        payload["sharded_prune"] = dict(sharded_prune)
    if enumeration:
        payload["enumeration"] = dict(enumeration)
    if distributed_join:
        payload["distributed_join"] = dict(distributed_join)
    if load_balance:
        payload["load_balance"] = dict(load_balance)
    if multi_tenant:
        payload["multi_tenant"] = dict(multi_tenant)
    if query_plan:
        payload["query_plan"] = dict(query_plan)
    if resilience:
        payload["resilience"] = dict(resilience)
    validate_rollup(payload)
    out = path or rollup_path()
    with open(out, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True, default=_np_default)
    return out

"""Table 10 / Fig. 13 — R-MAT degree-distribution sweep: Graph500,
Chakrabarti, Uniform presets (same scale/edge factor, different skew),
degree-based labels; full match enumeration time + counts for a Q4 flavor
and a larger 7-vertex unique-label pattern (RMAT-2 flavor)."""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.graph import generators as gen
from repro.core.template import Template
from repro.core.pipeline import prune
from repro.core.enumerate import enumerate_matches
from benchmarks.common import save

PATTERNS = {
    "Q4": ([3, 4, 5, 4, 2], [(0, 1), (0, 2), (0, 3), (1, 4)]),
    "RMAT-2": ([2, 3, 4, 5, 6, 7, 1],
               [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 0), (1, 6)]),
}


def run(scale: str = "small") -> Dict:
    sc = {"small": 10, "medium": 13, "large": 15}[scale]
    out: Dict = {"presets": {}}
    for preset in ("graph500", "chakrabarti", "uniform"):
        g = gen.rmat_graph(sc, edge_factor=8, preset=preset, seed=3)
        deg = g.degrees()
        entry = {
            "n": g.n, "m": g.m, "labels": int(g.labels.max()) + 1,
            "d_max": int(deg.max()), "d_stdev": float(deg.std()),
            "patterns": {},
        }
        for name, (labels, edges) in PATTERNS.items():
            tmpl = Template(labels, edges)
            t0 = time.perf_counter()
            res = prune(g, tmpl)
            enum = enumerate_matches(res.dg, res.state, tmpl, max_rows=20_000_000)
            secs = time.perf_counter() - t0
            entry["patterns"][name] = {
                "V*": res.counts()["V*"], "2E*": res.counts()["E*"],
                "count": enum.n_embeddings, "seconds": secs,
            }
        out["presets"][preset] = entry
    save("rmat_distributions", out)
    return out


if __name__ == "__main__":
    print(run())

"""Beyond-paper: benchmark-driven dispatch-policy autotune (the measurement
behind ROADMAP's "benchmark the packed NLCC frontier hop and packed LCC
fixpoint and decide the default").

Sweeps, on the live backend:
  - kernel modes for `bitset_spmm` at the two shapes the pipeline actually
    issues (LCC sweep width W = ceil(n0/32), NLCC wave width W = wave/32) and
    for the fused multi-hop `bitset_wave` at the NLCC wave shape —
    pallas-compiled on TPU, pallas-interpret, and the reference oracle,
  - routing for the LCC fixpoint sweep (packed vs unpacked) and the NLCC
    wave (packed per-hop launches vs unpacked boolean planes vs the fused
    wave engine) over the WDC-like templates,
then persists the winners to the dispatch-policy cache
(`registry.policy_path()`), and re-runs the full prune pipeline per template
under the tuned policy to report the end-to-end phase breakdown the
BENCH_pipeline.json roll-up records.

GraphPi-style rationale: measured per-shape schedule selection beats any
fixed heuristic; the win flips with graph/machine shape, so the decision is
re-tunable per host (docs/BENCHMARKS.md "Re-tuning on new hardware").
"""
from __future__ import annotations

from typing import Dict

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.lcc import LCC_ROUTE, TemplateDev, lcc_iteration, lcc_iteration_packed, lcc_route_bucket
from repro.core.nlcc import (
    NLCC_ROUTE, check_walk_constraint, check_walk_constraint_fused,
    check_walk_constraint_packed, nlcc_route_bucket,
)
from repro.core.enumerate import ENUM_ROUTE, enumerate_matches
from repro.core.pipeline import prune
from repro.core.state import init_state, pack_bits
from repro.core.template import Template
from repro.graph.blocked import build_blocked_structure
from repro.graph.structs import DeviceGraph
from repro.kernels import registry
from benchmarks.common import WDC_LIKE_TEMPLATES, graph_for, save, timer

WAVE = 1024  # prune()'s default NLCC wave width


def _route_template() -> Template:
    # T3-square: monocyclic, distinct labels -> no multiplicity counts, so
    # both the packed and unpacked LCC sweeps are exercisable
    labels, edges = WDC_LIKE_TEMPLATES["T3-square"]
    return Template(labels, edges)


def run(scale: str = "small") -> Dict:
    g = graph_for(scale)
    dg = DeviceGraph.from_host(g)
    bs = build_blocked_structure(
        np.asarray(dg.src), np.asarray(dg.dst), g.n, bn=256)
    backend = jax.default_backend()

    tmpl = _route_template()
    tdev = TemplateDev(tmpl)
    st = init_state(dg, tmpl)

    # measure against the pure eligibility fallback, not a stale cache
    registry.set_policy(None)

    # --- kernel-mode cases: the two bitset_spmm shapes the pipeline issues
    lcc_vals = pack_bits(st.omega)  # uint32[n, ceil(n0/32)]
    walk = (0, 1, 2, 3, 0)
    cand = jnp.stack([st.omega[:, q] for q in walk], axis=0)
    sources = np.flatnonzero(np.asarray(st.omega[:, 0]))[:WAVE]
    ids = np.full(WAVE, -1, np.int64)
    ids[: sources.size] = sources
    ids = jnp.asarray(ids, jnp.int32)
    safe = jnp.clip(ids, 0, g.n - 1)
    frontier = jnp.zeros((g.n, WAVE), dtype=bool)
    frontier = frontier.at[safe, jnp.arange(WAVE)].set(
        (ids >= 0) & jnp.take(cand[0], safe))
    nlcc_vals = pack_bits(frontier)  # uint32[n, WAVE/32]
    # hop-indexed candidacy stack for the fused wave kernel case
    nlcc_cand = jnp.where(cand[1:], jnp.uint32(0xFFFFFFFF), jnp.uint32(0))

    cases = [
        ("bitset_spmm", (lcc_vals, dg.src, dg.dst, g.n, st.edge_active, bs), {}),
        ("bitset_spmm", (nlcc_vals, dg.src, dg.dst, g.n, st.edge_active, bs), {}),
        ("bitset_wave",
         (nlcc_vals, dg.src, dg.dst, g.n, st.edge_active, nlcc_cand, bs), {}),
    ]

    # --- route cases: one LCC sweep / one NLCC wave. The NLCC wave races all
    # three engines: per-hop packed launches, boolean-plane scan, fused kernel
    nlcc_bucket = nlcc_route_bucket(st, WAVE)
    routes = [
        (LCC_ROUTE, lcc_route_bucket(st, dg), {
            registry.ROUTE_PACKED: lambda: lcc_iteration_packed(
                dg, tdev, st, bs)[0].omega,
            registry.ROUTE_UNPACKED: lambda: lcc_iteration(
                dg, tdev, st)[0].omega,
        }),
        (NLCC_ROUTE, nlcc_bucket, {
            registry.ROUTE_PACKED: lambda: check_walk_constraint_packed(
                dg, st, cand, True, ids, bs),
            registry.ROUTE_UNPACKED: lambda: check_walk_constraint(
                dg, st, cand, True, ids)[0],
            registry.ROUTE_FUSED: lambda: check_walk_constraint_fused(
                dg, st, cand, True, ids, bs),
        }),
    ]

    # --- enumeration-join routes: host numpy join vs the device-resident
    # join, per result mode, on the pruned T4-square-rare graph (|Aut| = 2 —
    # the symmetry restrictions actually fire in count mode)
    enum_labels, enum_edges = WDC_LIKE_TEMPLATES["T4-square-rare"]
    enum_tmpl = Template(enum_labels, enum_edges)
    enum_res = prune(g, enum_tmpl)
    for mode in ("materialize", "count"):
        routes.append((ENUM_ROUTE, ("local", mode), {
            registry.ROUTE_HOST: lambda m=mode: enumerate_matches(
                enum_res, mode=m, route="host").n_embeddings,
            registry.ROUTE_DEVICE: lambda m=mode: enumerate_matches(
                enum_res, mode=m, route="device").n_embeddings,
        }))

    policy = registry.tune(cases=cases, routes=routes, repeat=3)
    nlcc_entry = policy.route_entry_for(NLCC_ROUTE, backend, nlcc_bucket)

    # --- the enumeration-engine trajectory point: counting fast path
    # (symmetry-broken in-flight, rows never materialized) vs the classic
    # materialize-then-unique, under the tuned routing
    mat, t_mat = timer(
        lambda: enumerate_matches(enum_res), repeat=3)
    cnt, t_cnt = timer(
        lambda: enumerate_matches(enum_res, mode="count"), repeat=3)
    enumeration = {
        "template": "T4-square-rare",
        "count_seconds": t_cnt,
        "materialize_seconds": t_mat,
        "n_embeddings": int(mat.n_embeddings),
        "automorphisms": int(cnt.automorphisms),
        "n_canonical": int(cnt.n_canonical),
        "count_route": cnt.route,
        "materialize_route": mat.route,
        "count_matches_materialize": bool(cnt.n_embeddings == mat.n_embeddings),
    }

    # --- end-to-end: full prune per WDC template under the tuned policy
    patterns: Dict[str, Dict] = {}
    phase_totals: Dict[str, float] = {}
    for name, (labels, edges) in WDC_LIKE_TEMPLATES.items():
        res = prune(g, Template(labels, edges), blocked=bs)
        for p in res.phases:
            phase_totals[p.phase] = phase_totals.get(p.phase, 0.0) + p.seconds
        patterns[name] = {
            "total_seconds": sum(p.seconds for p in res.phases),
            "phases": [
                {"phase": p.phase, "constraint": p.constraint,
                 "seconds": p.seconds, "V*": p.active_vertices,
                 "E*": p.active_edges}
                for p in res.phases
            ],
            "solution": res.counts(),
            "dispatch_routes": res.stats.get("dispatch_routes", {}),
        }

    out = {
        "graph": {"n": g.n, "m": g.m},
        "backend": backend,
        "jax": jax.__version__,
        "policy_path": registry.policy_path(),
        "policy": policy.to_json(),
        # the measured NLCC wave (seconds per wave, per route) — the number
        # the CI smoke job gates PR-over-PR regressions on
        "nlcc_wave": {
            "bucket": registry.bucket_key(nlcc_bucket),
            "choice": nlcc_entry.choice,
            "measured_s": dict(nlcc_entry.measured_s),
        },
        # counting fast path vs materialize-then-unique (the enumeration
        # analogue of the nlcc_wave point; gated by the CI smoke job)
        "enumeration": enumeration,
        "decisions": {
            "modes": {k: e.choice for k, e in policy.modes.items()},
            "routes": {k: e.choice for k, e in policy.routes.items()},
        },
        "phase_breakdown": [
            {"phase": k, "seconds": v} for k, v in sorted(phase_totals.items())
        ],
        "patterns": patterns,
    }
    save("dispatch_policy", out)
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=1, default=str))

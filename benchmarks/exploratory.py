"""Fig. 10 — exploratory search: over-constrained template progressively
relaxed until matches appear; per-level variant counts, matched vertices and
per-variant time (the paper's 6-clique needed k=4 removals over 1,900
variants; we plant a structure so matches appear at k>=1)."""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.graph.structs import Graph
from repro.graph import generators as gen
from repro.core.template import Template
from repro.core.exploratory import exploratory_search
from benchmarks.common import graph_for, save


def run(scale: str = "small") -> Dict:
    bg = graph_for(scale)
    # rare labels (absent from the degree-labeled background) so no natural
    # matches: plant chordless diamonds; the 4-clique query over-constrains
    pattern = Graph.from_undirected_pairs(
        4, [(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)], [91, 92, 91, 92])
    g = gen.planted_pattern_graph(bg, pattern, n_copies=4, seed=7)
    clique = Template([91, 92, 91, 92],
                      [(0, 1), (1, 2), (2, 3), (3, 0), (0, 2), (1, 3)])
    res = exploratory_search(g, clique)
    out: Dict = {
        "graph": {"n": g.n, "m": g.m},
        "candidate_vertices": res.candidate_vertices,
        "found_level": res.found_level,
        "levels": [
            {"k": l.k, "variants": l.n_variants, "matched": l.matched_vertices,
             "seconds": l.seconds, "avg_per_variant": l.avg_seconds_per_variant}
            for l in res.levels
        ],
        "matched_vertices": int(res.vertex_mask.sum()),
    }
    save("exploratory", out)
    return out


if __name__ == "__main__":
    print(run())

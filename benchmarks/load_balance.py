"""Fig. 7 — load balancing: NLB vs LB (reshuffle) and the smaller-deployment
scenarios (LB-16 / LB-1). We report the paper's imbalance characterization
(shards holding half the active edges, max/mean) before and after reshuffle,
and a CPU-hours proxy (shards x per-shard max work) for elastic scale-down."""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.template import Template
from repro.core.pipeline import prune
from repro.core.loadbalance import (
    imbalance_stats, compact_and_repartition, compact_active_graph,
)
from benchmarks.common import WDC_LIKE_TEMPLATES, graph_for, save


def run(scale: str = "small") -> Dict:
    g = graph_for(scale)
    # T4 keeps a nonempty, concentrated solution (the paper's imbalance case)
    labels, edges = WDC_LIKE_TEMPLATES["T4-square-rare"]
    tmpl = Template(labels, edges)
    res = prune(g, tmpl)
    out: Dict = {"graph": {"n": g.n, "m": g.m}, "solution": res.counts(),
                 "deployments": {}}
    P0 = 64
    nlb = imbalance_stats(g, res.state, P0, res.dg)
    out["NLB"] = {
        "P": P0,
        "shards_holding_half": nlb.shards_holding_half,
        "max_over_mean": nlb.max_over_mean_edges,
        "gini": nlb.gini_edges,
    }
    for P in (64, 16, 1):
        shuffled, part, info = compact_and_repartition(g, res.dg, res.state, max(P, 1))
        after = info["imbalance_after"]
        # CPU-hours proxy: P x (max per-shard active arcs) / total arcs
        work_max = after.edges_per_shard.max() if after.edges_per_shard.size else 0
        out["deployments"][f"LB-{P}"] = {
            "P": P,
            "shards_holding_half": after.shards_holding_half,
            "max_over_mean": after.max_over_mean_edges,
            "gini": after.gini_edges,
            "cpu_work_proxy": int(P * work_max),
        }
    # BENCH_pipeline.json point (benchmarks/run.py merges it under
    # "load_balance"): the paper's headline — reshuffle spreads the active
    # edges that block partitioning concentrates. Gate on the shape fact, not
    # the (host-speed-dependent) magnitudes.
    lb64 = out["deployments"]["LB-64"]
    out["rollup"] = {
        "P": P0,
        "shards_holding_half_before": int(nlb.shards_holding_half),
        "shards_holding_half_after": int(lb64["shards_holding_half"]),
        "max_over_mean_before": float(nlb.max_over_mean_edges),
        "max_over_mean_after": float(lb64["max_over_mean"]),
        "reshuffle_evens_load": bool(
            lb64["shards_holding_half"] >= nlb.shards_holding_half
            and lb64["max_over_mean"] <= nlb.max_over_mean_edges),
    }
    save("load_balance", out)
    return out


if __name__ == "__main__":
    print(run())

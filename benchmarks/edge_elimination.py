"""Fig. 6(a) — impact of edge elimination: vertex-elimination-only vs
combined vertex+edge elimination. The paper reports 2-9x total speedup and an
order of magnitude sparser solution graph; we measure runtime, LCC/NLCC
message counts, and |E*| with and without edge elimination."""
from __future__ import annotations

from typing import Dict

from repro.core.template import Template
from repro.core.pipeline import prune
from benchmarks.common import WDC_LIKE_TEMPLATES, graph_for, save, timer


def _nlcc_messages(res) -> int:
    return sum(p.extra.get("nlcc_messages", 0) for p in res.phases)


def run(scale: str = "small") -> Dict:
    g = graph_for(scale)
    out: Dict = {"graph": {"n": g.n, "m": g.m}, "patterns": {}}
    # patterns with non-empty solutions so NLCC token passing is exercised —
    # the paper's gain is "no messages over eliminated edges" during NLCC
    for name in ("T4-square-rare", "T1-path-repeat"):
        labels, edges = WDC_LIKE_TEMPLATES[name]
        tmpl = Template(labels, edges)
        res_on, t_on = timer(
            prune, g, tmpl, edge_elimination=True, collect_stats=True)
        res_off, t_off = timer(
            prune, g, tmpl, edge_elimination=False, collect_stats=True)
        out["patterns"][name] = {
            "with_edge_elim": {
                "seconds": t_on, "solution": res_on.counts(),
                "lcc_messages": res_on.stats.get("lcc_messages"),
                "nlcc_messages": _nlcc_messages(res_on),
            },
            "without_edge_elim": {
                "seconds": t_off, "solution": res_off.counts(),
                "lcc_messages": res_off.stats.get("lcc_messages"),
                "nlcc_messages": _nlcc_messages(res_off),
            },
            "speedup": t_off / max(t_on, 1e-9),
            "nlcc_message_reduction": (
                _nlcc_messages(res_off) / max(_nlcc_messages(res_on), 1)),
            "edge_reduction": (
                res_off.counts()["E*"] / max(res_on.counts()["E*"], 1)
            ),
        }
    save("edge_elimination", out)
    return out


if __name__ == "__main__":
    print(run())

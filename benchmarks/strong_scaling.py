"""Fig. 5 — strong scaling: phase breakdown (LCC / NLCC per constraint) across
shard counts. On this CPU host true wall-clock scaling cannot be measured;
following the paper's own methodology we report, per shard count P:
per-phase wall time of the single-device engine, plus the distributed
engine's per-shard work distribution (max/mean active arcs per shard — the
quantity that bounds strong scaling, §5.3).

Also records one sharded END-TO-END prune point (the full pipeline through
the sim execution backend, core/engine.py) — wall seconds plus a bit-parity
check against the local engine. `benchmarks.run` copies it into the
BENCH_pipeline.json roll-up under the additive `sharded_prune` key, so the
sharded path's cost trajectory is visible PR-over-PR alongside the
single-device phases."""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.core.template import Template
from repro.core.pipeline import prune
from repro.core.loadbalance import imbalance_stats
from repro.graph.partition import partition_graph
from repro.graph.structs import DeviceGraph
from benchmarks.common import WDC_LIKE_TEMPLATES, graph_for, save, timer

SHARDED_PRUNE_P = 4
SHARDED_PRUNE_TEMPLATE = "T3-square"


def run(scale: str = "small") -> Dict:
    g = graph_for(scale)
    dg = DeviceGraph.from_host(g)
    out: Dict = {"graph": {"n": g.n, "m": g.m}, "patterns": {}}
    local_result = None
    for name, (labels, edges) in WDC_LIKE_TEMPLATES.items():
        tmpl = Template(labels, edges)
        res = prune(g, tmpl, collect_stats=True)
        if name == SHARDED_PRUNE_TEMPLATE:  # parity baseline, reused below
            local_result = res
        phases = [
            {"phase": p.phase, "constraint": p.constraint, "seconds": p.seconds,
             "V*": p.active_vertices, "E*": p.active_edges}
            for p in res.phases
        ]
        shards = {}
        for P in (4, 16, 64):
            st = imbalance_stats(g, res.state, P, dg)
            shards[P] = {
                "max_over_mean_edges": st.max_over_mean_edges,
                "gini": st.gini_edges,
                "shards_holding_half": st.shards_holding_half,
            }
        out["patterns"][name] = {
            "phases": phases,
            "total_seconds": sum(p.seconds for p in res.phases),
            "solution": res.counts(),
            "per_shard_balance": shards,
            "stats": res.stats,
        }

    # sharded end-to-end point: the whole pipeline through the sim backend.
    # The parity baseline is the loop's local result above — routing differs
    # under collect_stats but the pruned bits are route-invariant (pinned by
    # the parity suite), so no second local prune is paid.
    labels, edges = WDC_LIKE_TEMPLATES[SHARDED_PRUNE_TEMPLATE]
    tmpl = Template(labels, edges)
    local = local_result
    part = partition_graph(g, SHARDED_PRUNE_P)
    sharded, secs = timer(lambda: prune(g, tmpl, partition=part))
    out["sharded_prune"] = {
        "P": SHARDED_PRUNE_P,
        "template": SHARDED_PRUNE_TEMPLATE,
        "backend": sharded.stats["backend"],
        "seconds": secs,
        "nlcc_route": sharded.stats["dispatch_routes"]["prune.nlcc"],
        "solution": sharded.counts(),
        "matches_local": bool(
            np.array_equal(local.omega, sharded.omega)
            and np.array_equal(local.edge_mask, sharded.edge_mask)),
    }
    save("strong_scaling", out)
    return out


if __name__ == "__main__":
    print(run())

"""Fig. 5 — strong scaling: phase breakdown (LCC / NLCC per constraint) across
shard counts. On this CPU host true wall-clock scaling cannot be measured;
following the paper's own methodology we report, per shard count P:
per-phase wall time of the single-device engine, plus the distributed
engine's per-shard work distribution (max/mean active arcs per shard — the
quantity that bounds strong scaling, §5.3)."""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.core.template import Template
from repro.core.pipeline import prune
from repro.core.loadbalance import imbalance_stats
from repro.graph.structs import DeviceGraph
from benchmarks.common import WDC_LIKE_TEMPLATES, graph_for, save


def run(scale: str = "small") -> Dict:
    g = graph_for(scale)
    dg = DeviceGraph.from_host(g)
    out: Dict = {"graph": {"n": g.n, "m": g.m}, "patterns": {}}
    for name, (labels, edges) in WDC_LIKE_TEMPLATES.items():
        tmpl = Template(labels, edges)
        res = prune(g, tmpl, collect_stats=True)
        phases = [
            {"phase": p.phase, "constraint": p.constraint, "seconds": p.seconds,
             "V*": p.active_vertices, "E*": p.active_edges}
            for p in res.phases
        ]
        shards = {}
        for P in (4, 16, 64):
            st = imbalance_stats(g, res.state, P, dg)
            shards[P] = {
                "max_over_mean_edges": st.max_over_mean_edges,
                "gini": st.gini_edges,
                "shards_holding_half": st.shards_holding_half,
            }
        out["patterns"][name] = {
            "phases": phases,
            "total_seconds": sum(p.seconds for p in res.phases),
            "solution": res.counts(),
            "per_shard_balance": shards,
            "stats": res.stats,
        }
    save("strong_scaling", out)
    return out


if __name__ == "__main__":
    print(run())

"""Plan-level query optimizer: planned vs heuristic constraint order on an
adversarial template (core/planner.py).

The adversarial shape: one template holding BOTH a frequent-label triangle
(short walk, expensive, weakly selective) and a rare-label square (longer
walk, cheap, highly selective), sharing a vertex. The paper's heuristic
order sorts non-local constraints by walk length first, so it runs the
expensive triangle against the full post-LCC frontier before the square
has had a chance to shrink it. The planner's calibrated cost model sees
through the length tie-break and runs the rare-label square first. Both
orders end in the complete edge-cover TDS phase, which maps any sound
intermediate superset to the exact match set — the two runs must be
BIT-IDENTICAL (hard assert -> bit_identical).

CI gates on shape facts, not wall time (host-speed-immune):
  - bit_identical (omega + edge mask + match counts),
  - planned_walks <= heuristic_walks — NLCC walk dispatches each order
    issues (the planner's direction choice runs one cycle rotation where
    the default runs them all), and
  - planned_frontier_bits <= heuristic_frontier_bits — total omega
    candidacy bits ENTERING each non-local constraint phase.
All three are pure functions of the chosen plan and the graph — none
depends on how fast this host runs. Wall seconds for both orders are
recorded for the perf trajectory.
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np

from repro.core import count_matches, heuristic_plan, plan_query
from repro.core import nlcc as nlcc_mod
from repro.core import planner
from repro.core.template import generate_constraints
from repro.core.pipeline import prune
from repro.core.template import Template
from repro.graph import collect_graph_stats
from repro.graph import generators as gen
from repro.graph.structs import Graph
from benchmarks.common import graph_for, save

# graph_for labels by degree: l(v) = ceil(log2(deg+1)) — labels 2-4 are the
# frequent bulk, labels 7-10 the rare high-degree tail. Triangle 0-1-2 on
# frequent labels; square 0-3-4-5 descending into rare labels. Both emit
# cycle constraints; the triangle's walk is shorter, so the heuristic runs
# it first — the planner should not.
LABELS = [3, 2, 3, 8, 9, 7]
EDGES = [(0, 1), (1, 2), (2, 0),            # frequent-label triangle
         (0, 3), (3, 4), (4, 5), (5, 0)]    # rare-label selective square
TEMPLATE = Template(LABELS, EDGES)
N_PLANTED = 5


def _walk_dispatches(qp) -> int:
    """NLCC walk expansions the plan issues — each is its own wave-loop
    dispatch sequence, so fewer walks on the same frontier is strictly less
    device work (nlcc.expand_walks is the one expansion rule)."""
    return sum(len(nlcc_mod.expand_walks(p.constraint, p.direction))
               for p in qp.phases if p.engine == planner.ENGINE_NLCC)


def _frontier_bits(res) -> int:
    """Total omega candidacy bits entering each non-local constraint phase —
    the structural work proxy the plan gate reads. The trajectory interleaves
    constraint phases with conditional LCC re-runs; each phase's entering
    frontier is the omega_bits its predecessor left behind."""
    total = 0
    for prev, ph in zip(res.phases, res.phases[1:]):
        if ph.phase.startswith("NLCC"):
            total += int(prev.omega_bits)
    return total


def run(scale: str = "small") -> Dict:
    bg = graph_for(scale)
    # plant matches so the adversarial query is a needle search, not a
    # provably-empty one — the planted copies keep every phase's surviving
    # frontier (and the final match count) non-trivial
    pattern = Graph.from_undirected_pairs(TEMPLATE.n0, EDGES, LABELS)
    g = gen.planted_pattern_graph(bg, pattern, n_copies=N_PLANTED, seed=7)
    label_freq = g.label_frequency()
    st = collect_graph_stats(g)
    qp = plan_query(TEMPLATE, st, label_freq=label_freq)

    # warm-up both orders: steady-state comparison, not first-touch tracing
    prune(g, TEMPLATE, label_freq=label_freq)
    prune(g, TEMPLATE, plan=qp, label_freq=label_freq)

    t0 = time.perf_counter()
    heur = prune(g, TEMPLATE, label_freq=label_freq)
    heuristic_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    planned = prune(g, TEMPLATE, plan=qp, label_freq=label_freq)
    planned_s = time.perf_counter() - t0

    bit_identical = (
        np.array_equal(np.asarray(heur.state.omega),
                       np.asarray(planned.state.omega))
        and np.array_equal(np.asarray(heur.state.edge_active),
                           np.asarray(planned.state.edge_active)))
    ch = int(count_matches(heur.dg, heur.state, TEMPLATE,
                           label_freq=label_freq).n_embeddings)
    cp = int(count_matches(planned.dg, planned.state, TEMPLATE,
                           label_freq=label_freq).n_embeddings)
    bit_identical = bool(bit_identical and ch == cp)
    assert bit_identical, ("planned order diverged from heuristic", ch, cp)

    heuristic_bits = _frontier_bits(heur)
    planned_bits = _frontier_bits(planned)
    cs = generate_constraints(TEMPLATE, label_freq=label_freq)
    heuristic_walks = _walk_dispatches(heuristic_plan(cs))
    planned_walks = _walk_dispatches(qp)

    out = {
        "graph": {"n": g.n, "m": g.m},
        "template": {"n0": TEMPLATE.n0, "m0": TEMPLATE.m0},
        "plan_source": qp.source,
        "plan": [{"sig": p.signature, "engine": p.engine,
                  "direction": p.direction} for p in qp.phases],
        "heuristic_order": [ph["sig"]
                            for ph in heur.stats["plan"]["phases"]],
        "predicted_s": qp.predicted_s,
        "heuristic_seconds": heuristic_s,
        "planned_seconds": planned_s,
        "speedup": heuristic_s / max(planned_s, 1e-9),
        "heuristic_frontier_bits": heuristic_bits,
        "planned_frontier_bits": planned_bits,
        "heuristic_walks": heuristic_walks,
        "planned_walks": planned_walks,
        "bit_identical": bit_identical,
        "n_embeddings": ch,
        "predicted_vs_actual": [
            {"sig": ph["sig"], "predicted_s": ph["predicted_s"],
             "actual_s": ph["actual_s"]}
            for ph in planned.stats["plan"]["phases"]],
        "rollup": {
            "heuristic_seconds": heuristic_s,
            "planned_seconds": planned_s,
            "heuristic_frontier_bits": heuristic_bits,
            "planned_frontier_bits": planned_bits,
            "heuristic_walks": heuristic_walks,
            "planned_walks": planned_walks,
            "reordered": not qp.is_heuristic(),
            "bit_identical": bit_identical,
        },
    }
    save("query_plan", out)
    return out


if __name__ == "__main__":
    import json
    print(json.dumps(run(), indent=2, default=str))

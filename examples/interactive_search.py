"""Interactive scenarios from paper §5.4: incremental search (user revises
the template, the system reuses the candidate set + past constraint work)
and exploratory search (over-constrained template progressively relaxed).

  PYTHONPATH=src python examples/interactive_search.py
"""
import numpy as np

from repro.graph import generators as gen
from repro.graph.structs import Graph
from repro.core.template import Template
from repro.core.incremental import IncrementalSession
from repro.core.exploratory import exploratory_search

g = gen.rmat_graph(11, edge_factor=8, seed=0)  # degree labels

# --- incremental: add edges one at a time (Fig. 8 flavor)
labels = [4, 3, 5, 3, 4]
revisions = [
    [(0, 1), (1, 2), (2, 3), (3, 4)],
    [(0, 1), (1, 2), (2, 3), (3, 4), (4, 0)],
    [(0, 1), (1, 2), (2, 3), (3, 4), (4, 0), (1, 3)],
]
session = IncrementalSession(g, Template(labels, revisions[0]))
print("incremental search:")
for es in revisions:
    state, stat = session.search(Template(labels, es))
    print(f"  m0={stat.template_edges}: {stat.matched_vertices:6d} vertices, "
          f"{stat.seconds*1e3:7.1f} ms, "
          f"{stat.constraints_reused}/{stat.constraints_checked} constraints reused")

# --- exploratory: over-constrained clique, relax until matches appear
# (rare labels so the background holds no natural label-44 cliques; the
# planted 4-cycles only match after both chords are relaxed away)
bg = gen.rmat_graph(10, edge_factor=6, seed=3, labeler="random", n_labels=50)
square = Graph.from_undirected_pairs(4, [(0, 1), (1, 2), (2, 3), (3, 0)],
                                     [44, 44, 44, 44])
g2 = gen.planted_pattern_graph(bg, square, n_copies=3, seed=4)
clique = Template([44, 44, 44, 44],
                  [(0, 1), (1, 2), (2, 3), (3, 0), (0, 2), (1, 3)])
res = exploratory_search(g2, clique)
print("exploratory search (4-clique query, only 4-cycles exist):")
for l in res.levels:
    print(f"  k={l.k}: {l.n_variants:3d} variants, matched={l.matched_vertices:5d}, "
          f"{l.avg_seconds_per_variant*1e3:6.1f} ms/variant")
print(f"first matches at k={res.found_level}")
assert res.found_level is not None and res.found_level >= 1
print("OK")

"""Quickstart: the paper's pipeline end-to-end on a small graph.

  PYTHONPATH=src python examples/quickstart.py

1. Build a labeled background graph (R-MAT) and plant a needle pattern.
2. Decompose the search template into constraints (Table 2).
3. Prune via LCC + NLCC to the exact solution subgraph (100% P/R).
4. Enumerate and count all matches on the pruned graph.
"""
import numpy as np

from repro.graph import generators as gen
from repro.graph.structs import Graph
from repro.core.template import Template, generate_constraints
from repro.core.pipeline import prune
from repro.core.enumerate import enumerate_matches

# 1. background graph + planted diamond pattern
background = gen.rmat_graph(12, edge_factor=8, seed=0, labeler="random", n_labels=8)
needle = Graph.from_undirected_pairs(
    4, [(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)], [9, 8, 9, 8])
g = gen.planted_pattern_graph(background, needle, n_copies=5, seed=1)
print(f"background graph: {g.n} vertices, {g.m} arcs, "
      f"{g.n_labels} labels")

# 2. the search template and its constraint decomposition
template = Template([9, 8, 9, 8], [(0, 1), (1, 2), (2, 3), (3, 0), (0, 2)])
for c in generate_constraints(template, label_freq=g.label_frequency()):
    print(f"  constraint: {c.kind:6s} walk={c.walk} complete={c.complete}")

# 3. prune
result = prune(g, template)
print(f"solution subgraph: {result.counts()} "
      f"(pruned from n={g.n}, m={g.m})")
for p in result.phases:
    print(f"  {p.phase:12s} {str(p.constraint or ''):42s} "
          f"V*={p.active_vertices:6d} E*={p.active_edges:7d} {p.seconds*1e3:7.1f} ms")

# 4. enumerate on the pruned graph
enum = enumerate_matches(result.dg, result.state, template)
print(f"matches: {enum.n_embeddings} embeddings, "
      f"{enum.n_distinct_vertex_sets} distinct vertex sets, "
      f"|Aut|={enum.automorphisms}")
assert enum.n_embeddings >= 5 * enum.automorphisms  # the planted needles
print("OK")

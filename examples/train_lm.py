"""End-to-end driver (deliverable (b)): train a ~100M-param LM for a few
hundred steps with the full production substrate — microbatched train step,
remat, AdamW + cosine schedule, checkpoint/restart mid-run.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]

~100M params: 12 layers x d_model 768 x GQA 12/4 heads x d_ff 2048, vocab 8k.
On CPU this runs a genuinely converging run at a reduced step count by
default; pass --steps 300 for the full demonstration.
"""
import argparse
import tempfile

import jax

from repro.configs.base import LMConfig
from repro.train import TrainConfig, build_train_step, init_state, trainer
from repro.optim.adamw import AdamWConfig
from repro.data import SyntheticTokenStream

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--batch", type=int, default=16)
ap.add_argument("--seq", type=int, default=128)
args = ap.parse_args()

cfg = LMConfig(
    name="lm-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
    d_ff=2048, vocab=8192, dtype="float32",
)
from repro.configs.base import LMConfig as _  # noqa
n_params = cfg.n_params()
print(f"model: {n_params/1e6:.1f}M params")

tc = TrainConfig(
    optimizer=AdamWConfig(lr=3e-4, weight_decay=0.01),
    microbatches=2, remat=True,
    warmup_steps=max(args.steps // 10, 1), total_steps=args.steps,
)
state, specs = init_state(jax.random.key(0), cfg, tc)
step = jax.jit(build_train_step(cfg, tc), donate_argnums=(0,))
stream = SyntheticTokenStream(cfg.vocab, args.batch, args.seq, seed=0)

with tempfile.TemporaryDirectory() as ckpt_dir:
    # train the first half, simulate a crash, resume for the second half
    half = args.steps // 2

    class Bomb:
        armed = True
    def fail_once(s):
        if s == half and Bomb.armed:
            Bomb.armed = False
            raise trainer.SimulatedFailure("node failure injected")

    report = trainer.run(
        state, step, stream, num_steps=args.steps,
        ckpt_dir=ckpt_dir, ckpt_interval=max(half // 2, 1),
        fail_hook=fail_once, log_every=10,
    )
    print(f"restarts survived: {report.restarts}")
    print(f"loss: {report.losses[0]:.4f} -> {report.losses[-1]:.4f}")
    assert report.losses[-1] < report.losses[0]
    print("OK")

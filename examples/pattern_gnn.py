"""Paper technique x GNN integration: PruneJuice pruning as the data
selection stage for GNN training (DESIGN.md §5 'beyond-paper feature').

  PYTHONPATH=src python examples/pattern_gnn.py

1. Prune a labeled graph to the union of all matches of a template.
2. Train a PNA node classifier ON the pruned subgraph, with the engine's
   per-vertex omega annotations as extra input features.
"""
import numpy as np
import jax

from repro.graph import generators as gen
from repro.graph.structs import Graph
from repro.core.template import Template
from repro.data import PatternFilteredDataset
from repro.configs import get_arch
from repro.train import TrainConfig, build_train_step, init_state
from repro.optim.adamw import AdamWConfig

bg = gen.rmat_graph(11, edge_factor=8, seed=0, labeler="random", n_labels=6)
needle = Graph.from_undirected_pairs(3, [(0, 1), (1, 2), (2, 0)], [4, 5, 3])
g = gen.planted_pattern_graph(bg, needle, n_copies=30, seed=2)
template = Template([4, 5, 3], [(0, 1), (1, 2), (2, 0)])

D_FEAT, N_CLASSES = 16, 4
ds = PatternFilteredDataset(g, template, d_feat=D_FEAT, n_classes=N_CLASSES, seed=0)
print(f"background: n={g.n} m={g.m}; pruned to {ds.prune_counts} "
      f"(omega features: {ds.omega.shape[1]})")

cfg = get_arch("pna").smoke()
tc = TrainConfig(optimizer=AdamWConfig(lr=5e-3, weight_decay=0.0))
state, _ = init_state(jax.random.key(0), cfg, tc,
                      d_in=D_FEAT + template.n0, n_classes=N_CLASSES)
step = jax.jit(build_train_step(cfg, tc))
losses = []
for i in range(30):
    state, metrics = step(state, ds(i))
    losses.append(float(metrics["loss"]))
print(f"PNA on pruned graph: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
assert losses[-1] < losses[0]
print("OK")
